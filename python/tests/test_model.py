"""L2 invariants: prefill/decode consistency, sampling semantics, training
step semantics — everything the rust runtime relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import transformer as tfm
from compile.configs import EOS, PAD, artifact_config

jax.config.update("jax_platform_name", "cpu")

ACFG = artifact_config("tiny", engine_batch=4, decode_chunk=8, train_batch=4)
CFG = ACFG.model


@pytest.fixture(scope="module")
def params():
    return tfm.init_params(CFG, jax.random.PRNGKey(0))


def test_param_spec_matches_init(params):
    spec = tfm.param_spec(CFG)
    assert len(spec) == len(params)
    for (name, shape), p in zip(spec, params):
        assert tuple(shape) == p.shape, name
    assert sum(int(np.prod(s)) for _, s in spec) == CFG.param_count()


def test_prefill_last_logits_match_forward(params):
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, CFG.max_seq), 3, CFG.vocab)
    lens = jnp.array([5, 9, 3, 12], jnp.int32)
    logits = tfm.forward(CFG, params, toks)
    _, last = tfm.prefill(CFG, params, toks, lens)
    want = jnp.take_along_axis(logits, (lens - 1)[:, None, None], axis=1)[:, 0]
    np.testing.assert_allclose(last, want, rtol=1e-4, atol=1e-5)


def test_decode_chain_matches_forward(params):
    """Teacher-force tokens through decode_one; logits must match the full
    causal forward at every step (the KV cache is exact, not approximate)."""
    b, n = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, n), 3, CFG.vocab)
    full = tfm.forward(CFG, params, toks)

    kv = jnp.zeros(tfm.kv_cache_shape(CFG, b), jnp.float32)
    active = jnp.ones((b,), bool)
    for t in range(n):
        pos = jnp.full((b,), t, jnp.int32)
        kv, logits = tfm.decode_one(CFG, params, kv, toks[:, t], pos, active,
                                    use_pallas=True)
        np.testing.assert_allclose(logits, full[:, t], rtol=2e-4, atol=1e-4,
                                   err_msg=f"step {t}")


def test_decode_pallas_and_ref_paths_agree(params):
    b = 3
    kv = jax.random.normal(jax.random.PRNGKey(3), tfm.kv_cache_shape(CFG, b)) * 0.3
    tok = jnp.array([5, 9, 11], jnp.int32)
    pos = jnp.array([4, 7, 2], jnp.int32)
    act = jnp.ones((b,), bool)
    kv1, l1 = tfm.decode_one(CFG, params, kv, tok, pos, act, use_pallas=True)
    kv2, l2 = tfm.decode_one(CFG, params, kv, tok, pos, act, use_pallas=False)
    np.testing.assert_allclose(l1, l2, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(kv1, kv2, rtol=2e-5, atol=1e-5)


def test_prefill_then_decode_continues_consistently(params):
    """prefill(prompt) + decode_one(next_tok, pos=len) must equal the full
    forward over prompt+next_tok — the engine's resume invariant."""
    b = 4
    plen = 6
    toks = jax.random.randint(jax.random.PRNGKey(4), (b, plen + 1), 3, CFG.vocab)
    prompt = jnp.pad(toks[:, :plen], ((0, 0), (0, CFG.max_seq - plen)))
    lens = jnp.full((b,), plen, jnp.int32)
    kv, _ = tfm.prefill(CFG, params, prompt, lens)
    kv, logits = tfm.decode_one(CFG, params, kv, toks[:, plen], lens,
                                jnp.ones((b,), bool), use_pallas=True)
    full = tfm.forward(CFG, params, toks)[:, plen]
    np.testing.assert_allclose(logits, full, rtol=2e-4, atol=1e-4)


class TestDecodeChunk:
    def _run(self, params, kv, tok, pos, active, u, temp=1.0):
        dc = M.make_decode_chunk(ACFG, use_pallas=True)
        return jax.jit(dc)(*params, kv, tok, pos, active, u,
                           jnp.float32(temp))

    def test_greedy_is_deterministic(self, params):
        b, k = ACFG.engine_batch, ACFG.decode_chunk
        kv = jnp.zeros(tfm.kv_cache_shape(CFG, b), jnp.float32)
        tok = jnp.full((b,), 3, jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        act = jnp.ones((b,), jnp.int32)
        u = -jnp.ones((b, k))                    # negative -> greedy
        _, _, _, _, t1, lp1 = self._run(params, kv, tok, pos, act, u)
        _, _, _, _, t2, lp2 = self._run(params, kv, tok, pos, act, u)
        assert (t1 == t2).all()
        np.testing.assert_allclose(lp1, lp2)

    def test_inactive_lane_emits_pad_and_freezes(self, params):
        b, k = ACFG.engine_batch, ACFG.decode_chunk
        kv = jnp.zeros(tfm.kv_cache_shape(CFG, b), jnp.float32)
        tok = jnp.full((b,), 3, jnp.int32)
        pos = jnp.array([0, 5, 0, 7], jnp.int32)
        act = jnp.array([1, 0, 1, 0], jnp.int32)
        u = jax.random.uniform(jax.random.PRNGKey(5), (b, k))
        _, tok2, pos2, act2, toks, logps = self._run(params, kv, tok, pos, act, u)
        assert (toks[1] == PAD).all() and (toks[3] == PAD).all()
        assert (logps[1] == 0).all() and (logps[3] == 0).all()
        assert pos2[1] == 5 and pos2[3] == 7
        assert act2[1] == 0 and act2[3] == 0

    def test_inactive_lane_does_not_corrupt_cache(self, params):
        """An inactive lane writes only to the trash slot S-1."""
        b, k = ACFG.engine_batch, ACFG.decode_chunk
        kv = jax.random.normal(jax.random.PRNGKey(6),
                               tfm.kv_cache_shape(CFG, b)) * 0.1
        tok = jnp.full((b,), 3, jnp.int32)
        pos = jnp.array([2, 5, 3, 7], jnp.int32)
        act = jnp.array([0, 0, 0, 0], jnp.int32)
        u = jax.random.uniform(jax.random.PRNGKey(7), (b, k))
        kv2, *_ = self._run(params, kv, tok, pos, act, u)
        np.testing.assert_allclose(kv2[:, :, :, :, :-1], kv[:, :, :, :, :-1],
                                   rtol=1e-6, atol=1e-6)

    @staticmethod
    def _force_logits(params, col_vals):
        """Make logits constant: lnf_scale=0, lnf_bias=e0, lm_head[0,c]=v."""
        p = list(params)
        spec = [n for n, _ in tfm.param_spec(CFG)]
        p[spec.index("lnf_scale")] = jnp.zeros(CFG.d_model)
        p[spec.index("lnf_bias")] = jnp.zeros(CFG.d_model).at[0].set(1.0)
        head = jnp.zeros_like(p[spec.index("lm_head")])
        for c, v in col_vals:
            head = head.at[0, c].set(v)
        p[spec.index("lm_head")] = head
        return p

    def test_eos_freezes_lane_mid_chunk(self, params):
        """Force EOS deterministically via a constant logit vector."""
        p = self._force_logits(params, [(EOS, 10.0)])
        b, k = ACFG.engine_batch, ACFG.decode_chunk
        kv = jnp.zeros(tfm.kv_cache_shape(CFG, b), jnp.float32)
        tok = jnp.full((b,), 3, jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        act = jnp.ones((b,), jnp.int32)
        u = -jnp.ones((b, k))                    # greedy -> always EOS
        _, _, pos2, act2, toks, _ = self._run(p, kv, tok, pos, act, u)
        assert (toks[:, 0] == EOS).all()
        assert (toks[:, 1:] == PAD).all()
        assert (act2 == 0).all()
        assert (pos2 == 1).all()

    def test_position_limit_deactivates(self, params):
        b, k = ACFG.engine_batch, ACFG.decode_chunk
        s = CFG.max_seq
        kv = jnp.zeros(tfm.kv_cache_shape(CFG, b), jnp.float32)
        tok = jnp.full((b,), 3, jnp.int32)
        pos = jnp.full((b,), s - 3, jnp.int32)   # one step before the limit
        act = jnp.ones((b,), jnp.int32)
        u = jax.random.uniform(jax.random.PRNGKey(8), (b, k))
        _, _, pos2, act2, toks, _ = self._run(params, kv, tok, pos, act, u)
        assert (act2 == 0).all()
        assert (pos2 <= s - 2).all()

    def test_sampling_follows_uniform_inverse_cdf(self, params):
        """u=0 must pick the first token with nonzero prob; u→1 the last."""
        # concentrate mass on tokens 10 and 20 (roughly 50/50)
        p = self._force_logits(params, [(10, 8.0), (20, 8.0)])
        b, k = ACFG.engine_batch, ACFG.decode_chunk
        kv = jnp.zeros(tfm.kv_cache_shape(CFG, b), jnp.float32)
        tok = jnp.full((b,), 3, jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        act = jnp.ones((b,), jnp.int32)
        u = jnp.full((b, k), 0.01)
        _, _, _, _, toks, _ = self._run(p, kv, tok, pos, act, u)
        assert (toks[:, 0] == 10).all()
        u = jnp.full((b, k), 0.99)
        _, _, _, _, toks, _ = self._run(p, kv, tok, pos, act, u)
        assert (toks[:, 0] == 20).all()


class TestTrainStep:
    def _setup(self, params):
        n = len(params)
        zeros = [jnp.zeros_like(x) for x in params]
        b, t = ACFG.train_batch, ACFG.train_seq
        toks = jax.random.randint(jax.random.PRNGKey(9), (b, t), 3, CFG.vocab)
        mask = jnp.zeros((b, t)).at[:, 4:40].set(1.0)
        lp = M.make_logprob(ACFG)(*params, toks)[0]
        return n, zeros, toks, mask, lp

    def test_ratio_one_loss_equals_neg_mean_adv(self, params):
        n, zeros, toks, mask, lp = self._setup(params)
        adv = jnp.ones_like(mask) * 0.7
        ts = jax.jit(M.make_train_step(ACFG))
        out = ts(*params, *zeros, *zeros, jnp.int32(0), toks, mask, adv, lp,
                 jnp.float32(1e-3))
        step, loss, ratio, clipf, ent, kl, gnorm = out[3 * n:]
        assert int(step) == 1
        np.testing.assert_allclose(float(loss), -0.7, rtol=1e-5)
        np.testing.assert_allclose(float(ratio), 1.0, rtol=1e-5)
        assert float(clipf) == 0.0
        np.testing.assert_allclose(float(kl), 0.0, atol=1e-6)
        assert float(gnorm) > 0

    def test_positive_advantage_increases_logp(self, params):
        """One PPO step with adv>0 must raise the response tokens' logp."""
        n, zeros, toks, mask, lp = self._setup(params)
        adv = jnp.ones_like(mask)
        ts = jax.jit(M.make_train_step(ACFG))
        out = ts(*params, *zeros, *zeros, jnp.int32(0), toks, mask, adv, lp,
                 jnp.float32(1e-2))
        new_params = list(out[:n])
        lp2 = M.make_logprob(ACFG)(*new_params, toks)[0]
        gain = ((lp2 - lp) * mask).sum() / mask.sum()
        assert float(gain) > 0, float(gain)

    def test_pallas_and_ref_train_step_agree(self, params):
        n, zeros, toks, mask, lp = self._setup(params)
        adv = jax.random.normal(jax.random.PRNGKey(10), mask.shape)
        a = jax.jit(M.make_train_step(ACFG, use_pallas=True))(
            *params, *zeros, *zeros, jnp.int32(0), toks, mask, adv, lp,
            jnp.float32(1e-3))
        b = jax.jit(M.make_train_step(ACFG, use_pallas=False))(
            *params, *zeros, *zeros, jnp.int32(0), toks, mask, adv, lp,
            jnp.float32(1e-3))
        np.testing.assert_allclose(float(a[3 * n + 1]), float(b[3 * n + 1]),
                                   rtol=1e-5)
        for x, y in zip(a[:n], b[:n]):
            # Adam's rsqrt amplifies f32 noise on near-zero grads; tolerate it.
            np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-5)

    def test_sft_decreases_loss(self, params):
        n, zeros, toks, mask, _ = self._setup(params)
        sft = jax.jit(M.make_sft_step(ACFG))
        p, m, v = list(params), list(zeros), list(zeros)
        step = jnp.int32(0)
        losses = []
        for _ in range(8):
            out = sft(*p, *m, *v, step, toks, mask, jnp.float32(3e-3))
            p, m, v = list(out[:n]), list(out[n:2 * n]), list(out[2 * n:3 * n])
            step = out[3 * n]
            losses.append(float(out[3 * n + 1]))
        assert losses[-1] < losses[0] * 0.9, losses


def test_logprob_alignment(params):
    """logprob[t] is the log-prob of tokens[t] given tokens[<t]; slot 0 is 0."""
    b, t = ACFG.train_batch, ACFG.train_seq
    toks = jax.random.randint(jax.random.PRNGKey(11), (b, t), 3, CFG.vocab)
    lp = M.make_logprob(ACFG)(*params, toks)[0]
    assert lp.shape == (b, t)
    assert (lp[:, 0] == 0).all()
    logits = tfm.forward(CFG, params, toks)
    want = jnp.take_along_axis(jax.nn.log_softmax(logits[:, :-1], -1),
                               toks[:, 1:, None], -1)[..., 0]
    np.testing.assert_allclose(lp[:, 1:], want, rtol=1e-5, atol=1e-6)
