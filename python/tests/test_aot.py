"""AOT bridge: the emitted HLO text + manifest must be loadable and
self-consistent — this is the contract the rust runtime compiles against."""

import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.configs import VOCAB, artifact_config
from compile import transformer as tfm

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    acfg = artifact_config("tiny", engine_batch=2, decode_chunk=4, train_batch=2)
    manifest = aot.build(acfg, out)
    return out, manifest, acfg


def test_all_entry_files_exist_and_hash(built):
    out, manifest, _ = built
    assert set(manifest["entries"]) == {
        "init", "prefill", "decode_chunk", "train_step", "sft_step", "logprob"}
    for name, e in manifest["entries"].items():
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), name
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]


def test_entry_layouts_match_manifest(built):
    """The HLO entry_computation_layout must list exactly the manifest's
    input shapes in order — rust marshals literals by this contract."""
    out, manifest, _ = built
    for name, e in manifest["entries"].items():
        header = open(os.path.join(out, e["file"])).readline()
        layout = header.split("entry_computation_layout={")[1]
        args = layout.split("->")[0]
        hlo_ty = {"f32": "f32", "i32": "s32"}   # HLO spells int32 "s32"
        for t in e["inputs"]:
            dims = ",".join(str(d) for d in t["shape"])
            token = f"{hlo_ty[t['dtype']]}[{dims}]"
            assert token in args, (name, token, args[:200])


def test_param_manifest_matches_spec(built):
    _, manifest, acfg = built
    spec = tfm.param_spec(acfg.model)
    assert len(manifest["params"]) == len(spec)
    for entry, (name, shape) in zip(manifest["params"], spec):
        assert entry["name"] == name
        assert entry["shape"] == list(shape)


def test_vocab_embedded(built):
    _, manifest, _ = built
    assert manifest["vocab"] == VOCAB
    assert manifest["model"]["vocab"] == len(VOCAB)


def test_train_io_symmetry(built):
    """train_step outputs params+adam state with identical names/shapes as
    inputs — the rust trainer swaps them wholesale between steps."""
    _, manifest, acfg = built
    e = manifest["entries"]["train_step"]
    n = manifest["shapes"]["n_param_tensors"]
    ins, outs = e["inputs"], e["outputs"]
    for i in range(3 * n):
        assert ins[i]["name"] == outs[i]["name"]
        assert ins[i]["shape"] == outs[i]["shape"]
    assert [o["name"] for o in outs[3 * n:]] == [
        "step", "loss", "mean_ratio", "clip_frac", "mean_entropy",
        "approx_kl", "grad_norm"]


def test_manifest_json_round_trips(built, tmp_path):
    _, manifest, _ = built
    p = tmp_path / "m.json"
    p.write_text(json.dumps({"format_version": 1,
                             "configs": {manifest["tag"]: manifest}}, indent=1))
    again = json.loads(p.read_text())
    assert again["configs"][manifest["tag"]]["shapes"] == manifest["shapes"]


def test_hlo_runs_under_jax_interpreter(built):
    """Execute the emitted decode_chunk HLO via jax's own CPU client to prove
    the text is a valid, runnable program (rust does the same via PJRT)."""
    from jax._src.lib import xla_client as xc
    out, manifest, acfg = built
    cfg = acfg.model
    e = manifest["entries"]["decode_chunk"]
    text = open(os.path.join(out, e["file"])).read()

    backend = jax.devices("cpu")[0].client
    comp = xc._xla.hlo_module_from_text(text)
    # Round-trip through text proves parseability even on jax's side.
    assert comp is not None
