"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

This is the CORE correctness signal for the kernels that end up inside the
AOT-compiled HLO: hypothesis sweeps shapes/values and asserts allclose.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention, vmem_bytes_estimate
from compile.kernels.ppo_loss import ppo_loss
from compile.kernels.ref import (decode_attention_ref, ppo_loss_grad_ref,
                                 ppo_loss_ref)

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 5),
    h=st.integers(1, 4),
    s=st.sampled_from([16, 48, 64, 96, 130]),
    dh=st.sampled_from([8, 16, 32]),
    block_k=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_decode_attention_matches_ref(b, h, s, dh, block_k, seed):
    kq, kk, kv_, kp = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(kq, (b, h, dh))
    k = jax.random.normal(kk, (b, h, s, dh))
    v = jax.random.normal(kv_, (b, h, s, dh))
    pos = jax.random.randint(kp, (b,), 0, s - 1)
    got = decode_attention(q, k, v, pos, block_k=block_k)
    want = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_attention_pos_zero_attends_only_slot0():
    b, h, s, dh = 2, 2, 32, 8
    k = rand(0, (b, h, s, dh))
    v = rand(1, (b, h, s, dh))
    q = rand(2, (b, h, dh))
    pos = jnp.zeros((b,), jnp.int32)
    got = decode_attention(q, k, v, pos)
    # softmax over a single slot == that slot's value
    np.testing.assert_allclose(got, v[:, :, 0], rtol=1e-5, atol=1e-5)


def test_decode_attention_full_window():
    b, h, s, dh = 1, 3, 64, 16
    q, k, v = rand(3, (b, h, dh)), rand(4, (b, h, s, dh)), rand(5, (b, h, s, dh))
    pos = jnp.array([s - 1], jnp.int32)
    np.testing.assert_allclose(decode_attention(q, k, v, pos),
                               decode_attention_ref(q, k, v, pos),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_ignores_garbage_beyond_pos():
    """Slots > pos must not influence the output (cache holds trash there)."""
    b, h, s, dh = 2, 2, 48, 8
    q, k, v = rand(6, (b, h, dh)), rand(7, (b, h, s, dh)), rand(8, (b, h, s, dh))
    pos = jnp.array([10, 20], jnp.int32)
    base = decode_attention(q, k, v, pos)
    k2 = k.at[:, :, 30:].set(1e4)
    v2 = v.at[:, :, 30:].set(-1e4)
    np.testing.assert_allclose(decode_attention(q, k2, v2, pos), base,
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_large_scores_stable():
    b, h, s, dh = 1, 1, 32, 8
    q = rand(9, (b, h, dh), scale=30.0)
    k = rand(10, (b, h, s, dh), scale=30.0)
    v = rand(11, (b, h, s, dh))
    pos = jnp.array([s - 1], jnp.int32)
    got = decode_attention(q, k, v, pos)
    assert bool(jnp.isfinite(got).all())
    np.testing.assert_allclose(got, decode_attention_ref(q, k, v, pos),
                               rtol=1e-4, atol=1e-4)


def test_vmem_estimate_monotonic_in_block():
    assert vmem_bytes_estimate(512, 64, 32) < vmem_bytes_estimate(512, 64, 128)


# --------------------------------------------------------------------------
# fused PPO loss
# --------------------------------------------------------------------------

def _ppo_inputs(seed, b, t, v, adv_scale=1.0, off=0.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    logits = jax.random.normal(ks[0], (b, t, v)) * 2.0
    targets = jax.random.randint(ks[1], (b, t), 0, v)
    # old_logp near the actual logp plus an offset -> ratios around exp(-off)
    lp_all = jax.nn.log_softmax(logits, -1)
    logp = jnp.take_along_axis(lp_all, targets[..., None], -1)[..., 0]
    old_logp = logp + jax.random.normal(ks[2], (b, t)) * 0.3 + off
    adv = jax.random.normal(ks[3], (b, t)) * adv_scale
    mask = (jax.random.uniform(ks[4], (b, t)) > 0.25).astype(jnp.float32)
    return logits, targets, old_logp, adv, mask


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    t=st.sampled_from([1, 7, 16, 33]),
    v=st.sampled_from([8, 64, 100]),
    cl=st.sampled_from([0.1, 0.2, 0.3]),
    ch=st.sampled_from([0.2, 0.28, 0.4]),
    seed=st.integers(0, 2**16),
)
def test_ppo_loss_fwd_matches_ref(b, t, v, cl, ch, seed):
    logits, targets, old_logp, adv, mask = _ppo_inputs(seed, b, t, v)
    got = ppo_loss(logits, targets, old_logp, adv, mask, cl, ch)
    want = ppo_loss_ref(logits, targets, old_logp, adv, mask, cl, ch)
    for g, w, name in zip(got, want, ["loss", "logp", "entropy"]):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5, err_msg=name)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    t=st.sampled_from([4, 16]),
    v=st.sampled_from([16, 64]),
    off=st.sampled_from([-1.0, 0.0, 1.0]),  # push ratios into/out of the clip window
    seed=st.integers(0, 2**16),
)
def test_ppo_loss_bwd_matches_autodiff(b, t, v, off, seed):
    logits, targets, old_logp, adv, mask = _ppo_inputs(seed, b, t, v, off=off)
    g = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, t))

    def f(lg):
        return (ppo_loss(lg, targets, old_logp, adv, mask, 0.2, 0.28)[0] * g).sum()

    got = jax.grad(f)(logits)
    want = ppo_loss_grad_ref(logits, targets, old_logp, adv, mask, 0.2, 0.28, g)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_ppo_loss_zero_mask_zero_loss_and_grad():
    logits, targets, old_logp, adv, _ = _ppo_inputs(3, 2, 8, 16)
    mask = jnp.zeros((2, 8))
    loss, _, _ = ppo_loss(logits, targets, old_logp, adv, mask, 0.2, 0.28)
    assert float(jnp.abs(loss).max()) == 0.0
    d = jax.grad(lambda lg: ppo_loss(lg, targets, old_logp, adv, mask, 0.2, 0.28)[0].sum())(logits)
    assert float(jnp.abs(d).max()) == 0.0


def test_ppo_loss_ratio_one_equals_neg_adv():
    """old_logp == logp -> ratio 1 -> loss_tok == -adv * mask exactly."""
    logits, targets, _, adv, mask = _ppo_inputs(4, 2, 12, 32)
    lp_all = jax.nn.log_softmax(logits, -1)
    logp = jnp.take_along_axis(lp_all, targets[..., None], -1)[..., 0]
    loss, _, _ = ppo_loss(logits, targets, logp, adv, mask, 0.2, 0.28)
    np.testing.assert_allclose(loss, -adv * mask, rtol=1e-5, atol=1e-5)


def test_ppo_loss_clip_is_asymmetric():
    """DAPO clip-higher: ratio above 1+ch is clipped for adv>0 but the
    *negative-advantage* branch keeps the raw ratio (min picks it)."""
    b, t, v = 1, 1, 4
    logits = jnp.zeros((b, t, v)).at[0, 0, 0].set(3.0)
    targets = jnp.zeros((b, t), jnp.int32)
    lp_all = jax.nn.log_softmax(logits, -1)
    logp = lp_all[0, 0, 0]
    old = jnp.full((b, t), logp - 1.0)           # ratio = e ≈ 2.72 > 1.28
    mask = jnp.ones((b, t))
    loss_pos, _, _ = ppo_loss(logits, targets, old, jnp.ones((b, t)), mask, 0.2, 0.28)
    np.testing.assert_allclose(loss_pos[0, 0], -(1 + 0.28), rtol=1e-5)
    loss_neg, _, _ = ppo_loss(logits, targets, old, -jnp.ones((b, t)), mask, 0.2, 0.28)
    np.testing.assert_allclose(loss_neg[0, 0], float(jnp.exp(1.0)), rtol=1e-5)
