"""AOT build: lower every L2 entry point to HLO *text* + emit manifest.json.

HLO text (not `.serialize()`) is the interchange format: the image's
xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit instruction
ids); the text parser reassigns ids (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --preset small --out-dir ../artifacts \
        [--engine-batch 32] [--decode-chunk 16] [--train-batch 32] [--no-pallas]

The manifest describes, for each entry point, the ordered input/output
tensors (name, shape, dtype) so the rust runtime can marshal literals
without any knowledge of the jax code.  It also embeds the vocabulary and
model config; rust asserts its own tokenizer table matches.
"""

import argparse
import hashlib
import json
import os
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import transformer as tfm
from .configs import ArtifactConfig, VOCAB, artifact_config


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(d) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(d).name]


def _tensor_entry(name: str, sds) -> dict:
    return {"name": name, "shape": list(sds.shape), "dtype": _dtype_name(sds.dtype)}


def lower_entry(fn: Callable, in_specs: Sequence[Tuple[str, jax.ShapeDtypeStruct]],
                out_names: Sequence[str], path: str) -> dict:
    """Lower `fn` to HLO text at `path`; return its manifest entry."""
    shapes = [s for _, s in in_specs]
    lowered = jax.jit(fn).lower(*shapes)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    # Recover output shapes from the lowering itself.
    out_avals = jax.eval_shape(fn, *shapes)
    if not isinstance(out_avals, (tuple, list)):
        out_avals = (out_avals,)
    flat, _ = jax.tree_util.tree_flatten(out_avals)
    assert len(flat) == len(out_names), (len(flat), out_names)
    return {
        "file": os.path.basename(path),
        "inputs": [_tensor_entry(n, s) for n, s in in_specs],
        "outputs": [_tensor_entry(n, s) for n, s in zip(out_names, flat)],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build(acfg: ArtifactConfig, out_dir: str, use_pallas: bool = True) -> dict:
    cfg = acfg.model
    os.makedirs(out_dir, exist_ok=True)
    spec = tfm.param_spec(cfg)
    n_params = len(spec)
    param_in = [(name, f32(*shape)) for name, shape in spec]
    adam_m = [("m." + name, f32(*shape)) for name, shape in spec]
    adam_v = [("v." + name, f32(*shape)) for name, shape in spec]
    param_out = [name for name, _ in spec]

    B, k = acfg.engine_batch, acfg.decode_chunk
    Bt, T = acfg.train_batch, acfg.train_seq
    Sp = acfg.prefill_seq
    kv = f32(*tfm.kv_cache_shape(cfg, B))
    tag = f"{cfg.name}.B{B}k{k}.Bt{Bt}T{T}"

    entries = {}

    entries["init"] = lower_entry(
        M.make_init(cfg),
        [("seed", i32())],
        param_out,
        os.path.join(out_dir, f"init.{tag}.hlo.txt"))

    entries["prefill"] = lower_entry(
        M.make_prefill(acfg),
        param_in + [("tokens", i32(B, Sp)), ("length", i32(B))],
        ["kv", "last_logits"],
        os.path.join(out_dir, f"prefill.{tag}.hlo.txt"))

    entries["decode_chunk"] = lower_entry(
        M.make_decode_chunk(acfg, use_pallas=use_pallas),
        param_in + [("kv", kv), ("tok", i32(B)), ("pos", i32(B)),
                    ("active", i32(B)), ("uniforms", f32(B, k)), ("temp", f32())],
        ["kv", "tok", "pos", "active", "out_tokens", "out_logp"],
        os.path.join(out_dir, f"decode_chunk.{tag}.hlo.txt"))

    entries["train_step"] = lower_entry(
        M.make_train_step(acfg, use_pallas=use_pallas),
        param_in + adam_m + adam_v + [
            ("step", i32()), ("tokens", i32(Bt, T)), ("mask", f32(Bt, T)),
            ("adv", f32(Bt, T)), ("old_logp", f32(Bt, T)), ("lr", f32())],
        param_out + ["m." + n for n in param_out] + ["v." + n for n in param_out]
        + ["step", "loss", "mean_ratio", "clip_frac", "mean_entropy",
           "approx_kl", "grad_norm"],
        os.path.join(out_dir, f"train_step.{tag}.hlo.txt"))

    entries["sft_step"] = lower_entry(
        M.make_sft_step(acfg),
        param_in + adam_m + adam_v + [
            ("step", i32()), ("tokens", i32(Bt, T)), ("weights", f32(Bt, T)),
            ("lr", f32())],
        param_out + ["m." + n for n in param_out] + ["v." + n for n in param_out]
        + ["step", "loss", "grad_norm"],
        os.path.join(out_dir, f"sft_step.{tag}.hlo.txt"))

    entries["logprob"] = lower_entry(
        M.make_logprob(acfg),
        param_in + [("tokens", i32(Bt, T))],
        ["logp"],
        os.path.join(out_dir, f"logprob.{tag}.hlo.txt"))

    manifest = {
        "format_version": 1,
        "tag": tag,
        "preset": cfg.name,
        "model": {
            "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq, "vocab": cfg.vocab,
            "param_count": cfg.param_count(),
        },
        "shapes": {
            "engine_batch": B, "decode_chunk": k,
            "train_batch": Bt, "train_seq": T, "prefill_seq": Sp,
            "n_param_tensors": n_params,
            "kv_cache": list(tfm.kv_cache_shape(cfg, B)),
        },
        "vocab": VOCAB,
        "use_pallas": use_pallas,
        "params": [{"name": n, "shape": list(s)} for n, s in spec],
        "entries": entries,
    }
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--engine-batch", type=int, default=32)
    ap.add_argument("--decode-chunk", type=int, default=16)
    ap.add_argument("--train-batch", type=int, default=32)
    ap.add_argument("--no-pallas", action="store_true",
                    help="use the pure-jnp reference ops instead of the "
                         "Pallas kernels (ablation / debugging)")
    args = ap.parse_args()

    acfg = artifact_config(args.preset, args.engine_batch, args.decode_chunk,
                           args.train_batch)
    manifest = build(acfg, args.out_dir, use_pallas=not args.no_pallas)

    # Merge into a multi-config manifest keyed by tag.
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    merged = {"format_version": 1, "configs": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            merged = json.load(f)
    merged["configs"][manifest["tag"]] = manifest
    with open(manifest_path, "w") as f:
        json.dump(merged, f, indent=1)
    print(f"built {manifest['tag']}: {len(manifest['entries'])} entries -> {args.out_dir}")


if __name__ == "__main__":
    main()
