"""Model / artifact shape presets shared between the JAX build path and the
rust runtime (via artifacts/manifest.json).

The vocabulary here MUST stay in sync with rust/src/tokenizer/mod.rs; the
manifest carries `vocab` so the rust side can assert the mapping at startup.
"""

from dataclasses import dataclass, field


# Symbolic vocabulary shared by the logic (Knights & Knaves) and math
# (arithmetic-chain) tasks.  Index == token id.
VOCAB = [
    "<pad>", "<bos>", "<eos>", ";", "<think>", "</think>", "<answer>", "</answer>",
    "0", "1", "2", "3", "4", "5", "6", "7", "8", "9",
    "+", "-", "*", "/", "(", ")", "=",
    "K", "N", "&", "|", "!", "<=>", ":", "says",
    "P0", "P1", "P2", "P3", "P4", "P5", "P6", "P7", "P8", "P9",
    "LOGIC", "MATH", ",", "?", "step", "->",
    "so", "if", "then", "not", "true", "false", "check", "by",
    "<r0>", "<r1>", "<r2>", "<r3>", "<r4>", "<r5>", "<r6>",
]
assert len(VOCAB) == 64, len(VOCAB)

PAD, BOS, EOS = 0, 1, 2


@dataclass(frozen=True)
class ModelConfig:
    """Transformer hyperparameters (decoder-only, pre-LN, learned pos-emb)."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int              # S: KV-cache length == max trained position
    vocab: int = len(VOCAB)

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v, s = self.d_model, self.d_ff, self.vocab, self.max_seq
        per_layer = 4 * d * d + 2 * d * f + f + 5 * d
        return v * d + s * d + self.n_layers * per_layer + 2 * d + d * v


@dataclass(frozen=True)
class ArtifactConfig:
    """Shapes baked into the AOT-compiled HLO entry points."""

    model: ModelConfig
    engine_batch: int = 32    # B: rollout engine lane count (the "captured graph" size)
    decode_chunk: int = 16    # k: tokens generated per decode_chunk call
    train_batch: int = 32     # Bt: trajectories per train/sft step
    train_seq: int = 0        # T: training unroll (defaults to model.max_seq)
    prefill_seq: int = 0      # Sp: max prompt(+resume) length fed to prefill

    def __post_init__(self):
        if self.train_seq == 0:
            object.__setattr__(self, "train_seq", self.model.max_seq)
        if self.prefill_seq == 0:
            object.__setattr__(self, "prefill_seq", self.model.max_seq)


PRESETS = {
    "tiny": ModelConfig("tiny", d_model=64, n_layers=2, n_heads=2, d_ff=256, max_seq=192),
    # single-core-friendly training config (XLA-CPU dispatch-bound decode:
    # fewer layers => fewer ops per token)
    "mini": ModelConfig("mini", d_model=96, n_layers=3, n_heads=3, d_ff=384, max_seq=224),
    "small": ModelConfig("small", d_model=128, n_layers=4, n_heads=4, d_ff=512, max_seq=256),
    "base": ModelConfig("base", d_model=256, n_layers=8, n_heads=8, d_ff=1024, max_seq=320),
    "ref100m": ModelConfig("ref100m", d_model=768, n_layers=14, n_heads=12, d_ff=3072, max_seq=512),
}


def artifact_config(preset: str, engine_batch: int = 32, decode_chunk: int = 16,
                    train_batch: int = 32) -> ArtifactConfig:
    return ArtifactConfig(model=PRESETS[preset], engine_batch=engine_batch,
                          decode_chunk=decode_chunk, train_batch=train_batch)
