"""Fused token-level PPO-clip loss (Pallas, fwd + bwd via custom_vjp).

The update-phase hot spot: for each response token, gather the target
log-prob out of the [T, V] logits slab, form the importance ratio against
the behavior policy's sampling-time log-prob (π_old, stored by the rollout
buffer — paper §3.2), and apply the DAPO-style asymmetric clip.  Fusing the
gather + logsumexp + ratio + clip avoids materializing [B, T, V] softmax and
log-softmax intermediates that a naive composition keeps in HBM.

jax cannot autodiff through ``pallas_call``, so the backward pass is its own
kernel wired up with ``jax.custom_vjp``; both are checked against
``ref.ppo_loss_ref`` / ``ref.ppo_loss_grad_ref`` by pytest + hypothesis.

Grid: (B,) — each program owns one trajectory's [T, V] slab (T·V ≤ 512·64
floats ≈ 128 KiB, comfortably VMEM-resident).  Always ``interpret=True``.
"""

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(targets_ref, old_logp_ref, adv_ref, mask_ref, clip_ref,
                logits_ref, loss_ref, logp_ref, ent_ref):
    logits = logits_ref[0]                          # [T, V]
    t, v = logits.shape
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - m
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1)     # [T]
    lse = jnp.log(sumexp) + m[:, 0]                 # [T]
    tgt = targets_ref[0]                            # i32[T]
    onehot = jax.lax.iota(jnp.int32, v)[None, :] == tgt[:, None]
    tgt_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    logp = tgt_logit - lse                          # [T]

    old_logp = old_logp_ref[0]
    adv = adv_ref[0]
    mask = mask_ref[0]
    clip_low, clip_high = clip_ref[0], clip_ref[1]
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - clip_low, 1.0 + clip_high)
    obj = jnp.minimum(ratio * adv, clipped * adv)
    loss_ref[0] = -mask * obj
    logp_ref[0] = logp

    probs = jnp.exp(shifted) / sumexp[:, None]
    ent_ref[0] = lse - jnp.sum(probs * logits, axis=-1)


def _bwd_kernel(targets_ref, old_logp_ref, adv_ref, mask_ref, clip_ref,
                logits_ref, g_ref, dlogits_ref):
    logits = logits_ref[0]
    t, v = logits.shape
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - m
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1)
    probs = jnp.exp(shifted) / sumexp[:, None]       # [T, V]
    lse = jnp.log(sumexp) + m[:, 0]
    tgt = targets_ref[0]
    onehot = (jax.lax.iota(jnp.int32, v)[None, :] == tgt[:, None]).astype(jnp.float32)
    logp = jnp.sum(onehot * logits, axis=-1) - lse

    old_logp = old_logp_ref[0]
    adv = adv_ref[0]
    mask = mask_ref[0]
    clip_low, clip_high = clip_ref[0], clip_ref[1]
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - clip_low, 1.0 + clip_high)
    # min() picks the unclipped branch iff ratio*adv <= clipped*adv; on the
    # tie (ratio inside the clip window) both branches have identical value
    # AND derivative, so the selector is exact — see test_kernels.py.
    unclipped_sel = (ratio * adv <= clipped * adv).astype(jnp.float32)
    dobj_dlogp = unclipped_sel * ratio * adv          # [T]
    dloss_dlogp = -mask * dobj_dlogp
    g = g_ref[0]                                      # [T]
    coef = (g * dloss_dlogp)[:, None]                 # [T, 1]
    dlogits_ref[0] = coef * (onehot - probs)


def _pallas_fwd(logits, targets, old_logp, adv, mask, clips):
    b, t, v = logits.shape
    spec_bt = pl.BlockSpec((1, t), lambda i: (i, 0))
    spec_btv = pl.BlockSpec((1, t, v), lambda i: (i, 0, 0))
    spec_clip = pl.BlockSpec((2,), lambda i: (0,))
    return pl.pallas_call(
        _fwd_kernel,
        grid=(b,),
        in_specs=[spec_bt, spec_bt, spec_bt, spec_bt, spec_clip, spec_btv],
        out_specs=[spec_bt, spec_bt, spec_bt],
        out_shape=[jax.ShapeDtypeStruct((b, t), jnp.float32)] * 3,
        interpret=True,
    )(targets, old_logp, adv, mask, clips, logits)


def _pallas_bwd(logits, targets, old_logp, adv, mask, clips, g):
    b, t, v = logits.shape
    spec_bt = pl.BlockSpec((1, t), lambda i: (i, 0))
    spec_btv = pl.BlockSpec((1, t, v), lambda i: (i, 0, 0))
    spec_clip = pl.BlockSpec((2,), lambda i: (0,))
    return pl.pallas_call(
        _bwd_kernel,
        grid=(b,),
        in_specs=[spec_bt, spec_bt, spec_bt, spec_bt, spec_clip, spec_btv, spec_bt],
        out_specs=spec_btv,
        out_shape=jax.ShapeDtypeStruct((b, t, v), jnp.float32),
        interpret=True,
    )(targets, old_logp, adv, mask, clips, logits, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ppo_loss(logits: jax.Array, targets: jax.Array, old_logp: jax.Array,
             adv: jax.Array, mask: jax.Array, clip_low: float,
             clip_high: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused PPO-clip token loss; same contract as ``ref.ppo_loss_ref``.

    Returns (loss_tok f32[B,T], logp f32[B,T], entropy f32[B,T]); only
    loss_tok is differentiable w.r.t. logits (logp/entropy are diagnostics).
    """
    clips = jnp.array([clip_low, clip_high], jnp.float32)
    loss_tok, logp, ent = _pallas_fwd(logits, targets, old_logp, adv, mask, clips)
    return loss_tok, logp, ent


def _vjp_fwd(logits, targets, old_logp, adv, mask, clip_low, clip_high):
    out = ppo_loss(logits, targets, old_logp, adv, mask, clip_low, clip_high)
    return out, (logits, targets, old_logp, adv, mask)


def _vjp_bwd(clip_low, clip_high, res, cotangents):
    logits, targets, old_logp, adv, mask = res
    g_loss, _g_logp, _g_ent = cotangents  # logp/entropy treated as non-diff stats
    clips = jnp.array([clip_low, clip_high], jnp.float32)
    dlogits = _pallas_bwd(logits, targets, old_logp, adv, mask, clips, g_loss)
    return (dlogits, None, None, None, None)


ppo_loss.defvjp(_vjp_fwd, _vjp_bwd)
