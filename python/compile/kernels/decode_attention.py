"""Flash-decoding-style single-query attention over the KV cache (Pallas).

This is the rollout hot spot the paper identifies (§2.2: autoregressive
rollout throughput is HBM-bandwidth-bound on KV-cache reads).  GPU serving
engines stream the cache through shared memory per warp; the TPU rethink
(DESIGN.md §Hardware-Adaptation) streams `(BLOCK_K, Dh)` cache tiles from
HBM into VMEM via the grid/BlockSpec schedule and folds them into an
online-softmax accumulator, so VMEM holds only one tile + the O(Dh)
accumulator regardless of S.

Grid: (B, H) parallel lanes x an in-kernel sequential walk over KV tiles.
Always built with ``interpret=True`` — real-TPU Mosaic lowering cannot run
on the CPU PJRT plugin (see /opt/xla-example/README.md).
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_K = 64


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq: int,
            scale: float):
    """One (batch, head) lane: online softmax over KV tiles.

    pos_ref: i32[1] — highest cache slot to attend to (inclusive).
    q_ref:   f32[1, 1, Dh]
    k_ref/v_ref: f32[1, 1, S, Dh]
    o_ref:   f32[1, 1, Dh]
    """
    q = q_ref[0, 0, :] * scale                       # [Dh]
    pos = pos_ref[0]
    dh = q.shape[0]
    num_tiles = seq // block_k

    def tile_step(i, carry):
        m, l, acc = carry
        k_tile = k_ref[0, 0, pl.ds(i * block_k, block_k), :]    # [Bk, Dh]
        v_tile = v_ref[0, 0, pl.ds(i * block_k, block_k), :]
        # q·Kᵀ for the tile — a [Bk, Dh] x [Dh] contraction (MXU-eligible
        # when q is tiled [1, Dh] on real hardware).
        scores = jnp.dot(k_tile, q, preferred_element_type=jnp.float32)  # [Bk]
        idx = i * block_k + jax.lax.iota(jnp.int32, block_k)
        scores = jnp.where(idx <= pos, scores, -1e30)
        m_new = jnp.maximum(m, jnp.max(scores))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new)                              # [Bk]
        l_new = alpha * l + jnp.sum(p)
        acc_new = alpha * acc + jnp.dot(p, v_tile, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.float32(-1e30)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((dh,), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_tiles, tile_step, (m0, l0, acc0))
    o_ref[0, 0, :] = acc / jnp.maximum(l, 1e-30)


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, block_k: int = DEFAULT_BLOCK_K) -> jax.Array:
    """q: f32[B,H,Dh]; k_cache/v_cache: f32[B,H,S,Dh]; pos: i32[B] -> f32[B,H,Dh].

    Lane b attends to cache slots j <= pos[b].
    """
    b, h, s, dh = k_cache.shape
    block_k = min(block_k, s)
    if s % block_k != 0:
        pad = block_k - s % block_k
        # Padded slots are masked out by the `idx <= pos` predicate as long
        # as pos < s, which the engine guarantees (slot S-1 is a trash slot).
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        s += pad
    scale = 1.0 / math.sqrt(dh)
    kernel = functools.partial(_kernel, block_k=block_k, seq=s, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, 1, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        interpret=True,
    )(pos, q, k_cache, v_cache)


def vmem_bytes_estimate(s: int, dh: int, block_k: int = DEFAULT_BLOCK_K) -> int:
    """Analytic VMEM footprint per grid cell (DESIGN.md §Perf)."""
    tile = block_k * dh * 4 * 2          # K and V tiles
    accum = (dh + 2) * 4                 # acc + m + l
    qb = dh * 4
    return tile + accum + qb
