"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every Pallas kernel in this package has a reference implementation here with
the identical signature; pytest (python/tests/test_kernels.py) asserts
allclose between the two over hypothesis-generated shapes/values.
"""

from typing import Tuple

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         pos: jax.Array) -> jax.Array:
    """Single-query attention over a KV cache.

    q: f32[B, H, Dh]; k_cache/v_cache: f32[B, H, S, Dh]; pos: i32[B].
    Lane b attends to cache slots j <= pos[b]. Returns f32[B, H, Dh].
    """
    b, h, s, dh = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) * scale
    mask = jnp.arange(s)[None, :] <= pos[:, None]            # [B, S]
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", probs, v_cache)


def ppo_loss_ref(logits: jax.Array, targets: jax.Array, old_logp: jax.Array,
                 adv: jax.Array, mask: jax.Array, clip_low: float,
                 clip_high: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Token-level PPO-clip objective (clip-higher variant, DAPO-style).

    logits: f32[B, T, V]; targets: i32[B, T]; old_logp/adv/mask: f32[B, T].
    Returns (loss_tok, logp, entropy), each f32[B, T]:
      loss_tok = -mask * min(r * adv, clip(r, 1-cl, 1+ch) * adv),
      r = exp(logp - old_logp).
    """
    logp_all = jax.nn.log_softmax(logits, axis=-1)
    logp = jnp.take_along_axis(logp_all, targets[..., None], axis=-1)[..., 0]
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - clip_low, 1.0 + clip_high)
    obj = jnp.minimum(ratio * adv, clipped * adv)
    loss_tok = -mask * obj
    probs = jnp.exp(logp_all)
    entropy = -(probs * logp_all).sum(-1)
    return loss_tok, logp, entropy


def ppo_loss_grad_ref(logits: jax.Array, targets: jax.Array, old_logp: jax.Array,
                      adv: jax.Array, mask: jax.Array, clip_low: float,
                      clip_high: float, g: jax.Array) -> jax.Array:
    """d(sum(g * loss_tok))/d logits via jax autodiff — oracle for the bwd kernel."""

    def scalar_loss(lg):
        loss_tok, _, _ = ppo_loss_ref(lg, targets, old_logp, adv, mask,
                                      clip_low, clip_high)
        return (loss_tok * g).sum()

    return jax.grad(scalar_loss)(logits)
