"""Decoder-only transformer (pre-LN, learned positional embeddings, GELU MLP).

Pure functions over an explicit parameter list so the AOT entry points have a
stable, manifest-described calling convention.  Parameters travel as a flat
*ordered list* of arrays; `param_spec` is the single source of truth for the
order, names and shapes (mirrored in artifacts/manifest.json for rust).
"""

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------

def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the wire format between python and rust."""
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_seq
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (v, d)),
        ("pos_emb", (s, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "ln1_scale", (d,)), (p + "ln1_bias", (d,)),
            (p + "wq", (d, d)), (p + "wk", (d, d)),
            (p + "wv", (d, d)), (p + "wo", (d, d)),
            (p + "ln2_scale", (d,)), (p + "ln2_bias", (d,)),
            (p + "w1", (d, f)), (p + "b1", (f,)),
            (p + "w2", (f, d)), (p + "b2", (d,)),
        ]
    spec += [
        ("lnf_scale", (d,)), ("lnf_bias", (d,)),
        ("lm_head", (d, v)),
    ]
    return spec


def init_params(cfg: ModelConfig, key: jax.Array) -> List[jax.Array]:
    """GPT-2-style init: N(0, 0.02), residual projections scaled by 1/sqrt(2L)."""
    spec = param_spec(cfg)
    keys = jax.random.split(key, len(spec))
    out = []
    resid_scale = 1.0 / math.sqrt(2.0 * cfg.n_layers)
    for (name, shape), k in zip(spec, keys):
        base = name.split(".")[-1]
        if base in ("ln1_scale", "ln2_scale", "lnf_scale"):
            out.append(jnp.ones(shape, jnp.float32))
        elif base in ("ln1_bias", "ln2_bias", "lnf_bias", "b1", "b2"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            std = 0.02
            if base in ("wo", "w2"):
                std *= resid_scale
            out.append(jax.random.normal(k, shape, jnp.float32) * std)
    return out


def as_dict(cfg: ModelConfig, params: List[jax.Array]) -> Dict[str, jax.Array]:
    return {name: p for (name, _), p in zip(param_spec(cfg), params)}


# --------------------------------------------------------------------------
# Shared pieces
# --------------------------------------------------------------------------

def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def gelu(x: jax.Array) -> jax.Array:
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def mlp(p: Dict[str, jax.Array], prefix: str, x: jax.Array) -> jax.Array:
    h = gelu(x @ p[prefix + "w1"] + p[prefix + "b1"])
    return h @ p[prefix + "w2"] + p[prefix + "b2"]


def split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    # [..., T, D] -> [..., H, T, Dh]
    *lead, t, d = x.shape
    x = x.reshape(*lead, t, n_heads, d // n_heads)
    return jnp.moveaxis(x, -2, -3)


def merge_heads(x: jax.Array) -> jax.Array:
    # [..., H, T, Dh] -> [..., T, D]
    x = jnp.moveaxis(x, -3, -2)
    *lead, t, h, dh = x.shape
    return x.reshape(*lead, t, h * dh)


# --------------------------------------------------------------------------
# Full-sequence causal forward (training / scoring path)
# --------------------------------------------------------------------------

def causal_attention(cfg: ModelConfig, p: Dict[str, jax.Array], prefix: str,
                     x: jax.Array) -> jax.Array:
    """x: [B, T, D] -> [B, T, D] with a causal mask."""
    b, t, d = x.shape
    q = split_heads(x @ p[prefix + "wq"], cfg.n_heads)  # [B,H,T,Dh]
    k = split_heads(x @ p[prefix + "wk"], cfg.n_heads)
    v = split_heads(x @ p[prefix + "wv"], cfg.n_heads)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(cfg.d_head)
    causal = jnp.tril(jnp.ones((t, t), jnp.bool_))
    scores = jnp.where(causal[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    return merge_heads(out) @ p[prefix + "wo"]


def forward(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array) -> jax.Array:
    """tokens: i32[B, T] -> logits f32[B, T, V] (full causal forward)."""
    p = as_dict(cfg, params)
    b, t = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :t]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        x = x + causal_attention(cfg, p, pre, layer_norm(x, p[pre + "ln1_scale"], p[pre + "ln1_bias"]))
        x = x + mlp(p, pre, layer_norm(x, p[pre + "ln2_scale"], p[pre + "ln2_bias"]))
    x = layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    return x @ p["lm_head"]


# --------------------------------------------------------------------------
# KV-cache paths (rollout)
# --------------------------------------------------------------------------
# Cache layout: f32[n_layers, 2, B, H, S, Dh]; index 0=K, 1=V.
# Invariant: for an active lane with current position `pos`, cache slots
# [0, pos) hold valid K/V; the token at `pos` is the lane's pending token.

def kv_cache_shape(cfg: ModelConfig, batch: int) -> Tuple[int, ...]:
    return (cfg.n_layers, 2, batch, cfg.n_heads, cfg.max_seq, cfg.d_head)


def decode_attend(cfg: ModelConfig, q: jax.Array, k_cache: jax.Array,
                  v_cache: jax.Array, pos: jax.Array, *, use_pallas: bool) -> jax.Array:
    """Single-query attention over the cache.

    q: [B, H, Dh]; k_cache/v_cache: [B, H, S, Dh]; pos: i32[B]
    (attend to slots j <= pos). Returns [B, H, Dh].
    """
    if use_pallas:
        from .kernels.decode_attention import decode_attention
        return decode_attention(q, k_cache, v_cache, pos)
    from .kernels.ref import decode_attention_ref
    return decode_attention_ref(q, k_cache, v_cache, pos)


def decode_one(cfg: ModelConfig, params: List[jax.Array], kv: jax.Array,
               tok: jax.Array, pos: jax.Array, active: jax.Array,
               *, use_pallas: bool) -> Tuple[jax.Array, jax.Array]:
    """One decode step for the whole engine batch.

    kv: cache; tok: i32[B] token at `pos`; pos: i32[B]; active: bool[B].
    Inactive lanes write to the reserved trash slot S-1 so their cache is
    not corrupted. Returns (new_kv, logits f32[B,V]).
    """
    p = as_dict(cfg, params)
    s = cfg.max_seq
    safe_pos = jnp.clip(pos, 0, s - 1)
    write_pos = jnp.where(active, safe_pos, s - 1)

    x = p["tok_emb"][tok] + p["pos_emb"][safe_pos]          # [B, D]
    new_kv = kv
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = layer_norm(x, p[pre + "ln1_scale"], p[pre + "ln1_bias"])
        q = split_heads((h @ p[pre + "wq"])[:, None], cfg.n_heads)[:, :, 0]  # [B,H,Dh]
        k = split_heads((h @ p[pre + "wk"])[:, None], cfg.n_heads)[:, :, 0]
        v = split_heads((h @ p[pre + "wv"])[:, None], cfg.n_heads)[:, :, 0]

        def write(cache_l, val, wp):
            # cache_l: [B,H,S,Dh]; val: [B,H,Dh]; wp: i32[B]
            def one(c, x_, w):
                return jax.lax.dynamic_update_slice(c, x_[:, None], (0, w, 0))
            return jax.vmap(one)(cache_l, val, wp)

        k_cache = write(new_kv[i, 0], k, write_pos)
        v_cache = write(new_kv[i, 1], v, write_pos)
        new_kv = new_kv.at[i, 0].set(k_cache).at[i, 1].set(v_cache)

        att = decode_attend(cfg, q, k_cache, v_cache, safe_pos, use_pallas=use_pallas)
        x = x + att.reshape(att.shape[0], cfg.d_model) @ p[pre + "wo"]
        x = x + mlp(p, pre, layer_norm(x, p[pre + "ln2_scale"], p[pre + "ln2_bias"]))
    x = layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    return new_kv, x @ p["lm_head"]


def prefill(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array,
            length: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Prompt (or prompt+resumed-partial) ingestion.

    tokens: i32[B, Sp] left-aligned, PAD beyond `length`; length: i32[B].
    Fills cache slots [0, Sp) and returns (kv, logits at position length-1).
    """
    p = as_dict(cfg, params)
    b, sp = tokens.shape
    s = cfg.max_seq
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :sp]
    kv = jnp.zeros(kv_cache_shape(cfg, b), jnp.float32)
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = layer_norm(x, p[pre + "ln1_scale"], p[pre + "ln1_bias"])
        q = split_heads(h @ p[pre + "wq"], cfg.n_heads)   # [B,H,Sp,Dh]
        k = split_heads(h @ p[pre + "wk"], cfg.n_heads)
        v = split_heads(h @ p[pre + "wv"], cfg.n_heads)
        kv = kv.at[i, 0, :, :, :sp].set(k).at[i, 1, :, :, :sp].set(v)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(cfg.d_head)
        causal = jnp.tril(jnp.ones((sp, sp), jnp.bool_))
        scores = jnp.where(causal[None, None], scores, -1e30)
        att = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(scores, -1), v)
        x = x + merge_heads(att) @ p[pre + "wo"]
        x = x + mlp(p, pre, layer_norm(x, p[pre + "ln2_scale"], p[pre + "ln2_bias"]))
    x = layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    logits = x @ p["lm_head"]                              # [B, Sp, V]
    idx = jnp.clip(length - 1, 0, sp - 1)
    last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
    return kv, last
