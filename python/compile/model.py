"""L2 entry points AOT-compiled to HLO for the rust runtime.

Six programs per artifact config (see aot.py / manifest.json):

  init         seed            -> params
  prefill      params, prompt tokens, lengths -> kv cache, last logits
  decode_chunk params, kv, lane state, uniforms, temp -> k sampled tokens
               + their sampling-time log-probs (π_old for the buffer, §3.2)
  train_step   params, adam state, trajectories, advantages, old log-probs
               -> updated params + stats (PPO-clip via the fused L1 kernel)
  sft_step     params, adam state, tokens, weights -> updated params (warm
               start — stands in for the paper's pretrained instruct models)
  logprob      params, tokens -> per-token log-probs (diagnostics / eval)

Sampling happens *inside* decode_chunk from rust-provided uniforms, so the
rust coordinator owns the RNG stream per request while the HLO computes the
exact behavior-policy log-prob of every sampled token — the quantity the
stateful rollout buffer must cache for partial mode (paper Eq. 1).
"""

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .configs import ArtifactConfig, ModelConfig, EOS, PAD
from . import transformer as tfm
from .kernels.ppo_loss import ppo_loss


@dataclass(frozen=True)
class Hyper:
    """Optimizer / objective constants baked into the train_step HLO."""
    clip_low: float = 0.2
    clip_high: float = 0.28       # DAPO clip-higher
    max_grad_norm: float = 1.0
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def make_init(cfg: ModelConfig):
    def init(seed: jax.Array) -> Tuple[jax.Array, ...]:
        key = jax.random.PRNGKey(seed)
        return tuple(tfm.init_params(cfg, key))
    return init


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------

def make_prefill(acfg: ArtifactConfig):
    cfg = acfg.model
    n_params = len(tfm.param_spec(cfg))

    def prefill(*args):
        params = list(args[:n_params])
        tokens, length = args[n_params], args[n_params + 1]
        kv, last_logits = tfm.prefill(cfg, params, tokens, length)
        return kv, last_logits

    return prefill


# --------------------------------------------------------------------------
# decode_chunk
# --------------------------------------------------------------------------

def make_decode_chunk(acfg: ArtifactConfig, use_pallas: bool = True):
    cfg = acfg.model
    n_params = len(tfm.param_spec(cfg))
    s = cfg.max_seq
    max_pos = s - 2  # slot S-1 is the trash slot for inactive lanes

    def decode_chunk(*args):
        params = list(args[:n_params])
        kv, tok, pos, active, uniforms, temp = args[n_params:n_params + 6]
        # kv: f32[NL,2,B,H,S,Dh]; tok/pos/active: i32[B];
        # uniforms: f32[B,k] in [0,1) (negative -> greedy); temp: f32[]
        inv_temp = 1.0 / jnp.maximum(temp, 1e-6)

        def step(carry, u):
            kv, tok, pos, active = carry
            act_b = active > 0
            kv, logits = tfm.decode_one(cfg, params, kv, tok, pos, act_b,
                                        use_pallas=use_pallas)
            logp_all = jax.nn.log_softmax(logits * inv_temp, axis=-1)  # [B,V]
            cdf = jnp.cumsum(jnp.exp(logp_all), axis=-1)
            sampled = jnp.argmax(cdf >= u[:, None], axis=-1).astype(jnp.int32)
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(u < 0.0, greedy, sampled)
            logp_tok = jnp.take_along_axis(logp_all, nxt[:, None], axis=-1)[:, 0]

            emit = jnp.where(act_b, nxt, PAD)
            logp_emit = jnp.where(act_b, logp_tok, 0.0)
            pos_next = jnp.where(act_b, pos + 1, pos)
            active_next = (act_b & (nxt != EOS) & (pos_next < max_pos)).astype(jnp.int32)
            tok_next = jnp.where(act_b, nxt, tok)
            return (kv, tok_next, pos_next, active_next), (emit, logp_emit)

        (kv, tok, pos, active), (toks, logps) = jax.lax.scan(
            step, (kv, tok, pos, active), uniforms.T)
        return kv, tok, pos, active, toks.T, logps.T

    return decode_chunk


# --------------------------------------------------------------------------
# train_step (PPO-clip through the fused L1 kernel)
# --------------------------------------------------------------------------

def _global_norm(tree: List[jax.Array]) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(g * g) for g in tree))


def _adam(params, m, v, grads, step, lr, hp: Hyper):
    step = step + 1
    b1, b2 = hp.adam_b1, hp.adam_b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    new_p, new_m, new_v = [], [], []
    for p, mi, vi, g in zip(params, m, v, grads):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        upd = (mi / c1) / (jnp.sqrt(vi / c2) + hp.adam_eps)
        new_p.append(p - lr * upd)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, step


def make_train_step(acfg: ArtifactConfig, hp: Hyper = Hyper(), use_pallas: bool = True):
    cfg = acfg.model
    n_params = len(tfm.param_spec(cfg))

    def train_step(*args):
        params = list(args[:n_params])
        m = list(args[n_params:2 * n_params])
        v = list(args[2 * n_params:3 * n_params])
        step, tokens, mask, adv, old_logp, lr = args[3 * n_params:3 * n_params + 6]
        # tokens i32[B,T]; mask/adv/old_logp f32[B,T] aligned to *generated*
        # token index t (mask[t]=1 iff tokens[t] is a response token);
        # lr f32[].  Position t is predicted from logits at t-1.
        denom = jnp.maximum(mask[:, 1:].sum(), 1.0)

        def loss_fn(ps):
            logits = tfm.forward(cfg, ps, tokens)          # [B,T,V]
            if use_pallas:
                loss_tok, logp, ent = ppo_loss(
                    logits[:, :-1], tokens[:, 1:], old_logp[:, 1:],
                    adv[:, 1:], mask[:, 1:], hp.clip_low, hp.clip_high)
            else:
                from .kernels.ref import ppo_loss_ref
                loss_tok, logp, ent = ppo_loss_ref(
                    logits[:, :-1], tokens[:, 1:], old_logp[:, 1:],
                    adv[:, 1:], mask[:, 1:], hp.clip_low, hp.clip_high)
            loss = loss_tok.sum() / denom
            return loss, (jax.lax.stop_gradient(logp), jax.lax.stop_gradient(ent))

        (loss, (logp, ent)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)

        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, hp.max_grad_norm / jnp.maximum(gnorm, 1e-12))
        grads = [g * scale for g in grads]
        new_p, new_m, new_v, new_step = _adam(params, m, v, grads, step, lr, hp)

        msk = mask[:, 1:]
        ratio = jnp.exp(logp - old_logp[:, 1:])
        mean_ratio = (ratio * msk).sum() / denom
        clip_frac = (((ratio > 1 + hp.clip_high) | (ratio < 1 - hp.clip_low)) * msk).sum() / denom
        mean_entropy = (ent * msk).sum() / denom
        approx_kl = ((old_logp[:, 1:] - logp) * msk).sum() / denom
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (
            new_step, loss, mean_ratio, clip_frac, mean_entropy, approx_kl, gnorm)

    return train_step


# --------------------------------------------------------------------------
# sft_step (supervised warm start)
# --------------------------------------------------------------------------

def make_sft_step(acfg: ArtifactConfig, hp: Hyper = Hyper()):
    cfg = acfg.model
    n_params = len(tfm.param_spec(cfg))

    def sft_step(*args):
        params = list(args[:n_params])
        m = list(args[n_params:2 * n_params])
        v = list(args[2 * n_params:3 * n_params])
        step, tokens, weights, lr = args[3 * n_params:3 * n_params + 4]
        denom = jnp.maximum(weights[:, 1:].sum(), 1.0)

        def loss_fn(ps):
            logits = tfm.forward(cfg, ps, tokens)
            logp_all = jax.nn.log_softmax(logits[:, :-1], axis=-1)
            logp = jnp.take_along_axis(logp_all, tokens[:, 1:, None], axis=-1)[..., 0]
            return -(logp * weights[:, 1:]).sum() / denom

        loss, grads = jax.value_and_grad(loss_fn)(params)
        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, hp.max_grad_norm / jnp.maximum(gnorm, 1e-12))
        grads = [g * scale for g in grads]
        new_p, new_m, new_v, new_step = _adam(params, m, v, grads, step, lr, hp)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (new_step, loss, gnorm)

    return sft_step


# --------------------------------------------------------------------------
# logprob (scoring)
# --------------------------------------------------------------------------

def make_logprob(acfg: ArtifactConfig):
    cfg = acfg.model
    n_params = len(tfm.param_spec(cfg))

    def logprob(*args):
        params = list(args[:n_params])
        tokens = args[n_params]
        logits = tfm.forward(cfg, params, tokens)
        logp_all = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        logp = jnp.take_along_axis(logp_all, tokens[:, 1:, None], axis=-1)[..., 0]
        return (jnp.pad(logp, ((0, 0), (1, 0))),)

    return logprob
