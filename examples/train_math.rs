//! Math-task training driver: compare all three schedulers on arithmetic
//! chains from one shared warm start (a small-scale Fig. 4).
//!
//! Run:  make artifacts && cargo run --release --example train_math -- \
//!           [updates-per-scheduler]

use sortedrl::coordinator::{sft_warm_start, Controller, LoopConfig, SchedulerKind};
use sortedrl::data::Dataset;
use sortedrl::exp::suites::clone_state;
use sortedrl::rl::advantage::AdvantageKind;
use sortedrl::runtime::Runtime;
use sortedrl::tasks::math::MathTask;
use sortedrl::tasks::Task;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let updates: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    let rt = Runtime::load(Path::new("artifacts"), None)?;
    eprintln!("platform {}; tag {}", rt.platform(), rt.manifest.tag);

    let task = MathTask;
    let ds = Dataset::generate(&task, 80, 0.1, 9);
    let mut warm = rt.init(9)?;
    let problems: Vec<&sortedrl::tasks::Problem> = ds.train.iter().collect();
    eprintln!("warm start (120 sft steps)...");
    sft_warm_start(&rt, &mut warm, &problems, 120, 2e-3, 30)?;

    println!("\n{:>14} | {:>9} | {:>8} | {:>8} | {:>7} | {:>7}",
             "scheduler", "val score", "accuracy", "resp len", "bubble", "tokens");
    for scheduler in [SchedulerKind::Baseline, SchedulerKind::SortedOnPolicy,
                      SchedulerKind::SortedPartial] {
        let cfg = LoopConfig {
            scheduler,
            rollout_prompts: 4,
            group_size: 4,
            samples_per_prompt: 2,
            update_batch: 32,
            max_updates: updates,
            lr: 4e-4,
            temperature: 1.0,
            seed: 9,
            adv: AdvantageKind::ReinforcePlusPlus,
            max_new: 160,
            eval_every: 0,
            eval_limit: 48,
            verbose: false,
            ..LoopConfig::default()
        };
        let ds = Dataset::generate(&task, 80, 0.1, 9);
        let mut state = clone_state(&warm);
        let mut ctl = Controller::new(&rt, Box::new(MathTask), ds, cfg);
        let result = ctl.run(&mut state)?;
        println!("{:>14} | {:>9.3} | {:>8.3} | {:>8.1} | {:>6.2}% | {:>7}",
                 scheduler.name(), result.final_eval.score,
                 result.final_eval.accuracy, result.final_eval.mean_resp_len,
                 result.bubble_ratio * 100.0, result.total_rollout_tokens);
    }
    println!("\n(expect: token-efficiency ordered on-policy >= partial >= baseline, \
              bubbles lower for sorted modes)");
    Ok(())
}
