//! Rollout-throughput study at paper scale via the discrete-event simulator
//! (the Fig. 5 experiment, plus a queue-capacity sweep the paper motivates
//! in §3.1: the engine is most efficient at its captured batch size).
//!
//! Run:  cargo run --release --example throughput_sim

use sortedrl::sim::{longtail_workload, simulate, CostModel, SimMode};

fn main() {
    let cost = CostModel::default();

    println!("=== Fig 5 operating point: 512 samples, 4x128 batches, cap 8k ===\n");
    let w = longtail_workload(512, 8192, 5);
    println!("{:>10} | {:>8} | {:>8} | {:>9} | {:>8} | {:>7}",
             "mode", "tok/s", "bubble", "rollout s", "wasted", "clipped");
    for (mode, label) in [(SimMode::Baseline, "baseline"),
                          (SimMode::SortedOnPolicy, "on-policy"),
                          (SimMode::SortedPartial, "partial")] {
        let r = simulate(mode, &w, 128, 128, cost);
        println!("{label:>10} | {:>8.0} | {:>7.2}% | {:>9.1} | {:>8} | {:>7}",
                 r.throughput, r.bubble_ratio * 100.0, r.rollout_time,
                 r.wasted_tokens, r.clipped);
    }

    println!("\n=== queue-capacity sweep (partial mode, same workload) ===\n");
    println!("{:>6} | {:>8} | {:>8}", "Q", "tok/s", "bubble");
    for q in [32usize, 64, 96, 128, 192, 256] {
        let r = simulate(SimMode::SortedPartial, &w, q, 128, cost);
        println!("{q:>6} | {:>8.0} | {:>7.2}%", r.throughput, r.bubble_ratio * 100.0);
    }

    println!("\n=== update-batch sweep (on-policy, U controls harvest cadence) ===\n");
    println!("{:>6} | {:>8} | {:>8} | {:>8}", "U", "tok/s", "bubble", "wasted");
    for u in [32usize, 64, 128, 256, 512] {
        let r = simulate(SimMode::SortedOnPolicy, &w, 128, u, cost);
        println!("{u:>6} | {:>8.0} | {:>7.2}% | {:>8}",
                 r.throughput, r.bubble_ratio * 100.0, r.wasted_tokens);
    }
}
