//! Quickstart: the whole stack in ~60 lines.
//!
//!   1. load the AOT artifacts (HLO text compiled via PJRT — no python)
//!   2. initialize a policy + supervised warm start on the math task
//!   3. run a handful of SortedRL on-policy updates
//!   4. evaluate greedily
//!
//! Run:  make artifacts && cargo run --release --example quickstart

use sortedrl::coordinator::{sft_warm_start, Controller, LoopConfig, SchedulerKind};
use sortedrl::data::Dataset;
use sortedrl::rl::advantage::AdvantageKind;
use sortedrl::runtime::Runtime;
use sortedrl::tasks::math::MathTask;
use sortedrl::tasks::Task;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(Path::new("artifacts"), None)?;
    println!("platform {}; model {} params; engine B={} chunk k={}",
             rt.platform(), rt.manifest.model.param_count,
             rt.manifest.shapes.engine_batch, rt.manifest.shapes.decode_chunk);

    // dataset: arithmetic chains, difficulty 2..=8, 10% eval split
    let task = MathTask;
    let ds = Dataset::generate(&task, 24, 0.1, 7);
    println!("dataset: {} train / {} eval problems", ds.train.len(), ds.eval.len());

    // fresh policy + short supervised warm start (stands in for starting
    // from a pretrained instruct model)
    let mut state = rt.init(7)?;
    let problems: Vec<&sortedrl::tasks::Problem> = ds.train.iter().collect();
    let losses = sft_warm_start(&rt, &mut state, &problems, 30, 3e-3, 10)?;
    println!("warm start: sft loss {:.3} -> {:.3}", losses[0], losses.last().unwrap());

    // a few SortedRL on-policy updates
    let cfg = LoopConfig {
        scheduler: SchedulerKind::SortedOnPolicy,
        rollout_prompts: 4,
        group_size: 2,
        samples_per_prompt: 2,
        update_batch: 8,
        max_updates: 6,
        lr: 5e-4,
        temperature: 1.0,
        seed: 7,
        adv: AdvantageKind::ReinforcePlusPlus,
        max_new: 96,
        eval_every: 3,
        eval_limit: 16,
        verbose: true,
        ..LoopConfig::default()
    };
    let mut ctl = Controller::new(&rt, Box::new(MathTask), ds, cfg);
    let result = ctl.run(&mut state)?;

    println!("\nfinal eval: score {:.3} accuracy {:.3} (reward in [-1, 1] of max)",
             result.final_eval.score, result.final_eval.accuracy);
    println!("rollout bubble ratio {:.1}%; {} rollout tokens",
             result.bubble_ratio * 100.0, result.total_rollout_tokens);
    Ok(())
}
