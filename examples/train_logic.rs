//! End-to-end driver (the EXPERIMENTS.md §E2E run): RL-train the policy LM
//! on Knights & Knaves for a few hundred update steps through the full
//! three-layer stack, logging the reward/score/length curves to CSV.
//!
//! Run:  make artifacts && cargo run --release --example train_logic -- \
//!           [updates] [scheduler]
//!
//! Defaults: 200 updates, sorted-on-policy.  The loss curve lands in
//! results/e2e_logic_<scheduler>.csv.

use sortedrl::coordinator::{sft_warm_start, Controller, LoopConfig, SchedulerKind};
use sortedrl::data::Dataset;
use sortedrl::rl::advantage::AdvantageKind;
use sortedrl::runtime::Runtime;
use sortedrl::tasks::logic::LogicTask;
use sortedrl::tasks::Task;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let updates: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let scheduler = SchedulerKind::parse(
        args.get(1).map(|s| s.as_str()).unwrap_or("on-policy"),
    )
    .expect("scheduler: baseline|on-policy|partial|post-hoc-sort|no-grouped");

    let rt = Runtime::load(Path::new("artifacts"), None)?;
    eprintln!("platform {}; tag {}; {} params",
              rt.platform(), rt.manifest.tag, rt.manifest.model.param_count);

    let task = LogicTask::default();
    let ds = Dataset::generate(&task, 200, 0.1, 42); // 1000 puzzles, 3..=7 chars
    eprintln!("dataset: {} train / {} eval", ds.train.len(), ds.eval.len());

    let mut state = rt.init(42)?;
    let problems: Vec<&sortedrl::tasks::Problem> = ds.train.iter().collect();
    eprintln!("warm start: 200 sft steps...");
    let losses = sft_warm_start(&rt, &mut state, &problems, 200, 2e-3, 25)?;
    eprintln!("warm start done: {:.3} -> {:.3}", losses[0], losses.last().unwrap());

    let cfg = LoopConfig {
        scheduler,
        rollout_prompts: 4,
        group_size: 4,
        samples_per_prompt: 4,
        update_batch: 32,
        max_updates: updates,
        lr: 4e-4,
        temperature: 1.0,
        seed: 42,
        adv: AdvantageKind::ReinforcePlusPlus,
        max_new: 176,
        eval_every: 10,
        eval_limit: 64,
        verbose: true,
        ..LoopConfig::default()
    };
    let mut ctl = Controller::new(&rt, Box::new(task), ds, cfg);
    let t0 = std::time::Instant::now();
    let result = ctl.run(&mut state)?;
    let wall = t0.elapsed().as_secs_f64();

    // loss/score curve -> CSV
    let mut csv = String::from(
        "update,epochs,mean_reward,accuracy,format_rate,mean_resp_len,\
         staleness,kl,loss,eval_score,eval_acc,eval_len\n");
    for r in &result.rows {
        let (es, ea, el) = r
            .eval
            .map(|e| (e.score.to_string(), e.accuracy.to_string(),
                      e.mean_resp_len.to_string()))
            .unwrap_or_default();
        csv.push_str(&format!(
            "{},{:.3},{:.4},{:.4},{:.4},{:.2},{:.3},{:.5},{:.5},{},{},{}\n",
            r.update.update_idx, r.epochs, r.update.mean_reward,
            r.update.accuracy, r.update.format_rate, r.update.mean_resp_len,
            r.update.mean_staleness, r.update.stats.approx_kl,
            r.update.stats.loss, es, ea, el));
    }
    std::fs::create_dir_all("results")?;
    let out = format!("results/e2e_logic_{}.csv", scheduler.name());
    std::fs::write(&out, csv)?;

    println!("\n=== E2E summary ({} updates, {:.1}s wall) ===", updates, wall);
    println!("scheduler:        {}", scheduler.name());
    println!("final val score:  {:.3} (max 1.0)", result.final_eval.score);
    println!("final accuracy:   {:.3}", result.final_eval.accuracy);
    println!("final resp len:   {:.1} tokens", result.final_eval.mean_resp_len);
    println!("bubble ratio:     {:.2}%", result.bubble_ratio * 100.0);
    println!("rollout tokens:   {}", result.total_rollout_tokens);
    println!("rollout/update s: {:.1} / {:.1}",
             result.phase_clock.rollout, result.phase_clock.update);
    println!("curve:            {out}");
    Ok(())
}
