//! Tiny shared bench harness (criterion is unavailable offline): timed
//! closures with warmup, median-of-runs reporting, ns/op + throughput.

use std::time::Instant;

pub struct BenchReport {
    pub name: String,
    pub iters: u64,
    pub total_secs: f64,
    pub per_iter_secs: f64,
}

/// Run `f` repeatedly until ~`budget_secs` elapse (after 2 warmup calls);
/// prints and returns the per-iteration time.
pub fn bench<F: FnMut()>(name: &str, budget_secs: f64, mut f: F) -> BenchReport {
    f();
    f();
    let mut iters = 0u64;
    let start = Instant::now();
    loop {
        f();
        iters += 1;
        if start.elapsed().as_secs_f64() >= budget_secs {
            break;
        }
    }
    let total = start.elapsed().as_secs_f64();
    let per = total / iters as f64;
    let human = if per < 1e-6 {
        format!("{:.0} ns", per * 1e9)
    } else if per < 1e-3 {
        format!("{:.2} us", per * 1e6)
    } else if per < 1.0 {
        format!("{:.2} ms", per * 1e3)
    } else {
        format!("{:.2} s", per)
    };
    println!("{name:<52} {human:>12}/iter   ({iters} iters)");
    BenchReport { name: name.to_string(), iters, total_secs: total, per_iter_secs: per }
}

/// Report a rate metric computed by the caller.
pub fn report_rate(name: &str, unit: &str, rate: f64) {
    println!("{name:<52} {rate:>12.0} {unit}");
}
