//! End-to-end runtime benches over the real PJRT artifacts: prefill /
//! decode_chunk / train_step latency and engine decode throughput — one
//! bench per paper-relevant hot path (Fig. 5's real-engine analogue).
//!
//! Requires `make artifacts`; skips politely otherwise.
//! `cargo bench --bench runtime_bench`.

mod bench_util;

use bench_util::{bench, report_rate};
use sortedrl::rollout::{Engine, EngineConfig, Request};
use sortedrl::runtime::{Runtime, TrainBatch};
use sortedrl::tokenizer::PAD;
use sortedrl::util::rng::Pcg64;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP runtime_bench: no artifacts (run `make artifacts`)");
        return Ok(());
    }
    let rt = Runtime::load(dir, None)?;
    let sh = rt.manifest.shapes.clone();
    println!("== runtime benches (tag {}, {} params) ==",
             rt.manifest.tag, rt.manifest.model.param_count);
    let state = rt.init(1)?;
    let mut rng = Pcg64::new(2);

    // prefill
    let tokens: Vec<i32> = (0..sh.engine_batch * sh.prefill_seq)
        .map(|_| rng.range_i64(3, 60) as i32)
        .collect();
    let lens = vec![48i32; sh.engine_batch];
    bench(&format!("prefill B={} Sp={}", sh.engine_batch, sh.prefill_seq), 3.0, || {
        std::hint::black_box(rt.prefill(&state, &tokens, &lens).unwrap());
    });

    // decode chunk at full occupancy
    let (kv0, _) = rt.prefill(&state, &tokens, &lens)?;
    let tok = vec![5i32; sh.engine_batch];
    let pos = lens.clone();
    let active = vec![1i32; sh.engine_batch];
    let uniforms: Vec<f32> = (0..sh.engine_batch * sh.decode_chunk)
        .map(|_| rng.uniform_f32())
        .collect();
    let mut kv_cell = Some(kv0);
    let r = bench(&format!("decode_chunk B={} k={}", sh.engine_batch, sh.decode_chunk),
                  3.0, || {
        let kv = kv_cell.take().unwrap();
        let (kv, out) = rt
            .decode_chunk(&state, kv, &tok, &pos, &active, &uniforms, 1.0)
            .unwrap();
        std::hint::black_box(&out);
        kv_cell = Some(kv);
    });
    report_rate("  decode tokens/sec (full occupancy)", "tok/s",
                (sh.engine_batch * sh.decode_chunk) as f64 / r.per_iter_secs);

    // train step
    let t = sh.train_seq;
    let toks: Vec<i32> = (0..sh.train_batch * t)
        .map(|_| rng.range_i64(3, 60) as i32)
        .collect();
    let mut mask = vec![0f32; sh.train_batch * t];
    for b in 0..sh.train_batch {
        for i in 8..t.min(120) {
            mask[b * t + i] = 1.0;
        }
    }
    let old_logp = rt.logprob(&state, &toks)?;
    let mut st2 = rt.init(1)?;
    let r = bench(&format!("train_step Bt={} T={}", sh.train_batch, t), 5.0, || {
        let batch = TrainBatch {
            tokens: toks.clone(),
            mask: mask.clone(),
            adv: vec![0.1; sh.train_batch * t],
            old_logp: old_logp.clone(),
            lr: 1e-4,
        };
        std::hint::black_box(rt.train_step(&mut st2, &batch).unwrap());
    });
    report_rate("  trained tokens/sec", "tok/s",
                mask.iter().sum::<f32>() as f64 / r.per_iter_secs);

    // engine end-to-end: generate to completion from 2x-oversubscribed queue
    let r = bench("engine run_to_completion (2x oversub, cap 48)", 8.0, || {
        let mut engine = Engine::new(&rt, EngineConfig {
            temperature: 1.0,
            greedy: false,
            seed: 3,
            ..EngineConfig::default()
        });
        let prompt: Vec<i32> = vec![1, 43, 11, 3, 33, 32, 34, 25, 3, 46];
        engine.submit((0..sh.engine_batch * 2).map(|i| {
            Request::fresh(i as u64, 0, i as u64, prompt.clone(), 48)
        }));
        let rollouts = engine.run_to_completion(&state).unwrap();
        std::hint::black_box(&rollouts);
    });
    let _ = r;
    let _ = PAD;
    let st = rt.stats_snapshot();
    println!("\ncumulative runtime stats: prefill {:.2}s/{} calls, decode {:.2}s/{} calls, train {:.2}s/{} calls",
             st.prefill_secs, st.prefill_calls, st.decode_secs, st.decode_calls,
             st.train_secs, st.train_calls);
    Ok(())
}
