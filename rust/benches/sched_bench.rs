//! Benchmarks for the `sched` layer: dispatch-policy makespan comparison
//! (the headline: predicted-SJF vs FCFS round-robin on the long-tail
//! workload) plus host-side cost of the pool simulator and predictors.
//! `cargo bench --bench sched_bench`.

mod bench_util;

use bench_util::{bench, report_rate};
use sortedrl::rollout::kv::KvMode;
use sortedrl::sched::{
    make_predictor, DispatchPolicy, EngineSpec, LengthPredictor, PredictorKind, TailConfig,
};
use sortedrl::sim::{
    longtail_workload, pool_makespan, scale_probe, scale_probe_arrivals, simulate_pool,
    CostModel, PoolSimOpts, SimCore, SimMode, SimRun,
};
use sortedrl::trace::Tracer;
use sortedrl::util::json::{num, obj, s, Json};
use sortedrl::workload::ArrivalSpec;

/// Peak resident set (VmHWM) in kB from /proc/self/status; 0.0 when the
/// proc filesystem is unavailable (non-Linux hosts).
fn peak_rss_kb() -> f64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|body| {
            body.lines()
                .find(|l| l.starts_with("VmHWM:"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse::<f64>().ok())
        })
        .unwrap_or(0.0)
}

/// `--arrival SPEC` override for the open-loop leg of the scale headline
/// (defaults to a Poisson stream slightly above the pool's sustained
/// rate).
fn arrival_override() -> Option<ArrivalSpec> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--arrival")
        .and_then(|i| args.get(i + 1))
        .map(|v| ArrivalSpec::parse(v).expect("invalid --arrival spec"))
}

/// `--tail-threshold TOK [--tail-engines N]` override for the headline's
/// tail-packing leg (defaults: 2048-token threshold, 1 tail engine).
fn tail_override() -> Option<TailConfig> {
    let args: Vec<String> = std::env::args().collect();
    let get = |flag: &str| {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .map(|v| v.parse::<usize>().unwrap_or_else(|_| panic!("{flag} wants an integer")))
    };
    get("--tail-threshold").map(|threshold| {
        let cfg = TailConfig { threshold, tail_engines: get("--tail-engines").unwrap_or(1) };
        cfg.validate().expect("invalid tail config");
        cfg
    })
}

/// `--engine-spec SPEC` override for the tail leg's fleet shape
/// (`[Nx]LANES:KV[:SPEED]`, comma-separated).
fn spec_override() -> Vec<EngineSpec> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--engine-spec")
        .and_then(|i| args.get(i + 1))
        .map(|v| EngineSpec::parse_fleet(v).expect("invalid --engine-spec"))
        .unwrap_or_default()
}

/// The scale headline: stage one oversubscribed wave of `requests`
/// long-tail requests on `engines` engines, let the event core run the
/// whole wave (cut off at `wall_ceiling_secs`), then time-box the
/// tick-by-tick reference stepper on the same workload to measure the
/// speedup.  A third leg replays the same request count as an open-loop
/// arrival stream (Poisson by default, `--arrival` to override) through
/// the arrival key class on the same event heap — the claim under guard
/// is that open loop costs what closed loop costs.  Emits BENCH_sim.json
/// for the CI perf guard.  Returns whether the event core finished every
/// request inside the ceiling, in both loop shapes.
fn scale_run(requests: usize, engines: usize, q_total: usize,
             wall_ceiling_secs: f64) -> bool {
    let cost = CostModel::default();
    let w = longtail_workload(requests, 8192, 1);
    println!("== scale headline: {requests} requests / {engines} engines x {} lanes ==",
             q_total / engines);
    let ev = scale_probe(&w, engines, q_total, cost,
                         DispatchPolicy::ShortestPredictedFirst,
                         PredictorKind::History, SimCore::Event,
                         wall_ceiling_secs, 64);
    let ev_rate = ev.completed as f64 / ev.wall_secs.max(1e-9);
    println!("  event core:     {:>9}/{} requests in {:6.2}s host  \
              ({:.0} req/s), makespan {:.0}s sim",
             ev.completed, ev.requests, ev.wall_secs, ev_rate, ev.makespan);

    // time-box the reference core on the same staged wave; its completion
    // rate inside the budget is the speedup denominator (running 1M
    // requests tick-by-tick to completion would take hours)
    let rf = scale_probe(&w, engines, q_total, cost,
                         DispatchPolicy::ShortestPredictedFirst,
                         PredictorKind::History, SimCore::Reference,
                         5.0_f64.min(wall_ceiling_secs), 64);
    let rf_rate = rf.completed as f64 / rf.wall_secs.max(1e-9);
    let speedup = if rf_rate > 0.0 { ev_rate / rf_rate } else { f64::INFINITY };
    println!("  reference core: {:>9} requests in {:6.2}s host  \
              ({:.0} req/s)  ->  {speedup:.0}x event-core speedup",
             rf.completed, rf.wall_secs, rf_rate);
    // open-loop leg: the same request count as a timestamped stream.  The
    // default rate sits ~13% above the pool's sustained throughput (~2.1
    // req/s per engine at this cost model), so the central queue stays
    // non-empty and delivery stays O(log engines) per arrival.
    let default_rate = engines as f64 * 2.4;
    let spec = arrival_override()
        .unwrap_or(ArrivalSpec::Poisson { rate: default_rate });
    let arrivals = spec.build(requests, 8192, 1).expect("arrival stream build");
    let op = scale_probe_arrivals(&arrivals, engines, q_total, cost,
                                  DispatchPolicy::LeastLoaded,
                                  PredictorKind::History, SimCore::Event,
                                  wall_ceiling_secs, 64);
    let op_rate = op.completed as f64 / op.wall_secs.max(1e-9);
    println!("  open loop:      {:>9}/{} arrivals in {:6.2}s host  \
              ({:.0} req/s host), makespan {:.0}s sim  [{spec:?}]",
             op.completed, op.requests, op.wall_secs, op_rate, op.makespan);

    // tail-packing leg: run a bounded sub-wave to completion with and
    // without tail rounds (the completion-driven SimRun on the full wave
    // would dwarf the time-boxed probes above) and record the bubble drop.
    // `--tail-threshold/--tail-engines/--engine-spec` override the shape.
    let tail_cfg =
        tail_override().unwrap_or(TailConfig { threshold: 2048, tail_engines: 1 });
    let specs = spec_override();
    let (tl_engines, tl_q) = if specs.is_empty() {
        (8usize, 256usize)
    } else {
        (specs.len(), specs.iter().map(|s| s.lanes).sum())
    };
    let tw = longtail_workload(requests.min(20_000), 8192, 1);
    let tl_opts = PoolSimOpts {
        engines: tl_engines,
        q_total: tl_q,
        update_batch: tl_q,
        cost,
        dispatch: DispatchPolicy::ShortestPredictedFirst,
        predictor: PredictorKind::Oracle,
        core: SimCore::Event,
        ..PoolSimOpts::default()
    };
    let t0 = std::time::Instant::now();
    let tl_off = SimRun::new(SimMode::SortedPartial, tl_opts)
        .workload(&tw)
        .specs(&specs)
        .run();
    let tl_on = SimRun::new(SimMode::SortedPartial,
                            PoolSimOpts { tail: Some(tail_cfg), ..tl_opts })
        .workload(&tw)
        .specs(&specs)
        .run();
    let tl_wall = t0.elapsed().as_secs_f64();
    println!("  tail packing:   bubble {:5.2}% -> {:5.2}%  ({} rounds, {} requests \
              packed, {} reparts; head/tail {:.2}%/{:.2}%) in {:.2}s host",
             tl_off.bubble_ratio * 100.0, tl_on.bubble_ratio * 100.0,
             tl_on.tail_rounds, tl_on.tail_admitted, tl_on.repartitions,
             tl_on.head_bubble * 100.0, tl_on.tail_bubble * 100.0, tl_wall);

    let rss = peak_rss_kb();
    println!("  peak RSS (VmHWM proxy): {:.0} MiB", rss / 1024.0);

    let j = obj(vec![
        ("bench", s("sched_bench/scale")),
        ("requests", num(ev.requests as f64)),
        ("engines", num(ev.engines as f64)),
        ("completed", num(ev.completed as f64)),
        ("finished_all", Json::Bool(ev.finished_all)),
        ("wall_secs", num(ev.wall_secs)),
        ("requests_per_sec", num(ev_rate)),
        ("makespan_sim_secs", num(ev.makespan)),
        ("reference_requests_per_sec", num(rf_rate)),
        ("speedup_vs_reference", num(if speedup.is_finite() { speedup } else { -1.0 })),
        ("openloop_arrival", s(&format!("{spec:?}"))),
        ("openloop_completed", num(op.completed as f64)),
        ("openloop_finished_all", Json::Bool(op.finished_all)),
        ("openloop_wall_secs", num(op.wall_secs)),
        ("openloop_requests_per_sec", num(op_rate)),
        ("openloop_makespan_sim_secs", num(op.makespan)),
        ("tail_threshold", num(tail_cfg.threshold as f64)),
        ("tail_engines", num(tail_cfg.tail_engines as f64)),
        ("tail_bubble_off", num(tl_off.bubble_ratio)),
        ("tail_bubble_on", num(tl_on.bubble_ratio)),
        ("tail_rounds", num(tl_on.tail_rounds as f64)),
        ("tail_repartitions", num(tl_on.repartitions as f64)),
        ("tail_head_bubble", num(tl_on.head_bubble)),
        ("tail_tail_bubble", num(tl_on.tail_bubble)),
        ("tail_wall_secs", num(tl_wall)),
        ("peak_rss_kb", num(rss)),
    ]);
    match std::fs::write("BENCH_sim.json", j.to_string_pretty()) {
        Ok(()) => println!("  wrote BENCH_sim.json\n"),
        Err(e) => eprintln!("  BENCH_sim.json write failed: {e}"),
    }
    ev.finished_all && op.finished_all
}

fn main() {
    // `--headline` (the CI perf guard) runs ONLY the 1M-request / 1k-engine
    // scale probe so the wall-clock ceiling bounds a single measurement
    if std::env::args().any(|a| a == "--headline") {
        let ok = scale_run(1_000_000, 1_000, 32_000, 240.0);
        if !ok {
            eprintln!("headline FAILED: event core did not finish 1M requests \
                       (closed or open loop) inside the wall ceiling");
            std::process::exit(1);
        }
        return;
    }

    println!("== sched benches: engine-pool dispatch on longtail_workload(512, 8192) ==\n");
    let w = longtail_workload(512, 8192, 1);
    let cost = CostModel::default();

    // ---- makespan comparison (simulated seconds, 4 engines x 32 lanes) ----
    let rr = pool_makespan(&w, 4, 128, cost, DispatchPolicy::RoundRobin,
                           PredictorKind::History);
    let ll = pool_makespan(&w, 4, 128, cost, DispatchPolicy::LeastLoaded,
                           PredictorKind::History);
    let sjf_h = pool_makespan(&w, 4, 128, cost,
                              DispatchPolicy::ShortestPredictedFirst,
                              PredictorKind::History);
    let sjf_o = pool_makespan(&w, 4, 128, cost,
                              DispatchPolicy::ShortestPredictedFirst,
                              PredictorKind::Oracle);
    println!("makespan, 4 engines x 32 lanes (simulated seconds):");
    println!("  fcfs round-robin     {rr:8.1}s");
    println!("  least-loaded         {ll:8.1}s   ({:+.1}% vs rr)", 100.0 * (ll / rr - 1.0));
    println!("  sjf (history)        {sjf_h:8.1}s   ({:+.1}% vs rr)", 100.0 * (sjf_h / rr - 1.0));
    println!("  sjf (oracle)         {sjf_o:8.1}s   ({:+.1}% vs rr)", 100.0 * (sjf_o / rr - 1.0));
    // the headline uses the PREDICTED (history) variant — the oracle line
    // above shows the ceiling a better predictor could reach
    println!("  predicted-SJF (history) beats round-robin by {:.1}% on makespan\n",
             100.0 * (rr / sjf_h - 1.0));

    // ---- 1-vs-4 engine bubble + latency tail under the partial scheduler ----
    let slo_opts = PoolSimOpts {
        q_total: 128,
        update_batch: 128,
        cost,
        dispatch: DispatchPolicy::ShortestPredictedFirst,
        predictor: PredictorKind::Oracle,
        slo: Some(25.0),
        ..PoolSimOpts::default()
    };
    let one = SimRun::new(SimMode::SortedPartial, PoolSimOpts { engines: 1, ..slo_opts })
        .workload(&w)
        .run();
    let four = SimRun::new(SimMode::SortedPartial, PoolSimOpts { engines: 4, ..slo_opts })
        .workload(&w)
        .run();
    println!("sorted-partial bubble: 1 engine {:.2}% | 4 engines {:.2}%;  \
              rollout {:.1}s -> {:.1}s",
             one.bubble_ratio * 100.0, four.bubble_ratio * 100.0,
             one.rollout_time, four.rollout_time);
    println!("  e2e p99 {:.1}s -> {:.1}s; goodput@25s {:.3} -> {:.3}\n",
             one.slo.e2e_p99, four.slo.e2e_p99,
             one.slo.goodput, four.slo.goodput);

    // ---- async updates vs the sync baseline (the policy-API payoff) ----
    let base = simulate_pool(SimMode::Baseline, &w, 4, 128, 128, cost,
                             DispatchPolicy::ShortestPredictedFirst,
                             PredictorKind::History);
    let asy = simulate_pool(SimMode::Async, &w, 4, 128, 128, cost,
                            DispatchPolicy::ShortestPredictedFirst,
                            PredictorKind::History);
    println!("async vs baseline (4 engines x 32 lanes):");
    println!("  bubble    {:6.2}%  vs  {:6.2}%  (async must be lower)",
             asy.bubble_ratio * 100.0, base.bubble_ratio * 100.0);
    println!("  total     {:6.1}s  vs  {:6.1}s  (update time hidden under decode)",
             asy.total_time, base.total_time);
    println!("  update    {:6.1}s overlapped; overhang {:.1}s\n",
             asy.update_time,
             (asy.total_time - asy.infer_time - asy.rollout_time).max(0.0));

    // ---- work stealing vs baseline makespan (skewed length distribution) ----
    let steal_opts = PoolSimOpts {
        engines: 4,
        q_total: 128,
        update_batch: 128,
        cost,
        dispatch: DispatchPolicy::RoundRobin,
        predictor: PredictorKind::History,
        steal: false,
        ..PoolSimOpts::default()
    };
    let no_steal = SimRun::new(SimMode::Baseline, steal_opts).workload(&w).run();
    let stealing = SimRun::new(SimMode::Baseline, PoolSimOpts { steal: true, ..steal_opts })
        .workload(&w)
        .run();
    println!("work stealing vs none (baseline waves, 4x32, round-robin striping):");
    println!("  makespan  {:6.1}s  vs  {:6.1}s  ({:+.1}% with stealing)",
             stealing.rollout_time, no_steal.rollout_time,
             100.0 * (stealing.rollout_time / no_steal.rollout_time - 1.0));
    println!("  bubble    {:6.2}%  vs  {:6.2}%",
             stealing.bubble_ratio * 100.0, no_steal.bubble_ratio * 100.0);
    println!("  {} steals, {} partial tokens migrated\n",
             stealing.steals, stealing.migrated_tokens);

    // ---- paged vs reserved KV accounting at a fixed budget ----
    // 40k tokens/engine: reserve-the-cap admission (~8.4k per worst-case
    // lane) caps each engine at ~4 of its 32 lanes; paged accounting
    // charges actual context (median ~1k) and packs many more
    let kv_opts = PoolSimOpts {
        engines: 4,
        q_total: 128,
        update_batch: 128,
        cost,
        dispatch: DispatchPolicy::ShortestPredictedFirst,
        predictor: PredictorKind::History,
        kv_budget: 40_000,
        kv_page: 256,
        ..PoolSimOpts::default()
    };
    let reserved =
        SimRun::new(SimMode::SortedPartial, PoolSimOpts { kv_mode: KvMode::Reserve, ..kv_opts })
            .workload(&w)
            .run();
    let paged =
        SimRun::new(SimMode::SortedPartial, PoolSimOpts { kv_mode: KvMode::Paged, ..kv_opts })
            .workload(&w)
            .run();
    println!("paged vs reserved KV (sorted-partial, 4x32, 40k budget, 256-page):");
    println!("  concurrent lanes  {:4} vs {:4}  (peak; paged must admit more)",
             paged.peak_lanes, reserved.peak_lanes);
    println!("  bubble            {:6.2}%  vs  {:6.2}%", paged.bubble_ratio * 100.0,
             reserved.bubble_ratio * 100.0);
    println!("  rollout           {:6.1}s  vs  {:6.1}s  ({:+.1}% with paging)",
             paged.rollout_time, reserved.rollout_time,
             100.0 * (paged.rollout_time / reserved.rollout_time - 1.0));
    println!("  backpressure      {} forced sheds, {} throttles\n",
             paged.kv_sheds, paged.throttles);

    // ---- host-time benches ----
    bench("pool_makespan 4x32 sjf/oracle (host)", 2.0, || {
        std::hint::black_box(pool_makespan(
            &w, 4, 128, cost, DispatchPolicy::ShortestPredictedFirst,
            PredictorKind::Oracle));
    });
    bench("simulate_pool partial 4x32 sjf/history (host)", 2.0, || {
        std::hint::black_box(simulate_pool(
            SimMode::SortedPartial, &w, 4, 128, 128, cost,
            DispatchPolicy::ShortestPredictedFirst, PredictorKind::History));
    });
    bench("simulate_pool baseline 8x16 round-robin (host)", 2.0, || {
        std::hint::black_box(simulate_pool(
            SimMode::Baseline, &w, 8, 128, 128, cost,
            DispatchPolicy::RoundRobin, PredictorKind::Bucket));
    });

    // tracer overhead guard: the disabled tracer rides the same drive loop
    // as every golden/fuzz run, so its cost must stay in the noise; the
    // enabled run (spans + chrome events) shows the price of observability
    let trace_opts = PoolSimOpts {
        engines: 4,
        q_total: 128,
        update_batch: 128,
        cost,
        dispatch: DispatchPolicy::ShortestPredictedFirst,
        predictor: PredictorKind::History,
        ..PoolSimOpts::default()
    };
    let off = bench("simulate_pool partial 4x32 tracer OFF (host)", 2.0, || {
        let mut t = Tracer::disabled();
        std::hint::black_box(
            SimRun::new(SimMode::SortedPartial, trace_opts).workload(&w).tracer(&mut t).run(),
        );
    });
    let on = bench("simulate_pool partial 4x32 tracer ON (spans+chrome)", 2.0, || {
        let mut t = Tracer::new(Some(25.0), true);
        std::hint::black_box(
            SimRun::new(SimMode::SortedPartial, trace_opts).workload(&w).tracer(&mut t).run(),
        );
    });
    println!("  tracer overhead: {:+.1}% per run when fully enabled",
             100.0 * (on.per_iter_secs / off.per_iter_secs - 1.0));

    // predictor hot path: predict+observe churn
    for kind in PredictorKind::ALL {
        let mut p = make_predictor(kind);
        for r in &w {
            p.observe(r.id as u64, r.prompt_len, r.output_len);
        }
        let mut i = 0usize;
        let r = bench(&format!("predictor {} predict (hot)", kind.name()), 1.0, || {
            let req = &w[i % w.len()];
            std::hint::black_box(p.predict(req.id as u64, req.prompt_len));
            i += 1;
        });
        report_rate("  predictions/sec", "ops/s", 1.0 / r.per_iter_secs);
    }
    println!();

    // reduced-scale probe so every bench run emits BENCH_sim.json; the CI
    // perf guard runs the full 1M/1k version via `--headline`
    scale_run(100_000, 128, 4_096, 120.0);
}
