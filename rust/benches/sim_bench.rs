//! Benchmarks for the discrete-event simulator + the Fig. 5 regeneration
//! path (the performance-figure harness itself must be fast enough to
//! sweep).  Run via `cargo bench --bench sim_bench`.

mod bench_util;

use bench_util::{bench, report_rate};
use sortedrl::sim::{longtail_workload, simulate, CostModel, SimMode};

fn main() {
    println!("== simulator benches ==");
    let w512 = longtail_workload(512, 8192, 1);
    let w4k = longtail_workload(4096, 8192, 2);
    let cost = CostModel::default();

    let r = bench("sim baseline 512x8k", 2.0, || {
        std::hint::black_box(simulate(SimMode::Baseline, &w512, 128, 128, cost));
    });
    // iterations processed per second of host time
    let sim_report = simulate(SimMode::Baseline, &w512, 128, 128, cost);
    let events = sim_report.timeline.events().len() as f64;
    report_rate("  timeline events/sec (host)", "ev/s", events / r.per_iter_secs);

    bench("sim sorted-partial 512x8k", 2.0, || {
        std::hint::black_box(simulate(SimMode::SortedPartial, &w512, 128, 128, cost));
    });
    bench("sim sorted-on-policy 512x8k", 2.0, || {
        std::hint::black_box(simulate(SimMode::SortedOnPolicy, &w512, 128, 128, cost));
    });
    bench("sim sorted-partial 4096x8k (8 groups)", 4.0, || {
        for chunk in w4k.chunks(512) {
            std::hint::black_box(simulate(SimMode::SortedPartial, chunk, 128, 128, cost));
        }
    });
    bench("workload generation 4096", 1.0, || {
        std::hint::black_box(longtail_workload(4096, 8192, 3));
    });
}
