//! Benchmarks for the pure-rust substrates on the controller's hot path:
//! task generation/verification, tokenizer, advantage computation, buffer
//! operations.  `cargo bench --bench substrate_bench`.

mod bench_util;

use bench_util::bench;
use sortedrl::coordinator::{Mode, RolloutBuffer};
use sortedrl::rl::advantage::{advantages, AdvantageKind, BaselineState, RewardEntry};
use sortedrl::rollout::Rollout;
use sortedrl::tasks::logic::LogicTask;
use sortedrl::tasks::math::MathTask;
use sortedrl::tasks::Task;
use sortedrl::tokenizer::Tokenizer;
use sortedrl::util::rng::Pcg64;

fn main() {
    println!("== substrate benches ==");
    let mut rng = Pcg64::new(1);
    let logic = LogicTask::default();
    let math = MathTask;

    bench("K&K generate+solve n=5", 1.0, || {
        std::hint::black_box(logic.generate(&mut rng, 5, 0));
    });
    bench("K&K generate+solve n=7 (128 models)", 1.0, || {
        std::hint::black_box(logic.generate(&mut rng, 7, 0));
    });
    bench("math chain generate d=8", 1.0, || {
        std::hint::black_box(math.generate(&mut rng, 8, 0));
    });

    let prob = logic.generate(&mut rng, 5, 1);
    bench("logic verify (sft target)", 1.0, || {
        std::hint::black_box(logic.verify(&prob, &prob.sft_target));
    });

    let tok = Tokenizer::new();
    let text = tok.decode(&prob.prompt);
    bench("tokenizer encode (~50 tokens)", 1.0, || {
        std::hint::black_box(tok.encode(&text).unwrap());
    });

    let entries: Vec<RewardEntry> = (0..1024)
        .map(|i| RewardEntry { reward: (i % 7) as f64 - 3.0, group: (i / 8) as u64 })
        .collect();
    let mut bl = BaselineState::default();
    bench("advantages reinforce++ (1024 traj)", 1.0, || {
        std::hint::black_box(advantages(AdvantageKind::ReinforcePlusPlus, &entries, &mut bl));
    });
    bench("advantages group-norm (1024 traj, 128 groups)", 1.0, || {
        std::hint::black_box(advantages(AdvantageKind::GroupNorm, &entries, &mut bl));
    });

    bench("buffer lifecycle churn (512 entries)", 1.0, || {
        let mut buf = RolloutBuffer::new();
        let rids: Vec<u64> = (0..512)
            .map(|i| buf.load_prompt(i, i as u64, vec![1, 2, 3], 64))
            .collect();
        let reqs = buf.dispatch(&rids);
        for (i, req) in reqs.iter().enumerate() {
            let r = Rollout {
                request: req.clone(),
                response: vec![5; 16],
                logp: vec![-0.5; 16],
                finish_version: 1,
                complete: i % 3 != 0,
                finished_at: i as f64,
            };
            if r.complete {
                buf.record_finished(&r);
            } else {
                buf.record_terminated(&r, Mode::Partial);
            }
        }
        let ready = buf.ready_rids();
        std::hint::black_box(buf.consume(&ready));
    });
}
