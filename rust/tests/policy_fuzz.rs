//! Randomized policy-driver fuzz harness.
//!
//! Seeded-RNG event sequences — random workload lengths, engine counts,
//! lane counts, KV budgets, dispatch modes, steal on/off, tail-packing
//! configs, heterogeneous fleet specs — driven through EVERY
//! `SchedulerKind` on both backends:
//!
//!   * [`TokenBackend`] (deterministic multi-engine harness) checks its
//!     invariants after every single transition: conservation (no request
//!     lost or duplicated, across any number of cross-engine steals), the
//!     KV budget ceiling, progress bounds.  A completed `drive` call IS
//!     the proof; the assertions below add the terminal contract.
//!   * The simulator backend (driven through `SimRun`) re-checks request
//!     and token conservation from the report side.
//!
//! Termination is part of the property: `drive` has livelock tripwires
//! (decision budget, idle-step and fruitless-decision caps), so a policy
//! that stops making progress fails the test instead of hanging it.
//!
//! The `#[ignore]`d sweep is the same property at ~10x the iteration
//! count for the nightly `cargo test --release -- --ignored` job.

use sortedrl::coordinator::SchedulerKind;
use sortedrl::rollout::kv::{KvConfig, KvMode};
use sortedrl::sched::harness::{HarnessDispatch, TokenBackend, HARNESS_PROMPT};
use sortedrl::sched::policy::{drive_traced, PolicyBuilder, PolicyParams, ScheduleBackend};
use sortedrl::sched::{EngineSpec, TailConfig};
use sortedrl::sim::{
    longtail_workload, CostModel, PoolSimOpts, SimCore, SimMode, SimReport, SimRun,
};
use sortedrl::trace::{SpanOutcome, Tracer};
use sortedrl::util::proptest::{property, Gen};
use sortedrl::workload::Arrival;

const MAX_LEN: usize = 24;

fn fuzz_token_backend_once(g: &mut Gen) {
    let n = g.usize_in(3..24);
    let lens: Vec<usize> = (0..n).map(|_| g.usize_in(1..MAX_LEN + 1)).collect();
    let engines = g.usize_in(1..5);
    let lanes = g.usize_in(1..4);
    let dispatch = if g.bool() { HarnessDispatch::Striped } else { HarnessDispatch::Central };
    // reserve or paged accounting, with page granularity fuzzed too —
    // paged runs exercise estimate admission, in-step sheds, and the
    // KvGovernor throttle path
    let kv_mode = if g.bool() { KvMode::Reserve } else { KvMode::Paged };
    let kv_page = g.usize_in(1..9);
    // budgets always cover the largest single admission estimate (page
    // rounding included), so the empty-engine escape never has to overrun
    // and the KV ceiling checked inside the harness stays strict
    let max_reserve = (HARNESS_PROMPT + MAX_LEN).div_ceil(kv_page) * kv_page;
    let kv_budget = if g.bool() {
        usize::MAX
    } else {
        g.usize_in(max_reserve..4 * max_reserve)
    };
    let kv = KvConfig { mode: kv_mode, budget: kv_budget, page: kv_page };
    let steal = g.bool();
    let kind = *g.pick(&SchedulerKind::ALL);
    let params = PolicyParams {
        refill_prompts: g.usize_in(1..n + 1),
        entries_per_prompt: 1,
        update_batch: g.usize_in(1..9),
    };
    let ctx = format!(
        "n={n} engines={engines} lanes={lanes} {dispatch:?} kv={kv:?} \
         steal={steal} kind={kind:?} refill={} batch={}",
        params.refill_prompts, params.update_batch
    );
    let mut policy = PolicyBuilder::new(kind, params).steal(steal).kv(kv).build();
    let mut b = TokenBackend::new_kv(&lens, engines, lanes, dispatch, kv);
    // per-transition invariants assert inside the backend; an Err here is
    // a driver livelock bail — also a failure.  The recording tracer rides
    // along so span completeness is fuzzed over the same schedule space.
    let mut tracer = Tracer::new(None, false);
    drive_traced(policy.as_mut(), &mut b, &mut tracer)
        .unwrap_or_else(|e| panic!("{ctx}: {e:#}"));
    // terminal contract: nothing left in flight, every request trained or
    // deliberately dropped exactly once
    let v = b.view();
    assert_eq!(v.running, 0, "{ctx}: requests left running");
    assert_eq!(v.queued, 0, "{ctx}: requests left queued");
    assert_eq!(b.consumed.len() + b.dropped.len(), n, "{ctx}: request lost");
    if !steal {
        assert!(b.steal_log.is_empty(), "{ctx}: stole without the wrapper");
    }
    // span completeness: every trained rid has a full, ordered lifecycle;
    // every dropped rid was closed with the Dropped outcome
    for &rid in &b.consumed {
        let sp = tracer.spans().get(&rid)
            .unwrap_or_else(|| panic!("{ctx}: consumed rid {rid} has no span"));
        assert!(sp.dispatched.is_some(), "{ctx}: rid {rid} never dispatched");
        assert!(sp.first_token.is_some(), "{ctx}: rid {rid} has no first token");
        assert!(sp.finished.is_some(), "{ctx}: rid {rid} never finished");
        assert!(sp.consumed.is_some(), "{ctx}: rid {rid} never consumed");
        assert!(sp.is_ordered(), "{ctx}: rid {rid} span out of order: {sp:?}");
        assert!(sp.is_complete(), "{ctx}: rid {rid} span incomplete: {sp:?}");
    }
    for &rid in &b.dropped {
        let sp = tracer.spans().get(&rid)
            .unwrap_or_else(|| panic!("{ctx}: dropped rid {rid} has no span"));
        assert_eq!(sp.outcome, SpanOutcome::Dropped, "{ctx}: rid {rid} {sp:?}");
        assert!(sp.finished.is_some(), "{ctx}: rid {rid} drop never stamped");
    }
}

fn fuzz_sim_backend_once(g: &mut Gen) {
    let n = g.usize_in(16..80);
    let cap = g.usize_in(64..1024);
    let engines = g.usize_in(1..5);
    let q_total = engines * g.usize_in(2..9);
    let mode = *g.pick(&[SimMode::Baseline, SimMode::SortedOnPolicy,
                         SimMode::SortedPartial, SimMode::Async]);
    let opts = PoolSimOpts {
        engines,
        q_total,
        update_batch: g.usize_in(4..33),
        dispatch: *g.pick(&sortedrl::sched::DispatchPolicy::ALL),
        predictor: *g.pick(&sortedrl::sched::PredictorKind::ALL),
        steal: g.bool(),
        // covers the largest possible reservation (prompt < 256 + cap,
        // plus one page of rounding slack in paged mode)
        kv_budget: if g.bool() { usize::MAX } else { (cap + 512) * g.usize_in(1..4) },
        kv_mode: if g.bool() { KvMode::Reserve } else { KvMode::Paged },
        kv_page: g.usize_in(1..257),
        ..PoolSimOpts::default()
    };
    let w = longtail_workload(n, cap, g.usize_in(0..1_000_000) as u64);
    let r = SimRun::new(mode, opts).workload(&w).run();
    let ctx = format!("{mode:?} {opts:?}");
    assert_eq!(r.timeline.finished() as usize + r.clipped + r.dropped, n,
               "request conservation violated: {ctx}");
    assert_eq!(r.useful_tokens + r.wasted_tokens, r.timeline.tokens_out(),
               "token conservation violated: {ctx}");
    assert!((0.0..=1.0).contains(&r.bubble_ratio), "{ctx}");
    assert!(r.rollout_time.is_finite() && r.rollout_time > 0.0, "{ctx}");
    assert_eq!(r.engine_idle.len(), engines, "{ctx}");
    if !opts.steal {
        assert_eq!(r.steals, 0, "{ctx}");
    }
    if mode == SimMode::SortedPartial {
        assert_eq!(r.wasted_tokens, 0, "partial discards nothing: {ctx}");
    }
}

/// Dyadic cost model for the cross-core differential: every per-iteration
/// cost is a power of two, so the reference core's repeated clock adds and
/// the event core's fused `clock + k * iter` multiply are both exact —
/// clocks compare bit-equal and decision equivalence needs no tolerance.
fn dyadic_cost() -> CostModel {
    CostModel {
        t_weights: 0.5,
        t_token: 0.25,
        t_prefill_token: 0.125,
        t_update_token: 0.0625,
        t_infer_token: 0.03125,
    }
}

/// Assert the event core and the reference stepper produced the SAME run:
/// every conservation counter, both simulated clocks (bitwise), and the
/// full training-consumption rid sequence — the decision-equivalence
/// fingerprint.
fn assert_cores_agree(ev: &SimReport, rf: &SimReport, ctx: &str) {
    assert_eq!(ev.timeline.finished(), rf.timeline.finished(), "finished: {ctx}");
    assert_eq!(ev.timeline.tokens_out(), rf.timeline.tokens_out(), "tokens: {ctx}");
    assert_eq!(ev.useful_tokens, rf.useful_tokens, "useful tokens: {ctx}");
    assert_eq!(ev.wasted_tokens, rf.wasted_tokens, "wasted tokens: {ctx}");
    assert_eq!(ev.harvests, rf.harvests, "harvests: {ctx}");
    assert_eq!(ev.clipped, rf.clipped, "clipped: {ctx}");
    assert_eq!(ev.dropped, rf.dropped, "dropped: {ctx}");
    assert_eq!(ev.steals, rf.steals, "steals: {ctx}");
    assert_eq!(ev.migrated_tokens, rf.migrated_tokens, "migrated: {ctx}");
    assert_eq!(ev.kv_sheds, rf.kv_sheds, "kv sheds: {ctx}");
    assert_eq!(ev.throttles, rf.throttles, "throttles: {ctx}");
    assert_eq!(ev.peak_lanes, rf.peak_lanes, "peak lanes: {ctx}");
    assert_eq!(ev.consumed_rids, rf.consumed_rids, "consumed-rid sequence: {ctx}");
    assert_eq!(ev.rollout_time.to_bits(), rf.rollout_time.to_bits(),
               "rollout clock: {ctx}");
    assert_eq!(ev.total_time.to_bits(), rf.total_time.to_bits(),
               "total clock: {ctx}");
    assert_eq!(ev.predictor_mae.to_bits(), rf.predictor_mae.to_bits(),
               "predictor mae: {ctx}");
    assert_eq!(ev.predictor_tau.to_bits(), rf.predictor_tau.to_bits(),
               "predictor tau: {ctx}");
    assert_eq!(ev.kv_trace, rf.kv_trace, "kv trace: {ctx}");
    assert_eq!(ev.tail_rounds, rf.tail_rounds, "tail rounds: {ctx}");
    assert_eq!(ev.tail_admitted, rf.tail_admitted, "tail admitted: {ctx}");
    assert_eq!(ev.repartitions, rf.repartitions, "repartitions: {ctx}");
    assert_eq!(ev.head_bubble.to_bits(), rf.head_bubble.to_bits(), "head bubble: {ctx}");
    assert_eq!(ev.tail_bubble.to_bits(), rf.tail_bubble.to_bits(), "tail bubble: {ctx}");
    let ev_idle: Vec<u64> = ev.engine_idle.iter().map(|v| v.to_bits()).collect();
    let rf_idle: Vec<u64> = rf.engine_idle.iter().map(|v| v.to_bits()).collect();
    assert_eq!(ev_idle, rf_idle, "engine idle: {ctx}");
}

/// Fuzz an optional heterogeneous fleet (empty = uniform shapes).  Speeds
/// stay dyadic (0.5 / 1 / 2) so the spec-normalized clock arithmetic is
/// exact and the Event vs Reference differential can demand bitwise
/// equality; per-engine budgets mirror the pool-level rule of always
/// covering the largest single reservation.
fn fuzz_specs(g: &mut Gen, engines: usize, cap: usize) -> Vec<EngineSpec> {
    if g.bool() {
        return Vec::new();
    }
    (0..engines)
        .map(|_| EngineSpec {
            lanes: g.usize_in(1..5),
            kv_budget: if g.bool() { usize::MAX } else { (cap + 512) * g.usize_in(1..4) },
            speed: *g.pick(&[0.5, 1.0, 2.0]),
        })
        .collect()
}

/// Fuzz an optional tail-packing layer.  Thresholds span the whole
/// plausible band (deep inside the length distribution up to the cap), so
/// runs range from "everything defers" to "tail never opens"; engine
/// counts above the fleet size are legal (the policy clamps the tail
/// group to `engines - 1`).
fn fuzz_tail(g: &mut Gen, cap: usize) -> Option<TailConfig> {
    if g.bool() {
        return None;
    }
    Some(TailConfig {
        threshold: g.usize_in(cap / 4..cap + 1),
        tail_engines: g.usize_in(1..4),
    })
}

/// The cross-core differential: the SAME random workload and options run
/// through the event-heap core and the tick-by-tick reference stepper
/// must be indistinguishable from the report side.
fn fuzz_cross_core_once(g: &mut Gen) {
    let n = g.usize_in(16..80);
    let cap = g.usize_in(64..512);
    let engines = g.usize_in(1..5);
    let q_total = engines * g.usize_in(2..9);
    let mode = *g.pick(&[SimMode::Baseline, SimMode::SortedOnPolicy,
                         SimMode::SortedPartial, SimMode::Async]);
    let base = PoolSimOpts {
        engines,
        q_total,
        update_batch: g.usize_in(4..33),
        cost: dyadic_cost(),
        dispatch: *g.pick(&sortedrl::sched::DispatchPolicy::ALL),
        predictor: *g.pick(&sortedrl::sched::PredictorKind::ALL),
        steal: g.bool(),
        kv_budget: if g.bool() { usize::MAX } else { (cap + 512) * g.usize_in(1..4) },
        kv_mode: if g.bool() { KvMode::Reserve } else { KvMode::Paged },
        kv_page: g.usize_in(1..257),
        tail: fuzz_tail(g, cap),
        ..PoolSimOpts::default()
    };
    let specs = fuzz_specs(g, engines, cap);
    let w = longtail_workload(n, cap, g.usize_in(0..1_000_000) as u64);
    let ctx = format!("{mode:?} specs={specs:?} {base:?}");
    let ev = SimRun::new(mode, PoolSimOpts { core: SimCore::Event, ..base })
        .workload(&w)
        .specs(&specs)
        .run();
    let rf = SimRun::new(mode, PoolSimOpts { core: SimCore::Reference, ..base })
        .workload(&w)
        .specs(&specs)
        .run();
    assert_cores_agree(&ev, &rf, &ctx);
}

/// Open-loop cross-core differential: the same fuzzed workload wrapped in
/// a dyadic arrival stream (gaps are multiples of 0.25, ZERO included so
/// simultaneous arrivals exercise the heap tie rule — engines win ties
/// against the arrival pseudo-index, matching the reference core's strict
/// `t < min clock` delivery gate) must still be bitwise-indistinguishable
/// between the event core and the tick stepper.
fn fuzz_open_loop_cross_core_once(g: &mut Gen) {
    let n = g.usize_in(16..80);
    let cap = g.usize_in(64..512);
    let engines = g.usize_in(1..5);
    let q_total = engines * g.usize_in(2..9);
    let tenants = g.usize_in(1..5);
    let mode = *g.pick(&[SimMode::Baseline, SimMode::SortedOnPolicy,
                         SimMode::SortedPartial, SimMode::Async]);
    let base = PoolSimOpts {
        engines,
        q_total,
        update_batch: g.usize_in(4..33),
        cost: dyadic_cost(),
        dispatch: *g.pick(&sortedrl::sched::DispatchPolicy::ALL),
        predictor: *g.pick(&sortedrl::sched::PredictorKind::ALL),
        steal: g.bool(),
        kv_budget: if g.bool() { usize::MAX } else { (cap + 512) * g.usize_in(1..4) },
        kv_mode: if g.bool() { KvMode::Reserve } else { KvMode::Paged },
        kv_page: g.usize_in(1..257),
        tail: fuzz_tail(g, cap),
        ..PoolSimOpts::default()
    };
    let specs = fuzz_specs(g, engines, cap);
    let w = longtail_workload(n, cap, g.usize_in(0..1_000_000) as u64);
    let mut t = 0.0f64;
    let arrivals: Vec<Arrival> = w
        .iter()
        .map(|&req| {
            t += g.usize_in(0..8) as f64 * 0.25;
            Arrival { t, tenant: req.id % tenants, req }
        })
        .collect();
    let ctx = format!("open-loop {mode:?} tenants={tenants} specs={specs:?} {base:?}");
    let ev = SimRun::new(mode, PoolSimOpts { core: SimCore::Event, ..base })
        .arrivals(&arrivals)
        .specs(&specs)
        .run();
    let rf = SimRun::new(mode, PoolSimOpts { core: SimCore::Reference, ..base })
        .arrivals(&arrivals)
        .specs(&specs)
        .run();
    assert_cores_agree(&ev, &rf, &ctx);
    assert_eq!(ev.timeline.finished() as usize + ev.clipped + ev.dropped, n,
               "open-loop request conservation violated: {ctx}");
}

/// The CI-tier fuzz pass: 200 seeded iterations on the token backend plus
/// 60 on the simulator backend (fixed seeds — `util::proptest` derives
/// them from the property name, so failures replay exactly).
#[test]
fn policy_fuzz_token_backend() {
    property("policy fuzz (token backend)", 200, fuzz_token_backend_once);
}

#[test]
fn policy_fuzz_sim_backend() {
    property("policy fuzz (sim backend)", 60, fuzz_sim_backend_once);
}

#[test]
fn policy_fuzz_cross_core_differential() {
    property("policy fuzz (event vs reference core)", 60, fuzz_cross_core_once);
}

#[test]
fn policy_fuzz_open_loop_cross_core() {
    property("policy fuzz (open-loop event vs reference)", 40, fuzz_open_loop_cross_core_once);
}

/// Nightly-tier long sweep: same properties, ~10x the iterations.
/// Run with `cargo test --release -- --ignored`.
#[test]
#[ignore = "long randomized sweep; nightly job runs it with --ignored"]
fn policy_fuzz_long_sweep() {
    property("policy fuzz long (token backend)", 2000, fuzz_token_backend_once);
    property("policy fuzz long (sim backend)", 500, fuzz_sim_backend_once);
    property("policy fuzz long (event vs reference core)", 500, fuzz_cross_core_once);
    property("policy fuzz long (open-loop event vs reference)", 300,
             fuzz_open_loop_cross_core_once);
}
