//! End-to-end pipeline integration on the tiny artifact config: SFT warm
//! start, then a few RL updates under every scheduler variant.  Verifies
//! the machinery (engine + buffer + controller + trainer) composes, not
//! training quality (that's examples/train_logic.rs at real scale).

use sortedrl::coordinator::{sft_warm_start, Controller, LoopConfig, SchedulerKind};
use sortedrl::data::Dataset;
use sortedrl::rl::advantage::AdvantageKind;
use sortedrl::runtime::Runtime;
use sortedrl::tasks::logic::LogicTask;
use sortedrl::tasks::math::MathTask;
use sortedrl::tasks::Task;
use std::path::Path;

const TAG: &str = "tiny.B4k8.Bt4T192";

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    Runtime::load(&dir, Some(TAG)).ok().or_else(|| {
        eprintln!("SKIP: tag {TAG} unavailable");
        None
    })
}

fn short_cfg(scheduler: SchedulerKind) -> LoopConfig {
    LoopConfig {
        scheduler,
        rollout_prompts: 4,
        group_size: 2,
        samples_per_prompt: 2,
        update_batch: 4,
        max_updates: 3,
        lr: 5e-4,
        temperature: 1.0,
        seed: 7,
        adv: AdvantageKind::ReinforcePlusPlus,
        max_new: 48,
        eval_every: 0,
        eval_limit: 8,
        verbose: false,
        ..LoopConfig::default()
    }
}

fn run_scheduler(scheduler: SchedulerKind) {
    let Some(rt) = runtime() else { return };
    let task = MathTask;
    let ds = Dataset::generate(&task, 6, 0.2, 1);
    let mut state = rt.init(11).unwrap();
    let mut ctl = Controller::new(&rt, Box::new(MathTask), ds, short_cfg(scheduler));
    let result = ctl.run(&mut state).unwrap();
    assert_eq!(result.rows.len(), 3, "{scheduler:?} must do 3 updates");
    for row in &result.rows {
        assert!(row.update.n_traj > 0);
        assert!(row.update.stats.loss.is_finite());
        assert!(row.update.mean_resp_len > 0.0);
        assert!(row.update.format_rate >= 0.0 && row.update.format_rate <= 1.0);
    }
    assert!(result.total_rollout_tokens > 0);
    assert!(result.bubble_ratio >= 0.0 && result.bubble_ratio <= 1.0,
            "bubble {:?}", result.bubble_ratio);
    // the policy actually moved
    assert!(state.version >= 3);
}

#[test]
fn sorted_on_policy_runs() {
    run_scheduler(SchedulerKind::SortedOnPolicy);
}

#[test]
fn sorted_partial_runs() {
    run_scheduler(SchedulerKind::SortedPartial);
}

#[test]
fn baseline_runs() {
    run_scheduler(SchedulerKind::Baseline);
}

#[test]
fn post_hoc_sort_runs() {
    run_scheduler(SchedulerKind::PostHocSort);
}

#[test]
fn no_grouped_runs() {
    run_scheduler(SchedulerKind::NoGroupedRollout);
}

#[test]
fn sft_warm_start_reduces_loss_on_real_task() {
    let Some(rt) = runtime() else { return };
    let task = LogicTask::default();
    let ds = Dataset::generate(&task, 8, 0.1, 3);
    let mut state = rt.init(5).unwrap();
    let problems: Vec<&sortedrl::tasks::Problem> = ds.train.iter().collect();
    let losses = sft_warm_start(&rt, &mut state, &problems, 12, 3e-3, 0).unwrap();
    assert!(losses.last().unwrap() < &(losses[0] * 0.9),
            "sft {} -> {}", losses[0], losses.last().unwrap());
}

#[test]
fn partial_mode_produces_resumed_trajectories() {
    // With a small update batch and long generations, partial mode must
    // actually exercise the scavenge-resume path (resumes > 0 somewhere).
    let Some(rt) = runtime() else { return };
    let task = LogicTask { max_checks: 16 };
    let ds = Dataset::generate(&task, 6, 0.2, 9);
    let mut state = rt.init(13).unwrap();
    let mut cfg = short_cfg(SchedulerKind::SortedPartial);
    cfg.update_batch = 2; // harvest aggressively -> many interruptions
    cfg.max_updates = 6;
    cfg.max_new = 96;
    let mut ctl = Controller::new(&rt, Box::new(task), ds, cfg);
    let result = ctl.run(&mut state).unwrap();
    assert!(!result.rows.is_empty());
}

#[test]
fn multi_engine_pool_runs_end_to_end() {
    // The sched layer: 2 engines, history predictor, predicted-SJF
    // dispatch, with partial-mode straggler preemption enabled.
    let Some(rt) = runtime() else { return };
    let task = MathTask;
    let ds = Dataset::generate(&task, 6, 0.2, 21);
    let mut state = rt.init(29).unwrap();
    let mut cfg = short_cfg(SchedulerKind::SortedPartial);
    cfg.num_engines = 2;
    cfg.predictor = sortedrl::sched::PredictorKind::History;
    cfg.dispatch = sortedrl::sched::DispatchPolicy::ShortestPredictedFirst;
    let mut ctl = Controller::new(&rt, Box::new(MathTask), ds, cfg);
    let result = ctl.run(&mut state).unwrap();
    assert_eq!(result.rows.len(), 3);
    assert!(result.total_rollout_tokens > 0);
    assert!(result.bubble_ratio >= 0.0 && result.bubble_ratio <= 1.0);
}

#[test]
fn eval_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let task = MathTask;
    let ds = Dataset::generate(&task, 6, 0.3, 17);
    let state = rt.init(23).unwrap();
    let ctl = Controller::new(&rt, Box::new(MathTask), ds, short_cfg(SchedulerKind::Baseline));
    let a = ctl.evaluate(&state).unwrap();
    let b = ctl.evaluate(&state).unwrap();
    assert_eq!(a.score, b.score);
    assert_eq!(a.mean_resp_len, b.mean_resp_len);
    let _ = task;
}
