//! Property + regression tests for the `sched` subsystem's pool semantics,
//! exercised through the discrete-event simulator mirror (`simulate_pool`),
//! which shares the dispatch/preempt/requeue/drain state machine shape with
//! the real `EnginePool` (that one needs PJRT artifacts and is covered by
//! `pipeline_integration.rs`).
//!
//! The conservation property is the issue's contract: across dispatch,
//! preemption, requeue and drain, NO request is lost or duplicated, for
//! every `DispatchPolicy` x `PredictorKind` x `SimMode` x engine count.

use sortedrl::coordinator::SchedulerKind;
use sortedrl::rollout::kv::{KvConfig, KvMode};
use sortedrl::sched::harness::{HarnessDispatch, TokenBackend};
use sortedrl::sched::policy::{
    drive, HarvestAction, PolicyBuilder, PolicyParams, ScheduleBackend,
};
use sortedrl::sched::{make_predictor, DispatchPolicy, LengthPredictor, PredictorKind};
use sortedrl::sim::{
    longtail_workload, pool_makespan, simulate, simulate_pool, CostModel, PoolSimOpts,
    SimMode, SimRun,
};
use sortedrl::util::proptest::{property, Gen};

const MODES: [SimMode; 3] =
    [SimMode::Baseline, SimMode::SortedOnPolicy, SimMode::SortedPartial];

/// No request lost or duplicated: natural finishes + clipped harvests +
/// dropped prompts account for the whole workload exactly once, and token
/// accounting (useful + wasted == generated) stays consistent, under
/// randomized pool geometry and every dispatch policy.
#[test]
fn pool_conserves_requests_for_every_policy() {
    property("pool request conservation", 60, |g: &mut Gen| {
        let n = g.usize_in(16..120);
        let cap = g.usize_in(64..2048);
        let engines = g.usize_in(1..5);
        let q_total = engines * g.usize_in(2..17); // divisible by engines
        let update_batch = g.usize_in(4..40);
        let mode = *g.pick(&MODES);
        let policy = *g.pick(&DispatchPolicy::ALL);
        let predictor = *g.pick(&PredictorKind::ALL);
        let seed = g.usize_in(0..1_000_000) as u64;
        let w = longtail_workload(n, cap, seed);
        let r = simulate_pool(mode, &w, engines, q_total, update_batch,
                              CostModel::default(), policy, predictor);
        let ctx = format!(
            "n={n} cap={cap} engines={engines} q={q_total} u={update_batch} \
             {mode:?} {} {}",
            policy.name(),
            predictor.name()
        );
        assert_eq!(
            r.timeline.finished() as usize + r.clipped + r.dropped,
            n,
            "request conservation violated: {ctx}"
        );
        assert!(r.useful_tokens + r.wasted_tokens == r.timeline.tokens_out(),
                "token conservation violated: {ctx}");
        assert!(r.useful_tokens > 0, "{ctx}");
        assert!((0.0..=1.0).contains(&r.bubble_ratio), "{ctx}");
        assert!(r.throughput.is_finite() && r.rollout_time > 0.0, "{ctx}");
        if mode == SimMode::SortedPartial {
            assert_eq!(r.wasted_tokens, 0, "partial mode discards nothing: {ctx}");
        }
        if mode == SimMode::Baseline {
            assert_eq!(r.clipped, 0, "{ctx}");
            assert_eq!(r.dropped, 0, "{ctx}");
            assert_eq!(r.useful_tokens,
                       w.iter().map(|x| x.output_len as u64).sum::<u64>(),
                       "{ctx}");
        }
    });
}

/// Same conservation contract for run-to-completion makespan runs: the
/// makespan is finite/positive and never shorter than the serial decode
/// time of the longest request.
#[test]
fn pool_makespan_bounded_below_by_longest_request() {
    property("pool makespan lower bound", 40, |g: &mut Gen| {
        let n = g.usize_in(16..100);
        let cap = g.usize_in(64..1024);
        let engines = g.usize_in(1..5);
        let q_total = engines * g.usize_in(2..13);
        let policy = *g.pick(&DispatchPolicy::ALL);
        let predictor = *g.pick(&PredictorKind::ALL);
        let w = longtail_workload(n, cap, g.usize_in(0..1_000_000) as u64);
        let cost = CostModel::default();
        let m = pool_makespan(&w, engines, q_total, cost, policy, predictor);
        // the longest request needs one decode iteration per output token,
        // each costing at least t_weights + 1 * t_token on its engine
        let longest = w.iter().map(|r| r.output_len).max().unwrap() as f64;
        assert!(m.is_finite() && m > 0.0);
        assert!(m >= longest * (cost.t_weights + cost.t_token),
                "makespan {m} below serial decode floor ({longest} tokens)");
    });
}

/// Predictors never panic and keep ordering-compatible outputs under
/// random observe/predict interleavings (the pool calls them from every
/// dispatch and preemption site).
#[test]
fn predictors_total_under_random_churn() {
    property("predictor churn", 100, |g: &mut Gen| {
        let kind = *g.pick(&PredictorKind::ALL);
        let mut p = make_predictor(kind);
        for _ in 0..g.usize_in(1..200) {
            let key = g.usize_in(0..32) as u64;
            let plen = g.usize_in(1..512);
            match g.usize_in(0..3) {
                0 => p.observe(key, plen, g.usize_in(1..4096)),
                1 => p.observe_progress(key, plen, g.usize_in(0..4096)),
                _ => {
                    let v = p.predict(key, plen);
                    assert!(v.is_finite(), "{} produced {v}", p.name());
                }
            }
        }
    });
}

/// Deterministic-seed regression pinning the bubble-ratio ordering on the
/// paper's Fig. 5 operating point:
///
///     multi-engine SortedPartial <= single-engine SortedPartial <= Baseline
///
/// Multi-engine SJF packs similar predicted lengths per engine, so each
/// engine's lanes drain together AND per-engine prefill stalls shrink;
/// sharding must not cost occupancy.
#[test]
fn bubble_ordering_multi_le_single_le_baseline() {
    let w = longtail_workload(512, 8192, 1);
    let cost = CostModel::default();
    let base = simulate(SimMode::Baseline, &w, 128, 128, cost);
    let single = simulate_pool(SimMode::SortedPartial, &w, 1, 128, 128, cost,
                               DispatchPolicy::ShortestPredictedFirst,
                               PredictorKind::Oracle);
    let multi = simulate_pool(SimMode::SortedPartial, &w, 4, 128, 128, cost,
                              DispatchPolicy::ShortestPredictedFirst,
                              PredictorKind::Oracle);
    assert!(single.bubble_ratio <= base.bubble_ratio,
            "single partial {} > baseline {}",
            single.bubble_ratio, base.bubble_ratio);
    // small relative tolerance: at sub-percent bubbles the harvest-barrier
    // alignment skew is the same order as the packing win; a real sharding
    // regression shows up as a multiple, not a few tens of percent
    assert!(multi.bubble_ratio <= single.bubble_ratio * 1.25,
            "multi partial {} > single partial {}",
            multi.bubble_ratio, single.bubble_ratio);
    // and the gap to baseline is structural, not noise (paper: 74% -> ~3%)
    assert!(single.bubble_ratio < base.bubble_ratio / 2.0,
            "single partial {} not < half of baseline {}",
            single.bubble_ratio, base.bubble_ratio);
    assert!(multi.bubble_ratio < base.bubble_ratio / 2.0);
    // sharding buys wall-clock: parallel weight streaming
    assert!(multi.rollout_time < single.rollout_time);
}

// --------------------------------------------------------------------------
// per-verdict HarvestAction pins (deterministic TokenBackend)
// --------------------------------------------------------------------------

/// One engine, one lane, two requests: run rid 0 for two ticks, then
/// harvest — rid 0 arrives as a progress-2 partial, rid 1 as untouched
/// queued work.  Each test below applies ONE verdict and pins its exact
/// state transition.
fn harvested_pair() -> (TokenBackend, Vec<sortedrl::sched::policy::HarvestItem>) {
    let mut b = TokenBackend::new(&[5, 5], 1, 1, HarnessDispatch::Central, usize::MAX);
    b.load_prompts(2).unwrap();
    b.admit(&[0, 1], None).unwrap();
    b.step().unwrap();
    b.step().unwrap();
    let items = b.harvest_candidates().unwrap();
    assert_eq!(items.len(), 2);
    assert_eq!((items[0].rid, items[0].progress, items[0].queued), (0, 2, false));
    assert_eq!((items[1].rid, items[1].progress, items[1].queued), (1, 0, true));
    (b, items)
}

#[test]
fn verdict_clip_truncates_and_readies() {
    let (mut b, items) = harvested_pair();
    b.resolve(&items[0], HarvestAction::Clip).unwrap();
    assert_eq!(b.ready_rids(), vec![0]);
    assert_eq!(b.ready_len(0), 2, "clip keeps the partial length");
    assert_eq!(b.clipped, vec![0]);
    b.resolve(&items[1], HarvestAction::Requeue).unwrap();
    b.train(&[0]).unwrap();
    assert_eq!(b.consumed, vec![0]);
}

#[test]
fn verdict_restart_discards_progress() {
    let (mut b, items) = harvested_pair();
    b.resolve(&items[0], HarvestAction::Restart).unwrap();
    b.resolve(&items[1], HarvestAction::Requeue).unwrap();
    assert_eq!(b.ready_len(0), 0, "restart zeroes the partial");
    assert_eq!(b.schedulable(), vec![0, 1], "both back in the schedulable set");
    // rerun from scratch: rid 0 needs its full 5 ticks again
    b.admit(&[0], None).unwrap();
    for _ in 0..5 {
        b.step().unwrap();
    }
    assert_eq!(b.ready_rids(), vec![0]);
    assert_eq!(b.ready_len(0), 5);
}

#[test]
fn verdict_resume_preserves_progress() {
    let (mut b, items) = harvested_pair();
    b.resolve(&items[0], HarvestAction::Resume).unwrap();
    b.resolve(&items[1], HarvestAction::Requeue).unwrap();
    assert_eq!(b.ready_len(0), 2, "resume keeps the partial tokens");
    // only the remaining 3 tokens are decoded on re-admission
    b.admit(&[0], None).unwrap();
    for _ in 0..3 {
        b.step().unwrap();
    }
    assert_eq!(b.ready_rids(), vec![0]);
}

#[test]
fn verdict_requeue_leaves_untouched() {
    let (mut b, items) = harvested_pair();
    b.resolve(&items[0], HarvestAction::Requeue).unwrap();
    b.resolve(&items[1], HarvestAction::Requeue).unwrap();
    assert_eq!(b.schedulable(), vec![0, 1]);
    assert_eq!(b.ready_len(0), 2, "requeue does not erase progress");
    assert_eq!(b.ready_len(1), 0);
    assert!(b.clipped.is_empty() && b.dropped.is_empty() && b.consumed.is_empty());
}

#[test]
fn verdict_drop_consumes_untrained() {
    let (mut b, items) = harvested_pair();
    b.resolve(&items[0], HarvestAction::Drop).unwrap();
    b.resolve(&items[1], HarvestAction::Drop).unwrap();
    assert_eq!(b.dropped, vec![0, 1]);
    assert!(b.consumed.is_empty(), "drop never reaches the trainer");
    assert!(b.schedulable().is_empty() && b.ready_rids().is_empty());
}

/// Requeue of a STOLEN lane preserves its partial tokens: the migration
/// carries progress to the thief, and a later harvest + Requeue hands the
/// same partial back to the schedulable set intact.
#[test]
fn verdict_requeue_after_steal_preserves_partial() {
    let mut b = TokenBackend::new(&[6, 6], 2, 1, HarnessDispatch::Striped, usize::MAX);
    b.load_prompts(2).unwrap();
    b.admit(&[0], Some(0)).unwrap();
    b.admit(&[1], Some(1)).unwrap();
    for _ in 0..3 {
        b.step().unwrap();
    }
    // steal engine 0's running lane (rid 0, progress 3) onto engine 1
    assert!(b.steal(0, 1, Some(0)).unwrap());
    assert_eq!(b.steal_log, vec![(0, 1, 0, 3)]);
    assert_eq!(b.migrated_tokens, 3);
    let items = b.harvest_candidates().unwrap();
    // rid 0 sits in engine 1's queue WITH progress: a partial, not
    // untouched queued work
    let it0 = items.iter().find(|i| i.rid == 0).unwrap();
    assert_eq!((it0.progress, it0.queued), (3, false));
    for it in &items {
        b.resolve(it, HarvestAction::Requeue).unwrap();
    }
    assert_eq!(b.ready_len(0), 3, "stolen partial survives requeue");
    assert_eq!(b.schedulable(), vec![0, 1]);
}

// --------------------------------------------------------------------------
// work-stealing regression (the issue's acceptance criterion)
// --------------------------------------------------------------------------

/// Skewed workload, 4 engines, static round-robin striping: with stealing
/// enabled the bubble ratio strictly improves over the identical policy
/// without stealing, request conservation holds in both runs, and the
/// per-engine idle breakdown shows the imbalance stealing removed.
#[test]
fn stealing_strictly_improves_skewed_bubble() {
    let w = longtail_workload(256, 8192, 1);
    let opts = PoolSimOpts {
        engines: 4,
        q_total: 64,
        update_batch: 64,
        dispatch: DispatchPolicy::RoundRobin,
        predictor: PredictorKind::History,
        steal: false,
        ..PoolSimOpts::default()
    };
    let flat = SimRun::new(SimMode::Baseline, opts).workload(&w).run();
    let stealing = SimRun::new(SimMode::Baseline, PoolSimOpts { steal: true, ..opts })
        .workload(&w)
        .run();
    assert_eq!(flat.steals, 0);
    assert!(stealing.steals > 0, "no steals fired on a skewed workload");
    assert!(stealing.bubble_ratio < flat.bubble_ratio,
            "stealing bubble {} !< baseline bubble {}",
            stealing.bubble_ratio, flat.bubble_ratio);
    // migrating a lane never extends the critical path (the thief decodes
    // it at least as fast as the loaded victim would have)
    assert!(stealing.rollout_time <= flat.rollout_time * 1.0001,
            "stealing makespan {} > no-steal {}",
            stealing.rollout_time, flat.rollout_time);
    for r in [&flat, &stealing] {
        assert_eq!(r.timeline.finished() as usize + r.clipped + r.dropped, 256);
        assert_eq!(r.engine_idle.len(), 4);
        assert!(r.engine_idle.iter().all(|&b| (0.0..=1.0).contains(&b)));
    }
    // same regression under partial-mode semantics: stolen partials keep
    // their tokens, and occupancy must not get worse
    let part_flat = SimRun::new(SimMode::SortedPartial, opts).workload(&w).run();
    let part_steal = SimRun::new(SimMode::SortedPartial, PoolSimOpts { steal: true, ..opts })
        .workload(&w)
        .run();
    assert_eq!(part_steal.wasted_tokens, 0, "partial mode discards nothing");
    assert!(part_steal.bubble_ratio <= part_flat.bubble_ratio * 1.02,
            "partial stealing bubble {} regressed vs {}",
            part_steal.bubble_ratio, part_flat.bubble_ratio);
}

// --------------------------------------------------------------------------
// paged KV accounting (the issue's acceptance criterion + backpressure pins)
// --------------------------------------------------------------------------

/// The paged-KV acceptance regression: on the skewed 4-engine workload at
/// the same per-engine budget, paged accounting admits strictly more
/// concurrent lanes than reserve-the-cap and achieves a strictly lower
/// bubble ratio (and faster rollout), while conserving every request.
/// Reserve mode never needs backpressure; paged backpressure (forced
/// sheds + governor throttles) is what keeps its budget hard despite
/// admission over-commit.
#[test]
fn paged_kv_admits_more_lanes_and_cuts_bubble_at_fixed_budget() {
    let w = longtail_workload(256, 8192, 1);
    // one worst-case lane reserves ~prompt(64..256)+cap(8192) ≈ 8.4k
    // tokens, so a 40k budget caps reserve mode at 4 of each engine's 16
    // lanes; most ACTUAL contexts stay ~1k, which paged mode recovers
    let opts = PoolSimOpts {
        engines: 4,
        q_total: 64,
        update_batch: 64,
        dispatch: DispatchPolicy::ShortestPredictedFirst,
        predictor: PredictorKind::History,
        kv_budget: 40_000,
        kv_page: 256,
        ..PoolSimOpts::default()
    };
    let reserved =
        SimRun::new(SimMode::SortedPartial, PoolSimOpts { kv_mode: KvMode::Reserve, ..opts })
            .workload(&w)
            .run();
    let paged =
        SimRun::new(SimMode::SortedPartial, PoolSimOpts { kv_mode: KvMode::Paged, ..opts })
            .workload(&w)
            .run();
    for (r, tag) in [(&reserved, "reserved"), (&paged, "paged")] {
        assert_eq!(r.timeline.finished() as usize + r.clipped + r.dropped, 256,
                   "{tag}: request conservation");
        assert_eq!(r.wasted_tokens, 0, "{tag}: partial mode discards nothing");
    }
    // reserve-the-cap concurrency is pinned by arithmetic: floor(40k/8.3k)
    // = 4 lanes per engine, 16 pool-wide
    assert!(reserved.peak_lanes <= 16,
            "reserved admitted {} lanes past its arithmetic cap", reserved.peak_lanes);
    assert!(paged.peak_lanes > reserved.peak_lanes,
            "paged peak {} !> reserved peak {}", paged.peak_lanes, reserved.peak_lanes);
    assert!(paged.bubble_ratio < reserved.bubble_ratio,
            "paged bubble {} !< reserved bubble {}",
            paged.bubble_ratio, reserved.bubble_ratio);
    assert!(paged.rollout_time < reserved.rollout_time,
            "paged rollout {} !< reserved {}",
            paged.rollout_time, reserved.rollout_time);
    assert_eq!(reserved.kv_sheds, 0, "reserve mode cannot over-commit");
    assert_eq!(reserved.throttles, 0, "governor must be inert in reserve mode");
}

/// Deterministic forced-shed pin (no governor): 1 engine x 4 lanes,
/// central queue, lens [8,8,8,8], paged budget 24 / page 1.  Admission
/// estimates (12 each) admit a third lane at t2 that reserve mode never
/// admits; actual charges outgrow the budget at t5 and the engine sheds
/// the smallest-context lane — the harness asserts "usage <= budget" and
/// ledger release-exactly-once after every transition, so completing at
/// all proves the invariants.
#[test]
fn paged_forced_shed_keeps_budget_hard() {
    let params = PolicyParams { refill_prompts: 4, entries_per_prompt: 1, update_batch: 4 };
    let run = |mode: KvMode| {
        let kv = KvConfig { mode, budget: 24, page: 1 };
        // bare builder (no governor): the forced in-step path must hold
        // the budget entirely on its own
        let mut policy = PolicyBuilder::new(SchedulerKind::Baseline, params).build();
        let mut b = TokenBackend::new_kv(&[8, 8, 8, 8], 1, 4,
                                         HarnessDispatch::Central, kv);
        drive(policy.as_mut(), &mut b).unwrap();
        b
    };
    let paged = run(KvMode::Paged);
    assert_eq!(paged.peak_running, 3, "estimate admission packs a third lane");
    assert_eq!(paged.kv_sheds, 1, "growth past the budget sheds exactly once");
    assert_eq!(paged.throttled, 0, "no governor in this composition");
    assert_eq!(paged.consumed.len(), 4);
    assert_eq!(paged.ticks, 16);
    let reserved = run(KvMode::Reserve);
    assert_eq!(reserved.peak_running, 2, "reserve caps at floor(24/12) lanes");
    assert_eq!(reserved.kv_sheds, 0);
    assert_eq!(reserved.ticks, 16);
    assert_eq!(reserved.consumed, paged.consumed, "same data either way");
}

/// Same scenario WITH the KvGovernor (the production paged composition):
/// pressure is detected from the PoolLoad snapshot one tick before the
/// forced path would fire, a Throttle sheds proactively, and the forced
/// path then never triggers.
#[test]
fn paged_governor_throttles_before_forced_shed() {
    let params = PolicyParams { refill_prompts: 4, entries_per_prompt: 1, update_batch: 4 };
    let kv = KvConfig { mode: KvMode::Paged, budget: 24, page: 1 };
    let mut policy = PolicyBuilder::new(SchedulerKind::Baseline, params).kv(kv).build();
    let mut b = TokenBackend::new_kv(&[8, 8, 8, 8], 1, 4, HarnessDispatch::Central, kv);
    drive(policy.as_mut(), &mut b).unwrap();
    assert_eq!(b.throttled, 1, "governor sheds once at the pressure point");
    assert_eq!(b.kv_sheds, 0, "proactive throttle preempts the forced path");
    assert_eq!(b.peak_running, 3);
    assert_eq!(b.consumed.len(), 4);
    assert_eq!(b.ticks, 16);
}

/// Predicted-SJF dispatch beats static round-robin on makespan for the
/// long-tail workload (deterministic seed — the sched_bench headline).
#[test]
fn sjf_dispatch_beats_round_robin_makespan() {
    let w = longtail_workload(512, 8192, 1);
    let cost = CostModel::default();
    let rr = pool_makespan(&w, 4, 128, cost, DispatchPolicy::RoundRobin,
                           PredictorKind::History);
    let ll = pool_makespan(&w, 4, 128, cost, DispatchPolicy::LeastLoaded,
                           PredictorKind::History);
    let sjf_oracle = pool_makespan(&w, 4, 128, cost,
                                   DispatchPolicy::ShortestPredictedFirst,
                                   PredictorKind::Oracle);
    let sjf_history = pool_makespan(&w, 4, 128, cost,
                                    DispatchPolicy::ShortestPredictedFirst,
                                    PredictorKind::History);
    assert!(sjf_oracle < rr, "sjf(oracle) {sjf_oracle} !< round-robin {rr}");
    // the acceptance claim is about PREDICTED sjf, not just the oracle
    // ceiling: late-binding pull alone must already beat static striping
    assert!(sjf_history < rr, "sjf(history) {sjf_history} !< round-robin {rr}");
    assert!(ll.is_finite() && ll > 0.0);
}
