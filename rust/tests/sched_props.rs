//! Property + regression tests for the `sched` subsystem's pool semantics,
//! exercised through the discrete-event simulator mirror (`simulate_pool`),
//! which shares the dispatch/preempt/requeue/drain state machine shape with
//! the real `EnginePool` (that one needs PJRT artifacts and is covered by
//! `pipeline_integration.rs`).
//!
//! The conservation property is the issue's contract: across dispatch,
//! preemption, requeue and drain, NO request is lost or duplicated, for
//! every `DispatchPolicy` x `PredictorKind` x `SimMode` x engine count.

use sortedrl::sched::{make_predictor, DispatchPolicy, LengthPredictor, PredictorKind};
use sortedrl::sim::{
    longtail_workload, pool_makespan, simulate, simulate_pool, CostModel, SimMode,
};
use sortedrl::util::proptest::{property, Gen};

const MODES: [SimMode; 3] =
    [SimMode::Baseline, SimMode::SortedOnPolicy, SimMode::SortedPartial];

/// No request lost or duplicated: natural finishes + clipped harvests +
/// dropped prompts account for the whole workload exactly once, and token
/// accounting (useful + wasted == generated) stays consistent, under
/// randomized pool geometry and every dispatch policy.
#[test]
fn pool_conserves_requests_for_every_policy() {
    property("pool request conservation", 60, |g: &mut Gen| {
        let n = g.usize_in(16..120);
        let cap = g.usize_in(64..2048);
        let engines = g.usize_in(1..5);
        let q_total = engines * g.usize_in(2..17); // divisible by engines
        let update_batch = g.usize_in(4..40);
        let mode = *g.pick(&MODES);
        let policy = *g.pick(&DispatchPolicy::ALL);
        let predictor = *g.pick(&PredictorKind::ALL);
        let seed = g.usize_in(0..1_000_000) as u64;
        let w = longtail_workload(n, cap, seed);
        let r = simulate_pool(mode, &w, engines, q_total, update_batch,
                              CostModel::default(), policy, predictor);
        let ctx = format!(
            "n={n} cap={cap} engines={engines} q={q_total} u={update_batch} \
             {mode:?} {} {}",
            policy.name(),
            predictor.name()
        );
        assert_eq!(
            r.timeline.finished() as usize + r.clipped + r.dropped,
            n,
            "request conservation violated: {ctx}"
        );
        assert!(r.useful_tokens + r.wasted_tokens == r.timeline.tokens_out(),
                "token conservation violated: {ctx}");
        assert!(r.useful_tokens > 0, "{ctx}");
        assert!((0.0..=1.0).contains(&r.bubble_ratio), "{ctx}");
        assert!(r.throughput.is_finite() && r.rollout_time > 0.0, "{ctx}");
        if mode == SimMode::SortedPartial {
            assert_eq!(r.wasted_tokens, 0, "partial mode discards nothing: {ctx}");
        }
        if mode == SimMode::Baseline {
            assert_eq!(r.clipped, 0, "{ctx}");
            assert_eq!(r.dropped, 0, "{ctx}");
            assert_eq!(r.useful_tokens,
                       w.iter().map(|x| x.output_len as u64).sum::<u64>(),
                       "{ctx}");
        }
    });
}

/// Same conservation contract for run-to-completion makespan runs: the
/// makespan is finite/positive and never shorter than the serial decode
/// time of the longest request.
#[test]
fn pool_makespan_bounded_below_by_longest_request() {
    property("pool makespan lower bound", 40, |g: &mut Gen| {
        let n = g.usize_in(16..100);
        let cap = g.usize_in(64..1024);
        let engines = g.usize_in(1..5);
        let q_total = engines * g.usize_in(2..13);
        let policy = *g.pick(&DispatchPolicy::ALL);
        let predictor = *g.pick(&PredictorKind::ALL);
        let w = longtail_workload(n, cap, g.usize_in(0..1_000_000) as u64);
        let cost = CostModel::default();
        let m = pool_makespan(&w, engines, q_total, cost, policy, predictor);
        // the longest request needs one decode iteration per output token,
        // each costing at least t_weights + 1 * t_token on its engine
        let longest = w.iter().map(|r| r.output_len).max().unwrap() as f64;
        assert!(m.is_finite() && m > 0.0);
        assert!(m >= longest * (cost.t_weights + cost.t_token),
                "makespan {m} below serial decode floor ({longest} tokens)");
    });
}

/// Predictors never panic and keep ordering-compatible outputs under
/// random observe/predict interleavings (the pool calls them from every
/// dispatch and preemption site).
#[test]
fn predictors_total_under_random_churn() {
    property("predictor churn", 100, |g: &mut Gen| {
        let kind = *g.pick(&PredictorKind::ALL);
        let mut p = make_predictor(kind);
        for _ in 0..g.usize_in(1..200) {
            let key = g.usize_in(0..32) as u64;
            let plen = g.usize_in(1..512);
            match g.usize_in(0..3) {
                0 => p.observe(key, plen, g.usize_in(1..4096)),
                1 => p.observe_progress(key, plen, g.usize_in(0..4096)),
                _ => {
                    let v = p.predict(key, plen);
                    assert!(v.is_finite(), "{} produced {v}", p.name());
                }
            }
        }
    });
}

/// Deterministic-seed regression pinning the bubble-ratio ordering on the
/// paper's Fig. 5 operating point:
///
///     multi-engine SortedPartial <= single-engine SortedPartial <= Baseline
///
/// Multi-engine SJF packs similar predicted lengths per engine, so each
/// engine's lanes drain together AND per-engine prefill stalls shrink;
/// sharding must not cost occupancy.
#[test]
fn bubble_ordering_multi_le_single_le_baseline() {
    let w = longtail_workload(512, 8192, 1);
    let cost = CostModel::default();
    let base = simulate(SimMode::Baseline, &w, 128, 128, cost);
    let single = simulate_pool(SimMode::SortedPartial, &w, 1, 128, 128, cost,
                               DispatchPolicy::ShortestPredictedFirst,
                               PredictorKind::Oracle);
    let multi = simulate_pool(SimMode::SortedPartial, &w, 4, 128, 128, cost,
                              DispatchPolicy::ShortestPredictedFirst,
                              PredictorKind::Oracle);
    assert!(single.bubble_ratio <= base.bubble_ratio,
            "single partial {} > baseline {}",
            single.bubble_ratio, base.bubble_ratio);
    // small relative tolerance: at sub-percent bubbles the harvest-barrier
    // alignment skew is the same order as the packing win; a real sharding
    // regression shows up as a multiple, not a few tens of percent
    assert!(multi.bubble_ratio <= single.bubble_ratio * 1.25,
            "multi partial {} > single partial {}",
            multi.bubble_ratio, single.bubble_ratio);
    // and the gap to baseline is structural, not noise (paper: 74% -> ~3%)
    assert!(single.bubble_ratio < base.bubble_ratio / 2.0,
            "single partial {} not < half of baseline {}",
            single.bubble_ratio, base.bubble_ratio);
    assert!(multi.bubble_ratio < base.bubble_ratio / 2.0);
    // sharding buys wall-clock: parallel weight streaming
    assert!(multi.rollout_time < single.rollout_time);
}

/// Predicted-SJF dispatch beats static round-robin on makespan for the
/// long-tail workload (deterministic seed — the sched_bench headline).
#[test]
fn sjf_dispatch_beats_round_robin_makespan() {
    let w = longtail_workload(512, 8192, 1);
    let cost = CostModel::default();
    let rr = pool_makespan(&w, 4, 128, cost, DispatchPolicy::RoundRobin,
                           PredictorKind::History);
    let ll = pool_makespan(&w, 4, 128, cost, DispatchPolicy::LeastLoaded,
                           PredictorKind::History);
    let sjf_oracle = pool_makespan(&w, 4, 128, cost,
                                   DispatchPolicy::ShortestPredictedFirst,
                                   PredictorKind::Oracle);
    let sjf_history = pool_makespan(&w, 4, 128, cost,
                                    DispatchPolicy::ShortestPredictedFirst,
                                    PredictorKind::History);
    assert!(sjf_oracle < rr, "sjf(oracle) {sjf_oracle} !< round-robin {rr}");
    // the acceptance claim is about PREDICTED sjf, not just the oracle
    // ceiling: late-binding pull alone must already beat static striping
    assert!(sjf_history < rr, "sjf(history) {sjf_history} !< round-robin {rr}");
    assert!(ll.is_finite() && ll > 0.0);
}
