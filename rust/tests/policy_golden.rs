//! Golden-equivalence tests for the `SchedulePolicy` port.
//!
//! The pinned sequences below were derived BY HAND from the legacy
//! controller loops (`run_group` / `run_baseline` / `run_no_grouped`, now
//! deleted) on a deterministic mini-engine: one token per tick per lane,
//! FIFO lane admission, known output lengths.  Each pre-existing
//! `SchedulerKind` must reproduce the legacy update counts and consumed-rid
//! sequences through the unified driver, with
//! `RolloutBuffer::check_invariants` holding after EVERY driver transition
//! (every backend method asserts it).
//!
//! The backend here is the live backend's structural twin: a real
//! `RolloutBuffer` carries the entry lifecycles, and `resolve` applies the
//! same verdict->buffer mapping `coordinator::controller::LiveBackend`
//! uses, so lifecycle/log-prob bookkeeping is exercised for real — only
//! the PJRT engine is replaced by the deterministic mini-engine.

use anyhow::Result;
use sortedrl::coordinator::{Lifecycle, Mode, RolloutBuffer, SchedulerKind};
use sortedrl::rollout::kv::{KvConfig, KvMode};
use sortedrl::rollout::{Request, Rollout};
use sortedrl::sched::harness::{HarnessDispatch, TokenBackend};
use sortedrl::sched::policy::{
    drive, HarvestAction, HarvestItem, PolicyBuilder, PolicyParams, SchedView,
    ScheduleBackend,
};
use sortedrl::sched::{DispatchPolicy, PredictorKind};
use sortedrl::sim::{
    longtail_workload, simulate, simulate_pool, CostModel, PoolSimOpts, SimMode, SimRun,
};
use std::collections::{BTreeMap, VecDeque};

fn assemble(req: &Request, toks: &[i32], lps: &[f32], complete: bool, at: f64) -> Rollout {
    let mut response = req.resumed.clone();
    response.extend_from_slice(toks);
    let mut logp = req.resumed_logp.clone();
    logp.extend_from_slice(lps);
    Rollout {
        request: req.clone(),
        response,
        logp,
        finish_version: 1,
        complete,
        finished_at: at,
    }
}

struct InFlight {
    req: Request,
    toks: Vec<i32>,
    lps: Vec<f32>,
}

/// Deterministic live-backend twin: real RolloutBuffer, mini-engine with
/// `lanes` lanes emitting one token per tick, FIFO admission.
struct BufferBackend {
    buffer: RolloutBuffer,
    /// rid -> target response length.
    lens: BTreeMap<u64, usize>,
    /// Lengths for prompts not yet loaded (grouped loading pops these).
    plan: VecDeque<usize>,
    lanes: usize,
    running: Vec<u64>,
    queue: VecDeque<u64>,
    inflight: BTreeMap<u64, InFlight>,
    stash: BTreeMap<u64, Rollout>,
    clock: f64,
    updates: usize,
    max_updates: usize,
    harvest_calls: usize,
    consumed_order: Vec<u64>,
    clipped: Vec<u64>,
    dropped: u64,
}

impl BufferBackend {
    fn new(lens: &[usize], lanes: usize, max_updates: usize) -> Self {
        BufferBackend {
            buffer: RolloutBuffer::new(),
            lens: BTreeMap::new(),
            plan: lens.iter().copied().collect(),
            lanes,
            running: Vec::new(),
            queue: VecDeque::new(),
            inflight: BTreeMap::new(),
            stash: BTreeMap::new(),
            clock: 0.0,
            updates: 0,
            max_updates,
            harvest_calls: 0,
            consumed_order: Vec::new(),
            clipped: Vec::new(),
            dropped: 0,
        }
    }

    /// The golden contract: buffer invariants hold after EVERY transition.
    fn check(&self) {
        self.buffer.check_invariants().unwrap();
    }
}

impl ScheduleBackend for BufferBackend {
    fn view(&self) -> SchedView {
        SchedView {
            running: self.running.len(),
            queued: self.queue.len(),
            ready: self.buffer.count(Lifecycle::Ready),
            fresh: self.buffer.count(Lifecycle::Fresh),
            unconsumed: self.buffer.len() - self.buffer.count(Lifecycle::Consumed),
            lanes: self.lanes,
            updates: self.updates,
        }
    }

    fn schedulable(&self) -> Vec<u64> {
        self.buffer.schedulable()
    }

    fn ready_rids(&self) -> Vec<u64> {
        self.buffer.ready_rids()
    }

    fn ready_len(&self, rid: u64) -> usize {
        self.buffer.get(rid).map(|e| e.partial.len()).unwrap_or(0)
    }

    fn load_prompts(&mut self, prompts: usize) -> Result<usize> {
        let mut count = 0;
        for _ in 0..prompts {
            let Some(len) = self.plan.pop_front() else { break };
            let rid = self.buffer.load_prompt(count, 1000 + count as u64, vec![1, 2], 64);
            self.lens.insert(rid, len);
            count += 1;
        }
        self.check();
        Ok(count)
    }

    fn admit(&mut self, rids: &[u64], _engine: Option<usize>) -> Result<()> {
        for req in self.buffer.dispatch(rids) {
            self.queue.push_back(req.rid);
            self.inflight
                .insert(req.rid, InFlight { req, toks: Vec::new(), lps: Vec::new() });
        }
        self.check();
        Ok(())
    }

    fn step(&mut self) -> Result<usize> {
        self.clock += 1.0;
        while self.running.len() < self.lanes {
            let Some(rid) = self.queue.pop_front() else { break };
            self.running.push(rid);
        }
        let mut finished = 0;
        let mut still = Vec::new();
        for rid in std::mem::take(&mut self.running) {
            let fl = self.inflight.get_mut(&rid).unwrap();
            fl.toks.push(7);
            fl.lps.push(-0.5);
            let total = fl.req.resumed.len() + fl.toks.len();
            if total >= self.lens[&rid] {
                let fl = self.inflight.remove(&rid).unwrap();
                let r = assemble(&fl.req, &fl.toks, &fl.lps, true, self.clock);
                self.buffer.record_finished(&r);
                finished += 1;
            } else {
                still.push(rid);
            }
        }
        self.running = still;
        self.check();
        Ok(finished)
    }

    fn harvest_candidates(&mut self) -> Result<Vec<HarvestItem>> {
        self.harvest_calls += 1;
        let mut partials: Vec<Rollout> = Vec::new();
        let mut fresh_queued: Vec<u64> = Vec::new();
        for rid in std::mem::take(&mut self.running) {
            let fl = self.inflight.remove(&rid).unwrap();
            partials.push(assemble(&fl.req, &fl.toks, &fl.lps, false, self.clock));
        }
        for rid in std::mem::take(&mut self.queue) {
            let fl = self.inflight.remove(&rid).unwrap();
            if fl.req.resumed.is_empty() && fl.toks.is_empty() {
                fresh_queued.push(rid);
            } else {
                partials.push(assemble(&fl.req, &fl.toks, &fl.lps, false, self.clock));
            }
        }
        partials.sort_by(|a, b| {
            b.response
                .len()
                .cmp(&a.response.len())
                .then(a.request.rid.cmp(&b.request.rid))
        });
        self.stash.clear();
        let mut items = Vec::with_capacity(partials.len() + fresh_queued.len());
        for r in partials {
            items.push(HarvestItem {
                rid: r.request.rid,
                progress: r.response.len(),
                queued: false,
            });
            self.stash.insert(r.request.rid, r);
        }
        for rid in fresh_queued {
            items.push(HarvestItem { rid, progress: 0, queued: true });
        }
        self.check();
        Ok(items)
    }

    fn resolve(&mut self, item: &HarvestItem, action: HarvestAction) -> Result<()> {
        // the same verdict->buffer mapping LiveBackend applies
        match (self.stash.remove(&item.rid), action) {
            (Some(r), HarvestAction::Clip) => {
                self.buffer.record_clipped(&r);
                self.clipped.push(item.rid);
            }
            (Some(r), HarvestAction::Restart) => {
                self.buffer.record_terminated(&r, Mode::OnPolicy);
            }
            (Some(r), HarvestAction::Resume | HarvestAction::Requeue) => {
                self.buffer.record_terminated(&r, Mode::Partial);
            }
            (Some(r), HarvestAction::Drop) => {
                self.buffer.record_terminated(&r, Mode::OnPolicy);
                self.dropped += self.buffer.consume_untrained(&[r.request.rid]) as u64;
            }
            (None, HarvestAction::Drop) => {
                self.buffer.record_requeued(item.rid);
                self.dropped += self.buffer.consume_untrained(&[item.rid]) as u64;
            }
            (None, _) => self.buffer.record_requeued(item.rid),
        }
        self.check();
        Ok(())
    }

    fn preempt(&mut self, _engine: usize, lane: usize) -> Result<()> {
        if lane < self.running.len() {
            let rid = self.running.remove(lane);
            self.queue.push_back(rid);
        }
        Ok(())
    }

    fn train(&mut self, rids: &[u64]) -> Result<()> {
        let entries = self.buffer.consume(rids);
        for e in &entries {
            assert_eq!(e.partial.len(), e.partial_logp.len());
            assert!(e.complete || e.clipped);
        }
        self.consumed_order.extend_from_slice(rids);
        self.updates += 1;
        self.check();
        Ok(())
    }

    fn barrier(&mut self) -> Result<()> {
        self.buffer.clear_consumed();
        self.check();
        Ok(())
    }

    fn exhausted(&self) -> bool {
        self.updates >= self.max_updates
    }
}

/// Shared scenario: 6 prompts with lengths [2,4,6,3,9,1], 2 lanes, update
/// batch 2, one group of all 6.
const LENS: [usize; 6] = [2, 4, 6, 3, 9, 1];

fn run_kind(kind: SchedulerKind) -> BufferBackend {
    let params = PolicyParams {
        refill_prompts: LENS.len(),
        entries_per_prompt: 1,
        update_batch: 2,
    };
    let mut policy = PolicyBuilder::new(kind, params).build();
    let mut b = BufferBackend::new(&LENS, 2, 100);
    drive(policy.as_mut(), &mut b).unwrap();
    b
}

#[test]
fn golden_sorted_on_policy() {
    let b = run_kind(SchedulerKind::SortedOnPolicy);
    // legacy run_group(OnPolicy): wave 1 finishes rid0 and clips rid1 at
    // progress 1 to fill the quota; wave 2 finishes rid3, clips rid2;
    // final wave runs 4 and 5 to completion (5 is shorter, finishes first)
    assert_eq!(b.updates, 3);
    assert_eq!(b.consumed_order, vec![0, 1, 2, 3, 5, 4]);
    assert_eq!(b.clipped, vec![1, 2]);
    assert_eq!(b.dropped, 0);
}

#[test]
fn golden_sorted_partial() {
    let b = run_kind(SchedulerKind::SortedPartial);
    // legacy partial mode: threshold waits for full completions; rid2 is
    // scavenged with progress kept and finishes at its true length
    assert_eq!(b.updates, 3);
    assert_eq!(b.consumed_order, vec![0, 1, 3, 2, 5, 4]);
    assert!(b.clipped.is_empty());
    assert_eq!(b.dropped, 0);
}

#[test]
fn golden_baseline() {
    let b = run_kind(SchedulerKind::Baseline);
    // legacy run_baseline: one wave to completion (order t2,t4,t7,t8,t9,t16
    // = rids 0,1,3,2,5,4), then sequential update chunks of 2
    assert_eq!(b.updates, 3);
    assert_eq!(b.consumed_order, vec![0, 1, 3, 2, 5, 4]);
    assert!(b.clipped.is_empty());
    assert_eq!(b.harvest_calls, 0, "baseline never harvests");
}

#[test]
fn golden_post_hoc_sort() {
    let b = run_kind(SchedulerKind::PostHocSort);
    // lengths ascending: rid5(1), rid0(2), rid3(3), rid1(4), rid2(6), rid4(9)
    assert_eq!(b.updates, 3);
    assert_eq!(b.consumed_order, vec![5, 0, 3, 1, 2, 4]);
}

#[test]
fn golden_no_grouped() {
    let b = run_kind(SchedulerKind::NoGroupedRollout);
    // legacy run_no_grouped: interrupted rids 2 and 4 are abandoned at the
    // two harvests; only 0,1 then 3,5 train
    assert_eq!(b.updates, 2);
    assert_eq!(b.consumed_order, vec![0, 1, 3, 5]);
    assert_eq!(b.dropped, 2);
    assert!(b.clipped.is_empty());
}

#[test]
fn golden_async_update() {
    let b = run_kind(SchedulerKind::AsyncUpdate);
    // async consumes in the same order as partial (same resume semantics)
    // but NEVER harvests in this scenario: updates fire while lanes run
    assert_eq!(b.updates, 3);
    assert_eq!(b.consumed_order, vec![0, 1, 3, 2, 5, 4]);
    assert_eq!(b.harvest_calls, 0, "async must update without a harvest barrier");
    assert!(b.clipped.is_empty());
    assert_eq!(b.dropped, 0);
}

#[test]
fn max_updates_truncates_mid_group() {
    let params = PolicyParams { refill_prompts: 6, entries_per_prompt: 1, update_batch: 2 };
    let mut policy = PolicyBuilder::new(SchedulerKind::Baseline, params).build();
    let mut b = BufferBackend::new(&LENS, 2, 2);
    drive(policy.as_mut(), &mut b).unwrap();
    assert_eq!(b.updates, 2);
    assert_eq!(b.consumed_order, vec![0, 1, 3, 2]);
}

// --------------------------------------------------------------------------
// work-stealing goldens (deterministic TokenBackend)
// --------------------------------------------------------------------------

/// On a single engine the WorkStealing wrapper must be inert: every kind
/// reproduces its unwrapped golden sequence exactly.
#[test]
fn steal_wrapper_is_inert_on_single_engine() {
    for kind in SchedulerKind::ALL {
        let base = run_kind(kind);
        let params = PolicyParams {
            refill_prompts: LENS.len(),
            entries_per_prompt: 1,
            update_batch: 2,
        };
        let mut policy = PolicyBuilder::new(kind, params).steal(true).build();
        let mut b = BufferBackend::new(&LENS, 2, 100);
        drive(policy.as_mut(), &mut b).unwrap();
        assert_eq!(b.consumed_order, base.consumed_order, "{kind:?}");
        assert_eq!(b.updates, base.updates, "{kind:?}");
        assert_eq!(b.harvest_calls, base.harvest_calls, "{kind:?}");
    }
}

/// Hand-derived queue-steal scenario: 2 engines x 1 lane, static striping,
/// lens [1,9,1,9] (e0 gets the two short ones, e1 the two cap-length).
/// After tick 2 engine 0 has drained; the wrapper steals e1's queued rid 3
/// (still at progress 0) so both long requests decode in parallel: the run
/// takes 11 ticks instead of the 18 the same policy needs without stealing.
#[test]
fn golden_steal_queue_migration_pinned() {
    let params = PolicyParams { refill_prompts: 4, entries_per_prompt: 1, update_batch: 2 };
    let run = |steal: bool| {
        let mut policy =
            PolicyBuilder::new(SchedulerKind::Baseline, params).steal(steal).build();
        let mut b =
            TokenBackend::new(&[1, 9, 1, 9], 2, 1, HarnessDispatch::Striped, usize::MAX);
        drive(policy.as_mut(), &mut b).unwrap();
        b
    };
    let stealing = run(true);
    assert_eq!(stealing.updates, 2);
    assert_eq!(stealing.consumed, vec![0, 2, 1, 3]);
    assert_eq!(stealing.steal_log, vec![(1, 0, 3, 0)]);
    assert_eq!(stealing.migrated_tokens, 0, "rid 3 had not started yet");
    assert_eq!(stealing.ticks, 11);
    assert_eq!(stealing.harvests, 0, "baseline never harvests");
    let flat = run(false);
    assert_eq!(flat.consumed, vec![0, 2, 1, 3], "same data, different clock");
    assert!(flat.steal_log.is_empty());
    assert_eq!(flat.ticks, 18);
}

/// A KV-choked engine is a legitimate queue-steal victim even with a free
/// lane: 2 engines x 2 lanes, budget 14 (reserves 13/5/9), static
/// striping.  Engine 0 runs rid 0 (reserve 13) with rid 2 stuck behind
/// the KV gate despite the free lane; engine 1 drains rid 1 after one
/// tick and sits idle.  `EngineLoad::kv_blocked` marks e0 saturated, so
/// the wrapper migrates rid 2 to e1 and the run takes 9 ticks instead of
/// the 14 needed when rid 2 must wait for rid 0's reservation.
#[test]
fn golden_steal_rescues_kv_blocked_queue() {
    let params = PolicyParams { refill_prompts: 3, entries_per_prompt: 1, update_batch: 3 };
    let run = |steal: bool| {
        let mut policy =
            PolicyBuilder::new(SchedulerKind::Baseline, params).steal(steal).build();
        let mut b = TokenBackend::new(&[9, 1, 5], 2, 2, HarnessDispatch::Striped, 14);
        drive(policy.as_mut(), &mut b).unwrap();
        b
    };
    let stealing = run(true);
    assert_eq!(stealing.steal_log, vec![(0, 1, 2, 0)]);
    assert_eq!(stealing.consumed, vec![1, 2, 0]);
    assert_eq!(stealing.migrated_tokens, 0, "rid 2 was still queued");
    assert_eq!(stealing.updates, 1);
    assert_eq!(stealing.ticks, 9);
    let flat = run(false);
    assert!(flat.steal_log.is_empty());
    assert_eq!(flat.consumed, vec![1, 0, 2], "rid 2 serialized behind rid 0's KV");
    assert_eq!(flat.ticks, 14);
}

/// Every wrapped kind pins identical consumed-rid AND steal-event
/// sequences across runs on the deterministic backend (no hidden
/// nondeterminism in the stealing path), and conserves the workload —
/// every request ends trained or deliberately dropped, never lost to a
/// migration.
#[test]
fn stealing_goldens_deterministic_across_runs() {
    let run = |kind: SchedulerKind| {
        let params =
            PolicyParams { refill_prompts: 8, entries_per_prompt: 1, update_batch: 2 };
        let mut policy = PolicyBuilder::new(kind, params).steal(true).build();
        let mut b = TokenBackend::new(&[2, 4, 6, 3, 9, 1, 5, 7], 2, 2,
                                      HarnessDispatch::Striped, usize::MAX);
        drive(policy.as_mut(), &mut b).unwrap();
        b
    };
    for kind in SchedulerKind::ALL {
        let a = run(kind);
        let b = run(kind);
        assert_eq!(a.consumed, b.consumed, "{kind:?}");
        assert_eq!(a.steal_log, b.steal_log, "{kind:?}");
        assert_eq!(a.updates, b.updates, "{kind:?}");
        assert_eq!(a.ticks, b.ticks, "{kind:?}");
        assert_eq!(a.consumed.len() + a.dropped.len(), 8,
                   "{kind:?} lost a request across steals");
    }
}

// --------------------------------------------------------------------------
// paged-KV goldens (deterministic TokenBackend)
// --------------------------------------------------------------------------

/// Hand-derived paged-vs-reserved golden on the skewed 4-engine workload:
/// 4 engines x 2 lanes, static striping, lens [9,9,9,9,2,2,2,2] (each
/// engine gets one long + one short request), budget 14, page 1.
///
/// Reserve mode charges the long request 4+9=13 up front, so the short
/// one (4+2=6) waits behind the KV gate until tick 9 — 4 concurrent
/// lanes, 11 ticks.  Paged mode charges the long lane only its actual
/// context (5 tokens after tick 1), so the short request co-runs from
/// tick 2 — 8 concurrent lanes, 9 ticks, and the shorts finish (and
/// train) first.  No backpressure fires: actual usage peaks at 12 of 14.
#[test]
fn golden_paged_admits_strictly_more_lanes_on_skewed_pool() {
    let params = PolicyParams { refill_prompts: 8, entries_per_prompt: 1, update_batch: 8 };
    let lens = [9, 9, 9, 9, 2, 2, 2, 2];
    let run = |mode: KvMode| {
        let kv = KvConfig { mode, budget: 14, page: 1 };
        // production paged composition (governor mounts iff kv is paged);
        // inert in reserve
        let mut policy = PolicyBuilder::new(SchedulerKind::Baseline, params).kv(kv).build();
        let mut b = TokenBackend::new_kv(&lens, 4, 2, HarnessDispatch::Striped, kv);
        drive(policy.as_mut(), &mut b).unwrap();
        b
    };
    let paged = run(KvMode::Paged);
    assert_eq!(paged.peak_running, 8, "paged co-runs long+short on every engine");
    assert_eq!(paged.ticks, 9);
    assert_eq!(paged.consumed, vec![4, 5, 6, 7, 0, 1, 2, 3], "shorts finish first");
    assert_eq!(paged.updates, 1);
    assert_eq!(paged.kv_sheds, 0, "exact estimates never over-commit here");
    assert_eq!(paged.throttled, 0);
    let reserved = run(KvMode::Reserve);
    assert_eq!(reserved.peak_running, 4, "cap reservations serialize the shorts");
    assert_eq!(reserved.ticks, 11);
    assert_eq!(reserved.consumed, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    assert_eq!(reserved.updates, 1);
    assert_eq!(reserved.kv_sheds, 0);
    assert!(paged.peak_running > reserved.peak_running);
    assert!(paged.ticks < reserved.ticks);
}

/// Paged runs are deterministic across repetitions, exactly like the
/// stealing goldens: same consumed order, tick count, shed/throttle
/// counts — no hidden nondeterminism in the backpressure paths.
#[test]
fn paged_goldens_deterministic_across_runs() {
    let run = |kind: SchedulerKind| {
        let params =
            PolicyParams { refill_prompts: 8, entries_per_prompt: 1, update_batch: 2 };
        let kv = KvConfig { mode: KvMode::Paged, budget: 20, page: 2 };
        let mut policy = PolicyBuilder::new(kind, params).steal(true).kv(kv).build();
        let mut b = TokenBackend::new_kv(&[2, 4, 6, 3, 9, 1, 5, 7], 2, 2,
                                         HarnessDispatch::Striped, kv);
        drive(policy.as_mut(), &mut b).unwrap();
        b
    };
    for kind in SchedulerKind::ALL {
        let a = run(kind);
        let b = run(kind);
        assert_eq!(a.consumed, b.consumed, "{kind:?}");
        assert_eq!(a.ticks, b.ticks, "{kind:?}");
        assert_eq!(a.steal_log, b.steal_log, "{kind:?}");
        assert_eq!(a.kv_sheds, b.kv_sheds, "{kind:?}");
        assert_eq!(a.throttled, b.throttled, "{kind:?}");
        assert_eq!(a.consumed.len() + a.dropped.len(), 8,
                   "{kind:?} lost a request under paged backpressure");
    }
}

// --------------------------------------------------------------------------
// simulator-side golden checks
// --------------------------------------------------------------------------

const SIM_MODES: [SimMode; 4] =
    [SimMode::Baseline, SimMode::SortedOnPolicy, SimMode::SortedPartial, SimMode::Async];

/// Same seed, same config -> bit-identical reports (the driver introduces
/// no hidden nondeterminism).
#[test]
fn sim_reports_deterministic_across_runs() {
    let w = longtail_workload(160, 2048, 9);
    for mode in SIM_MODES {
        let a = simulate_pool(mode, &w, 2, 32, 24, CostModel::default(),
                              DispatchPolicy::ShortestPredictedFirst,
                              PredictorKind::History);
        let b = simulate_pool(mode, &w, 2, 32, 24, CostModel::default(),
                              DispatchPolicy::ShortestPredictedFirst,
                              PredictorKind::History);
        assert_eq!(a.harvests, b.harvests, "{mode:?}");
        assert_eq!(a.useful_tokens, b.useful_tokens, "{mode:?}");
        assert_eq!(a.wasted_tokens, b.wasted_tokens, "{mode:?}");
        assert_eq!(a.clipped, b.clipped, "{mode:?}");
        assert_eq!(a.dropped, b.dropped, "{mode:?}");
        assert!((a.rollout_time - b.rollout_time).abs() < 1e-9, "{mode:?}");
        assert!((a.total_time - b.total_time).abs() < 1e-9, "{mode:?}");
    }
}

/// With stealing enabled, `simulate_pool` stays bit-deterministic across
/// runs — steal counts, migrated tokens, and the full report agree.
#[test]
fn sim_stealing_deterministic_across_runs() {
    let w = longtail_workload(160, 2048, 9);
    let opts = PoolSimOpts {
        engines: 4,
        q_total: 32,
        update_batch: 24,
        dispatch: DispatchPolicy::RoundRobin,
        predictor: PredictorKind::History,
        steal: true,
        ..PoolSimOpts::default()
    };
    for mode in SIM_MODES {
        let a = SimRun::new(mode, opts).workload(&w).run();
        let b = SimRun::new(mode, opts).workload(&w).run();
        assert_eq!(a.steals, b.steals, "{mode:?}");
        assert_eq!(a.migrated_tokens, b.migrated_tokens, "{mode:?}");
        assert_eq!(a.useful_tokens, b.useful_tokens, "{mode:?}");
        assert_eq!(a.wasted_tokens, b.wasted_tokens, "{mode:?}");
        assert_eq!(a.clipped, b.clipped, "{mode:?}");
        assert_eq!(a.dropped, b.dropped, "{mode:?}");
        assert!((a.rollout_time - b.rollout_time).abs() < 1e-9, "{mode:?}");
        // stealing must not break request conservation
        assert_eq!(a.timeline.finished() as usize + a.clipped + a.dropped, 160,
                   "{mode:?}");
    }
}

/// `simulate` is literally the one-engine member of the pool family now —
/// identical decision sequence, identical report.
#[test]
fn single_engine_sim_is_the_pool_member() {
    let w = longtail_workload(96, 1024, 3);
    for mode in SIM_MODES {
        let a = simulate(mode, &w, 16, 12, CostModel::default());
        let b = simulate_pool(mode, &w, 1, 16, 12, CostModel::default(),
                              DispatchPolicy::ShortestPredictedFirst,
                              PredictorKind::History);
        assert_eq!(a.useful_tokens, b.useful_tokens, "{mode:?}");
        assert_eq!(a.wasted_tokens, b.wasted_tokens, "{mode:?}");
        assert_eq!(a.clipped, b.clipped, "{mode:?}");
        assert_eq!(a.harvests, b.harvests, "{mode:?}");
        assert!((a.rollout_time - b.rollout_time).abs() < 1e-9, "{mode:?}");
    }
}
