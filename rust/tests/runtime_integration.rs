//! Integration: the rust runtime against real AOT artifacts (tiny config).
//!
//! Requires `make artifacts` (skips with a notice otherwise).  Exercises the
//! full bridge: manifest parse -> HLO compile -> init/prefill/decode/train.

use sortedrl::runtime::{Runtime, TrainBatch};
use sortedrl::tokenizer::{Tokenizer, BOS, EOS, PAD};
use sortedrl::util::rng::Pcg64;
use std::path::Path;

const TAG: &str = "tiny.B4k8.Bt4T192";

// xla::Literal is !Send, so each test builds its own Runtime (tiny HLOs
// compile in well under a second).
fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    match Runtime::load(&dir, Some(TAG)) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifacts present but tag {TAG} unavailable: {e:#}");
            None
        }
    }
}

macro_rules! need_rt {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => return,
        }
    };
}

#[test]
fn init_produces_manifest_shapes() {
    let rt = &need_rt!();
    let state = rt.init(42).unwrap();
    assert_eq!(state.params.len(), rt.manifest.shapes.n_param_tensors);
    for (lit, spec) in state.params.iter().zip(&rt.manifest.params) {
        assert_eq!(lit.element_count(), spec.elements(), "{}", spec.name);
    }
    // deterministic in the seed
    let again = rt.init(42).unwrap();
    let a = state.params[0].to_vec::<f32>().unwrap();
    let b = again.params[0].to_vec::<f32>().unwrap();
    assert_eq!(a, b);
    let other = rt.init(43).unwrap();
    let c = other.params[0].to_vec::<f32>().unwrap();
    assert_ne!(a, c);
}

#[test]
fn prefill_then_decode_generates_tokens() {
    let rt = &need_rt!();
    let sh = rt.manifest.shapes.clone();
    let state = rt.init(1).unwrap();
    let tok = Tokenizer::new();
    let prompt = tok.encode("<bos> LOGIC 3 ; P0 says P1 K ; ?").unwrap();

    let mut tokens = vec![PAD; sh.engine_batch * sh.prefill_seq];
    let mut lens = vec![1i32; sh.engine_batch];
    for b in 0..sh.engine_batch {
        tokens[b * sh.prefill_seq] = BOS;
        if b < 2 {
            for (i, &t) in prompt.iter().enumerate() {
                tokens[b * sh.prefill_seq + i] = t;
            }
            lens[b] = prompt.len() as i32;
        }
    }
    let (kv, logits) = rt.prefill(&state, &tokens, &lens).unwrap();
    assert_eq!(logits.len(), sh.engine_batch * rt.manifest.model.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));

    // sample first token in rust (log-softmax + inverse CDF)
    let mut rng = Pcg64::new(9);
    let v = rt.manifest.model.vocab;
    let first: Vec<i32> = (0..sh.engine_batch)
        .map(|b| {
            let row = &logits[b * v..(b + 1) * v];
            let m = row.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = row.iter().map(|x| (x - m).exp()).collect();
            let sum: f32 = exps.iter().sum();
            let u = rng.uniform_f32() * sum;
            let mut acc = 0.0;
            for (i, e) in exps.iter().enumerate() {
                acc += e;
                if acc >= u {
                    return i as i32;
                }
            }
            (v - 1) as i32
        })
        .collect();

    let pos: Vec<i32> = lens.clone();
    let active = vec![1i32; sh.engine_batch];
    let uniforms: Vec<f32> = (0..sh.engine_batch * sh.decode_chunk)
        .map(|_| rng.uniform_f32())
        .collect();
    let (_kv, out) = rt.decode_chunk(&state, kv, &first, &pos, &active, &uniforms, 1.0).unwrap();
    assert_eq!(out.out_tokens.len(), sh.engine_batch * sh.decode_chunk);
    // positions advance monotonically for lanes that stayed active
    for b in 0..sh.engine_batch {
        assert!(out.pos[b] >= pos[b]);
        assert!(out.pos[b] <= pos[b] + sh.decode_chunk as i32);
    }
    // all emitted tokens in-vocab; logps non-positive for active emissions
    for (i, &t) in out.out_tokens.iter().enumerate() {
        assert!((0..v as i32).contains(&t));
        if t != PAD as i32 {
            assert!(out.out_logp[i] <= 1e-5, "logp[{i}]={}", out.out_logp[i]);
        }
    }
}

#[test]
fn greedy_decode_is_reproducible() {
    let rt = &need_rt!();
    let sh = rt.manifest.shapes.clone();
    let state = rt.init(2).unwrap();
    let tokens = vec![BOS; sh.engine_batch * sh.prefill_seq];
    let lens = vec![1i32; sh.engine_batch];
    let uniforms = vec![-1.0f32; sh.engine_batch * sh.decode_chunk];
    let tok0 = vec![BOS; sh.engine_batch];
    let pos = lens.clone();
    let active = vec![1i32; sh.engine_batch];

    let (kv_a, _) = rt.prefill(&state, &tokens, &lens).unwrap();
    let (_, a) = rt.decode_chunk(&state, kv_a, &tok0, &pos, &active, &uniforms, 1.0).unwrap();
    let (kv_b, _) = rt.prefill(&state, &tokens, &lens).unwrap();
    let (_, b) = rt.decode_chunk(&state, kv_b, &tok0, &pos, &active, &uniforms, 1.0).unwrap();
    assert_eq!(a.out_tokens, b.out_tokens);
    assert_eq!(a.out_logp, b.out_logp);
}

#[test]
fn eos_terminates_lane() {
    let rt = &need_rt!();
    let sh = rt.manifest.shapes.clone();
    let state = rt.init(3).unwrap();
    let tokens = vec![BOS; sh.engine_batch * sh.prefill_seq];
    let lens = vec![1i32; sh.engine_batch];
    let (mut kv, _) = rt.prefill(&state, &tokens, &lens).unwrap();
    // run several chunks; once a lane emits EOS its active flag must drop
    let mut tok = vec![BOS; sh.engine_batch];
    let mut pos = lens.clone();
    let mut active = vec![1i32; sh.engine_batch];
    let mut rng = Pcg64::new(5);
    for _ in 0..6 {
        let uniforms: Vec<f32> = (0..sh.engine_batch * sh.decode_chunk)
            .map(|_| rng.uniform_f32())
            .collect();
        let (kv2, out) = rt.decode_chunk(&state, kv, &tok, &pos, &active, &uniforms, 1.0).unwrap();
        kv = kv2;
        for b in 0..sh.engine_batch {
            let row = &out.out_tokens[b * sh.decode_chunk..(b + 1) * sh.decode_chunk];
            if let Some(i) = row.iter().position(|&t| t == EOS) {
                assert!(row[i + 1..].iter().all(|&t| t == PAD),
                        "tokens after EOS must be PAD: {row:?}");
                assert_eq!(out.active[b], 0);
            }
        }
        tok = out.tok;
        pos = out.pos;
        active = out.active;
        if active.iter().all(|&a| a == 0) {
            break;
        }
    }
}

#[test]
fn sft_step_decreases_loss() {
    let rt = &need_rt!();
    let sh = rt.manifest.shapes.clone();
    let mut state = rt.init(4).unwrap();
    let tok = Tokenizer::new();
    // one fixed easy pattern repeated across the batch
    let sample = tok.encode("<bos> MATH ( 3 + 4 ) = ? <think> step 3 + 4 = 7 ; </think> <answer> 7 </answer> <eos>").unwrap();
    let mut tokens = vec![PAD; sh.train_batch * sh.train_seq];
    let mut weights = vec![0f32; sh.train_batch * sh.train_seq];
    for b in 0..sh.train_batch {
        for (i, &t) in sample.iter().enumerate() {
            tokens[b * sh.train_seq + i] = t;
            weights[b * sh.train_seq + i] = 1.0;
        }
    }
    let (first, _) = rt.sft_step(&mut state, &tokens, &weights, 3e-3).unwrap();
    let mut last = first;
    for _ in 0..7 {
        let (loss, gnorm) = rt.sft_step(&mut state, &tokens, &weights, 3e-3).unwrap();
        assert!(gnorm.is_finite());
        last = loss;
    }
    assert!(last < first * 0.8, "sft loss {first} -> {last}");
    assert_eq!(state.step, 8);
    assert_eq!(state.version, 8);
}

#[test]
fn train_step_moves_policy_toward_positive_advantage() {
    let rt = &need_rt!();
    let sh = rt.manifest.shapes.clone();
    let mut state = rt.init(5).unwrap();
    let mut rng = Pcg64::new(7);
    let mut tokens = vec![PAD; sh.train_batch * sh.train_seq];
    for t in tokens.iter_mut() {
        *t = rng.range_i64(3, rt.manifest.model.vocab as i64) as i32;
    }
    let mut mask = vec![0f32; sh.train_batch * sh.train_seq];
    for b in 0..sh.train_batch {
        for i in 4..60 {
            mask[b * sh.train_seq + i] = 1.0;
        }
    }
    let old_logp = rt.logprob(&state, &tokens).unwrap();
    let adv = vec![1.0f32; sh.train_batch * sh.train_seq];
    let stats = rt
        .train_step(&mut state, &TrainBatch {
            tokens: tokens.clone(),
            mask: mask.clone(),
            adv,
            old_logp: old_logp.clone(),
            lr: 5e-3,
        })
        .unwrap();
    // ratio starts at 1 -> loss == -mean(adv) == -1, no clipping, zero KL
    assert!((stats.loss + 1.0).abs() < 1e-4, "loss={}", stats.loss);
    assert!((stats.mean_ratio - 1.0).abs() < 1e-4);
    assert!(stats.clip_frac.abs() < 1e-6);
    assert!(stats.approx_kl.abs() < 1e-5);

    let new_logp = rt.logprob(&state, &tokens).unwrap();
    let gain: f32 = new_logp
        .iter()
        .zip(&old_logp)
        .zip(&mask)
        .map(|((n, o), m)| (n - o) * m)
        .sum();
    assert!(gain > 0.0, "policy must move toward positive-advantage tokens");
}

#[test]
fn merge_kv_lanes_overwrites_only_selected() {
    let rt = &need_rt!();
    let sh = rt.manifest.shapes.clone();
    let state = rt.init(6).unwrap();
    // cache A: prompts all BOS; cache B: prompts all "MATH"
    let lens = vec![1i32; sh.engine_batch];
    let (kv_a, _) = rt.prefill(&state, &vec![BOS; sh.engine_batch * sh.prefill_seq], &lens).unwrap();
    let math_tok = Tokenizer::new().encode("MATH").unwrap()[0];
    let (kv_b, _) = rt.prefill(&state, &vec![math_tok; sh.engine_batch * sh.prefill_seq], &lens).unwrap();

    let merged = rt.merge_kv_lanes(&kv_a, &kv_b, &[1, 3]).unwrap();
    let dims = &sh.kv_cache;
    let lane_block = dims[3] * dims[4] * dims[5];
    let a = kv_a.to_vec::<f32>().unwrap();
    let b = kv_b.to_vec::<f32>().unwrap();
    let m = merged.to_vec::<f32>().unwrap();
    for outer in 0..dims[0] * dims[1] {
        for lane in 0..dims[2] {
            let off = (outer * dims[2] + lane) * lane_block;
            let want = if lane == 1 || lane == 3 { &b } else { &a };
            assert_eq!(&m[off..off + lane_block], &want[off..off + lane_block],
                       "outer={outer} lane={lane}");
        }
    }
}
