//! Property tests over the coordinator state machine and simulator —
//! randomized sequences of buffer operations and workloads must preserve
//! the paper-level invariants regardless of scheduling interleaving.
//! (In-repo property harness; the proptest crate is unavailable offline.)

use sortedrl::coordinator::{Lifecycle, Mode, RolloutBuffer};
use sortedrl::rollout::{Request, Rollout};
use sortedrl::sim::{longtail_workload, simulate, CostModel, SimMode};
use sortedrl::util::proptest::{property, Gen};

fn mk_rollout(req: &Request, n_tok: usize, complete: bool, at: f64) -> Rollout {
    let mut response = req.resumed.clone();
    let mut logp = req.resumed_logp.clone();
    for i in 0..n_tok {
        response.push(10 + (i % 20) as i32);
        logp.push(-0.3 - i as f32 * 0.01);
    }
    Rollout {
        request: req.clone(),
        response,
        logp,
        finish_version: req.born_version.unwrap_or(0) + 1,
        complete,
        finished_at: at,
    }
}

/// Random dispatch/finish/terminate/consume churn never violates buffer
/// invariants, and every trajectory's log-probs stay aligned.
#[test]
fn buffer_invariants_under_random_churn() {
    property("buffer churn", 200, |g: &mut Gen| {
        let mut buf = RolloutBuffer::new();
        let n = g.usize_in(1..24);
        let max_new = 32;
        let rids: Vec<u64> = (0..n)
            .map(|i| buf.load_prompt(i, i as u64, vec![1, 2, 3], max_new))
            .collect();
        let mode = if g.bool() { Mode::OnPolicy } else { Mode::Partial };
        let mut clock = 0.0;
        for _round in 0..g.usize_in(1..6) {
            let schedulable = buf.schedulable();
            if schedulable.is_empty() {
                break;
            }
            let take = g.usize_in(1..schedulable.len() + 1);
            let reqs = buf.dispatch(&schedulable[..take]);
            for req in &reqs {
                clock += 0.25;
                let remaining = max_new - req.resumed.len();
                if remaining == 0 {
                    // nothing left to generate: must finish
                    buf.record_finished(&mk_rollout(req, 0, true, clock));
                    continue;
                }
                match g.usize_in(0..3) {
                    0 => {
                        let k = g.usize_in(1..remaining + 1);
                        buf.record_finished(&mk_rollout(req, k, true, clock));
                    }
                    1 => {
                        let k = g.usize_in(0..remaining);
                        buf.record_terminated(&mk_rollout(req, k, false, clock), mode);
                    }
                    _ => buf.record_requeued(req.rid),
                }
            }
            buf.check_invariants().unwrap();
            // consume some ready
            let ready = buf.ready_rids();
            if !ready.is_empty() {
                let k = g.usize_in(1..ready.len() + 1);
                let entries = buf.consume(&ready[..k]);
                for e in &entries {
                    assert_eq!(e.partial.len(), e.partial_logp.len());
                    assert!(e.complete || e.clipped);
                }
            }
            buf.check_invariants().unwrap();
        }
        // ready ordering is completion order (finished_at ascending)
        let ready = buf.ready_rids();
        let times: Vec<f64> = ready
            .iter()
            .map(|r| buf.get(*r).unwrap().finished_at)
            .collect();
        for w in times.windows(2) {
            assert!(w[0] <= w[1]);
        }
        let _ = rids;
    });
}

/// On-policy termination always clears partials and resets born_version;
/// partial termination preserves exactly the generated prefix + log-probs.
#[test]
fn termination_mode_semantics() {
    property("termination semantics", 200, |g: &mut Gen| {
        let mut buf = RolloutBuffer::new();
        let rid = buf.load_prompt(0, 1, vec![1, 2], 64);
        let reqs = buf.dispatch(&[rid]);
        let k = g.usize_in(1..40);
        let r = mk_rollout(&reqs[0], k, false, 1.0);
        if g.bool() {
            buf.record_terminated(&r, Mode::OnPolicy);
            let e = buf.get(rid).unwrap();
            assert!(e.partial.is_empty());
            assert_eq!(e.born_version, None);
        } else {
            buf.record_terminated(&r, Mode::Partial);
            let e = buf.get(rid).unwrap();
            assert_eq!(e.partial.len(), k);
            assert_eq!(e.partial_logp.len(), k);
            assert_eq!(e.partial, r.response);
        }
        let e = buf.get(rid).unwrap();
        assert_eq!(e.lifecycle, Lifecycle::Scavenged);
        assert_eq!(e.resumes, 1);
    });
}

/// Resume composition: repeated partial terminations concatenate prefixes
/// without loss (π_old continuity — Eq. 1's requirement).
#[test]
fn partial_resume_concatenates_logps() {
    property("resume concatenation", 100, |g: &mut Gen| {
        let mut buf = RolloutBuffer::new();
        let rid = buf.load_prompt(0, 1, vec![1, 2], 256);
        let mut expected_tokens: Vec<i32> = Vec::new();
        let mut expected_logp: Vec<f32> = Vec::new();
        let rounds = g.usize_in(1..5);
        for round in 0..rounds {
            let reqs = buf.dispatch(&[rid]);
            assert_eq!(reqs[0].resumed, expected_tokens);
            assert_eq!(reqs[0].resumed_logp, expected_logp);
            let k = g.usize_in(1..20);
            let r = mk_rollout(&reqs[0], k, round == rounds - 1, round as f64);
            expected_tokens = r.response.clone();
            expected_logp = r.logp.clone();
            if round == rounds - 1 {
                buf.record_finished(&r);
            } else {
                buf.record_terminated(&r, Mode::Partial);
            }
        }
        let e = buf.get(rid).unwrap();
        assert_eq!(e.partial, expected_tokens);
        assert_eq!(e.partial_logp, expected_logp);
        assert_eq!(e.lifecycle, Lifecycle::Ready);
    });
}

/// Simulator conservation: under any (n, cap, q, u) every request is
/// accounted exactly once and bubble ratio stays in [0, 1].
#[test]
fn sim_conservation_under_random_configs() {
    property("sim conservation", 40, |g: &mut Gen| {
        let n = g.usize_in(16..256);
        let cap = *g.pick(&[512usize, 1024, 4096]);
        let q = *g.pick(&[8usize, 32, 128]);
        let u = g.usize_in(4..n + 1);
        let seed = g.rng.next_u64();
        let w = longtail_workload(n, cap, seed);
        for mode in [SimMode::Baseline, SimMode::SortedOnPolicy, SimMode::SortedPartial] {
            let r = simulate(mode, &w, q, u, CostModel::default());
            assert_eq!(
                r.timeline.finished() as usize + r.clipped + r.dropped,
                n,
                "{mode:?} n={n} q={q} u={u} seed={seed}"
            );
            assert!(r.bubble_ratio >= 0.0 && r.bubble_ratio <= 1.0);
            assert!(r.rollout_time > 0.0);
            assert!(r.useful_tokens > 0);
            if mode == SimMode::SortedPartial {
                assert_eq!(r.wasted_tokens, 0, "partial never wastes");
            }
        }
    });
}

/// The sorted schedulers never lose to baseline on bubble ratio across
/// random long-tailed workloads (the paper's headline claim).
#[test]
fn sorted_always_improves_bubble() {
    property("bubble dominance", 15, |g: &mut Gen| {
        let n = g.usize_in(128..512);
        let w = longtail_workload(n, 8192, g.rng.next_u64());
        let u = *g.pick(&[64usize, 128]);
        let base = simulate(SimMode::Baseline, &w, 128, u, CostModel::default());
        for mode in [SimMode::SortedOnPolicy, SimMode::SortedPartial] {
            let r = simulate(mode, &w, 128, u, CostModel::default());
            assert!(
                r.bubble_ratio < base.bubble_ratio,
                "{mode:?}: {} !< {}",
                r.bubble_ratio,
                base.bubble_ratio
            );
        }
    });
}

/// Advantage normalization: permutation-invariance within a batch and
/// zero-mean for Reinforce++ (what makes selective batching matter is the
/// membership, never the order).
#[test]
fn advantage_permutation_invariant() {
    use sortedrl::rl::advantage::{advantages, AdvantageKind, BaselineState, RewardEntry};
    property("advantage permutation", 100, |g: &mut Gen| {
        let n = g.usize_in(2..64);
        let entries: Vec<RewardEntry> = (0..n)
            .map(|i| RewardEntry {
                reward: g.f64_in(-2.0, 3.0),
                group: (i % 4) as u64,
            })
            .collect();
        let mut b = BaselineState::default();
        let a1 = advantages(AdvantageKind::ReinforcePlusPlus, &entries, &mut b);
        let mean: f64 = a1.iter().sum::<f64>() / n as f64;
        assert!(mean.abs() < 1e-6, "z-scores must be zero-mean: {mean}");
        // permute
        let mut idx: Vec<usize> = (0..n).collect();
        g.rng.shuffle(&mut idx);
        let permuted: Vec<RewardEntry> = idx.iter().map(|&i| entries[i]).collect();
        let a2 = advantages(AdvantageKind::ReinforcePlusPlus, &permuted, &mut b);
        for (j, &i) in idx.iter().enumerate() {
            assert!((a2[j] - a1[i]).abs() < 1e-9);
        }
    });
}
