//! Vendored offline stub of the `xla` (xla-rs) binding surface that
//! `sortedrl::runtime` compiles against.
//!
//! The real crate wraps the XLA C++ client + PJRT; that native dependency
//! cannot be built offline, so this stub provides the same API shape with
//! two behaviors:
//!
//!   * **Host literal plumbing works for real** — `Literal::scalar/vec1/
//!     reshape/to_vec/get_first_element` store and convert data faithfully,
//!     so every code path up to device execution is exercised by tests.
//!   * **Device entry points fail fast** — `HloModuleProto::from_text_file`,
//!     `PjRtClient::compile` and `PjRtLoadedExecutable::execute` return an
//!     "XLA unavailable" error, which `Runtime::load` surfaces and the
//!     integration tests treat as a skip (they already skip when
//!     `artifacts/` is absent, which is the same situation).
//!
//! Swapping in the real bindings = pointing the workspace `xla` dependency
//! at xla-rs and providing `XLA_EXTENSION_DIR`; the sortedrl call sites are
//! written against the subset that both implementations share.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT native bindings unavailable (offline stub; \
         see DESIGN.md §Substitutions)"
    ))
}

#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host tensor: a typed buffer plus dims (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Element types the stub can marshal (the manifest only uses f32/i32).
pub trait NativeType: Copy + Sized {
    fn wrap(v: &[Self]) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: &[f32]) -> Data {
        Data::F32(v.to_vec())
    }
    fn unwrap(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(x) => Some(x.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: &[i32]) -> Data {
        Data::I32(v.to_vec())
    }
    fn unwrap(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(x) => Some(x.clone()),
            _ => None,
        }
    }
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(&[v]), dims: Vec::new() }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v), dims: vec![v.len() as i64] }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!(
                "reshape: cannot view {have} elements as {dims:?}"
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("to_vec: dtype mismatch".into()))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("get_first_element: empty literal".into()))
    }

    /// Tuple literals only exist as device execution results, which the
    /// stub cannot produce; kept for API compatibility.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub: parsing requires the native bindings).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-offline-stub".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.get_first_element::<f32>().unwrap(), 1.0);
    }

    #[test]
    fn literal_scalar_i32() {
        let l = Literal::scalar(7i32);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn device_paths_fail_fast() {
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert!(client.compile(&XlaComputation).is_err());
    }
}
