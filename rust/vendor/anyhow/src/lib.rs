//! Vendored minimal stand-in for the `anyhow` crate (the build must work
//! with no crates.io access — see DESIGN.md §Substitutions).
//!
//! Implements exactly the subset this workspace uses:
//!
//!   * [`Result`] / [`Error`] with a context chain,
//!   * `?` conversion from any `std::error::Error + Send + Sync + 'static`,
//!   * [`anyhow!`] / [`bail!`] macros,
//!   * [`Context`] (`.context(..)` / `.with_context(..)`) on `Result`
//!     (both std-error and `anyhow::Error` variants) and on `Option`,
//!   * `{e}` prints the outermost message, `{e:#}` the full `a: b: c` chain
//!     (matching real anyhow's Display semantics).
//!
//! Swap back to crates.io `anyhow` by pointing the workspace dependency at
//! the registry; no call sites need to change.

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` lowers to).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    /// Prepend a context message (what `Context::context` lowers to).
    pub fn wrap<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what keeps the blanket `From` below coherent with core's `From<T> for T`
// (the same trick real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod ext {
    use super::Error;

    /// Sealed conversion helper so `Context` has one impl covering both
    /// `Result<T, E: std::error::Error>` and `Result<T, anyhow::Error>`.
    pub trait IntoError {
        fn into_err(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_err(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_err(self) -> Error {
            self
        }
    }
}

pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_err().wrap(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_err().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: Result<()> = Err(io_err()).context("reading manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.context("missing field");
        assert_eq!(format!("{:#}", r.unwrap_err()), "missing field");
        let r: Result<i32> = Some(3).with_context(|| "unused");
        assert_eq!(r.unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let x = 7;
        let e = anyhow!("value {x} and {}", 8);
        assert_eq!(format!("{e}"), "value 7 and 8");
        let s = String::from("owned message");
        let e = anyhow!(s);
        assert_eq!(format!("{e}"), "owned message");
    }

    #[test]
    fn bail_returns_err() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 1);
            }
            Ok(5)
        }
        assert_eq!(f(false).unwrap(), 5);
        assert_eq!(format!("{:#}", f(true).unwrap_err()), "boom 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
