//! Symbolic tokenizer shared by the logic and math tasks.
//!
//! The vocabulary MUST match `python/compile/configs.py::VOCAB` (index ==
//! token id); the AOT manifest embeds the python copy and
//! [`Tokenizer::assert_matches_manifest`] fails fast on drift.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3; // ";"
pub const THINK_OPEN: i32 = 4;
pub const THINK_CLOSE: i32 = 5;
pub const ANS_OPEN: i32 = 6;
pub const ANS_CLOSE: i32 = 7;

/// Token ids for the digits 0..=9 are `DIGIT0 + d`.
pub const DIGIT0: i32 = 8;
pub const PLUS: i32 = 18;
pub const MINUS: i32 = 19;
pub const STAR: i32 = 20;
pub const SLASH: i32 = 21;
pub const LPAREN: i32 = 22;
pub const RPAREN: i32 = 23;
pub const EQUALS: i32 = 24;
pub const KNIGHT: i32 = 25; // "K"
pub const KNAVE: i32 = 26; // "N"
pub const AND: i32 = 27;
pub const OR: i32 = 28;
pub const NOT: i32 = 29;
pub const IFF: i32 = 30; // "<=>"
pub const COLON: i32 = 31;
pub const SAYS: i32 = 32;
/// Person tokens are `PERSON0 + i` for i in 0..10.
pub const PERSON0: i32 = 33;
pub const LOGIC: i32 = 43;
pub const MATH: i32 = 44;
pub const COMMA: i32 = 45;
pub const QMARK: i32 = 46;
pub const STEP: i32 = 47;
pub const ARROW: i32 = 48; // "->"
pub const SO: i32 = 49;
pub const IF: i32 = 50;
pub const THEN: i32 = 51;
pub const NOT_WORD: i32 = 52;
pub const TRUE_WORD: i32 = 53;
pub const FALSE_WORD: i32 = 54;
pub const CHECK: i32 = 55;
pub const BY: i32 = 56;

pub const VOCAB: [&str; 64] = [
    "<pad>", "<bos>", "<eos>", ";", "<think>", "</think>", "<answer>", "</answer>",
    "0", "1", "2", "3", "4", "5", "6", "7", "8", "9",
    "+", "-", "*", "/", "(", ")", "=",
    "K", "N", "&", "|", "!", "<=>", ":", "says",
    "P0", "P1", "P2", "P3", "P4", "P5", "P6", "P7", "P8", "P9",
    "LOGIC", "MATH", ",", "?", "step", "->",
    "so", "if", "then", "not", "true", "false", "check", "by",
    "<r0>", "<r1>", "<r2>", "<r3>", "<r4>", "<r5>", "<r6>",
];

pub const VOCAB_SIZE: usize = VOCAB.len();

#[derive(Debug, Clone)]
pub struct Tokenizer {
    lookup: HashMap<&'static str, i32>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let lookup = VOCAB
            .iter()
            .enumerate()
            .map(|(i, s)| (*s, i as i32))
            .collect();
        Self { lookup }
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB_SIZE
    }

    /// Encode a whitespace-separated symbolic string.
    pub fn encode(&self, text: &str) -> Result<Vec<i32>, String> {
        text.split_whitespace()
            .map(|w| {
                self.lookup
                    .get(w)
                    .copied()
                    .ok_or_else(|| format!("unknown token {w:?}"))
            })
            .collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&id| self.token_str(id))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn token_str(&self, id: i32) -> &'static str {
        VOCAB.get(id as usize).copied().unwrap_or("<?>")
    }

    /// Encode a (possibly negative, multi-digit) integer as digit tokens.
    pub fn encode_int(&self, value: i64) -> Vec<i32> {
        let mut out = Vec::new();
        if value < 0 {
            out.push(MINUS);
        }
        let digits = value.unsigned_abs().to_string();
        for c in digits.bytes() {
            out.push(DIGIT0 + (c - b'0') as i32);
        }
        out
    }

    /// Parse digit tokens (optionally led by MINUS) back into an integer.
    /// Returns None on any non-digit token or empty input.
    pub fn decode_int(&self, ids: &[i32]) -> Option<i64> {
        let (neg, rest) = match ids.split_first() {
            Some((&MINUS, rest)) => (true, rest),
            _ => (false, ids),
        };
        if rest.is_empty() || rest.len() > 10 {
            return None;
        }
        let mut v: i64 = 0;
        for &id in rest {
            if !(DIGIT0..DIGIT0 + 10).contains(&id) {
                return None;
            }
            v = v * 10 + (id - DIGIT0) as i64;
        }
        Some(if neg { -v } else { v })
    }

    pub fn person(&self, idx: usize) -> i32 {
        assert!(idx < 10);
        PERSON0 + idx as i32
    }

    /// Fail fast if the manifest's embedded vocabulary drifted from ours.
    pub fn assert_matches_manifest(&self, manifest_vocab: &[String]) -> Result<(), String> {
        if manifest_vocab.len() != VOCAB.len() {
            return Err(format!(
                "vocab size mismatch: manifest {} vs rust {}",
                manifest_vocab.len(),
                VOCAB.len()
            ));
        }
        for (i, (m, r)) in manifest_vocab.iter().zip(VOCAB.iter()).enumerate() {
            if m != r {
                return Err(format!("vocab[{i}] mismatch: manifest {m:?} vs rust {r:?}"));
            }
        }
        Ok(())
    }
}

/// Find the token span strictly between `open` and `close` markers.
/// Returns None if either marker is missing or out of order.
pub fn span_between(ids: &[i32], open: i32, close: i32) -> Option<&[i32]> {
    let start = ids.iter().position(|&t| t == open)? + 1;
    let end = start + ids[start..].iter().position(|&t| t == close)?;
    Some(&ids[start..end])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_round_trip() {
        let tok = Tokenizer::new();
        let text = "<bos> LOGIC 3 ; P0 says P1 K ; ?";
        let ids = tok.encode(text).unwrap();
        assert_eq!(tok.decode(&ids), text);
    }

    #[test]
    fn unknown_token_errors() {
        let tok = Tokenizer::new();
        assert!(tok.encode("hello world").is_err());
    }

    #[test]
    fn int_round_trip() {
        let tok = Tokenizer::new();
        for v in [-99, -7, 0, 5, 42, 12345] {
            let ids = tok.encode_int(v);
            assert_eq!(tok.decode_int(&ids), Some(v), "{v}");
        }
    }

    #[test]
    fn decode_int_rejects_garbage() {
        let tok = Tokenizer::new();
        assert_eq!(tok.decode_int(&[]), None);
        assert_eq!(tok.decode_int(&[MINUS]), None);
        assert_eq!(tok.decode_int(&[KNIGHT]), None);
        assert_eq!(tok.decode_int(&[DIGIT0, SAYS]), None);
    }

    #[test]
    fn span_between_basic() {
        let ids = [BOS, ANS_OPEN, DIGIT0 + 4, DIGIT0 + 2, ANS_CLOSE, EOS];
        assert_eq!(span_between(&ids, ANS_OPEN, ANS_CLOSE), Some(&ids[2..4]));
        assert_eq!(span_between(&ids, THINK_OPEN, THINK_CLOSE), None);
    }

    #[test]
    fn vocab_ids_match_constants() {
        assert_eq!(VOCAB[PAD as usize], "<pad>");
        assert_eq!(VOCAB[IFF as usize], "<=>");
        assert_eq!(VOCAB[SAYS as usize], "says");
        assert_eq!(VOCAB[PERSON0 as usize], "P0");
        assert_eq!(VOCAB[LOGIC as usize], "LOGIC");
        assert_eq!(VOCAB[MATH as usize], "MATH");
        assert_eq!(VOCAB[BY as usize], "by");
        assert_eq!(VOCAB_SIZE, 64);
    }
}
