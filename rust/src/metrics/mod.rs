//! Occupancy timeline + bubble ratio (paper Eq. 4) + throughput accounting.
//!
//! Both the real PJRT-backed engine and the discrete-event simulator record
//! the same [`Timeline`], so Fig. 5's bubble numbers come out of one code
//! path regardless of backend.

/// Piecewise-constant record of how many requests were actively decoding.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    /// (time, running_requests_after_this_instant)
    events: Vec<(f64, usize)>,
    tokens_out: u64,
    finished: u64,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the running-request count changing at time `t` (seconds).
    pub fn set_running(&mut self, t: f64, running: usize) {
        if let Some(&(lt, lr)) = self.events.last() {
            debug_assert!(t >= lt, "time went backwards: {t} < {lt}");
            if lr == running {
                return;
            }
        }
        self.events.push((t, running));
    }

    pub fn add_tokens(&mut self, n: u64) {
        self.tokens_out += n;
    }

    pub fn add_finished(&mut self, n: u64) {
        self.finished += n;
    }

    pub fn tokens_out(&self) -> u64 {
        self.tokens_out
    }

    pub fn finished(&self) -> u64 {
        self.finished
    }

    pub fn span(&self) -> (f64, f64) {
        match (self.events.first(), self.events.last()) {
            (Some(&(a, _)), Some(&(b, _))) => (a, b),
            _ => (0.0, 0.0),
        }
    }

    /// Paper Eq. 4: bubble = Σ_k (Q − r_k)·Δt_k / (T·Q), where Q is the
    /// engine's running-queue capacity, r_k the running requests during
    /// interval k, T the total elapsed time.  `end` closes the last
    /// interval (generation finished / harvest time).
    pub fn bubble_ratio(&self, queue_capacity: usize, end: f64) -> f64 {
        if self.events.is_empty() || queue_capacity == 0 {
            return 0.0;
        }
        let start = self.events[0].0;
        let total = end - start;
        if total <= 0.0 {
            return 0.0;
        }
        let mut idle_area = 0.0;
        for w in self.events.windows(2) {
            let (t0, r0) = w[0];
            let (t1, _) = w[1];
            idle_area += (queue_capacity.saturating_sub(r0)) as f64 * (t1 - t0);
        }
        let (t_last, r_last) = *self.events.last().unwrap();
        if end > t_last {
            idle_area += (queue_capacity.saturating_sub(r_last)) as f64 * (end - t_last);
        }
        idle_area / (total * queue_capacity as f64)
    }

    /// Output tokens per second over [start, end].
    pub fn throughput(&self, end: f64) -> f64 {
        let (start, _) = self.span();
        let dt = end - start;
        if dt <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / dt
        }
    }

    /// Mean occupancy (running / capacity) over the recorded span.
    pub fn mean_occupancy(&self, queue_capacity: usize, end: f64) -> f64 {
        1.0 - self.bubble_ratio(queue_capacity, end)
    }

    pub fn events(&self) -> &[(f64, usize)] {
        &self.events
    }

    /// Serialize as CSV ("t,running") for plotting.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("t,running\n");
        for (t, r) in &self.events {
            s.push_str(&format!("{t},{r}\n"));
        }
        s
    }
}

/// Wall-time phase accounting for the Fig. 1a latency breakdown.
#[derive(Debug, Clone, Default)]
pub struct PhaseClock {
    pub rollout: f64,
    pub inference: f64, // reward/reference scoring
    pub update: f64,
}

impl PhaseClock {
    pub fn total(&self) -> f64 {
        self.rollout + self.inference + self.update
    }

    pub fn rollout_share(&self) -> f64 {
        if self.total() == 0.0 {
            0.0
        } else {
            self.rollout / self.total()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_occupancy_no_bubble() {
        let mut tl = Timeline::new();
        tl.set_running(0.0, 8);
        assert_eq!(tl.bubble_ratio(8, 10.0), 0.0);
    }

    #[test]
    fn empty_queue_is_all_bubble() {
        let mut tl = Timeline::new();
        tl.set_running(0.0, 0);
        assert!((tl.bubble_ratio(8, 10.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn half_occupancy_half_bubble() {
        let mut tl = Timeline::new();
        tl.set_running(0.0, 8);
        tl.set_running(5.0, 0);
        assert!((tl.bubble_ratio(8, 10.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn long_tail_drain_matches_closed_form() {
        // capacity 4; drain one request per second from t=0: r = 4,3,2,1
        let mut tl = Timeline::new();
        for i in 0..4 {
            tl.set_running(i as f64, 4 - i);
        }
        // idle area = 0+1+2+3 = 6 over T*Q = 4*4
        assert!((tl.bubble_ratio(4, 4.0) - 6.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn coalesces_equal_samples() {
        let mut tl = Timeline::new();
        tl.set_running(0.0, 4);
        tl.set_running(1.0, 4);
        tl.set_running(2.0, 2);
        assert_eq!(tl.events().len(), 2);
    }

    #[test]
    fn throughput_counts_tokens() {
        let mut tl = Timeline::new();
        tl.set_running(0.0, 1);
        tl.add_tokens(500);
        assert!((tl.throughput(2.0) - 250.0).abs() < 1e-12);
    }

    #[test]
    fn phase_clock_share() {
        let pc = PhaseClock { rollout: 7.0, inference: 1.0, update: 2.0 };
        assert!((pc.rollout_share() - 0.7).abs() < 1e-12);
    }
}
