//! Occupancy timeline + bubble ratio (paper Eq. 4) + throughput accounting.
//!
//! Both the real PJRT-backed engine and the discrete-event simulator record
//! the same [`Timeline`], so Fig. 5's bubble numbers come out of one code
//! path regardless of backend.

/// Piecewise-constant record of how many requests were actively decoding.
#[derive(Debug, Clone)]
pub struct Timeline {
    /// (time, running_requests_after_this_instant); every `stride`-th
    /// change is retained (all of them at the default stride 1).
    events: Vec<(f64, usize)>,
    tokens_out: u64,
    finished: u64,
    /// Record-time downsampling: keep every `stride`-th occupancy change.
    /// The busy-area integral stays exact regardless.
    stride: usize,
    /// Occupancy changes observed (including ones striding dropped).
    changes: u64,
    /// Latest observed (t, running), even when striding dropped it.
    last: Option<(f64, usize)>,
    /// Exact ∫ running dt over [first event, `last`].
    busy_area: f64,
}

impl Default for Timeline {
    fn default() -> Self {
        Timeline {
            events: Vec::new(),
            tokens_out: 0,
            finished: 0,
            stride: 1,
            changes: 0,
            last: None,
            busy_area: 0.0,
        }
    }
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Keep only every `stride`-th occupancy change (memory bound for
    /// million-request simulations).  Set before recording anything;
    /// `bubble_ratio` stays exact (busy area integrates every change),
    /// only the plotted `events()` curve is downsampled.
    pub fn set_stride(&mut self, stride: usize) {
        debug_assert!(self.events.is_empty() && self.last.is_none(),
                      "set_stride after recording started");
        self.stride = stride.max(1);
    }

    /// Record the running-request count changing at time `t` (seconds).
    pub fn set_running(&mut self, t: f64, running: usize) {
        if let Some((lt, lr)) = self.last {
            debug_assert!(t >= lt, "time went backwards: {t} < {lt}");
            if lr == running {
                return;
            }
            self.busy_area += lr as f64 * (t - lt);
        }
        self.last = Some((t, running));
        if self.changes % self.stride as u64 == 0 {
            self.events.push((t, running));
        }
        self.changes += 1;
    }

    pub fn add_tokens(&mut self, n: u64) {
        self.tokens_out += n;
    }

    pub fn add_finished(&mut self, n: u64) {
        self.finished += n;
    }

    pub fn tokens_out(&self) -> u64 {
        self.tokens_out
    }

    pub fn finished(&self) -> u64 {
        self.finished
    }

    pub fn span(&self) -> (f64, f64) {
        // `last` tracks the true final change even when striding dropped
        // it from `events`; at stride 1 they coincide
        match (self.events.first(), self.last) {
            (Some(&(a, _)), Some((b, _))) => (a, b),
            _ => (0.0, 0.0),
        }
    }

    /// Paper Eq. 4: bubble = Σ_k (Q − r_k)·Δt_k / (T·Q), where Q is the
    /// engine's running-queue capacity, r_k the running requests during
    /// interval k, T the total elapsed time.  `end` closes the last
    /// interval (generation finished / harvest time).
    pub fn bubble_ratio(&self, queue_capacity: usize, end: f64) -> f64 {
        if self.events.is_empty() || queue_capacity == 0 {
            return 0.0;
        }
        let start = self.events[0].0;
        let total = end - start;
        if total <= 0.0 {
            return 0.0;
        }
        if self.stride <= 1 {
            // exact interval walk over the full event list
            let mut idle_area = 0.0;
            for w in self.events.windows(2) {
                let (t0, r0) = w[0];
                let (t1, _) = w[1];
                idle_area += (queue_capacity.saturating_sub(r0)) as f64 * (t1 - t0);
            }
            let (t_last, r_last) = *self.events.last().unwrap();
            if end > t_last {
                idle_area += (queue_capacity.saturating_sub(r_last)) as f64 * (end - t_last);
            }
            return idle_area / (total * queue_capacity as f64);
        }
        // strided: `events` is lossy but `busy_area` integrated every
        // change, so idle = capacity-area minus exact busy area
        let (t_last, r_last) = self.last.expect("events non-empty implies last");
        let mut busy = self.busy_area;
        if end > t_last {
            busy += r_last as f64 * (end - t_last);
        }
        let cap_area = total * queue_capacity as f64;
        ((cap_area - busy) / cap_area).clamp(0.0, 1.0)
    }

    /// Output tokens per second over [start, end].
    pub fn throughput(&self, end: f64) -> f64 {
        let (start, _) = self.span();
        let dt = end - start;
        if dt <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / dt
        }
    }

    /// Mean occupancy (running / capacity) over the recorded span.
    pub fn mean_occupancy(&self, queue_capacity: usize, end: f64) -> f64 {
        1.0 - self.bubble_ratio(queue_capacity, end)
    }

    pub fn events(&self) -> &[(f64, usize)] {
        &self.events
    }

    /// Serialize as CSV ("t,running") for plotting — the shared
    /// [`crate::trace::series`] export path.
    pub fn to_csv(&self) -> String {
        crate::trace::series::to_csv("t,running", &self.events)
    }
}

/// Online accuracy telemetry for a `sched::LengthPredictor`: mean absolute
/// error in tokens (over every observation) plus Kendall rank correlation
/// (tau-a) over a bounded sliding window of (predicted, actual) pairs.
///
/// Rank quality is the headline number — shortest-predicted-first dispatch
/// only needs the *order* of lengths to be right, so a rank-only predictor
/// (e.g. `Bucket`) can score tau close to 1 while its MAE is meaningless.
#[derive(Debug, Clone)]
pub struct PredictorScore {
    window: Vec<(f64, f64)>,
    cap: usize,
    cursor: usize,
    n: u64,
    abs_err: f64,
    /// Memoized [`Self::kendall_tau`] — the tau scan is O(window²), and
    /// telemetry polls it per tick; `push` invalidates.  `Cell` because
    /// every caller holds `&self` through the backend.
    tau_cache: std::cell::Cell<Option<f64>>,
}

impl Default for PredictorScore {
    fn default() -> Self {
        Self::new(512)
    }
}

impl PredictorScore {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 2);
        PredictorScore {
            window: Vec::new(),
            cap,
            cursor: 0,
            n: 0,
            abs_err: 0.0,
            tau_cache: std::cell::Cell::new(None),
        }
    }

    /// Record one (prediction, ground truth) pair. Call with the prediction
    /// made *before* the truth was observed.
    pub fn push(&mut self, predicted: f64, actual: f64) {
        self.n += 1;
        self.abs_err += (predicted - actual).abs();
        self.tau_cache.set(None);
        if self.window.len() < self.cap {
            self.window.push((predicted, actual));
        } else {
            self.window[self.cursor] = (predicted, actual);
            self.cursor = (self.cursor + 1) % self.cap;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean absolute error over every pair ever pushed.
    pub fn mae(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.abs_err / self.n as f64
        }
    }

    /// Kendall tau-a over the window: (concordant - discordant) / all pairs.
    /// 1.0 = perfect ranking, 0.0 = uninformative, -1.0 = anti-ranking.
    /// Memoized between pushes (the scan is O(window²)).
    pub fn kendall_tau(&self) -> f64 {
        if let Some(tau) = self.tau_cache.get() {
            return tau;
        }
        let tau = self.kendall_tau_uncached();
        self.tau_cache.set(Some(tau));
        tau
    }

    /// Knight's O(n log n) tau-a: sort by (p, a), count strict inversions
    /// of the a-sequence with a counting merge sort (= discordant pairs;
    /// within an equal-p group a ascends, so those pairs contribute none),
    /// then C − D = total − ties − 2D by inclusion-exclusion over tied
    /// pairs.  The integer count equals the old O(n²) pair scan's exactly
    /// (same classification for finite token-scale values, where the
    /// naive product (pᵢ−pⱼ)(aᵢ−aⱼ) cannot underflow to 0), so the final
    /// division is bit-identical to the values it replaced.
    fn kendall_tau_uncached(&self) -> f64 {
        let w = &self.window;
        if w.len() < 2 {
            return 0.0;
        }
        let n = w.len() as i64;
        let total = n * (n - 1) / 2;
        let mut pairs: Vec<(f64, f64)> = w.clone();
        pairs.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.total_cmp(&y.1)));
        let mut ties_p = 0i64;
        let mut ties_pa = 0i64;
        let (mut run_p, mut run_pa) = (1i64, 1i64);
        for pw in pairs.windows(2) {
            if pw[0].0.total_cmp(&pw[1].0).is_eq() {
                run_p += 1;
                if pw[0].1.total_cmp(&pw[1].1).is_eq() {
                    run_pa += 1;
                } else {
                    ties_pa += run_pa * (run_pa - 1) / 2;
                    run_pa = 1;
                }
            } else {
                ties_p += run_p * (run_p - 1) / 2;
                run_p = 1;
                ties_pa += run_pa * (run_pa - 1) / 2;
                run_pa = 1;
            }
        }
        ties_p += run_p * (run_p - 1) / 2;
        ties_pa += run_pa * (run_pa - 1) / 2;
        let mut a: Vec<f64> = pairs.iter().map(|&(_, a)| a).collect();
        let discordant = count_inversions(&mut a);
        // `a` is now sorted: tie runs are adjacent
        let mut ties_a = 0i64;
        let mut run_a = 1i64;
        for aw in a.windows(2) {
            if aw[0].total_cmp(&aw[1]).is_eq() {
                run_a += 1;
            } else {
                ties_a += run_a * (run_a - 1) / 2;
                run_a = 1;
            }
        }
        ties_a += run_a * (run_a - 1) / 2;
        let ties = ties_p + ties_a - ties_pa;
        (total - ties - 2 * discordant) as f64 / total as f64
    }
}

/// Count strict inversions (i < j with a[i] > a[j]) while merge-sorting
/// `a` ascending in place.
fn count_inversions(a: &mut [f64]) -> i64 {
    let mut buf = a.to_vec();
    sort_count(a, &mut buf)
}

fn sort_count(a: &mut [f64], buf: &mut [f64]) -> i64 {
    let n = a.len();
    if n < 2 {
        return 0;
    }
    let mid = n / 2;
    let mut inv = {
        let (l, r) = a.split_at_mut(mid);
        let (bl, br) = buf.split_at_mut(mid);
        sort_count(l, bl) + sort_count(r, br)
    };
    buf[..n].copy_from_slice(a);
    let (l, r) = buf[..n].split_at(mid);
    let (mut i, mut j) = (0usize, 0usize);
    for slot in a.iter_mut() {
        // ties take the left element: only STRICT descents count
        if i < l.len() && (j >= r.len() || l[i].total_cmp(&r[j]).is_le()) {
            *slot = l[i];
            i += 1;
        } else {
            if i < l.len() {
                inv += (l.len() - i) as i64;
            }
            *slot = r[j];
            j += 1;
        }
    }
    inv
}

/// Paper Eq. 4 aggregate bubble: idle capacity-time over TOTAL
/// capacity-time, both in lane-seconds.  This is the fraction-of-total
/// definition the paper reports (NOT an idle-to-busy odds ratio): a pool of
/// Q lanes observed for T seconds has `capacity_area = Q*T`, and
/// `idle_area` is the part of that area with no request decoding.  The
/// controller aggregates both areas across engines and groups and divides
/// once, so engines with different spans weight by their capacity-time.
pub fn bubble_fraction(idle_area: f64, capacity_area: f64) -> f64 {
    if capacity_area <= 0.0 {
        0.0
    } else {
        (idle_area / capacity_area).clamp(0.0, 1.0)
    }
}

/// Wall-time phase accounting for the Fig. 1a latency breakdown.
#[derive(Debug, Clone, Default)]
pub struct PhaseClock {
    pub rollout: f64,
    pub inference: f64, // reward/reference scoring
    pub update: f64,
}

impl PhaseClock {
    pub fn total(&self) -> f64 {
        self.rollout + self.inference + self.update
    }

    pub fn rollout_share(&self) -> f64 {
        if self.total() == 0.0 {
            0.0
        } else {
            self.rollout / self.total()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_occupancy_no_bubble() {
        let mut tl = Timeline::new();
        tl.set_running(0.0, 8);
        assert_eq!(tl.bubble_ratio(8, 10.0), 0.0);
    }

    #[test]
    fn empty_queue_is_all_bubble() {
        let mut tl = Timeline::new();
        tl.set_running(0.0, 0);
        assert!((tl.bubble_ratio(8, 10.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn half_occupancy_half_bubble() {
        let mut tl = Timeline::new();
        tl.set_running(0.0, 8);
        tl.set_running(5.0, 0);
        assert!((tl.bubble_ratio(8, 10.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn long_tail_drain_matches_closed_form() {
        // capacity 4; drain one request per second from t=0: r = 4,3,2,1
        let mut tl = Timeline::new();
        for i in 0..4 {
            tl.set_running(i as f64, 4 - i);
        }
        // idle area = 0+1+2+3 = 6 over T*Q = 4*4
        assert!((tl.bubble_ratio(4, 4.0) - 6.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn coalesces_equal_samples() {
        let mut tl = Timeline::new();
        tl.set_running(0.0, 4);
        tl.set_running(1.0, 4);
        tl.set_running(2.0, 2);
        assert_eq!(tl.events().len(), 2);
    }

    #[test]
    fn throughput_counts_tokens() {
        let mut tl = Timeline::new();
        tl.set_running(0.0, 1);
        tl.add_tokens(500);
        assert!((tl.throughput(2.0) - 250.0).abs() < 1e-12);
    }

    /// Hand-computed Eq. 4 case: 4 lanes over 10 s = 40 lane-seconds of
    /// capacity; one lane idles for 6 s -> bubble = 6/40 = 0.15 of TOTAL
    /// capacity-time (the idle-to-busy odds ratio would be 6/34 ≈ 0.176 —
    /// pinning 0.15 here is what fixes the definition to the paper's).
    #[test]
    fn bubble_fraction_is_idle_over_total() {
        assert!((bubble_fraction(6.0, 40.0) - 0.15).abs() < 1e-12);
        // degenerate inputs stay safe and in range
        assert_eq!(bubble_fraction(3.0, 0.0), 0.0);
        assert_eq!(bubble_fraction(-1.0, 10.0), 0.0);
        assert_eq!(bubble_fraction(99.0, 10.0), 1.0);
        // consistency with Timeline::bubble_ratio on the drain case above:
        // idle area 6 over capacity 4*4=16 -> 0.375
        let mut tl = Timeline::new();
        for i in 0..4 {
            tl.set_running(i as f64, 4 - i);
        }
        assert!((tl.bubble_ratio(4, 4.0) - bubble_fraction(6.0, 16.0)).abs() < 1e-12);
    }

    #[test]
    fn phase_clock_share() {
        let pc = PhaseClock { rollout: 7.0, inference: 1.0, update: 2.0 };
        assert!((pc.rollout_share() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn predictor_score_perfect_rank() {
        let mut s = PredictorScore::new(16);
        for x in [10.0, 40.0, 20.0, 90.0, 5.0] {
            s.push(x, x * 2.0); // monotone map: perfect rank, nonzero MAE
        }
        assert!((s.kendall_tau() - 1.0).abs() < 1e-12);
        assert!(s.mae() > 0.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn predictor_score_anti_rank() {
        let mut s = PredictorScore::new(16);
        for (p, a) in [(1.0, 9.0), (2.0, 8.0), (3.0, 7.0), (4.0, 6.0)] {
            s.push(p, a);
        }
        assert!((s.kendall_tau() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_tau_cache_invalidated_on_push() {
        let mut s = PredictorScore::new(8);
        s.push(1.0, 1.0);
        s.push(2.0, 2.0);
        let first = s.kendall_tau();
        assert!((first - 1.0).abs() < 1e-12);
        // repeated polls hit the memo and agree with a fresh scan
        assert_eq!(s.kendall_tau(), s.kendall_tau_uncached());
        // a discordant push must invalidate, not replay the memo
        s.push(3.0, 0.0);
        let after = s.kendall_tau();
        assert!(after < first);
        assert_eq!(after, s.kendall_tau_uncached());
    }

    #[test]
    fn predictor_score_window_is_bounded() {
        let mut s = PredictorScore::new(4);
        for i in 0..100 {
            s.push(i as f64, i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.kendall_tau() - 1.0).abs() < 1e-12);
        assert!(s.mae() < 1e-12);
    }

    /// The O(n²) pair scan Knight's algorithm replaced, kept verbatim as
    /// the pinning oracle.
    fn naive_tau(w: &[(f64, f64)]) -> f64 {
        if w.len() < 2 {
            return 0.0;
        }
        let mut concordant = 0i64;
        let mut discordant = 0i64;
        let mut total = 0i64;
        for i in 0..w.len() {
            for j in i + 1..w.len() {
                total += 1;
                let s = (w[i].0 - w[j].0) * (w[i].1 - w[j].1);
                if s > 0.0 {
                    concordant += 1;
                } else if s < 0.0 {
                    discordant += 1;
                }
            }
        }
        (concordant - discordant) as f64 / total as f64
    }

    #[test]
    fn knight_tau_matches_old_pair_scan_bitwise() {
        // structured tie patterns: p-ties, a-ties, joint ties, constants
        let cases: Vec<Vec<(f64, f64)>> = vec![
            vec![(1.0, 2.0), (1.0, 3.0), (2.0, 1.0)],
            vec![(1.0, 5.0), (2.0, 5.0), (3.0, 5.0), (4.0, 2.0)],
            vec![(3.0, 3.0), (3.0, 3.0), (3.0, 3.0)],
            vec![(9.0, 1.0), (8.0, 2.0), (7.0, 3.0), (7.0, 3.0), (6.0, 9.0)],
            vec![(1.0, 1.0), (2.0, 2.0)],
        ];
        for (i, case) in cases.iter().enumerate() {
            let mut s = PredictorScore::new(16);
            for &(p, a) in case {
                s.push(p, a);
            }
            assert_eq!(
                s.kendall_tau_uncached().to_bits(),
                naive_tau(case).to_bits(),
                "case {i}"
            );
        }
        // randomized integer-valued (token-scale) windows, heavy on ties
        let mut rng = crate::util::rng::Pcg64::with_stream(0xC0FFEE, 7);
        for trial in 0..60 {
            let n = 2 + rng.below(60) as usize;
            let mut s = PredictorScore::new(64);
            for _ in 0..n {
                let p = rng.below(24) as f64 * 8.0;
                let a = rng.below(24) as f64 * 4.0;
                s.push(p, a);
            }
            assert_eq!(
                s.kendall_tau_uncached().to_bits(),
                naive_tau(&s.window).to_bits(),
                "trial {trial}"
            );
        }
    }

    #[test]
    fn count_inversions_counts_strictly() {
        let mut a = vec![3.0, 1.0, 2.0, 2.0, 0.0];
        // pairs (3,1)(3,2)(3,2)(3,0)(1,0)(2,0)(2,0) -> 7; the (2,2) tie
        // does not count
        assert_eq!(count_inversions(&mut a), 7);
        assert_eq!(a, vec![0.0, 1.0, 2.0, 2.0, 3.0]);
        let mut sorted = vec![1.0, 2.0, 3.0];
        assert_eq!(count_inversions(&mut sorted), 0);
        let mut rev: Vec<f64> = (0..10).rev().map(|x| x as f64).collect();
        assert_eq!(count_inversions(&mut rev), 45);
    }

    #[test]
    fn strided_timeline_keeps_exact_bubble_and_span() {
        // capacity 4, one change per second: r cycles 4,3,2,1,4,3,2,1,...
        let mut exact = Timeline::new();
        let mut strided = Timeline::new();
        strided.set_stride(7);
        for i in 0..1000 {
            let r = 4 - (i % 4);
            exact.set_running(i as f64, r);
            strided.set_running(i as f64, r);
        }
        let end = 1000.0;
        let b_exact = exact.bubble_ratio(4, end);
        let b_strided = strided.bubble_ratio(4, end);
        // busy-area integration makes the strided bubble exact, not
        // approximate, even though 6/7 of the points were dropped
        assert!((b_exact - b_strided).abs() < 1e-12,
                "exact {b_exact} strided {b_strided}");
        assert!(strided.events().len() < exact.events().len() / 6);
        assert_eq!(exact.span(), (0.0, 999.0));
        assert_eq!(strided.span(), (0.0, 999.0));
    }

    #[test]
    fn stride_one_is_lossless() {
        let mut tl = Timeline::new();
        tl.set_stride(1);
        tl.set_running(0.0, 2);
        tl.set_running(1.0, 2); // coalesced
        tl.set_running(2.0, 1);
        tl.set_running(3.0, 0);
        assert_eq!(tl.events(), &[(0.0, 2), (2.0, 1), (3.0, 0)]);
        assert_eq!(tl.span(), (0.0, 3.0));
        // interval walk: idle = (4-2)*2 + (4-1)*1 + (4-0)*3 = 19 over 24
        assert!((tl.bubble_ratio(4, 6.0) - 19.0 / 24.0).abs() < 1e-12);
    }
}
