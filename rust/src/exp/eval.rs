//! Sampled evaluation (mean@k) used by the Table-1 harness: the paper
//! collects 32 responses per competition problem and reports mean accuracy.

use crate::rollout::{Engine, EngineConfig, Request};
use crate::runtime::{ParamState, Runtime};
use crate::tasks::{Problem, Task};
use anyhow::Result;

#[derive(Debug, Clone, Copy, Default)]
pub struct SampledEval {
    pub accuracy: f64,
    pub score: f64,
    pub format_rate: f64,
    pub mean_resp_len: f64,
    pub n: usize,
}

/// mean@k over `problems` at the given temperature (k=1, temp=0 == greedy).
pub fn evaluate_sampled(rt: &Runtime, state: &ParamState, task: &dyn Task,
                        problems: &[&Problem], k: usize, temperature: f32,
                        max_new: usize, seed: u64) -> Result<SampledEval> {
    let greedy = temperature <= 0.0;
    let mut engine = Engine::new(rt, EngineConfig {
        temperature: if greedy { 1.0 } else { temperature },
        greedy,
        seed,
        ..EngineConfig::default()
    });
    let mut rid = 0u64;
    for (pi, p) in problems.iter().enumerate() {
        for _ in 0..k {
            engine.submit([Request::fresh(rid, pi, p.id, p.prompt.clone(), max_new)]);
            rid += 1;
        }
    }
    let rollouts = engine.run_to_completion(state)?;
    let mut acc = 0.0;
    let mut score = 0.0;
    let mut fmt = 0.0;
    let mut len = 0.0;
    for r in &rollouts {
        let p = problems[r.request.problem_idx];
        let reward = task.verify(p, &r.response);
        acc += reward.correct as u8 as f64;
        score += reward.total() / crate::tasks::Reward::MAX;
        fmt += reward.format_ok as u8 as f64;
        len += r.response.len() as f64;
    }
    let n = rollouts.len().max(1) as f64;
    Ok(SampledEval {
        accuracy: acc / n,
        score: score / n,
        format_rate: fmt / n,
        mean_resp_len: len / n,
        n: rollouts.len(),
    })
}
