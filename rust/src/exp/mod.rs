//! Experiment harness: one entry per table/figure of the paper (§4).
//!
//! `sortedrl exp <id>` regenerates the rows/series the paper reports.
//! Simulator-backed experiments (fig1a/fig1b/fig5/pool) run at paper scale;
//! real-training experiments (fig3/fig4/fig6/fig9/tab1) run the full
//! three-layer stack on the synthetic task substrates at a configurable
//! scale (see DESIGN.md §Substitutions).  Results print as tables and are
//! also written as JSON under `results/`.

pub mod eval;
pub mod fig1;
pub mod fig5;
pub mod suites;

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Shared experiment context.
pub struct ExpContext {
    pub artifacts_dir: PathBuf,
    pub tag: Option<String>,
    pub out_dir: PathBuf,
    /// Scale multiplier for real-training experiments: "ci" (minutes),
    /// "small" (default, ~1h for the full set), "paper" (structural match
    /// of the paper's batch geometry; long).
    pub scale: Scale,
    pub seed: u64,
    /// `--arrival` override for the open-loop section of `exp pool`;
    /// `None` uses the suite's synthetic multi-tenant trace.
    pub arrival: Option<crate::workload::ArrivalSpec>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Ci,
    Small,
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "ci" => Scale::Ci,
            "small" => Scale::Small,
            "paper" => Scale::Paper,
            _ => return None,
        })
    }
}

impl ExpContext {
    pub fn write_json(&self, name: &str, value: &Json) -> anyhow::Result<PathBuf> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = self.out_dir.join(format!("{name}.json"));
        std::fs::write(&path, value.to_string_pretty())?;
        eprintln!("  wrote {}", path.display());
        Ok(path)
    }

    pub fn write_csv(&self, name: &str, content: &str) -> anyhow::Result<PathBuf> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = self.out_dir.join(format!("{name}.csv"));
        std::fs::write(&path, content)?;
        eprintln!("  wrote {}", path.display());
        Ok(path)
    }
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext {
            artifacts_dir: Path::new("artifacts").to_path_buf(),
            tag: None,
            out_dir: Path::new("results").to_path_buf(),
            scale: Scale::Small,
            seed: 0,
            arrival: None,
        }
    }
}

/// Render a simple aligned table to stdout.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{c:>w$}", w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", line(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
    for row in rows {
        println!("{}", line(row));
    }
}
