//! Real-training experiment suites (full three-layer stack).
//!
//!   logic_suite -> Fig. 3 (a/b) + Fig. 9a        (LogicRL, Reinforce++)
//!   fig6a       -> ablations: no-grouped, post-hoc sort
//!   fig6b       -> group-size sensitivity n ∈ {2, 4, 8, big}
//!   math_suite  -> Fig. 4 + Table 1 + Fig. 9b    (math chains)
//!   pool_suite  -> engine-pool scaling (simulator-backed, no artifacts):
//!                  1..8 engines x dispatch policy x length predictor
//!
//! All runs share one SFT warm start per task (stands in for the paper's
//! pretrained instruct checkpoints) so scheduler comparisons start from an
//! identical policy.

use super::eval::evaluate_sampled;
use super::{print_table, ExpContext, Scale};
use crate::coordinator::{sft_warm_start, Controller, LoopConfig, SchedulerKind};
use crate::data::Dataset;
use crate::rl::advantage::AdvantageKind;
use crate::runtime::{ParamState, Runtime};
use crate::tasks::logic::LogicTask;
use crate::tasks::math::MathTask;
use crate::tasks::Task;
use crate::util::json::{arr, num, obj, s, Json};
use anyhow::Result;

/// Scale-dependent knobs for the training experiments.
#[derive(Debug, Clone)]
pub struct TrainScale {
    pub per_difficulty: usize,
    pub sft_steps: usize,
    pub max_updates: usize,
    pub rollout_prompts: usize,
    pub group_size: usize,
    pub samples_per_prompt: usize,
    pub update_batch: usize,
    pub eval_every: usize,
    pub eval_limit: usize,
    pub max_new: usize,
    pub lr_sft: f32,
    pub lr_rl: f32,
}

pub fn train_scale(scale: Scale) -> TrainScale {
    match scale {
        Scale::Ci => TrainScale {
            per_difficulty: 8,
            sft_steps: 12,
            max_updates: 4,
            rollout_prompts: 2,
            group_size: 2,
            samples_per_prompt: 2,
            update_batch: 8,
            eval_every: 0,
            eval_limit: 8,
            max_new: 64,
            lr_sft: 3e-3,
            lr_rl: 1e-3,
        },
        // sized for a single-core CPU PJRT box: ~1-2 min per training run
        Scale::Small => TrainScale {
            per_difficulty: 40,
            sft_steps: 120,
            max_updates: 24,
            rollout_prompts: 4,
            group_size: 4,
            samples_per_prompt: 2,
            update_batch: 16,
            eval_every: 6,
            eval_limit: 24,
            max_new: 150,
            lr_sft: 2e-3,
            lr_rl: 4e-4,
        },
        // Structural match of the paper's geometry (128-prompt rollout
        // batches, group 4, 1024-trajectory updates) — hours on CPU.
        Scale::Paper => TrainScale {
            per_difficulty: 1000,
            sft_steps: 400,
            max_updates: 600,
            rollout_prompts: 16,
            group_size: 4,
            samples_per_prompt: 8,
            update_batch: 128,
            eval_every: 20,
            eval_limit: 128,
            max_new: 176,
            lr_sft: 2e-3,
            lr_rl: 3e-4,
        },
    }
}

pub fn clone_state(state: &ParamState) -> ParamState {
    state.clone()
}

/// Staleness histogram as a JSON object keyed by version delta.
fn staleness_json(h: &std::collections::BTreeMap<u64, u64>) -> Json {
    Json::Obj(h.iter().map(|(&d, &n)| (d.to_string(), num(n as f64))).collect())
}

fn loop_config(ts: &TrainScale, scheduler: SchedulerKind, seed: u64) -> LoopConfig {
    LoopConfig {
        scheduler,
        rollout_prompts: ts.rollout_prompts,
        group_size: ts.group_size,
        samples_per_prompt: ts.samples_per_prompt,
        update_batch: ts.update_batch,
        max_updates: ts.max_updates,
        lr: ts.lr_rl,
        temperature: 1.0,
        seed,
        adv: AdvantageKind::ReinforcePlusPlus,
        max_new: ts.max_new,
        eval_every: ts.eval_every,
        eval_limit: ts.eval_limit,
        verbose: true,
        ..LoopConfig::default()
    }
}

fn make_task(name: &str) -> Box<dyn Task> {
    match name {
        "logic" => Box::new(LogicTask::default()),
        "math" => Box::new(MathTask),
        _ => unreachable!(),
    }
}

/// SFT warm start on the train split (shared across schedulers).
pub fn warm_start(rt: &Runtime, task_name: &str, ts: &TrainScale, seed: u64)
                  -> Result<(ParamState, Dataset)> {
    let task = make_task(task_name);
    let ds = Dataset::generate(task.as_ref(), ts.per_difficulty, 0.1, seed);
    let mut state = rt.init(seed as i32)?;
    let problems: Vec<&crate::tasks::Problem> = ds.train.iter().collect();
    eprintln!("[warm start] {} sft steps on {} problems", ts.sft_steps, problems.len());
    let losses = sft_warm_start(rt, &mut state, &problems, ts.sft_steps, ts.lr_sft, 20)?;
    eprintln!("[warm start] sft loss {:.3} -> {:.3}",
              losses.first().unwrap_or(&0.0), losses.last().unwrap_or(&0.0));
    Ok((state, ds))
}

/// Run one scheduler from a shared warm state; returns (rows-json, summary,
/// final state).
pub fn run_one(rt: &Runtime, task_name: &str, ds_seed: u64, ts: &TrainScale,
               warm: &ParamState, scheduler: SchedulerKind, seed: u64)
               -> Result<(Json, Json, ParamState, crate::coordinator::RunResult)> {
    let task = make_task(task_name);
    let ds = Dataset::generate(task.as_ref(), ts.per_difficulty, 0.1, ds_seed);
    let mut state = clone_state(warm);
    let mut ctl = Controller::new(rt, task, ds, loop_config(ts, scheduler, seed));
    eprintln!("[{}] starting ({} updates)...", scheduler.name(), ts.max_updates);
    let t0 = std::time::Instant::now();
    let result = ctl.run(&mut state)?;
    eprintln!("[{}] done in {:.1}s; final eval score {:.3} acc {:.3}",
              scheduler.name(), t0.elapsed().as_secs_f64(),
              result.final_eval.score, result.final_eval.accuracy);
    let rows = arr(result.rows.iter().map(|r| {
        let mut o = vec![
            ("update", num(r.update.update_idx as f64)),
            ("epochs", num(r.epochs)),
            ("mean_reward", num(r.update.mean_reward)),
            ("accuracy", num(r.update.accuracy)),
            ("format_rate", num(r.update.format_rate)),
            ("mean_resp_len", num(r.update.mean_resp_len)),
            ("mean_staleness", num(r.update.mean_staleness)),
            ("kl", num(r.update.stats.approx_kl as f64)),
            ("loss", num(r.update.stats.loss as f64)),
            ("rollout_tokens", num(r.rollout_tokens as f64)),
        ];
        if let Some(e) = r.eval {
            o.push(("eval_score", num(e.score)));
            o.push(("eval_acc", num(e.accuracy)));
            o.push(("eval_len", num(e.mean_resp_len)));
        }
        obj(o)
    }));
    let summary = obj(vec![
        ("scheduler", s(scheduler.name())),
        ("final_score", num(result.final_eval.score)),
        ("final_accuracy", num(result.final_eval.accuracy)),
        ("final_resp_len", num(result.final_eval.mean_resp_len)),
        ("bubble_ratio", num(result.bubble_ratio)),
        ("rollout_tokens", num(result.total_rollout_tokens as f64)),
        ("rollout_secs", num(result.phase_clock.rollout)),
        ("update_secs", num(result.phase_clock.update)),
        ("discarded", num(result.discarded as f64)),
        ("stale_resyncs", num(result.stale_resyncs as f64)),
        ("max_staleness", num(result.max_staleness as f64)),
        ("staleness_hist", staleness_json(&result.staleness_hist)),
    ]);
    Ok((rows, summary, state, result))
}

/// Fig. 3 (+ Fig. 9a data): LogicRL with baseline / on-policy / partial.
pub fn logic_suite(ctx: &ExpContext, rt: &Runtime) -> Result<()> {
    println!("== Fig 3: LogicRL training — baseline vs SortedRL modes ==\n");
    let ts = train_scale(ctx.scale);
    let (warm, _ds) = warm_start(rt, "logic", &ts, ctx.seed + 31)?;
    let mut summaries = Vec::new();
    let mut all = Vec::new();
    for sched in [SchedulerKind::Baseline, SchedulerKind::SortedOnPolicy,
                  SchedulerKind::SortedPartial, SchedulerKind::AsyncUpdate] {
        let (rows, summary, _state, result) =
            run_one(rt, "logic", ctx.seed + 31, &ts, &warm, sched, ctx.seed + 32)?;
        // Fig 9a: per-update (length, reward) trace shows the
        // short-short-long micro-curriculum pattern
        all.push(obj(vec![
            ("scheduler", s(sched.name())),
            ("rows", rows),
        ]));
        summaries.push((sched.name().to_string(), summary, result));
    }
    ctx.write_json("fig3_curves", &arr(all))?;

    let mut table = Vec::new();
    let mut js = Vec::new();
    for (name, summary, result) in &summaries {
        table.push(vec![
            name.clone(),
            format!("{:.3}", result.final_eval.score),
            format!("{:.3}", result.final_eval.accuracy),
            format!("{:.1}", result.final_eval.mean_resp_len),
            format!("{:.1}%", result.bubble_ratio * 100.0),
            format!("{}", result.total_rollout_tokens),
        ]);
        js.push(summary.clone());
    }
    print_table(&["scheduler", "val score", "accuracy", "resp len", "bubble",
                  "rollout tokens"], &table);
    println!("\npaper shape: on-policy reaches a given score with fewer samples \
              than baseline;\npartial sits between; async matches partial's \
              bubble with updates overlapped; ablation collapse is fig6a");
    ctx.write_json("fig3_summary", &arr(js))?;
    fig9a_from_curves(ctx)?;
    Ok(())
}

/// Fig. 9a: close-up of two consecutive groups — batch mean length + reward
/// exhibit the short-short-long micro-curriculum pattern.
fn fig9a_from_curves(ctx: &ExpContext) -> Result<()> {
    let path = ctx.out_dir.join("fig3_curves.json");
    let Ok(text) = std::fs::read_to_string(&path) else { return Ok(()) };
    let j = Json::parse(&text)?;
    println!("\n== Fig 9a: micro-curriculum close-up (on-policy run) ==");
    if let Some(runs) = j.as_arr() {
        for run in runs {
            if run.get("scheduler").and_then(Json::as_str) == Some("sorted-on-policy") {
                let rows = run.get("rows").and_then(Json::as_arr).unwrap_or(&[]);
                println!("update | mean resp len | mean reward");
                for r in rows.iter().take(16) {
                    let len = r.get("mean_resp_len").and_then(Json::as_f64).unwrap_or(0.0);
                    let rew = r.get("mean_reward").and_then(Json::as_f64).unwrap_or(0.0);
                    let bar = "#".repeat((len / 4.0) as usize);
                    println!("{:>6} | {:>7.1} {bar:<40} | {:+.2}",
                             r.get("update").and_then(Json::as_f64).unwrap_or(0.0), len, rew);
                }
            }
        }
    }
    println!("(expect: length ramps up within each group, resetting at group \
              boundaries — the short-short-long pattern)");
    Ok(())
}

/// Fig. 6a: ablations — no grouped rollout, post-hoc sort.
pub fn fig6a(ctx: &ExpContext, rt: &Runtime) -> Result<()> {
    println!("== Fig 6a: ablations (LogicRL) ==\n");
    let ts = train_scale(ctx.scale);
    let (warm, _ds) = warm_start(rt, "logic", &ts, ctx.seed + 61)?;
    let mut table = Vec::new();
    let mut js = Vec::new();
    for sched in [SchedulerKind::SortedOnPolicy, SchedulerKind::NoGroupedRollout,
                  SchedulerKind::PostHocSort] {
        let (rows, summary, _state, result) =
            run_one(rt, "logic", ctx.seed + 61, &ts, &warm, sched, ctx.seed + 62)?;
        table.push(vec![
            sched.name().to_string(),
            format!("{:.3}", result.final_eval.score),
            format!("{:.3}", result.final_eval.accuracy),
            format!("{:.1}", result.final_eval.mean_resp_len),
            format!("{}", result.discarded),
        ]);
        js.push(obj(vec![
            ("scheduler", s(sched.name())),
            ("summary", summary),
            ("rows", rows),
        ]));
    }
    print_table(&["variant", "val score", "accuracy", "resp len", "discarded"],
                &table);
    println!("\npaper shape: no-grouped caps early (short-response bias); \
              post-hoc sort lags on-policy (off-policiness)");
    ctx.write_json("fig6a", &arr(js))?;
    Ok(())
}

/// Fig. 6b: group-size sensitivity (n = 2, 4, 8, and effectively-infinite).
pub fn fig6b(ctx: &ExpContext, rt: &Runtime) -> Result<()> {
    println!("== Fig 6b: group size sensitivity (LogicRL, on-policy) ==\n");
    let ts = train_scale(ctx.scale);
    let (warm, _ds) = warm_start(rt, "logic", &ts, ctx.seed + 63)?;
    let mut table = Vec::new();
    let mut js = Vec::new();
    for n in [2usize, 4, 8, 32] {
        let mut ts_n = ts.clone();
        ts_n.group_size = n;
        let (rows, summary, _state, result) = run_one(
            rt, "logic", ctx.seed + 63, &ts_n, &warm,
            SchedulerKind::SortedOnPolicy, ctx.seed + 64)?;
        table.push(vec![
            format!("n={n}"),
            format!("{:.3}", result.final_eval.score),
            format!("{:.3}", result.final_eval.accuracy),
            format!("{:.1}", result.final_eval.mean_resp_len),
        ]);
        js.push(obj(vec![
            ("group_size", num(n as f64)),
            ("summary", summary),
            ("rows", rows),
        ]));
    }
    print_table(&["group size", "val score", "accuracy", "resp len"], &table);
    println!("\npaper shape: very large n degrades (short-only batches); \
              n=2 behaves like baseline; n=4 best");
    ctx.write_json("fig6b", &arr(js))?;
    Ok(())
}

/// Fig. 4 + Table 1 + Fig. 9b: the math suite.
pub fn math_suite(ctx: &ExpContext, rt: &Runtime) -> Result<()> {
    println!("== Fig 4 / Table 1: math training — baseline vs SortedRL ==\n");
    let ts = train_scale(ctx.scale);
    let (warm, _ds) = warm_start(rt, "math", &ts, ctx.seed + 41)?;
    let mut finals: Vec<(String, ParamState)> = Vec::new();
    let mut all = Vec::new();
    let mut table = Vec::new();
    for sched in [SchedulerKind::Baseline, SchedulerKind::SortedOnPolicy,
                  SchedulerKind::SortedPartial] {
        let (rows, summary, state, result) =
            run_one(rt, "math", ctx.seed + 41, &ts, &warm, sched, ctx.seed + 42)?;
        all.push(obj(vec![("scheduler", s(sched.name())), ("rows", rows),
                          ("summary", summary)]));
        table.push(vec![
            sched.name().to_string(),
            format!("{:.3}", result.final_eval.score),
            format!("{:.3}", result.final_eval.accuracy),
            format!("{:.1}", result.final_eval.mean_resp_len),
            format!("{:.1}%", result.bubble_ratio * 100.0),
        ]);
        finals.push((sched.name().to_string(), state));
    }
    print_table(&["scheduler", "val score", "accuracy", "resp len", "bubble"],
                &table);
    ctx.write_json("fig4_curves", &arr(all))?;

    // ---------------- Table 1: per-stratum benchmark analogues -----------
    println!("\n== Table 1: benchmark-analogue evaluation at final checkpoint ==");
    println!("   (difficulty strata of the math eval split stand in for the");
    println!("    paper's 6 benchmarks — see DESIGN.md §Substitutions)\n");
    let task = MathTask;
    let ds = Dataset::generate(&task, ts.per_difficulty, 0.1, ctx.seed + 41);
    let strata = ds.eval_by_difficulty();
    // benchmark analogue -> (difficulties, k for mean@k)
    let benches: Vec<(&str, Vec<u32>, usize)> = vec![
        ("GSM8K~d2", vec![2], 1),
        ("MATH500~d3", vec![3], 1),
        ("Minerva~d4", vec![4], 1),
        ("Olympiad~d5-6", vec![5, 6], 1),
        ("AIME~d7", vec![7], 4),
        ("AMC~d8", vec![8], 4),
    ];
    let mut rows = Vec::new();
    let mut js = Vec::new();
    for (name, state) in &finals {
        let mut row = vec![name.clone()];
        let mut jrow = vec![("scheduler", s(name))];
        for (bname, diffs, k) in &benches {
            let problems: Vec<&crate::tasks::Problem> = strata
                .iter()
                .filter(|(d, _)| diffs.contains(d))
                .flat_map(|(_, v)| v.iter().copied())
                .take(ts.eval_limit)
                .collect();
            if problems.is_empty() {
                row.push("-".into());
                continue;
            }
            let temp = if *k > 1 { 0.8 } else { 0.0 };
            let e = evaluate_sampled(rt, state, &task, &problems, *k, temp,
                                     ts.max_new, ctx.seed + 43)?;
            row.push(format!("{:.1}", e.accuracy * 100.0));
            jrow.push((*bname, num(e.accuracy)));
        }
        rows.push(row);
        js.push(obj(jrow));
    }
    let mut headers = vec!["checkpoint"];
    headers.extend(benches.iter().map(|(n, _, _)| *n));
    print_table(&headers, &rows);
    println!("\npaper shape: on-policy leads on the harder strata; baseline \
              can win the easiest (GSM8K inversion)");
    ctx.write_json("tab1", &arr(js))?;
    Ok(())
}

/// Engine-pool scaling suite (simulator-backed; runs without artifacts).
///
/// Two sweeps at the Fig. 5 operating point (512 samples, cap 8192,
/// 128 total lanes):
///   1. engine count 1/2/4/8 under SJF dispatch — bubble + throughput per
///      SimMode, the 1-vs-N comparison the sched subsystem exists for;
///   2. dispatch policy x predictor at 4 engines — run-to-completion
///      makespan plus online predictor telemetry (MAE / Kendall tau).
pub fn pool_suite(ctx: &ExpContext) -> Result<()> {
    use crate::rollout::kv::KvMode;
    use crate::sched::{DispatchPolicy, PredictorKind, TailConfig};
    use crate::sim::{
        longtail_workload, pool_makespan, simulate_pool, CostModel, PoolSimOpts, SimMode,
        SimRun,
    };
    use crate::trace::Tracer;

    println!("== Pool scaling: engines x dispatch x predictor (sim) ==");
    println!("   512 samples, cap 8192, 128 total lanes, update batch 128\n");
    let w = longtail_workload(512, 8192, ctx.seed + 7);
    let cost = CostModel::default();

    let mut rows = Vec::new();
    let mut js = Vec::new();
    for engines in [1usize, 2, 4, 8] {
        for (mode, label) in [(SimMode::Baseline, "baseline"),
                              (SimMode::SortedOnPolicy, "on-policy"),
                              (SimMode::SortedPartial, "partial"),
                              (SimMode::Async, "async")] {
            let r = simulate_pool(mode, &w, engines, 128, 128, cost,
                                  DispatchPolicy::ShortestPredictedFirst,
                                  PredictorKind::History);
            rows.push(vec![
                format!("{engines}x{}", 128 / engines),
                label.to_string(),
                format!("{:.2}%", r.bubble_ratio * 100.0),
                format!("{:.0}", r.throughput),
                format!("{:.1}", r.rollout_time),
                format!("{}", r.wasted_tokens),
            ]);
            js.push(obj(vec![
                ("engines", num(engines as f64)),
                ("mode", s(label)),
                ("bubble", num(r.bubble_ratio)),
                ("throughput", num(r.throughput)),
                ("rollout_secs", num(r.rollout_time)),
                ("wasted_tokens", num(r.wasted_tokens as f64)),
                ("predictor_mae", num(r.predictor_mae)),
                ("predictor_tau", num(r.predictor_tau)),
                ("engine_idle", arr(r.engine_idle.iter().map(|&b| num(b)))),
            ]));
        }
    }
    print_table(&["pool", "mode", "bubble", "tok/s", "rollout s", "wasted"], &rows);
    println!("\nexpect: N engines stream weights in parallel -> wall time drops; \
              SJF packing keeps the bubble flat as lanes shard");
    ctx.write_json("pool_scaling", &arr(js))?;

    println!("\n-- dispatch policy x predictor (4 engines, run-to-completion) --\n");
    let mut rows = Vec::new();
    let mut js = Vec::new();
    for policy in DispatchPolicy::ALL {
        for kind in PredictorKind::ALL {
            let makespan = pool_makespan(&w, 4, 128, cost, policy, kind);
            let probe = simulate_pool(SimMode::SortedPartial, &w, 4, 128, 128,
                                      cost, policy, kind);
            rows.push(vec![
                policy.name().to_string(),
                kind.name().to_string(),
                format!("{:.1}", makespan),
                format!("{:.2}%", probe.bubble_ratio * 100.0),
                format!("{:.1}", probe.predictor_mae),
                format!("{:.3}", probe.predictor_tau),
            ]);
            js.push(obj(vec![
                ("dispatch", s(policy.name())),
                ("predictor", s(kind.name())),
                ("makespan_secs", num(makespan)),
                ("partial_bubble", num(probe.bubble_ratio)),
                ("predictor_mae", num(probe.predictor_mae)),
                ("predictor_tau", num(probe.predictor_tau)),
            ]));
        }
    }
    print_table(&["dispatch", "predictor", "makespan s", "partial bubble",
                  "pred MAE", "pred tau"], &rows);
    println!("\nexpect: predicted-SJF beats static round-robin on makespan \
              (late binding rebalances the long tail); bucket's MAE is \
              meaningless by design — its tau is what SJF consumes");
    ctx.write_json("pool_dispatch", &arr(js))?;

    println!("\n-- async updates vs sync schedulers (4 engines) --\n");
    let mut rows = Vec::new();
    let mut js = Vec::new();
    for (mode, label, staleness) in [(SimMode::Baseline, "baseline", None),
                                     (SimMode::SortedPartial, "partial", None),
                                     (SimMode::Async, "async", None),
                                     (SimMode::Async, "async-s2", Some(2))] {
        let r = SimRun::new(mode, PoolSimOpts {
            engines: 4,
            q_total: 128,
            update_batch: 128,
            cost,
            dispatch: DispatchPolicy::ShortestPredictedFirst,
            predictor: PredictorKind::History,
            staleness,
            ..PoolSimOpts::default()
        }).workload(&w).run();
        rows.push(vec![
            label.to_string(),
            format!("{:.2}%", r.bubble_ratio * 100.0),
            format!("{:.1}", r.rollout_time),
            format!("{:.1}", r.update_time),
            format!("{:.1}", r.total_time),
            format!("{}", r.max_staleness),
            format!("{}", r.stale_resyncs),
        ]);
        js.push(obj(vec![
            ("mode", s(label)),
            ("bubble", num(r.bubble_ratio)),
            ("rollout_secs", num(r.rollout_time)),
            ("update_secs", num(r.update_time)),
            ("total_secs", num(r.total_time)),
            ("staleness", staleness.map(|n: usize| num(n as f64)).unwrap_or(Json::Null)),
            ("max_staleness", num(r.max_staleness as f64)),
            ("stale_resyncs", num(r.stale_resyncs as f64)),
            ("staleness_hist", staleness_json(&r.staleness_hist)),
        ]));
    }
    print_table(&["mode", "bubble", "rollout s", "update s", "total s",
                  "max stale", "resyncs"], &rows);
    println!("\nexpect: async's bubble matches partial (same resume \
              semantics, lower than baseline) while its total time drops \
              by ~the update time — updates hide under decoding instead of \
              serializing behind the harvest barrier; async-s2 additionally \
              caps every consumed sample at 2 versions off-policy");
    ctx.write_json("pool_async", &arr(js))?;

    println!("\n-- work stealing vs none (4 engines, round-robin striping) --\n");
    let mut rows = Vec::new();
    let mut js = Vec::new();
    for (mode, label) in [(SimMode::Baseline, "baseline"),
                          (SimMode::SortedPartial, "partial")] {
        for steal in [false, true] {
            let r = SimRun::new(mode, PoolSimOpts {
                engines: 4,
                q_total: 128,
                update_batch: 128,
                cost,
                dispatch: DispatchPolicy::RoundRobin,
                predictor: PredictorKind::History,
                steal,
                ..PoolSimOpts::default()
            }).workload(&w).run();
            // the per-engine idle breakdown is the imbalance stealing fixes
            let worst = r.engine_idle.iter().cloned().fold(0.0, f64::max);
            let best = r.engine_idle.iter().cloned().fold(1.0, f64::min);
            rows.push(vec![
                label.to_string(),
                (if steal { "on" } else { "off" }).to_string(),
                format!("{:.2}%", r.bubble_ratio * 100.0),
                format!("{:.1}", r.rollout_time),
                format!("{:.2}%..{:.2}%", best * 100.0, worst * 100.0),
                format!("{}", r.steals),
                format!("{}", r.migrated_tokens),
            ]);
            js.push(obj(vec![
                ("mode", s(label)),
                ("steal", Json::Bool(steal)),
                ("bubble", num(r.bubble_ratio)),
                ("rollout_secs", num(r.rollout_time)),
                ("steals", num(r.steals as f64)),
                ("migrated_tokens", num(r.migrated_tokens as f64)),
                ("engine_idle", arr(r.engine_idle.iter().map(|&b| num(b)))),
            ]));
        }
    }
    print_table(&["mode", "steal", "bubble", "rollout s", "engine idle spread",
                  "steals", "migrated"], &rows);
    println!("\nexpect: static striping strands the long tail on a few \
              engines (wide idle spread); stealing lets drained engines \
              pull that backlog, cutting both the spread and the pool \
              bubble — partial tokens survive the migration.  Sorted \
              partial mode already balances the tail, so its steal count \
              is ~0: stealing rescues the schedules sorting can't fix");
    ctx.write_json("pool_steal", &arr(js))?;

    println!("\n-- paged vs reserved KV accounting (4 engines, fixed budget) --\n");
    // budget sized so reserve-the-cap admission binds hard: one worst-case
    // lane reserves ~prompt(64..256)+cap(8192) ~ 8.4k tokens, so a 40k
    // budget caps reserve mode at ~4 of each engine's 16 lanes while most
    // ACTUAL contexts stay under ~1.2k — exactly the over-conservative
    // admission gap paged accounting recovers
    let kv_budget = 40_000;
    let kv_page = 256;
    let mut rows = Vec::new();
    let mut js = Vec::new();
    for (mode, label) in [(SimMode::Baseline, "baseline"),
                          (SimMode::SortedPartial, "partial")] {
        for kv_mode in KvMode::ALL {
            let r = SimRun::new(mode, PoolSimOpts {
                engines: 4,
                q_total: 64,
                update_batch: 64,
                cost,
                dispatch: DispatchPolicy::ShortestPredictedFirst,
                predictor: PredictorKind::History,
                kv_budget,
                kv_mode,
                kv_page,
                ..PoolSimOpts::default()
            }).workload(&w).run();
            rows.push(vec![
                label.to_string(),
                kv_mode.name().to_string(),
                format!("{}", r.peak_lanes),
                format!("{:.2}%", r.bubble_ratio * 100.0),
                format!("{:.1}", r.rollout_time),
                format!("{:.0}", r.throughput),
                format!("{}", r.kv_sheds),
                format!("{}", r.throttles),
            ]);
            js.push(obj(vec![
                ("mode", s(label)),
                ("kv_mode", s(kv_mode.name())),
                ("kv_budget", num(kv_budget as f64)),
                ("kv_page", num(kv_page as f64)),
                ("peak_lanes", num(r.peak_lanes as f64)),
                ("bubble", num(r.bubble_ratio)),
                ("rollout_secs", num(r.rollout_time)),
                ("throughput", num(r.throughput)),
                ("kv_sheds", num(r.kv_sheds as f64)),
                ("throttles", num(r.throttles as f64)),
                // admitted-lane curve: merged (engine secs, running lanes),
                // downsampled like kv_curve so paper-scale JSON stays small
                ("lane_curve", {
                    let ev = r.timeline.events();
                    let stride = ev.len().div_ceil(256).max(1);
                    arr(ev.iter().step_by(stride).map(|&(t, n)| {
                        arr([num(t), num(n as f64)])
                    }))
                }),
                // utilization curve: merged (engine secs, KV tokens charged)
                ("kv_curve", arr(r.kv_trace.iter().map(|&(t, used)| {
                    arr([num(t), num(used as f64)])
                }))),
            ]));
        }
    }
    print_table(&["mode", "kv", "peak lanes", "bubble", "rollout s", "tok/s",
                  "sheds", "throttles"], &rows);
    println!("\nexpect: at the same budget, paged accounting admits strictly \
              more concurrent lanes (actual context vs worst-case \
              reservation) and cuts bubble + rollout time; sheds/throttles \
              count the backpressure paid when estimates undershoot");
    ctx.write_json("pool_kv", &arr(js))?;

    println!("\n-- SLO telemetry: latency quantiles + goodput (4 engines) --\n");
    // target chosen near the partial-mode e2e median at this operating
    // point, so goodput separates the schedulers instead of saturating at
    // 0 or 1 for every mode
    let slo = 25.0; // simulated seconds, end to end
    let mut rows = Vec::new();
    let mut js = Vec::new();
    for (mode, label) in [(SimMode::Baseline, "baseline"),
                          (SimMode::SortedOnPolicy, "on-policy"),
                          (SimMode::SortedPartial, "partial"),
                          (SimMode::Async, "async")] {
        let mut tracer = Tracer::new(Some(slo), false);
        let r = SimRun::new(mode, PoolSimOpts {
            engines: 4,
            q_total: 128,
            update_batch: 128,
            cost,
            dispatch: DispatchPolicy::ShortestPredictedFirst,
            predictor: PredictorKind::History,
            ..PoolSimOpts::default()
        }).workload(&w).tracer(&mut tracer).run();
        let t = &r.slo;
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", t.ttft_p50),
            format!("{:.2}", t.ttft_p99),
            format!("{:.3}", t.tpot_p50),
            format!("{:.2}", t.e2e_p50),
            format!("{:.2}", t.e2e_p99),
            format!("{:.3}", t.goodput),
        ]);
        js.push(obj(vec![
            ("mode", s(label)),
            ("slo_secs", num(slo)),
            ("enqueued", num(t.enqueued as f64)),
            ("completed", num(t.completed as f64)),
            ("clipped", num(t.clipped as f64)),
            ("ttft_p50", num(t.ttft_p50)),
            ("ttft_p90", num(t.ttft_p90)),
            ("ttft_p99", num(t.ttft_p99)),
            ("tpot_p50", num(t.tpot_p50)),
            ("tpot_p99", num(t.tpot_p99)),
            ("e2e_p50", num(t.e2e_p50)),
            ("e2e_p99", num(t.e2e_p99)),
            ("queue_p99", num(t.queue_p99)),
            ("goodput", num(t.goodput)),
        ]));
    }
    print_table(&["mode", "ttft p50", "ttft p99", "tpot p50", "e2e p50",
                  "e2e p99", "goodput"], &rows);
    println!("\nexpect: sorting compresses the e2e tail (p99 falls vs \
              baseline) at the cost of TTFT spread — long requests queue \
              behind short ones — while goodput@{slo}s rises; async's \
              quantiles track partial's since spans only cover rollout");
    ctx.write_json("pool_slo", &arr(js))?;

    // ---------------- tail packing: rounds vs no rounds ------------------
    println!("\n-- tail packing: batched tail rounds vs none (4 engines, oracle) --\n");
    // oracle predictor so the threshold splits exactly on true lengths;
    // the longtail workload's top decile is what the tail rounds absorb
    let tail_cfg = TailConfig { threshold: 2048, tail_engines: 1 };
    let mut rows = Vec::new();
    let mut js = Vec::new();
    for (mode, label) in [(SimMode::Baseline, "baseline"),
                          (SimMode::SortedPartial, "partial")] {
        for tail in [None, Some(tail_cfg)] {
            let r = SimRun::new(mode, PoolSimOpts {
                engines: 4,
                q_total: 128,
                update_batch: 128,
                cost,
                dispatch: DispatchPolicy::ShortestPredictedFirst,
                predictor: PredictorKind::Oracle,
                tail,
                ..PoolSimOpts::default()
            }).workload(&w).run();
            rows.push(vec![
                label.to_string(),
                (if tail.is_some() { "on" } else { "off" }).to_string(),
                format!("{:.2}%", r.bubble_ratio * 100.0),
                format!("{:.1}", r.rollout_time),
                format!("{}", r.tail_rounds),
                format!("{}", r.tail_admitted),
                format!("{}", r.repartitions),
                format!("{:.2}%/{:.2}%", r.head_bubble * 100.0,
                        r.tail_bubble * 100.0),
            ]);
            js.push(obj(vec![
                ("mode", s(label)),
                ("tail", Json::Bool(tail.is_some())),
                ("threshold", num(tail_cfg.threshold as f64)),
                ("tail_engines", num(tail_cfg.tail_engines as f64)),
                ("bubble", num(r.bubble_ratio)),
                ("rollout_secs", num(r.rollout_time)),
                ("tail_rounds", num(r.tail_rounds as f64)),
                ("tail_admitted", num(r.tail_admitted as f64)),
                ("repartitions", num(r.repartitions as f64)),
                ("head_bubble", num(r.head_bubble)),
                ("tail_bubble", num(r.tail_bubble)),
            ]));
        }
    }
    print_table(&["mode", "tail", "bubble", "rollout s", "rounds", "packed",
                  "reparts", "head/tail bubble"], &rows);
    println!("\nexpect: deferring predicted-long rollouts into batched tail \
              rounds keeps head rounds at full occupancy — the pool bubble \
              falls and the residual idle concentrates in the (smaller) \
              tail group; repartitions count the elastic lane/KV moves");
    ctx.write_json("pool_tail", &arr(js))?;

    // ------------- open-loop arrivals: per-tenant SLO + fairness ---------
    use crate::workload::{generate_trace, replay_trace, ArrivalSpec};

    println!("\n-- open-loop arrivals: per-tenant SLO + fairness (4 engines) --\n");
    // latencies are arrival-relative here (queueing delay included), so
    // the target sits well above the closed-loop one
    let slo_open = 60.0;
    let (arrivals, arrival_desc) = match &ctx.arrival {
        Some(spec) => (spec.build(384, 8192, ctx.seed + 7)?, format!("{spec:?}")),
        None => {
            // synthetic 3-tenant trace just under the pool's sustained
            // ceiling (~12 req/s at this operating point), so queues form
            // and drain instead of growing without bound
            let ev = generate_trace(3, 10.0, 40.0, 8192, ctx.seed + 7);
            (replay_trace(&ev, ctx.seed + 7),
             "trace-gen tenants=3 rate=10 horizon=40".to_string())
        }
    };
    let mut tracer = Tracer::new(Some(slo_open), false);
    let open = SimRun::new(SimMode::SortedPartial, PoolSimOpts {
        engines: 4,
        q_total: 128,
        update_batch: 128,
        cost,
        dispatch: DispatchPolicy::ShortestPredictedFirst,
        predictor: PredictorKind::History,
        ..PoolSimOpts::default()
    }).arrivals(&arrivals).tracer(&mut tracer).run();
    let t = &open.slo;
    let mut rows = Vec::new();
    for ten in &t.tenants {
        rows.push(vec![
            format!("t{}", ten.tenant),
            format!("{}", ten.enqueued),
            format!("{}", ten.completed),
            format!("{:.2}", ten.ttft_p50),
            format!("{:.2}", ten.e2e_p50),
            format!("{:.2}", ten.e2e_p99),
            format!("{:.3}", ten.goodput),
        ]);
    }
    print_table(&["tenant", "enq", "done", "ttft p50", "e2e p50", "e2e p99",
                  "goodput"], &rows);
    println!("Jain fairness {:.3}; queue depth peaked at {}",
             t.fairness_jain,
             t.queue_depth.iter().map(|&(_, d)| d).max().unwrap_or(0));

    // -------- robustness: steal / kv-preempt / tail under bursty+diurnal --
    println!("\n-- robustness: steal / preempt / tail under bursty + diurnal --\n");
    // non-stationary load is where the mitigations earn their keep: bursts
    // pile the long tail onto whichever engines the burst hit, and diurnal
    // troughs are when batched tail rounds can run without displacing the
    // head.  Oracle predictor isolates the scheduling effect.
    let base = PoolSimOpts {
        engines: 4,
        q_total: 128,
        update_batch: 128,
        cost,
        dispatch: DispatchPolicy::ShortestPredictedFirst,
        predictor: PredictorKind::Oracle,
        ..PoolSimOpts::default()
    };
    let variants: [(&str, PoolSimOpts); 4] = [
        ("plain", base),
        // stealing rescues static striping, so pair it with round-robin
        ("steal", PoolSimOpts {
            steal: true,
            dispatch: DispatchPolicy::RoundRobin,
            ..base
        }),
        ("kv-preempt", PoolSimOpts {
            kv_budget: 40_000,
            kv_mode: KvMode::Paged,
            kv_page: 256,
            ..base
        }),
        ("tail", PoolSimOpts { tail: Some(tail_cfg), ..base }),
    ];
    let generators = [
        ("bursty", ArrivalSpec::Bursty { rate_hi: 24.0, rate_lo: 2.0, flip: 0.1 }),
        ("diurnal", ArrivalSpec::Diurnal { base: 10.0, amp: 0.8, period: 20.0 }),
    ];
    let mut rows = Vec::new();
    let mut js = Vec::new();
    for (gname, spec) in &generators {
        let a = spec.build(384, 8192, ctx.seed + 7)?;
        for (vname, o) in &variants {
            let r = SimRun::new(SimMode::SortedPartial, *o).arrivals(&a).run();
            rows.push(vec![
                gname.to_string(),
                vname.to_string(),
                format!("{:.2}%", r.bubble_ratio * 100.0),
                format!("{:.1}", r.rollout_time),
                format!("{}", r.steals),
                format!("{}", r.kv_sheds),
                format!("{}", r.tail_rounds),
            ]);
            js.push(obj(vec![
                ("arrival", s(gname)),
                ("variant", s(vname)),
                ("bubble", num(r.bubble_ratio)),
                ("rollout_secs", num(r.rollout_time)),
                ("throughput", num(r.throughput)),
                ("steals", num(r.steals as f64)),
                ("kv_sheds", num(r.kv_sheds as f64)),
                ("throttles", num(r.throttles as f64)),
                ("tail_rounds", num(r.tail_rounds as f64)),
                ("tail_admitted", num(r.tail_admitted as f64)),
                ("head_bubble", num(r.head_bubble)),
                ("tail_bubble", num(r.tail_bubble)),
            ]));
        }
    }
    print_table(&["arrival", "variant", "bubble", "rollout s", "steals",
                  "sheds", "tail rounds"], &rows);
    ctx.write_json("pool_robustness", &arr(js))?;

    // ------------- sustained throughput at SLO (bisection) ---------------
    println!("\n-- sustained throughput at SLO: max arrival rate (bisection) --\n");
    // "meets the SLO" = >= 90% of arrivals finish within 30 simulated
    // seconds end to end, arrival-relative.  goodput(rate) is monotone
    // non-increasing once queues saturate, so bisection converges.  The
    // `--arrival` family (poisson/bursty/diurnal) shapes the probe stream;
    // its rate parameters are rescaled to the bisected aggregate rate.
    let slo_rate = 30.0;
    let target = 0.9;
    let family = ctx.arrival.clone().unwrap_or(ArrivalSpec::Poisson { rate: 1.0 });
    let probe_spec = |rate: f64| -> ArrivalSpec {
        match &family {
            ArrivalSpec::Bursty { rate_hi, rate_lo, flip } => {
                // keep the on/off shape, steer the (approximate) midpoint
                let k = rate / (0.5 * (rate_hi + rate_lo));
                ArrivalSpec::Bursty {
                    rate_hi: rate_hi * k,
                    rate_lo: rate_lo * k,
                    flip: *flip,
                }
            }
            ArrivalSpec::Diurnal { amp, period, .. } => {
                ArrivalSpec::Diurnal { base: rate, amp: *amp, period: *period }
            }
            // batch/trace have no free rate knob — probe plain Poisson
            _ => ArrivalSpec::Poisson { rate },
        }
    };
    let probe = |rate: f64| -> Result<f64> {
        let a = probe_spec(rate).build(192, 4096, ctx.seed + 7)?;
        let mut tr = Tracer::new(Some(slo_rate), false);
        let r = SimRun::new(SimMode::SortedPartial, PoolSimOpts {
            engines: 4,
            q_total: 128,
            update_batch: 128,
            cost,
            dispatch: DispatchPolicy::ShortestPredictedFirst,
            predictor: PredictorKind::History,
            ..PoolSimOpts::default()
        }).arrivals(&a).tracer(&mut tr).run();
        Ok(r.slo.goodput)
    };
    let (mut lo, mut hi) = (1.0f64, 64.0f64);
    let mut steps: Vec<(f64, f64)> = Vec::new();
    let g_lo = probe(lo)?;
    let g_hi = probe(hi)?;
    steps.push((lo, g_lo));
    steps.push((hi, g_hi));
    let sustained = if g_lo < target {
        println!("  even {lo:.1} req/s misses the target (goodput {g_lo:.3})");
        lo
    } else if g_hi >= target {
        println!("  {hi:.1} req/s still meets the target (goodput {g_hi:.3})");
        hi
    } else {
        for _ in 0..7 {
            let mid = 0.5 * (lo + hi);
            let g = probe(mid)?;
            steps.push((mid, g));
            if g >= target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    };
    println!("  sustained rate: {sustained:.2} req/s at goodput >= {target} \
              (e2e SLO {slo_rate}s, partial mode, 4x32 lanes)");
    ctx.write_json("pool_openloop", &obj(vec![
        ("arrival", s(&arrival_desc)),
        ("bisection_family", s(&format!("{family:?}"))),
        ("slo_secs", num(slo_open)),
        ("summary", t.to_json()),
        ("sustained_rate", num(sustained)),
        ("sustained_target_goodput", num(target)),
        ("sustained_slo_secs", num(slo_rate)),
        ("bisection", arr(steps.iter().map(|&(r, g)| arr([num(r), num(g)])))),
    ]))?;
    Ok(())
}

/// Fig. 9b: small-model saturation — the initial format jump then plateau.
pub fn fig9b(ctx: &ExpContext, rt: &Runtime) -> Result<()> {
    println!("== Fig 9b: small-model saturation on math ==\n");
    let mut ts = train_scale(ctx.scale);
    // deliberately undertrained warm start => format learning happens in RL
    ts.sft_steps = (ts.sft_steps / 4).max(4);
    let (warm, _ds) = warm_start(rt, "math", &ts, ctx.seed + 91)?;
    let (rows, summary, _state, result) = run_one(
        rt, "math", ctx.seed + 91, &ts, &warm,
        SchedulerKind::Baseline, ctx.seed + 92)?;
    println!("final: score {:.3}, format {:.2}", result.final_eval.score,
             result.final_eval.format_rate);
    println!("(expect: format_rate jumps early — the 'abrupt increment' — \
              then accuracy plateaus for the small model)");
    ctx.write_json("fig9b", &obj(vec![("rows", rows), ("summary", summary)]))?;
    Ok(())
}
