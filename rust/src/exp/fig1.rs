//! Fig. 1 — motivation: (a) rollout dominates RL latency as max generation
//! length grows; (b) sync-barrier drain bubbles within one rollout batch;
//! (c) long-tailed length distribution.
//!
//! (a) and (b) are simulator-backed at paper scale; (c) combines the
//! simulator's workload model with (optionally) real rollouts from the
//! trained small model.

use super::{print_table, ExpContext};
use crate::sim::{longtail_workload, simulate, CostModel, SimMode};
use crate::util::json::{arr, num, obj, Json};
use crate::util::stats::Histogram;
use anyhow::Result;

/// Fig. 1a: latency breakdown (rollout / inference / update shares) as the
/// maximum generation length scales 1k -> 16k.  The paper reports rollout
/// reaching ~70% at 16k.
pub fn fig1a(ctx: &ExpContext) -> Result<()> {
    println!("== Fig 1a: latency breakdown vs max generation length ==");
    println!("   (baseline scheduler, batch 128, long-tailed lengths)\n");
    let mut rows = Vec::new();
    let mut js = Vec::new();
    for max_len in [1024usize, 2048, 4096, 8192, 16384] {
        let w = longtail_workload(512, max_len, ctx.seed + 1);
        let r = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let total = r.total_time;
        let share = |x: f64| format!("{:.1}%", 100.0 * x / total);
        rows.push(vec![
            format!("{max_len}"),
            share(r.rollout_time),
            share(r.infer_time),
            share(r.update_time),
            format!("{:.1}s", total),
        ]);
        js.push(obj(vec![
            ("max_len", num(max_len as f64)),
            ("rollout_share", num(r.rollout_time / total)),
            ("infer_share", num(r.infer_time / total)),
            ("update_share", num(r.update_time / total)),
            ("total_secs", num(total)),
        ]));
    }
    print_table(&["max_len", "rollout", "inference", "update", "total"], &rows);
    println!("\npaper shape: rollout share grows with max length, ~70% at 16k");
    ctx.write_json("fig1a", &arr(js))?;
    Ok(())
}

/// Fig. 1b: running-request occupancy over one rollout batch (batch 128) —
/// the drain tail that creates the bubbles.
pub fn fig1b(ctx: &ExpContext) -> Result<()> {
    println!("== Fig 1b: GPU occupancy during one rollout batch (b=128) ==\n");
    let w = longtail_workload(128, 4096, ctx.seed + 2);
    let r = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
    // occupancy curve, bucketed to 40 time bins
    let end = r.rollout_time;
    let ev = r.timeline.events();
    let bins = 40usize;
    let mut occ = vec![0.0f64; bins];
    let mut wsum = vec![0.0f64; bins];
    for win in ev.windows(2) {
        let (t0, r0) = win[0];
        let (t1, _) = win[1];
        // spread the piecewise-constant segment over every bin it covers
        let b0 = ((t0 / end * bins as f64) as usize).min(bins - 1);
        let b1 = ((t1 / end * bins as f64) as usize).min(bins - 1);
        for b in b0..=b1 {
            let lo = (end * b as f64 / bins as f64).max(t0);
            let hi = (end * (b + 1) as f64 / bins as f64).min(t1);
            if hi > lo {
                occ[b] += r0 as f64 * (hi - lo);
                wsum[b] += hi - lo;
            }
        }
    }
    println!("time->   occupancy (128 = full)");
    for i in 0..bins {
        let o = if wsum[i] > 0.0 { occ[i] / wsum[i] } else { 0.0 };
        let bar = "#".repeat((o / 128.0 * 60.0) as usize);
        println!("{:>5.1}s |{bar}", end * i as f64 / bins as f64);
    }
    println!("\nbubble ratio of this batch: {:.1}% (paper: large sync bubbles)",
             r.bubble_ratio * 100.0);
    ctx.write_csv("fig1b_timeline", &r.timeline.to_csv())?;
    ctx.write_json("fig1b", &obj(vec![
        ("bubble_ratio", num(r.bubble_ratio)),
        ("rollout_secs", num(r.rollout_time)),
    ]))?;
    Ok(())
}

/// Fig. 1c: length distribution of sampled trajectories (batch 512, 4k cap).
/// `real_lengths` (if provided by the caller, from actual engine rollouts)
/// is plotted alongside the workload model.
pub fn fig1c(ctx: &ExpContext, real_lengths: Option<&[usize]>) -> Result<()> {
    println!("== Fig 1c: length distribution of sampled trajectories ==\n");
    let cap = 4096;
    let w = longtail_workload(512, cap, ctx.seed + 3);
    let mut h = Histogram::new(0.0, cap as f64, 16);
    for r in &w {
        h.push(r.output_len as f64);
    }
    println!("workload model (512 samples, cap {cap}):");
    print!("{}", h.ascii(50));
    let cdf = h.cdf();
    let under_3k = cdf[(3000 * 16 / cap).min(15)];
    // cap-hitting samples land in the histogram's explicit overflow bin
    // (lengths are clamped AT the cap, i.e. at the [lo, hi) right edge)
    println!("\nfraction within 3k: {:.1}% (paper: ~80%); at cap: {:.1}% (paper: ~5%)",
             under_3k * 100.0,
             100.0 * (h.counts[15] + h.overflow) as f64 / h.total() as f64);
    let mut out = vec![
        ("model_hist", arr(h.counts.iter().map(|&c| num(c as f64)))),
        ("model_at_cap", num(h.overflow as f64)),
    ];
    if let Some(lens) = real_lengths {
        let mut hr = Histogram::new(0.0, lens.iter().copied().max().unwrap_or(1) as f64 + 1.0, 16);
        for &l in lens {
            hr.push(l as f64);
        }
        println!("\nreal rollouts from the trained model ({} samples):", lens.len());
        print!("{}", hr.ascii(50));
        out.push(("real_hist", arr(hr.counts.iter().map(|&c| num(c as f64)))));
        out.push(("real_n", num(lens.len() as f64)));
    }
    ctx.write_json("fig1c", &obj(out.into_iter().collect()))?;
    Ok(())
}

pub fn to_json_row(name: &str, vals: &[(&str, f64)]) -> Json {
    let mut v = vec![("name", Json::Str(name.to_string()))];
    for (k, x) in vals {
        v.push((k, num(*x)));
    }
    obj(v)
}
