//! Fig. 5 — rollout throughput + bubble ratio under the three strategies,
//! at the paper's workload scale: 512 samples in 4 batches, 8k-token cap,
//! generation lengths pinned across strategies.
//!
//! Paper numbers: throughput 3987 / 4289 / 5559 tok/s (baseline /
//! on-policy / partial); bubble 74% -> 5.81% / 3.37%.

use super::{print_table, ExpContext};
use crate::sim::{longtail_workload, simulate, CostModel, SimMode};
use crate::util::json::{arr, num, obj, s};
use anyhow::Result;

pub fn fig5(ctx: &ExpContext) -> Result<()> {
    println!("== Fig 5: rollout throughput & bubble ratio (sim, paper scale) ==");
    println!("   512 samples, 4 batches of 128, cap 8192, lengths pinned\n");
    let w = longtail_workload(512, 8192, ctx.seed + 5);
    let cost = CostModel::default();
    let mut rows = Vec::new();
    let mut js = Vec::new();
    let mut tputs = Vec::new();
    for (mode, label, paper_tput, paper_bubble) in [
        (SimMode::Baseline, "baseline", 3987.0, 0.74),
        (SimMode::SortedOnPolicy, "on-policy", 4289.0, 0.0581),
        (SimMode::SortedPartial, "partial", 5559.0, 0.0337),
    ] {
        let r = simulate(mode, &w, 128, 128, cost);
        rows.push(vec![
            label.to_string(),
            format!("{:.0}", r.throughput),
            format!("{:.0}", paper_tput),
            format!("{:.2}%", r.bubble_ratio * 100.0),
            format!("{:.2}%", paper_bubble * 100.0),
            format!("{}", r.wasted_tokens),
            format!("{}", r.clipped),
        ]);
        js.push(obj(vec![
            ("mode", s(label)),
            ("throughput", num(r.throughput)),
            ("paper_throughput", num(paper_tput)),
            ("bubble", num(r.bubble_ratio)),
            ("paper_bubble", num(paper_bubble)),
            ("wasted_tokens", num(r.wasted_tokens as f64)),
            ("clipped", num(r.clipped as f64)),
            ("rollout_secs", num(r.rollout_time)),
        ]));
        tputs.push(r.throughput);
    }
    print_table(
        &["mode", "tok/s", "paper", "bubble", "paper", "wasted", "clipped"],
        &rows,
    );
    println!("\nspeedup over baseline: on-policy {:+.1}% (paper +7.6%), partial {:+.1}% (paper +39.4%)",
             100.0 * (tputs[1] / tputs[0] - 1.0),
             100.0 * (tputs[2] / tputs[0] - 1.0));
    ctx.write_json("fig5", &arr(js))?;
    Ok(())
}
