//! SortedRL — online length-aware scheduling for RL training of LLMs.
//!
//! Reproduction of "SortedRL: Accelerating RL Training for LLMs through
//! Online Length-Aware Scheduling" as a three-layer rust + JAX + Pallas
//! stack: rust owns the coordinator (this crate), JAX/Pallas author the
//! policy LM AOT-compiled to HLO, and PJRT executes it (runtime module).
//! See DESIGN.md for the system inventory.

pub mod coordinator;
pub mod data;
pub mod exp;
pub mod metrics;
pub mod rl;
pub mod rollout;
pub mod sched;
pub mod sim;
pub mod trace;
pub mod runtime;
pub mod tasks;
pub mod tokenizer;
pub mod util;
pub mod workload;
