//! Continuous-batching rollout engine over the AOT-compiled policy LM.
//!
//! The engine owns B fixed lanes (the PJRT decode_chunk batch — the
//! "captured graph" size the paper's oversubscription strategy keeps
//! saturated, §3.1), a waiting queue, and the persistent KV cache.  The
//! SortedRL controller drives it chunk by chunk and decides when to admit,
//! harvest and terminate; the engine is policy-free.
//!
//! Determinism: every request carries its own PCG stream, so a trajectory's
//! sampled tokens depend only on (seed, request id, policy weights) — not on
//! scheduling order.  This is what lets the Fig.5 harness pin generation
//! lengths across scheduling strategies like the paper does.

pub mod kv;

use crate::metrics::Timeline;
use crate::runtime::{ParamState, Runtime};
use crate::tokenizer::{EOS, PAD};
use crate::util::rng::Pcg64;
use anyhow::Result;
use kv::KvConfig;
use std::collections::VecDeque;

/// A rollout request: a prompt plus (for partial-mode resumes) the tokens
/// and behavior-policy log-probs generated before an interruption.
#[derive(Debug, Clone)]
pub struct Request {
    pub rid: u64,
    pub problem_idx: usize,
    /// Shared by the G samples of one prompt (GRPO grouping / bookkeeping).
    pub prompt_id: u64,
    pub prompt: Vec<i32>,
    pub resumed: Vec<i32>,
    pub resumed_logp: Vec<f32>,
    /// Policy version when the FIRST response token was sampled.
    pub born_version: Option<u64>,
    pub resumes: u32,
    /// Per-request cap on generated tokens (keeps prompt+response <= T).
    pub max_new: usize,
    /// Predicted TOTAL response length, stamped by the pool's
    /// `LengthPredictor` at dispatch (None = unknown, or the predictor is
    /// rank-only).  Paged KV admission estimates from it, falling back to
    /// `max_new`.
    pub predicted_len: Option<usize>,
}

impl Request {
    pub fn fresh(rid: u64, problem_idx: usize, prompt_id: u64, prompt: Vec<i32>,
                 max_new: usize) -> Self {
        Request {
            rid,
            problem_idx,
            prompt_id,
            prompt,
            resumed: Vec::new(),
            resumed_logp: Vec::new(),
            born_version: None,
            resumes: 0,
            max_new,
            predicted_len: None,
        }
    }

    /// Prompt + already-generated tokens (what prefill must ingest).
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.resumed.len()
    }
}

/// A finished (EOS or cap) or terminated (scheduler-interrupted) rollout.
#[derive(Debug, Clone)]
pub struct Rollout {
    pub request: Request,
    /// Full response so far (resumed ++ newly generated).
    pub response: Vec<i32>,
    /// Behavior-policy log-prob of each response token at sampling time.
    pub logp: Vec<f32>,
    pub finish_version: u64,
    /// True if the model ended the sequence itself (EOS) or hit its cap;
    /// false if the scheduler terminated it mid-generation.
    pub complete: bool,
    /// Wall-clock seconds (engine time) when this rollout finished.
    pub finished_at: f64,
}

impl Rollout {
    /// Assemble a partial (scheduler-interrupted) rollout: the request's
    /// already-resumed prefix plus whatever was emitted since
    /// (re-)admission, with log-probs aligned.
    pub fn partial(request: Request, emitted: &[i32], logps: &[f32], version: u64,
                   at: f64) -> Rollout {
        let mut response = request.resumed.clone();
        response.extend_from_slice(emitted);
        let mut logp = request.resumed_logp.clone();
        logp.extend_from_slice(logps);
        Rollout {
            request,
            response,
            logp,
            finish_version: version,
            complete: false,
            finished_at: at,
        }
    }
}

/// Worst-case KV reservation of a request: prompt plus its full generation
/// cap, i.e. the largest context the lane's cache can grow to.  This is
/// the reserve-mode lane charge; paged mode tracks the growing context
/// instead (see [`kv::KvConfig`]).
pub fn kv_reservation(req: &Request) -> usize {
    req.prompt.len() + req.max_new
}

/// Progress of one active lane (see [`Engine::lane_progress`]).
#[derive(Debug, Clone, Copy)]
pub struct LaneProgress {
    pub lane: usize,
    /// Tokens generated since (re-)admission.
    pub emitted: usize,
    /// Total response length so far (resumed + emitted).
    pub total: usize,
    pub rid: u64,
    pub prompt_id: u64,
    pub prompt_len: usize,
    /// KV this lane would need to be admitted elsewhere (the steal-fit
    /// check): the full reservation in reserve mode, the paged admission
    /// estimate otherwise.
    pub reserve: usize,
    /// Generation cap of the lane's request (victim pricing input).
    pub max_new: usize,
    /// Predicted-length stamp captured at dispatch (None when rank-only).
    pub predicted: Option<usize>,
}

struct Lane {
    request: Request,
    emitted: Vec<i32>,
    logps: Vec<f32>,
    rng: Pcg64,
    tok: i32,
    pos: i32,
    active: bool,
}

/// Engine knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub temperature: f32,
    /// Greedy decoding (eval): ignore temperature, take argmax.
    pub greedy: bool,
    pub seed: u64,
    /// KV memory model: reserve-the-cap or paged accounting, budget in
    /// tokens, page granularity.  Admission stops once the budget is
    /// reached, except that an otherwise-empty engine always admits one
    /// request (progress guarantee).  `budget == usize::MAX` disables the
    /// model.
    pub kv: KvConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { temperature: 1.0, greedy: false, seed: 0, kv: KvConfig::default() }
    }
}

pub struct Engine<'rt> {
    rt: &'rt Runtime,
    cfg: EngineConfig,
    lanes: Vec<Option<Lane>>,
    queue: VecDeque<Request>,
    finished: Vec<Rollout>,
    /// Virtual clock: advanced by the wall time of engine calls only, so
    /// controller/trainer time does not pollute rollout occupancy numbers.
    clock: f64,
    pub timeline: Timeline,
    kv: Option<xla::Literal>,
    /// Lanes force-evicted by the paged-KV backpressure path (progress
    /// kept, requeued locally).
    sheds: u64,
    /// Incremental Σ admission estimate over the local queue — the O(1)
    /// half of [`Engine::kv_committed`], maintained at every queue
    /// mutation and cross-checked against the O(queue) recompute in debug
    /// builds (double-entry bookkeeping).  Sound because a queued
    /// request's estimate inputs are immutable while it waits.
    queue_est: usize,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: EngineConfig) -> Self {
        let b = rt.manifest.shapes.engine_batch;
        Engine {
            rt,
            cfg,
            lanes: (0..b).map(|_| None).collect(),
            queue: VecDeque::new(),
            finished: Vec::new(),
            clock: 0.0,
            timeline: Timeline::new(),
            kv: None,
            sheds: 0,
            queue_est: 0,
        }
    }

    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    pub fn running(&self) -> usize {
        self.lanes
            .iter()
            .filter(|l| l.as_ref().is_some_and(|l| l.active))
            .count()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn in_flight(&self) -> usize {
        self.running() + self.queued()
    }

    /// KV tokens actually charged by occupied lanes (queued requests hold
    /// no KV until admitted): worst-case reservations in reserve mode, the
    /// paged context held so far otherwise.
    pub fn kv_used(&self) -> usize {
        self.lanes
            .iter()
            .filter_map(|l| l.as_ref())
            .map(|l| self.lane_charge(l))
            .sum()
    }

    fn lane_charge(&self, l: &Lane) -> usize {
        self.cfg.kv.lane_charge(
            l.request.prompt.len(),
            l.request.resumed.len() + l.emitted.len(),
            l.request.max_new,
        )
    }

    /// What the admission gate charges `req` as a candidate: the full
    /// reservation in reserve mode, the predictor-informed paged estimate
    /// otherwise (see [`KvConfig::admit_estimate`]).
    pub fn request_estimate(&self, req: &Request) -> usize {
        self.cfg.kv.admit_estimate(
            req.prompt.len(),
            req.resumed.len(),
            req.max_new,
            req.predicted_len,
        )
    }

    pub fn kv_budget(&self) -> usize {
        self.cfg.kv.budget
    }

    pub fn kv_config(&self) -> KvConfig {
        self.cfg.kv
    }

    /// Budget headroom over actual lane charges (`usize::MAX` when
    /// accounting is off — see [`KvConfig::headroom`]).
    pub fn kv_headroom(&self) -> usize {
        self.cfg.kv.headroom(self.kv_used())
    }

    /// Actual charges plus the admission estimates of everything already
    /// placed in the local queue — what budget-aware dispatch must assume
    /// this engine is committed to before routing more work here.
    pub fn kv_committed(&self) -> usize {
        debug_assert_eq!(
            self.queue_est,
            self.queue.iter().map(|q| self.request_estimate(q)).sum::<usize>(),
            "queue estimate double-entry drift"
        );
        self.kv_used() + self.queue_est
    }

    /// Paged over-commit warning: projected usage (one more page per
    /// active lane) would overrun the budget (see [`KvConfig::pressure`]).
    pub fn kv_pressure(&self) -> bool {
        self.cfg.kv.pressure(self.kv_used(), self.running())
    }

    /// Lanes force-evicted by paged backpressure so far.
    pub fn kv_sheds(&self) -> u64 {
        self.sheds
    }

    /// Elastic repartition hook (`Decision::Repartition`): resize this
    /// engine's usable lane window and KV budget transactionally.  Live
    /// lanes are pinned to their cache rows, so the window can only
    /// shrink to a suffix that is already free; growth is clamped to the
    /// compiled kernel batch width (the hardware ceiling — a grant above
    /// it still "applies" at the clamped width).  The new budget must
    /// cover what occupied lanes already hold, except that a single
    /// running lane keeps the progress guarantee.  Returns false — state
    /// untouched — when either half cannot apply.
    pub fn set_capacity(&mut self, lanes: usize, budget: usize) -> bool {
        let width = self.rt.manifest.shapes.engine_batch;
        let lanes = lanes.clamp(1, width);
        let pinned = self.lanes.iter().rposition(|l| l.is_some()).map_or(0, |i| i + 1);
        if lanes < pinned {
            return false;
        }
        if budget < self.kv_used() && self.running() > 1 {
            return false;
        }
        self.lanes.resize_with(lanes, || None);
        self.cfg.kv.budget = budget;
        true
    }

    /// The KV admission gate shared by `admit`, `kv_blocked`, and the
    /// pool's `steal_to`: admitting `estimate` on top of `used` is refused
    /// iff occupied lanes already hold KV and the sum overruns the budget
    /// (the empty-engine escape admits any head request alone).
    pub fn kv_gate_refuses(&self, used: usize, estimate: usize) -> bool {
        self.cfg.kv.gate_refuses(used, estimate)
    }

    /// The KV gate currently refuses the queue head: a free lane will NOT
    /// drain this queue until a running lane releases its charge — a
    /// stealing policy should treat this as saturation.
    pub fn kv_blocked(&self) -> bool {
        self.queue
            .front()
            .is_some_and(|front| self.kv_gate_refuses(self.kv_used(), self.request_estimate(front)))
    }

    /// Remove the newest request from the local queue (a work-stealing
    /// victim — the entry furthest from running here anyway).
    pub fn steal_queued(&mut self) -> Option<Request> {
        let req = self.queue.pop_back()?;
        self.queue_est -= self.request_estimate(&req);
        Some(req)
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Enqueue requests (oversubscription: queue may exceed lane count).
    pub fn submit(&mut self, reqs: impl IntoIterator<Item = Request>) {
        for req in reqs {
            self.queue_est += self.request_estimate(&req);
            self.queue.push_back(req);
        }
    }

    /// Drain finished rollouts collected so far (completion order — i.e.
    /// sorted by generation length within a wave, the property SortedRL's
    /// micro-curriculum exploits).
    pub fn drain_finished(&mut self) -> Vec<Rollout> {
        std::mem::take(&mut self.finished)
    }

    pub fn finished_count(&self) -> usize {
        self.finished.len()
    }

    fn record_occupancy(&mut self) {
        let r = self.running();
        self.timeline.set_running(self.clock, r);
    }

    /// Admit queued requests into free lanes; one batched prefill if any.
    pub fn admit(&mut self, state: &ParamState) -> Result<usize> {
        let sh = self.rt.manifest.shapes.clone();
        let free: Vec<usize> = (0..self.lanes.len())
            .filter(|&i| self.lanes[i].is_none())
            .collect();
        if free.is_empty() || self.queue.is_empty() {
            return Ok(0);
        }
        let mut tokens = vec![PAD; sh.engine_batch * sh.prefill_seq];
        let mut lens = vec![1i32; sh.engine_batch];
        let mut newly: Vec<(usize, Request)> = Vec::with_capacity(free.len());
        let mut kv_used = self.kv_used();
        for &lane in &free {
            let Some(front) = self.queue.front() else { break };
            // KV admission gate: stop once the budget is reached, but an
            // otherwise-empty engine always admits its head request so a
            // single oversized context cannot deadlock the queue.  Within
            // this pass the gate accumulates admission ESTIMATES (paged
            // mode would otherwise co-admit a whole queue of tiny
            // prompt-only charges that all grow toward the cap at once).
            let estimate = self.request_estimate(front);
            if self.kv_gate_refuses(kv_used, estimate) {
                break;
            }
            kv_used += estimate;
            let req = self.queue.pop_front().unwrap();
            self.queue_est -= estimate;
            let ctx_len = req.context_len().min(sh.prefill_seq);
            for i in 0..ctx_len {
                let t = if i < req.prompt.len() {
                    req.prompt[i]
                } else {
                    req.resumed[i - req.prompt.len()]
                };
                tokens[lane * sh.prefill_seq + i] = t;
            }
            lens[lane] = ctx_len as i32;
            newly.push((lane, req));
        }
        if newly.is_empty() {
            return Ok(0); // every candidate blocked on the KV budget
        }
        let n = newly.len();
        // lanes not being admitted keep length 1 (BOS-ish dummy); their
        // cache lanes are restored from the old cache right after.
        let t0 = std::time::Instant::now();
        let (fresh, logits) = self.rt.prefill(state, &tokens, &lens)?;
        self.kv = match self.kv.take() {
            // keep old lanes, take fresh ones for the admitted requests
            Some(old) => {
                let lanes_new: Vec<usize> = newly.iter().map(|(l, _)| *l).collect();
                Some(self.rt.merge_kv_lanes(&old, &fresh, &lanes_new)?)
            }
            None => Some(fresh),
        };

        let v = self.rt.manifest.model.vocab;
        for (lane, req) in newly {
            let mut rng = Pcg64::with_stream(self.cfg.seed ^ req.rid, 0xB0 + req.resumes as u64);
            let row = &logits[lane * v..(lane + 1) * v];
            let (tok, logp) = sample_row(row, self.cfg.temperature, self.cfg.greedy, &mut rng);
            let mut l = Lane {
                tok,
                pos: lens[lane],
                active: true,
                emitted: vec![tok],
                logps: vec![logp],
                rng,
                request: req,
            };
            if l.request.born_version.is_none() {
                l.request.born_version = Some(state.version);
            }
            // immediate EOS / zero-budget edge cases
            if tok == EOS || l.request.max_new <= l.request.resumed.len() + 1 {
                self.finish_lane_inner(&mut l, state.version, tok == EOS);
                self.lanes[lane] = None;
                continue;
            }
            self.lanes[lane] = Some(l);
        }
        self.clock += t0.elapsed().as_secs_f64();
        self.record_occupancy();
        Ok(n)
    }

    fn finish_lane_inner(&mut self, lane: &mut Lane, version: u64, _eos: bool) {
        let req = lane.request.clone();
        let mut response = req.resumed.clone();
        response.extend(&lane.emitted);
        let mut logp = req.resumed_logp.clone();
        logp.extend(&lane.logps);
        self.timeline.add_finished(1);
        self.finished.push(Rollout {
            request: req,
            response,
            logp,
            finish_version: version,
            complete: true,
            finished_at: self.clock,
        });
    }

    /// One decode_chunk across all lanes. Returns #tokens generated.
    pub fn step(&mut self, state: &ParamState) -> Result<usize> {
        let sh = self.rt.manifest.shapes.clone();
        let (b, k) = (sh.engine_batch, sh.decode_chunk);
        if self.kv.is_none() || self.running() == 0 {
            return Ok(0);
        }
        let mut tok = vec![PAD; b];
        let mut pos = vec![0i32; b];
        let mut active = vec![0i32; b];
        let mut uniforms = vec![-1.0f32; b * k];
        for (i, slot) in self.lanes.iter_mut().enumerate() {
            if let Some(l) = slot.as_mut() {
                tok[i] = l.tok;
                pos[i] = l.pos;
                active[i] = l.active as i32;
                for j in 0..k {
                    uniforms[i * k + j] = if self.cfg.greedy {
                        -1.0
                    } else {
                        l.rng.uniform_f32()
                    };
                }
            }
        }
        let t0 = std::time::Instant::now();
        let kv = self.kv.take().expect("kv checked above");
        let (kv, out) = self
            .rt
            .decode_chunk(state, kv, &tok, &pos, &active, &uniforms, self.cfg.temperature)?;
        self.kv = Some(kv);
        self.clock += t0.elapsed().as_secs_f64();

        let mut tokens_out = 0usize;
        let mut to_finish: Vec<usize> = Vec::new();
        for i in 0..b {
            let Some(l) = self.lanes[i].as_mut() else { continue };
            if !l.active {
                continue;
            }
            let row_tok = &out.out_tokens[i * k..(i + 1) * k];
            let row_lp = &out.out_logp[i * k..(i + 1) * k];
            let budget = l.request.max_new - l.request.resumed.len();
            for (t, lp) in row_tok.iter().zip(row_lp) {
                if *t == PAD {
                    // lane went inactive mid-chunk (EOS emitted earlier or cap)
                    break;
                }
                l.emitted.push(*t);
                l.logps.push(*lp);
                tokens_out += 1;
                if *t == EOS || l.emitted.len() >= budget {
                    break;
                }
            }
            l.tok = out.tok[i];
            l.pos = out.pos[i];
            let model_active = out.active[i] != 0;
            let capped = l.emitted.len() >= budget;
            l.active = model_active && !capped;
            if !l.active {
                to_finish.push(i);
            }
        }
        self.timeline.add_tokens(tokens_out as u64);
        for i in to_finish {
            let mut lane = self.lanes[i].take().unwrap();
            self.finish_lane_inner(&mut lane, state.version, true);
        }
        self.shed_over_budget();
        self.record_occupancy();
        Ok(tokens_out)
    }

    /// Paged-mode forced backpressure: if actual usage outgrew the budget
    /// (admission estimates undershot), evict the lane with the most
    /// predicted remaining work (per-page fragmentation breaks ties — see
    /// [`KvConfig::victim_key`]) back to the local queue — progress and
    /// log-probs kept, resume pays one re-prefill — until the budget holds
    /// again or one lane remains (the running twin of the empty-engine
    /// admission escape).  This is what keeps "usage never exceeds the
    /// budget" a hard invariant even though paged admission may
    /// over-commit; the policy-level `Decision::Throttle` path sheds
    /// proactively so this rarely fires.
    fn shed_over_budget(&mut self) {
        if self.cfg.kv.mode != kv::KvMode::Paged || self.cfg.kv.unlimited() {
            return;
        }
        while self.running() > 1 && self.kv_used() > self.cfg.kv.budget {
            let victim = self
                .lanes
                .iter()
                .enumerate()
                .filter_map(|(i, slot)| {
                    slot.as_ref().map(|l| {
                        let held = l.request.resumed.len() + l.emitted.len();
                        let key = self.cfg.kv.victim_key(
                            l.request.prompt.len(),
                            held,
                            l.request.max_new,
                            l.request.predicted_len,
                        );
                        (key, std::cmp::Reverse(i))
                    })
                })
                .max()
                .map(|(_, std::cmp::Reverse(i))| i);
            let Some(i) = victim else { break };
            let l = self.lanes[i].take().unwrap();
            let mut req = l.request;
            req.resumed.extend(&l.emitted);
            req.resumed_logp.extend(&l.logps);
            req.resumes += 1;
            // the back of the queue: fresh short work admits first, and the
            // evicted partial becomes the preferred steal victim
            // (`steal_queued` pops the back) for a KV-rich peer
            self.queue_est += self.request_estimate(&req);
            self.queue.push_back(req);
            self.sheds += 1;
        }
    }

    /// Terminate every in-flight request (queue included), returning partial
    /// rollouts for in-flight lanes and untouched requests for the queue.
    /// This is the controller's early-termination harvest (paper §3.1):
    /// in on-policy mode the caller discards partials (prompt re-queued),
    /// in partial mode it scavenges tokens + log-probs into the buffer.
    pub fn terminate_all(&mut self, version: u64) -> (Vec<Rollout>, Vec<Request>) {
        let mut partials = Vec::new();
        for slot in self.lanes.iter_mut() {
            if let Some(l) = slot.take() {
                partials.push(Rollout::partial(
                    l.request, &l.emitted, &l.logps, version, self.clock,
                ));
            }
        }
        let queued: Vec<Request> = self.queue.drain(..).collect();
        self.queue_est = 0;
        self.kv = None;
        self.record_occupancy();
        (partials, queued)
    }

    /// Progress snapshot of every active lane (for the pool scheduler's
    /// straggler detection).
    pub fn lane_progress(&self) -> Vec<LaneProgress> {
        self.lanes
            .iter()
            .enumerate()
            .filter_map(|(i, slot)| {
                slot.as_ref().filter(|l| l.active).map(|l| {
                    let total = l.request.resumed.len() + l.emitted.len();
                    LaneProgress {
                        lane: i,
                        emitted: l.emitted.len(),
                        total,
                        rid: l.request.rid,
                        prompt_id: l.request.prompt_id,
                        prompt_len: l.request.prompt.len(),
                        reserve: self.cfg.kv.admit_estimate(
                            l.request.prompt.len(),
                            total,
                            l.request.max_new,
                            l.request.predicted_len,
                        ),
                        max_new: l.request.max_new,
                        predicted: l.request.predicted_len,
                    }
                })
            })
            .collect()
    }

    /// Preempt ONE lane mid-generation, returning its partial rollout
    /// (progress + log-probs kept — APRIL-style active partial rollout).
    /// The freed lane admits queued work on the next `admit`; the caller
    /// requeues the partial (resume pays one prefill over prompt+prefix).
    pub fn preempt_lane(&mut self, lane: usize, version: u64) -> Option<Rollout> {
        let l = self.lanes.get_mut(lane)?.take()?;
        let rollout = Rollout::partial(l.request, &l.emitted, &l.logps, version, self.clock);
        self.record_occupancy();
        Some(rollout)
    }

    /// Run until every submitted request has finished (baseline semantics —
    /// the sync barrier that produces Fig.1b's drain bubbles).
    pub fn run_to_completion(&mut self, state: &ParamState) -> Result<Vec<Rollout>> {
        loop {
            self.admit(state)?;
            if self.running() == 0 {
                if self.queue.is_empty() {
                    break;
                }
                continue;
            }
            self.step(state)?;
        }
        Ok(self.drain_finished())
    }
}

/// Temperature / greedy sampling over one logits row; returns (token, logp).
/// Mirrors the in-HLO sampler (log-softmax + inverse CDF) so rust-sampled
/// first tokens carry the same behavior-policy log-prob semantics.
pub fn sample_row(row: &[f32], temperature: f32, greedy: bool, rng: &mut Pcg64) -> (i32, f32) {
    let inv_t = 1.0 / temperature.max(1e-6);
    let m = row.iter().cloned().fold(f32::MIN, f32::max);
    let mut exps: Vec<f32> = row.iter().map(|x| ((x - m) * inv_t).exp()).collect();
    let sum: f32 = exps.iter().sum();
    for e in exps.iter_mut() {
        *e /= sum;
    }
    let idx = if greedy {
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    } else {
        let u = rng.uniform_f32();
        let mut acc = 0.0;
        let mut chosen = exps.len() - 1;
        for (i, p) in exps.iter().enumerate() {
            acc += p;
            if acc >= u {
                chosen = i;
                break;
            }
        }
        chosen
    };
    (idx as i32, exps[idx].max(1e-30).ln())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_row_greedy_picks_max() {
        let mut rng = Pcg64::new(1);
        let row = [0.1, 2.0, -1.0, 0.5];
        let (t, lp) = sample_row(&row, 1.0, true, &mut rng);
        assert_eq!(t, 1);
        assert!(lp < 0.0);
    }

    #[test]
    fn sample_row_respects_distribution() {
        let mut rng = Pcg64::new(2);
        let row = [10.0, 0.0, 0.0, 0.0]; // ~token 0 almost surely
        let hits = (0..200)
            .filter(|_| sample_row(&row, 1.0, false, &mut rng).0 == 0)
            .count();
        assert!(hits > 190, "{hits}");
    }

    #[test]
    fn sample_row_temperature_flattens() {
        let mut rng = Pcg64::new(3);
        let row = [3.0, 0.0];
        let cold = (0..500)
            .filter(|_| sample_row(&row, 0.25, false, &mut rng).0 == 0)
            .count();
        let hot = (0..500)
            .filter(|_| sample_row(&row, 4.0, false, &mut rng).0 == 0)
            .count();
        assert!(cold > hot, "cold={cold} hot={hot}");
    }

    #[test]
    fn request_context_len() {
        let mut r = Request::fresh(1, 0, 0, vec![1, 2, 3], 10);
        assert_eq!(r.context_len(), 3);
        r.resumed = vec![4, 5];
        assert_eq!(r.context_len(), 5);
    }
}
