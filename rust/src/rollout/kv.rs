//! Paged KV accounting — the memory model shared by the live engine, the
//! discrete-event simulator, and the deterministic test harness.
//!
//! The PR-3 reservation model charged every admitted lane its worst case
//! (`prompt + generation cap`) up front, which makes "budget never
//! exceeded" trivially hard — but it is exactly the over-conservative
//! admission RollPacker identifies as a utilization killer: most responses
//! finish far below the cap, so engines report "full" while the bulk of
//! their budget is unused.  Paged mode instead charges each lane its
//! *actual* context (prompt + tokens generated so far), rounded up to a
//! configurable page size — the vLLM-style block granularity — so usage
//! grows as lanes decode and is released the moment a lane leaves
//! (harvest, clip, preempt, steal, finish).
//!
//! Admission in paged mode is gated on a *predictor-informed estimate* of
//! the lane's final context (predicted total length, clamped to
//! `[progress + 1, cap]`, falling back to the cap when no token-count
//! prediction exists).  Because an estimate can undershoot, paged mode can
//! over-commit; the matching backpressure is:
//!
//!   * a **forced shed** inside each engine's decode step — if actual
//!     usage crosses the budget, the smallest-context lane is evicted back
//!     to the queue (progress kept, resume pays one re-prefill) until the
//!     budget holds again (or one lane remains, mirroring the
//!     empty-engine admission escape), keeping "actual usage never exceeds
//!     the budget" a hard invariant even under over-commit;
//!   * a **`KvPressure` signal** in `EngineLoad` plus the
//!     `Decision::Throttle` path (`sched::policy::KvGovernor`) that sheds
//!     proactively at the policy level before the forced path triggers;
//!   * **budget-aware dispatch** — the pool routes new work around
//!     KV-tight engines instead of queueing it behind a gate that will
//!     refuse it (`EnginePool::dispatch`, `SimPool::refill`), and the
//!     `WorkStealing` wrapper prefers KV-rich thieves.
//!
//! Reserve mode remains available (`--kv-mode reserve`) and is the
//! default, so every pre-paging decision golden stays byte-identical.

/// Default page size in tokens (`--kv-page`).
pub const DEFAULT_KV_PAGE: usize = 64;

/// Parse-time ceiling on `--kv-page`: a page larger than this exceeds any
/// plausible context and indicates a mistyped flag, not a configuration.
pub const MAX_KV_PAGE: usize = 1 << 20;

/// Convert a raw predictor output into the token-count stamp paged-KV
/// admission estimates consume: `None` for rank-only predictors (bucket
/// indices are not token counts) or non-finite outputs, otherwise at
/// least one token.  THE single definition — the live pool and the
/// simulator both stamp through here so their KV estimates cannot
/// silently diverge.
pub fn stamp_prediction(rank_only: bool, predicted: f64) -> Option<usize> {
    (!rank_only && predicted.is_finite()).then(|| predicted.max(1.0) as usize)
}

/// Predicted response tokens a lane still has to generate: the predicted
/// total (clamped exactly like [`KvConfig::admit_estimate`] — to
/// `[progress + 1, cap]`, cap fallback when no token-count prediction
/// exists) minus observed progress.  THE single remaining-work price used
/// by shed/preempt victim selection in every backend.
pub fn predicted_remaining(progress: usize, cap: usize, predicted: Option<usize>) -> usize {
    let floor = progress.saturating_add(1).min(cap.max(1));
    let total = predicted.unwrap_or(cap).clamp(floor, cap.max(1));
    total.saturating_sub(progress)
}

/// How admitted lanes are charged against the KV budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvMode {
    /// Charge `prompt + generation cap` at admission (worst case — the
    /// PR-3 model).  Cannot over-commit; wastes headroom on short
    /// responses.
    Reserve,
    /// Charge `prompt + tokens generated so far`, rounded up to the page
    /// size; admit on a predicted-length estimate.  Can over-commit;
    /// backpressure (shed/throttle/routing) keeps the budget hard.
    Paged,
}

impl KvMode {
    pub const ALL: [KvMode; 2] = [KvMode::Reserve, KvMode::Paged];

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "reserve" | "reserved" => Self::Reserve,
            "paged" | "page" => Self::Paged,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Reserve => "reserve",
            Self::Paged => "paged",
        }
    }
}

/// The per-engine KV memory model: mode + budget + page granularity.
/// `budget == usize::MAX` disables accounting entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvConfig {
    pub mode: KvMode,
    /// Budget in tokens of KV capacity per engine.
    pub budget: usize,
    /// Allocation granularity in tokens (paged mode only).
    pub page: usize,
}

impl Default for KvConfig {
    fn default() -> Self {
        KvConfig { mode: KvMode::Reserve, budget: usize::MAX, page: DEFAULT_KV_PAGE }
    }
}

impl KvConfig {
    pub fn unlimited(&self) -> bool {
        self.budget == usize::MAX
    }

    /// Round a context length up to whole pages.
    pub fn page_ceil(&self, tokens: usize) -> usize {
        let page = self.page.max(1);
        tokens.div_ceil(page).saturating_mul(page)
    }

    /// What an occupied lane charges against the budget right now.
    /// `held` is the response context the cache actually holds (resumed +
    /// emitted tokens); `cap` is the lane's total generation cap.
    pub fn lane_charge(&self, prompt: usize, held: usize, cap: usize) -> usize {
        match self.mode {
            KvMode::Reserve => prompt + cap,
            KvMode::Paged => self.page_ceil(prompt + held),
        }
    }

    /// What the admission gate charges a *candidate* request: the
    /// worst case in reserve mode; in paged mode a predictor-informed
    /// estimate of the final context — predicted total response length
    /// clamped to `[progress + 1, cap]`, falling back to the cap when no
    /// token-count prediction is available (rank-only predictors emit
    /// bucket indices, which must never be mixed with token quantities).
    pub fn admit_estimate(&self, prompt: usize, progress: usize, cap: usize,
                          predicted: Option<usize>) -> usize {
        match self.mode {
            KvMode::Reserve => prompt + cap,
            KvMode::Paged => {
                let floor = progress.saturating_add(1).min(cap.max(1));
                let total = predicted.unwrap_or(cap).clamp(floor, cap.max(1));
                self.page_ceil(prompt + total)
            }
        }
    }

    /// The admission gate shared by every backend: admitting `estimate`
    /// on top of `used` is refused iff occupied lanes already hold KV and
    /// the sum overruns the budget (the empty-engine escape admits any
    /// head request alone, so one oversized context cannot deadlock).
    pub fn gate_refuses(&self, used: usize, estimate: usize) -> bool {
        used > 0 && used.saturating_add(estimate) > self.budget
    }

    /// Budget headroom for dispatch/steal routing.  Unlimited budgets
    /// report `usize::MAX` — NOT `MAX - used` — so engines without
    /// accounting compare equal and routing stays byte-identical to the
    /// pre-paging behavior.
    pub fn headroom(&self, used: usize) -> usize {
        if self.unlimited() {
            usize::MAX
        } else {
            self.budget.saturating_sub(used)
        }
    }

    /// Per-page fragmentation a lane's context currently wastes: the slack
    /// between the page-rounded charge and the tokens actually held.
    /// Reserve mode charges the worst case regardless of pages, so its
    /// fragmentation is defined as zero (the victim tiebreak degrades to
    /// index order there).
    pub fn fragmentation(&self, prompt: usize, held: usize) -> usize {
        match self.mode {
            KvMode::Reserve => 0,
            KvMode::Paged => {
                let ctx = prompt.saturating_add(held);
                self.page_ceil(ctx).saturating_sub(ctx)
            }
        }
    }

    /// Sort key for shed/preempt victim selection: `(predicted remaining
    /// work, per-page fragmentation)`, both descending via `max_by_key`.
    /// Evicting the lane with the most predicted work left frees its KV
    /// for the longest stretch and defers exactly the request tail rounds
    /// exist to absorb (RollPacker's pricing — the PR-4 "smallest context"
    /// rule evicted whichever lane happened to be cheapest NOW, which is
    /// maximally wrong about the future).  Fragmentation breaks ties
    /// toward the lane wasting the most page slack.
    pub fn victim_key(&self, prompt: usize, held: usize, cap: usize,
                      predicted: Option<usize>) -> (usize, usize) {
        (predicted_remaining(held, cap, predicted), self.fragmentation(prompt, held))
    }

    /// Projected-overflow signal: in paged mode, every active lane can
    /// cross a page boundary within the next decode chunk, so usage may
    /// grow by one page per lane — `KvPressure` fires when that projection
    /// overruns the budget.  Reserve mode cannot over-commit and never
    /// signals pressure.
    pub fn pressure(&self, used: usize, active: usize) -> bool {
        self.mode == KvMode::Paged
            && !self.unlimited()
            && active > 0
            && used.saturating_add(active.saturating_mul(self.page.max(1))) > self.budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paged(budget: usize, page: usize) -> KvConfig {
        KvConfig { mode: KvMode::Paged, budget, page }
    }

    #[test]
    fn page_ceil_rounds_up_to_whole_pages() {
        let k = paged(1000, 16);
        assert_eq!(k.page_ceil(0), 0);
        assert_eq!(k.page_ceil(1), 16);
        assert_eq!(k.page_ceil(16), 16);
        assert_eq!(k.page_ceil(17), 32);
    }

    #[test]
    fn reserve_charges_worst_case_paged_charges_context() {
        let r = KvConfig { mode: KvMode::Reserve, budget: 1000, page: 16 };
        assert_eq!(r.lane_charge(64, 3, 512), 64 + 512);
        let p = paged(1000, 16);
        // 64 + 3 = 67 -> 5 pages of 16
        assert_eq!(p.lane_charge(64, 3, 512), 80);
    }

    #[test]
    fn admit_estimate_uses_prediction_clamped_to_cap_and_progress() {
        let p = paged(10_000, 1);
        // prediction drives the estimate
        assert_eq!(p.admit_estimate(64, 0, 512, Some(100)), 164);
        // no prediction: fall back to the cap (reserve-equivalent)
        assert_eq!(p.admit_estimate(64, 0, 512, None), 64 + 512);
        // prediction below observed progress is floored at progress + 1
        assert_eq!(p.admit_estimate(64, 200, 512, Some(100)), 64 + 201);
        // prediction above the cap is clamped to it
        assert_eq!(p.admit_estimate(64, 0, 512, Some(9_999)), 64 + 512);
    }

    #[test]
    fn gate_always_admits_into_an_empty_engine() {
        let p = paged(100, 1);
        assert!(!p.gate_refuses(0, 5_000), "empty-engine escape");
        assert!(p.gate_refuses(1, 5_000));
        assert!(!p.gate_refuses(50, 50));
        assert!(p.gate_refuses(50, 51));
    }

    #[test]
    fn headroom_is_max_when_unlimited() {
        let p = paged(usize::MAX, 16);
        assert_eq!(p.headroom(12_345), usize::MAX);
        let q = paged(100, 16);
        assert_eq!(q.headroom(40), 60);
        assert_eq!(q.headroom(200), 0);
    }

    #[test]
    fn pressure_projects_one_page_per_active_lane() {
        let p = paged(100, 10);
        assert!(!p.pressure(60, 3), "60 + 30 = 90 <= 100");
        assert!(p.pressure(75, 3), "75 + 30 > 100");
        assert!(!p.pressure(0, 0), "idle engine has no pressure");
        let r = KvConfig { mode: KvMode::Reserve, budget: 100, page: 10 };
        assert!(!r.pressure(99, 8), "reserve mode cannot over-commit");
    }

    #[test]
    fn predicted_remaining_clamps_like_the_admission_gate() {
        // oracle-ish prediction: remaining = predicted - progress
        assert_eq!(predicted_remaining(10, 512, Some(100)), 90);
        // no prediction: assume the cap
        assert_eq!(predicted_remaining(10, 512, None), 502);
        // prediction already overtaken by progress: floored at one token
        assert_eq!(predicted_remaining(200, 512, Some(100)), 1);
        // prediction past the cap: clamped to it
        assert_eq!(predicted_remaining(0, 512, Some(9_999)), 512);
    }

    #[test]
    fn victim_key_prices_remaining_work_then_fragmentation() {
        let p = paged(10_000, 16);
        // long-predicted lane outranks a short one regardless of context
        assert!(p.victim_key(64, 300, 512, Some(500)) > p.victim_key(64, 10, 512, Some(20)));
        // equal remaining work: the lane wasting more page slack loses
        // (held 16 -> ctx 80, 0 slack; held 17 -> ctx 81, 15 slack)
        assert!(p.victim_key(64, 17, 512, Some(100)) > p.victim_key(64, 16, 512, Some(99)));
        // reserve mode: fragmentation is defined as zero
        let r = KvConfig { mode: KvMode::Reserve, budget: 1000, page: 16 };
        assert_eq!(r.victim_key(64, 17, 512, Some(100)).1, 0);
    }

    #[test]
    fn mode_parse_name_round_trip() {
        for m in KvMode::ALL {
            assert_eq!(KvMode::parse(m.name()), Some(m));
        }
        assert_eq!(KvMode::parse("nope"), None);
    }
}
