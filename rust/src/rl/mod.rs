//! RL algorithm pieces that live in rust (advantages, trajectory records).
//!
//! The PPO-clip objective itself runs inside the AOT-compiled train_step
//! HLO (see python/compile/kernels/ppo_loss.py); rust computes advantages
//! and assembles update batches — the placement the paper's selective
//! batching requires.

pub mod advantage;

/// A completed (or partial-mode resumed-and-completed) trajectory, ready
/// for the trainer.  `old_logp[i]` is the *sampling-time* log-prob of
/// `response[i]` — the exact behavior-policy value (paper §3.2).
#[derive(Debug, Clone)]
pub struct Trajectory {
    pub problem_idx: usize,
    pub prompt_id: u64,
    pub prompt: Vec<i32>,
    pub response: Vec<i32>,
    pub old_logp: Vec<f32>,
    pub reward: f64,
    pub correct: bool,
    pub format_ok: bool,
    /// Policy version that generated the FIRST response token.
    pub born_version: u64,
    /// Policy version that generated the LAST response token (differs from
    /// born_version only for partial-mode resumed trajectories).
    pub finish_version: u64,
    /// Number of times this trajectory was interrupted and resumed.
    pub resumes: u32,
}

impl Trajectory {
    pub fn response_len(&self) -> usize {
        self.response.len()
    }

    pub fn total_len(&self) -> usize {
        self.prompt.len() + self.response.len()
    }

    /// Off-policy distance in policy versions at the time of an update
    /// performed by `current_version`.
    pub fn staleness(&self, current_version: u64) -> u64 {
        current_version.saturating_sub(self.born_version)
    }
}
