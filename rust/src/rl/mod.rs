//! RL algorithm pieces that live in rust (advantages, trajectory records).
//!
//! The PPO-clip objective itself runs inside the AOT-compiled train_step
//! HLO (see python/compile/kernels/ppo_loss.py); rust computes advantages
//! and assembles update batches — the placement the paper's selective
//! batching requires.

pub mod advantage;

/// THE canonical off-policy staleness definition — every call site (the
/// trainer's per-sample logs, the rollout cache's consume-time cap, the
/// simulator's modeled cap, [`Trajectory::staleness`]) computes through
/// this helper so the convention cannot fork again.
///
/// **Convention (pinned by `staleness_convention` below):** staleness is
/// the number of trainer updates COMPLETED between the policy version that
/// generated the sample's first response token (`born_version`) and the
/// version ENTERING the logical update that consumes it (`train_version`).
/// A sample born at version `v` and consumed by the very next update
/// (which enters at version `v`) has staleness 0 — it is exactly
/// on-policy.  Callers must pass the version at update ENTRY, not the
/// post-update version: an update of `k` micro-steps bumps
/// `ParamState::version` `k` times, and measuring after the bump would
/// inflate every sample by `k` (the trainer's old inline formula was off
/// by `k - 1` this way).  Saturating: a clock skew can never go negative.
pub fn staleness(train_version: u64, born_version: u64) -> u64 {
    train_version.saturating_sub(born_version)
}

/// A completed (or partial-mode resumed-and-completed) trajectory, ready
/// for the trainer.  `old_logp[i]` is the *sampling-time* log-prob of
/// `response[i]` — the exact behavior-policy value (paper §3.2).
#[derive(Debug, Clone)]
pub struct Trajectory {
    pub problem_idx: usize,
    pub prompt_id: u64,
    pub prompt: Vec<i32>,
    pub response: Vec<i32>,
    pub old_logp: Vec<f32>,
    pub reward: f64,
    pub correct: bool,
    pub format_ok: bool,
    /// Policy version that generated the FIRST response token.
    pub born_version: u64,
    /// Policy version that generated the LAST response token (differs from
    /// born_version only for partial-mode resumed trajectories).
    pub finish_version: u64,
    /// Number of times this trajectory was interrupted and resumed.
    pub resumes: u32,
}

impl Trajectory {
    pub fn response_len(&self) -> usize {
        self.response.len()
    }

    pub fn total_len(&self) -> usize {
        self.prompt.len() + self.response.len()
    }

    /// Off-policy distance in policy versions at the time of an update
    /// entering at `current_version` (delegates to the canonical
    /// [`staleness`] helper — see its doc for the exact convention).
    pub fn staleness(&self, current_version: u64) -> u64 {
        staleness(current_version, self.born_version)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pins the canonical convention: born at v, consumed by the update
    /// entering at v -> 0 (on-policy); one completed update in between ->
    /// 1; never negative under clock skew.
    #[test]
    fn staleness_convention() {
        assert_eq!(staleness(5, 5), 0);
        assert_eq!(staleness(6, 5), 1);
        assert_eq!(staleness(9, 5), 4);
        assert_eq!(staleness(3, 7), 0); // saturating, not underflowing
        let t = Trajectory {
            problem_idx: 0,
            prompt_id: 0,
            prompt: vec![],
            response: vec![],
            old_logp: vec![],
            reward: 0.0,
            correct: false,
            format_ok: false,
            born_version: 5,
            finish_version: 6,
            resumes: 1,
        };
        assert_eq!(t.staleness(7), staleness(7, 5));
    }
}
