//! Advantage estimators.
//!
//! The paper trains Reinforce++ on LogicRL and PPO on math, both with
//! outcome rewards.  The SortedRL-relevant property is that Reinforce++
//! normalizes by *batch* statistics (Eq. 3) — so which trajectories the
//! controller groups into an update batch changes the normalization, the
//! "selective batching" effect §3.1 calls out (and §6 highlights).

/// How per-trajectory advantages are computed from scalar rewards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdvantageKind {
    /// Reinforce++ (Eq. 3): z-score over the update batch.
    ReinforcePlusPlus,
    /// GRPO-style: z-score within each prompt's response group.
    GroupNorm,
    /// Raw reward minus a running baseline (no batch coupling).
    Baseline,
}

/// Per-trajectory inputs to advantage computation.
#[derive(Debug, Clone, Copy)]
pub struct RewardEntry {
    /// Total scalar reward of the trajectory.
    pub reward: f64,
    /// Group key (prompt id) for GroupNorm.
    pub group: u64,
}

#[derive(Debug, Default)]
pub struct BaselineState {
    mean: f64,
    count: u64,
}

impl BaselineState {
    pub fn update(&mut self, r: f64) {
        self.count += 1;
        self.mean += (r - self.mean) / self.count as f64;
    }

    pub fn value(&self) -> f64 {
        self.mean
    }
}

const EPS: f64 = 1e-6;

/// Compute one advantage per trajectory.
pub fn advantages(kind: AdvantageKind, entries: &[RewardEntry],
                  baseline: &mut BaselineState) -> Vec<f64> {
    match kind {
        AdvantageKind::ReinforcePlusPlus => {
            let rs: Vec<f64> = entries.iter().map(|e| e.reward).collect();
            let (mu, sigma) = crate::util::stats::mean_std(&rs);
            rs.iter().map(|r| (r - mu) / (sigma + EPS)).collect()
        }
        AdvantageKind::GroupNorm => {
            // group means/stds keyed by prompt
            use std::collections::HashMap;
            let mut groups: HashMap<u64, Vec<f64>> = HashMap::new();
            for e in entries {
                groups.entry(e.group).or_default().push(e.reward);
            }
            let stats: HashMap<u64, (f64, f64)> = groups
                .into_iter()
                .map(|(k, v)| (k, crate::util::stats::mean_std(&v)))
                .collect();
            entries
                .iter()
                .map(|e| {
                    let (mu, sigma) = stats[&e.group];
                    (e.reward - mu) / (sigma + EPS)
                })
                .collect()
        }
        AdvantageKind::Baseline => entries
            .iter()
            .map(|e| {
                let a = e.reward - baseline.value();
                baseline.update(e.reward);
                a
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entries(rs: &[f64]) -> Vec<RewardEntry> {
        rs.iter().map(|&reward| RewardEntry { reward, group: 0 }).collect()
    }

    #[test]
    fn reinforce_pp_is_zscore() {
        let mut b = BaselineState::default();
        let a = advantages(AdvantageKind::ReinforcePlusPlus,
                           &entries(&[1.0, 3.0]), &mut b);
        assert!((a[0] + 1.0).abs() < 1e-3);
        assert!((a[1] - 1.0).abs() < 1e-3);
    }

    #[test]
    fn reinforce_pp_batch_composition_matters() {
        // The same reward gets a different advantage depending on who else
        // is in the batch — the selective-batching effect.
        let mut b = BaselineState::default();
        let a1 = advantages(AdvantageKind::ReinforcePlusPlus,
                            &entries(&[2.0, 0.0, 0.0]), &mut b);
        let a2 = advantages(AdvantageKind::ReinforcePlusPlus,
                            &entries(&[2.0, 2.0, 0.0]), &mut b);
        assert!((a1[0] - a2[0]).abs() > 0.1);
    }

    #[test]
    fn group_norm_normalizes_within_prompt() {
        let es = vec![
            RewardEntry { reward: 1.0, group: 1 },
            RewardEntry { reward: 3.0, group: 1 },
            RewardEntry { reward: 100.0, group: 2 },
            RewardEntry { reward: 102.0, group: 2 },
        ];
        let mut b = BaselineState::default();
        let a = advantages(AdvantageKind::GroupNorm, &es, &mut b);
        // both groups normalize to ±1 despite wildly different scales
        assert!((a[0] + 1.0).abs() < 1e-3 && (a[1] - 1.0).abs() < 1e-3);
        assert!((a[2] + 1.0).abs() < 1e-3 && (a[3] - 1.0).abs() < 1e-3);
    }

    #[test]
    fn degenerate_batch_all_equal_rewards() {
        let mut b = BaselineState::default();
        let a = advantages(AdvantageKind::ReinforcePlusPlus,
                           &entries(&[1.0, 1.0, 1.0]), &mut b);
        for x in a {
            assert!(x.abs() < 1e-6);
        }
    }

    #[test]
    fn baseline_tracks_running_mean() {
        let mut b = BaselineState::default();
        let a = advantages(AdvantageKind::Baseline, &entries(&[1.0, 1.0, 4.0]),
                           &mut b);
        assert_eq!(a[0], 1.0);            // baseline starts at 0
        assert_eq!(a[1], 0.0);            // baseline now 1.0
        assert!((a[2] - 3.0).abs() < 1e-9);
        assert!((b.value() - 2.0).abs() < 1e-9);
    }
}
