//! `SchedulePolicy` — one scheduling brain for both the live controller
//! and the discrete-event simulator.
//!
//! The paper's stateful controller (§3) makes a small set of decisions:
//! when to load prompts, what to admit, when to stop generating, what to
//! clip/restart/resume at a harvest, and when to train.  Before this module
//! those decisions were written twice — once in the live coordinator's
//! hard-coded loops and once in the simulator — and every new schedule had
//! to be implemented in both and kept from drifting.
//!
//! Here a policy is written ONCE against two small traits:
//!
//!   * [`SchedulePolicy`] observes typed [`Event`]s and emits typed
//!     [`Decision`]s, plus a per-item harvest verdict ([`HarvestAction`]).
//!   * [`ScheduleBackend`] executes decisions against a concrete engine
//!     stack.  The **Live** impl (`coordinator::controller`) drives
//!     `EnginePool` + `RolloutBuffer` + `Trainer` + `Runtime`; the **Sim**
//!     impl (`sim`) drives the `CostModel`/`SimRequest` machinery.
//!
//! [`drive`] is the single generic loop: it asks the policy for a decision,
//! executes it on the backend, and feeds the resulting event back to the
//! policy — so a `SimReport` timeline and a live training run come from the
//! identical decision sequence.
//!
//! Shipped policies (one per `SchedulerKind`):
//!
//!   * [`GroupPolicy`] — SortedRL's grouped schedule, on-policy or partial
//!     (§3.1/§3.2): oversubscribe, early-terminate at the batching
//!     threshold, clip/restart/resume at harvests, drop never-scheduled
//!     leftovers at group end.
//!   * [`BaselinePolicy`] — sync-barrier rollout waves + k sequential
//!     updates (canonical VeRL pipeline), optionally post-hoc length-sorted
//!     (the Fig. 6a ablation).
//!   * [`NoGroupedPolicy`] — oversubscription without the group barrier;
//!     interrupted generations are abandoned (Fig. 6a's short-bias mode).
//!   * [`AsyncUpdatePolicy`] — NEW, and previously impossible to express:
//!     the trainer update overlaps continued decoding (PipelineRL-style).
//!     No harvest barrier before updates; staleness is bounded by a full
//!     re-sync harvest every `sync_every` updates via the existing
//!     partial-mode scavenge machinery.

use crate::coordinator::buffer::Mode;
use crate::coordinator::controller::SchedulerKind;
use crate::rollout::kv::{KvConfig, KvMode};
use crate::sched::tail::{TailConfig, TailPacking};
use crate::trace::Tracer;
use anyhow::Result;

/// Backend-agnostic snapshot of scheduler-relevant state.  Counts are in
/// buffer ENTRIES (the live backend holds G samples per prompt; the sim
/// backend one entry per request).
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedView {
    /// Requests actively decoding in engine lanes.
    pub running: usize,
    /// Requests waiting in engine/pool queues.
    pub queued: usize,
    /// Finished (or clipped) trajectories awaiting training.
    pub ready: usize,
    /// Entries loaded but never scheduled yet.
    pub fresh: usize,
    /// Entries loaded and not yet consumed by the trainer.
    pub unconsumed: usize,
    /// Total decode lanes across engines.
    pub lanes: usize,
    /// Trainer updates completed so far.
    pub updates: usize,
}

/// Knobs every shipped policy shares.  `refill_prompts` is in PROMPTS;
/// backends multiply by their own samples-per-prompt factor
/// (`entries_per_prompt` lets a policy convert entry deficits back).
#[derive(Debug, Clone, Copy)]
pub struct PolicyParams {
    /// Prompts loaded per group refill.
    pub refill_prompts: usize,
    /// Buffer entries created per loaded prompt (live: G; sim: 1).
    pub entries_per_prompt: usize,
    /// Trajectories per logical update.
    pub update_batch: usize,
}

/// Per-engine load snapshot — the pool-load view a work-stealing policy
/// reads.  `queued` counts the engine's LOCAL queue only (central-queue
/// work is not yet bound to an engine); `kv_used`/`kv_budget` are the KV
/// memory model in reservation tokens (a lane reserves prompt + generation
/// cap at admission; `usize::MAX` budget = accounting off).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineLoad {
    /// Requests waiting in this engine's local queue.
    pub queued: usize,
    /// Lanes actively decoding.
    pub active: usize,
    /// Total decode lanes.
    pub lanes: usize,
    /// Relative decode speed in Q8.8 fixed point ([`SPEED_Q8_UNIT`] =
    /// 1.0× — the homogeneous default).  Fixed point keeps `EngineLoad`
    /// `Eq` and the spec-normalized routing keys pure integer math.
    pub speed_q8: u32,
    /// KV reservation tokens held by active lanes.
    pub kv_used: usize,
    /// KV reservation budget (admission is rejected above this).
    pub kv_budget: usize,
    /// The KV gate currently refuses this engine's queue head: a free
    /// lane does NOT imply the local queue drains on its own, so a
    /// stealing policy must treat the engine as saturated.
    pub kv_blocked: bool,
    /// Paged-KV over-commit warning: projected usage (one more page per
    /// active lane) would overrun the budget.  The [`KvGovernor`] wrapper
    /// reacts with `Decision::Throttle` before the engine's forced
    /// eviction path has to fire.  Always false in reserve mode, which
    /// cannot over-commit.
    pub kv_pressure: bool,
}

/// Q8.8 fixed-point unit for [`EngineLoad::speed_q8`] / [`EngineSpec`]:
/// 256 = 1.0× relative decode speed.
pub const SPEED_Q8_UNIT: u32 = 256;

/// Convert a relative decode speed into the Q8.8 fixed point
/// [`EngineLoad::speed_q8`] carries (rounded; exact for powers of two,
/// floored at 1 so normalization never divides by zero).
pub fn speed_to_q8(speed: f64) -> u32 {
    ((speed * SPEED_Q8_UNIT as f64).round() as u32).max(1)
}

impl EngineLoad {
    /// KV headroom for routing decisions.  Unlimited budgets report
    /// `usize::MAX` — not `MAX - used` — so engines without accounting
    /// compare equal and KV-oblivious runs keep their exact pre-paging
    /// decision sequences.
    pub fn headroom(&self) -> usize {
        if self.kv_budget == usize::MAX {
            usize::MAX
        } else {
            self.kv_budget.saturating_sub(self.kv_used)
        }
    }

    /// Spec-normalized idle decode capacity: free lanes weighted by the
    /// engine's relative speed.  On a homogeneous fleet every engine
    /// scales by the same constant, so orderings (and the pinned steal
    /// goldens) are exactly the pre-spec ones.
    pub fn norm_free(&self) -> u64 {
        (self.lanes.saturating_sub(self.active)) as u64 * self.speed_q8 as u64
    }

    /// Spec-normalized backlog: queued work divided by relative speed (a
    /// slow engine's backlog costs proportionally more wall time).  Pure
    /// integer math; order-preserving on homogeneous fleets.
    pub fn norm_backlog(&self) -> u64 {
        self.norm_cost(self.queued)
    }

    /// Spec-normalized cost of `n` work items on this engine (divide by
    /// relative speed, Q8.8 scaled to stay integral).
    pub fn norm_cost(&self, n: usize) -> u64 {
        n as u64 * (SPEED_Q8_UNIT as u64 * SPEED_Q8_UNIT as u64)
            / self.speed_q8.max(1) as u64
    }
}

/// Static per-engine shape for heterogeneous fleets (`--engine-spec`):
/// lane count, KV budget and relative decode speed.  Parsed from
/// `LANES:KV[:SPEED]` atoms (`KV` may be `max`/`unlimited` = accounting
/// off; an optional `N x` prefix repeats an atom, e.g.
/// `2x8:4096:2,2x4:65536:0.5`).  Speeds are validated positive and
/// finite; powers of two keep the sim's clock arithmetic exact so the
/// Event≡Reference differential tests stay bitwise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineSpec {
    /// Decode lanes.
    pub lanes: usize,
    /// KV budget in tokens (`usize::MAX` = accounting off).
    pub kv_budget: usize,
    /// Relative decode speed (1.0 = baseline).
    pub speed: f64,
}

impl EngineSpec {
    /// The homogeneous default shape: `lanes`/`kv_budget` as given,
    /// speed 1.0.
    pub fn uniform(lanes: usize, kv_budget: usize) -> Self {
        EngineSpec { lanes, kv_budget, speed: 1.0 }
    }

    /// Speed in the Q8.8 fixed point [`EngineLoad::speed_q8`] carries
    /// (rounded; exact for power-of-two speeds).
    pub fn speed_q8(&self) -> u32 {
        speed_to_q8(self.speed)
    }

    /// Validate one spec the way the CLI validates `--queue`/`--kv-*`:
    /// at least one lane, a non-zero budget, and a positive finite speed.
    /// (`paged` budgets must additionally cover one prompt + one page —
    /// checked where the KV config is known, mirroring `--kv-page`.)
    pub fn validate(&self) -> Result<()> {
        if self.lanes == 0 {
            anyhow::bail!("engine spec: lanes must be >= 1");
        }
        if self.kv_budget == 0 {
            anyhow::bail!("engine spec: kv budget must be >= 1 (use 'max' for unlimited)");
        }
        if !(self.speed.is_finite() && self.speed > 0.0) {
            anyhow::bail!("engine spec: speed must be positive and finite");
        }
        Ok(())
    }

    /// Parse a comma-separated fleet spec (see type docs for the
    /// grammar).  Every atom is validated; the result is never empty.
    pub fn parse_fleet(s: &str) -> Result<Vec<EngineSpec>> {
        let mut fleet = Vec::new();
        for atom in s.split(',') {
            let atom = atom.trim();
            if atom.is_empty() {
                anyhow::bail!("engine spec: empty atom in '{s}'");
            }
            let (reps, body) = match atom.split_once(['x', 'X']) {
                Some((n, rest)) if n.trim().chars().all(|c| c.is_ascii_digit())
                    && !n.trim().is_empty() =>
                {
                    let reps: usize = n.trim().parse().map_err(|_| {
                        anyhow::anyhow!("engine spec: bad repeat count in '{atom}'")
                    })?;
                    if reps == 0 {
                        anyhow::bail!("engine spec: repeat count must be >= 1 in '{atom}'");
                    }
                    (reps, rest)
                }
                _ => (1, atom),
            };
            let mut parts = body.split(':');
            let lanes: usize = parts
                .next()
                .unwrap_or("")
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("engine spec: bad lane count in '{atom}'"))?;
            let kv_raw = parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("engine spec: missing kv budget in '{atom}' \
                                                (want LANES:KV[:SPEED])"))?
                .trim();
            let kv_budget = match kv_raw {
                "max" | "unlimited" => usize::MAX,
                n => n.parse().map_err(|_| {
                    anyhow::anyhow!("engine spec: bad kv budget in '{atom}'")
                })?,
            };
            let speed: f64 = match parts.next() {
                Some(sp) => sp.trim().parse().map_err(|_| {
                    anyhow::anyhow!("engine spec: bad speed in '{atom}'")
                })?,
                None => 1.0,
            };
            if parts.next().is_some() {
                anyhow::bail!("engine spec: too many fields in '{atom}' \
                               (want LANES:KV[:SPEED])");
            }
            let spec = EngineSpec { lanes, kv_budget, speed };
            spec.validate()?;
            fleet.extend(std::iter::repeat(spec).take(reps));
        }
        Ok(fleet)
    }
}

/// One active lane of one engine, as shown to a stealing policy when it
/// picks a migration victim.
#[derive(Debug, Clone, Copy)]
pub struct LaneView {
    pub lane: usize,
    /// Response tokens so far (resumed + emitted).
    pub progress: usize,
    /// KV reservation the lane holds (prompt + generation cap) — what a
    /// steal must fit into the destination's budget.
    pub reserve: usize,
}

/// One terminated in-flight (or queued) request at a harvest, as shown to
/// the policy.  Items arrive highest-progress-first.
#[derive(Debug, Clone, Copy)]
pub struct HarvestItem {
    pub rid: u64,
    /// Response tokens generated so far (0 = never ran).
    pub progress: usize,
    /// True if the request was waiting in a queue, not decoding.
    pub queued: bool,
}

/// The policy's verdict on one harvested item.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HarvestAction {
    /// Truncate and train as-is (§3.1 "partially generated outputs").
    Clip,
    /// Discard progress, re-queue the prompt from scratch (on-policy).
    Restart,
    /// Keep tokens + log-probs, resume later (partial mode).
    Resume,
    /// Untouched — back to the schedulable set.
    Requeue,
    /// Remove without training (group-end drops / no-grouped abandonment).
    Drop,
}

/// Typed events the driver feeds back to the policy.
#[derive(Debug, Clone)]
pub enum Event {
    /// A refill completed; `count` buffer entries were created (0 = the
    /// prompt source is exhausted).
    PromptsLoaded { count: usize },
    /// One generation tick completed; `finished` requests completed.
    Tick { finished: usize },
    /// A harvest completed; `count` items were classified.
    Harvested { count: usize },
    /// A trainer update completed.
    UpdateDone,
    /// Per-engine load snapshot, emitted after every executed `Step` (the
    /// pool-load view event work stealing triggers on).
    PoolLoad { loads: Vec<EngineLoad> },
    /// A `Steal` decision executed; `moved` is false when the backend
    /// refused it (no such work, or destination KV budget).
    Stole { from: usize, to: usize, moved: bool },
    /// A `Throttle` decision executed; `shed` is false when the backend
    /// refused it (engine gone, or only one lane running — the progress
    /// guarantee keeps the last lane decoding).
    Throttled { engine: usize, shed: bool },
    /// A `Repartition` decision executed; `applied` is false when the
    /// backend refused it (engine gone, or the new shape would strand
    /// running lanes / violate the KV ceiling — repartitions are
    /// transactional: applied whole or not at all).
    Repartitioned { engine: usize, applied: bool },
}

/// Typed decisions the policy emits.
#[derive(Debug, Clone)]
pub enum Decision {
    /// Load `prompts` more prompts into the buffer.
    Refill { prompts: usize },
    /// Dispatch these schedulable entries into the engine pool.
    /// `engine: Some(i)` pins them to engine i's local queue (targeted
    /// admission); `None` follows the backend's dispatch policy.
    Admit { rids: Vec<u64>, engine: Option<usize> },
    /// One generation tick (admit free lanes + one decode chunk).
    Step,
    /// Terminate everything in flight; the driver then asks
    /// [`SchedulePolicy::classify`] for a verdict on every item.
    Harvest,
    /// Preempt one running lane back to the pool queue, progress kept.
    Preempt { engine: usize, lane: usize },
    /// Migrate work from engine `from` to engine `to`: `lane: Some(l)`
    /// preempts running lane `l` and re-admits it on `to` (progress kept —
    /// Preempt + targeted Admit in one transactional step); `lane: None`
    /// moves the newest entry of `from`'s local queue.  The backend
    /// refuses moves past the destination's KV budget.
    Steal { from: usize, to: usize, lane: Option<usize> },
    /// Paged-KV backpressure: shed one lane of engine `engine` back to the
    /// queue (progress kept; the backend evicts the lane with the most
    /// predicted REMAINING work, fragmentation as tiebreak — see
    /// `rollout::kv::victim_key`) so projected usage drops below the
    /// budget — the deferral path that keeps over-committed admission
    /// from reaching the engines' forced in-step eviction.
    Throttle { engine: usize },
    /// Elastically resize engine `engine` to `lanes` decode lanes and a
    /// `kv` token budget (tail-round boundaries donate head capacity to
    /// the tail group and restore it after).  Transactional: the backend
    /// applies the whole new shape or refuses (never strands running
    /// lanes, never drops below committed KV).
    Repartition { engine: usize, lanes: usize, kv: usize },
    /// Train one update on these ready trajectories, in this order.
    Update { rids: Vec<u64> },
    /// Group end: drop consumed entries, re-align engine clocks.
    Barrier,
    /// Stop the run.
    Done,
}

impl Decision {
    /// Stable tally key for telemetry (`TelemetryHub::decisions`).
    pub fn label(&self) -> &'static str {
        match self {
            Decision::Refill { .. } => "refill",
            Decision::Admit { .. } => "admit",
            Decision::Step => "step",
            Decision::Harvest => "harvest",
            Decision::Preempt { .. } => "preempt",
            Decision::Steal { .. } => "steal",
            Decision::Throttle { .. } => "throttle",
            Decision::Repartition { .. } => "repartition",
            Decision::Update { .. } => "update",
            Decision::Barrier => "barrier",
            Decision::Done => "done",
        }
    }
}

/// A scheduling policy: pure decision logic, no engine or buffer access.
pub trait SchedulePolicy {
    fn name(&self) -> &'static str;

    /// Next decision given the backend's current state.  Policies may read
    /// `schedulable()` / `ready_rids()` to name rids in their decisions.
    fn decide(&mut self, backend: &dyn ScheduleBackend) -> Decision;

    /// Verdict for one harvested item.  `view` reflects verdicts already
    /// applied earlier in this harvest (clips raise `view.ready`).
    fn classify(&mut self, item: &HarvestItem, view: &SchedView) -> HarvestAction;

    /// Feedback after the driver executes a decision.
    fn observe(&mut self, _ev: &Event) {}

    /// Whether this policy consumes `Event::PoolLoad` snapshots.  The
    /// driver skips the per-step `engine_loads()` scan for policies that
    /// return false — at pool scale that scan is O(engines) of KV math
    /// per decode step, pure overhead for policies that never read it.
    /// Composers that react to load (stealing, KV governing) keep the
    /// default.
    fn wants_loads(&self) -> bool {
        true
    }
}

/// A concrete engine stack the driver executes decisions against.
pub trait ScheduleBackend {
    // ---- introspection ----
    fn view(&self) -> SchedView;
    /// Entries schedulable right now (fresh or scavenged), FIFO by rid.
    fn schedulable(&self) -> Vec<u64>;
    /// Ready entries in completion order.
    fn ready_rids(&self) -> Vec<u64>;
    /// Harvested response length of a Ready entry (post-hoc sort key).
    fn ready_len(&self, rid: u64) -> usize;
    /// Per-engine load snapshot (the pool-load view).  The default models
    /// the backend as one engine with KV accounting off — correct for
    /// single-engine backends, which a stealing policy then leaves alone.
    fn engine_loads(&self) -> Vec<EngineLoad> {
        let v = self.view();
        vec![EngineLoad {
            queued: v.queued,
            active: v.running,
            lanes: v.lanes,
            kv_used: 0,
            kv_budget: usize::MAX,
            kv_blocked: false,
            kv_pressure: false,
            speed_q8: SPEED_Q8_UNIT,
        }]
    }
    /// Active lanes of one engine (steal-victim selection).  Backends
    /// without lane introspection return nothing, which disables lane
    /// steals (queue steals may still work).
    fn engine_lanes(&self, _engine: usize) -> Vec<LaneView> {
        Vec::new()
    }
    /// The backend's own clock for trace timestamps, always at the POOL
    /// level (max over engines) so one run shares one monotone axis.
    /// Units are the backend's own (simulated seconds, host seconds,
    /// harness ticks).  The NaN default tells the tracer to fall back to
    /// counting executed `Step`s.
    fn trace_clock(&self) -> f64 {
        f64::NAN
    }
    /// `(lane, rid)` occupancy of one engine — the identity the tracer
    /// needs for first-token stamps and victim attribution, which
    /// [`ScheduleBackend::engine_lanes`] deliberately omits.  Backends
    /// without lane introspection return nothing; the tracer then falls
    /// back to stamping first tokens at finish time.
    fn lane_rids(&self, _engine: usize) -> Vec<(usize, u64)> {
        Vec::new()
    }
    /// Off-policy staleness (see [`crate::rl::staleness`]) of one entry the
    /// trainer just consumed, in completed-update versions.  Backends that
    /// stamp weight versions on their cached samples report the exact
    /// per-sample delta here and the tracer folds it into the telemetry
    /// hub's staleness histogram; the default (no version bookkeeping)
    /// reports nothing and the histogram stays empty.
    fn staleness_of(&self, _rid: u64) -> Option<u64> {
        None
    }
    /// Stamped length prediction for a schedulable entry, in response
    /// tokens — what [`crate::sched::tail::TailPacking`] compares against
    /// its threshold.  `None` means no token-denominated estimate exists
    /// (no predictor, or a rank-only one — see
    /// `rollout::kv::stamp_prediction`); tail packing then leaves the
    /// entry in the head rounds, so the wrapper is inert by construction
    /// exactly when estimates are meaningless.
    fn predicted_len(&self, _rid: u64) -> Option<usize> {
        None
    }

    // ---- actuation ----
    /// Load up to `prompts` prompts; returns buffer entries created.
    fn load_prompts(&mut self, prompts: usize) -> Result<usize>;
    /// Move these entries into the engine pool's admission queue
    /// (`engine: Some(i)` = engine i's local queue).
    fn admit(&mut self, rids: &[u64], engine: Option<usize>) -> Result<()>;
    /// One tick: admit queued work into free lanes + one decode chunk;
    /// finished rollouts are recorded Ready.  Returns requests finished.
    fn step(&mut self) -> Result<usize>;
    /// Terminate everything in flight (lanes AND queues), highest progress
    /// first.  Every in-flight entry appears in the result exactly once.
    fn harvest_candidates(&mut self) -> Result<Vec<HarvestItem>>;
    /// Apply one harvest verdict.
    fn resolve(&mut self, item: &HarvestItem, action: HarvestAction) -> Result<()>;
    /// Preempt one running lane back to the pool queue, progress kept.
    fn preempt(&mut self, engine: usize, lane: usize) -> Result<()>;
    /// Execute one migration (see [`Decision::Steal`]).  Returns true if
    /// work actually moved.  The default refuses every steal — correct for
    /// backends without targeted admission.
    fn steal(&mut self, _from: usize, _to: usize, _lane: Option<usize>) -> Result<bool> {
        Ok(false)
    }
    /// Execute one `Throttle` (shed the lane of `engine` with the most
    /// predicted remaining work — see `rollout::kv::victim_key` — back to
    /// the queue, progress kept).  Returns true if a lane was
    /// actually shed.  The default refuses — correct for backends without
    /// paged KV accounting, where pressure never arises.
    fn throttle(&mut self, _engine: usize) -> Result<bool> {
        Ok(false)
    }
    /// Execute one `Repartition` (see [`Decision::Repartition`]): resize
    /// one engine to a new lane count and KV budget, transactionally —
    /// the backend refuses (returns `Ok(false)`) any shape that would
    /// strand running lanes (`lanes < active`) or drop the budget below
    /// committed usage while more than one lane runs.  The default
    /// refuses every repartition — correct for backends without
    /// resizable engines.
    fn repartition(&mut self, _engine: usize, _lanes: usize, _kv: usize) -> Result<bool> {
        Ok(false)
    }
    /// Train one update on these Ready entries, in order.
    fn train(&mut self, rids: &[u64]) -> Result<()>;
    /// Group barrier: drop consumed entries, align engine clocks.
    fn barrier(&mut self) -> Result<()>;
    /// True when the run is over (live: max updates reached; sim: every
    /// workload request consumed or dropped).
    fn exhausted(&self) -> bool;
}

/// Hard ceiling on driver decisions — a policy livelock tripwire, far above
/// any legitimate run (paper-scale sims take ~1e6 decisions).
const MAX_DECISIONS: u64 = 200_000_000;
/// Consecutive no-op steps (no work anywhere) before the driver bails.
const MAX_IDLE_STEPS: usize = 10_000;
/// Consecutive decisions that cannot make progress (empty refills, empty
/// harvests, admissions, barriers) before the driver bails.  Only Step,
/// an executed Update, and a non-empty Refill count as progress — an
/// Admit/Harvest/requeue cycle that never decodes or trains is a livelock.
const MAX_FRUITLESS: usize = 10_000;

/// THE driver: executes one policy against one backend until the backend is
/// exhausted or the policy says [`Decision::Done`].  Live training runs and
/// simulator reports both come out of this loop.  Tracing-free entry point:
/// runs [`drive_traced`] with the no-op sink, whose taps return before
/// touching anything — decision sequences are byte-identical either way
/// (pinned by the policy goldens).
pub fn drive(policy: &mut dyn SchedulePolicy, backend: &mut dyn ScheduleBackend) -> Result<()> {
    drive_traced(policy, backend, &mut Tracer::disabled())
}

/// [`drive`] with a [`Tracer`] riding along.  This loop is the ONE tap
/// point for all per-request lifecycle telemetry: every backend records
/// through the same calls, so live runs, simulations and harness fuzzes
/// produce identically-shaped traces.  Taps only read the backend's
/// introspection surface and never influence a decision.
pub fn drive_traced(
    policy: &mut dyn SchedulePolicy,
    backend: &mut dyn ScheduleBackend,
    tracer: &mut Tracer,
) -> Result<()> {
    let mut decisions: u64 = 0;
    let mut idle_steps: usize = 0;
    let mut fruitless: usize = 0;
    tracer.begin(policy.name(), backend);
    while !backend.exhausted() {
        decisions += 1;
        if decisions > MAX_DECISIONS {
            anyhow::bail!("drive: decision budget exceeded (policy livelock?)");
        }
        if fruitless > MAX_FRUITLESS {
            anyhow::bail!("drive: {fruitless} consecutive decisions without \
                           decoding, training, or loading (policy livelock)");
        }
        let decision = policy.decide(backend);
        tracer.decision(&decision);
        match decision {
            Decision::Refill { prompts } => {
                tracer.pre_refill(backend);
                let count = backend.load_prompts(prompts)?;
                if count > 0 {
                    fruitless = 0;
                } else {
                    fruitless += 1;
                }
                tracer.post_refill(backend, count);
                policy.observe(&Event::PromptsLoaded { count });
            }
            Decision::Admit { rids, engine } => {
                fruitless += 1;
                if !rids.is_empty() {
                    backend.admit(&rids, engine)?;
                    tracer.admitted(backend, &rids);
                }
            }
            Decision::Step => {
                fruitless = 0;
                let before = backend.view();
                tracer.pre_step(backend);
                let finished = backend.step()?;
                if finished == 0 && before.running == 0 && before.queued == 0 {
                    idle_steps += 1;
                    if idle_steps > MAX_IDLE_STEPS {
                        anyhow::bail!("drive: policy keeps stepping an idle backend");
                    }
                } else {
                    idle_steps = 0;
                }
                if tracer.enabled() || policy.wants_loads() {
                    // one snapshot serves the tracer and the PoolLoad event
                    // (engine_loads is read-only, and the Tick observation
                    // cannot change backend state in between)
                    let loads = backend.engine_loads();
                    tracer.post_step(backend, &loads);
                    policy.observe(&Event::Tick { finished });
                    policy.observe(&Event::PoolLoad { loads });
                } else {
                    policy.observe(&Event::Tick { finished });
                }
            }
            Decision::Harvest => {
                fruitless += 1;
                tracer.pre_harvest(backend);
                let items = backend.harvest_candidates()?;
                for it in &items {
                    let act = policy.classify(it, &backend.view());
                    backend.resolve(it, act)?;
                    tracer.verdict(backend, it, act);
                }
                tracer.post_harvest(backend);
                policy.observe(&Event::Harvested { count: items.len() });
            }
            Decision::Preempt { engine, lane } => {
                fruitless += 1;
                tracer.pre_preempt(backend, engine, lane);
                backend.preempt(engine, lane)?;
            }
            Decision::Steal { from, to, lane } => {
                // a steal never decodes or trains by itself, so it counts
                // as fruitless — a steal-ponging policy trips the livelock
                // guard instead of spinning forever
                fruitless += 1;
                tracer.pre_steal(backend, from, lane);
                let moved = backend.steal(from, to, lane)?;
                tracer.post_steal(backend, from, to, moved);
                policy.observe(&Event::Stole { from, to, moved });
            }
            Decision::Throttle { engine } => {
                // same reasoning as Steal: shedding never decodes or
                // trains, so a throttle-spinning policy trips the guard
                fruitless += 1;
                tracer.pre_throttle(backend, engine);
                let shed = backend.throttle(engine)?;
                tracer.post_throttle(backend, engine, shed);
                policy.observe(&Event::Throttled { engine, shed });
            }
            Decision::Repartition { engine, lanes, kv } => {
                // resizing never decodes or trains either: a policy that
                // repartitions in a loop trips the livelock guard
                fruitless += 1;
                let applied = backend.repartition(engine, lanes, kv)?;
                tracer.post_repartition(backend, engine, lanes, applied);
                policy.observe(&Event::Repartitioned { engine, applied });
            }
            Decision::Update { rids } => {
                if rids.is_empty() {
                    fruitless += 1;
                } else {
                    fruitless = 0;
                    backend.train(&rids)?;
                    tracer.updated(backend, &rids);
                    policy.observe(&Event::UpdateDone);
                }
            }
            Decision::Barrier => {
                fruitless += 1;
                backend.barrier()?;
                tracer.barrier(backend);
            }
            Decision::Done => return Ok(()),
        }
    }
    Ok(())
}

/// THE one way to build a composed scheduling policy (replaces the old
/// `make_policy`/`make_policy_opts`/`make_policy_full`/
/// `make_policy_staleness` ladder, whose positional bools read as
/// `(kind, p, true, false, None)` at call sites).  Wrapping order is
/// fixed, innermost first:
///
///   base kind → [`KvGovernor`] (`.kv` paged) → [`WorkStealing`]
///   (`.steal`) → [`TailPacking`] (`.tail`)
///
/// The governor sits inside the stealing wrapper so a steal that
/// relieves a pressured engine is preferred over shedding its lane; tail
/// packing sits outermost so its deferrals filter every admission,
/// including ones the inner wrappers pass through.  The pinned policy
/// goldens run through this builder — its decision sequences are
/// byte-identical to the deleted ladder's.
pub struct PolicyBuilder {
    kind: SchedulerKind,
    params: PolicyParams,
    steal: bool,
    kv: KvConfig,
    staleness: Option<usize>,
    tail: Option<TailConfig>,
}

impl PolicyBuilder {
    /// Start from a scheduler kind and the shared knobs; all composition
    /// layers default off (reserve KV, no stealing, default async
    /// re-sync window, no tail packing).
    pub fn new(kind: SchedulerKind, params: PolicyParams) -> Self {
        PolicyBuilder {
            kind,
            params,
            steal: false,
            kv: KvConfig::default(),
            staleness: None,
            tail: None,
        }
    }

    /// Compose the [`WorkStealing`] wrapper (the `--steal` flag /
    /// `LoopConfig::steal`).
    pub fn steal(mut self, steal: bool) -> Self {
        self.steal = steal;
        self
    }

    /// KV accounting the run executes under.  Paged mode composes the
    /// [`KvGovernor`] backpressure wrapper; reserve mode cannot
    /// over-commit, so no governor is mounted and decision sequences
    /// stay byte-identical to the KV-oblivious ones.
    pub fn kv(mut self, kv: KvConfig) -> Self {
        self.kv = kv;
        self
    }

    /// The off-policy-degree knob (`--staleness N`).  For
    /// [`SchedulerKind::AsyncUpdate`], `Some(n)` derives the re-sync
    /// window (`sync_every = n`, replacing the [`ASYNC_SYNC_EVERY`]
    /// default) so the phase machine re-syncs on the same bound the
    /// backends enforce at consume time.  Other kinds run every sample
    /// on-policy (or resume under current weights), so the knob composes
    /// as a no-op there.
    pub fn staleness(mut self, staleness: Option<usize>) -> Self {
        self.staleness = staleness;
        self
    }

    /// Compose the [`TailPacking`] wrapper (`--tail-threshold` /
    /// `--tail-engines`): defer predicted-long requests out of head
    /// rounds into batched tail rounds with elastic lane/KV
    /// repartitioning.  Requires a predictor that stamps
    /// token-denominated estimates to have any effect (see
    /// [`ScheduleBackend::predicted_len`]).
    pub fn tail(mut self, tail: Option<TailConfig>) -> Self {
        self.tail = tail;
        self
    }

    /// Build the composed policy.
    pub fn build(self) -> Box<dyn SchedulePolicy> {
        let p = self.params;
        let mut policy: Box<dyn SchedulePolicy> = match (self.kind, self.staleness) {
            (SchedulerKind::AsyncUpdate, Some(n)) => Box::new(AsyncUpdatePolicy::new(p, n)),
            (SchedulerKind::AsyncUpdate, None) => {
                Box::new(AsyncUpdatePolicy::new(p, ASYNC_SYNC_EVERY))
            }
            (SchedulerKind::SortedOnPolicy, _) => Box::new(GroupPolicy::new(p, Mode::OnPolicy)),
            (SchedulerKind::SortedPartial, _) => Box::new(GroupPolicy::new(p, Mode::Partial)),
            (SchedulerKind::Baseline, _) => Box::new(BaselinePolicy::new(p, false)),
            (SchedulerKind::PostHocSort, _) => Box::new(BaselinePolicy::new(p, true)),
            (SchedulerKind::NoGroupedRollout, _) => Box::new(NoGroupedPolicy::new(p)),
        };
        if self.kv.mode == KvMode::Paged {
            policy = Box::new(KvGovernor::wrap(policy));
        }
        if self.steal {
            policy = Box::new(WorkStealing::wrap(policy, StealConfig::default()));
        }
        if let Some(tail) = self.tail {
            policy = Box::new(TailPacking::wrap(policy, tail));
        }
        policy
    }
}

/// AsyncUpdate's bounded-staleness window: a full re-sync harvest (partial
/// scavenge of every in-flight lane) after this many overlapped updates.
/// The `--staleness N` knob overrides it (see [`PolicyBuilder::staleness`]);
/// the consume-time cap in the backends enforces the same `N` on every
/// trained sample, so the phase machine and the cache can never disagree.
pub const ASYNC_SYNC_EVERY: usize = 4;

// ==========================================================================
// WorkStealing — cross-engine migration wrapper (composes with any policy)
// ==========================================================================

/// Knobs for the [`WorkStealing`] wrapper.
#[derive(Debug, Clone, Copy)]
pub struct StealConfig {
    /// Queue-steal trigger: a saturated peer's (all lanes busy, or KV
    /// budget refusing its queue head) local queue must be at least this
    /// deep while the destination has an empty queue and a free lane.
    pub queue_depth: usize,
    /// Lane-steal trigger: the victim must run at least this many lanes
    /// while the destination is FULLY idle (2+ prevents single-lane
    /// ping-pong).
    pub lane_gap: usize,
}

impl Default for StealConfig {
    fn default() -> Self {
        StealConfig { queue_depth: 1, lane_gap: 2 }
    }
}

/// Wrapper policy adding Seer-style cross-engine work stealing to ANY
/// [`SchedulePolicy`]: when an engine idles (free lane, empty local queue,
/// nothing central to pull) while a SATURATED peer (all lanes busy, or
/// KV-blocked) still has local backlog, its queued work migrates; a
/// running lane migrates only to a FULLY idle engine.  (Both victim/destination conditions are strict on
/// purpose: an engine with a free lane admits its own queue next tick, so
/// looser triggers just ping-pong work and pay re-prefill for nothing.)
/// At most one [`Decision::Steal`] fires per generation tick.  Victim
/// lanes are chosen lowest-progress-first (the cheapest migration — least
/// re-prefill) and never past the destination's KV budget; all other
/// decisions pass straight through to the inner policy, so stealing
/// composes with every `SchedulerKind`.
pub struct WorkStealing {
    inner: Box<dyn SchedulePolicy>,
    cfg: StealConfig,
    /// One steal attempt per tick: re-armed by `Event::Tick`, disarmed
    /// when a steal is emitted (bounds steal chatter between decodes).
    armed: bool,
    steals: u64,
}

impl WorkStealing {
    pub fn wrap(inner: Box<dyn SchedulePolicy>, cfg: StealConfig) -> Self {
        WorkStealing { inner, cfg, armed: true, steals: 0 }
    }

    /// Successful migrations so far.
    pub fn steals(&self) -> u64 {
        self.steals
    }

    fn plan(&self, b: &dyn ScheduleBackend) -> Option<Decision> {
        let loads = b.engine_loads();
        if loads.len() < 2 {
            return None;
        }
        // central-queue work is still late-binding: any engine can pull
        // it, so an idle engine is not starved and stealing would only
        // fight the dispatch policy
        let local: usize = loads.iter().map(|l| l.queued).sum();
        if b.view().queued > local {
            return None;
        }
        // 1) queue steal: the destination has a free lane and nothing
        // queued; the victim is the deepest backlog that cannot drain on
        // its own — lane-saturated, or KV-blocked (free lanes its budget
        // refuses to fill).  An engine that WILL admit its own queue next
        // tick is not a victim: stealing from it only ping-pongs the
        // request back.  Destinations rank by spec-normalized free decode
        // capacity (free lanes × speed — a fast engine's idle lane is
        // worth more), then KV headroom (ties at usize::MAX when
        // accounting is off); victims by spec-normalized backlog (queued ÷
        // speed — a slow engine's backlog hurts more).  On homogeneous
        // fleets both keys scale every engine by the same constant, so
        // the pre-spec selections (and the pinned steal goldens) are
        // reproduced exactly.
        if let Some(to) = (0..loads.len())
            .filter(|&i| loads[i].queued == 0 && loads[i].active < loads[i].lanes)
            .max_by_key(|&i| {
                (loads[i].norm_free(), loads[i].headroom(), std::cmp::Reverse(i))
            })
        {
            if let Some(from) = (0..loads.len())
                .filter(|&i| {
                    i != to
                        && loads[i].queued >= self.cfg.queue_depth
                        && (loads[i].active >= loads[i].lanes || loads[i].kv_blocked)
                })
                .max_by_key(|&i| (loads[i].norm_backlog(), std::cmp::Reverse(i)))
            {
                return Some(Decision::Steal { from, to, lane: None });
            }
        }
        // 2) lane steal: only a FULLY idle engine (no running lanes, no
        // queue) may pull a running lane — migration pays re-prefill, so
        // it is reserved for the motivating long-tail straggler case.
        // Among idle engines prefer the KV-richest, then the fastest
        // (equal headroom and speed — the homogeneous unlimited-budget
        // case — degrades to lowest index, the pre-paging selection);
        // then pick the peer with the most spec-normalized lane work and
        // its cheapest lane that fits that destination's headroom.
        let to = (0..loads.len())
            .filter(|&i| loads[i].queued == 0 && loads[i].active == 0)
            .max_by_key(|&i| (loads[i].headroom(), loads[i].speed_q8, std::cmp::Reverse(i)))?;
        let from = (0..loads.len())
            .filter(|&i| i != to && loads[i].active >= self.cfg.lane_gap)
            .max_by_key(|&i| (loads[i].norm_cost(loads[i].active), std::cmp::Reverse(i)))?;
        let headroom = loads[to].headroom();
        let lane = b
            .engine_lanes(from)
            .into_iter()
            .filter(|l| l.reserve <= headroom)
            .min_by_key(|l| (l.progress, l.lane))?;
        Some(Decision::Steal { from, to, lane: Some(lane.lane) })
    }
}

impl SchedulePolicy for WorkStealing {
    fn name(&self) -> &'static str {
        "work-stealing"
    }

    fn decide(&mut self, b: &dyn ScheduleBackend) -> Decision {
        if self.armed {
            if let Some(d) = self.plan(b) {
                self.armed = false;
                return d;
            }
        }
        self.inner.decide(b)
    }

    fn classify(&mut self, item: &HarvestItem, view: &SchedView) -> HarvestAction {
        self.inner.classify(item, view)
    }

    fn observe(&mut self, ev: &Event) {
        match ev {
            Event::Tick { .. } => self.armed = true,
            Event::Stole { moved, .. } => {
                if *moved {
                    self.steals += 1;
                }
            }
            _ => {}
        }
        self.inner.observe(ev);
    }
}

// ==========================================================================
// KvGovernor — paged-KV backpressure wrapper (composes with any policy)
// ==========================================================================

/// Wrapper policy that watches the `PoolLoad` snapshots for `KvPressure`
/// (a paged engine whose projected usage would overrun its budget) and
/// emits [`Decision::Throttle`] for the most-pressured engine: the backend
/// sheds the lane with the most predicted remaining work (fragmentation
/// as tiebreak) back to the queue, progress kept, so
/// the budget holds *before* the engine's forced in-step eviction has to
/// fire — and the shed work re-enters dispatch, where budget-aware routing
/// can place it on a KV-richer engine instead.
///
/// Like [`WorkStealing`], at most one throttle fires per generation tick
/// (re-armed by `Event::Tick`), engines running a single lane are never
/// throttled (the progress guarantee), and every other decision passes
/// straight through — in reserve mode pressure never arises, so the
/// wrapper is inert and decision sequences stay byte-identical.
pub struct KvGovernor {
    inner: Box<dyn SchedulePolicy>,
    /// Engines pressured in the latest `PoolLoad` snapshot.
    pressured: Vec<usize>,
    armed: bool,
    throttles: u64,
}

impl KvGovernor {
    pub fn wrap(inner: Box<dyn SchedulePolicy>) -> Self {
        KvGovernor { inner, pressured: Vec::new(), armed: true, throttles: 0 }
    }

    /// Successful sheds so far.
    pub fn throttles(&self) -> u64 {
        self.throttles
    }
}

impl SchedulePolicy for KvGovernor {
    fn name(&self) -> &'static str {
        "kv-governor"
    }

    fn decide(&mut self, b: &dyn ScheduleBackend) -> Decision {
        if self.armed && !self.pressured.is_empty() {
            let loads = b.engine_loads();
            // re-validate against live state: the snapshot may predate a
            // harvest or steal that already relieved the pressure
            if let Some(engine) = self
                .pressured
                .iter()
                .copied()
                .filter(|&i| {
                    loads.get(i).is_some_and(|l| l.kv_pressure && l.active >= 2)
                })
                .max_by_key(|&i| (loads[i].kv_used, std::cmp::Reverse(i)))
            {
                self.armed = false;
                return Decision::Throttle { engine };
            }
        }
        self.inner.decide(b)
    }

    fn classify(&mut self, item: &HarvestItem, view: &SchedView) -> HarvestAction {
        self.inner.classify(item, view)
    }

    fn observe(&mut self, ev: &Event) {
        match ev {
            Event::Tick { .. } => self.armed = true,
            Event::PoolLoad { loads } => {
                self.pressured = loads
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| l.kv_pressure)
                    .map(|(i, _)| i)
                    .collect();
            }
            Event::Throttled { shed, .. } => {
                if *shed {
                    self.throttles += 1;
                }
            }
            _ => {}
        }
        self.inner.observe(ev);
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Refill,
    Dispatch,
    Generate,
    HarvestNow,
    Consume,
    CycleEnd,
}

// ==========================================================================
// GroupPolicy — SortedRL grouped schedule (on-policy / partial)
// ==========================================================================

/// SortedRL's grouped schedule (§3.1): one group of prompts is consumed
/// fully before new prompts load (cache-aware loading); generation
/// early-terminates at the batching threshold; harvests clip/restart/resume
/// per `Mode`; never-scheduled leftovers are dropped at group end.
pub struct GroupPolicy {
    p: PolicyParams,
    mode: Mode,
    phase: Phase,
    quota: usize,
    threshold: usize,
    occ_floor: usize,
    final_wave: bool,
    refill_empty: bool,
    /// One update per harvest cycle (legacy run_group consumed once per
    /// wave): leftover ready beyond `update_batch` waits for the next
    /// cycle so it lands inside a full-size batch.
    updated_this_cycle: bool,
}

impl GroupPolicy {
    pub fn new(p: PolicyParams, mode: Mode) -> Self {
        GroupPolicy {
            p,
            mode,
            phase: Phase::Refill,
            quota: 1,
            threshold: 1,
            occ_floor: 1,
            final_wave: false,
            refill_empty: false,
            updated_this_cycle: false,
        }
    }
}

impl SchedulePolicy for GroupPolicy {
    fn name(&self) -> &'static str {
        match self.mode {
            Mode::OnPolicy => "sorted-on-policy",
            Mode::Partial => "sorted-partial",
        }
    }

    fn wants_loads(&self) -> bool {
        false // threshold logic reads view() only
    }

    fn decide(&mut self, b: &dyn ScheduleBackend) -> Decision {
        loop {
            let v = b.view();
            match self.phase {
                Phase::Refill => {
                    if v.unconsumed > 0 {
                        self.phase = Phase::Dispatch;
                        continue;
                    }
                    if self.refill_empty {
                        return Decision::Done;
                    }
                    self.phase = Phase::Dispatch;
                    return Decision::Refill { prompts: self.p.refill_prompts };
                }
                Phase::Dispatch => {
                    // wave parameters, recomputed at every wave start
                    self.quota = self.p.update_batch.min(v.unconsumed).max(1);
                    self.threshold = match self.mode {
                        Mode::OnPolicy => (self.quota * 3 / 4).max(1),
                        Mode::Partial => self.quota,
                    };
                    self.final_wave = v.unconsumed <= self.p.update_batch;
                    self.occ_floor = (v.lanes * 3 / 4).max(1);
                    self.phase = Phase::Generate;
                    let rids = b.schedulable();
                    if rids.is_empty() {
                        continue;
                    }
                    return Decision::Admit { rids, engine: None };
                }
                Phase::Generate => {
                    if v.ready >= self.threshold && !self.final_wave {
                        // early termination: batching threshold reached
                        self.phase = Phase::HarvestNow;
                        continue;
                    }
                    if self.final_wave && v.queued == 0 && v.running < self.occ_floor {
                        // batching floor: clip the stragglers
                        self.phase = Phase::HarvestNow;
                        continue;
                    }
                    if v.running == 0 && v.queued == 0 {
                        if v.ready == 0 && b.schedulable().is_empty() {
                            // nothing running, ready, or schedulable
                            return Decision::Done;
                        }
                        self.phase = Phase::HarvestNow;
                        continue;
                    }
                    return Decision::Step;
                }
                Phase::HarvestNow => {
                    self.phase = Phase::Consume;
                    self.updated_this_cycle = false;
                    return Decision::Harvest;
                }
                Phase::Consume => {
                    if v.unconsumed == 0 {
                        self.phase = Phase::Refill;
                        return Decision::Barrier;
                    }
                    let ready = b.ready_rids();
                    // After this cycle's update, a SMALL leftover (below the
                    // wave threshold) waits for the next wave so it lands in
                    // a full batch; a leftover at/above the threshold is
                    // consumed back-to-back — regenerating first would just
                    // re-admit work the next harvest immediately terminates.
                    let defer = self.updated_this_cycle
                        && ready.len() < self.threshold
                        && !b.schedulable().is_empty();
                    if ready.is_empty() || defer {
                        if ready.is_empty()
                            && b.schedulable().is_empty()
                            && v.running == 0
                            && v.queued == 0
                        {
                            return Decision::Done;
                        }
                        self.phase = Phase::Dispatch;
                        continue;
                    }
                    self.updated_this_cycle = true;
                    let rids: Vec<u64> =
                        ready.into_iter().take(self.p.update_batch).collect();
                    return Decision::Update { rids };
                }
                Phase::CycleEnd => unreachable!("GroupPolicy has no CycleEnd"),
            }
        }
    }

    fn classify(&mut self, item: &HarvestItem, view: &SchedView) -> HarvestAction {
        if item.progress == 0 {
            // never produced a token: re-queue mid-group, drop at group end
            if self.final_wave {
                HarvestAction::Drop
            } else {
                HarvestAction::Requeue
            }
        } else if self.final_wave
            || (self.mode == Mode::OnPolicy && view.ready < self.quota)
        {
            // §3.1: harvest "both completed and partially generated
            // outputs" — highest-progress runners fill the update batch
            HarvestAction::Clip
        } else {
            match self.mode {
                Mode::OnPolicy => HarvestAction::Restart,
                Mode::Partial => HarvestAction::Resume,
            }
        }
    }

    fn observe(&mut self, ev: &Event) {
        if let Event::PromptsLoaded { count } = ev {
            self.refill_empty = *count == 0;
        }
    }
}

// ==========================================================================
// BaselinePolicy — sync-barrier waves (+ post-hoc sort ablation)
// ==========================================================================

/// Canonical baseline: load one rollout batch, run it to full completion
/// behind a sync barrier, then k sequential updates on the (aging) data.
/// `post_hoc_sort` trains in length-ascending order (the Fig. 6a ablation).
pub struct BaselinePolicy {
    p: PolicyParams,
    post_hoc_sort: bool,
    phase: Phase,
    refill_empty: bool,
}

impl BaselinePolicy {
    pub fn new(p: PolicyParams, post_hoc_sort: bool) -> Self {
        BaselinePolicy { p, post_hoc_sort, phase: Phase::Refill, refill_empty: false }
    }
}

impl SchedulePolicy for BaselinePolicy {
    fn name(&self) -> &'static str {
        if self.post_hoc_sort {
            "post-hoc-sort"
        } else {
            "baseline"
        }
    }

    fn wants_loads(&self) -> bool {
        false
    }

    fn decide(&mut self, b: &dyn ScheduleBackend) -> Decision {
        loop {
            let v = b.view();
            match self.phase {
                Phase::Refill => {
                    if v.unconsumed > 0 {
                        self.phase = Phase::Dispatch;
                        continue;
                    }
                    if self.refill_empty {
                        return Decision::Done;
                    }
                    self.phase = Phase::Dispatch;
                    return Decision::Refill { prompts: self.p.refill_prompts };
                }
                Phase::Dispatch => {
                    self.phase = Phase::Generate;
                    let rids = b.schedulable();
                    if rids.is_empty() {
                        continue;
                    }
                    return Decision::Admit { rids, engine: None };
                }
                Phase::Generate => {
                    if v.running == 0 && v.queued == 0 {
                        // sync barrier: the whole wave completed
                        self.phase = Phase::Consume;
                        continue;
                    }
                    return Decision::Step;
                }
                Phase::Consume => {
                    let ready = b.ready_rids();
                    if ready.is_empty() {
                        if v.unconsumed == 0 {
                            self.phase = Phase::Refill;
                            return Decision::Barrier;
                        }
                        if b.schedulable().is_empty() && v.running == 0 && v.queued == 0 {
                            return Decision::Done;
                        }
                        self.phase = Phase::Dispatch;
                        continue;
                    }
                    let mut order: Vec<u64> = ready;
                    if self.post_hoc_sort {
                        // sort by response length ascending AFTER generation
                        let mut keyed: Vec<(usize, u64)> =
                            order.iter().map(|&r| (b.ready_len(r), r)).collect();
                        keyed.sort();
                        order = keyed.into_iter().map(|(_, r)| r).collect();
                    }
                    let rids: Vec<u64> =
                        order.into_iter().take(self.p.update_batch).collect();
                    return Decision::Update { rids };
                }
                _ => unreachable!("BaselinePolicy phase {:?}", self.phase),
            }
        }
    }

    fn classify(&mut self, _item: &HarvestItem, _view: &SchedView) -> HarvestAction {
        // the baseline never harvests mid-generation; inert verdict
        HarvestAction::Requeue
    }

    fn observe(&mut self, ev: &Event) {
        if let Event::PromptsLoaded { count } = ev {
            self.refill_empty = *count == 0;
        }
    }
}

// ==========================================================================
// NoGroupedPolicy — oversubscription without the group barrier (Fig. 6a)
// ==========================================================================

/// Ablation: the pool is continuously topped up with fresh prompts (no
/// grouped-loading barrier) and interrupted generations are abandoned
/// outright, so training data biases hard toward short responses.
pub struct NoGroupedPolicy {
    p: PolicyParams,
    phase: Phase,
    refill_empty: bool,
}

impl NoGroupedPolicy {
    pub fn new(p: PolicyParams) -> Self {
        NoGroupedPolicy { p, phase: Phase::Refill, refill_empty: false }
    }
}

impl SchedulePolicy for NoGroupedPolicy {
    fn name(&self) -> &'static str {
        "no-grouped"
    }

    fn wants_loads(&self) -> bool {
        false
    }

    fn decide(&mut self, b: &dyn ScheduleBackend) -> Decision {
        loop {
            let v = b.view();
            match self.phase {
                Phase::Refill => {
                    // top up: fresh prompts stream in with no barrier.
                    // Deliberate unit fix vs the legacy loop: the target is
                    // refill_prompts PROMPTS = refill_prompts * G entries
                    // (legacy compared a prompt count against an entry
                    // count, under-filling the pool by the G factor).
                    let target = self.p.refill_prompts * self.p.entries_per_prompt;
                    let deficit = target.saturating_sub(v.fresh);
                    self.phase = Phase::Dispatch;
                    if deficit > 0 && !self.refill_empty {
                        return Decision::Refill {
                            prompts: deficit.div_ceil(self.p.entries_per_prompt.max(1)),
                        };
                    }
                    continue;
                }
                Phase::Dispatch => {
                    self.phase = Phase::Generate;
                    let rids = b.schedulable();
                    if rids.is_empty() {
                        continue;
                    }
                    return Decision::Admit { rids, engine: None };
                }
                Phase::Generate => {
                    if v.ready >= self.p.update_batch {
                        self.phase = Phase::HarvestNow;
                        continue;
                    }
                    if v.running == 0 && v.queued == 0 {
                        if v.ready == 0 && b.schedulable().is_empty() {
                            return Decision::Done;
                        }
                        self.phase = Phase::HarvestNow;
                        continue;
                    }
                    return Decision::Step;
                }
                Phase::HarvestNow => {
                    self.phase = Phase::Consume;
                    return Decision::Harvest;
                }
                Phase::Consume => {
                    let ready = b.ready_rids();
                    if ready.is_empty() {
                        self.phase = Phase::Refill;
                        if v.running == 0
                            && v.queued == 0
                            && self.refill_empty
                            && b.schedulable().is_empty()
                        {
                            return Decision::Done;
                        }
                        continue;
                    }
                    let rids: Vec<u64> =
                        ready.into_iter().take(self.p.update_batch).collect();
                    self.phase = Phase::CycleEnd;
                    return Decision::Update { rids };
                }
                Phase::CycleEnd => {
                    self.phase = Phase::Refill;
                    return Decision::Barrier;
                }
            }
        }
    }

    fn classify(&mut self, item: &HarvestItem, _view: &SchedView) -> HarvestAction {
        if item.progress > 0 {
            // abandon interrupted generations entirely (prompt starvation)
            HarvestAction::Drop
        } else {
            HarvestAction::Requeue
        }
    }

    fn observe(&mut self, ev: &Event) {
        if let Event::PromptsLoaded { count } = ev {
            self.refill_empty = *count == 0;
        }
    }
}

// ==========================================================================
// AsyncUpdatePolicy — overlap trainer updates with continued decoding
// ==========================================================================

/// PipelineRL-style async schedule: when the batching threshold fires, the
/// update runs WITHOUT a harvest barrier — in-flight lanes keep decoding
/// (live: lanes keep their KV and continue under the new weights; sim: the
/// update's modeled cost overlaps engine clocks).  Tokens sampled before an
/// update keep their behavior-policy log-probs, so the existing
/// partial-mode importance machinery handles the staleness.  A full re-sync
/// harvest (partial scavenge) every `sync_every` updates bounds how far any
/// lane can lag the trainer.
pub struct AsyncUpdatePolicy {
    p: PolicyParams,
    sync_every: usize,
    updates_since_sync: usize,
    phase: Phase,
    quota: usize,
    occ_floor: usize,
    final_wave: bool,
    /// The next harvest is a bounded-staleness re-sync (scavenge + resume
    /// under fresh weights), not a group-end clip: progress survives and
    /// never-run work re-queues even when `final_wave` is set.
    resync: bool,
    refill_empty: bool,
}

impl AsyncUpdatePolicy {
    pub fn new(p: PolicyParams, sync_every: usize) -> Self {
        AsyncUpdatePolicy {
            p,
            sync_every: sync_every.max(1),
            updates_since_sync: 0,
            phase: Phase::Refill,
            quota: 1,
            occ_floor: 1,
            final_wave: false,
            resync: false,
            refill_empty: false,
        }
    }
}

impl SchedulePolicy for AsyncUpdatePolicy {
    fn name(&self) -> &'static str {
        "async"
    }

    fn wants_loads(&self) -> bool {
        false
    }

    fn decide(&mut self, b: &dyn ScheduleBackend) -> Decision {
        loop {
            let v = b.view();
            match self.phase {
                Phase::Refill => {
                    if v.unconsumed > 0 {
                        self.phase = Phase::Dispatch;
                        continue;
                    }
                    if self.refill_empty {
                        return Decision::Done;
                    }
                    self.phase = Phase::Dispatch;
                    return Decision::Refill { prompts: self.p.refill_prompts };
                }
                Phase::Dispatch => {
                    self.quota = self.p.update_batch.min(v.unconsumed).max(1);
                    self.final_wave = v.unconsumed <= self.p.update_batch;
                    self.occ_floor = (v.lanes * 3 / 4).max(1);
                    self.phase = Phase::Generate;
                    let rids = b.schedulable();
                    if rids.is_empty() {
                        continue;
                    }
                    return Decision::Admit { rids, engine: None };
                }
                Phase::Generate => {
                    if v.ready >= self.quota {
                        // enough finished work: update NOW, lanes keep
                        // decoding — no harvest barrier (the async win)
                        self.phase = Phase::Consume;
                        continue;
                    }
                    if self.updates_since_sync >= self.sync_every
                        && (v.running > 0 || v.queued > 0)
                    {
                        // bounded staleness: full re-sync harvest.  This
                        // fires during the final wave too — the long-tail
                        // endgame is exactly where lanes decode longest
                        // between updates, so exempting it (as this branch
                        // once did) let final-wave lanes lag the trainer
                        // unboundedly.
                        self.updates_since_sync = 0;
                        self.resync = true;
                        self.phase = Phase::HarvestNow;
                        continue;
                    }
                    if self.final_wave && v.queued == 0 && v.running < self.occ_floor {
                        self.phase = Phase::HarvestNow;
                        continue;
                    }
                    if v.running == 0 && v.queued == 0 {
                        if v.ready > 0 || v.unconsumed == 0 {
                            // consume leftovers — or, with the whole group
                            // consumed, let Consume hit the group barrier
                            // so the next group loads (live runs continue
                            // to max_updates across many groups)
                            self.phase = Phase::Consume;
                            continue;
                        }
                        if b.schedulable().is_empty() {
                            return Decision::Done;
                        }
                        self.phase = Phase::Dispatch;
                        continue;
                    }
                    return Decision::Step;
                }
                Phase::HarvestNow => {
                    self.phase = Phase::Consume;
                    return Decision::Harvest;
                }
                Phase::Consume => {
                    if v.unconsumed == 0 {
                        self.phase = Phase::Refill;
                        return Decision::Barrier;
                    }
                    let ready = b.ready_rids();
                    if ready.is_empty() {
                        self.phase = Phase::Dispatch;
                        continue;
                    }
                    let rids: Vec<u64> =
                        ready.into_iter().take(self.p.update_batch).collect();
                    self.phase = Phase::Dispatch;
                    return Decision::Update { rids };
                }
                Phase::CycleEnd => unreachable!("AsyncUpdatePolicy has no CycleEnd"),
            }
        }
    }

    fn classify(&mut self, item: &HarvestItem, _view: &SchedView) -> HarvestAction {
        // partial-mode semantics: progress always survives a harvest.  A
        // re-sync harvest keeps the mid-group verdicts even in the final
        // wave — it exists to refresh lanes onto current weights, not to
        // end the group, so clipping runners or dropping never-run queue
        // entries there would trade data for nothing.
        if item.progress == 0 {
            if self.final_wave && !self.resync {
                HarvestAction::Drop
            } else {
                HarvestAction::Requeue
            }
        } else if self.final_wave && !self.resync {
            HarvestAction::Clip
        } else {
            HarvestAction::Resume
        }
    }

    fn observe(&mut self, ev: &Event) {
        match ev {
            Event::PromptsLoaded { count } => self.refill_empty = *count == 0,
            Event::UpdateDone => self.updates_since_sync += 1,
            Event::Harvested { .. } => self.resync = false,
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::VecDeque;

    /// Deterministic in-memory backend: every request emits one token per
    /// tick; lane admission is FIFO.  Used to pin policy decision sequences
    /// by hand (see `tests/policy_golden.rs` for the buffer-backed mirror).
    struct MockBackend {
        lens: Vec<usize>,
        progress: Vec<usize>,
        // 0 = unloaded, 1 = fresh, 2 = in pool, 3 = ready, 4 = consumed
        state: Vec<u8>,
        lanes: usize,
        running: Vec<u64>,
        queue: VecDeque<u64>,
        ready: Vec<u64>,
        consumed: Vec<u64>,
        clipped: Vec<u64>,
        dropped: Vec<u64>,
        updates: usize,
        harvests: usize,
        next_load: usize,
    }

    impl MockBackend {
        fn new(lens: Vec<usize>, lanes: usize) -> Self {
            let n = lens.len();
            MockBackend {
                lens,
                progress: vec![0; n],
                state: vec![0; n],
                lanes,
                running: Vec::new(),
                queue: VecDeque::new(),
                ready: Vec::new(),
                consumed: Vec::new(),
                clipped: Vec::new(),
                dropped: Vec::new(),
                updates: 0,
                harvests: 0,
                next_load: 0,
            }
        }

        fn fill_lanes(&mut self) {
            while self.running.len() < self.lanes {
                let Some(rid) = self.queue.pop_front() else { break };
                self.running.push(rid);
            }
        }
    }

    impl ScheduleBackend for MockBackend {
        fn view(&self) -> SchedView {
            SchedView {
                running: self.running.len(),
                queued: self.queue.len(),
                ready: self.ready.len(),
                fresh: self.state.iter().filter(|&&s| s == 1).count(),
                unconsumed: self.state.iter().filter(|&&s| (1..=3).contains(&s)).count(),
                lanes: self.lanes,
                updates: self.updates,
            }
        }

        fn schedulable(&self) -> Vec<u64> {
            (0..self.lens.len())
                .filter(|&i| self.state[i] == 1)
                .map(|i| i as u64)
                .collect()
        }

        fn ready_rids(&self) -> Vec<u64> {
            self.ready.clone()
        }

        fn ready_len(&self, rid: u64) -> usize {
            self.progress[rid as usize]
        }

        fn load_prompts(&mut self, prompts: usize) -> Result<usize> {
            let mut count = 0;
            while count < prompts && self.next_load < self.lens.len() {
                self.state[self.next_load] = 1;
                self.next_load += 1;
                count += 1;
            }
            Ok(count)
        }

        fn admit(&mut self, rids: &[u64], _engine: Option<usize>) -> Result<()> {
            for &rid in rids {
                assert_eq!(self.state[rid as usize], 1, "admit non-fresh {rid}");
                self.state[rid as usize] = 2;
                self.queue.push_back(rid);
            }
            Ok(())
        }

        fn step(&mut self) -> Result<usize> {
            self.fill_lanes();
            let mut finished = 0;
            let mut still = Vec::new();
            for &rid in &self.running {
                let i = rid as usize;
                self.progress[i] += 1;
                if self.progress[i] >= self.lens[i] {
                    self.state[i] = 3;
                    self.ready.push(rid);
                    finished += 1;
                } else {
                    still.push(rid);
                }
            }
            self.running = still;
            Ok(finished)
        }

        fn harvest_candidates(&mut self) -> Result<Vec<HarvestItem>> {
            self.harvests += 1;
            let mut items: Vec<HarvestItem> = self
                .running
                .drain(..)
                .map(|rid| HarvestItem {
                    rid,
                    progress: self.progress[rid as usize],
                    queued: false,
                })
                .collect();
            items.extend(self.queue.drain(..).map(|rid| HarvestItem {
                rid,
                progress: self.progress[rid as usize],
                queued: true,
            }));
            items.sort_by(|a, b| b.progress.cmp(&a.progress).then(a.rid.cmp(&b.rid)));
            Ok(items)
        }

        fn resolve(&mut self, item: &HarvestItem, action: HarvestAction) -> Result<()> {
            let i = item.rid as usize;
            match action {
                HarvestAction::Clip => {
                    self.state[i] = 3;
                    self.ready.push(item.rid);
                    self.clipped.push(item.rid);
                }
                HarvestAction::Restart => {
                    self.progress[i] = 0;
                    self.state[i] = 1;
                }
                HarvestAction::Resume | HarvestAction::Requeue => {
                    self.state[i] = 1;
                }
                HarvestAction::Drop => {
                    self.state[i] = 4;
                    self.dropped.push(item.rid);
                }
            }
            Ok(())
        }

        fn preempt(&mut self, _engine: usize, lane: usize) -> Result<()> {
            if lane < self.running.len() {
                let rid = self.running.remove(lane);
                self.queue.push_back(rid);
            }
            Ok(())
        }

        fn train(&mut self, rids: &[u64]) -> Result<()> {
            for &rid in rids {
                assert_eq!(self.state[rid as usize], 3, "train non-ready {rid}");
                self.state[rid as usize] = 4;
                self.consumed.push(rid);
            }
            self.updates += 1;
            Ok(())
        }

        fn barrier(&mut self) -> Result<()> {
            Ok(())
        }

        fn exhausted(&self) -> bool {
            self.state.iter().all(|&s| s == 4) && self.next_load >= self.lens.len()
        }
    }

    fn params(refill: usize, batch: usize) -> PolicyParams {
        PolicyParams { refill_prompts: refill, entries_per_prompt: 1, update_batch: batch }
    }

    /// Hand-computed on-policy group run: lens [1,2,3,8], 2 lanes, update
    /// batch 2.  Wave 1 finishes rid0, clips rid1 (progress 1) to fill the
    /// quota and requeues 2/3; wave 2 (final) runs 2 and 3 to completion.
    #[test]
    fn group_on_policy_pinned_sequence() {
        let mut p = GroupPolicy::new(params(4, 2), Mode::OnPolicy);
        let mut b = MockBackend::new(vec![1, 2, 3, 8], 2);
        drive(&mut p, &mut b).unwrap();
        assert_eq!(b.updates, 2);
        assert_eq!(b.consumed, vec![0, 1, 2, 3]);
        assert_eq!(b.clipped, vec![1]);
        assert!(b.dropped.is_empty());
        // rid1 was clipped at progress 1, not rerun to its full length
        assert_eq!(b.progress[1], 1);
    }

    /// Partial mode on the same workload: no mid-group clipping (the
    /// threshold waits for full completions), everything completes.
    #[test]
    fn group_partial_pinned_sequence() {
        let mut p = GroupPolicy::new(params(4, 2), Mode::Partial);
        let mut b = MockBackend::new(vec![1, 2, 3, 8], 2);
        drive(&mut p, &mut b).unwrap();
        assert_eq!(b.updates, 2);
        assert_eq!(b.consumed.len(), 4);
        // every trajectory trained at its true length (nothing clipped at
        // progress < len except possibly the final-wave straggler)
        for &rid in &b.consumed {
            let i = rid as usize;
            assert!(b.progress[i] == b.lens[i] || b.clipped.contains(&rid));
        }
    }

    /// Baseline: one wave to full completion, then sequential updates in
    /// completion order; nothing clipped or dropped.
    #[test]
    fn baseline_runs_wave_to_completion() {
        let mut p = BaselinePolicy::new(params(4, 2), false);
        let mut b = MockBackend::new(vec![3, 1, 4, 2], 2);
        drive(&mut p, &mut b).unwrap();
        assert_eq!(b.updates, 2);
        assert!(b.clipped.is_empty());
        assert!(b.dropped.is_empty());
        assert_eq!(b.harvests, 0, "baseline must never harvest");
        for i in 0..4 {
            assert_eq!(b.progress[i], b.lens[i]);
        }
    }

    /// Post-hoc sort trains in length-ascending order.
    #[test]
    fn post_hoc_sorts_by_length() {
        let mut p = BaselinePolicy::new(params(4, 4), true);
        let mut b = MockBackend::new(vec![9, 2, 7, 4], 4);
        drive(&mut p, &mut b).unwrap();
        assert_eq!(b.updates, 1);
        assert_eq!(b.consumed, vec![1, 3, 2, 0]); // lengths 2,4,7,9
    }

    /// AsyncUpdate fires its first update with lanes still running (no
    /// harvest barrier), and the long request is never restarted.
    #[test]
    fn async_updates_without_harvest_barrier() {
        let mut p = AsyncUpdatePolicy::new(params(6, 2), 1_000);
        let mut b = MockBackend::new(vec![1, 2, 3, 20, 21, 22], 2);
        drive(&mut p, &mut b).unwrap();
        assert_eq!(b.consumed.len(), 6);
        assert!(b.updates >= 2);
        // sync_every is huge, so the only harvest is the final-wave clip
        assert!(b.harvests <= 2, "async harvested {} times", b.harvests);
        // nothing lost progress to a restart
        for i in 0..6 {
            assert!(b.progress[i] > 0);
        }
    }

    /// Wrapper that measures the staleness bound the async policy promises:
    /// trainer updates completed since the last harvest (every harvest is a
    /// weight re-sync for the surviving lanes).
    struct SyncBoundProbe {
        inner: AsyncUpdatePolicy,
        since_sync: usize,
        max_since_sync: usize,
    }

    impl SyncBoundProbe {
        fn new(inner: AsyncUpdatePolicy) -> Self {
            SyncBoundProbe { inner, since_sync: 0, max_since_sync: 0 }
        }
    }

    impl SchedulePolicy for SyncBoundProbe {
        fn name(&self) -> &'static str {
            "sync-bound-probe"
        }
        fn decide(&mut self, b: &dyn ScheduleBackend) -> Decision {
            self.inner.decide(b)
        }
        fn classify(&mut self, item: &HarvestItem, view: &SchedView) -> HarvestAction {
            self.inner.classify(item, view)
        }
        fn observe(&mut self, ev: &Event) {
            match ev {
                Event::UpdateDone => {
                    self.since_sync += 1;
                    self.max_since_sync = self.max_since_sync.max(self.since_sync);
                }
                Event::Harvested { .. } => self.since_sync = 0,
                _ => {}
            }
            self.inner.observe(ev);
        }
    }

    /// Regression for the final-wave staleness lapse: the bounded-staleness
    /// re-sync must fire during the final wave too.  lens [1,1,1,1,8,60],
    /// 2 lanes, update batch 2, sync_every 2: two quick updates land before
    /// the final wave starts, so the wave opens with updates_since_sync ==
    /// sync_every while rids 4/5 are still queued.  The fixed policy
    /// re-syncs right there (one harvest; never-run work requeued, nothing
    /// dropped) and the updates-between-syncs count never exceeds the bound.
    #[test]
    fn async_resyncs_during_final_wave() {
        let mut p = SyncBoundProbe::new(AsyncUpdatePolicy::new(params(6, 2), 2));
        let mut b = MockBackend::new(vec![1, 1, 1, 1, 8, 60], 2);
        drive(&mut p, &mut b).unwrap();
        assert_eq!(b.updates, 3);
        assert_eq!(b.consumed.len(), 6);
        assert!(b.dropped.is_empty(), "re-sync must not drop never-run work");
        assert_eq!(b.harvests, 1, "the final-wave re-sync harvest");
        assert!(
            p.max_since_sync <= 2,
            "staleness bound violated: {} updates between syncs",
            p.max_since_sync
        );
    }

    /// The same workload under a policy whose re-sync window never fires
    /// reproduces the OLD buggy behavior exactly (the `!final_wave` guard
    /// made the final wave behave as if sync_every were infinite): all
    /// three updates run without a single re-sync, exceeding the bound of
    /// 2 that the fixed policy holds above.
    #[test]
    fn final_wave_lapse_pinned_by_unbounded_window() {
        let mut p = SyncBoundProbe::new(AsyncUpdatePolicy::new(params(6, 2), 1_000));
        let mut b = MockBackend::new(vec![1, 1, 1, 1, 8, 60], 2);
        drive(&mut p, &mut b).unwrap();
        assert_eq!(b.updates, 3);
        assert_eq!(b.harvests, 0, "no re-sync ever fires without the fix");
        assert!(
            p.max_since_sync > 2,
            "the lapse scenario must exceed the sync_every=2 bound (got {})",
            p.max_since_sync
        );
    }

    /// NoGrouped abandons interrupted work: with update_batch 1 and a long
    /// straggler, harvests fire early and the straggler is dropped.
    #[test]
    fn no_grouped_abandons_stragglers() {
        let mut p = NoGroupedPolicy::new(params(3, 1));
        let mut b = MockBackend::new(vec![1, 1, 50], 3);
        drive(&mut p, &mut b).unwrap();
        assert!(b.consumed.len() + b.dropped.len() == 3);
        assert!(!b.dropped.is_empty(), "the len-50 straggler should be abandoned");
        assert!(b.clipped.is_empty(), "no-grouped never clips");
    }

    /// The driver refuses to livelock on a policy that always steps.
    #[test]
    fn driver_bails_on_idle_stepping() {
        struct StepForever;
        impl SchedulePolicy for StepForever {
            fn name(&self) -> &'static str {
                "step-forever"
            }
            fn decide(&mut self, _b: &dyn ScheduleBackend) -> Decision {
                Decision::Step
            }
            fn classify(&mut self, _i: &HarvestItem, _v: &SchedView) -> HarvestAction {
                HarvestAction::Requeue
            }
        }
        let mut p = StepForever;
        let mut b = MockBackend::new(vec![1], 1);
        // nothing loaded -> the backend is idle forever
        let err = drive(&mut p, &mut b).unwrap_err();
        assert!(format!("{err:#}").contains("idle"));
    }

    /// `--engine-spec` grammar round trip: atoms, repeat prefixes, `max`
    /// budgets and default speeds parse to the exact fleet shapes.
    #[test]
    fn engine_spec_fleet_grammar() {
        let fleet = EngineSpec::parse_fleet("2x8:4096:2, 4:65536:0.5 ,1:max").unwrap();
        assert_eq!(fleet, vec![
            EngineSpec { lanes: 8, kv_budget: 4096, speed: 2.0 },
            EngineSpec { lanes: 8, kv_budget: 4096, speed: 2.0 },
            EngineSpec { lanes: 4, kv_budget: 65536, speed: 0.5 },
            EngineSpec { lanes: 1, kv_budget: usize::MAX, speed: 1.0 },
        ]);
        // omitted speed defaults to the homogeneous 1.0
        assert_eq!(EngineSpec::parse_fleet("16:8192").unwrap(),
                   vec![EngineSpec::uniform(16, 8192)]);
    }

    /// Malformed fleet specs are rejected at parse time with pointed
    /// messages — zero lanes, zero/non-finite speeds, zero budgets, bad
    /// repeat counts, missing or surplus fields.
    #[test]
    fn engine_spec_fleet_rejections() {
        for (bad, needle) in [
            ("0:4096", "lanes must be >= 1"),
            ("8:0", "kv budget must be >= 1"),
            ("8:4096:0", "speed must be positive"),
            ("8:4096:-1", "speed must be positive"),
            ("8:4096:inf", "speed must be positive"),
            ("0x8:4096", "repeat count must be >= 1"),
            ("8", "missing kv budget"),
            ("8:4096:1:9", "too many fields"),
            ("8:4096,,4:max", "empty atom"),
            ("eight:4096", "bad lane count"),
        ] {
            let err = EngineSpec::parse_fleet(bad).unwrap_err();
            assert!(format!("{err:#}").contains(needle),
                    "'{bad}' produced the wrong error: {err:#}");
        }
    }

    /// `EngineSpec::validate` enforces the same floor directly (the path
    /// hand-built specs take through `SimRun::specs`).
    #[test]
    fn engine_spec_validate_rejections() {
        assert!(EngineSpec { lanes: 0, kv_budget: 1, speed: 1.0 }.validate().is_err());
        assert!(EngineSpec { lanes: 1, kv_budget: 0, speed: 1.0 }.validate().is_err());
        assert!(EngineSpec { lanes: 1, kv_budget: 1, speed: 0.0 }.validate().is_err());
        assert!(EngineSpec { lanes: 1, kv_budget: 1, speed: f64::NAN }.validate().is_err());
        assert!(EngineSpec::uniform(1, usize::MAX).validate().is_ok());
    }

    /// Dyadic speeds map exactly into Q8.8 (what keeps the cross-core
    /// differential bitwise on heterogeneous fleets); pathological speeds
    /// floor at 1 instead of dividing by zero.
    #[test]
    fn speed_q8_dyadic_exact() {
        assert_eq!(speed_to_q8(0.5), SPEED_Q8_UNIT / 2);
        assert_eq!(speed_to_q8(1.0), SPEED_Q8_UNIT);
        assert_eq!(speed_to_q8(2.0), 2 * SPEED_Q8_UNIT);
        assert_eq!(speed_to_q8(1e-9), 1);
    }

    /// `TailConfig::validate` rejects the two degenerate shapes the CLI
    /// must refuse.
    #[test]
    fn tail_config_validate_rejections() {
        assert!(TailConfig { threshold: 0, tail_engines: 1 }.validate().is_err());
        assert!(TailConfig { threshold: 1, tail_engines: 0 }.validate().is_err());
        assert!(TailConfig { threshold: 2048, tail_engines: 1 }.validate().is_ok());
    }

    /// The builder mounts wrappers in the fixed order (governor inside
    /// stealing inside tail), observable from the outermost `name()`:
    /// reserve KV never mounts a governor, paged KV does, stealing wraps
    /// it, and tail packing is always outermost.
    #[test]
    fn policy_builder_composition_order() {
        let p = params(4, 2);
        let paged = KvConfig { mode: KvMode::Paged, budget: 1024, page: 16 };
        let tail = TailConfig { threshold: 64, tail_engines: 1 };
        let name = |b: Box<dyn SchedulePolicy>| b.name();
        assert_eq!(name(PolicyBuilder::new(SchedulerKind::Baseline, p).build()),
                   "baseline");
        assert_eq!(name(PolicyBuilder::new(SchedulerKind::Baseline, p)
                        .kv(KvConfig::default()).build()),
                   "baseline", "reserve KV must not mount a governor");
        assert_eq!(name(PolicyBuilder::new(SchedulerKind::Baseline, p).kv(paged).build()),
                   "kv-governor");
        assert_eq!(name(PolicyBuilder::new(SchedulerKind::Baseline, p)
                        .kv(paged).steal(true).build()),
                   "work-stealing", "stealing wraps the governor");
        assert_eq!(name(PolicyBuilder::new(SchedulerKind::Baseline, p)
                        .kv(paged).steal(true).tail(Some(tail)).build()),
                   "tail-packing", "tail packing is outermost");
        assert_eq!(name(PolicyBuilder::new(SchedulerKind::SortedPartial, p)
                        .tail(Some(tail)).build()),
                   "tail-packing");
    }
}
