//! `EnginePool` — N rollout engines behind one submit/step/drain facade.
//!
//! The paper's clusters shard "large rollout batches across engines"; the
//! seed only ever drove a single engine.  The pool owns the engines, a
//! central admission queue, a pluggable [`DispatchPolicy`] and a
//! [`LengthPredictor`](super::LengthPredictor), and adds two scheduling
//! moves the single engine cannot express:
//!
//!   * **Predictive placement** — `ShortestPredictedFirst` sorts the
//!     admission queue by predicted generation length and hands contiguous
//!     (similar-length) runs to each engine, so lanes within an engine
//!     finish together and the drain tail collapses (the SortedRL insight,
//!     applied *before* generation instead of after).
//!   * **Preemptive partial requeue** — a lane whose emitted length blows
//!     far past its prediction is preempted and its partial rollout goes
//!     back into the pool queue (progress + log-probs kept, APRIL-style);
//!     resume pays one prefill over prompt+prefix, which `Engine::admit`
//!     models naturally.
//!
//! Dispatch is late-binding: requests stay in the central queue until an
//! engine actually has a free lane (except `RoundRobin`, which statically
//! stripes — the FCFS baseline the benches compare against).

use crate::metrics::PredictorScore;
use crate::rollout::{Engine, EngineConfig, Request, Rollout};
use crate::runtime::{ParamState, Runtime};
use crate::sched::policy::{speed_to_q8, EngineLoad, EngineSpec};
use crate::sched::predictor::{make_predictor, sjf_priority, LengthPredictor, PredictorKind};
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};

/// How the pool assigns queued requests to engines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Static striping in arrival order, ignoring load (FCFS baseline).
    RoundRobin,
    /// Next request to the engine with the fewest in-flight requests.
    LeastLoaded,
    /// Sort the admission queue by predicted length ascending and pack
    /// similar-length runs onto the same engine.
    ShortestPredictedFirst,
}

impl DispatchPolicy {
    pub const ALL: [DispatchPolicy; 3] = [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::LeastLoaded,
        DispatchPolicy::ShortestPredictedFirst,
    ];

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "rr" | "round-robin" | "fcfs" => Self::RoundRobin,
            "least-loaded" | "ll" => Self::LeastLoaded,
            "sjf" | "shortest-predicted-first" => Self::ShortestPredictedFirst,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::RoundRobin => "round-robin",
            Self::LeastLoaded => "least-loaded",
            Self::ShortestPredictedFirst => "sjf",
        }
    }
}

/// Pool knobs (engine-count, dispatch, prediction, preemption).
#[derive(Debug, Clone)]
pub struct PoolConfig {
    pub num_engines: usize,
    pub dispatch: DispatchPolicy,
    pub predictor: PredictorKind,
    /// Preempt stragglers back into the pool queue (partial-mode only —
    /// on-policy semantics would discard the preempted tokens anyway).
    pub preempt: bool,
    /// A lane is a straggler once its total response length exceeds this
    /// multiple of its predicted length while other work waits.
    pub straggler_factor: f64,
    /// Check for stragglers every this many pool steps.
    pub preempt_every: usize,
    /// Never preempt a lane that has emitted fewer tokens than this since
    /// (re-)admission — caps re-prefill churn.
    pub min_preempt_emitted: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            num_engines: 1,
            dispatch: DispatchPolicy::LeastLoaded,
            predictor: PredictorKind::History,
            preempt: false,
            straggler_factor: 2.0,
            preempt_every: 8,
            min_preempt_emitted: 16,
        }
    }
}

/// Convert a preempted partial rollout back into a resumable request.
pub fn resume_request(r: &Rollout) -> Request {
    let mut req = r.request.clone();
    req.resumed = r.response.clone();
    req.resumed_logp = r.logp.clone();
    req.resumes += 1;
    req
}

pub struct EnginePool<'rt> {
    engines: Vec<Engine<'rt>>,
    queue: VecDeque<Request>,
    cfg: PoolConfig,
    predictor: Box<dyn LengthPredictor>,
    /// Online predictor telemetry (MAE + Kendall tau).
    pub score: PredictorScore,
    /// Prediction captured when each in-flight request was handed to an
    /// engine — scoring must use what actually drove the dispatch
    /// decision, not a prediction recomputed after siblings finished.
    dispatched_pred: BTreeMap<u64, f64>,
    /// SJF sort keys are stale (new submissions, requeues, or predictor
    /// observations since the last sort) — avoids re-sorting the whole
    /// backlog on every decode iteration.
    queue_dirty: bool,
    rr_cursor: usize,
    steps: usize,
    preempted: u64,
    stolen: u64,
    throttled: u64,
    /// Spec-declared relative speeds (`--engine-spec`), exposed through
    /// `engine_loads` so spec-normalized routing weighs backlog against
    /// declared throughput.  Real engines decode at hardware speed — this
    /// shapes ROUTING only.  All 1.0 for a uniform fleet.
    speeds: Vec<f64>,
}

impl<'rt> EnginePool<'rt> {
    pub fn new(rt: &'rt Runtime, ecfg: EngineConfig, cfg: PoolConfig) -> Self {
        assert!(cfg.num_engines >= 1, "pool needs at least one engine");
        assert!(cfg.preempt_every >= 1, "preempt_every must be >= 1");
        assert!(cfg.straggler_factor > 1.0, "straggler_factor must exceed 1.0");
        let n = cfg.num_engines;
        let engines = (0..n).map(|_| Engine::new(rt, ecfg.clone())).collect();
        let predictor = make_predictor(cfg.predictor);
        EnginePool {
            engines,
            queue: VecDeque::new(),
            cfg,
            predictor,
            score: PredictorScore::default(),
            dispatched_pred: BTreeMap::new(),
            queue_dirty: true,
            rr_cursor: 0,
            steps: 0,
            preempted: 0,
            stolen: 0,
            throttled: 0,
            speeds: vec![1.0; n],
        }
    }

    /// Apply heterogeneous per-engine specs (`--engine-spec`): lane
    /// window, KV budget, and routing speed per engine.  Lane counts are
    /// clamped to the compiled kernel batch width by
    /// [`Engine::set_capacity`]; call before submitting work (every lane
    /// is free then, so nothing can refuse).
    pub fn apply_specs(&mut self, specs: &[EngineSpec]) {
        assert_eq!(specs.len(), self.engines.len(), "one spec per engine");
        for (i, s) in specs.iter().enumerate() {
            s.validate().expect("specs are validated at parse time");
            let ok = self.engines[i].set_capacity(s.lanes, s.kv_budget);
            debug_assert!(ok, "pre-submission set_capacity cannot refuse");
            self.speeds[i] = s.speed;
        }
    }

    // ------------------------------------------------------------------
    // aggregate views (facade mirrors the single-engine API)
    // ------------------------------------------------------------------

    pub fn num_engines(&self) -> usize {
        self.engines.len()
    }

    pub fn engines(&self) -> &[Engine<'rt>] {
        &self.engines
    }

    /// Total lanes across engines.
    pub fn lane_count(&self) -> usize {
        self.engines.iter().map(|e| e.lane_count()).sum()
    }

    pub fn running(&self) -> usize {
        self.engines.iter().map(|e| e.running()).sum()
    }

    /// Central queue + every engine's local queue.
    pub fn queued(&self) -> usize {
        self.queue.len() + self.engines.iter().map(|e| e.queued()).sum::<usize>()
    }

    pub fn in_flight(&self) -> usize {
        self.running() + self.queued()
    }

    pub fn finished_count(&self) -> usize {
        self.engines.iter().map(|e| e.finished_count()).sum()
    }

    /// MODELED parallel wall clock: max over engine clocks, i.e. what an
    /// N-device deployment would take.  On one host the engines actually
    /// execute serially — use [`Self::host_secs`] for real elapsed engine
    /// time.  Occupancy/bubble math uses this clock (bubble is a property
    /// of the modeled parallel pool).
    pub fn clock(&self) -> f64 {
        self.engines.iter().map(|e| e.clock()).fold(0.0, f64::max)
    }

    /// Real host seconds spent inside engine calls (sum over engines —
    /// they share one Runtime and run serially).
    pub fn host_secs(&self) -> f64 {
        self.engines.iter().map(|e| e.clock()).sum()
    }

    /// Requests preempted-and-requeued so far.
    pub fn preempted(&self) -> u64 {
        self.preempted
    }

    /// Cross-engine migrations executed so far (see [`Self::steal_to`]).
    pub fn stolen(&self) -> u64 {
        self.stolen
    }

    /// Lanes shed by `Decision::Throttle` so far (see [`Self::throttle`]).
    pub fn throttled(&self) -> u64 {
        self.throttled
    }

    /// Forced paged-KV evictions inside engine decode steps, summed.
    pub fn kv_sheds(&self) -> u64 {
        self.engines.iter().map(|e| e.kv_sheds()).sum()
    }

    /// Execute a `Decision::Throttle`: shed engine `engine`'s lane with
    /// the most predicted remaining work (per-page fragmentation breaks
    /// ties — see [`KvConfig::victim_key`](crate::rollout::kv::KvConfig))
    /// back into the pool queue (progress kept) so projected paged-KV
    /// usage drops below the budget before the forced in-step eviction
    /// path has to fire.  Refuses (returns false) when the engine runs
    /// fewer than two lanes — the last lane is the progress guarantee and
    /// must keep decoding.
    pub fn throttle(&mut self, engine: usize, version: u64) -> bool {
        if engine >= self.engines.len() || self.engines[engine].running() < 2 {
            return false;
        }
        let kv = self.engines[engine].kv_config();
        let victim = self.engines[engine]
            .lane_progress()
            .into_iter()
            .max_by_key(|p| {
                (kv.victim_key(p.prompt_len, p.total, p.max_new, p.predicted),
                 std::cmp::Reverse(p.lane))
            })
            .map(|p| p.lane);
        match victim {
            Some(lane) => {
                let ok = self.preempt(engine, lane, version);
                if ok {
                    self.throttled += 1;
                }
                ok
            }
            None => false,
        }
    }

    /// Per-engine load snapshot (the policy layer's pool-load view).
    pub fn engine_loads(&self) -> Vec<EngineLoad> {
        self.engines
            .iter()
            .zip(&self.speeds)
            .map(|(e, &speed)| EngineLoad {
                queued: e.queued(),
                active: e.running(),
                lanes: e.lane_count(),
                kv_used: e.kv_used(),
                kv_budget: e.kv_budget(),
                kv_blocked: e.kv_blocked(),
                kv_pressure: e.kv_pressure(),
                speed_q8: speed_to_q8(speed),
            })
            .collect()
    }

    /// Execute a `Decision::Repartition` against engine `engine`
    /// transactionally (see [`Engine::set_capacity`] for the live refusal
    /// rules — lane pinning and the kernel-width clamp are live-only).
    /// Returns whether it applied.
    pub fn repartition(&mut self, engine: usize, lanes: usize, kv: usize) -> bool {
        match self.engines.get_mut(engine) {
            Some(e) => e.set_capacity(lanes, kv),
            None => false,
        }
    }

    /// Predicted-length stamp for a not-yet-dispatched prompt — what
    /// `ScheduleBackend::predicted_len` feeds the tail-packing
    /// classifier.  `None` under rank-only predictors, which keeps tail
    /// packing inert by construction (bucket indices are not tokens).
    pub fn predict_stamp(&self, prompt_id: u64, prompt_len: usize) -> Option<usize> {
        self.predict_pair(prompt_id, prompt_len).1
    }

    /// Output tokens generated so far, summed over engines — cheap, so
    /// per-update telemetry can read it mid-run (the occupancy/bubble
    /// aggregation via [`Self::occupancy`] still happens once at run end).
    pub fn tokens_out(&self) -> u64 {
        self.engines.iter().map(|e| e.timeline.tokens_out()).sum()
    }

    /// (idle_area, busy_span, tokens_out) aggregated over engines against
    /// the pool-wide end time — feeds the controller's bubble accounting.
    /// An engine that never admitted work counts as 100% idle capacity
    /// over the pool span (paper Eq. 4 — an idle engine is bubble, not a
    /// non-event).
    pub fn occupancy(&self) -> (f64, f64, u64) {
        let end = self.clock();
        let start = self
            .engines
            .iter()
            .filter(|e| !e.timeline.events().is_empty())
            .map(|e| e.timeline.span().0)
            .fold(f64::INFINITY, f64::min);
        if !start.is_finite() {
            return (0.0, 0.0, 0); // pool never ran at all
        }
        let mut idle = 0.0;
        let mut busy = 0.0;
        let mut tokens = 0u64;
        // every engine is accountable for the POOL span: capacity idling
        // before an engine's first admission is bubble too, exactly like
        // an engine that never ran at all
        let span = (end - start).max(0.0);
        for e in &self.engines {
            let cap = e.lane_count();
            busy += span * cap as f64;
            if e.timeline.events().is_empty() {
                idle += span * cap as f64;
                continue;
            }
            let (e_start, _) = e.timeline.span();
            let bubble = e.timeline.bubble_ratio(cap, end);
            let measured = (end - e_start).max(0.0);
            idle += bubble * measured * cap as f64
                + (e_start - start).max(0.0) * cap as f64;
            tokens += e.timeline.tokens_out();
        }
        (idle, busy, tokens)
    }

    /// (head, tail) bubble ratios for the engine-group split tail rounds
    /// use: the same idle-capacity accounting as [`Self::occupancy`],
    /// restricted per group, both measured against the pool-wide span.
    /// `(whole-pool bubble, 0.0)` when `tail_group == 0`; a group that
    /// never admitted anything reads 1.0 (all-bubble, like an idle
    /// engine).  `(0.0, 0.0)` when the pool never ran.
    pub fn bubble_split(&self, tail_group: usize) -> (f64, f64) {
        let n = self.engines.len();
        let group = tail_group.min(n.saturating_sub(1));
        let split = n - group;
        let end = self.clock();
        let start = self
            .engines
            .iter()
            .filter(|e| !e.timeline.events().is_empty())
            .map(|e| e.timeline.span().0)
            .fold(f64::INFINITY, f64::min);
        if !start.is_finite() {
            return (0.0, 0.0);
        }
        let head = Self::group_bubble(&self.engines[..split], start, end);
        let tail = if group == 0 {
            0.0
        } else {
            Self::group_bubble(&self.engines[split..], start, end)
        };
        (head, tail)
    }

    fn group_bubble(engines: &[Engine<'_>], start: f64, end: f64) -> f64 {
        if engines.iter().all(|e| e.timeline.events().is_empty()) {
            return 1.0;
        }
        let span = (end - start).max(0.0);
        let mut idle = 0.0;
        let mut busy = 0.0;
        for e in engines {
            let cap = e.lane_count();
            busy += span * cap as f64;
            if e.timeline.events().is_empty() {
                idle += span * cap as f64;
                continue;
            }
            let (e_start, _) = e.timeline.span();
            let bubble = e.timeline.bubble_ratio(cap, end);
            let measured = (end - e_start).max(0.0);
            idle += bubble * measured * cap as f64
                + (e_start - start).max(0.0) * cap as f64;
        }
        if busy <= 0.0 {
            1.0
        } else {
            (idle / busy).clamp(0.0, 1.0)
        }
    }

    // ------------------------------------------------------------------
    // scheduling
    // ------------------------------------------------------------------

    /// Enqueue requests into the central pool queue.
    pub fn submit(&mut self, reqs: impl IntoIterator<Item = Request>) {
        self.queue.extend(reqs);
        self.queue_dirty = true;
    }

    /// Targeted admission: hand requests straight to engine `i`'s local
    /// queue, bypassing the dispatch policy (the policy-API
    /// `Admit { engine: Some(i) }` decision).
    pub fn submit_to(&mut self, engine: usize, reqs: impl IntoIterator<Item = Request>) {
        assert!(engine < self.engines.len(), "submit_to engine out of range");
        for req in reqs {
            self.hand_to_engine(engine, req);
        }
    }

    /// SJF priority of a request (see [`sjf_priority`] for the policy —
    /// one definition shared with the simulator mirror).
    fn predicted_remaining(&self, req: &Request) -> f64 {
        sjf_priority(
            self.predictor.as_ref(),
            req.prompt_id,
            req.prompt.len(),
            req.resumed.len(),
        )
    }

    fn engine_free(&self, i: usize) -> usize {
        let e = &self.engines[i];
        e.lane_count().saturating_sub(e.running() + e.queued())
    }

    /// One predictor lookup, shaped for both consumers: the raw
    /// prediction (scored against the true length on completion) and the
    /// token-count stamp paged-KV estimates consume (see
    /// [`crate::rollout::kv::stamp_prediction`] — the one rule shared
    /// with the simulator).
    fn predict_pair(&self, prompt_id: u64, prompt_len: usize) -> (f64, Option<usize>) {
        let p = self.predictor.predict(prompt_id, prompt_len);
        (p, crate::rollout::kv::stamp_prediction(self.predictor.is_rank_only(), p))
    }

    /// Admission estimate of a still-central request given its stamp (the
    /// engines share one KV mode + page size; budgets may differ under
    /// `--engine-spec` but estimates are budget-independent): what
    /// budget-aware dispatch assumes the request will cost wherever it
    /// lands.
    fn admit_estimate_of(&self, req: &Request, stamp: Option<usize>) -> usize {
        self.engines[0].kv_config().admit_estimate(
            req.prompt.len(),
            req.resumed.len(),
            req.max_new,
            stamp,
        )
    }

    /// Hand one request to engine `i` with its precomputed prediction
    /// pair — the dispatch loops already looked it up for the KV gate, so
    /// the hand-off must not pay a second predictor probe.
    fn hand_to_engine_with(&mut self, i: usize, mut req: Request,
                           (predicted, stamp): (f64, Option<usize>)) {
        self.dispatched_pred.insert(req.rid, predicted);
        req.predicted_len = stamp;
        self.engines[i].submit([req]);
    }

    /// Hand one request to engine `i`, capturing the prediction that drove
    /// the decision and stamping it onto the request so the engine's
    /// paged-KV admission gate can estimate from it.
    fn hand_to_engine(&mut self, i: usize, req: Request) {
        let pair = self.predict_pair(req.prompt_id, req.prompt.len());
        self.hand_to_engine_with(i, req, pair);
    }

    /// Move central-queue requests onto engines per the dispatch policy.
    fn dispatch(&mut self) {
        if self.queue.is_empty() {
            return;
        }
        // no engine can accept work: skip (for SJF this avoids re-sorting
        // the whole backlog on every decode iteration; for round-robin the
        // eventual striping order is queue order either way)
        if (0..self.engines.len()).all(|i| self.engine_free(i) == 0) {
            return;
        }
        match self.cfg.dispatch {
            DispatchPolicy::RoundRobin => {
                // static hand-out: everything leaves the central queue now
                while let Some(req) = self.queue.pop_front() {
                    let i = self.rr_cursor % self.engines.len();
                    self.rr_cursor += 1;
                    self.hand_to_engine(i, req);
                }
            }
            DispatchPolicy::LeastLoaded => {
                // late-binding: hand out only what can run now, one request
                // at a time to the emptiest engine whose KV headroom can
                // actually absorb it (route around KV-tight engines).  The
                // per-engine committed KV is hoisted once and maintained
                // incrementally — recomputing it per request would scan
                // every lane and queued estimate on the live hot path.
                // The gate is per-engine: budgets differ under
                // heterogeneous `--engine-spec` fleets.
                let mut committed: Vec<usize> =
                    self.engines.iter().map(|e| e.kv_committed()).collect();
                loop {
                    let Some(req) = self.queue.front() else { break };
                    let pair = self.predict_pair(req.prompt_id, req.prompt.len());
                    let est = self.admit_estimate_of(req, pair.1);
                    let Some(i) = (0..self.engines.len())
                        .filter(|&i| {
                            self.engine_free(i) > 0
                                && !self.engines[i].kv_config().gate_refuses(committed[i], est)
                        })
                        .min_by_key(|&i| self.engines[i].in_flight())
                    else {
                        break;
                    };
                    committed[i] = committed[i].saturating_add(est);
                    let req = self.queue.pop_front().unwrap();
                    self.hand_to_engine_with(i, req, pair);
                }
            }
            DispatchPolicy::ShortestPredictedFirst => {
                // sort the admission queue by predicted remaining length —
                // only when keys went stale (new work or new observations),
                // not on every decode iteration — then give each engine
                // (emptiest first) a contiguous run from the sorted front:
                // similar lengths land on the same engine and drain together
                if self.queue_dirty {
                    let drained: Vec<Request> = self.queue.drain(..).collect();
                    let mut keyed: Vec<(f64, Request)> = drained
                        .into_iter()
                        .map(|r| (self.predicted_remaining(&r), r))
                        .collect();
                    keyed.sort_by(|a, b| {
                        a.0.partial_cmp(&b.0).unwrap().then(a.1.rid.cmp(&b.1.rid))
                    });
                    self.queue = keyed.into_iter().map(|(_, r)| r).collect();
                    self.queue_dirty = false;
                }
                let mut order: Vec<usize> = (0..self.engines.len()).collect();
                order.sort_by_key(|&i| self.engines[i].in_flight());
                for i in order {
                    let free = self.engine_free(i);
                    // budget-aware packing: stop filling this engine once
                    // the next request's estimate no longer fits what the
                    // engine is already committed to (same gate shape as
                    // admission, empty-engine escape included)
                    let kv = self.engines[i].kv_config();
                    let mut committed = self.engines[i].kv_committed();
                    for _ in 0..free {
                        let Some(req) = self.queue.front() else { break };
                        let pair = self.predict_pair(req.prompt_id, req.prompt.len());
                        let est = self.admit_estimate_of(req, pair.1);
                        if kv.gate_refuses(committed, est) {
                            break;
                        }
                        committed = committed.saturating_add(est);
                        let req = self.queue.pop_front().unwrap();
                        self.hand_to_engine_with(i, req, pair);
                    }
                }
            }
        }
    }

    /// Dispatch + admit queued work into free lanes (batched prefill per
    /// engine). Returns the number of newly admitted requests.
    pub fn admit(&mut self, state: &ParamState) -> Result<usize> {
        self.dispatch();
        let mut admitted = 0;
        for e in self.engines.iter_mut() {
            if e.queued() > 0 && e.running() < e.lane_count() {
                admitted += e.admit(state)?;
            }
        }
        Ok(admitted)
    }

    /// One decode chunk on every engine with running lanes; periodically
    /// preempts stragglers when enabled. Returns tokens generated.
    pub fn step(&mut self, state: &ParamState) -> Result<usize> {
        self.steps += 1;
        if self.cfg.preempt && self.steps % self.cfg.preempt_every == 0 {
            self.preempt_stragglers(state.version);
        }
        let mut tokens = 0;
        for e in self.engines.iter_mut() {
            if e.running() > 0 {
                tokens += e.step(state)?;
            }
        }
        Ok(tokens)
    }

    /// Preempt at most one straggler lane per engine: a lane whose total
    /// response length exceeds `straggler_factor` x its predicted length
    /// while other work is waiting. The partial goes back into the pool
    /// queue with progress kept; `observe_progress` raises the request's
    /// prediction toward its observed floor, so repeat preemption of the
    /// same request self-extinguishes.
    fn preempt_stragglers(&mut self, version: u64) {
        // The over-prediction ratio compares token counts; rank-only
        // predictors (bucket) emit bucket indices, so the ratio would be
        // meaningless and every lane would look like a straggler.
        if self.predictor.is_rank_only() {
            return;
        }
        // Static striping cannot route waiting work to a freed lane (and
        // would stripe the victim onto an arbitrary engine), so preemption
        // under round-robin only lowers occupancy.
        if self.cfg.dispatch == DispatchPolicy::RoundRobin {
            return;
        }
        // Snapshot the CENTRAL-queue depth before preempting anything:
        // that is the work a freed lane can actually pull (engine-local
        // queues are already placed); the pre-pass snapshot keeps victims
        // requeued during this pass from licensing further preemption on
        // later engines, and bounds preemptions — freeing more lanes than
        // there are waiting requests just buys re-prefill churn.
        let mut budget = self.queue.len();
        for i in 0..self.engines.len() {
            if budget == 0 {
                return; // nothing (left) waiting: stragglers keep their lanes
            }
            let progress = self.engines[i].lane_progress();
            let mut victim: Option<usize> = None;
            let mut worst = 0.0f64;
            for p in &progress {
                if p.emitted < self.cfg.min_preempt_emitted {
                    continue;
                }
                let predicted = self.predictor.predict(p.prompt_id, p.prompt_len).max(1.0);
                let over = p.total as f64 / predicted;
                if over >= self.cfg.straggler_factor && over > worst {
                    worst = over;
                    victim = Some(p.lane);
                }
            }
            if let Some(lane) = victim {
                if let Some(r) = self.engines[i].preempt_lane(lane, version) {
                    self.predictor.observe_progress(
                        r.request.prompt_id,
                        r.request.prompt.len(),
                        r.response.len(),
                    );
                    self.preempted += 1;
                    budget -= 1;
                    // stale: a fresh prediction is captured on redispatch
                    self.dispatched_pred.remove(&r.request.rid);
                    self.queue.push_back(resume_request(&r));
                    self.queue_dirty = true;
                }
            }
        }
    }

    /// Preempt one specific lane of one engine back into the pool queue,
    /// progress kept (the policy-API `Preempt` decision; the periodic
    /// straggler sweep in [`Self::step`] uses the same machinery).
    /// Returns false if the (engine, lane) pair holds no active request.
    pub fn preempt(&mut self, engine: usize, lane: usize, version: u64) -> bool {
        if engine >= self.engines.len() {
            return false;
        }
        match self.engines[engine].preempt_lane(lane, version) {
            Some(r) => {
                self.predictor.observe_progress(
                    r.request.prompt_id,
                    r.request.prompt.len(),
                    r.response.len(),
                );
                self.preempted += 1;
                self.dispatched_pred.remove(&r.request.rid);
                self.queue.push_back(resume_request(&r));
                self.queue_dirty = true;
                true
            }
            None => false,
        }
    }

    /// Migrate work from engine `from` to engine `to` (the policy-API
    /// `Steal` decision): `lane: Some(l)` preempts running lane `l` and
    /// re-admits the partial on `to` (progress + log-probs kept, exactly
    /// the APRIL preempt machinery plus a targeted hand-off); `lane: None`
    /// moves the newest entry of `from`'s local queue.  Refused (returns
    /// false) when the migrated reservation cannot fit `to`'s KV budget.
    pub fn steal_to(&mut self, from: usize, to: usize, lane: Option<usize>,
                    version: u64) -> bool {
        let n = self.engines.len();
        if from >= n || to >= n || from == to {
            return false;
        }
        match lane {
            None => {
                let Some(req) = self.engines[from].steal_queued() else {
                    return false;
                };
                // queued work holds no KV yet, but refuse both what the
                // destination can never hold and what its current
                // headroom cannot admit — landing a fat request on a
                // KV-loaded engine would just mark IT blocked and
                // ping-pong the request straight back
                let dst = &self.engines[to];
                let res = dst.request_estimate(&req);
                if res > dst.kv_budget() || dst.kv_gate_refuses(dst.kv_used(), res) {
                    self.engines[from].submit([req]); // back where it was
                    return false;
                }
                self.stolen += 1;
                // dispatched_pred stays keyed by rid: the prediction that
                // drove the original placement still scores this request
                self.engines[to].submit([req]);
                true
            }
            Some(l) => {
                // pre-check the destination's CURRENT headroom: a lane
                // steal only pays off if the victim can re-admit promptly
                let reserve = self.engines[from]
                    .lane_progress()
                    .iter()
                    .find(|p| p.lane == l)
                    .map(|p| p.reserve);
                let Some(reserve) = reserve else { return false };
                if reserve > self.engines[to].kv_headroom() {
                    return false;
                }
                match self.engines[from].preempt_lane(l, version) {
                    Some(r) => {
                        self.predictor.observe_progress(
                            r.request.prompt_id,
                            r.request.prompt.len(),
                            r.response.len(),
                        );
                        self.stolen += 1;
                        self.dispatched_pred.remove(&r.request.rid);
                        let req = resume_request(&r);
                        self.hand_to_engine(to, req);
                        true
                    }
                    None => false,
                }
            }
        }
    }

    /// Drain finished rollouts from every engine, feeding the predictor
    /// (prediction scored BEFORE the observation lands).
    pub fn drain_finished(&mut self) -> Vec<Rollout> {
        let mut out = Vec::new();
        for e in self.engines.iter_mut() {
            out.extend(e.drain_finished());
        }
        for r in &out {
            if r.complete {
                let predicted = self
                    .dispatched_pred
                    .remove(&r.request.rid)
                    .unwrap_or_else(|| {
                        self.predictor.predict(r.request.prompt_id, r.request.prompt.len())
                    });
                self.score.push(predicted, r.response.len() as f64);
                self.predictor.observe(
                    r.request.prompt_id,
                    r.request.prompt.len(),
                    r.response.len(),
                );
                // new observation: queued SJF sort keys are stale
                self.queue_dirty = true;
            }
        }
        out
    }

    /// Terminate everything pool-wide. Returns partial rollouts (running
    /// lanes AND queued requests that carry preempted progress — their
    /// tokens must reach the buffer) plus untouched fresh requests.
    pub fn terminate_all(&mut self, version: u64) -> (Vec<Rollout>, Vec<Request>) {
        let mut partials = Vec::new();
        let mut queued: Vec<Request> = Vec::new();
        for e in self.engines.iter_mut() {
            let (p, q) = e.terminate_all(version);
            partials.extend(p);
            queued.extend(q);
        }
        queued.extend(self.queue.drain(..));
        self.dispatched_pred.clear(); // everything in flight is leaving
        let clock = self.clock();
        let (resumed, fresh): (Vec<Request>, Vec<Request>) =
            queued.into_iter().partition(|q| !q.resumed.is_empty());
        for q in resumed {
            partials.push(Rollout::partial(q, &[], &[], version, clock));
        }
        (partials, fresh)
    }

    /// Run until every submitted request finishes (baseline semantics).
    pub fn run_to_completion(&mut self, state: &ParamState) -> Result<Vec<Rollout>> {
        loop {
            self.admit(state)?;
            if self.running() == 0 {
                if self.queued() == 0 {
                    break;
                }
                continue;
            }
            self.step(state)?;
        }
        Ok(self.drain_finished())
    }
}
