//! `sched` — length-prediction + multi-engine scheduling (the pool layer).
//!
//! The seed reproduced SortedRL's core loop on a *single* engine that only
//! sorts *after* lengths are observed.  This subsystem adds the two pieces
//! the paper's "large rollout batches across engines" regime needs:
//!
//!   * [`LengthPredictor`] — online length prediction (Oracle / History /
//!     Bucket), scored live via [`crate::metrics::PredictorScore`]
//!     (MAE + Kendall tau).  Prediction replaces the controller's
//!     generate-to-sense discovery rotation: admission order is decided
//!     *before* tokens are spent.
//!   * [`EnginePool`] — N `rollout::Engine`s behind one submit/step/drain
//!     facade with a pluggable [`DispatchPolicy`] (round-robin /
//!     least-loaded / shortest-predicted-first) and APRIL-style preemptive
//!     partial requeue of long-tail stragglers.
//!
//! The simulator mirror lives in [`crate::sim`] (`simulate_pool`,
//! `pool_makespan`) so 1-vs-N engine comparisons run at paper scale in
//! milliseconds; `exp pool` and `benches/sched_bench.rs` drive it.
//!
//! [`policy`] is the unified scheduling brain: a [`SchedulePolicy`] emits
//! typed decisions that one generic driver executes against either the
//! live controller backend or the simulator backend, so every scheduler
//! (including the async-update one) is written exactly once.

pub mod harness;
pub mod policy;
pub mod pool;
pub mod predictor;
pub mod tail;

pub use policy::{
    drive, drive_traced, speed_to_q8, Decision, EngineLoad, EngineSpec, Event, HarvestAction,
    HarvestItem, KvGovernor, LaneView, PolicyBuilder, PolicyParams, SchedView, SchedulePolicy,
    ScheduleBackend, StealConfig, WorkStealing, ASYNC_SYNC_EVERY, SPEED_Q8_UNIT,
};
pub use pool::{resume_request, DispatchPolicy, EnginePool, PoolConfig};
pub use predictor::{
    make_predictor, sjf_priority, BucketPredictor, HistoryPredictor, LengthPredictor,
    OraclePredictor, PredictorKind,
};
pub use tail::{TailConfig, TailPacking};
