//! `TailPacking` — RollPacker-style tail rounds as a wrapper policy.
//!
//! The bubble ratio of every head-round schedule is dominated by
//! long-tail rollouts: a predicted-long request admitted into a head
//! round pins a lane (and its KV) through every harvest/update boundary
//! while its short cohort drains, so the pool grinds at low occupancy for
//! exactly the span the paper's Fig. 4 calls the bubble.  RollPacker
//! (PAPERS.md) defers those stragglers into dedicated **tail rounds**:
//! head rounds run the predicted-short bulk at full occupancy, and the
//! deferred tail is batched together onto a carved-out engine group whose
//! lanes/KV are **elastically repartitioned** from the head group for the
//! duration of the round.
//!
//! [`TailPacking`] implements that as a third composable wrapper, sitting
//! outermost above [`KvGovernor`](crate::sched::policy::KvGovernor) and
//! [`WorkStealing`](crate::sched::policy::WorkStealing) (see
//! `PolicyBuilder`):
//!
//!   * Every untargeted `Admit` from the inner policy is filtered:
//!     requests whose stamped prediction
//!     ([`ScheduleBackend::predicted_len`]) exceeds
//!     [`TailConfig::threshold`] are deferred; the rest pass through.
//!     Rank-only or absent predictors stamp nothing, so the wrapper is
//!     **inert by construction** exactly when estimates are meaningless —
//!     decision sequences stay byte-identical to the unwrapped policy.
//!   * A tail round opens when the deferred set can fill the tail group's
//!     lanes, or immediately when the head rounds starve (an all-deferred
//!     admission with nothing running or queued — the liveness guarantee:
//!     deferred work can never be stranded).
//!   * At the round boundary each head engine donates half its lanes
//!     (never below what it is running) and half its finite KV budget
//!     (never below what it has committed) to the tail group via
//!     [`Decision::Repartition`]; the deferred rids are admitted in
//!     ascending order as contiguous chunks targeted at the tail engines.
//!     Donations are conserving — total lanes/KV across the fleet are
//!     unchanged — and both sides' configured shapes are restored by
//!     mirror repartitions when the tail group drains.
//!
//! The tail group is the TOP of the engine index range (`tail_engines`
//! engines), so on heterogeneous fleets (`--engine-spec`) the
//! slow-big-KV engines naturally take the tail role when listed last.

use std::collections::{BTreeSet, VecDeque};

use crate::sched::policy::{
    Decision, Event, HarvestAction, HarvestItem, SchedView, SchedulePolicy, ScheduleBackend,
};

/// Knobs for the [`TailPacking`] wrapper (`--tail-threshold` /
/// `--tail-engines`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TailConfig {
    /// Predictions STRICTLY above this many response tokens are deferred
    /// into tail rounds.
    pub threshold: usize,
    /// Engines (top of the index range) forming the tail group.  Clamped
    /// to `engines - 1` at runtime so at least one head engine remains;
    /// on a single-engine fleet a tail round degrades to one batched
    /// admission of the deferred set (no repartition possible).
    pub tail_engines: usize,
}

impl TailConfig {
    /// CLI-style validation, mirroring the `--kv-page`/`--staleness`
    /// checks: a zero threshold would defer everything a predictor
    /// stamps, and a zero-sized tail group cannot host a round.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.threshold == 0 {
            anyhow::bail!("--tail-threshold must be >= 1 (every stamped request would defer)");
        }
        if self.tail_engines == 0 {
            anyhow::bail!("--tail-engines must be >= 1");
        }
        Ok(())
    }
}

/// Wrapper policy packing predicted-long requests into batched tail
/// rounds with elastic lane/KV repartitioning (see module docs).
/// Composes with every `SchedulerKind`.
pub struct TailPacking {
    inner: Box<dyn SchedulePolicy>,
    cfg: TailConfig,
    /// Deferred rids, ascending — tail admissions are deterministic.
    deferred: BTreeSet<u64>,
    /// Round-boundary decisions queued for the driver (repartitions,
    /// targeted admissions, restores), drained one per `decide`.
    pending: VecDeque<Decision>,
    /// Configured `(engine, lanes, kv_budget)` shapes to restore when the
    /// current round closes.
    saved: Vec<(usize, usize, usize)>,
    in_tail_round: bool,
    /// Round-close check runs at most once per tick (re-armed by
    /// `Event::Tick`), like the stealing/governor wrappers.
    armed: bool,
    tail_rounds: u64,
    repartitions: u64,
    tail_admitted: u64,
}

impl TailPacking {
    pub fn wrap(inner: Box<dyn SchedulePolicy>, cfg: TailConfig) -> Self {
        TailPacking {
            inner,
            cfg,
            deferred: BTreeSet::new(),
            pending: VecDeque::new(),
            saved: Vec::new(),
            in_tail_round: false,
            armed: true,
            tail_rounds: 0,
            repartitions: 0,
            tail_admitted: 0,
        }
    }

    /// Tail rounds opened so far.
    pub fn tail_rounds(&self) -> u64 {
        self.tail_rounds
    }

    /// Applied repartitions so far (donations + restores).
    pub fn repartitions(&self) -> u64 {
        self.repartitions
    }

    /// Requests admitted through tail rounds so far.
    pub fn tail_admitted(&self) -> u64 {
        self.tail_admitted
    }

    /// Size of the tail group on an `n`-engine fleet: at least one head
    /// engine always remains; 0 means "no group" (single engine).
    fn group(&self, n: usize) -> usize {
        self.cfg.tail_engines.min(n.saturating_sub(1))
    }

    fn should_open(&self, b: &dyn ScheduleBackend, head_empty: bool) -> bool {
        if self.deferred.is_empty() {
            return false;
        }
        let v = b.view();
        // starvation: the head rounds have nothing left to run, so the
        // deferred set is the only remaining work — open immediately
        // (this is the liveness guarantee; without it an all-deferred
        // admission loop would trip the driver's fruitless guard)
        if head_empty && v.running == 0 && v.queued == 0 {
            return true;
        }
        // capacity: enough deferred work to fill the tail group's lanes
        let loads = b.engine_loads();
        let t = self.group(loads.len());
        let cap: usize = if t == 0 {
            v.lanes
        } else {
            loads[loads.len() - t..].iter().map(|l| l.lanes).sum()
        };
        self.deferred.len() >= cap.max(1)
    }

    fn open_round(&mut self, b: &dyn ScheduleBackend) {
        let rids: Vec<u64> = std::mem::take(&mut self.deferred).into_iter().collect();
        self.tail_admitted += rids.len() as u64;
        self.in_tail_round = true;
        self.tail_rounds += 1;
        let loads = b.engine_loads();
        let n = loads.len();
        let t = self.group(n);
        if t == 0 {
            // single-engine fleet: a tail round is just the batched
            // admission of the deferred set
            self.pending.push_back(Decision::Admit { rids, engine: None });
            return;
        }
        let tail_start = n - t;
        // conserving donation: half of each head engine's lanes (never
        // below what it is running) and half its finite KV budget (never
        // below its committed usage)
        let mut lane_pool = 0usize;
        let mut kv_pool = 0usize;
        let mut donors: Vec<(usize, usize, usize)> = Vec::new();
        for (i, l) in loads.iter().enumerate().take(tail_start) {
            let give_l = (l.lanes / 2).min(l.lanes.saturating_sub(l.active));
            let give_k = if l.kv_budget == usize::MAX {
                0
            } else {
                (l.kv_budget / 2).min(l.kv_budget.saturating_sub(l.kv_used))
            };
            if give_l == 0 && give_k == 0 {
                continue;
            }
            lane_pool += give_l;
            kv_pool += give_k;
            donors.push((i, l.lanes - give_l, l.kv_budget - give_k));
        }
        // grow the tail group first (its admissions follow immediately;
        // growth can never violate a backend occupancy invariant), then
        // shrink the donors
        for (j, i) in (tail_start..n).enumerate() {
            let l = &loads[i];
            let extra_l = lane_pool / t + usize::from(j < lane_pool % t);
            let extra_k = kv_pool / t + usize::from(j < kv_pool % t);
            let new_kv = if l.kv_budget == usize::MAX {
                usize::MAX
            } else {
                l.kv_budget.saturating_add(extra_k)
            };
            if extra_l == 0 && new_kv == l.kv_budget {
                continue;
            }
            self.saved.push((i, l.lanes, l.kv_budget));
            self.pending.push_back(Decision::Repartition {
                engine: i,
                lanes: l.lanes + extra_l,
                kv: new_kv,
            });
        }
        for &(i, lanes, kv) in &donors {
            self.saved.push((i, loads[i].lanes, loads[i].kv_budget));
            self.pending.push_back(Decision::Repartition { engine: i, lanes, kv });
        }
        // targeted admissions: ascending rids in contiguous chunks across
        // the tail group
        let chunk = rids.len().div_ceil(t).max(1);
        for (k, c) in rids.chunks(chunk).enumerate() {
            self.pending.push_back(Decision::Admit {
                rids: c.to_vec(),
                engine: Some(tail_start + k.min(t - 1)),
            });
        }
    }

    fn round_over(&self, b: &dyn ScheduleBackend) -> bool {
        let loads = b.engine_loads();
        let t = self.group(loads.len());
        if t == 0 {
            let v = b.view();
            return v.running == 0 && v.queued == 0;
        }
        loads[loads.len() - t..]
            .iter()
            .all(|l| l.active == 0 && l.queued == 0)
    }

    fn close_round(&mut self) {
        // the saved list holds tail-group shapes first, donors second, so
        // draining it in order shrinks the tail group back BEFORE the
        // donors re-grow — total capacity never exceeds the configured
        // fleet at any intermediate decision
        for (engine, lanes, kv) in self.saved.drain(..) {
            self.pending.push_back(Decision::Repartition { engine, lanes, kv });
        }
        self.in_tail_round = false;
    }
}

impl SchedulePolicy for TailPacking {
    fn name(&self) -> &'static str {
        "tail-packing"
    }

    fn decide(&mut self, b: &dyn ScheduleBackend) -> Decision {
        if let Some(d) = self.pending.pop_front() {
            return d;
        }
        if self.in_tail_round && self.armed {
            self.armed = false;
            if self.round_over(b) {
                self.close_round();
                if let Some(d) = self.pending.pop_front() {
                    return d;
                }
            }
        }
        match self.inner.decide(b) {
            Decision::Admit { rids, engine: None } => {
                let mut head = Vec::with_capacity(rids.len());
                for rid in rids {
                    match b.predicted_len(rid) {
                        Some(p) if p > self.cfg.threshold => {
                            self.deferred.insert(rid);
                        }
                        _ => head.push(rid),
                    }
                }
                if !self.in_tail_round && self.should_open(b, head.is_empty()) {
                    self.open_round(b);
                }
                if head.is_empty() {
                    if let Some(d) = self.pending.pop_front() {
                        return d;
                    }
                }
                // an all-deferred admission with no round to open returns
                // the empty Admit, which the driver treats as a no-op
                Decision::Admit { rids: head, engine: None }
            }
            other => other,
        }
    }

    fn classify(&mut self, item: &HarvestItem, view: &SchedView) -> HarvestAction {
        self.inner.classify(item, view)
    }

    fn observe(&mut self, ev: &Event) {
        match ev {
            Event::Tick { .. } => self.armed = true,
            Event::Repartitioned { applied, .. } => {
                if *applied {
                    self.repartitions += 1;
                }
            }
            _ => {}
        }
        self.inner.observe(ev);
    }
}
