//! Deterministic multi-engine backend for policy-driver tests.
//!
//! [`TokenBackend`] is the `ScheduleBackend` the randomized fuzz suite
//! (`tests/policy_fuzz.rs`), the stealing goldens (`tests/policy_golden.rs`)
//! and the per-verdict pins (`tests/sched_props.rs`) all drive: N engines
//! of fixed lanes, one token per lane per tick, FIFO admission, the same
//! KV model as the live engine and the simulator (reserve-the-cap or
//! paged accounting per [`KvConfig`]; admission stops at the budget; an
//! otherwise-empty engine always admits one request; paged over-commit is
//! shed back under the budget inside the step), plus full support for
//! targeted admission, cross-engine stealing, and `Throttle` sheds.
//!
//! Unlike the mock in `policy.rs`'s unit tests it checks its own
//! invariants after EVERY backend call — conservation (each request lives
//! in exactly one place, across any number of steals), the KV budget
//! ceiling, a double-entry page ledger (every charge released exactly
//! once), progress bounds — so a driver run that completes is itself the
//! proof.

use crate::rollout::kv::{KvConfig, KvMode};
use crate::sched::policy::{
    speed_to_q8, EngineLoad, EngineSpec, HarvestAction, HarvestItem, LaneView, SchedView,
    ScheduleBackend,
};
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};

/// Fixed modeled prompt length (KV reservation = this + the response cap).
pub const HARNESS_PROMPT: usize = 4;

/// How `Admit { engine: None }` places work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HarnessDispatch {
    /// Round-robin stripe onto engine-local queues at admission (static
    /// placement — the mode where stealing has local backlog to move).
    Striped,
    /// Central FIFO queue; engines pull when a lane frees (late binding).
    Central,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum St {
    Unloaded,
    Fresh,
    /// Somewhere in the engine pool (a lane, a local queue, or central).
    Pool,
    /// Drained by a harvest, awaiting its verdict.
    Limbo,
    Ready,
    Consumed,
}

struct HEngine {
    lanes: usize,
    /// Per-engine KV budget.  Homogeneous constructors copy
    /// `KvConfig::budget` here; `--engine-spec` twins and
    /// `Decision::Repartition` reshape it per engine.
    budget: usize,
    /// Relative speed, reported through `EngineLoad::speed_q8` so
    /// spec-normalized routing keys see it.  The harness still decodes
    /// one token per lane per tick — speed shapes ROUTING, and the
    /// invariants must hold for any routing the policy derives from it.
    speed: f64,
    running: Vec<u64>,
    queue: VecDeque<u64>,
}

/// One recorded migration: (from, to, rid, progress tokens carried).
pub type StealEvent = (usize, usize, u64, usize);

pub struct TokenBackend {
    lens: Vec<usize>,
    progress: Vec<usize>,
    state: Vec<St>,
    engines: Vec<HEngine>,
    central: VecDeque<u64>,
    dispatch: HarnessDispatch,
    kv: KvConfig,
    /// Double-entry page ledger: rid -> (engine, charge) for every lane
    /// currently holding KV.  Every mutation of a `running` vector must
    /// mirror into this map; `check_invariants` proves the mirrored
    /// charges equal the derived usage and that every charge is released
    /// exactly once (an insert asserts absence, a release asserts
    /// presence).
    charged: BTreeMap<u64, (usize, usize)>,
    rr: usize,
    next_load: usize,
    ready_order: Vec<u64>,
    /// Generation ticks executed (one token per running lane per tick) —
    /// the harness's makespan, what stealing is supposed to shrink.
    pub ticks: u64,
    pub updates: usize,
    pub harvests: usize,
    /// Highest concurrent running-lane count ever observed (post-fill) —
    /// the admitted-lane headline paged KV accounting is meant to raise.
    pub peak_running: usize,
    /// Lanes force-evicted by the paged in-step backpressure path.
    pub kv_sheds: u64,
    /// Lanes shed by executed `Decision::Throttle`s.
    pub throttled: u64,
    /// Trainer-consumed rids, in consumption order.
    pub consumed: Vec<u64>,
    pub clipped: Vec<u64>,
    pub dropped: Vec<u64>,
    pub steal_log: Vec<StealEvent>,
    pub migrated_tokens: u64,
}

impl TokenBackend {
    /// Reserve-mode constructor (the pre-paging surface — every PR-3
    /// golden and fuzz call site builds through here unchanged).
    pub fn new(lens: &[usize], engines: usize, lanes_each: usize,
               dispatch: HarnessDispatch, kv_budget: usize) -> Self {
        Self::new_kv(lens, engines, lanes_each, dispatch, KvConfig {
            mode: KvMode::Reserve,
            budget: kv_budget,
            ..KvConfig::default()
        })
    }

    /// Full constructor with an explicit KV model (mode + budget + page).
    pub fn new_kv(lens: &[usize], engines: usize, lanes_each: usize,
                  dispatch: HarnessDispatch, kv: KvConfig) -> Self {
        assert!(engines >= 1 && lanes_each >= 1);
        assert!(lens.iter().all(|&l| l >= 1), "every request needs >= 1 token");
        assert!(kv.page >= 1, "kv page must be >= 1");
        let n = lens.len();
        TokenBackend {
            lens: lens.to_vec(),
            progress: vec![0; n],
            state: vec![St::Unloaded; n],
            engines: (0..engines)
                .map(|_| HEngine {
                    lanes: lanes_each,
                    budget: kv.budget,
                    speed: 1.0,
                    running: Vec::new(),
                    queue: VecDeque::new(),
                })
                .collect(),
            central: VecDeque::new(),
            dispatch,
            kv,
            charged: BTreeMap::new(),
            rr: 0,
            next_load: 0,
            ready_order: Vec::new(),
            ticks: 0,
            updates: 0,
            harvests: 0,
            peak_running: 0,
            kv_sheds: 0,
            throttled: 0,
            consumed: Vec::new(),
            clipped: Vec::new(),
            dropped: Vec::new(),
            steal_log: Vec::new(),
            migrated_tokens: 0,
        }
    }

    /// Heterogeneous-fleet constructor: one [`EngineSpec`] per engine
    /// (lanes / KV budget / speed).  `kv.mode`/`kv.page` set the shared
    /// accounting model; each spec's budget overrides `kv.budget` on its
    /// engine.
    pub fn new_specs(lens: &[usize], dispatch: HarnessDispatch, kv: KvConfig,
                     specs: &[EngineSpec]) -> Self {
        assert!(!specs.is_empty(), "need at least one engine spec");
        for s in specs {
            s.validate().expect("invalid engine spec");
        }
        let mut b = Self::new_kv(lens, specs.len(), 1, dispatch, kv);
        for (e, s) in b.engines.iter_mut().zip(specs) {
            e.lanes = s.lanes;
            e.budget = s.kv_budget;
            e.speed = s.speed;
        }
        b
    }

    /// The per-engine KV view: the shared mode/page with engine `i`'s own
    /// budget, so every gate/headroom/pressure helper prices against the
    /// budget that actually constrains that engine.
    fn kv_at(&self, i: usize) -> KvConfig {
        KvConfig { budget: self.engines[i].budget, ..self.kv }
    }

    /// What a lane holding `rid` charges right now (worst case in reserve
    /// mode, paged actual context otherwise).
    fn charge(&self, rid: u64) -> usize {
        let r = rid as usize;
        self.kv.lane_charge(HARNESS_PROMPT, self.progress[r], self.lens[r])
    }

    /// What the admission gate charges `rid` as a candidate.  The harness
    /// has no predictor, so the paged estimate falls back to the true
    /// length (== the cap — the harness twin of an exact oracle).
    fn estimate(&self, rid: u64) -> usize {
        let r = rid as usize;
        self.kv.admit_estimate(HARNESS_PROMPT, self.progress[r], self.lens[r], None)
    }

    fn kv_gate_refuses(&self, engine: usize, used: usize, estimate: usize) -> bool {
        self.kv_at(engine).gate_refuses(used, estimate)
    }

    fn kv_used(&self, engine: usize) -> usize {
        self.engines[engine]
            .running
            .iter()
            .map(|&rid| self.charge(rid))
            .sum()
    }

    /// Ledger: a lane starts holding KV (asserts it held none).
    fn charge_lane(&mut self, engine: usize, rid: u64) {
        let charge = self.charge(rid);
        let prev = self.charged.insert(rid, (engine, charge));
        assert!(prev.is_none(), "rid {rid} charged twice: {prev:?}");
    }

    /// Ledger: a lane releases its KV (asserts it held some).
    fn release_lane(&mut self, rid: u64) {
        let prev = self.charged.remove(&rid);
        assert!(prev.is_some(), "rid {rid} released KV it never charged");
    }

    /// The harness twin of the live engine's forced paged backpressure:
    /// evict the lane with the most predicted-remaining work (ties on
    /// paged fragmentation, then lowest lane) back to the queue, progress
    /// kept, until the budget holds or one lane remains — the same victim
    /// pricing as `KvConfig::victim_key` everywhere else.
    fn shed_over_budget(&mut self, i: usize) {
        if self.kv.mode != KvMode::Paged || self.engines[i].budget == usize::MAX {
            return;
        }
        while self.engines[i].running.len() > 1 && self.kv_used(i) > self.engines[i].budget {
            let pos = self.engines[i]
                .running
                .iter()
                .enumerate()
                .max_by_key(|&(pos, &rid)| {
                    let r = rid as usize;
                    (
                        self.kv.victim_key(HARNESS_PROMPT, self.progress[r], self.lens[r], None),
                        std::cmp::Reverse(pos),
                    )
                })
                .map(|(pos, _)| pos)
                .expect("running checked non-empty");
            let rid = self.engines[i].running.remove(pos);
            self.release_lane(rid);
            match self.dispatch {
                HarnessDispatch::Striped => self.engines[i].queue.push_back(rid),
                HarnessDispatch::Central => self.central.push_back(rid),
            }
            self.kv_sheds += 1;
        }
    }

    fn count(&self, s: St) -> usize {
        self.state.iter().filter(|&&x| x == s).count()
    }

    /// Admit queued work into engine `i`'s free lanes: local queue first,
    /// then (central mode) the shared queue, both behind the KV gate with
    /// the empty-engine escape.  The gate accumulates admission ESTIMATES
    /// within the pass (actual charges may be much smaller in paged mode,
    /// and co-admitting on them would over-commit a whole queue at once);
    /// the ledger charges the actual per-mode lane charge.
    fn fill(&mut self, i: usize) {
        let mut used = self.kv_used(i);
        loop {
            if self.engines[i].running.len() >= self.engines[i].lanes {
                break;
            }
            let local = self.engines[i].queue.front().copied();
            let rid = match local {
                Some(r) => r,
                None => {
                    if self.dispatch != HarnessDispatch::Central {
                        break;
                    }
                    match self.central.front().copied() {
                        Some(r) => r,
                        None => break,
                    }
                }
            };
            let est = self.estimate(rid);
            if self.kv_gate_refuses(i, used, est) {
                break;
            }
            if local.is_some() {
                self.engines[i].queue.pop_front();
            } else {
                self.central.pop_front();
            }
            used += est;
            self.engines[i].running.push(rid);
            self.charge_lane(i, rid);
        }
    }

    /// The harness's own conservation + KV contract, asserted after every
    /// backend call.
    pub fn check_invariants(&self) {
        for rid in 0..self.lens.len() {
            let occurrences = self
                .engines
                .iter()
                .map(|e| {
                    e.running.iter().filter(|&&r| r == rid as u64).count()
                        + e.queue.iter().filter(|&&r| r == rid as u64).count()
                })
                .sum::<usize>()
                + self.central.iter().filter(|&&r| r == rid as u64).count();
            let expected = usize::from(self.state[rid] == St::Pool);
            assert_eq!(
                occurrences, expected,
                "rid {rid} in state {:?} appears {occurrences}x in pool containers",
                self.state[rid]
            );
            let in_ready = self.ready_order.iter().filter(|&&r| r == rid as u64).count();
            assert_eq!(in_ready, usize::from(self.state[rid] == St::Ready),
                       "rid {rid} ready-list mismatch");
            assert!(self.progress[rid] <= self.lens[rid],
                    "rid {rid} progress {} past len {}", self.progress[rid], self.lens[rid]);
            let terminal = self.consumed.iter().filter(|&&r| r == rid as u64).count()
                + self.dropped.iter().filter(|&&r| r == rid as u64).count();
            assert_eq!(terminal, usize::from(self.state[rid] == St::Consumed),
                       "rid {rid} consumed/dropped {terminal}x in state {:?}",
                       self.state[rid]);
        }
        for (i, e) in self.engines.iter().enumerate() {
            let used = self.kv_used(i);
            // the empty-engine escape admits one oversized request alone;
            // beyond that the budget is a hard ceiling — in BOTH modes:
            // paged over-commit must have been shed back under the budget
            // before any transition completes
            assert!(used <= e.budget || e.running.len() == 1,
                    "engine {i} kv {used} over budget {} with {} lanes",
                    e.budget, e.running.len());
            assert!(e.running.len() <= e.lanes, "engine {i} over lanes");
            // double-entry ledger: the mirrored charges of this engine's
            // lanes must equal the derived usage, rid by rid
            for &rid in &e.running {
                let entry = self.charged.get(&rid);
                assert_eq!(entry, Some(&(i, self.charge(rid))),
                           "rid {rid} ledger mismatch on engine {i}: {entry:?}");
            }
        }
        // ...and nothing outside a lane may hold a charge (release-exactly-
        // once: queued, harvested, consumed work holds no KV)
        let lanes_total: usize = self.engines.iter().map(|e| e.running.len()).sum();
        assert_eq!(self.charged.len(), lanes_total,
                   "{} charges for {lanes_total} running lanes", self.charged.len());
    }
}

impl ScheduleBackend for TokenBackend {
    fn view(&self) -> SchedView {
        SchedView {
            running: self.engines.iter().map(|e| e.running.len()).sum(),
            queued: self.central.len()
                + self.engines.iter().map(|e| e.queue.len()).sum::<usize>(),
            ready: self.count(St::Ready),
            fresh: self.count(St::Fresh),
            unconsumed: self
                .state
                .iter()
                .filter(|&&s| !matches!(s, St::Unloaded | St::Consumed))
                .count(),
            lanes: self.engines.iter().map(|e| e.lanes).sum(),
            updates: self.updates,
        }
    }

    fn schedulable(&self) -> Vec<u64> {
        (0..self.lens.len())
            .filter(|&i| self.state[i] == St::Fresh)
            .map(|i| i as u64)
            .collect()
    }

    fn ready_rids(&self) -> Vec<u64> {
        self.ready_order.clone()
    }

    fn ready_len(&self, rid: u64) -> usize {
        self.progress[rid as usize]
    }

    fn engine_loads(&self) -> Vec<EngineLoad> {
        (0..self.engines.len())
            .map(|i| {
                let used = self.kv_used(i);
                let blocked = self
                    .engines[i]
                    .queue
                    .front()
                    .is_some_and(|&rid| self.kv_gate_refuses(i, used, self.estimate(rid)));
                EngineLoad {
                    queued: self.engines[i].queue.len(),
                    active: self.engines[i].running.len(),
                    lanes: self.engines[i].lanes,
                    kv_used: used,
                    kv_budget: self.engines[i].budget,
                    kv_blocked: blocked,
                    kv_pressure: self.kv_at(i).pressure(used, self.engines[i].running.len()),
                    speed_q8: speed_to_q8(self.engines[i].speed),
                }
            })
            .collect()
    }

    fn engine_lanes(&self, engine: usize) -> Vec<LaneView> {
        match self.engines.get(engine) {
            Some(e) => e
                .running
                .iter()
                .enumerate()
                .map(|(lane, &rid)| LaneView {
                    lane,
                    progress: self.progress[rid as usize],
                    reserve: self.estimate(rid),
                })
                .collect(),
            None => Vec::new(),
        }
    }

    fn trace_clock(&self) -> f64 {
        self.ticks as f64
    }

    fn lane_rids(&self, engine: usize) -> Vec<(usize, u64)> {
        match self.engines.get(engine) {
            Some(e) => e.running.iter().copied().enumerate().collect(),
            None => Vec::new(),
        }
    }

    fn load_prompts(&mut self, prompts: usize) -> Result<usize> {
        let mut count = 0;
        while count < prompts && self.next_load < self.lens.len() {
            self.state[self.next_load] = St::Fresh;
            self.next_load += 1;
            count += 1;
        }
        self.check_invariants();
        Ok(count)
    }

    fn admit(&mut self, rids: &[u64], engine: Option<usize>) -> Result<()> {
        for &rid in rids {
            assert_eq!(self.state[rid as usize], St::Fresh, "admit non-fresh {rid}");
            self.state[rid as usize] = St::Pool;
            match engine {
                Some(i) => self.engines[i].queue.push_back(rid),
                None => match self.dispatch {
                    HarnessDispatch::Striped => {
                        let i = self.rr % self.engines.len();
                        self.rr += 1;
                        self.engines[i].queue.push_back(rid);
                    }
                    HarnessDispatch::Central => self.central.push_back(rid),
                },
            }
        }
        self.check_invariants();
        Ok(())
    }

    fn step(&mut self) -> Result<usize> {
        self.ticks += 1;
        for i in 0..self.engines.len() {
            self.fill(i);
        }
        let admitted: usize = self.engines.iter().map(|e| e.running.len()).sum();
        self.peak_running = self.peak_running.max(admitted);
        let mut finished = 0;
        for i in 0..self.engines.len() {
            let running = std::mem::take(&mut self.engines[i].running);
            let mut still = Vec::with_capacity(running.len());
            for rid in running {
                let r = rid as usize;
                self.progress[r] += 1;
                if self.progress[r] >= self.lens[r] {
                    self.state[r] = St::Ready;
                    self.ready_order.push(rid);
                    finished += 1;
                    let prev = self.charged.remove(&rid);
                    assert!(prev.is_some(), "finished rid {rid} held no charge");
                } else {
                    // paged charges grow with the context: refresh the
                    // ledger to the post-token charge
                    let charge = self.kv.lane_charge(HARNESS_PROMPT, self.progress[r],
                                                     self.lens[r]);
                    let prev = self.charged.insert(rid, (i, charge));
                    assert!(prev.is_some(), "running rid {rid} held no charge");
                    still.push(rid);
                }
            }
            self.engines[i].running = still;
            self.shed_over_budget(i);
        }
        self.check_invariants();
        Ok(finished)
    }

    fn harvest_candidates(&mut self) -> Result<Vec<HarvestItem>> {
        self.harvests += 1;
        // (rid, progress, was_queued)
        let mut drained: Vec<(u64, usize, bool)> = Vec::new();
        for e in self.engines.iter_mut() {
            drained.extend(e.running.drain(..).map(|rid| (rid, 0, false)));
            drained.extend(e.queue.drain(..).map(|rid| (rid, 0, true)));
        }
        // every terminated lane releases its charge (exactly once)
        for &(rid, _, was_queued) in &drained {
            if !was_queued {
                self.release_lane(rid);
            }
        }
        drained.extend(self.central.drain(..).map(|rid| (rid, 0, true)));
        for d in drained.iter_mut() {
            d.1 = self.progress[d.0 as usize];
            self.state[d.0 as usize] = St::Limbo;
        }
        drained.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let items = drained
            .into_iter()
            .map(|(rid, progress, was_queued)| HarvestItem {
                rid,
                progress,
                // mirror the live/sim contract: a queued entry carrying
                // preempted progress is a partial, not untouched work
                queued: was_queued && progress == 0,
            })
            .collect();
        self.check_invariants();
        Ok(items)
    }

    fn resolve(&mut self, item: &HarvestItem, action: HarvestAction) -> Result<()> {
        let r = item.rid as usize;
        assert_eq!(self.state[r], St::Limbo, "resolve outside a harvest");
        match action {
            HarvestAction::Clip => {
                self.state[r] = St::Ready;
                self.ready_order.push(item.rid);
                self.clipped.push(item.rid);
            }
            HarvestAction::Restart => {
                self.progress[r] = 0;
                self.state[r] = St::Fresh;
            }
            HarvestAction::Resume | HarvestAction::Requeue => {
                self.state[r] = St::Fresh; // progress preserved
            }
            HarvestAction::Drop => {
                self.state[r] = St::Consumed;
                self.dropped.push(item.rid);
            }
        }
        self.check_invariants();
        Ok(())
    }

    fn preempt(&mut self, engine: usize, lane: usize) -> Result<()> {
        if engine < self.engines.len() && lane < self.engines[engine].running.len() {
            let rid = self.engines[engine].running.remove(lane);
            self.release_lane(rid);
            match self.dispatch {
                HarnessDispatch::Striped => self.engines[engine].queue.push_back(rid),
                HarnessDispatch::Central => self.central.push_back(rid),
            }
        }
        self.check_invariants();
        Ok(())
    }

    fn throttle(&mut self, engine: usize) -> Result<bool> {
        if engine >= self.engines.len() || self.engines[engine].running.len() < 2 {
            return Ok(false);
        }
        // shed the lane with the most predicted-remaining work (ties on
        // fragmentation) — the same victim rule as the forced in-step
        // path, routed like a preemption
        let pos = self.engines[engine]
            .running
            .iter()
            .enumerate()
            .max_by_key(|&(pos, &rid)| {
                let r = rid as usize;
                (
                    self.kv.victim_key(HARNESS_PROMPT, self.progress[r], self.lens[r], None),
                    std::cmp::Reverse(pos),
                )
            })
            .map(|(pos, _)| pos)
            .expect("running checked >= 2");
        let rid = self.engines[engine].running.remove(pos);
        self.release_lane(rid);
        match self.dispatch {
            HarnessDispatch::Striped => self.engines[engine].queue.push_back(rid),
            HarnessDispatch::Central => self.central.push_back(rid),
        }
        self.throttled += 1;
        self.check_invariants();
        Ok(true)
    }

    fn steal(&mut self, from: usize, to: usize, lane: Option<usize>) -> Result<bool> {
        let n = self.engines.len();
        if from >= n || to >= n || from == to {
            return Ok(false);
        }
        let moved = match lane {
            None => match self.engines[from].queue.pop_back() {
                Some(rid) => {
                    // refuse what the destination can never hold AND what
                    // its current headroom cannot admit — landing a fat
                    // request on a KV-loaded engine would just mark IT
                    // blocked and ping-pong the request straight back
                    let est = self.estimate(rid);
                    if est > self.engines[to].budget
                        || self.kv_gate_refuses(to, self.kv_used(to), est)
                    {
                        self.engines[from].queue.push_back(rid);
                        None
                    } else {
                        Some(rid)
                    }
                }
                None => None,
            },
            Some(l) => {
                if l < self.engines[from].running.len() {
                    let rid = self.engines[from].running[l];
                    let headroom = self.kv_at(to).headroom(self.kv_used(to));
                    if self.estimate(rid) > headroom {
                        None
                    } else {
                        self.engines[from].running.remove(l);
                        self.release_lane(rid);
                        Some(rid)
                    }
                } else {
                    None
                }
            }
        };
        let ok = match moved {
            Some(rid) => {
                self.engines[to].queue.push_back(rid);
                let progress = self.progress[rid as usize];
                self.steal_log.push((from, to, rid, progress));
                self.migrated_tokens += progress as u64;
                true
            }
            None => false,
        };
        self.check_invariants();
        Ok(ok)
    }

    fn repartition(&mut self, engine: usize, lanes: usize, kv: usize) -> Result<bool> {
        if engine >= self.engines.len() {
            return Ok(false);
        }
        // transactional: refuse any reshape that would strand running
        // lanes or committed KV (the single-lane escape mirrors the
        // admission gate), so the invariants hold unconditionally after
        let running = self.engines[engine].running.len();
        let used = self.kv_used(engine);
        let applied = lanes >= running && (kv >= used || running <= 1);
        if applied {
            self.engines[engine].lanes = lanes;
            self.engines[engine].budget = kv;
        }
        self.check_invariants();
        Ok(applied)
    }

    fn predicted_len(&self, rid: u64) -> Option<usize> {
        // the harness has no predictor; the stamped prediction is the
        // true length — the oracle twin `estimate` already prices with
        let r = rid as usize;
        (self.state.get(r) == Some(&St::Fresh)).then(|| self.lens[r])
    }

    fn train(&mut self, rids: &[u64]) -> Result<()> {
        for &rid in rids {
            assert_eq!(self.state[rid as usize], St::Ready, "train non-ready {rid}");
            self.state[rid as usize] = St::Consumed;
            self.ready_order.retain(|&r| r != rid);
            self.consumed.push(rid);
        }
        self.updates += 1;
        self.check_invariants();
        Ok(())
    }

    fn barrier(&mut self) -> Result<()> {
        self.check_invariants();
        Ok(())
    }

    fn exhausted(&self) -> bool {
        self.next_load >= self.lens.len() && self.state.iter().all(|&s| s == St::Consumed)
    }
}
