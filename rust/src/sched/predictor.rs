//! Online generation-length prediction (the pool scheduler's crystal ball).
//!
//! SortedRL's seed controller only *senses* lengths after generating tokens;
//! related work (Seer's online context learning, learning-to-rank length
//! predictors) shows the throughput headroom is in predicting lengths *ahead
//! of* generation so admission order and engine placement can be decided up
//! front.  Three predictors cover the quality spectrum:
//!
//!   * [`OraclePredictor`] — reads the true cost (simulator ground truth);
//!     the upper bound every other predictor is scored against.
//!   * [`HistoryPredictor`] — per-prompt EWMA over observed generation
//!     lengths across policy updates, warm-started from the prompt length
//!     and the global length mean (cheap, no model access).
//!   * [`BucketPredictor`] — rank-only quantile bucketing: predicts which
//!     length *bucket* a request falls into, not a token count.  Scored by
//!     Kendall tau (its MAE is intentionally meaningless) — the point is
//!     that SJF dispatch only needs order, not magnitude.
//!
//! Predictors are scored online via [`crate::metrics::PredictorScore`]
//! (push the prediction *before* observing the truth).

use std::collections::BTreeMap;

/// A length predictor keyed by prompt identity (`prompt_id` groups the G
/// samples of one prompt and survives preemption/resume cycles).
///
/// `predict` returns a priority score that orders requests by expected
/// generation length — token counts for Oracle/History, bucket indices for
/// Bucket.  Only the *order* is contractual.
pub trait LengthPredictor {
    fn name(&self) -> &'static str;

    /// True when `predict` returns rank scores (bucket indices) rather than
    /// token counts.  Callers must not mix rank scores with token
    /// quantities (progress subtraction, straggler ratios) — they may only
    /// compare them to each other.
    fn is_rank_only(&self) -> bool {
        false
    }

    /// Predicted total generation length (or rank score) for `key`.
    fn predict(&self, key: u64, prompt_len: usize) -> f64;

    /// Observe a finished generation's true length.
    fn observe(&mut self, key: u64, prompt_len: usize, observed: usize);

    /// Observe partial progress (a preempted request): `progress` is a
    /// LOWER bound on the final length.  Default: fold it in only when it
    /// already exceeds the current prediction — this is what stops a
    /// straggler from being preempted in a loop (each preemption raises
    /// its prediction toward its observed floor).
    fn observe_progress(&mut self, key: u64, prompt_len: usize, progress: usize) {
        if progress as f64 > self.predict(key, prompt_len) {
            self.observe(key, prompt_len, progress);
        }
    }
}

/// Which predictor an engine pool runs with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictorKind {
    Oracle,
    History,
    Bucket,
}

impl PredictorKind {
    pub const ALL: [PredictorKind; 3] =
        [PredictorKind::Oracle, PredictorKind::History, PredictorKind::Bucket];

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "oracle" => Self::Oracle,
            "history" | "ewma" => Self::History,
            "bucket" | "rank" => Self::Bucket,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Oracle => "oracle",
            Self::History => "history",
            Self::Bucket => "bucket",
        }
    }
}

pub fn make_predictor(kind: PredictorKind) -> Box<dyn LengthPredictor> {
    match kind {
        PredictorKind::Oracle => Box::new(OraclePredictor::new()),
        PredictorKind::History => Box::new(HistoryPredictor::new(0.5)),
        PredictorKind::Bucket => Box::new(BucketPredictor::new(8, 256)),
    }
}

/// Shortest-predicted-first priority for a request with `progress` tokens
/// already generated — THE policy shared by the real `EnginePool` and the
/// simulator mirror (one definition so they cannot drift):
///
///   * rank-only predictors return their rank unchanged (progress is a
///     token count and cannot be subtracted from a bucket index);
///   * otherwise the priority is predicted remaining = total - progress;
///   * an over-budget straggler (progress >= predicted total, e.g. after
///     a preemption floor-raised its prediction) takes its own progress
///     as the remaining estimate — heavy-tail conditional expectation —
///     so it queues behind fresh short work instead of collapsing to
///     minimum priority and reclaiming the lane it was preempted from.
pub fn sjf_priority(pred: &dyn LengthPredictor, key: u64, prompt_len: usize,
                    progress: usize) -> f64 {
    let total = pred.predict(key, prompt_len);
    if pred.is_rank_only() {
        return total;
    }
    let progress = progress as f64;
    let remaining = total - progress;
    if remaining >= 1.0 {
        remaining
    } else {
        progress.max(1.0)
    }
}

// --------------------------------------------------------------------------
// Oracle
// --------------------------------------------------------------------------

/// Knows the true generation length per key (fed from simulator ground
/// truth, or from a previous run's observations). Unknown keys fall back to
/// the prompt length so it degrades to a weak heuristic, never a panic.
#[derive(Debug, Default)]
pub struct OraclePredictor {
    truth: BTreeMap<u64, f64>,
}

impl OraclePredictor {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set_true(&mut self, key: u64, len: usize) {
        self.truth.insert(key, len as f64);
    }
}

impl LengthPredictor for OraclePredictor {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn predict(&self, key: u64, prompt_len: usize) -> f64 {
        self.truth.get(&key).copied().unwrap_or(prompt_len as f64)
    }

    fn observe(&mut self, key: u64, _prompt_len: usize, observed: usize) {
        // observing IS how the oracle reads true cost
        self.truth.insert(key, observed as f64);
    }
}

// --------------------------------------------------------------------------
// History (per-prompt EWMA)
// --------------------------------------------------------------------------

/// Per-prompt EWMA over observed lengths across updates.  Cold keys predict
/// the global EWMA; a completely cold predictor falls back to the prompt
/// length (long prompts tend to long answers in reasoning workloads — a
/// weak but harmless prior).
#[derive(Debug)]
pub struct HistoryPredictor {
    alpha: f64,
    per_key: BTreeMap<u64, f64>,
    global: f64,
    observations: u64,
}

impl HistoryPredictor {
    /// `alpha` governs the PER-KEY EWMA only.  The global fallback (what
    /// cold keys predict) smooths at a deliberately slower fixed 0.1 —
    /// a population statistic should move slower than a per-prompt one.
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        HistoryPredictor { alpha, per_key: BTreeMap::new(), global: 0.0, observations: 0 }
    }
}

impl LengthPredictor for HistoryPredictor {
    fn name(&self) -> &'static str {
        "history"
    }

    fn predict(&self, key: u64, prompt_len: usize) -> f64 {
        if let Some(&v) = self.per_key.get(&key) {
            v
        } else if self.observations > 0 {
            self.global
        } else {
            prompt_len as f64
        }
    }

    fn observe(&mut self, key: u64, _prompt_len: usize, observed: usize) {
        let x = observed as f64;
        self.global = if self.observations == 0 {
            x
        } else {
            0.1 * x + 0.9 * self.global
        };
        self.observations += 1;
        let e = self.per_key.entry(key).or_insert(x);
        *e = self.alpha * x + (1.0 - self.alpha) * *e;
    }

    /// Progress is a hard floor on the final length, so the per-key value
    /// jumps straight to it (no EWMA lag): an EWMA'd floor would stay a
    /// constant fraction below the observed length and the same straggler
    /// would be re-preempted geometrically often instead of the preemption
    /// self-extinguishing (work between preemptions doubles once the
    /// prediction tracks the floor).
    fn observe_progress(&mut self, key: u64, _prompt_len: usize, progress: usize) {
        if progress == 0 {
            return;
        }
        let x = progress as f64;
        let e = self.per_key.entry(key).or_insert(x);
        if x > *e {
            *e = x;
        }
    }
}

// --------------------------------------------------------------------------
// Bucket (rank-only quantile bucketing)
// --------------------------------------------------------------------------

/// Learning-to-rank style bucketing: keeps a bounded window of recent
/// observed lengths as an empirical distribution and predicts the quantile
/// bucket (0..buckets) of each key's last observed length.  Unseen keys
/// get the middle bucket.  Predictions are bucket indices — comparable to
/// each other but NOT token counts.
#[derive(Debug)]
pub struct BucketPredictor {
    buckets: usize,
    window: Vec<f64>,
    cap: usize,
    cursor: usize,
    last: BTreeMap<u64, f64>,
}

impl BucketPredictor {
    pub fn new(buckets: usize, window_cap: usize) -> Self {
        assert!(buckets >= 2 && window_cap >= buckets);
        BucketPredictor {
            buckets,
            window: Vec::new(),
            cap: window_cap,
            cursor: 0,
            last: BTreeMap::new(),
        }
    }

    fn bucket_of(&self, x: f64) -> f64 {
        if self.window.is_empty() {
            return (self.buckets / 2) as f64;
        }
        let below = self.window.iter().filter(|&&w| w < x).count();
        let q = below as f64 / self.window.len() as f64;
        (q * self.buckets as f64).min(self.buckets as f64 - 1.0).floor()
    }
}

impl LengthPredictor for BucketPredictor {
    fn name(&self) -> &'static str {
        "bucket"
    }

    fn is_rank_only(&self) -> bool {
        true
    }

    fn predict(&self, key: u64, _prompt_len: usize) -> f64 {
        match self.last.get(&key) {
            Some(&x) => self.bucket_of(x),
            None => (self.buckets / 2) as f64,
        }
    }

    fn observe(&mut self, key: u64, _prompt_len: usize, observed: usize) {
        let x = observed as f64;
        if self.window.len() < self.cap {
            self.window.push(x);
        } else {
            self.window[self.cursor] = x;
            self.cursor = (self.cursor + 1) % self.cap;
        }
        self.last.insert(key, x);
    }

    fn observe_progress(&mut self, key: u64, _prompt_len: usize, progress: usize) {
        // rank-only: a progress floor still moves the key's rank upward
        let x = progress as f64;
        let cur = self.last.get(&key).copied().unwrap_or(0.0);
        if x > cur {
            self.last.insert(key, x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_reads_true_cost() {
        let mut p = OraclePredictor::new();
        p.set_true(1, 500);
        p.set_true(2, 50);
        assert_eq!(p.predict(1, 64), 500.0);
        assert_eq!(p.predict(2, 64), 50.0);
        assert_eq!(p.predict(3, 64), 64.0); // fallback: prompt length
        assert_eq!(p.name(), "oracle");
    }

    #[test]
    fn history_ewma_converges_and_warm_starts() {
        let mut p = HistoryPredictor::new(0.5);
        // cold: prompt-length prior
        assert_eq!(p.predict(9, 128), 128.0);
        for _ in 0..12 {
            p.observe(1, 64, 100);
        }
        assert!((p.predict(1, 64) - 100.0).abs() < 1.0);
        // unseen key now predicts the global mean, not the prompt prior
        let g = p.predict(42, 64);
        assert!((g - 100.0).abs() < 1.0, "{g}");
    }

    #[test]
    fn history_tracks_per_key_differences() {
        let mut p = HistoryPredictor::new(0.5);
        for _ in 0..8 {
            p.observe(1, 64, 40);
            p.observe(2, 64, 400);
        }
        assert!(p.predict(1, 64) < p.predict(2, 64));
    }

    #[test]
    fn bucket_orders_short_before_long() {
        let mut p = BucketPredictor::new(8, 64);
        // build an empirical length distribution
        for i in 0..32 {
            p.observe(100 + i, 64, (i as usize + 1) * 20);
        }
        p.observe(1, 64, 30); // short key
        p.observe(2, 64, 600); // long key
        assert!(p.predict(1, 64) < p.predict(2, 64));
        // bucket indices stay inside [0, buckets)
        assert!(p.predict(2, 64) <= 7.0);
        assert!(p.predict(1, 64) >= 0.0);
    }

    #[test]
    fn observe_progress_raises_straggler_prediction() {
        let mut p = HistoryPredictor::new(0.5);
        p.observe(1, 64, 50);
        let before = p.predict(1, 64);
        p.observe_progress(1, 64, 400); // blew past its prediction
        assert!(p.predict(1, 64) > before);
        p.observe_progress(1, 64, 10); // below prediction: ignored
        assert!(p.predict(1, 64) > before);
    }

    #[test]
    fn make_predictor_covers_all_kinds() {
        for kind in PredictorKind::ALL {
            let p = make_predictor(kind);
            assert_eq!(p.name(), kind.name());
            assert_eq!(PredictorKind::parse(kind.name()), Some(kind));
            assert_eq!(p.is_rank_only(), kind == PredictorKind::Bucket);
        }
        assert_eq!(PredictorKind::parse("nope"), None);
    }
}
