//! Chrome trace-event JSON emission (the Perfetto-compatible subset).
//!
//! Layout convention for a scheduling trace:
//!
//! * **pid 0** is the driver/pool: refill, harvest, update, barrier
//!   instants plus the pool-wide `queued` counter track.
//! * **pid e+1** is engine `e`: lane slices live on `tid lane+1`
//!   (tid 0 carries the engine's instant events — steal, shed, preempt),
//!   and the engine owns `kv_used` / `running` counter tracks.
//! * One `"X"` complete event per finished request: `ts` = first token,
//!   `dur` = decode span, args carry rid/tokens/ttft/tpot/queue-wait.
//!
//! Counter tracks are coalesced on value change while recording and
//! downsampled to [`MAX_COUNTER_POINTS`] at [`ChromeTrace::finish`] so a
//! multi-million-tick run still loads in the Perfetto UI.  Every emitted
//! event — including the `"M"` metadata records — carries pid/tid/ts/ph,
//! which the schema round-trip test relies on.  Timestamps convert from
//! backend clock units to microseconds (`displayTimeUnit: "ms"`).

use crate::util::json::{arr, num, obj, s, Json};
use std::collections::{BTreeMap, HashMap};

use super::series;

/// Per-track point cap applied at `finish()`.
pub const MAX_COUNTER_POINTS: usize = 2048;

/// One counter time series ((clock, value), coalesced on value change).
#[derive(Debug, Clone)]
struct CounterTrack {
    pid: usize,
    name: String,
    points: Vec<(f64, f64)>,
}

/// Accumulates trace events and serializes the Chrome trace-event format.
#[derive(Debug, Clone, Default)]
pub struct ChromeTrace {
    events: Vec<Json>,
    tracks: Vec<CounterTrack>,
    track_idx: HashMap<(usize, String), usize>,
    processes: BTreeMap<usize, String>,
    threads: BTreeMap<(usize, usize), String>,
}

/// Clock units -> integer microseconds (Perfetto sorts on ts; emitting
/// whole numbers also keeps the JSON writer on the integer path).
fn us(clock: f64) -> f64 {
    if clock.is_finite() {
        (clock * 1e6).round()
    } else {
        0.0
    }
}

fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

impl ChromeTrace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Name a process row (idempotent; first name wins).
    pub fn process(&mut self, pid: usize, name: &str) {
        self.processes.entry(pid).or_insert_with(|| name.to_string());
    }

    /// Name a thread row within a process.
    pub fn thread(&mut self, pid: usize, tid: usize, name: &str) {
        self.threads.entry((pid, tid)).or_insert_with(|| name.to_string());
    }

    /// `"X"` complete event (a horizontal slice from `ts` for `dur`).
    pub fn slice(
        &mut self,
        pid: usize,
        tid: usize,
        ts: f64,
        dur: f64,
        name: &str,
        args: Vec<(&str, Json)>,
    ) {
        self.events.push(obj(vec![
            ("name", s(name)),
            ("ph", s("X")),
            ("pid", num(pid as f64)),
            ("tid", num(tid as f64)),
            ("ts", num(us(ts))),
            ("dur", num(us(dur).max(1.0))),
            ("args", obj(args)),
        ]));
    }

    /// `"i"` instant event (thread scope).
    pub fn instant(&mut self, pid: usize, tid: usize, ts: f64, name: &str, args: Vec<(&str, Json)>) {
        self.events.push(obj(vec![
            ("name", s(name)),
            ("ph", s("i")),
            ("s", s("t")),
            ("pid", num(pid as f64)),
            ("tid", num(tid as f64)),
            ("ts", num(us(ts))),
            ("args", obj(args)),
        ]));
    }

    /// Sample a counter track; consecutive equal values are coalesced.
    pub fn counter(&mut self, pid: usize, name: &str, clock: f64, value: f64) {
        let value = finite(value);
        let key = (pid, name.to_string());
        let idx = match self.track_idx.get(&key) {
            Some(&i) => i,
            None => {
                let i = self.tracks.len();
                self.tracks.push(CounterTrack { pid, name: key.1.clone(), points: Vec::new() });
                self.track_idx.insert(key, i);
                i
            }
        };
        let t = &mut self.tracks[idx];
        if t.points.last().map(|&(_, v)| v) != Some(value) {
            t.points.push((finite(clock), value));
        }
    }

    /// Number of events emitted so far plus counter points still buffered
    /// (pre-downsampling; for progress messages).
    pub fn event_count(&self) -> usize {
        self.events.len() + self.tracks.iter().map(|t| t.points.len()).sum::<usize>()
    }

    /// Serialize: metadata first, then slices/instants, then counter
    /// tracks (each downsampled, points in recording order so `ts` is
    /// monotone per track).
    pub fn finish(&self) -> Json {
        let mut all = Vec::new();
        for (pid, name) in &self.processes {
            all.push(obj(vec![
                ("name", s("process_name")),
                ("ph", s("M")),
                ("pid", num(*pid as f64)),
                ("tid", num(0.0)),
                ("ts", num(0.0)),
                ("args", obj(vec![("name", s(name))])),
            ]));
        }
        for ((pid, tid), name) in &self.threads {
            all.push(obj(vec![
                ("name", s("thread_name")),
                ("ph", s("M")),
                ("pid", num(*pid as f64)),
                ("tid", num(*tid as f64)),
                ("ts", num(0.0)),
                ("args", obj(vec![("name", s(name))])),
            ]));
        }
        all.extend(self.events.iter().cloned());
        for t in &self.tracks {
            for &(clock, v) in series::downsample(&t.points, MAX_COUNTER_POINTS).iter() {
                all.push(obj(vec![
                    ("name", s(&t.name)),
                    ("ph", s("C")),
                    ("pid", num(t.pid as f64)),
                    ("tid", num(0.0)),
                    ("ts", num(us(clock))),
                    ("args", obj(vec![("value", num(v))])),
                ]));
            }
        }
        obj(vec![("traceEvents", arr(all)), ("displayTimeUnit", s("ms"))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_event_has_required_fields() {
        let mut c = ChromeTrace::new();
        c.process(0, "driver");
        c.process(1, "engine 0");
        c.thread(1, 1, "lane 0");
        c.slice(1, 1, 1.0, 2.0, "req 0", vec![("rid", num(0.0))]);
        c.instant(0, 0, 0.5, "refill", vec![]);
        c.counter(1, "running", 0.0, 1.0);
        c.counter(1, "running", 1.0, 2.0);
        let j = c.finish();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(evs.len() >= 7); // 3 M + X + i + 2 C
        for e in evs {
            for k in ["pid", "tid", "ts", "ph"] {
                assert!(e.get(k).is_some(), "missing {k} in {e:?}");
            }
        }
        assert_eq!(j.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    }

    #[test]
    fn counters_coalesce_equal_values() {
        let mut c = ChromeTrace::new();
        for t in 0..10 {
            c.counter(0, "queued", t as f64, 5.0);
        }
        c.counter(0, "queued", 10.0, 6.0);
        assert_eq!(c.tracks[0].points.len(), 2);
    }

    #[test]
    fn round_trips_through_parser() {
        let mut c = ChromeTrace::new();
        c.process(0, "driver");
        c.instant(0, 0, 1.25, "update", vec![("rids", num(4.0))]);
        let text = c.finish().to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("traceEvents").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn nonfinite_inputs_never_reach_json() {
        let mut c = ChromeTrace::new();
        c.counter(0, "kv", f64::NAN, f64::INFINITY);
        c.slice(0, 0, f64::NAN, f64::NAN, "x", vec![]);
        let text = c.finish().to_string_compact();
        assert!(Json::parse(&text).is_ok());
        assert!(!text.contains("NaN") && !text.contains("inf"));
    }
}
