//! `TelemetryHub` — the SLO aggregation side of the tracer.
//!
//! The hub consumes finished [`RequestSpan`]s plus driver-level events and
//! keeps: raw latency samples (exact quantiles for reports), log-bucketed
//! histograms (the tail view, shared `util::stats` machinery), per-engine
//! counters with cause attribution (steals in/out, governor sheds, forced
//! preempts, KV-pressure ticks), and per-decision tallies keyed by
//! `Decision::label`.  Everything is in backend clock units; the CLI
//! converts `--slo MS` before construction.

use crate::util::stats::{quantile, LogHistogram};
use std::collections::BTreeMap;

use super::span::{RequestSpan, SpanOutcome};

/// Per-engine intervention counters (cause attribution: a lane leaving an
/// engine is a steal, a governor shed, or a forced preempt — never just
/// "a preemption").
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineCounters {
    /// Requests migrated away by executed steals.
    pub steals_out: u64,
    /// Requests migrated in by executed steals.
    pub steals_in: u64,
    /// Lanes shed by the KV governor (`Decision::Throttle`).
    pub sheds: u64,
    /// Lanes forced out by `Decision::Preempt`.
    pub preempts: u64,
    /// Post-step samples in which this engine reported `kv_pressure`.
    pub kv_pressure_ticks: u64,
    /// Post-step samples in which this engine reported `kv_blocked`.
    pub kv_blocked_ticks: u64,
}

/// SLO roll-up of one traced run (all times in backend clock units —
/// simulated seconds, live host seconds, or harness ticks).  Quantiles are
/// exact (computed from raw samples, `util::stats::quantile`); the hub's
/// log-histograms carry the same data for tail visualization.
#[derive(Debug, Clone, Default)]
pub struct SloSummary {
    /// Spans that ever entered the buffer.
    pub enqueued: usize,
    /// Natural completions (full length).
    pub completed: usize,
    /// Harvest-clipped (trained at partial length).
    pub clipped: usize,
    /// Dropped without training.
    pub dropped: usize,
    pub ttft_p50: f64,
    pub ttft_p90: f64,
    pub ttft_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p90: f64,
    pub tpot_p99: f64,
    pub e2e_p50: f64,
    pub e2e_p99: f64,
    pub queue_p50: f64,
    pub queue_p99: f64,
    pub mean_ttft: f64,
    pub mean_tpot: f64,
    /// The SLO threshold the goodput was judged against (clock units).
    pub slo: Option<f64>,
    /// Fraction of enqueued requests that produced a trained trajectory
    /// (completed or clipped) within the SLO; with no SLO set, simply the
    /// fraction that produced one at all.
    pub goodput: f64,
}

/// Latency + counter aggregation for one traced run.
#[derive(Debug, Clone)]
pub struct TelemetryHub {
    /// SLO threshold in backend clock units (None = no deadline).
    pub slo: Option<f64>,
    pub enqueued: usize,
    pub completed: usize,
    pub clipped: usize,
    pub dropped: usize,
    pub consumed: usize,
    slo_met: usize,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    e2e: Vec<f64>,
    queue_wait: Vec<f64>,
    /// Log-bucketed tails (20 bins/decade over 12 decades — wide enough
    /// for tick clocks and second clocks alike).
    pub ttft_hist: LogHistogram,
    pub e2e_hist: LogHistogram,
    pub engines: Vec<EngineCounters>,
    /// Driver decisions by `Decision::label`.
    pub decisions: BTreeMap<&'static str, u64>,
    pub ticks: u64,
    pub refills: u64,
    pub prompts_loaded: u64,
    pub harvests: u64,
    pub updates: u64,
    pub barriers: u64,
    pub steals_refused: u64,
    pub throttles_refused: u64,
}

impl TelemetryHub {
    pub fn new(slo: Option<f64>) -> Self {
        TelemetryHub {
            slo,
            enqueued: 0,
            completed: 0,
            clipped: 0,
            dropped: 0,
            consumed: 0,
            slo_met: 0,
            ttft: Vec::new(),
            tpot: Vec::new(),
            e2e: Vec::new(),
            queue_wait: Vec::new(),
            ttft_hist: LogHistogram::new(1e-6, 1e6, 240),
            e2e_hist: LogHistogram::new(1e-6, 1e6, 240),
            engines: Vec::new(),
            decisions: BTreeMap::new(),
            ticks: 0,
            refills: 0,
            prompts_loaded: 0,
            harvests: 0,
            updates: 0,
            barriers: 0,
            steals_refused: 0,
            throttles_refused: 0,
        }
    }

    /// Per-engine counter slot, grown on demand.
    pub fn engine(&mut self, i: usize) -> &mut EngineCounters {
        if i >= self.engines.len() {
            self.engines.resize(i + 1, EngineCounters::default());
        }
        &mut self.engines[i]
    }

    pub fn tally(&mut self, label: &'static str) {
        *self.decisions.entry(label).or_insert(0) += 1;
    }

    /// Fold one finished span into the latency aggregates.  Clipped spans
    /// count (they produced a trained trajectory); drops only count in the
    /// outcome tallies.
    pub fn finish_span(&mut self, span: &RequestSpan) {
        match span.outcome {
            SpanOutcome::Completed => self.completed += 1,
            SpanOutcome::Clipped => self.clipped += 1,
            SpanOutcome::Dropped => {
                self.dropped += 1;
                return;
            }
            SpanOutcome::InFlight => return,
        }
        if let Some(t) = span.ttft() {
            self.ttft.push(t);
            self.ttft_hist.push(t);
        }
        if let Some(t) = span.tpot() {
            self.tpot.push(t);
        }
        if let Some(t) = span.queue_wait() {
            self.queue_wait.push(t);
        }
        if let Some(t) = span.e2e() {
            self.e2e.push(t);
            self.e2e_hist.push(t);
            if self.slo.is_none_or(|s| t <= s) {
                self.slo_met += 1;
            }
        }
    }

    pub fn summary(&self) -> SloSummary {
        // exact quantiles from the raw samples; `q0` guards the NaN an
        // empty sample set would leak into JSON artifacts
        let q0 = |xs: &[f64], q: f64| if xs.is_empty() { 0.0 } else { quantile(xs, q) };
        let mean = |xs: &[f64]| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        SloSummary {
            enqueued: self.enqueued,
            completed: self.completed,
            clipped: self.clipped,
            dropped: self.dropped,
            ttft_p50: q0(&self.ttft, 0.50),
            ttft_p90: q0(&self.ttft, 0.90),
            ttft_p99: q0(&self.ttft, 0.99),
            tpot_p50: q0(&self.tpot, 0.50),
            tpot_p90: q0(&self.tpot, 0.90),
            tpot_p99: q0(&self.tpot, 0.99),
            e2e_p50: q0(&self.e2e, 0.50),
            e2e_p99: q0(&self.e2e, 0.99),
            queue_p50: q0(&self.queue_wait, 0.50),
            queue_p99: q0(&self.queue_wait, 0.99),
            mean_ttft: mean(&self.ttft),
            mean_tpot: mean(&self.tpot),
            slo: self.slo,
            goodput: if self.enqueued == 0 {
                0.0
            } else {
                self.slo_met as f64 / self.enqueued as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::span::SpanOutcome;

    fn span(rid: u64, ft: f64, fin: f64, tokens: usize, outcome: SpanOutcome) -> RequestSpan {
        let mut s = RequestSpan::new(rid, 0.0);
        s.dispatched = Some(0.0);
        s.first_token = Some(ft);
        s.finished = Some(fin);
        s.tokens = tokens;
        s.outcome = outcome;
        s
    }

    #[test]
    fn summary_quantiles_and_goodput() {
        let mut hub = TelemetryHub::new(Some(4.0));
        hub.enqueued = 4;
        for (rid, fin) in [(0, 3.0), (1, 5.0), (2, 3.0), (3, 5.0)] {
            hub.finish_span(&span(rid, 1.0, fin, 3, SpanOutcome::Completed));
        }
        let s = hub.summary();
        assert_eq!(s.completed, 4);
        assert!((s.ttft_p50 - 1.0).abs() < 1e-12);
        assert!((s.e2e_p50 - 4.0).abs() < 1e-12); // interp of [3,3,5,5]
        assert!((s.e2e_p99 - 5.0).abs() < 1e-12);
        assert!((s.goodput - 0.5).abs() < 1e-12); // two of four within 4.0
    }

    #[test]
    fn drops_and_inflight_skip_latency_stats() {
        let mut hub = TelemetryHub::new(None);
        hub.enqueued = 2;
        hub.finish_span(&span(0, 1.0, 2.0, 2, SpanOutcome::Dropped));
        hub.finish_span(&RequestSpan::new(1, 0.0)); // in-flight
        let s = hub.summary();
        assert_eq!(s.dropped, 1);
        assert_eq!(s.completed, 0);
        assert_eq!(s.e2e_p99, 0.0); // guarded, not NaN
        assert_eq!(s.goodput, 0.0);
    }

    #[test]
    fn engine_counters_grow_on_demand() {
        let mut hub = TelemetryHub::new(None);
        hub.engine(3).sheds += 1;
        assert_eq!(hub.engines.len(), 4);
        assert_eq!(hub.engines[3].sheds, 1);
        hub.tally("step");
        hub.tally("step");
        assert_eq!(hub.decisions["step"], 2);
    }
}
