//! `TelemetryHub` — the SLO aggregation side of the tracer.
//!
//! The hub consumes finished [`RequestSpan`]s plus driver-level events and
//! keeps: raw latency samples (exact quantiles for reports), log-bucketed
//! histograms (the tail view, shared `util::stats` machinery), per-engine
//! counters with cause attribution (steals in/out, governor sheds, forced
//! preempts, KV-pressure ticks), and per-decision tallies keyed by
//! `Decision::label`.  Everything is in backend clock units; the CLI
//! converts `--slo MS` before construction.

use crate::util::json::{num, Json};
use crate::util::stats::{quantile, LogHistogram};
use std::collections::BTreeMap;

use super::span::{RequestSpan, SpanOutcome};

/// Per-engine intervention counters (cause attribution: a lane leaving an
/// engine is a steal, a governor shed, or a forced preempt — never just
/// "a preemption").
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineCounters {
    /// Requests migrated away by executed steals.
    pub steals_out: u64,
    /// Requests migrated in by executed steals.
    pub steals_in: u64,
    /// Lanes shed by the KV governor (`Decision::Throttle`).
    pub sheds: u64,
    /// Lanes forced out by `Decision::Preempt`.
    pub preempts: u64,
    /// Post-step samples in which this engine reported `kv_pressure`.
    pub kv_pressure_ticks: u64,
    /// Post-step samples in which this engine reported `kv_blocked`.
    pub kv_blocked_ticks: u64,
    /// Applied `Decision::Repartition`s that resized this engine (tail
    /// rounds produce these in donate/restore pairs).
    pub repartitions: u64,
}

/// Per-tenant SLO roll-up for open-loop runs (tenants come from the
/// arrival stream; closed-loop runs register no arrivals and report no
/// tenants).  Latencies are ARRIVAL-relative: `first_token - arrival_t`
/// and `finished - arrival_t`, the open-loop quantities queueing theory
/// talks about.
#[derive(Debug, Clone, Default)]
pub struct TenantSlo {
    pub tenant: usize,
    /// Arrivals registered for this tenant.
    pub enqueued: usize,
    pub completed: usize,
    pub clipped: usize,
    pub dropped: usize,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    /// Inter-token latency quantiles — the open-loop streaming SLO
    /// (TTFT tells you when output starts; TPOT how fast it flows).
    pub tpot_p50: f64,
    pub tpot_p99: f64,
    pub e2e_p50: f64,
    pub e2e_p99: f64,
    /// Fraction of this tenant's arrivals trained within the SLO.
    pub goodput: f64,
}

/// SLO roll-up of one traced run (all times in backend clock units —
/// simulated seconds, live host seconds, or harness ticks).  Quantiles are
/// exact (computed from raw samples, `util::stats::quantile`); the hub's
/// log-histograms carry the same data for tail visualization.
#[derive(Debug, Clone, Default)]
pub struct SloSummary {
    /// Spans that ever entered the buffer.
    pub enqueued: usize,
    /// Natural completions (full length).
    pub completed: usize,
    /// Harvest-clipped (trained at partial length).
    pub clipped: usize,
    /// Dropped without training.
    pub dropped: usize,
    pub ttft_p50: f64,
    pub ttft_p90: f64,
    pub ttft_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p90: f64,
    pub tpot_p99: f64,
    pub e2e_p50: f64,
    pub e2e_p99: f64,
    pub queue_p50: f64,
    pub queue_p99: f64,
    pub mean_ttft: f64,
    pub mean_tpot: f64,
    /// The SLO threshold the goodput was judged against (clock units).
    pub slo: Option<f64>,
    /// Fraction of enqueued requests that produced a trained trajectory
    /// (completed or clipped) within the SLO; with no SLO set, simply the
    /// fraction that produced one at all.
    pub goodput: f64,
    /// Per-tenant roll-ups (open-loop runs only; empty for closed loop).
    pub tenants: Vec<TenantSlo>,
    /// Jain fairness index over per-tenant delivered fractions:
    /// `(Σx)² / (n·Σx²)`, 1.0 = perfectly fair, → 1/n under starvation.
    /// 1.0 when fewer than two tenants exist (nothing to be unfair to).
    pub fairness_jain: f64,
    /// Pool queue depth over time: `(clock, waiting requests)` samples,
    /// deduplicated on change and downsampled to ≤ 256 points.
    pub queue_depth: Vec<(f64, usize)>,
    /// Off-policy degree of everything trained on: `hist[d]` = samples
    /// whose consuming update ran `d` weight versions after their first
    /// response token.  Filled only by backends that report per-sample
    /// staleness (`ScheduleBackend::staleness_of`); empty otherwise.
    pub staleness_hist: BTreeMap<u64, u64>,
    /// Largest per-sample version delta trained on — with `--staleness N`
    /// this is provably `<= N`.
    pub max_staleness: u64,
}

impl SloSummary {
    /// JSON artifact form (what `--slo-out` and `exp pool` write).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("enqueued".into(), num(self.enqueued as f64));
        o.insert("completed".into(), num(self.completed as f64));
        o.insert("clipped".into(), num(self.clipped as f64));
        o.insert("dropped".into(), num(self.dropped as f64));
        o.insert("ttft_p50".into(), num(self.ttft_p50));
        o.insert("ttft_p90".into(), num(self.ttft_p90));
        o.insert("ttft_p99".into(), num(self.ttft_p99));
        o.insert("tpot_p50".into(), num(self.tpot_p50));
        o.insert("tpot_p90".into(), num(self.tpot_p90));
        o.insert("tpot_p99".into(), num(self.tpot_p99));
        o.insert("e2e_p50".into(), num(self.e2e_p50));
        o.insert("e2e_p99".into(), num(self.e2e_p99));
        o.insert("queue_p50".into(), num(self.queue_p50));
        o.insert("queue_p99".into(), num(self.queue_p99));
        o.insert("mean_ttft".into(), num(self.mean_ttft));
        o.insert("mean_tpot".into(), num(self.mean_tpot));
        o.insert(
            "slo".into(),
            self.slo.map(num).unwrap_or(Json::Null),
        );
        o.insert("goodput".into(), num(self.goodput));
        o.insert("fairness_jain".into(), num(self.fairness_jain));
        o.insert(
            "tenants".into(),
            Json::Arr(
                self.tenants
                    .iter()
                    .map(|t| {
                        let mut m = BTreeMap::new();
                        m.insert("tenant".into(), num(t.tenant as f64));
                        m.insert("enqueued".into(), num(t.enqueued as f64));
                        m.insert("completed".into(), num(t.completed as f64));
                        m.insert("clipped".into(), num(t.clipped as f64));
                        m.insert("dropped".into(), num(t.dropped as f64));
                        m.insert("ttft_p50".into(), num(t.ttft_p50));
                        m.insert("ttft_p99".into(), num(t.ttft_p99));
                        m.insert("tpot_p50".into(), num(t.tpot_p50));
                        m.insert("tpot_p99".into(), num(t.tpot_p99));
                        m.insert("e2e_p50".into(), num(t.e2e_p50));
                        m.insert("e2e_p99".into(), num(t.e2e_p99));
                        m.insert("goodput".into(), num(t.goodput));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        o.insert(
            "queue_depth".into(),
            Json::Arr(
                self.queue_depth
                    .iter()
                    .map(|&(t, d)| Json::Arr(vec![num(t), num(d as f64)]))
                    .collect(),
            ),
        );
        o.insert(
            "staleness_hist".into(),
            Json::Obj(
                self.staleness_hist
                    .iter()
                    .map(|(&d, &n)| (d.to_string(), num(n as f64)))
                    .collect(),
            ),
        );
        o.insert("max_staleness".into(), num(self.max_staleness as f64));
        Json::Obj(o)
    }
}

/// Latency + counter aggregation for one traced run.
#[derive(Debug, Clone)]
pub struct TelemetryHub {
    /// SLO threshold in backend clock units (None = no deadline).
    pub slo: Option<f64>,
    pub enqueued: usize,
    pub completed: usize,
    pub clipped: usize,
    pub dropped: usize,
    pub consumed: usize,
    slo_met: usize,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    e2e: Vec<f64>,
    queue_wait: Vec<f64>,
    /// Log-bucketed tails (20 bins/decade over 12 decades — wide enough
    /// for tick clocks and second clocks alike).
    pub ttft_hist: LogHistogram,
    pub e2e_hist: LogHistogram,
    pub engines: Vec<EngineCounters>,
    /// Driver decisions by `Decision::label`.
    pub decisions: BTreeMap<&'static str, u64>,
    pub ticks: u64,
    pub refills: u64,
    pub prompts_loaded: u64,
    pub harvests: u64,
    pub updates: u64,
    pub barriers: u64,
    pub steals_refused: u64,
    pub throttles_refused: u64,
    /// `Decision::Repartition`s the backend declined (occupancy would be
    /// violated); applied ones sit in the per-engine counters.
    pub repartitions_refused: u64,
    /// rid → (arrival instant, tenant); registered by open-loop entry
    /// points before driving.  Empty in closed-loop runs — which keeps
    /// every latency definition exactly as before.
    arrivals: BTreeMap<u64, (f64, usize)>,
    /// Per-tenant accumulators, indexed by tenant id.
    tenants: Vec<TenantAcc>,
    /// Raw (clock, waiting) queue-depth samples, dedup-on-change.
    queue_depth: Vec<(f64, usize)>,
    /// Per-sample off-policy degree of consumed trajectories (fed by
    /// `Tracer::updated` from `ScheduleBackend::staleness_of`).
    staleness_hist: BTreeMap<u64, u64>,
}

#[derive(Debug, Clone, Default)]
struct TenantAcc {
    enqueued: usize,
    completed: usize,
    clipped: usize,
    dropped: usize,
    slo_met: usize,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    e2e: Vec<f64>,
}

impl TelemetryHub {
    pub fn new(slo: Option<f64>) -> Self {
        TelemetryHub {
            slo,
            enqueued: 0,
            completed: 0,
            clipped: 0,
            dropped: 0,
            consumed: 0,
            slo_met: 0,
            ttft: Vec::new(),
            tpot: Vec::new(),
            e2e: Vec::new(),
            queue_wait: Vec::new(),
            ttft_hist: LogHistogram::new(1e-6, 1e6, 240),
            e2e_hist: LogHistogram::new(1e-6, 1e6, 240),
            engines: Vec::new(),
            decisions: BTreeMap::new(),
            ticks: 0,
            refills: 0,
            prompts_loaded: 0,
            harvests: 0,
            updates: 0,
            barriers: 0,
            steals_refused: 0,
            throttles_refused: 0,
            repartitions_refused: 0,
            arrivals: BTreeMap::new(),
            tenants: Vec::new(),
            queue_depth: Vec::new(),
            staleness_hist: BTreeMap::new(),
        }
    }

    /// Fold one consumed sample's off-policy degree (weight versions
    /// between its first response token and the update that trained on
    /// it) into the staleness histogram.
    pub fn record_staleness(&mut self, delta: u64) {
        *self.staleness_hist.entry(delta).or_insert(0) += 1;
    }

    /// Register one open-loop arrival.  Latencies for registered rids are
    /// measured from `t` (the arrival instant) instead of the tracer's
    /// enqueue stamp, and aggregate into the tenant's roll-up.
    pub fn register_arrival(&mut self, rid: u64, t: f64, tenant: usize) {
        if tenant >= self.tenants.len() {
            self.tenants.resize(tenant + 1, TenantAcc::default());
        }
        self.tenants[tenant].enqueued += 1;
        self.arrivals.insert(rid, (t, tenant));
    }

    /// Sample the pool's waiting-request count (dedup-on-change: long
    /// stretches at one depth cost one point).
    pub fn sample_queue_depth(&mut self, at: f64, depth: usize) {
        if self.queue_depth.last().map(|&(_, d)| d) != Some(depth) {
            self.queue_depth.push((at, depth));
        }
    }

    /// Per-engine counter slot, grown on demand.
    pub fn engine(&mut self, i: usize) -> &mut EngineCounters {
        if i >= self.engines.len() {
            self.engines.resize(i + 1, EngineCounters::default());
        }
        &mut self.engines[i]
    }

    pub fn tally(&mut self, label: &'static str) {
        *self.decisions.entry(label).or_insert(0) += 1;
    }

    /// Fold one finished span into the latency aggregates.  Clipped spans
    /// count (they produced a trained trajectory); drops only count in the
    /// outcome tallies.
    pub fn finish_span(&mut self, span: &RequestSpan) {
        // registered open-loop rids measure from the ARRIVAL instant,
        // not the tracer's enqueue stamp (release into the scheduler can
        // lag the arrival when the pool is saturated)
        let reg = self.arrivals.get(&span.rid).copied();
        match span.outcome {
            SpanOutcome::Completed => {
                self.completed += 1;
                if let Some((_, tenant)) = reg {
                    self.tenants[tenant].completed += 1;
                }
            }
            SpanOutcome::Clipped => {
                self.clipped += 1;
                if let Some((_, tenant)) = reg {
                    self.tenants[tenant].clipped += 1;
                }
            }
            SpanOutcome::Dropped => {
                self.dropped += 1;
                if let Some((_, tenant)) = reg {
                    self.tenants[tenant].dropped += 1;
                }
                return;
            }
            SpanOutcome::InFlight => return,
        }
        let ttft = match reg {
            Some((t0, _)) => span.first_token.map(|ft| (ft - t0).max(0.0)),
            None => span.ttft(),
        };
        let e2e = match reg {
            Some((t0, _)) => span.finished.map(|f| (f - t0).max(0.0)),
            None => span.e2e(),
        };
        if let Some(t) = ttft {
            self.ttft.push(t);
            self.ttft_hist.push(t);
            if let Some((_, tenant)) = reg {
                self.tenants[tenant].ttft.push(t);
            }
        }
        if let Some(t) = span.tpot() {
            self.tpot.push(t);
            if let Some((_, tenant)) = reg {
                self.tenants[tenant].tpot.push(t);
            }
        }
        if let Some(t) = span.queue_wait() {
            self.queue_wait.push(t);
        }
        if let Some(t) = e2e {
            self.e2e.push(t);
            self.e2e_hist.push(t);
            let met = self.slo.is_none_or(|s| t <= s);
            if met {
                self.slo_met += 1;
            }
            if let Some((_, tenant)) = reg {
                self.tenants[tenant].e2e.push(t);
                if met {
                    self.tenants[tenant].slo_met += 1;
                }
            }
        }
    }

    pub fn summary(&self) -> SloSummary {
        // exact quantiles from the raw samples; `q0` guards the NaN an
        // empty sample set would leak into JSON artifacts
        let q0 = |xs: &[f64], q: f64| if xs.is_empty() { 0.0 } else { quantile(xs, q) };
        let mean = |xs: &[f64]| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        let tenants: Vec<TenantSlo> = self
            .tenants
            .iter()
            .enumerate()
            .map(|(i, a)| TenantSlo {
                tenant: i,
                enqueued: a.enqueued,
                completed: a.completed,
                clipped: a.clipped,
                dropped: a.dropped,
                ttft_p50: q0(&a.ttft, 0.50),
                ttft_p99: q0(&a.ttft, 0.99),
                tpot_p50: q0(&a.tpot, 0.50),
                tpot_p99: q0(&a.tpot, 0.99),
                e2e_p50: q0(&a.e2e, 0.50),
                e2e_p99: q0(&a.e2e, 0.99),
                goodput: if a.enqueued == 0 {
                    0.0
                } else {
                    a.slo_met as f64 / a.enqueued as f64
                },
            })
            .collect();
        // Jain over per-tenant delivered fractions (trained trajectories
        // per arrival): 1.0 when every tenant gets the same service level
        let fairness_jain = if tenants.len() < 2 {
            1.0
        } else {
            let xs: Vec<f64> = tenants
                .iter()
                .map(|t| (t.completed + t.clipped) as f64 / t.enqueued.max(1) as f64)
                .collect();
            let sum: f64 = xs.iter().sum();
            let sq: f64 = xs.iter().map(|x| x * x).sum();
            if sq <= 0.0 {
                0.0
            } else {
                sum * sum / (xs.len() as f64 * sq)
            }
        };
        SloSummary {
            enqueued: self.enqueued,
            completed: self.completed,
            clipped: self.clipped,
            dropped: self.dropped,
            ttft_p50: q0(&self.ttft, 0.50),
            ttft_p90: q0(&self.ttft, 0.90),
            ttft_p99: q0(&self.ttft, 0.99),
            tpot_p50: q0(&self.tpot, 0.50),
            tpot_p90: q0(&self.tpot, 0.90),
            tpot_p99: q0(&self.tpot, 0.99),
            e2e_p50: q0(&self.e2e, 0.50),
            e2e_p99: q0(&self.e2e, 0.99),
            queue_p50: q0(&self.queue_wait, 0.50),
            queue_p99: q0(&self.queue_wait, 0.99),
            mean_ttft: mean(&self.ttft),
            mean_tpot: mean(&self.tpot),
            slo: self.slo,
            goodput: if self.enqueued == 0 {
                0.0
            } else {
                self.slo_met as f64 / self.enqueued as f64
            },
            tenants,
            fairness_jain,
            queue_depth: super::series::downsample(&self.queue_depth, 256),
            max_staleness: self.staleness_hist.keys().next_back().copied().unwrap_or(0),
            staleness_hist: self.staleness_hist.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::span::SpanOutcome;

    fn span(rid: u64, ft: f64, fin: f64, tokens: usize, outcome: SpanOutcome) -> RequestSpan {
        let mut s = RequestSpan::new(rid, 0.0);
        s.dispatched = Some(0.0);
        s.first_token = Some(ft);
        s.finished = Some(fin);
        s.tokens = tokens;
        s.outcome = outcome;
        s
    }

    #[test]
    fn summary_quantiles_and_goodput() {
        let mut hub = TelemetryHub::new(Some(4.0));
        hub.enqueued = 4;
        for (rid, fin) in [(0, 3.0), (1, 5.0), (2, 3.0), (3, 5.0)] {
            hub.finish_span(&span(rid, 1.0, fin, 3, SpanOutcome::Completed));
        }
        let s = hub.summary();
        assert_eq!(s.completed, 4);
        assert!((s.ttft_p50 - 1.0).abs() < 1e-12);
        assert!((s.e2e_p50 - 4.0).abs() < 1e-12); // interp of [3,3,5,5]
        assert!((s.e2e_p99 - 5.0).abs() < 1e-12);
        assert!((s.goodput - 0.5).abs() < 1e-12); // two of four within 4.0
    }

    #[test]
    fn drops_and_inflight_skip_latency_stats() {
        let mut hub = TelemetryHub::new(None);
        hub.enqueued = 2;
        hub.finish_span(&span(0, 1.0, 2.0, 2, SpanOutcome::Dropped));
        hub.finish_span(&RequestSpan::new(1, 0.0)); // in-flight
        let s = hub.summary();
        assert_eq!(s.dropped, 1);
        assert_eq!(s.completed, 0);
        assert_eq!(s.e2e_p99, 0.0); // guarded, not NaN
        assert_eq!(s.goodput, 0.0);
    }

    #[test]
    fn tenant_rollups_fairness_and_json() {
        let mut hub = TelemetryHub::new(Some(4.0));
        hub.enqueued = 3;
        hub.register_arrival(0, 1.0, 0);
        hub.register_arrival(1, 2.0, 1);
        hub.register_arrival(2, 3.0, 1);
        // arrival-relative: ttft 2.0-1.0, e2e 4.0-1.0 (within SLO 4.0)
        hub.finish_span(&span(0, 2.0, 4.0, 3, SpanOutcome::Completed));
        // e2e 8.0-2.0 = 6.0: delivered but missed the SLO
        hub.finish_span(&span(1, 3.0, 8.0, 3, SpanOutcome::Completed));
        hub.finish_span(&span(2, 3.5, 9.0, 1, SpanOutcome::Dropped));
        hub.sample_queue_depth(0.0, 0);
        hub.sample_queue_depth(1.0, 2);
        hub.sample_queue_depth(2.0, 2); // dedup-on-change drops this
        let s = hub.summary();
        assert_eq!(s.tenants.len(), 2);
        assert_eq!((s.tenants[0].enqueued, s.tenants[0].completed), (1, 1));
        assert_eq!((s.tenants[1].enqueued, s.tenants[1].dropped), (2, 1));
        assert!((s.tenants[0].ttft_p50 - 1.0).abs() < 1e-12);
        // tpot is inter-token (never arrival-relative): (4-2)/(3-1) = 1.0
        assert!((s.tenants[0].tpot_p50 - 1.0).abs() < 1e-12);
        assert!((s.tenants[0].e2e_p50 - 3.0).abs() < 1e-12);
        assert!((s.tenants[0].goodput - 1.0).abs() < 1e-12);
        // tenant 1: one completion at (8-3)/2 = 2.5; the drop contributes
        // no latency samples
        assert!((s.tenants[1].tpot_p50 - 2.5).abs() < 1e-12);
        assert!((s.tenants[1].tpot_p99 - 2.5).abs() < 1e-12);
        assert_eq!(s.tenants[1].goodput, 0.0);
        // delivered fractions 1.0 and 0.5: J = 1.5^2 / (2 * 1.25) = 0.9
        assert!((s.fairness_jain - 0.9).abs() < 1e-12);
        assert_eq!(s.queue_depth, vec![(0.0, 0), (1.0, 2)]);
        let j = s.to_json();
        assert_eq!(j.get("tenants").unwrap().as_arr().unwrap().len(), 2);
        assert!((j.get("fairness_jain").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-12);
        let t1 = &j.get("tenants").unwrap().as_arr().unwrap()[1];
        assert!((t1.get("tpot_p50").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn staleness_histogram_rolls_up_and_serializes() {
        let mut hub = TelemetryHub::new(None);
        // an untouched hub reports an empty histogram, max 0
        assert_eq!(hub.summary().max_staleness, 0);
        assert!(hub.summary().staleness_hist.is_empty());
        for d in [0, 0, 1, 0, 3] {
            hub.record_staleness(d);
        }
        let s = hub.summary();
        assert_eq!(s.staleness_hist.get(&0), Some(&3));
        assert_eq!(s.staleness_hist.get(&1), Some(&1));
        assert_eq!(s.staleness_hist.get(&3), Some(&1));
        assert_eq!(s.staleness_hist.len(), 3, "no empty buckets");
        assert_eq!(s.max_staleness, 3);
        let j = s.to_json();
        let h = j.get("staleness_hist").unwrap();
        assert_eq!(h.get("0").unwrap().as_f64(), Some(3.0));
        assert_eq!(h.get("3").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("max_staleness").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn closed_loop_summary_has_no_tenants() {
        let mut hub = TelemetryHub::new(None);
        hub.enqueued = 1;
        hub.finish_span(&span(0, 1.0, 2.0, 2, SpanOutcome::Completed));
        let s = hub.summary();
        assert!(s.tenants.is_empty());
        assert_eq!(s.fairness_jain, 1.0);
    }

    #[test]
    fn engine_counters_grow_on_demand() {
        let mut hub = TelemetryHub::new(None);
        hub.engine(3).sheds += 1;
        assert_eq!(hub.engines.len(), 4);
        assert_eq!(hub.engines[3].sheds, 1);
        hub.engine(2).repartitions += 1;
        assert_eq!(hub.engines[2].repartitions, 1);
        hub.repartitions_refused += 1;
        assert_eq!(hub.repartitions_refused, 1);
        hub.tally("step");
        hub.tally("step");
        assert_eq!(hub.decisions["step"], 2);
    }
}
