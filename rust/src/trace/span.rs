//! Per-request lifecycle spans.
//!
//! A [`RequestSpan`] is the serving-side biography of one buffer entry
//! (one rid), stamped by the driver tap in `sched::policy::drive_traced`:
//!
//! | stamp         | tap point                         | meaning                              |
//! |---------------|-----------------------------------|--------------------------------------|
//! | `enqueued`    | after `Refill` (schedulable diff) | prompt entered the buffer            |
//! | `dispatched`  | after `Admit` naming the rid      | scheduler handed it to the pool      |
//! | `first_token` | after a `Step` shows it in a lane | first decode iteration completed     |
//! | `finished`    | ready-set diff / harvest verdict  | trajectory done (complete or clipped)|
//! | `consumed`    | after `Update` naming the rid     | trainer consumed the trajectory      |
//!
//! In between, [`SpanMark`]s record the scheduling interventions the
//! request suffered (preempt, shed, steal, requeue, restart, resume), in
//! clock order.  All timestamps are in the backend's own clock units
//! (simulated seconds, harness ticks, or live host seconds) read through
//! `ScheduleBackend::trace_clock`, always sampled at the POOL level (max
//! over engines), so every track in one trace shares one monotone clock.

/// Terminal state of a span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Still running / queued / awaiting training when the trace ended.
    InFlight,
    /// Finished naturally at its full length.
    Completed,
    /// Harvest verdict truncated it; trained at partial length.
    Clipped,
    /// Harvest verdict discarded it; never trained.
    Dropped,
}

/// A scheduling intervention recorded mid-span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanMark {
    /// `Decision::Preempt` kicked it out of a lane (progress kept).
    Preempted { engine: usize },
    /// KV backpressure shed it from a lane (`Decision::Throttle`).
    Shed { engine: usize },
    /// A work steal migrated it between engines.
    Stolen { from: usize, to: usize },
    /// Harvest verdict `Requeue` — untouched, back to schedulable.
    Requeued,
    /// Harvest verdict `Restart` — progress discarded, rescheduled.
    Restarted,
    /// Harvest verdict `Resume` — progress kept, rescheduled.
    Resumed,
}

/// Lifecycle record of one request (see the module table).
#[derive(Debug, Clone)]
pub struct RequestSpan {
    pub rid: u64,
    pub enqueued: f64,
    pub dispatched: Option<f64>,
    pub first_token: Option<f64>,
    pub finished: Option<f64>,
    pub consumed: Option<f64>,
    /// Harvested response tokens (clips are shorter than the full length).
    pub tokens: usize,
    /// Engine where the request first held a lane (finish-time engine for
    /// requests that finish in the same tick they were admitted).
    pub engine: Option<usize>,
    pub lane: Option<usize>,
    pub outcome: SpanOutcome,
    /// Interventions in clock order.
    pub marks: Vec<(f64, SpanMark)>,
}

impl RequestSpan {
    pub fn new(rid: u64, enqueued: f64) -> Self {
        RequestSpan {
            rid,
            enqueued,
            dispatched: None,
            first_token: None,
            finished: None,
            consumed: None,
            tokens: 0,
            engine: None,
            lane: None,
            outcome: SpanOutcome::InFlight,
            marks: Vec::new(),
        }
    }

    /// Buffer wait before the scheduler dispatched it into the pool.
    pub fn queue_wait(&self) -> Option<f64> {
        self.dispatched.map(|d| d - self.enqueued)
    }

    /// Time-to-first-token: enqueue until the first decode iteration.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token.map(|t| t - self.enqueued)
    }

    /// Time-per-output-token over the decode phase (finish - first token,
    /// normalized by the tokens after the first; 1-token responses report
    /// the full decode span).
    pub fn tpot(&self) -> Option<f64> {
        match (self.first_token, self.finished) {
            (Some(ft), Some(fin)) => Some((fin - ft) / self.tokens.saturating_sub(1).max(1) as f64),
            _ => None,
        }
    }

    /// End-to-end latency: enqueue to finish.
    pub fn e2e(&self) -> Option<f64> {
        self.finished.map(|f| f - self.enqueued)
    }

    /// True when every present stamp is in lifecycle order
    /// (enqueued <= dispatched <= first_token <= finished <= consumed) and
    /// the marks are sorted by time.
    pub fn is_ordered(&self) -> bool {
        let mut last = self.enqueued;
        for stamp in [self.dispatched, self.first_token, self.finished, self.consumed]
            .into_iter()
            .flatten()
        {
            if stamp < last {
                return false;
            }
            last = stamp;
        }
        self.marks.windows(2).all(|w| w[0].0 <= w[1].0)
            && self.marks.iter().all(|&(t, _)| t >= self.enqueued)
    }

    /// True when the span reached a terminal verdict with every stamp the
    /// verdict implies: finished requests (completed or clipped) carry
    /// dispatch/first-token/finish; drops only need the finish stamp
    /// (a request can be dropped straight out of a queue).
    pub fn is_complete(&self) -> bool {
        match self.outcome {
            SpanOutcome::InFlight => false,
            SpanOutcome::Dropped => self.finished.is_some(),
            SpanOutcome::Completed | SpanOutcome::Clipped => {
                self.dispatched.is_some()
                    && self.first_token.is_some()
                    && self.finished.is_some()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finished_span() -> RequestSpan {
        let mut s = RequestSpan::new(7, 0.0);
        s.dispatched = Some(0.0);
        s.first_token = Some(1.0);
        s.finished = Some(5.0);
        s.tokens = 5;
        s.outcome = SpanOutcome::Completed;
        s
    }

    #[test]
    fn derived_latencies() {
        let s = finished_span();
        assert_eq!(s.ttft(), Some(1.0));
        assert_eq!(s.e2e(), Some(5.0));
        assert!((s.tpot().unwrap() - 1.0).abs() < 1e-12); // (5-1)/(5-1)
        assert_eq!(s.queue_wait(), Some(0.0));
        assert!(s.is_ordered() && s.is_complete());
    }

    #[test]
    fn one_token_tpot_is_full_decode_span() {
        let mut s = finished_span();
        s.tokens = 1;
        assert!((s.tpot().unwrap() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn disorder_detected() {
        let mut s = finished_span();
        s.first_token = Some(9.0); // after finish
        assert!(!s.is_ordered());
        let mut s = finished_span();
        s.marks = vec![(2.0, SpanMark::Requeued), (1.0, SpanMark::Resumed)];
        assert!(!s.is_ordered());
    }

    #[test]
    fn inflight_is_incomplete() {
        let s = RequestSpan::new(1, 0.0);
        assert!(!s.is_complete());
        assert!(s.ttft().is_none() && s.tpot().is_none());
    }
}
