//! Per-request lifecycle tracing + SLO telemetry.
//!
//! One [`Tracer`] rides along `sched::policy::drive_traced` — the SINGLE
//! tap point every backend (live, sim, harness) runs through — and
//! records three things from the same observations:
//!
//! 1. [`RequestSpan`]s: the lifecycle biography of every buffer entry
//!    (enqueue, dispatch, first token, interventions, finish verdict,
//!    trainer consumption), from which TTFT / TPOT / queue-wait / e2e
//!    latency derive.
//! 2. A [`TelemetryHub`]: exact p50/p90/p99 latency quantiles,
//!    log-bucketed tail histograms, per-engine intervention counters with
//!    cause attribution, per-decision tallies, and the SLO goodput.
//! 3. Optionally a [`ChromeTrace`]: a Perfetto-loadable trace with
//!    engines as processes, lanes as threads, one slice per request's
//!    decode span, instants for steals/sheds/preempts/harvests, and
//!    KV/occupancy counter tracks.
//!
//! Because the taps read only through [`ScheduleBackend`]'s shared
//! introspection surface (`schedulable`, `ready_rids`, `engine_loads`,
//! `lane_rids`, `trace_clock`), the three backends record identically and
//! none of them carries tracing code of its own.  [`Tracer::disabled`] is
//! a no-op sink: every tap returns immediately, so the plain `drive`
//! entry point costs nothing and decision sequences are byte-identical
//! with tracing off (pinned by the policy goldens; the disabled-vs-enabled
//! cost gap is measured in `benches/sched_bench.rs`).

pub mod chrome;
pub mod hub;
pub mod series;
pub mod span;

pub use chrome::ChromeTrace;
pub use hub::{EngineCounters, SloSummary, TelemetryHub};
pub use span::{RequestSpan, SpanMark, SpanOutcome};

use crate::sched::policy::{
    Decision, EngineLoad, HarvestAction, HarvestItem, ScheduleBackend,
};
use crate::util::json::{num, s, Json};
use std::collections::{BTreeMap, HashSet};

/// The driver-side recording facade (see the module docs).  All state
/// lives here — backends only expose read-only introspection.
pub struct Tracer {
    enabled: bool,
    hub: TelemetryHub,
    chrome: Option<ChromeTrace>,
    spans: BTreeMap<u64, RequestSpan>,
    /// Monotone pool clock (max over everything observed so far).
    clock: f64,
    /// Executed `Step`s — the fallback clock for backends that do not
    /// override `trace_clock`.
    steps: u64,
    /// `schedulable()` before the current `Refill` (enqueue diff).
    snap_sched: Vec<u64>,
    /// `ready_rids()` before the current `Step`/`Harvest` (finish diff).
    snap_ready: Vec<u64>,
    /// Lane victim captured before a `Preempt`/lane `Steal` executes.
    victim: Option<u64>,
    /// Lane rids of the throttled engine before the shed (victim diff).
    throttle_snap: Vec<u64>,
}

impl Tracer {
    /// The no-op sink `drive` uses: every tap returns immediately.
    pub fn disabled() -> Self {
        Self::build(false, None, false)
    }

    /// Recording tracer.  `slo` is the deadline in backend clock units
    /// (None = no deadline, goodput counts every trained trajectory);
    /// `chrome` additionally builds the Perfetto-loadable event trace.
    pub fn new(slo: Option<f64>, chrome: bool) -> Self {
        Self::build(true, slo, chrome)
    }

    fn build(enabled: bool, slo: Option<f64>, chrome: bool) -> Self {
        Tracer {
            enabled,
            hub: TelemetryHub::new(slo),
            chrome: if chrome { Some(ChromeTrace::new()) } else { None },
            spans: BTreeMap::new(),
            clock: 0.0,
            steps: 0,
            snap_sched: Vec::new(),
            snap_ready: Vec::new(),
            victim: None,
            throttle_snap: Vec::new(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Register an open-loop arrival (rid, arrival instant, tenant) so
    /// the hub reports arrival-relative latencies and per-tenant SLOs.
    /// Call before driving; a no-op on the disabled tracer.
    pub fn register_arrival(&mut self, rid: u64, t: f64, tenant: usize) {
        if !self.enabled {
            return;
        }
        self.hub.register_arrival(rid, t, tenant);
    }

    /// Current pool clock: the backend's own clock when it exposes one
    /// (`trace_clock`), else the executed-step count; never goes backward.
    fn now(&mut self, backend: &dyn ScheduleBackend) -> f64 {
        let c = backend.trace_clock();
        if c.is_finite() {
            self.clock = self.clock.max(c);
        } else {
            self.clock = self.clock.max(self.steps as f64);
        }
        self.clock
    }

    fn span_mut(&mut self, rid: u64, at: f64) -> &mut RequestSpan {
        self.spans.entry(rid).or_insert_with(|| RequestSpan::new(rid, at))
    }

    // ---- taps (one per drive_traced site) ----

    /// Before the loop: name the Perfetto rows and pick up anything the
    /// backend already considers schedulable (entries loaded before the
    /// driver started get their enqueue stamp here).
    pub fn begin(&mut self, policy: &str, backend: &dyn ScheduleBackend) {
        if !self.enabled {
            return;
        }
        let at = self.now(backend);
        let loads = backend.engine_loads();
        if let Some(c) = self.chrome.as_mut() {
            c.process(0, &format!("driver ({policy})"));
            for (e, l) in loads.iter().enumerate() {
                c.process(e + 1, &format!("engine {e}"));
                c.thread(e + 1, 0, "events");
                for lane in 0..l.lanes {
                    c.thread(e + 1, lane + 1, &format!("lane {lane}"));
                }
            }
        }
        for rid in backend.schedulable() {
            if !self.spans.contains_key(&rid) {
                self.hub.enqueued += 1;
                self.spans.insert(rid, RequestSpan::new(rid, at));
            }
        }
    }

    pub fn decision(&mut self, d: &Decision) {
        if !self.enabled {
            return;
        }
        self.hub.tally(d.label());
    }

    pub fn pre_refill(&mut self, backend: &dyn ScheduleBackend) {
        if !self.enabled {
            return;
        }
        self.snap_sched = backend.schedulable();
    }

    pub fn post_refill(&mut self, backend: &dyn ScheduleBackend, count: usize) {
        if !self.enabled {
            return;
        }
        let at = self.now(backend);
        self.hub.refills += 1;
        self.hub.prompts_loaded += count as u64;
        let prev: HashSet<u64> = self.snap_sched.iter().copied().collect();
        for rid in backend.schedulable() {
            if !prev.contains(&rid) && !self.spans.contains_key(&rid) {
                self.hub.enqueued += 1;
                self.spans.insert(rid, RequestSpan::new(rid, at));
            }
        }
        if count > 0 {
            if let Some(c) = self.chrome.as_mut() {
                c.instant(0, 0, at, "refill", vec![("prompts", num(count as f64))]);
            }
        }
    }

    pub fn admitted(&mut self, backend: &dyn ScheduleBackend, rids: &[u64]) {
        if !self.enabled {
            return;
        }
        let at = self.now(backend);
        for &rid in rids {
            let sp = self.span_mut(rid, at);
            if sp.dispatched.is_none() {
                sp.dispatched = Some(at);
            }
        }
    }

    pub fn pre_step(&mut self, backend: &dyn ScheduleBackend) {
        if !self.enabled {
            return;
        }
        self.snap_ready = backend.ready_rids();
    }

    /// After a `Step`: advance the clock, stamp first tokens from the
    /// lane occupancy, close spans for newly-ready rids, sample the
    /// counter tracks, and attribute KV pressure.
    pub fn post_step(&mut self, backend: &dyn ScheduleBackend, loads: &[EngineLoad]) {
        if !self.enabled {
            return;
        }
        self.steps += 1;
        let at = self.now(backend);
        self.hub.ticks += 1;
        for (e, l) in loads.iter().enumerate() {
            for (lane, rid) in backend.lane_rids(e) {
                if let Some(sp) = self.spans.get_mut(&rid) {
                    if sp.first_token.is_none() {
                        sp.first_token = Some(at);
                        sp.engine = Some(e);
                        sp.lane = Some(lane);
                    }
                }
            }
            let ec = self.hub.engine(e);
            if l.kv_pressure {
                ec.kv_pressure_ticks += 1;
            }
            if l.kv_blocked {
                ec.kv_blocked_ticks += 1;
            }
            if let Some(c) = self.chrome.as_mut() {
                c.counter(e + 1, "running", at, l.active as f64);
                c.counter(e + 1, "queued", at, l.queued as f64);
                if l.kv_budget != usize::MAX {
                    c.counter(e + 1, "kv_used", at, l.kv_used as f64);
                }
            }
        }
        let queued = backend.view().queued;
        self.hub.sample_queue_depth(at, queued);
        if let Some(c) = self.chrome.as_mut() {
            c.counter(0, "queued", at, queued as f64);
        }
        self.close_new_ready(backend, at);
    }

    /// Spans newly present in `ready_rids()` since the last snapshot
    /// finished naturally (full length).
    fn close_new_ready(&mut self, backend: &dyn ScheduleBackend, at: f64) {
        let prev: HashSet<u64> = self.snap_ready.iter().copied().collect();
        for rid in backend.ready_rids() {
            if prev.contains(&rid) {
                continue;
            }
            let done = self.spans.get(&rid).is_some_and(|sp| sp.finished.is_some());
            if !done {
                let tokens = backend.ready_len(rid);
                self.finish_request(rid, at, tokens, SpanOutcome::Completed);
            }
        }
        self.snap_ready = backend.ready_rids();
    }

    fn finish_request(&mut self, rid: u64, at: f64, tokens: usize, outcome: SpanOutcome) {
        let sp = self.span_mut(rid, at);
        if sp.finished.is_some() {
            return;
        }
        // a request that finishes in the tick it was admitted never shows
        // up in a lane scan: its whole decode span collapses to the finish
        if sp.first_token.is_none() && !matches!(outcome, SpanOutcome::Dropped) {
            sp.first_token = Some(at);
        }
        sp.finished = Some(at);
        sp.tokens = tokens;
        sp.outcome = outcome;
        let sp = self.spans[&rid].clone();
        self.hub.finish_span(&sp);
        if let Some(c) = self.chrome.as_mut() {
            if let (Some(ft), Some(fin)) = (sp.first_token, sp.finished) {
                let pid = sp.engine.map(|e| e + 1).unwrap_or(0);
                let tid = sp.lane.map(|l| l + 1).unwrap_or(0);
                let label = match outcome {
                    SpanOutcome::Completed => "completed",
                    SpanOutcome::Clipped => "clipped",
                    SpanOutcome::Dropped => "dropped",
                    SpanOutcome::InFlight => "in_flight",
                };
                c.slice(
                    pid,
                    tid,
                    ft,
                    fin - ft,
                    &format!("req {rid}"),
                    vec![
                        ("rid", num(rid as f64)),
                        ("tokens", num(tokens as f64)),
                        ("ttft", num(sp.ttft().unwrap_or(0.0))),
                        ("tpot", num(sp.tpot().unwrap_or(0.0))),
                        ("queue_wait", num(sp.queue_wait().unwrap_or(0.0))),
                        ("outcome", s(label)),
                    ],
                );
            }
        }
    }

    pub fn pre_harvest(&mut self, backend: &dyn ScheduleBackend) {
        if !self.enabled {
            return;
        }
        let at = self.now(backend);
        self.snap_ready = backend.ready_rids();
        self.hub.harvests += 1;
        if let Some(c) = self.chrome.as_mut() {
            c.instant(0, 0, at, "harvest", vec![]);
        }
    }

    /// One classified harvest item (called after `resolve` applied it).
    pub fn verdict(&mut self, backend: &dyn ScheduleBackend, it: &HarvestItem, act: HarvestAction) {
        if !self.enabled {
            return;
        }
        let at = self.now(backend);
        match act {
            HarvestAction::Clip => {
                self.finish_request(it.rid, at, it.progress, SpanOutcome::Clipped);
            }
            HarvestAction::Drop => {
                self.finish_request(it.rid, at, it.progress, SpanOutcome::Dropped);
            }
            HarvestAction::Requeue => {
                self.span_mut(it.rid, at).marks.push((at, SpanMark::Requeued));
            }
            HarvestAction::Restart => {
                self.span_mut(it.rid, at).marks.push((at, SpanMark::Restarted));
            }
            HarvestAction::Resume => {
                self.span_mut(it.rid, at).marks.push((at, SpanMark::Resumed));
            }
        }
    }

    /// After every verdict resolved: the live backend also drains natural
    /// completions into the ready set during `harvest_candidates`, so the
    /// finish diff runs here as well as after `Step`.
    pub fn post_harvest(&mut self, backend: &dyn ScheduleBackend) {
        if !self.enabled {
            return;
        }
        let at = self.now(backend);
        self.close_new_ready(backend, at);
    }

    /// Before a `Preempt` executes: capture the victim from the lane map.
    pub fn pre_preempt(&mut self, backend: &dyn ScheduleBackend, engine: usize, lane: usize) {
        if !self.enabled {
            return;
        }
        let at = self.now(backend);
        self.hub.engine(engine).preempts += 1;
        let victim = backend
            .lane_rids(engine)
            .into_iter()
            .find(|&(l, _)| l == lane)
            .map(|(_, rid)| rid);
        if let Some(rid) = victim {
            self.span_mut(rid, at).marks.push((at, SpanMark::Preempted { engine }));
            if let Some(c) = self.chrome.as_mut() {
                c.instant(engine + 1, 0, at, "preempt", vec![("rid", num(rid as f64))]);
            }
        }
    }

    /// Before a `Steal` executes: lane steals name their victim up front;
    /// queue steals are attributed by count only (queue contents are not
    /// introspectable through the backend trait).
    pub fn pre_steal(&mut self, backend: &dyn ScheduleBackend, from: usize, lane: Option<usize>) {
        if !self.enabled {
            return;
        }
        self.victim = lane.and_then(|l| {
            backend
                .lane_rids(from)
                .into_iter()
                .find(|&(ll, _)| ll == l)
                .map(|(_, rid)| rid)
        });
    }

    pub fn post_steal(&mut self, backend: &dyn ScheduleBackend, from: usize, to: usize, moved: bool) {
        if !self.enabled {
            return;
        }
        let at = self.now(backend);
        let victim = self.victim.take();
        if !moved {
            self.hub.steals_refused += 1;
            return;
        }
        self.hub.engine(from).steals_out += 1;
        self.hub.engine(to).steals_in += 1;
        if let Some(rid) = victim {
            self.span_mut(rid, at).marks.push((at, SpanMark::Stolen { from, to }));
        }
        if let Some(c) = self.chrome.as_mut() {
            let mut args = vec![("to", num(to as f64))];
            if let Some(rid) = victim {
                args.push(("rid", num(rid as f64)));
            }
            c.instant(from + 1, 0, at, "steal", args);
        }
    }

    /// Before a `Throttle` executes: snapshot the engine's lanes so the
    /// shed victim falls out of the diff.
    pub fn pre_throttle(&mut self, backend: &dyn ScheduleBackend, engine: usize) {
        if !self.enabled {
            return;
        }
        self.throttle_snap = backend.lane_rids(engine).into_iter().map(|(_, rid)| rid).collect();
    }

    pub fn post_throttle(&mut self, backend: &dyn ScheduleBackend, engine: usize, shed: bool) {
        if !self.enabled {
            return;
        }
        let at = self.now(backend);
        if !shed {
            self.hub.throttles_refused += 1;
            self.throttle_snap.clear();
            return;
        }
        self.hub.engine(engine).sheds += 1;
        let after: HashSet<u64> =
            backend.lane_rids(engine).into_iter().map(|(_, rid)| rid).collect();
        let snap = std::mem::take(&mut self.throttle_snap);
        for rid in snap {
            if !after.contains(&rid) {
                self.span_mut(rid, at).marks.push((at, SpanMark::Shed { engine }));
                if let Some(c) = self.chrome.as_mut() {
                    c.instant(engine + 1, 0, at, "shed", vec![("rid", num(rid as f64))]);
                }
            }
        }
    }

    /// After a `Repartition` executed: attribute applied resizes to the
    /// engine's counters, refused ones to the run-wide refusal tally.
    pub fn post_repartition(&mut self, backend: &dyn ScheduleBackend, engine: usize,
                            lanes: usize, applied: bool) {
        if !self.enabled {
            return;
        }
        if !applied {
            self.hub.repartitions_refused += 1;
            return;
        }
        self.hub.engine(engine).repartitions += 1;
        let at = self.now(backend);
        if let Some(c) = self.chrome.as_mut() {
            c.instant(engine + 1, 0, at, "repartition", vec![("lanes", num(lanes as f64))]);
        }
    }

    /// After a trainer update consumed these trajectories.
    pub fn updated(&mut self, backend: &dyn ScheduleBackend, rids: &[u64]) {
        if !self.enabled {
            return;
        }
        let at = self.now(backend);
        self.hub.updates += 1;
        self.hub.consumed += rids.len();
        for &rid in rids {
            // backends that stamp versions report how far off-policy each
            // consumed sample was; `None` (cap-bounced or a backend
            // without version tracking) contributes no bucket
            if let Some(delta) = backend.staleness_of(rid) {
                self.hub.record_staleness(delta);
            }
            let sp = self.span_mut(rid, at);
            if sp.consumed.is_none() {
                sp.consumed = Some(at);
            }
        }
        if let Some(c) = self.chrome.as_mut() {
            c.instant(0, 0, at, "update", vec![("trajectories", num(rids.len() as f64))]);
        }
    }

    pub fn barrier(&mut self, backend: &dyn ScheduleBackend) {
        if !self.enabled {
            return;
        }
        let at = self.now(backend);
        self.hub.barriers += 1;
        if let Some(c) = self.chrome.as_mut() {
            c.instant(0, 0, at, "barrier", vec![]);
        }
    }

    // ---- results ----

    pub fn slo_summary(&self) -> SloSummary {
        self.hub.summary()
    }

    pub fn hub(&self) -> &TelemetryHub {
        &self.hub
    }

    pub fn spans(&self) -> &BTreeMap<u64, RequestSpan> {
        &self.spans
    }

    /// The Chrome trace (None when constructed without one).
    pub fn chrome_json(&self) -> Option<Json> {
        self.chrome.as_ref().map(|c| c.finish())
    }

    /// Events + buffered counter points recorded so far.
    pub fn chrome_events(&self) -> usize {
        self.chrome.as_ref().map(|c| c.event_count()).unwrap_or(0)
    }

    /// Write the Chrome trace as JSON (chrome://tracing / ui.perfetto.dev).
    pub fn write_chrome(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let j = self
            .chrome_json()
            .ok_or_else(|| anyhow::anyhow!("tracer was built without a chrome trace"))?;
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, j.to_string_compact())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::policy::SchedView;
    use anyhow::Result;

    struct TestBackend {
        fresh: Vec<u64>,
        ready: Vec<u64>,
        clock: f64,
    }

    impl ScheduleBackend for TestBackend {
        fn view(&self) -> SchedView {
            SchedView::default()
        }
        fn schedulable(&self) -> Vec<u64> {
            self.fresh.clone()
        }
        fn ready_rids(&self) -> Vec<u64> {
            self.ready.clone()
        }
        fn ready_len(&self, _rid: u64) -> usize {
            3
        }
        fn load_prompts(&mut self, _p: usize) -> Result<usize> {
            Ok(0)
        }
        fn admit(&mut self, _r: &[u64], _e: Option<usize>) -> Result<()> {
            Ok(())
        }
        fn step(&mut self) -> Result<usize> {
            Ok(0)
        }
        fn harvest_candidates(&mut self) -> Result<Vec<HarvestItem>> {
            Ok(Vec::new())
        }
        fn resolve(&mut self, _it: &HarvestItem, _a: HarvestAction) -> Result<()> {
            Ok(())
        }
        fn preempt(&mut self, _e: usize, _l: usize) -> Result<()> {
            Ok(())
        }
        fn train(&mut self, _r: &[u64]) -> Result<()> {
            Ok(())
        }
        fn barrier(&mut self) -> Result<()> {
            Ok(())
        }
        fn exhausted(&self) -> bool {
            true
        }
        fn trace_clock(&self) -> f64 {
            self.clock
        }
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut b = TestBackend { fresh: vec![0, 1], ready: vec![], clock: 1.0 };
        let mut t = Tracer::disabled();
        t.begin("test", &b);
        t.pre_refill(&b);
        b.fresh.push(2);
        t.post_refill(&b, 1);
        t.admitted(&b, &[0]);
        t.pre_step(&b);
        t.post_step(&b, &b.engine_loads());
        assert!(t.spans().is_empty());
        assert_eq!(t.hub().enqueued, 0);
        assert_eq!(t.hub().ticks, 0);
        assert!(t.chrome_json().is_none());
        assert!(!t.enabled());
    }

    #[test]
    fn lifecycle_through_taps() {
        let mut b = TestBackend { fresh: vec![], ready: vec![], clock: 0.0 };
        let mut t = Tracer::new(Some(10.0), false);
        t.begin("test", &b);
        t.pre_refill(&b);
        b.fresh = vec![0, 1];
        t.post_refill(&b, 2);
        assert_eq!(t.hub().enqueued, 2);
        t.admitted(&b, &[0, 1]);
        b.fresh.clear();
        t.pre_step(&b);
        b.clock = 2.0;
        b.ready = vec![0];
        t.post_step(&b, &b.engine_loads());
        let sp = &t.spans()[&0];
        assert_eq!(sp.finished, Some(2.0));
        assert_eq!(sp.outcome, SpanOutcome::Completed);
        assert_eq!(sp.tokens, 3);
        assert!(sp.is_ordered() && sp.is_complete());
        // rid 1 still in flight
        assert!(!t.spans()[&1].is_complete());
        t.updated(&b, &[0]);
        assert_eq!(t.spans()[&0].consumed, Some(2.0));
        let s = t.slo_summary();
        assert_eq!(s.completed, 1);
        assert!((s.goodput - 0.5).abs() < 1e-12); // 1 of 2 within SLO
    }

    #[test]
    fn clock_never_goes_backward() {
        let mut b = TestBackend { fresh: vec![], ready: vec![], clock: 5.0 };
        let mut t = Tracer::new(None, false);
        t.begin("test", &b);
        b.clock = 3.0; // a skewed engine clock must not rewind the trace
        b.fresh = vec![7];
        t.pre_refill(&b);
        t.post_refill(&b, 1);
        assert_eq!(t.spans()[&7].enqueued, 5.0);
    }
}
