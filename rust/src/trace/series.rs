//! Shared time-series machinery: the ONE export path for every
//! `(timestamp, value)` track the repo produces — the simulator's merged
//! KV-usage curve, `metrics::Timeline` CSV dumps, and the Chrome-trace
//! counter tracks all route through these helpers instead of carrying
//! their own copies of the merge/downsample/CSV logic.

/// Merge per-source `(t, running_total)` sample streams into one pool-wide
/// curve whose value at any time is the SUM of the latest sample from each
/// source (sources start at 0).  Events are ordered by time, ties broken
/// by source index, exactly like the per-engine merges the simulator has
/// always done; the output carries one point per input event (coalescing
/// is the consumer's choice).
pub fn merge_running_totals(sources: &[&[(f64, usize)]]) -> Vec<(f64, usize)> {
    let mut events: Vec<(f64, usize, usize)> = Vec::new();
    for (idx, src) in sources.iter().enumerate() {
        for &(t, v) in src.iter() {
            events.push((t, idx, v));
        }
    }
    if events.is_empty() {
        return Vec::new();
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut cur = vec![0usize; sources.len()];
    let mut total = 0usize;
    let mut merged = Vec::with_capacity(events.len());
    for (t, idx, v) in events {
        total = total + v - cur[idx];
        cur[idx] = v;
        merged.push((t, total));
    }
    merged
}

/// Stride-downsample `points` to at most `cap` entries (first point always
/// kept, order preserved).  `cap == 0` means unlimited.
pub fn downsample<T: Copy>(points: &[T], cap: usize) -> Vec<T> {
    if cap == 0 || points.len() <= cap {
        return points.to_vec();
    }
    let stride = points.len().div_ceil(cap).max(1);
    points.iter().copied().step_by(stride).collect()
}

/// Render a `(t, value)` series as a two-column CSV under `header`
/// (pass e.g. `"t,running"`).  Timestamps print with `f64` Display —
/// the format `Timeline::to_csv` has always emitted.
pub fn to_csv(header: &str, points: &[(f64, usize)]) -> String {
    let mut out = String::from(header);
    out.push('\n');
    for &(t, v) in points {
        out.push_str(&format!("{t},{v}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums_latest_sample_per_source() {
        let a: &[(f64, usize)] = &[(0.0, 1), (2.0, 3)];
        let b: &[(f64, usize)] = &[(1.0, 2), (2.0, 0)];
        let merged = merge_running_totals(&[a, b]);
        // t=0: a=1; t=1: a=1,b=2 -> 3; t=2: a=3 first (idx tie-break) -> 5,
        // then b=0 -> 3
        assert_eq!(merged, vec![(0.0, 1), (1.0, 3), (2.0, 5), (2.0, 3)]);
    }

    #[test]
    fn merge_empty_sources() {
        assert!(merge_running_totals(&[&[], &[]]).is_empty());
        assert!(merge_running_totals(&[]).is_empty());
    }

    #[test]
    fn downsample_caps_and_preserves_order() {
        let pts: Vec<(f64, usize)> = (0..1000).map(|i| (i as f64, i)).collect();
        let ds = downsample(&pts, 256);
        assert!(ds.len() <= 256);
        assert_eq!(ds[0], (0.0, 0)); // first point kept
        assert!(ds.windows(2).all(|w| w[0].0 < w[1].0));
        // short series pass through untouched
        assert_eq!(downsample(&pts[..10], 256), &pts[..10]);
    }

    #[test]
    fn csv_shape() {
        let csv = to_csv("t,running", &[(0.5, 2)]);
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("t,running"));
        assert_eq!(lines.next(), Some("0.5,2"));
    }
}
