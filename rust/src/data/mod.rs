//! Dataset construction + epoch dataloader.
//!
//! Mirrors the paper's setup (§4.1): a fixed training set generated ahead of
//! time (LogicRL: 1000 puzzles per difficulty 3..=7, shuffled; math: uniform
//! mixture over depth), a held-out eval split, and an epoch-shuffling loader
//! the SortedRL controller pulls prompts from.

use crate::tasks::{Problem, Task};
use crate::util::rng::Pcg64;

/// A materialized dataset (problems are immutable after generation).
pub struct Dataset {
    pub train: Vec<Problem>,
    pub eval: Vec<Problem>,
}

impl Dataset {
    /// `per_difficulty` problems per difficulty level, `eval_frac` held out
    /// (the paper spares 10%).
    pub fn generate(task: &dyn Task, per_difficulty: usize, eval_frac: f64,
                    seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0xDA7A);
        let (lo, hi) = task.difficulty_range();
        let mut all = Vec::new();
        let mut id = 0u64;
        for d in lo..=hi {
            for _ in 0..per_difficulty {
                all.push(task.generate(&mut rng, d, id));
                id += 1;
            }
        }
        rng.shuffle(&mut all);
        let n_eval = ((all.len() as f64) * eval_frac).round() as usize;
        let eval = all.split_off(all.len() - n_eval);
        Dataset { train: all, eval }
    }

    /// Stratified eval subsets by difficulty (for the Table-1 harness).
    pub fn eval_by_difficulty(&self) -> Vec<(u32, Vec<&Problem>)> {
        let mut lo = u32::MAX;
        let mut hi = 0;
        for p in &self.eval {
            lo = lo.min(p.difficulty);
            hi = hi.max(p.difficulty);
        }
        (lo..=hi)
            .map(|d| (d, self.eval.iter().filter(|p| p.difficulty == d).collect()))
            .collect()
    }
}

/// Epoch-shuffling prompt loader; the controller's upstream source.
pub struct DataLoader {
    indices: Vec<usize>,
    cursor: usize,
    epoch: usize,
    rng: Pcg64,
}

impl DataLoader {
    pub fn new(len: usize, seed: u64) -> Self {
        let mut loader = Self {
            indices: (0..len).collect(),
            cursor: 0,
            epoch: 0,
            rng: Pcg64::with_stream(seed, 0x10AD),
        };
        loader.rng.shuffle(&mut loader.indices);
        loader
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Fraction of epochs consumed, e.g. 2.25 epochs.
    pub fn epochs_elapsed(&self) -> f64 {
        self.epoch as f64 + self.cursor as f64 / self.indices.len().max(1) as f64
    }

    /// Next `n` dataset indices, reshuffling at epoch boundaries.
    pub fn next_batch(&mut self, n: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if self.cursor == self.indices.len() {
                self.rng.shuffle(&mut self.indices);
                self.cursor = 0;
                self.epoch += 1;
            }
            out.push(self.indices[self.cursor]);
            self.cursor += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::logic::LogicTask;
    use crate::tasks::math::MathTask;

    #[test]
    fn dataset_sizes_and_split() {
        let ds = Dataset::generate(&MathTask, 20, 0.1, 1);
        let total = 20 * 7; // difficulties 2..=8
        assert_eq!(ds.train.len() + ds.eval.len(), total);
        assert_eq!(ds.eval.len(), (total as f64 * 0.1).round() as usize);
    }

    #[test]
    fn dataset_is_difficulty_mixture() {
        let ds = Dataset::generate(&LogicTask::default(), 10, 0.0, 2);
        for d in 3..=7 {
            assert_eq!(ds.train.iter().filter(|p| p.difficulty == d).count(), 10);
        }
    }

    #[test]
    fn dataset_generation_deterministic() {
        let a = Dataset::generate(&MathTask, 5, 0.1, 42);
        let b = Dataset::generate(&MathTask, 5, 0.1, 42);
        for (x, y) in a.train.iter().zip(&b.train) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn loader_visits_every_index_once_per_epoch() {
        let mut dl = DataLoader::new(10, 3);
        let mut seen = dl.next_batch(10);
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
        assert_eq!(dl.epoch(), 0);
        dl.next_batch(1);
        assert_eq!(dl.epoch(), 1);
    }

    #[test]
    fn loader_epochs_elapsed() {
        let mut dl = DataLoader::new(8, 4);
        dl.next_batch(12);
        assert!((dl.epochs_elapsed() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn eval_by_difficulty_partitions() {
        let ds = Dataset::generate(&MathTask, 10, 0.3, 5);
        let strata = ds.eval_by_difficulty();
        let total: usize = strata.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total, ds.eval.len());
    }
}
