//! Multi-tenant JSONL trace format: one `{t, tenant, prompt_len, cap}`
//! object per line, timestamps non-decreasing.
//!
//! The trace carries *observable* request facts only — arrival time,
//! tenant, prompt length, generation cap.  Output lengths are NOT in the
//! trace (a serving log doesn't know them up front either); replay draws
//! them from the shared [`LengthProfile`] using one Pcg64 stream per
//! tenant, so a tenant's sampled lengths depend only on `(seed, tenant,
//! event-order-within-tenant)` — never on how other tenants interleave.
//!
//! `emit_trace` is canonical (fixed key order, shortest-round-trip f64
//! formatting), so `emit(parse(emit(events)))` is byte-identical — CI
//! pins that.

use super::{
    take, Arrival, ArrivalProcess, LengthProfile, TRACE_GEN_STREAM, TRACE_REPLAY_STREAM,
};
use crate::sim::SimRequest;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use anyhow::{bail, Result};
use std::fmt::Write as _;

/// One trace line: a request with `prompt_len` tokens from `tenant`
/// arriving at `t` (simulated seconds) with generation cap `cap`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub t: f64,
    pub tenant: usize,
    pub prompt_len: usize,
    pub cap: usize,
}

/// Canonical JSONL emit.  f64 `Display` prints the shortest string that
/// round-trips, so parse → re-emit reproduces the bytes exactly.
pub fn emit_trace(events: &[TraceEvent]) -> String {
    let mut out = String::with_capacity(events.len() * 48);
    for e in events {
        let _ = writeln!(
            out,
            "{{\"t\":{},\"tenant\":{},\"prompt_len\":{},\"cap\":{}}}",
            e.t, e.tenant, e.prompt_len, e.cap
        );
    }
    out
}

/// Parse a JSONL trace.  Rejects malformed lines, missing or non-integer
/// fields, zero lengths/caps, and out-of-order timestamps.
pub fn parse_trace(text: &str) -> Result<Vec<TraceEvent>> {
    let mut events = Vec::new();
    let mut prev_t = f64::NEG_INFINITY;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let n = ln + 1;
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("trace line {n}: {e}"))?;
        let field = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("trace line {n}: missing number field {key:?}"))
        };
        let int_field = |key: &str| -> Result<usize> {
            let v = field(key)?;
            if v < 0.0 || v.fract() != 0.0 || v > u32::MAX as f64 {
                bail!("trace line {n}: {key} must be a non-negative integer, got {v}");
            }
            Ok(v as usize)
        };
        let t = field("t")?;
        if !t.is_finite() || t < 0.0 {
            bail!("trace line {n}: t must be finite and >= 0, got {t}");
        }
        if t < prev_t {
            bail!("trace line {n}: timestamps must be non-decreasing ({t} < {prev_t})");
        }
        prev_t = t;
        let ev = TraceEvent {
            t,
            tenant: int_field("tenant")?,
            prompt_len: int_field("prompt_len")?,
            cap: int_field("cap")?,
        };
        if ev.prompt_len == 0 || ev.cap == 0 {
            bail!("trace line {n}: prompt_len and cap must be >= 1");
        }
        events.push(ev);
    }
    Ok(events)
}

/// Generate a synthetic multi-tenant trace: `tenants` independent
/// Poisson streams over `[0, horizon]` whose rates sum to `rate` and
/// split ∝ `1/(i+1)` (tenant 0 heaviest), each with its own length mix —
/// tenant `i` prompts start at `64 * (1 + i % 3)` tokens and its cap
/// alternates between `cap` and `cap / 2`.  Per-tenant Pcg64 streams
/// (`0x7E00 + i`) make every tenant's sub-trace independent of the
/// tenant count.
pub fn generate_trace(tenants: usize, rate: f64, horizon: f64, cap: usize, seed: u64) -> Vec<TraceEvent> {
    assert!(tenants > 0, "need at least one tenant");
    assert!(rate > 0.0 && horizon > 0.0, "rate and horizon must be > 0");
    let weight_sum: f64 = (0..tenants).map(|i| 1.0 / (i + 1) as f64).sum();
    let mut events: Vec<TraceEvent> = Vec::new();
    for i in 0..tenants {
        let tenant_rate = rate * (1.0 / (i + 1) as f64) / weight_sum;
        let mut rng = Pcg64::with_stream(seed, TRACE_GEN_STREAM + i as u64);
        let mut profile = LengthProfile::longtail();
        profile.prompt_base = 64 * (1 + i % 3);
        let tenant_cap = (cap >> (i % 2)).max(profile.min_len);
        let mut t = 0.0;
        loop {
            t += -(1.0 - rng.uniform_f64()).ln() / tenant_rate;
            if t > horizon {
                break;
            }
            events.push(TraceEvent {
                t,
                tenant: i,
                prompt_len: profile.prompt_len(&mut rng),
                cap: tenant_cap,
            });
        }
    }
    events.sort_by(|a, b| a.t.total_cmp(&b.t).then(a.tenant.cmp(&b.tenant)));
    events
}

/// Replay source over a parsed trace: finite [`ArrivalProcess`] that
/// draws each event's output length from the tenant's own Pcg64 stream.
pub struct TraceReplay {
    events: Vec<TraceEvent>,
    idx: usize,
    rngs: Vec<Pcg64>,
    profile: LengthProfile,
}

impl TraceReplay {
    pub fn new(events: &[TraceEvent], seed: u64) -> Self {
        let tenants = events.iter().map(|e| e.tenant + 1).max().unwrap_or(0);
        TraceReplay {
            events: events.to_vec(),
            idx: 0,
            rngs: (0..tenants)
                .map(|i| Pcg64::with_stream(seed, TRACE_REPLAY_STREAM + i as u64))
                .collect(),
            profile: LengthProfile::longtail(),
        }
    }

    pub fn tenants(&self) -> usize {
        self.rngs.len()
    }
}

impl ArrivalProcess for TraceReplay {
    fn name(&self) -> &'static str {
        "trace"
    }

    fn next_arrival(&mut self) -> Option<Arrival> {
        let e = *self.events.get(self.idx)?;
        let id = self.idx;
        self.idx += 1;
        let output_len = self.profile.output_len(e.cap, &mut self.rngs[e.tenant]);
        Some(Arrival {
            t: e.t,
            tenant: e.tenant,
            req: SimRequest { id, prompt_len: e.prompt_len, output_len },
        })
    }
}

/// Replay a whole trace into a materialized arrival vector (request ids
/// are trace-line indices).
pub fn replay_trace(events: &[TraceEvent], seed: u64) -> Vec<Arrival> {
    let mut r = TraceReplay::new(events, seed);
    take(&mut r, events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_is_byte_identical() {
        let events = generate_trace(3, 6.0, 25.0, 2048, 11);
        assert!(!events.is_empty());
        let text = emit_trace(&events);
        let parsed = parse_trace(&text).unwrap();
        assert_eq!(parsed.len(), events.len());
        for (a, b) in parsed.iter().zip(&events) {
            assert_eq!(a.t.to_bits(), b.t.to_bits());
            assert_eq!((a.tenant, a.prompt_len, a.cap), (b.tenant, b.prompt_len, b.cap));
        }
        assert_eq!(emit_trace(&parsed), text, "re-emit must reproduce bytes");
    }

    #[test]
    fn generated_traces_are_sorted_weighted_and_deterministic() {
        let a = generate_trace(3, 6.0, 40.0, 2048, 11);
        let b = generate_trace(3, 6.0, 40.0, 2048, 11);
        assert_eq!(a, b);
        for w in a.windows(2) {
            assert!(w[0].t <= w[1].t);
        }
        let count = |k: usize| a.iter().filter(|e| e.tenant == k).count();
        // rates split 1 : 1/2 : 1/3 — tenant 0 strictly heaviest over a
        // 40 s horizon at 6 req/s (~130 events for tenant 0 alone)
        assert!(count(0) > count(1) && count(1) > count(2), "counts {:?}", (count(0), count(1), count(2)));
        // per-tenant length mixes: caps alternate full/half
        assert!(a.iter().filter(|e| e.tenant == 0).all(|e| e.cap == 2048));
        assert!(a.iter().filter(|e| e.tenant == 1).all(|e| e.cap == 1024));
        assert!(a.iter().all(|e| e.t <= 40.0 && e.prompt_len >= 64));
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        for bad in [
            "not json",
            "{\"t\":1,\"tenant\":0,\"prompt_len\":8}",          // missing cap
            "{\"t\":1,\"tenant\":0,\"prompt_len\":0,\"cap\":4}", // zero prompt
            "{\"t\":1,\"tenant\":-1,\"prompt_len\":8,\"cap\":4}", // negative tenant
            "{\"t\":1,\"tenant\":0.5,\"prompt_len\":8,\"cap\":4}", // fractional tenant
            "{\"t\":-1,\"tenant\":0,\"prompt_len\":8,\"cap\":4}", // negative t
            "{\"t\":2,\"tenant\":0,\"prompt_len\":8,\"cap\":4}\n{\"t\":1,\"tenant\":0,\"prompt_len\":8,\"cap\":4}", // decreasing t
        ] {
            assert!(parse_trace(bad).is_err(), "accepted {bad:?}");
        }
        // blank lines are fine
        let ok = "\n{\"t\":1,\"tenant\":0,\"prompt_len\":8,\"cap\":4}\n\n";
        assert_eq!(parse_trace(ok).unwrap().len(), 1);
    }

    /// Per-tenant stream splitting: replaying only tenant 1's events
    /// yields the same output lengths that tenant 1 got in the full
    /// multi-tenant replay — lengths never depend on interleaving.
    #[test]
    fn replay_streams_are_tenant_independent() {
        let events = generate_trace(3, 8.0, 30.0, 2048, 5);
        let full = replay_trace(&events, 99);
        assert_eq!(full.len(), events.len());
        for (i, (a, e)) in full.iter().zip(&events).enumerate() {
            assert_eq!(a.req.id, i);
            assert_eq!(a.t.to_bits(), e.t.to_bits());
            assert_eq!(a.req.prompt_len, e.prompt_len);
            assert!(a.req.output_len <= e.cap);
        }
        let only1: Vec<TraceEvent> = events.iter().copied().filter(|e| e.tenant == 1).collect();
        let solo = replay_trace(&only1, 99);
        let full1: Vec<usize> = full
            .iter()
            .filter(|a| a.tenant == 1)
            .map(|a| a.req.output_len)
            .collect();
        let solo1: Vec<usize> = solo.iter().map(|a| a.req.output_len).collect();
        assert_eq!(full1, solo1);
    }
}
