//! Open-loop workload subsystem: arrival processes and trace replay.
//!
//! Every pre-PR-8 workload was a closed-loop batch — `n` prompts handed to
//! the pool at `t = 0`.  This module generates and replays *open-loop*
//! request streams instead: requests keep arriving while the scheduler is
//! mid-flight, which is the regime where HOL blocking, predictor quality
//! and KV backpressure actually matter (vllm-ltr's 2.8x chatbot-latency
//! win is an open-loop number).
//!
//! * [`ArrivalProcess`] (`arrival.rs`) — the stream trait plus the three
//!   synthetic generators: Poisson, bursty (Markov-modulated on/off) and
//!   diurnal (sinusoidal rate, Lewis–Shedler thinning).
//! * `trace.rs` — the multi-tenant JSONL trace format
//!   (`{t, tenant, prompt_len, cap}` per line): canonical emit, parser,
//!   synthetic trace generator, and the replay source.
//! * [`LengthProfile`] — the parameterized length distribution every
//!   source shares (the old hard-coded `longtail_workload` body), so
//!   generated and replayed requests go through one `SimRequest`
//!   construction path.
//!
//! Determinism: every stream derives from `(seed, stream-constant)` via
//! [`Pcg64::with_stream`]; multi-tenant sources split one stream per
//! tenant, so a tenant's sample sequence is independent of how the other
//! tenants' events interleave with it.
//!
//! How arrivals execute: see DESIGN.md §Workload.  At the pool level an
//! arrival is one extra key class on the event heap (pseudo-engine index
//! `n`, so engines win ties and delivery is strictly ordered against
//! decision points); at the backend level arrivals gate `load_prompts`
//! and stamp `SimWork::ready_at` so an idle engine can never admit work
//! before it exists.

mod arrival;
mod trace;

pub use arrival::{
    take, ArrivalProcess, BurstyArrivals, DiurnalArrivals, PoissonArrivals,
};
pub use trace::{
    emit_trace, generate_trace, parse_trace, replay_trace, TraceEvent, TraceReplay,
};

use crate::sim::SimRequest;
use crate::util::rng::Pcg64;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// Pcg64 stream constants (one per source so seeds never alias).
pub(crate) const LEN_STREAM: u64 = 0x51; // longtail_workload's historical stream
pub(crate) const POISSON_STREAM: u64 = 0x41;
pub(crate) const BURSTY_STREAM: u64 = 0x42;
pub(crate) const DIURNAL_STREAM: u64 = 0x43;
pub(crate) const TRACE_GEN_STREAM: u64 = 0x7E00; // + tenant
pub(crate) const TRACE_REPLAY_STREAM: u64 = 0x7E50; // + tenant

/// One open-loop arrival: a request that becomes schedulable at `t`
/// (simulated seconds), attributed to `tenant` for fairness accounting.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    pub t: f64,
    pub tenant: usize,
    pub req: SimRequest,
}

/// Parameterized long-tail length distribution — the single `SimRequest`
/// construction path shared by [`longtail_workload`], the arrival
/// generators, and trace replay.  Defaults reproduce the historical
/// hard-coded distribution bit-for-bit (same draw order, same arithmetic).
#[derive(Debug, Clone, Copy)]
pub struct LengthProfile {
    /// Probability a request runs all the way to the generation cap.
    pub frac_at_cap: f64,
    /// Lognormal (mu, sigma) of the body distribution.
    pub mu: f64,
    pub sigma: f64,
    /// Body median as a fraction of the cap.
    pub scale_frac: f64,
    /// Floor for body output lengths.
    pub min_len: usize,
    /// Prompt length = `prompt_base + uniform[0, prompt_spread)`.
    pub prompt_base: usize,
    pub prompt_spread: u64,
}

impl LengthProfile {
    /// Fig. 1c's shape: a lognormal body (~80% of samples within 3/8 of
    /// the cap) plus ~6% of requests truncated AT the generation cap.
    pub fn longtail() -> Self {
        LengthProfile {
            frac_at_cap: 0.08,
            mu: 0.0,
            sigma: 0.85,
            scale_frac: 0.11,
            min_len: 16,
            prompt_base: 64,
            prompt_spread: 192,
        }
    }

    /// Sample an output length against `cap`.  Draw order (one `bool`,
    /// then a lognormal only on the body branch) is part of the contract:
    /// it reproduces the historical `longtail_workload` stream exactly.
    pub fn output_len(&self, cap: usize, rng: &mut Pcg64) -> usize {
        if rng.bool_with(self.frac_at_cap) {
            cap // hit the generation limit
        } else {
            let body = rng.lognormal(self.mu, self.sigma) * self.scale_frac * cap as f64;
            (body as usize).clamp(self.min_len, cap)
        }
    }

    pub fn prompt_len(&self, rng: &mut Pcg64) -> usize {
        self.prompt_base + rng.below(self.prompt_spread) as usize
    }

    /// Sample a full request: output draws first, then the prompt draw —
    /// the historical order.
    pub fn sample(&self, id: usize, cap: usize, rng: &mut Pcg64) -> SimRequest {
        let output_len = self.output_len(cap, rng);
        SimRequest { id, prompt_len: self.prompt_len(rng), output_len }
    }
}

/// Long-tailed length workload matching Fig. 1c's shape: a lognormal body
/// plus ~6% of requests truncated AT the generation cap — the paper
/// observes "5% can extend up to the token limit", and those cap-clipped
/// requests are what the schedulers fight over.  (Moved here from
/// `sim::longtail_workload`, which re-exports it; byte-identical output.)
pub fn longtail_workload(n: usize, cap: usize, seed: u64) -> Vec<SimRequest> {
    let profile = LengthProfile::longtail();
    let mut rng = Pcg64::with_stream(seed, LEN_STREAM);
    (0..n).map(|id| profile.sample(id, cap, &mut rng)).collect()
}

/// Parsed `--arrival` flag: which stream feeds the run.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    /// Closed loop (default): the whole workload is schedulable at t=0.
    Batch,
    /// Poisson arrivals at `rate` req/s.
    Poisson { rate: f64 },
    /// Markov-modulated on/off: exponential gaps at `rate_hi` (on) or
    /// `rate_lo` (off), state flipped after each arrival with prob `flip`.
    Bursty { rate_hi: f64, rate_lo: f64, flip: f64 },
    /// Sinusoidal rate `base * (1 + amp * sin(2 pi t / period))` via
    /// Lewis–Shedler thinning.
    Diurnal { base: f64, amp: f64, period: f64 },
    /// Replay a multi-tenant JSONL trace file.
    Trace { path: PathBuf },
}

impl ArrivalSpec {
    /// Parse the `--arrival` flag value:
    /// `batch | poisson:RATE | bursty:HI,LO,FLIP | diurnal:BASE,AMP,PERIOD
    ///  | trace:FILE`.
    pub fn parse(s: &str) -> Result<Self> {
        let (kind, args) = match s.split_once(':') {
            Some((k, a)) => (k, a),
            None => (s, ""),
        };
        let nums = |want: usize| -> Result<Vec<f64>> {
            let parts: Vec<f64> = args
                .split(',')
                .map(|p| p.trim().parse::<f64>())
                .collect::<std::result::Result<_, _>>()
                .map_err(|_| anyhow::anyhow!("--arrival {kind}: bad number in {args:?}"))?;
            if parts.len() != want {
                bail!("--arrival {kind}: expected {want} comma-separated values, got {args:?}");
            }
            if parts.iter().any(|x| !x.is_finite()) {
                bail!("--arrival {kind}: values must be finite, got {args:?}");
            }
            Ok(parts)
        };
        Ok(match kind {
            "batch" => {
                if !args.is_empty() {
                    bail!("--arrival batch takes no arguments");
                }
                ArrivalSpec::Batch
            }
            "poisson" => {
                let v = nums(1)?;
                if v[0] <= 0.0 {
                    bail!("--arrival poisson: rate must be > 0");
                }
                ArrivalSpec::Poisson { rate: v[0] }
            }
            "bursty" => {
                let v = nums(3)?;
                if v[0] <= 0.0 || v[1] <= 0.0 {
                    bail!("--arrival bursty: both rates must be > 0");
                }
                if !(v[2] > 0.0 && v[2] <= 1.0) {
                    bail!("--arrival bursty: flip must be in (0, 1]");
                }
                ArrivalSpec::Bursty { rate_hi: v[0], rate_lo: v[1], flip: v[2] }
            }
            "diurnal" => {
                let v = nums(3)?;
                if v[0] <= 0.0 {
                    bail!("--arrival diurnal: base rate must be > 0");
                }
                if !(0.0..1.0).contains(&v[1]) {
                    bail!("--arrival diurnal: amplitude must be in [0, 1)");
                }
                if v[2] <= 0.0 {
                    bail!("--arrival diurnal: period must be > 0");
                }
                ArrivalSpec::Diurnal { base: v[0], amp: v[1], period: v[2] }
            }
            "trace" => {
                if args.is_empty() {
                    bail!("--arrival trace: missing file path");
                }
                ArrivalSpec::Trace { path: PathBuf::from(args) }
            }
            other => bail!(
                "unknown --arrival {other:?} (batch|poisson:RATE|bursty:HI,LO,FLIP|\
                 diurnal:BASE,AMP,PERIOD|trace:FILE)"
            ),
        })
    }

    pub fn is_open_loop(&self) -> bool {
        *self != ArrivalSpec::Batch
    }

    /// Materialize the stream: `n` arrivals for generators (lengths drawn
    /// from the shared longtail profile against `cap`), every event for a
    /// trace (its own lengths/caps; `n` and `cap` ignored).  Batch yields
    /// the closed-loop workload with every `t = 0`.
    pub fn build(&self, n: usize, cap: usize, seed: u64) -> Result<Vec<Arrival>> {
        let profile = LengthProfile::longtail();
        Ok(match self {
            ArrivalSpec::Batch => longtail_workload(n, cap, seed)
                .into_iter()
                .map(|req| Arrival { t: 0.0, tenant: 0, req })
                .collect(),
            ArrivalSpec::Poisson { rate } => {
                take(&mut PoissonArrivals::new(*rate, cap, profile, seed), n)
            }
            ArrivalSpec::Bursty { rate_hi, rate_lo, flip } => take(
                &mut BurstyArrivals::new(*rate_hi, *rate_lo, *flip, cap, profile, seed),
                n,
            ),
            ArrivalSpec::Diurnal { base, amp, period } => take(
                &mut DiurnalArrivals::new(*base, *amp, *period, cap, profile, seed),
                n,
            ),
            ArrivalSpec::Trace { path } => {
                let text = std::fs::read_to_string(path).map_err(|e| {
                    anyhow::anyhow!("--arrival trace: cannot read {}: {e}", path.display())
                })?;
                replay_trace(&parse_trace(&text)?, seed)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The moved `longtail_workload` still produces the historical
    /// sequence: first six (prompt, output) pairs for seed 1 / cap 8192,
    /// hand-derived through an independent Pcg64 mirror.
    #[test]
    fn longtail_pins_historical_values() {
        let w = longtail_workload(6, 8192, 1);
        let got: Vec<(usize, usize)> =
            w.iter().map(|r| (r.prompt_len, r.output_len)).collect();
        assert_eq!(
            got,
            vec![(88, 175), (191, 4702), (171, 859), (200, 134), (154, 2012), (249, 446)]
        );
        for (i, r) in w.iter().enumerate() {
            assert_eq!(r.id, i);
        }
    }

    #[test]
    fn arrival_generators_share_the_longtail_length_stream() {
        // same seed => generated request bodies are exactly the closed-loop
        // workload; only the timestamps differ (one shared construction path)
        let w = longtail_workload(64, 2048, 9);
        for spec in [
            ArrivalSpec::Poisson { rate: 3.0 },
            ArrivalSpec::Bursty { rate_hi: 8.0, rate_lo: 1.0, flip: 0.2 },
            ArrivalSpec::Diurnal { base: 4.0, amp: 0.5, period: 10.0 },
        ] {
            let a = spec.build(64, 2048, 9).unwrap();
            assert_eq!(a.len(), 64);
            for (x, r) in a.iter().zip(&w) {
                assert_eq!(x.req.id, r.id);
                assert_eq!(x.req.prompt_len, r.prompt_len);
                assert_eq!(x.req.output_len, r.output_len);
            }
            // arrival times are non-decreasing and strictly positive overall
            for pair in a.windows(2) {
                assert!(pair[0].t <= pair[1].t);
            }
            assert!(a.last().unwrap().t > 0.0);
        }
    }

    #[test]
    fn batch_spec_is_t0_closed_loop() {
        let a = ArrivalSpec::Batch.build(16, 1024, 3).unwrap();
        let w = longtail_workload(16, 1024, 3);
        assert_eq!(a.len(), 16);
        for (x, r) in a.iter().zip(&w) {
            assert_eq!(x.t, 0.0);
            assert_eq!(x.tenant, 0);
            assert_eq!(x.req.output_len, r.output_len);
        }
    }

    #[test]
    fn spec_parse_round_trips_and_rejects_nonsense() {
        assert_eq!(ArrivalSpec::parse("batch").unwrap(), ArrivalSpec::Batch);
        assert_eq!(
            ArrivalSpec::parse("poisson:2.5").unwrap(),
            ArrivalSpec::Poisson { rate: 2.5 }
        );
        assert_eq!(
            ArrivalSpec::parse("bursty:8,0.5,0.15").unwrap(),
            ArrivalSpec::Bursty { rate_hi: 8.0, rate_lo: 0.5, flip: 0.15 }
        );
        assert_eq!(
            ArrivalSpec::parse("diurnal:2,0.8,8").unwrap(),
            ArrivalSpec::Diurnal { base: 2.0, amp: 0.8, period: 8.0 }
        );
        assert_eq!(
            ArrivalSpec::parse("trace:/tmp/x.jsonl").unwrap(),
            ArrivalSpec::Trace { path: PathBuf::from("/tmp/x.jsonl") }
        );
        for bad in [
            "poisson", "poisson:0", "poisson:-1", "poisson:nope", "bursty:1,2",
            "bursty:0,1,0.5", "bursty:1,1,0", "diurnal:1,1.5,8", "diurnal:1,0.5,0",
            "trace:", "fancy:1", "batch:now",
        ] {
            assert!(ArrivalSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
        assert!(!ArrivalSpec::Batch.is_open_loop());
        assert!(ArrivalSpec::Poisson { rate: 1.0 }.is_open_loop());
    }
}
