//! Synthetic arrival processes: Poisson, bursty (Markov-modulated
//! on/off), and diurnal (sinusoidal rate via Lewis–Shedler thinning).
//!
//! Each generator keeps TWO independent Pcg64 streams: a *times* stream
//! (unique per process kind) and a *lengths* stream (the shared
//! [`LEN_STREAM`](super::LEN_STREAM) that `longtail_workload` has always
//! used).  Splitting them means the request bodies a generator produces
//! for `(seed, cap)` are byte-identical to the closed-loop workload —
//! only the timestamps differ — which keeps open-loop vs closed-loop
//! comparisons apples-to-apples and is pinned by a test.

use super::{Arrival, LengthProfile, BURSTY_STREAM, DIURNAL_STREAM, LEN_STREAM, POISSON_STREAM};
use crate::util::rng::Pcg64;

/// An unbounded, deterministic open-loop request stream.  Arrival times
/// are non-decreasing; `next_arrival` returns `None` only for finite
/// sources (trace replay) — the synthetic generators never exhaust.
pub trait ArrivalProcess {
    fn name(&self) -> &'static str;
    fn next_arrival(&mut self) -> Option<Arrival>;
}

/// Drain the first `n` arrivals of a process into a vector.
pub fn take(p: &mut dyn ArrivalProcess, n: usize) -> Vec<Arrival> {
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        match p.next_arrival() {
            Some(a) => out.push(a),
            None => break,
        }
    }
    out
}

/// Exponential inter-arrival gap at `rate` (inverse-CDF on one uniform).
/// `1.0 - u` keeps the draw in (0, 1] so `ln` never sees zero.
fn exp_gap(rate: f64, rng: &mut Pcg64) -> f64 {
    -(1.0 - rng.uniform_f64()).ln() / rate
}

/// Homogeneous Poisson arrivals at a fixed rate (req/s).
pub struct PoissonArrivals {
    rate: f64,
    cap: usize,
    profile: LengthProfile,
    t: f64,
    next_id: usize,
    times: Pcg64,
    lengths: Pcg64,
}

impl PoissonArrivals {
    pub fn new(rate: f64, cap: usize, profile: LengthProfile, seed: u64) -> Self {
        assert!(rate > 0.0, "poisson rate must be > 0");
        PoissonArrivals {
            rate,
            cap,
            profile,
            t: 0.0,
            next_id: 0,
            times: Pcg64::with_stream(seed, POISSON_STREAM),
            lengths: Pcg64::with_stream(seed, LEN_STREAM),
        }
    }
}

impl ArrivalProcess for PoissonArrivals {
    fn name(&self) -> &'static str {
        "poisson"
    }

    fn next_arrival(&mut self) -> Option<Arrival> {
        self.t += exp_gap(self.rate, &mut self.times);
        let req = self.profile.sample(self.next_id, self.cap, &mut self.lengths);
        self.next_id += 1;
        Some(Arrival { t: self.t, tenant: 0, req })
    }
}

/// Markov-modulated on/off arrivals: exponential gaps at `rate_hi` while
/// "on" and `rate_lo` while "off"; after every arrival the state flips
/// with probability `flip`.  Burst length is geometric with mean
/// `1/flip`, and the gap CV is > 1 (over-dispersed vs Poisson) whenever
/// the two rates differ — pinned by a test.
pub struct BurstyArrivals {
    rate_hi: f64,
    rate_lo: f64,
    flip: f64,
    cap: usize,
    profile: LengthProfile,
    on: bool,
    t: f64,
    next_id: usize,
    times: Pcg64,
    lengths: Pcg64,
}

impl BurstyArrivals {
    pub fn new(
        rate_hi: f64,
        rate_lo: f64,
        flip: f64,
        cap: usize,
        profile: LengthProfile,
        seed: u64,
    ) -> Self {
        assert!(rate_hi > 0.0 && rate_lo > 0.0, "bursty rates must be > 0");
        assert!(flip > 0.0 && flip <= 1.0, "bursty flip must be in (0, 1]");
        BurstyArrivals {
            rate_hi,
            rate_lo,
            flip,
            cap,
            profile,
            on: true,
            t: 0.0,
            next_id: 0,
            times: Pcg64::with_stream(seed, BURSTY_STREAM),
            lengths: Pcg64::with_stream(seed, LEN_STREAM),
        }
    }
}

impl ArrivalProcess for BurstyArrivals {
    fn name(&self) -> &'static str {
        "bursty"
    }

    fn next_arrival(&mut self) -> Option<Arrival> {
        let rate = if self.on { self.rate_hi } else { self.rate_lo };
        self.t += exp_gap(rate, &mut self.times);
        if self.times.bool_with(self.flip) {
            self.on = !self.on;
        }
        let req = self.profile.sample(self.next_id, self.cap, &mut self.lengths);
        self.next_id += 1;
        Some(Arrival { t: self.t, tenant: 0, req })
    }
}

/// Inhomogeneous Poisson with sinusoidal rate
/// `base * (1 + amp * sin(2 pi t / period))`, realized by Lewis–Shedler
/// thinning: candidates at the peak rate `base * (1 + amp)`, each kept
/// with probability `rate(t) / rate_max`.  One candidate costs exactly
/// two uniform draws (gap, accept) regardless of acceptance, so the
/// stream stays reproducible.
pub struct DiurnalArrivals {
    base: f64,
    amp: f64,
    period: f64,
    rate_max: f64,
    cap: usize,
    profile: LengthProfile,
    t: f64,
    next_id: usize,
    times: Pcg64,
    lengths: Pcg64,
}

impl DiurnalArrivals {
    pub fn new(base: f64, amp: f64, period: f64, cap: usize, profile: LengthProfile, seed: u64) -> Self {
        assert!(base > 0.0, "diurnal base rate must be > 0");
        assert!((0.0..1.0).contains(&amp), "diurnal amplitude must be in [0, 1)");
        assert!(period > 0.0, "diurnal period must be > 0");
        DiurnalArrivals {
            base,
            amp,
            period,
            rate_max: base * (1.0 + amp),
            cap,
            profile,
            t: 0.0,
            next_id: 0,
            times: Pcg64::with_stream(seed, DIURNAL_STREAM),
            lengths: Pcg64::with_stream(seed, LEN_STREAM),
        }
    }

    fn rate_at(&self, t: f64) -> f64 {
        self.base * (1.0 + self.amp * (std::f64::consts::TAU * t / self.period).sin())
    }
}

impl ArrivalProcess for DiurnalArrivals {
    fn name(&self) -> &'static str {
        "diurnal"
    }

    fn next_arrival(&mut self) -> Option<Arrival> {
        loop {
            self.t += exp_gap(self.rate_max, &mut self.times);
            let accept = self.times.uniform_f64() < self.rate_at(self.t) / self.rate_max;
            if accept {
                let req = self.profile.sample(self.next_id, self.cap, &mut self.lengths);
                self.next_id += 1;
                return Some(Arrival { t: self.t, tenant: 0, req });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaps(arrivals: &[Arrival]) -> Vec<f64> {
        let mut prev = 0.0;
        arrivals
            .iter()
            .map(|a| {
                let g = a.t - prev;
                prev = a.t;
                g
            })
            .collect()
    }

    fn mean_cv(g: &[f64]) -> (f64, f64) {
        let mean = g.iter().sum::<f64>() / g.len() as f64;
        let var = g.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / g.len() as f64;
        (mean, var.sqrt() / mean)
    }

    /// Interarrival-gap pins for seed 7, hand-derived through an
    /// independent Pcg64 mirror.  Counts use a small tolerance band; the
    /// nearest gap sits >= 1e-3 from the 1.0 threshold, so any libm ulp
    /// drift cannot move the count more than that.
    #[test]
    fn poisson_gap_statistics_pin() {
        let mut p = PoissonArrivals::new(1.0, 8192, LengthProfile::longtail(), 7);
        let a = take(&mut p, 1000);
        let g = gaps(&a);
        let short = g.iter().filter(|&&x| x < 1.0).count();
        assert!((616..=622).contains(&short), "gaps<1.0 = {short}, pin 619");
        let (mean, cv) = mean_cv(&g);
        assert!((mean - 1.0017).abs() < 0.05, "mean {mean}, pin 1.0017");
        // exponential gaps: CV ~ 1
        assert!((cv - 0.97).abs() < 0.15, "cv {cv}, pin 0.97");
        assert!(g.iter().all(|&x| x > 0.0));
    }

    /// Bursty pin (hi 4.0, lo 0.5, flip 0.15, seed 7): the on/off mix is
    /// over-dispersed — CV well above the Poisson ~1.
    #[test]
    fn bursty_gap_statistics_pin() {
        let mut p = BurstyArrivals::new(4.0, 0.5, 0.15, 8192, LengthProfile::longtail(), 7);
        let a = take(&mut p, 1000);
        let g = gaps(&a);
        let short = g.iter().filter(|&&x| x < 0.25).count();
        assert!((370..=376).contains(&short), "gaps<0.25 = {short}, pin 373");
        let (_, cv) = mean_cv(&g);
        assert!(cv > 1.2, "bursty cv {cv} should exceed 1.2 (pin 1.55)");
    }

    /// Diurnal pin (base 2.0, amp 0.8, period 8.0, seed 7): arrivals
    /// concentrate in the sin>0 half of each period — 766/1000 in the
    /// mirror run vs 500 for a flat rate.
    #[test]
    fn diurnal_concentrates_in_peak_half() {
        let mut p = DiurnalArrivals::new(2.0, 0.8, 8.0, 8192, LengthProfile::longtail(), 7);
        let a = take(&mut p, 1000);
        let peak = a.iter().filter(|x| x.t.rem_euclid(8.0) < 4.0).count();
        assert!((761..=771).contains(&peak), "peak-half = {peak}, pin 766");
        for w in a.windows(2) {
            assert!(w[0].t < w[1].t);
        }
    }

    #[test]
    fn generators_are_deterministic_bit_for_bit() {
        let runs: Vec<Vec<Arrival>> = (0..2)
            .map(|_| {
                let mut p = BurstyArrivals::new(8.0, 1.0, 0.2, 4096, LengthProfile::longtail(), 42);
                take(&mut p, 256)
            })
            .collect();
        for (x, y) in runs[0].iter().zip(&runs[1]) {
            assert_eq!(x.t.to_bits(), y.t.to_bits());
            assert_eq!(x.req.output_len, y.req.output_len);
            assert_eq!(x.req.prompt_len, y.req.prompt_len);
        }
    }
}
