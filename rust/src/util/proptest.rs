//! A small property-testing harness (the `proptest` crate is unavailable
//! offline).  Seeded, iterated, with failure-case reporting; generators are
//! plain closures over [`Pcg64`].
//!
//! ```no_run
//! use sortedrl::util::proptest::{property, Gen};
//! property("reverse twice is identity", 200, |g| {
//!     let v = g.vec(0..50, |g| g.rng.range_i64(-100, 100));
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     assert_eq!(v, w);
//! });
//! ```

use super::rng::Pcg64;
use std::ops::Range;

/// Generator context handed to each property iteration.
pub struct Gen {
    pub rng: Pcg64,
    pub iteration: usize,
}

impl Gen {
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        self.rng.range_usize(r.start, r.end)
    }

    pub fn i64_in(&mut self, r: Range<i64>) -> i64 {
        self.rng.range_i64(r.start, r.end)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool_with(0.5)
    }

    pub fn vec<T>(&mut self, len: Range<usize>, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize_in(len);
        (0..n).map(|_| f(self)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len() as u64) as usize;
        &xs[i]
    }
}

/// Run `body` for `iters` seeded iterations; panics (with the failing seed)
/// on the first assertion failure so `cargo test` reports it.
pub fn property(name: &str, iters: usize, mut body: impl FnMut(&mut Gen)) {
    let base_seed = 0x5EED_0000u64 ^ fxhash(name);
    for i in 0..iters {
        let mut g = Gen { rng: Pcg64::with_stream(base_seed, i as u64 + 1), iteration: i };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at iteration {i} (seed base {base_seed:#x}): {msg}"
            );
        }
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        property("addition commutes", 100, |g| {
            let a = g.i64_in(-1000..1000);
            let b = g.i64_in(-1000..1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failure_with_iteration() {
        property("always fails", 10, |g| {
            assert!(g.i64_in(0..10) > 100);
        });
    }

    #[test]
    fn deterministic_per_name() {
        let mut seen = Vec::new();
        property("det", 5, |g| seen.push(g.rng.next_u64()));
        let mut seen2 = Vec::new();
        property("det", 5, |g| seen2.push(g.rng.next_u64()));
        assert_eq!(seen, seen2);
    }
}
