//! In-repo substrates that would normally come from crates.io (the build is
//! fully offline): PRNG streams, JSON, statistics, a property-test harness.

pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Monotonic wall-clock helper for the real (non-simulated) pipeline.
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}

/// Format a f64 seconds value compactly for harness output.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}
