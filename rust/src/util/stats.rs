//! Small statistics helpers used by metrics, the simulator and the
//! experiment harnesses (quantiles, histograms, online mean/variance).

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Running {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Population mean/std over a slice (used for advantage normalization —
/// Reinforce++ Eq. 3 normalizes by the *batch* statistics).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Quantile with linear interpolation; `q` in [0, 1]. Sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Fixed-width histogram over [lo, hi) with EXPLICIT underflow/overflow
/// bins: out-of-range values no longer distort the edge bins (the old
/// clamp-to-edge behavior silently merged `x < lo` into bin 0 and
/// `x >= hi` into the last bin, which misreported tails).  NaN samples are
/// ignored.  `total()` and `cdf()` account for the out-of-range mass.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    /// Samples below `lo`.
    pub underflow: u64,
    /// Samples at or above `hi`.
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        Self { lo, hi, counts: vec![0; bins], underflow: 0, overflow: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        if t < 0.0 {
            self.underflow += 1;
            return;
        }
        let idx = (t * bins as f64) as usize;
        if idx >= bins {
            self.overflow += 1;
        } else {
            self.counts[idx] += 1;
        }
    }

    /// All samples, including the underflow/overflow bins.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Fraction of samples at or below the right edge of each bin (CDF).
    /// Underflow counts as before the first bin; overflow only reaches the
    /// total after the last edge, so `cdf().last() < 1` iff overflow > 0.
    pub fn cdf(&self) -> Vec<f64> {
        let total = self.total().max(1) as f64;
        let mut acc = self.underflow;
        self.counts
            .iter()
            .map(|c| {
                acc += c;
                acc as f64 / total
            })
            .collect()
    }

    /// Quantile estimate by linear interpolation within the bin holding
    /// the target rank.  Ranks in the underflow bin resolve to `lo`, in
    /// the overflow bin to `hi` (the histogram cannot know how far out
    /// they sit).  NaN when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.total();
        if total == 0 {
            return f64::NAN;
        }
        let target = q.clamp(0.0, 1.0) * total as f64;
        let mut acc = self.underflow as f64;
        if self.underflow > 0 && target <= acc {
            return self.lo;
        }
        let width = (self.hi - self.lo) / self.counts.len() as f64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = acc + c as f64;
            if target <= next {
                let frac = ((target - acc) / c as f64).clamp(0.0, 1.0);
                return self.lo + width * (i as f64 + frac);
            }
            acc = next;
        }
        self.hi
    }

    /// Render as a compact ASCII bar chart (for harness stdout); nonzero
    /// underflow/overflow get their own rows.
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let bins = self.counts.len();
        let mut out = String::new();
        if self.underflow > 0 {
            out.push_str(&format!("{:>8}{:<9} | {}\n", "< ", self.lo, self.underflow));
        }
        for (i, &c) in self.counts.iter().enumerate() {
            let l = self.lo + (self.hi - self.lo) * i as f64 / bins as f64;
            let r = self.lo + (self.hi - self.lo) * (i + 1) as f64 / bins as f64;
            let bar = "#".repeat((c as usize * width).div_ceil(max as usize).min(width));
            out.push_str(&format!("{l:>8.0}-{r:<8.0} |{bar:<width$}| {c}\n"));
        }
        if self.overflow > 0 {
            out.push_str(&format!("{:>8}{:<9} | {}\n", ">= ", self.hi, self.overflow));
        }
        out
    }
}

/// Log-bucketed histogram for long-tailed POSITIVE samples (latencies):
/// fixed-width bins over `log10(x)` between `lo` and `hi`, so p99 of a
/// heavy tail lands in a bin of proportional (not absolute) width.
/// Non-positive samples count as underflow.  Shares [`Histogram`]'s
/// underflow/overflow and interpolation machinery.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    inner: Histogram,
}

impl LogHistogram {
    /// `lo`/`hi` are sample-space bounds (both > 0).
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(lo > 0.0 && hi > lo);
        LogHistogram { inner: Histogram::new(lo.log10(), hi.log10(), bins) }
    }

    pub fn push(&mut self, x: f64) {
        if x > 0.0 {
            self.inner.push(x.log10());
        } else if !x.is_nan() {
            self.inner.underflow += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.inner.total()
    }

    /// Quantile in sample space (the inner log-space estimate mapped back).
    pub fn quantile(&self, q: f64) -> f64 {
        let v = self.inner.quantile(q);
        if v.is_nan() {
            v
        } else {
            10f64.powf(v)
        }
    }

    /// The log-space histogram (bin edges are log10 of sample values).
    pub fn log_bins(&self) -> &Histogram {
        &self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::default();
        for x in xs {
            r.push(x);
        }
        assert!((r.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((r.variance() - direct_var).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_cdf() {
        let mut h = Histogram::new(0.0, 10.0, 5);
        for x in [0.5, 1.5, 2.5, 9.5, 11.0, -1.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 6);
        assert_eq!(h.counts[0], 2); // 0.5, 1.5 — NOT the clamped -1.0
        assert_eq!(h.counts[1], 1); // 2.5
        assert_eq!(h.counts[4], 1); // 9.5 — NOT the clamped 11.0
        assert_eq!(h.underflow, 1); // -1.0
        assert_eq!(h.overflow, 1); // 11.0
        let cdf = h.cdf();
        // 1 underflow + 4 in-range of 6 by the last edge; overflow never
        // crosses an edge
        assert!((cdf[4] - 5.0 / 6.0).abs() < 1e-12);
        assert!((cdf[0] - 3.0 / 6.0).abs() < 1e-12); // underflow + bin 0
    }

    #[test]
    fn histogram_quantiles_match_exact_on_uniform() {
        // bin-center samples: within any bin the mass sits at one point,
        // so interpolation error is bounded by the bin width
        let xs: Vec<f64> = (0..100).map(|i| i as f64 + 0.5).collect();
        let mut h = Histogram::new(0.0, 100.0, 100);
        for &x in &xs {
            h.push(x);
        }
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let exact = quantile(&xs, q);
            assert!(
                (h.quantile(q) - exact).abs() <= 1.0,
                "q={q}: hist {} vs exact {exact}",
                h.quantile(q)
            );
        }
        assert!(h.quantile(0.5) > 0.0);
    }

    #[test]
    fn histogram_quantile_overflow_and_underflow_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [1.0, 2.0, 3.0, 50.0, 60.0] {
            h.push(x);
        }
        assert_eq!(h.overflow, 2);
        // p99 rank lands in the overflow bin -> reported at the hi edge,
        // not silently inside the last in-range bin
        assert_eq!(h.quantile(0.99), 10.0);
        assert_eq!(h.quantile(1.0), 10.0);
        // all-underflow resolves to lo; empty is NaN
        let mut u = Histogram::new(0.0, 1.0, 4);
        u.push(-5.0);
        assert_eq!(u.quantile(0.5), 0.0);
        assert!(Histogram::new(0.0, 1.0, 4).quantile(0.5).is_nan());
    }

    #[test]
    fn log_histogram_tracks_exact_quantiles_on_longtail() {
        // two-decade spread; 20 bins/decade keeps relative error ~12%
        let xs: Vec<f64> = (1..=200).map(|i| (i as f64).powf(1.5)).collect();
        let mut h = LogHistogram::new(1e-3, 1e6, 180);
        for &x in &xs {
            h.push(x);
        }
        assert_eq!(h.total(), 200);
        for q in [0.5, 0.9, 0.99] {
            let exact = quantile(&xs, q);
            let est = h.quantile(q);
            assert!(
                (est / exact).ln().abs() < 0.13,
                "q={q}: log-hist {est} vs exact {exact}"
            );
        }
        // non-positive latencies are counted but never panic the log
        let mut z = LogHistogram::new(1e-3, 1e3, 10);
        z.push(0.0);
        z.push(-1.0);
        assert_eq!(z.total(), 2);
        assert!((z.quantile(0.5) - 1e-3).abs() < 1e-9); // resolves to lo
    }

    #[test]
    fn mean_std_empty() {
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }
}
