//! Small statistics helpers used by metrics, the simulator and the
//! experiment harnesses (quantiles, histograms, online mean/variance).

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Running {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Population mean/std over a slice (used for advantage normalization —
/// Reinforce++ Eq. 3 normalizes by the *batch* statistics).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Quantile with linear interpolation; `q` in [0, 1]. Sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Fixed-width histogram over [lo, hi); values outside clamp to edge bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        Self { lo, hi, counts: vec![0; bins] }
    }

    pub fn push(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Fraction of samples at or below the right edge of each bin (CDF).
    pub fn cdf(&self) -> Vec<f64> {
        let total = self.total().max(1) as f64;
        let mut acc = 0u64;
        self.counts
            .iter()
            .map(|c| {
                acc += c;
                acc as f64 / total
            })
            .collect()
    }

    /// Render as a compact ASCII bar chart (for harness stdout).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let bins = self.counts.len();
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let l = self.lo + (self.hi - self.lo) * i as f64 / bins as f64;
            let r = self.lo + (self.hi - self.lo) * (i + 1) as f64 / bins as f64;
            let bar = "#".repeat((c as usize * width).div_ceil(max as usize).min(width));
            out.push_str(&format!("{l:>8.0}-{r:<8.0} |{bar:<width$}| {c}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::default();
        for x in xs {
            r.push(x);
        }
        assert!((r.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((r.variance() - direct_var).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_cdf() {
        let mut h = Histogram::new(0.0, 10.0, 5);
        for x in [0.5, 1.5, 2.5, 9.5, 11.0, -1.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 6);
        assert_eq!(h.counts[0], 3); // 0.5, 1.5, clamped -1.0
        assert_eq!(h.counts[1], 1); // 2.5
        assert_eq!(h.counts[4], 2); // 9.5 and clamped 11.0
        let cdf = h.cdf();
        assert!((cdf[4] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_std_empty() {
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }
}
