//! Deterministic PRNG streams (no `rand` crate offline — built in-repo).
//!
//! `Pcg64` is the workhorse: every request, lane and component gets its own
//! stream derived via `SplitMix64` so rollout sampling is reproducible under
//! any interleaving the scheduler produces (a correctness requirement for
//! the paper's throughput experiments, which pin generation lengths).

/// SplitMix64 — used for seeding / stream derivation.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 with 128-bit state emulated by two 64-bit lcg streams
/// folded together — small, fast, good statistical quality for simulation.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xDA3E39CB94B95BDB)
    }

    /// Independent stream: different `stream` values never collide.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.rotate_left(17));
        let mut rng = Self { state: sm.next_u64(), inc: sm.next_u64() | 1 };
        rng.next_u32();
        rng
    }

    /// Derive a child stream (request RNGs, lane RNGs, ...).
    pub fn fork(&mut self, salt: u64) -> Pcg64 {
        Pcg64::with_stream(self.next_u64() ^ salt, salt.wrapping_mul(2) | 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.uniform_f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform_f64().max(1e-300);
        let u2 = self.uniform_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal with given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::with_stream(42, 1);
        let mut b = Pcg64::with_stream(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.uniform_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Pcg64::new(3);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.uniform_f64()).sum();
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Pcg64::new(9);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Pcg64::new(1);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(1); // same salt, later fork point -> distinct
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
