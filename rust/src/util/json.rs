//! Minimal JSON: parser + writer (no serde offline — built in-repo).
//!
//! Parses artifacts/manifest.json and writes experiment result files.
//! Supports the full JSON grammar minus exotic escapes (\u beyond BMP pairs
//! is passed through unvalidated).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["configs", tag, "entries"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for writing result files.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = &self.bytes[start..];
                    let len = utf8_len(rest[0]);
                    let chunk = rest.get(..len).ok_or_else(|| self.err("bad utf8"))?;
                    out.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?,
                    );
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn round_trips() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":-3}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, again);
        let compact = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, compact);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        let j = Json::parse("\"π_old\"").unwrap();
        assert_eq!(j.as_str(), Some("π_old"));
    }
}
