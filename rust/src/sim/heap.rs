//! Event-heap machinery for the discrete-event simulator core.
//!
//! Two small data structures, both `O(log n)` per operation:
//!
//! * [`EventHeap`] — a lazy-deletion binary min-heap of per-engine
//!   decision points keyed by `(event time, engine index)`.  Each engine
//!   owns at most one *live* entry at a time; superseded entries are not
//!   removed eagerly but invalidated by bumping the engine's epoch
//!   counter, and skipped when popped.  Ordering uses `f64::total_cmp`
//!   with the engine index as tiebreaker so the pop order reproduces the
//!   reference stepper's "first minimal engine wins" scan exactly.
//! * [`MarkStack`] — a monotone stack over the sequence of processed
//!   event keys supporting `suffix_max(since)`: the lexicographic
//!   maximum `(key, engine)` among all events processed at or after a
//!   given sequence number.  The pool uses it to materialize an engine's
//!   silent span up to (not past) the last decision point that could
//!   have observed it — the discrete-event analogue of "how far has the
//!   reference stepper's scan provably advanced past this engine".

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One pending decision point: engine `engine` must run a micro-tick at
/// absolute simulated time `key`, after silently folding `fold` decode
/// iterations (clock / token / KV deltas with no intervening decision).
#[derive(Debug, Clone, Copy)]
struct Entry {
    key: f64,
    engine: usize,
    epoch: u64,
    fold: u64,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap order; the heap stores `Reverse`-free entries but we
        // invert here so `BinaryHeap::pop` yields the minimum
        // `(key, engine)`.  `total_cmp` keeps the order total (the sim
        // never produces NaN keys, but a partial compare would still be
        // a latent panic).
        other
            .key
            .total_cmp(&self.key)
            .then_with(|| other.engine.cmp(&self.engine))
    }
}

/// Min-heap of per-engine decision points with lazy epoch invalidation.
#[derive(Debug, Default)]
pub struct EventHeap {
    heap: BinaryHeap<Entry>,
    /// Per-engine epoch; an entry is live iff its epoch matches.
    epoch: Vec<u64>,
}

impl EventHeap {
    pub fn new(engines: usize) -> Self {
        EventHeap { heap: BinaryHeap::new(), epoch: vec![0; engines] }
    }

    /// Drop the engine's live entry (if any) without touching the heap;
    /// the stale entry is skipped when it eventually pops.
    pub fn invalidate(&mut self, engine: usize) {
        self.epoch[engine] += 1;
    }

    /// Push a fresh entry for `engine`.  Any previous entry for the same
    /// engine must have been invalidated first.
    pub fn push(&mut self, engine: usize, key: f64, fold: u64) {
        let epoch = self.epoch[engine];
        self.heap.push(Entry { key, engine, epoch, fold });
    }

    /// Pop the minimum live `(key, engine, fold)`, skipping stale
    /// entries.  Returns `None` when no live entry remains.
    pub fn pop(&mut self) -> Option<(f64, usize, u64)> {
        while let Some(e) = self.heap.pop() {
            if self.epoch[e.engine] == e.epoch {
                return Some((e.key, e.engine, e.fold));
            }
        }
        None
    }

    pub fn clear(&mut self) {
        self.heap.clear();
        for ep in &mut self.epoch {
            *ep += 1;
        }
    }

    #[cfg(test)]
    fn len_raw(&self) -> usize {
        self.heap.len()
    }
}

/// Lexicographic order on `(key, engine)` event identities.
#[inline]
pub fn key_after(a: (f64, usize), b: (f64, usize)) -> bool {
    match a.0.total_cmp(&b.0) {
        Ordering::Greater => true,
        Ordering::Equal => a.1 > b.1,
        Ordering::Less => false,
    }
}

/// Monotone stack answering "max processed event key since seq S".
///
/// Events are pushed in processing order, which is NOT monotone in
/// `(key, engine)` — an engine idle since early in the run can fire an
/// event below the current high-water mark once re-staged.  The stack
/// keeps only suffix maxima: entries ascend in `seq` and strictly
/// descend in `(key, engine)`, so the bottom entry is the overall
/// maximum and `suffix_max(since)` is the first entry with
/// `seq >= since` (a `partition_point` binary search).
#[derive(Debug, Default)]
pub struct MarkStack {
    /// `(seq, key, engine)`, ascending in seq, strictly descending in
    /// `(key, engine)`.
    stack: Vec<(u64, f64, usize)>,
}

impl MarkStack {
    pub fn new() -> Self {
        MarkStack { stack: Vec::new() }
    }

    /// Record event `(key, engine)` processed at sequence number `seq`.
    /// `seq` must be strictly increasing across calls.
    pub fn push(&mut self, seq: u64, key: f64, engine: usize) {
        debug_assert!(self.stack.last().map_or(true, |&(s, _, _)| s < seq));
        while let Some(&(_, k, e)) = self.stack.last() {
            if key_after((k, e), (key, engine)) {
                break;
            }
            self.stack.pop();
        }
        self.stack.push((seq, key, engine));
    }

    /// Max `(key, engine)` over all events with sequence `>= since`, or
    /// `None` if no such event was recorded.
    pub fn suffix_max(&self, since: u64) -> Option<(f64, usize)> {
        let i = self.stack.partition_point(|&(s, _, _)| s < since);
        self.stack.get(i).map(|&(_, k, e)| (k, e))
    }

    pub fn clear(&mut self) {
        self.stack.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_pops_in_key_then_engine_order() {
        let mut h = EventHeap::new(4);
        h.push(2, 5.0, 1);
        h.push(0, 3.0, 2);
        h.push(3, 3.0, 3);
        h.push(1, 4.0, 4);
        assert_eq!(h.pop(), Some((3.0, 0, 2)));
        assert_eq!(h.pop(), Some((3.0, 3, 3)));
        assert_eq!(h.pop(), Some((4.0, 1, 4)));
        assert_eq!(h.pop(), Some((5.0, 2, 1)));
        assert_eq!(h.pop(), None);
    }

    #[test]
    fn heap_skips_invalidated_entries() {
        let mut h = EventHeap::new(2);
        h.push(0, 1.0, 0);
        h.push(1, 2.0, 0);
        h.invalidate(0);
        h.push(0, 3.0, 7);
        assert_eq!(h.pop(), Some((2.0, 1, 0)));
        assert_eq!(h.pop(), Some((3.0, 0, 7)));
        assert_eq!(h.pop(), None);
        // the stale entry was physically consumed along the way
        assert_eq!(h.len_raw(), 0);
    }

    #[test]
    fn heap_clear_invalidates_everything() {
        let mut h = EventHeap::new(2);
        h.push(0, 1.0, 0);
        h.clear();
        h.push(1, 9.0, 0);
        assert_eq!(h.pop(), Some((9.0, 1, 0)));
        assert_eq!(h.pop(), None);
    }

    #[test]
    fn mark_stack_suffix_max() {
        let mut m = MarkStack::new();
        m.push(0, 10.0, 0);
        m.push(1, 4.0, 1); // dip below the high-water mark
        m.push(2, 4.0, 2); // same key, higher engine: replaces seq 1
        m.push(3, 12.0, 0); // new maximum: collapses everything
        assert_eq!(m.suffix_max(0), Some((12.0, 0)));
        assert_eq!(m.suffix_max(3), Some((12.0, 0)));
        assert_eq!(m.suffix_max(4), None);

        m.push(4, 6.0, 1);
        m.push(5, 5.0, 0);
        // suffix since 4 sees only the dip events
        assert_eq!(m.suffix_max(4), Some((6.0, 1)));
        assert_eq!(m.suffix_max(5), Some((5.0, 0)));
        // suffix since 1 still dominated by the seq-3 maximum
        assert_eq!(m.suffix_max(1), Some((12.0, 0)));
    }

    #[test]
    fn key_after_is_lexicographic() {
        assert!(key_after((2.0, 0), (1.0, 9)));
        assert!(key_after((1.0, 3), (1.0, 2)));
        assert!(!key_after((1.0, 2), (1.0, 2)));
        assert!(!key_after((0.5, 9), (1.0, 0)));
    }
}
