//! SimBackend — the simulator `ScheduleBackend`.
//!
//! Executes the SAME policy decision sequence the live controller
//! executes, against [`SimPool`]'s cost model.  The live mirror is
//! `coordinator::controller`'s `LiveBackend`.
//!
//! Request storage is an arena indexed by rid (sim rids are dense
//! 0..n), so lifecycle transitions are O(1) slot writes instead of
//! B-tree churn; ascending slot scans reproduce the old
//! `BTreeMap`-keyed iteration order exactly.

use super::engine::{stamp_work, SimEngine, SimWork};
use super::pool::{SimCore, SimPool};
use super::{CostModel, SimMode, SimReport, SimRequest};
use crate::metrics::{PredictorScore, Timeline};
use crate::rollout::kv::KvConfig;
use crate::sched::policy::{
    EngineLoad, HarvestAction, HarvestItem, LaneView, SchedView, ScheduleBackend,
};
use crate::sched::{make_predictor, DispatchPolicy, LengthPredictor, PredictorKind};
use crate::trace::{series, SloSummary};
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SimLife {
    Fresh,
    InFlight,
    Ready,
    Consumed,
}

struct SimEntry {
    req: SimRequest,
    /// Preserved progress a resume re-prefills over.
    progress: usize,
    life: SimLife,
    /// Harvested response length (output_len, or clip progress).
    ready_len: usize,
    complete: bool,
    /// Completion-order stamp (what `ready_rids` sorts by).
    seq: u64,
    /// Policy version (update count) when generation started — the sim's
    /// `born_version`.  Resumes keep it; restarts and re-syncs restamp at
    /// the next admit, mirroring the live buffer's born fallback.
    born: usize,
    /// Times this entry was bounced by the staleness cap (first violation
    /// re-syncs, second drops — `consume_bounded`'s verdict ladder).
    resyncs: u32,
}

pub(super) struct SimBackend {
    pub(super) pool: SimPool,
    cost: CostModel,
    pred: Box<dyn LengthPredictor>,
    score: PredictorScore,
    /// Prediction captured at stage time — what actually drove dispatch —
    /// not recomputed after siblings finished.  Arena slot per rid.
    staged_pred: Vec<Option<f64>>,
    /// Workload not yet loaded (grouped loading pops from here).
    backlog: VecDeque<SimRequest>,
    /// Open-loop arrivals not yet released into the backlog: `(t, req)`
    /// non-decreasing in `t`.  Empty in closed-loop runs.
    pending: VecDeque<(f64, SimRequest)>,
    /// Rid-indexed arrival instants (stamped onto `SimWork::ready_at` at
    /// admit time).  Empty in closed-loop runs.
    arrival_t: Vec<f64>,
    /// Rid-indexed arena; `None` = never loaded or retired at a barrier.
    entries: Vec<Option<SimEntry>>,
    /// Rids in training-consumption order — the decision-equivalence
    /// fingerprint the differential tests compare across cores.
    consumed: Vec<u64>,
    q_cap: usize,
    total: usize,
    done: usize,
    // O(1) lifecycle counters (view() runs 2-3x per driver decision; an
    // arena scan there would dominate paper-scale sim host time)
    fresh_count: usize,
    ready_count: usize,
    unconsumed_count: usize,
    seq: u64,
    updates: usize,
    harvests: usize,
    clipped: usize,
    dropped: usize,
    wasted: u64,
    steals: u64,
    migrated_tokens: u64,
    infer_time: f64,
    update_time: f64,
    /// Lanes shed by executed `Decision::Throttle`s.
    throttles: u64,
    /// Async mode: updates overlap decoding instead of serializing.
    overlap_updates: bool,
    /// Engine-clock time at which the (async) trainer frees up.
    update_free_at: f64,
    /// `--staleness` hard cap, enforced at consume time exactly like the
    /// live `RolloutBuffer::consume_bounded`: a sample whose version delta
    /// exceeds the cap is re-synced once, dropped on repeat.  `None`
    /// (default) keeps every pre-cap golden byte-identical.
    pub(super) staleness_cap: Option<u64>,
    /// Size of the tail engine group (top of the index range) when a
    /// `TailPacking` wrapper is active; 0 = no tail rounds.  Only used
    /// for accounting (round counters + head/tail bubble split) — the
    /// policy wrapper owns the actual deferral decisions.
    pub(super) tail_engines: usize,
    /// A tail round is open: a targeted admission landed on the tail
    /// group and the group has not drained back to idle yet.
    tail_round_open: bool,
    tail_rounds: u64,
    tail_admitted: u64,
    /// Applied (not refused) `Decision::Repartition`s.
    repartitions: u64,
    /// Per-sample version deltas of everything actually trained.
    staleness_hist: BTreeMap<u64, u64>,
    /// Deltas from the most recent `train` call, keyed by rid — what
    /// `staleness_of` answers to the tracer.
    last_staleness: BTreeMap<u64, u64>,
    stale_resyncs: u64,
}

impl SimBackend {
    #[allow(clippy::too_many_arguments)]
    pub(super) fn new(workload: &[SimRequest], engines: usize, q_each: usize,
                      cost: CostModel, dispatch: DispatchPolicy,
                      predictor: PredictorKind, overlap_updates: bool,
                      kv: KvConfig, core: SimCore, stride: usize) -> Self {
        let arena = workload.iter().map(|r| r.id + 1).max().unwrap_or(0);
        SimBackend {
            pool: SimPool::new(engines, q_each, cost, dispatch, kv, core, stride),
            cost,
            pred: make_sim_predictor(predictor, workload),
            score: PredictorScore::default(),
            staged_pred: Vec::new(),
            backlog: workload.iter().copied().collect(),
            pending: VecDeque::new(),
            arrival_t: Vec::new(),
            entries: (0..arena).map(|_| None).collect(),
            consumed: Vec::new(),
            q_cap: q_each * engines,
            total: workload.len(),
            done: 0,
            fresh_count: 0,
            ready_count: 0,
            unconsumed_count: 0,
            seq: 0,
            updates: 0,
            harvests: 0,
            clipped: 0,
            dropped: 0,
            wasted: 0,
            steals: 0,
            migrated_tokens: 0,
            infer_time: 0.0,
            update_time: 0.0,
            throttles: 0,
            overlap_updates,
            update_free_at: 0.0,
            staleness_cap: None,
            tail_engines: 0,
            tail_round_open: false,
            tail_rounds: 0,
            tail_admitted: 0,
            repartitions: 0,
            staleness_hist: BTreeMap::new(),
            last_staleness: BTreeMap::new(),
            stale_resyncs: 0,
        }
    }

    /// Open-loop constructor: same machinery as `new`, but the workload
    /// trickles in — requests sit in `pending` until the pool clock
    /// reaches their arrival instant, and admission stamps `ready_at` so
    /// an idle engine can never start a request before it exists.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn with_arrivals(arrivals: &[crate::workload::Arrival], engines: usize,
                                q_each: usize, cost: CostModel, dispatch: DispatchPolicy,
                                predictor: PredictorKind, overlap_updates: bool,
                                kv: KvConfig, core: SimCore, stride: usize) -> Self {
        let reqs: Vec<SimRequest> = arrivals.iter().map(|a| a.req).collect();
        let mut b = Self::new(&reqs, engines, q_each, cost, dispatch, predictor,
                              overlap_updates, kv, core, stride);
        b.backlog.clear();
        b.arrival_t = vec![0.0; b.entries.len()];
        for a in arrivals {
            debug_assert!(b.pending.back().map_or(true, |&(t, _)| t <= a.t),
                          "arrivals must be sorted by time");
            b.arrival_t[a.req.id] = a.t;
            b.pending.push_back((a.t, a.req));
        }
        b
    }

    /// Release every arrival whose instant has passed into the backlog;
    /// if the whole pool is idle with nothing releasable, jump the idle
    /// engines to the next arrival (a genuine pool-wide idle gap) so
    /// `load_prompts` always progresses while arrivals remain.  Policies
    /// refill only once every loaded request is consumed — the pool is
    /// provably idle there — so a busy pool returning 0 new prompts is
    /// never misread as exhaustion.
    fn release_due(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let now = self.pool.observed_clock();
        while let Some(&(t, req)) = self.pending.front() {
            if t > now {
                break;
            }
            self.pending.pop_front();
            self.backlog.push_back(req);
        }
        if self.backlog.is_empty()
            && self.pool.total_running() == 0
            && self.pool.queued() == 0
        {
            if let Some(&(t_next, _)) = self.pending.front() {
                self.pool.advance_idle_to(t_next);
                while let Some(&(t, req)) = self.pending.front() {
                    if t > t_next {
                        break;
                    }
                    self.pending.pop_front();
                    self.backlog.push_back(req);
                }
            }
        }
    }

    /// Reshape the fleet to heterogeneous per-engine specs
    /// (`--engine-spec`): lanes/KV/speed per engine, and the pool lane
    /// cap becomes the spec sum instead of `q_each * engines`.
    pub(super) fn apply_specs(&mut self, specs: &[crate::sched::EngineSpec]) {
        self.pool.apply_specs(specs);
        self.q_cap = specs.iter().map(|s| s.lanes).sum();
    }

    /// Engines in the tail group, clamped like `TailPacking::group` so at
    /// least one head engine remains.
    fn tail_group(&self) -> usize {
        self.tail_engines
            .min(self.pool.engines.len().saturating_sub(1))
    }

    fn in_tail_group(&self, engine: usize) -> bool {
        let t = self.tail_group();
        t > 0 && engine >= self.pool.engines.len() - t
    }

    fn stash_pred(&mut self, id: usize, v: f64) {
        if id >= self.staged_pred.len() {
            self.staged_pred.resize(id + 1, None);
        }
        self.staged_pred[id] = Some(v);
    }

    fn take_pred(&mut self, id: usize) -> Option<f64> {
        self.staged_pred.get_mut(id).and_then(|s| s.take())
    }

    pub(super) fn into_report(self, mode: SimMode) -> SimReport {
        let rollout_time = self.pool.observed_clock();
        let timeline = merge_timelines(&self.pool.engines);
        let bubble = timeline.bubble_ratio(self.q_cap, rollout_time);
        // the admitted-lane headline: max concurrent running lanes across
        // the pool over the whole run.  The merged-event max equals the
        // pool's incrementally tracked peak at stride 1; at coarser
        // strides the dropped-event peak survives in `peak_lanes`.
        let peak_lanes = timeline
            .events()
            .iter()
            .map(|&(_, r)| r)
            .max()
            .unwrap_or(0)
            .max(self.pool.peak_lanes);
        let kv_trace = merge_kv_traces(&self.pool.engines);
        // per-engine idle fraction against the POOL end time: an engine
        // that never ran is 100% idle capacity, not a non-event
        let engine_idle: Vec<f64> = self
            .pool
            .engines
            .iter()
            .map(|e| {
                if e.timeline.events().is_empty() {
                    1.0
                } else {
                    e.timeline.bubble_ratio(e.q, rollout_time)
                }
            })
            .collect();
        // head/tail bubble split: with a tail group configured, report
        // each engine group's bubble against its own configured capacity
        // (both over the pool end time) so tail-round packing shows up as
        // a head-side occupancy gain rather than vanishing into the
        // pool-wide average.  A group that never ran is 100% idle.
        let t = self.tail_group();
        let (head_bubble, tail_bubble) = if t == 0 {
            (bubble, 0.0)
        } else {
            let split = self.pool.engines.len() - t;
            let group_bubble = |engines: &[SimEngine]| {
                let tl = merge_timelines(engines);
                if tl.events().is_empty() {
                    1.0
                } else {
                    tl.bubble_ratio(engines.iter().map(|e| e.q).sum(), rollout_time)
                }
            };
            (
                group_bubble(&self.pool.engines[..split]),
                group_bubble(&self.pool.engines[split..]),
            )
        };
        // useful = tokens of trajectories actually harvested (clipping
        // shortens; restarts and drops waste)
        let useful = self.pool.tokens_out().saturating_sub(self.wasted);
        let total_time = if self.overlap_updates {
            // async: update cost hides under decoding; only the overhang
            // past the rollout end serializes
            rollout_time.max(self.update_free_at) + self.infer_time
        } else {
            rollout_time + self.infer_time + self.update_time
        };
        SimReport {
            mode,
            total_time,
            rollout_time,
            update_time: self.update_time,
            infer_time: self.infer_time,
            useful_tokens: useful,
            wasted_tokens: self.wasted,
            bubble_ratio: bubble,
            throughput: useful as f64 / rollout_time,
            timeline,
            harvests: self.harvests,
            clipped: self.clipped,
            dropped: self.dropped,
            engines: self.pool.engines.len(),
            predictor_mae: self.score.mae(),
            predictor_tau: self.score.kendall_tau(),
            steals: self.steals,
            migrated_tokens: self.migrated_tokens,
            engine_idle,
            peak_lanes,
            kv_sheds: self.pool.engines.iter().map(|e| e.sheds).sum(),
            throttles: self.throttles,
            tail_rounds: self.tail_rounds,
            tail_admitted: self.tail_admitted,
            repartitions: self.repartitions,
            head_bubble,
            tail_bubble,
            kv_trace,
            consumed_rids: self.consumed,
            max_staleness: self.staleness_hist.keys().next_back().copied().unwrap_or(0),
            staleness_hist: self.staleness_hist,
            stale_resyncs: self.stale_resyncs,
            slo: SloSummary::default(),
        }
    }
}

/// Merge per-engine occupancy timelines into one pool timeline whose
/// running count is the sum across engines (tokens and finish counts sum
/// too), so [`Timeline::bubble_ratio`] with the pool's total capacity gives
/// the aggregate bubble.
pub(super) fn merge_timelines(engines: &[SimEngine]) -> Timeline {
    let mut merged = Timeline::new();
    let sources: Vec<&[(f64, usize)]> =
        engines.iter().map(|e| e.timeline.events()).collect();
    for (t, total) in series::merge_running_totals(&sources) {
        merged.set_running(t, total);
    }
    let mut tokens = 0u64;
    let mut finished = 0u64;
    for e in engines {
        // SimEngine counts tokens in its own field — its timeline is
        // never fed add_tokens (unlike the real rollout::Engine)
        tokens += e.tokens_out;
        finished += e.timeline.finished();
    }
    merged.add_tokens(tokens);
    merged.add_finished(finished);
    merged
}

/// Merge per-engine (clock, kv_used) samples into one pool-wide usage
/// curve (running totals over merged event order), downsampled to at most
/// 256 points so `pool_kv.json` stays small at paper scale.
pub(super) fn merge_kv_traces(engines: &[SimEngine]) -> Vec<(f64, usize)> {
    let sources: Vec<&[(f64, usize)]> =
        engines.iter().map(|e| e.kv_trace.as_slice()).collect();
    series::downsample(&series::merge_running_totals(&sources), 256)
}

pub(super) fn make_sim_predictor(kind: PredictorKind,
                                 workload: &[SimRequest]) -> Box<dyn LengthPredictor> {
    let mut pred = make_predictor(kind);
    if kind == PredictorKind::Oracle {
        // the oracle reads true cost: simulator ground truth
        for r in workload {
            pred.observe(r.id as u64, r.prompt_len, r.output_len);
        }
    }
    pred
}

impl ScheduleBackend for SimBackend {
    fn view(&self) -> SchedView {
        SchedView {
            running: self.pool.total_running(),
            queued: self.pool.queued(),
            ready: self.ready_count,
            fresh: self.fresh_count,
            unconsumed: self.unconsumed_count,
            lanes: self.q_cap,
            updates: self.updates,
        }
    }

    fn schedulable(&self) -> Vec<u64> {
        // ascending rid scan == the old BTreeMap key order
        self.entries
            .iter()
            .filter_map(|s| s.as_ref())
            .filter(|e| e.life == SimLife::Fresh)
            .map(|e| e.req.id as u64)
            .collect()
    }

    fn ready_rids(&self) -> Vec<u64> {
        let mut v: Vec<(u64, u64)> = self
            .entries
            .iter()
            .filter_map(|s| s.as_ref())
            .filter(|e| e.life == SimLife::Ready)
            .map(|e| (e.seq, e.req.id as u64))
            .collect();
        v.sort_unstable();
        v.into_iter().map(|(_, rid)| rid).collect()
    }

    fn ready_len(&self, rid: u64) -> usize {
        self.entries
            .get(rid as usize)
            .and_then(|s| s.as_ref())
            .map(|e| e.ready_len)
            .unwrap_or(0)
    }

    fn load_prompts(&mut self, prompts: usize) -> Result<usize> {
        self.release_due();
        let mut count = 0;
        for _ in 0..prompts {
            let Some(req) = self.backlog.pop_front() else { break };
            let idx = req.id;
            if idx >= self.entries.len() {
                self.entries.resize_with(idx + 1, || None);
            }
            self.entries[idx] = Some(SimEntry {
                req,
                progress: 0,
                life: SimLife::Fresh,
                ready_len: 0,
                complete: false,
                seq: 0,
                born: 0,
                resyncs: 0,
            });
            self.fresh_count += 1;
            self.unconsumed_count += 1;
            count += 1;
        }
        Ok(count)
    }

    fn admit(&mut self, rids: &[u64], engine: Option<usize>) -> Result<()> {
        let mut work = Vec::with_capacity(rids.len());
        let rank_only = self.pred.is_rank_only();
        for rid in rids {
            let (req, progress) = {
                let e = self
                    .entries
                    .get_mut(*rid as usize)
                    .and_then(|s| s.as_mut())
                    .expect("admit unknown sim rid");
                assert_eq!(e.life, SimLife::Fresh, "admit non-fresh sim rid {rid}");
                e.life = SimLife::InFlight;
                if e.progress == 0 {
                    // fresh generation starts under the current weights;
                    // resumes keep the version their first token saw
                    e.born = self.updates;
                }
                (e.req, e.progress)
            };
            self.fresh_count -= 1;
            let predicted = self.pred.predict(req.id as u64, req.prompt_len);
            self.stash_pred(req.id, predicted);
            let mut w = stamp_work(rank_only, predicted, req, progress);
            if let Some(&t) = self.arrival_t.get(req.id) {
                w.ready_at = t;
            }
            work.push(w);
        }
        // tail-round accounting: a targeted admission onto the tail
        // group while no round is open IS the round opening (the policy
        // wrapper only ever targets tail engines at round boundaries)
        if let Some(i) = engine {
            if self.in_tail_group(i) && !rids.is_empty() {
                self.tail_admitted += rids.len() as u64;
                if !self.tail_round_open {
                    self.tail_round_open = true;
                    self.tail_rounds += 1;
                }
            }
        }
        match engine {
            Some(i) => self.pool.stage_to(i, work),
            None => self.pool.stage(work, self.pred.as_ref()),
        }
        Ok(())
    }

    fn engine_loads(&self) -> Vec<EngineLoad> {
        self.pool
            .engines
            .iter()
            .map(|e| {
                let used = e.kv_used();
                let blocked = e
                    .queue_front()
                    .is_some_and(|w| e.kv_gate_refuses(used, e.work_estimate(w)));
                EngineLoad {
                    queued: e.queue_len(),
                    active: e.running.len(),
                    lanes: e.q,
                    kv_used: used,
                    kv_budget: e.kv.budget,
                    kv_blocked: blocked,
                    kv_pressure: e.kv.pressure(used, e.running.len()),
                    speed_q8: crate::sched::speed_to_q8(e.speed),
                }
            })
            .collect()
    }

    fn engine_lanes(&self, engine: usize) -> Vec<LaneView> {
        self.pool
            .engines
            .get(engine)
            .map(|e| {
                e.running
                    .iter()
                    .enumerate()
                    .map(|(i, r)| LaneView {
                        lane: i,
                        progress: r.generated,
                        reserve: e.kv.admit_estimate(
                            r.req.prompt_len,
                            r.generated,
                            r.req.output_len,
                            r.predicted,
                        ),
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    fn trace_clock(&self) -> f64 {
        self.pool.observed_clock()
    }

    fn lane_rids(&self, engine: usize) -> Vec<(usize, u64)> {
        self.pool
            .engines
            .get(engine)
            .map(|e| {
                e.running
                    .iter()
                    .enumerate()
                    .map(|(i, r)| (i, r.req.id as u64))
                    .collect()
            })
            .unwrap_or_default()
    }

    fn throttle(&mut self, engine: usize) -> Result<bool> {
        let Some(e) = self.pool.engines.get(engine) else { return Ok(false) };
        if e.running.len() < 2 {
            return Ok(false);
        }
        // shed the lane with the most predicted-remaining work (ties on
        // paged fragmentation), progress kept, routed like a preemption
        // so budget-aware dispatch can re-place it — evicting the
        // longest-to-finish lane frees its reservation for the longest
        // span per eviction
        let lane = e
            .running
            .iter()
            .enumerate()
            .max_by_key(|&(i, r)| {
                (
                    e.kv.victim_key(r.req.prompt_len, r.generated, r.req.output_len, r.predicted),
                    std::cmp::Reverse(i),
                )
            })
            .map(|(i, _)| i)
            .expect("running checked >= 2");
        self.pool.preempt(engine, lane);
        self.throttles += 1;
        Ok(true)
    }

    fn steal(&mut self, from: usize, to: usize, lane: Option<usize>) -> Result<bool> {
        match self.pool.steal(from, to, lane) {
            Some(progress) => {
                self.steals += 1;
                self.migrated_tokens += progress as u64;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    fn repartition(&mut self, engine: usize, lanes: usize, kv: usize) -> Result<bool> {
        let applied = self.pool.repartition(engine, lanes, kv);
        if applied {
            self.repartitions += 1;
        }
        Ok(applied)
    }

    fn predicted_len(&self, rid: u64) -> Option<usize> {
        let e = self.entries.get(rid as usize)?.as_ref()?;
        if e.life != SimLife::Fresh {
            return None;
        }
        crate::rollout::kv::stamp_prediction(
            self.pred.is_rank_only(),
            self.pred.predict(rid, e.req.prompt_len),
        )
    }

    fn step(&mut self) -> Result<usize> {
        let Some(finished) = self.pool.tick() else { return Ok(0) };
        // a tail round closes when the tail group drains back to idle
        if self.tail_round_open {
            let split = self.pool.engines.len() - self.tail_group();
            if self.pool.engines[split..]
                .iter()
                .all(|e| e.running.is_empty() && e.queue_len() == 0)
            {
                self.tail_round_open = false;
            }
        }
        let n = finished.len();
        for r in &finished {
            let predicted = self
                .take_pred(r.id)
                .unwrap_or_else(|| self.pred.predict(r.id as u64, r.prompt_len));
            self.score.push(predicted, r.output_len as f64);
            self.pred.observe(r.id as u64, r.prompt_len, r.output_len);
            let e = self
                .entries
                .get_mut(r.id)
                .and_then(|s| s.as_mut())
                .expect("finished unknown sim rid");
            debug_assert_eq!(e.life, SimLife::InFlight);
            e.life = SimLife::Ready;
            e.ready_len = r.output_len;
            e.complete = true;
            e.seq = self.seq;
            self.ready_count += 1;
            self.seq += 1;
        }
        Ok(n)
    }

    fn harvest_candidates(&mut self) -> Result<Vec<HarvestItem>> {
        let mut terminated = self.pool.terminate_all();
        // harvest is a sync point: engine clocks jump to the pool max
        self.pool.align_clocks();
        // highest progress first — clipping candidates
        terminated.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.id.cmp(&b.0.id)));
        let mut items = Vec::with_capacity(terminated.len());
        for (req, progress, was_queued) in terminated {
            // preemption progress is a length floor the predictor can use
            self.pred.observe_progress(req.id as u64, req.prompt_len, progress);
            self.take_pred(req.id);
            // mirror the live backend's item contract: resumed requests
            // sitting in a queue still carry progress and count as partials
            items.push(HarvestItem {
                rid: req.id as u64,
                progress,
                queued: was_queued && progress == 0,
            });
        }
        Ok(items)
    }

    fn resolve(&mut self, item: &HarvestItem, action: HarvestAction) -> Result<()> {
        let progress = item.progress;
        let e = self
            .entries
            .get_mut(item.rid as usize)
            .and_then(|s| s.as_mut())
            .expect("resolve unknown sim rid");
        debug_assert_eq!(e.life, SimLife::InFlight);
        match action {
            HarvestAction::Clip => {
                e.life = SimLife::Ready;
                e.ready_len = progress;
                e.complete = false;
                e.seq = self.seq;
                self.ready_count += 1;
                self.seq += 1;
                self.clipped += 1;
            }
            HarvestAction::Restart => {
                e.progress = 0;
                e.life = SimLife::Fresh;
                self.fresh_count += 1;
                self.wasted += progress as u64;
            }
            HarvestAction::Resume | HarvestAction::Requeue => {
                e.progress = progress;
                e.life = SimLife::Fresh;
                self.fresh_count += 1;
            }
            HarvestAction::Drop => {
                e.life = SimLife::Consumed;
                self.unconsumed_count -= 1;
                self.wasted += progress as u64;
                self.dropped += 1;
                self.done += 1;
            }
        }
        Ok(())
    }

    fn preempt(&mut self, engine: usize, lane: usize) -> Result<()> {
        self.pool.preempt(engine, lane);
        Ok(())
    }

    fn train(&mut self, rids: &[u64]) -> Result<()> {
        // v_enter: updates completed before this one — the same convention
        // `crate::rl::staleness` documents for the live trainer
        let v_enter = self.updates as u64;
        self.last_staleness.clear();
        let mut toks = 0.0f64;
        for rid in rids {
            let e = self
                .entries
                .get_mut(*rid as usize)
                .and_then(|s| s.as_mut())
                .expect("train unknown sim rid");
            assert_eq!(e.life, SimLife::Ready, "train non-ready sim rid {rid}");
            // natural completions train at their true length; only clips
            // (complete == false) may be shorter
            debug_assert!(!e.complete || e.ready_len == e.req.output_len);
            let st = crate::rl::staleness(v_enter, e.born as u64);
            if self.staleness_cap.is_some_and(|cap| st > cap) {
                // consume-time cap, mirroring the live buffer's
                // `consume_bounded`: first violation re-syncs (the sample
                // regenerates under current weights), a repeat drops it
                self.ready_count -= 1;
                self.wasted += e.ready_len as u64;
                if e.resyncs == 0 {
                    e.resyncs = 1;
                    e.progress = 0;
                    e.ready_len = 0;
                    e.complete = false;
                    e.life = SimLife::Fresh;
                    self.fresh_count += 1;
                    self.stale_resyncs += 1;
                } else {
                    e.life = SimLife::Consumed;
                    self.unconsumed_count -= 1;
                    self.dropped += 1;
                    self.done += 1;
                }
                continue;
            }
            *self.staleness_hist.entry(st).or_insert(0) += 1;
            self.last_staleness.insert(*rid, st);
            e.life = SimLife::Consumed;
            toks += (e.req.prompt_len + e.ready_len) as f64;
            self.ready_count -= 1;
            self.unconsumed_count -= 1;
            self.done += 1;
            self.consumed.push(*rid);
        }
        self.infer_time += toks * self.cost.t_infer_token;
        let update_cost = toks * self.cost.t_update_token;
        self.update_time += update_cost;
        if self.overlap_updates {
            let start = self.update_free_at.max(self.pool.observed_clock());
            self.update_free_at = start + update_cost;
        }
        self.harvests += 1;
        self.updates += 1;
        Ok(())
    }

    fn staleness_of(&self, rid: u64) -> Option<u64> {
        self.last_staleness.get(&rid).copied()
    }

    fn barrier(&mut self) -> Result<()> {
        // group-end sync barrier
        self.pool.align_clocks();
        for slot in self.entries.iter_mut() {
            if slot.as_ref().is_some_and(|e| e.life == SimLife::Consumed) {
                *slot = None;
            }
        }
        Ok(())
    }

    fn exhausted(&self) -> bool {
        self.done >= self.total
    }
}
