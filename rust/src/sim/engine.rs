//! The simulated serving engine: lanes, local queue, KV accounting, and
//! the fused-span arithmetic the event core folds silent decode spans
//! with.
//!
//! All KV-derived views are incremental: `kv_used` and the queued
//! admission-estimate sum are maintained as deltas at every mutation and
//! cross-checked against the O(lanes) recompute under `debug_assert!`
//! (double-entry bookkeeping — release builds pay O(1), debug builds
//! verify every read).  Queue mutations therefore go through the
//! `enqueue_back`/`dequeue_back`/`drain_queue` methods; the raw deque is
//! private so pool code cannot bypass the cache.

use super::{CostModel, SimRequest};
use crate::metrics::Timeline;
use crate::rollout::kv::{KvConfig, KvMode};
use std::collections::VecDeque;

pub(crate) struct Running {
    pub(crate) req: SimRequest,
    pub(crate) generated: usize,
    /// Predicted total length stamped at stage time (None = rank-only
    /// predictor) — what the paged admission estimate consumed, kept so
    /// an evicted lane re-admits under the same estimate.
    pub(crate) predicted: Option<usize>,
}

/// One unit of stageable work: a request plus preserved progress and the
/// stamped length prediction driving paged-KV admission estimates.
#[derive(Debug, Clone, Copy)]
pub(crate) struct SimWork {
    pub(crate) req: SimRequest,
    pub(crate) progress: usize,
    pub(crate) predicted: Option<usize>,
    /// Open-loop arrival time: admission may not start before this
    /// simulated instant (an idle engine's clock is bumped up to it).
    /// 0.0 for closed-loop work — a bitwise no-op on every batch path.
    pub(crate) ready_at: f64,
}

/// Stamp a raw prediction onto staged work via the shared
/// [`crate::rollout::kv::stamp_prediction`] rule (None for rank-only
/// predictors — bucket indices are not token counts and must not feed KV
/// estimates).
pub(crate) fn stamp_work(rank_only: bool, predicted: f64, req: SimRequest,
                         progress: usize) -> SimWork {
    SimWork {
        req,
        progress,
        predicted: crate::rollout::kv::stamp_prediction(rank_only, predicted),
        ready_at: 0.0,
    }
}

/// Simulated engine with queue capacity `q`.
pub(crate) struct SimEngine {
    pub(crate) q: usize,
    pub(crate) cost: CostModel,
    /// KV memory model (mode + budget + page; `budget == usize::MAX` =
    /// accounting off).
    pub(crate) kv: KvConfig,
    /// Relative decode speed (`--engine-spec`; 1.0 = the homogeneous
    /// default).  Every time cost divides by it — division by 1.0 is
    /// bitwise exact in IEEE, so homogeneous fleets keep their pinned
    /// clocks, and power-of-two speeds keep the Event≡Reference
    /// differential exact on heterogeneous ones.
    pub(crate) speed: f64,
    pub(crate) clock: f64,
    pub(crate) running: Vec<Running>,
    queue: VecDeque<SimWork>,
    pub(crate) timeline: Timeline,
    pub(crate) tokens_out: u64,
    /// Forced paged evictions (actual usage outgrew the budget mid-step).
    pub(crate) sheds: u64,
    /// (clock, kv_used) samples — recorded only when accounting is on,
    /// deduplicated on change, then stride-downsampled at record time.
    pub(crate) kv_trace: Vec<(f64, usize)>,
    /// Incremental Σ lane_charge over running lanes (double-entry twin of
    /// the O(lanes) recompute `kv_used` cross-checks in debug builds).
    kv_used_cache: usize,
    /// Incremental Σ work_estimate over the local queue.
    queue_est_sum: usize,
    /// Last observed kv usage + change counter for stride downsampling.
    last_kv: Option<usize>,
    kv_changes: usize,
    stride: usize,
}

impl SimEngine {
    pub(crate) fn new(q: usize, cost: CostModel, kv: KvConfig, stride: usize) -> Self {
        let mut timeline = Timeline::new();
        timeline.set_stride(stride);
        SimEngine {
            q,
            cost,
            kv,
            speed: 1.0,
            clock: 0.0,
            running: Vec::new(),
            queue: VecDeque::new(),
            timeline,
            tokens_out: 0,
            sheds: 0,
            kv_trace: Vec::new(),
            kv_used_cache: 0,
            queue_est_sum: 0,
            last_kv: None,
            kv_changes: 0,
            stride: stride.max(1),
        }
    }

    pub(crate) fn record(&mut self) {
        self.timeline.set_running(self.clock, self.running.len());
        if !self.kv.unlimited() {
            let used = self.kv_used();
            // dedup-on-change: silent decode spans cannot move kv usage
            // between decision points, so recording only changes keeps the
            // trace identical across both cores AND bounded at scale
            if self.last_kv != Some(used) {
                self.last_kv = Some(used);
                if self.kv_changes % self.stride == 0 {
                    self.kv_trace.push((self.clock, used));
                }
                self.kv_changes += 1;
            }
        }
    }

    /// What a running lane charges right now (worst case in reserve mode,
    /// the paged actual context otherwise).
    pub(crate) fn lane_charge(&self, r: &Running) -> usize {
        self.kv.lane_charge(r.req.prompt_len, r.generated, r.req.output_len)
    }

    /// What the admission gate charges a queued candidate.
    pub(crate) fn work_estimate(&self, w: &SimWork) -> usize {
        self.kv
            .admit_estimate(w.req.prompt_len, w.progress, w.req.output_len, w.predicted)
    }

    /// Incremental Σ lane_charge, cross-checked against the O(lanes)
    /// recompute in debug builds (the double-entry contract).
    pub(crate) fn kv_used(&self) -> usize {
        debug_assert_eq!(
            self.kv_used_cache,
            self.running.iter().map(|r| self.lane_charge(r)).sum::<usize>(),
            "kv_used double-entry drift"
        );
        self.kv_used_cache
    }

    /// Incremental Σ admission estimate over the local queue (what refill
    /// counts as already committed), same double-entry contract.
    pub(crate) fn queue_committed(&self) -> usize {
        debug_assert_eq!(
            self.queue_est_sum,
            self.queue.iter().map(|w| self.work_estimate(w)).sum::<usize>(),
            "queue_committed double-entry drift"
        );
        self.queue_est_sum
    }

    // ---- queue access (mutations maintain queue_est_sum) ----

    pub(crate) fn enqueue_back(&mut self, w: SimWork) {
        self.queue_est_sum += self.work_estimate(&w);
        self.queue.push_back(w);
    }

    pub(crate) fn dequeue_back(&mut self) -> Option<SimWork> {
        let w = self.queue.pop_back()?;
        self.queue_est_sum -= self.work_estimate(&w);
        Some(w)
    }

    pub(crate) fn queue_front(&self) -> Option<&SimWork> {
        self.queue.front()
    }

    pub(crate) fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn drain_queue(&mut self) -> Vec<SimWork> {
        self.queue_est_sum = 0;
        self.queue.drain(..).collect()
    }

    /// The KV admission gate shared by `admit`, `engine_loads`, and the
    /// pool's `steal`: admitting `estimate` on top of `used` is refused
    /// iff running lanes already hold KV and the sum overruns the budget
    /// (the empty-engine escape admits any head request alone).
    pub(crate) fn kv_gate_refuses(&self, used: usize, estimate: usize) -> bool {
        self.kv.gate_refuses(used, estimate)
    }

    pub(crate) fn admit(&mut self) {
        let mut used = self.kv_used();
        while self.running.len() < self.q {
            let Some(front) = self.queue.front() else { break };
            // KV admission gate: an otherwise-empty engine always admits
            // its head request (progress guarantee — a single oversized
            // context must not deadlock the queue).  The gate accumulates
            // admission ESTIMATES within the pass; paged lanes charge
            // their much smaller actual context once admitted.
            let est = self.work_estimate(front);
            if self.kv_gate_refuses(used, est) {
                break;
            }
            let w = self.queue.pop_front().unwrap();
            self.queue_est_sum -= est;
            used += est;
            // open-loop: an idle engine cannot start prefill before the
            // request exists — wait (idle) until the arrival instant
            if w.ready_at > self.clock {
                self.clock = w.ready_at;
            }
            // prefill cost: prompt + any preserved progress, scaled by
            // the engine's relative speed
            self.clock +=
                (w.req.prompt_len + w.progress) as f64 * self.cost.t_prefill_token / self.speed;
            self.kv_used_cache +=
                self.kv.lane_charge(w.req.prompt_len, w.progress, w.req.output_len);
            self.running
                .push(Running { req: w.req, generated: w.progress, predicted: w.predicted });
        }
        self.record();
    }

    /// Cost of one decode iteration at the CURRENT occupancy — the grid
    /// pitch fused spans multiply against — scaled by the engine's
    /// relative speed.
    pub(crate) fn iter_cost(&self) -> f64 {
        (self.cost.t_weights + self.running.len() as f64 * self.cost.t_token) / self.speed
    }

    /// One decode iteration; returns finished requests.
    pub(crate) fn step(&mut self) -> Vec<SimRequest> {
        let r = self.running.len();
        if r == 0 {
            return Vec::new();
        }
        self.clock += self.iter_cost();
        self.tokens_out += r as u64;
        let kv = self.kv;
        let mut finished = Vec::new();
        let mut kv_delta = 0isize;
        self.running.retain_mut(|run| {
            let pre = kv.lane_charge(run.req.prompt_len, run.generated, run.req.output_len);
            run.generated += 1;
            if run.generated >= run.req.output_len {
                finished.push(run.req);
                kv_delta -= pre as isize;
                false
            } else {
                let post =
                    kv.lane_charge(run.req.prompt_len, run.generated, run.req.output_len);
                kv_delta += post as isize - pre as isize;
                true
            }
        });
        self.kv_used_cache = (self.kv_used_cache as isize + kv_delta) as usize;
        if !finished.is_empty() {
            self.timeline.add_finished(finished.len() as u64);
        }
        self.shed_over_budget();
        self.record();
        finished
    }

    /// Fold `k` silent decode iterations into one clock/token/KV delta.
    /// The caller (the event core) guarantees no lane finishes, no page
    /// boundary is crossed in limited paged mode, and no decision point
    /// falls inside the span — so no timeline event, finish, or shed can
    /// be skipped.
    pub(crate) fn fold_silent(&mut self, k: u64) {
        if k == 0 {
            return;
        }
        let r = self.running.len();
        debug_assert!(r > 0, "fold_silent on an idle engine");
        self.clock += k as f64 * self.iter_cost();
        self.tokens_out += k * r as u64;
        let kv = self.kv;
        let limited = !kv.unlimited();
        for run in &mut self.running {
            debug_assert!(
                run.generated + (k as usize) < run.req.output_len,
                "fused span swallowed a lane finish"
            );
            let pre = kv.lane_charge(run.req.prompt_len, run.generated, run.req.output_len);
            run.generated += k as usize;
            let post = kv.lane_charge(run.req.prompt_len, run.generated, run.req.output_len);
            // limited paged mode schedules a page-crossing event instead
            // of folding across it (the shed check must run there)
            debug_assert!(!limited || pre == post, "fused span crossed a page boundary");
            self.kv_used_cache = self.kv_used_cache - pre + post;
        }
    }

    /// Iterations from the CURRENT stored state until this engine's next
    /// intrinsic decision point: the earliest lane finish, min'd in
    /// limited paged mode with the first page-boundary crossing of any
    /// lane charge (where the in-step shed check can first change its
    /// answer).  Always >= 1; the event core folds `span - 1` iterations
    /// silently and runs the span-th as a real micro-tick.
    pub(crate) fn silent_span(&self) -> u64 {
        debug_assert!(!self.running.is_empty(), "span of an idle engine");
        let mut s = self
            .running
            .iter()
            .map(|r| r.req.output_len.saturating_sub(r.generated).max(1) as u64)
            .min()
            .expect("running checked non-empty");
        if self.kv.mode == KvMode::Paged && !self.kv.unlimited() {
            let page = self.kv.page.max(1);
            for r in &self.running {
                let held = r.req.prompt_len + r.generated;
                let rem = held % page;
                let jc = if rem == 0 { 1 } else { (page - rem + 1) as u64 };
                s = s.min(jc);
            }
        }
        s.max(1)
    }

    /// Forced paged backpressure: if actual usage outgrew the budget
    /// (admission estimates undershot), evict the lane with the most
    /// predicted REMAINING work (per-page fragmentation as tiebreak —
    /// `rollout::kv::victim_key`) back to the local queue — progress
    /// kept, resume pays a re-prefill — until the budget holds or one
    /// lane remains (the running twin of the empty-engine admission
    /// escape).  Evicting the longest-remaining lane frees its KV for the
    /// longest stretch and hands exactly the request tail rounds absorb;
    /// the back of the queue makes the evicted partial the preferred
    /// steal victim for a KV-rich peer.
    pub(crate) fn shed_over_budget(&mut self) {
        if self.kv.mode != KvMode::Paged || self.kv.unlimited() {
            return;
        }
        while self.running.len() > 1 && self.kv_used() > self.kv.budget {
            let lane = self
                .running
                .iter()
                .enumerate()
                .max_by_key(|&(i, r)| {
                    (
                        self.kv.victim_key(r.req.prompt_len, r.generated,
                                           r.req.output_len, r.predicted),
                        std::cmp::Reverse(i),
                    )
                })
                .map(|(i, _)| i)
                .expect("running checked non-empty");
            let r = self.running.remove(lane);
            self.kv_used_cache -= self.kv.lane_charge(r.req.prompt_len, r.generated,
                                                      r.req.output_len);
            self.enqueue_back(SimWork {
                req: r.req,
                progress: r.generated,
                predicted: r.predicted,
                ready_at: 0.0,
            });
            self.sheds += 1;
        }
    }

    /// Preempt ONE running lane back to the queue, KEEPING progress
    /// (resume costs only a re-prefill over prompt + prefix).
    pub(crate) fn preempt_lane(&mut self, lane: usize) -> Option<SimWork> {
        if lane >= self.running.len() {
            return None;
        }
        let r = self.running.remove(lane);
        self.kv_used_cache -=
            self.kv.lane_charge(r.req.prompt_len, r.generated, r.req.output_len);
        self.record();
        Some(SimWork { req: r.req, progress: r.generated, predicted: r.predicted, ready_at: 0.0 })
    }

    /// Terminate everything in flight; returns (request, progress, queued)
    /// triples — `queued` marks requests drained from the waiting queue
    /// rather than preempted out of a lane.
    pub(crate) fn terminate_all(&mut self) -> Vec<(SimRequest, usize, bool)> {
        let mut out: Vec<(SimRequest, usize, bool)> = self
            .running
            .drain(..)
            .map(|r| (r.req, r.generated, false))
            .collect();
        self.kv_used_cache = 0;
        out.extend(self.drain_queue().into_iter().map(|w| (w.req, w.progress, true)));
        self.record();
        out
    }
}
