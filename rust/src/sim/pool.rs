//! The engine pool with two interchangeable stepping cores.
//!
//! * [`SimCore::Reference`] is the original tick stepper, kept verbatim as
//!   the debug/differential oracle: linearly scan every engine for the
//!   minimum clock, advance it one decode iteration per call.  O(engines)
//!   per token.
//! * [`SimCore::Event`] (the default) is the discrete-event core: a
//!   binary heap orders per-engine decision points by `(time, engine)`,
//!   and each pop folds the engine's whole silent decode span — `k`
//!   iterations collapse into one clock/token/KV delta — before running
//!   ONE reference micro-tick (refill, admit, step) at the decision
//!   point.  O(log engines + lanes) per decision, independent of span
//!   length.
//!
//! Decision-point taxonomy (what terminates a fused span):
//!   1. earliest lane finish (frees a lane, may unblock admission);
//!   2. admission opportunity — local queue head or central head passes
//!      capacity + KV gate *right now* (piecewise-constant between
//!      events, so checking at push time is sound);
//!   3. page-boundary crossing of any lane charge in limited paged mode
//!      (the in-step shed check can first change its answer there);
//!   4. idle engine with staged work (refill/admit always progresses via
//!      the empty-engine gate escape).
//! External mutations (stage, preempt, steal, harvest, barrier) are not
//! spanned — they materialize affected engines and reschedule.
//!
//! Equivalence invariant: processing events in `(key, engine)` order
//! reproduces the reference scan's "first minimal index wins" pick order
//! exactly, and `key = clock + fold * iter_cost` is the same float
//! expression `fold_silent` advances the clock with, so clocks agree
//! bit-for-bit whenever the cost model is exactly representable (the
//! differential tests pin this with dyadic costs).
//!
//! Materialization: an engine's *stored* state lags the virtual time the
//! reference core would have reached.  `mat_fold(j)` computes how many
//! silent iterations are provably in the reference core's past: the first
//! grid point `(clock + k*iter, j)` lexicographically after the maximum
//! processed event key since `j`'s last state change ([`MarkStack`]
//! suffix max over `touched[j]`).  A plain high-water mark would
//! over-fold engines around stale-clock dips (an idle engine re-staged
//! below the pool max); the per-engine suffix handles those exactly.

use super::engine::{SimEngine, SimWork};
use super::heap::{key_after, EventHeap, MarkStack};
use super::{CostModel, SimRequest};
use crate::rollout::kv::KvConfig;
use crate::sched::{sjf_priority, DispatchPolicy, LengthPredictor};
use std::collections::VecDeque;

/// Which stepping core [`SimPool`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimCore {
    /// Event-heap core: O(log n) scheduling ops, fused decode spans.
    #[default]
    Event,
    /// The original linear-scan tick stepper — one decode iteration per
    /// call.  Kept as the differential oracle and for per-iteration
    /// observers (an enabled tracer forces this core).
    Reference,
}

impl SimCore {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "event" | "heap" => Self::Event,
            "reference" | "ref" | "tick" => Self::Reference,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Event => "event",
            Self::Reference => "reference",
        }
    }
}

/// One pool-level open-loop arrival: work that becomes dispatchable at
/// simulated time `t`.  `key` is the SJF dispatch priority, precomputed
/// at push time so delivery is a pure binary insert.
pub(crate) struct PoolArrival {
    pub(crate) t: f64,
    pub(crate) key: f64,
    pub(crate) work: SimWork,
}

/// Engine pool over [`SimEngine`]s: a central queue (or static stripes for
/// round-robin) plus event-driven stepping — always advance the
/// earliest-clock engine with work, so engine clocks stay within one
/// decode iteration of each other (parallel devices).
///
/// Open-loop arrivals (§Workload) are one extra key class on the same
/// heap: pseudo-engine index `n = engines.len()` holds the head arrival's
/// timestamp, so engines win ties and an arrival delivers exactly when
/// every pending decision point lies strictly later — the event-core twin
/// of the reference rule "deliver iff `t < min stored clock` over engines
/// with work".
pub(crate) struct SimPool {
    pub(crate) engines: Vec<SimEngine>,
    pub(crate) central: VecDeque<SimWork>,
    pub(crate) policy: DispatchPolicy,
    rr: usize,
    core: SimCore,
    // ---- event-core machinery (inert under the reference core) ----
    heap: EventHeap,
    marks: MarkStack,
    /// Pool event-seq at each engine's last state change/materialization.
    touched: Vec<u64>,
    seq: u64,
    // ---- incremental pool-level views (both cores) ----
    /// Per-engine (running, queued) as of the last `sync`.
    counts: Vec<(usize, usize)>,
    running_total: usize,
    queued_local: usize,
    /// Highest concurrent running-lane total observed at any sync point
    /// (exact even when timeline striding drops merged events).
    pub(crate) peak_lanes: usize,
    // ---- open-loop arrival machinery (inert in closed-loop runs) ----
    /// Pending arrivals, non-decreasing in `t`; head rides the heap at
    /// pseudo-engine index `engines.len()`.
    arrivals: VecDeque<PoolArrival>,
    /// SJF dispatch keys parallel to `central`, maintained only in
    /// arrival mode (stage-time sorting has no keys to keep).
    central_keys: VecDeque<f64>,
    arrival_mode: bool,
}

impl SimPool {
    pub(crate) fn new(n: usize, q_each: usize, cost: CostModel, policy: DispatchPolicy,
                      kv: KvConfig, core: SimCore, stride: usize) -> Self {
        SimPool {
            engines: (0..n).map(|_| SimEngine::new(q_each, cost, kv, stride)).collect(),
            central: VecDeque::new(),
            policy,
            rr: 0,
            core,
            // slot n is the arrival pseudo-engine (head arrival timestamp)
            heap: EventHeap::new(n + 1),
            marks: MarkStack::new(),
            touched: vec![0; n],
            seq: 0,
            counts: vec![(0, 0); n],
            running_total: 0,
            queued_local: 0,
            peak_lanes: 0,
            arrivals: VecDeque::new(),
            central_keys: VecDeque::new(),
            arrival_mode: false,
        }
    }

    /// Refresh engine `i`'s cached (running, queued) contribution.  Called
    /// after every mutation of an engine, including separately after the
    /// refill/admit/step phases of a tick so admission-time occupancy
    /// peaks are captured.
    fn sync(&mut self, i: usize) {
        let e = &self.engines[i];
        let (r, q) = (e.running.len(), e.queue_len());
        let (pr, pq) = self.counts[i];
        self.running_total = self.running_total - pr + r;
        self.queued_local = self.queued_local - pq + q;
        self.counts[i] = (r, q);
        if self.running_total > self.peak_lanes {
            self.peak_lanes = self.running_total;
        }
    }

    fn sync_all(&mut self) {
        for i in 0..self.engines.len() {
            self.sync(i);
        }
    }

    /// Shape the fleet per heterogeneous engine specs (`--engine-spec`):
    /// per-engine lanes, KV budget and relative speed.  Call before any
    /// work is staged — shapes are construction-time here; RUNTIME
    /// resizing goes through [`SimPool::repartition`].
    pub(crate) fn apply_specs(&mut self, specs: &[crate::sched::EngineSpec]) {
        assert_eq!(specs.len(), self.engines.len(), "one spec per engine");
        for (e, s) in self.engines.iter_mut().zip(specs) {
            e.q = s.lanes;
            e.kv.budget = s.kv_budget;
            e.speed = s.speed;
        }
    }

    /// Elastically resize one engine (tail-round boundaries):
    /// transactional — the new shape is applied whole, or refused when it
    /// would strand running lanes (`lanes < running`) or drop the budget
    /// below committed usage while more than one lane runs (the
    /// single-lane escape mirrors the admission gate's).  Usage that
    /// later outgrows a shrunken budget is handled by the engines' normal
    /// in-step shed path.
    pub(crate) fn repartition(&mut self, engine: usize, lanes: usize, kv: usize) -> bool {
        if engine >= self.engines.len() || lanes == 0 {
            return false;
        }
        // commit the virtual span first: the verdict must read the state
        // the reference core would hold at this decision point
        self.materialize(engine);
        let running = self.engines[engine].running.len();
        let used = self.engines[engine].kv_used();
        let applied = lanes >= running && (kv >= used || running <= 1);
        if applied {
            let e = &mut self.engines[engine];
            e.q = lanes;
            e.kv.budget = kv;
        }
        self.sync(engine);
        if self.core == SimCore::Event {
            // lane/budget changes flip admission and refill gates
            // pool-wide (central-head readers included), and the
            // materialize above invalidated this engine's entry even on
            // a refusal
            self.reschedule_all();
        }
        applied
    }

    /// Targeted admission: push work straight onto engine `i`'s local
    /// queue, bypassing the dispatch policy (`Admit { engine: Some(i) }`).
    pub(crate) fn stage_to(&mut self, i: usize, work: Vec<SimWork>) {
        assert!(i < self.engines.len(), "stage_to engine out of range");
        for w in work {
            self.engines[i].enqueue_back(w);
        }
        self.sync(i);
        self.reschedule(i);
    }

    /// Stage a wave of work per the dispatch policy.  Round-robin
    /// statically stripes (the FCFS baseline); least-loaded keeps a FIFO
    /// central queue that engines pull from as lanes free; SJF keeps the
    /// central queue sorted by predicted remaining length so each engine
    /// pulls a contiguous, similar-length run.
    pub(crate) fn stage(&mut self, work: Vec<SimWork>, pred: &dyn LengthPredictor) {
        // pool-level arrival runs are pure dispatch waves: they never mix
        // with stage(), which would break the sorted central_keys mirror
        debug_assert!(
            !self.arrival_mode || self.policy != DispatchPolicy::ShortestPredictedFirst,
            "stage() is not supported in SJF arrival mode"
        );
        match self.policy {
            DispatchPolicy::RoundRobin => {
                for w in work {
                    let i = self.rr % self.engines.len();
                    self.rr += 1;
                    self.engines[i].enqueue_back(w);
                }
            }
            DispatchPolicy::LeastLoaded => self.central.extend(work),
            DispatchPolicy::ShortestPredictedFirst => {
                // sjf_priority is THE policy shared with the real
                // EnginePool; keys computed once, not in the comparator
                let mut keyed: Vec<(f64, SimWork)> = work
                    .into_iter()
                    .map(|w| {
                        (sjf_priority(pred, w.req.id as u64, w.req.prompt_len, w.progress), w)
                    })
                    .collect();
                keyed.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0).unwrap().then(a.1.req.id.cmp(&b.1.req.id))
                });
                self.central.extend(keyed.into_iter().map(|(_, w)| w));
            }
        }
        self.sync_all();
        if self.core == SimCore::Event {
            self.reschedule_all();
        }
    }

    /// Pull central-queue work into engine `i`'s free lanes (late
    /// binding), KV-budget-aware: stop once the head's admission estimate
    /// no longer fits what the engine is already committed to (actual
    /// lane charges plus queued estimates) — route around KV-tight
    /// engines instead of queueing work behind a gate that will refuse
    /// it.  A fully empty engine always pulls (the dispatch twin of the
    /// empty-engine admission escape); unlimited budgets never refuse, so
    /// KV-oblivious runs pull exactly as before.  Returns the pull count
    /// so the event core knows the central head changed.
    fn refill(&mut self, i: usize) -> usize {
        if self.policy == DispatchPolicy::RoundRobin {
            return 0;
        }
        let kv = self.engines[i].kv;
        let mut committed = self.engines[i].kv_used() + self.engines[i].queue_committed();
        let mut pulled = 0;
        loop {
            let e = &self.engines[i];
            if e.running.len() + e.queue_len() >= e.q {
                break;
            }
            let Some(front) = self.central.front() else { break };
            let est = e.work_estimate(front);
            if kv.gate_refuses(committed, est) {
                break;
            }
            committed = committed.saturating_add(est);
            let w = self.central.pop_front().unwrap();
            if self.arrival_mode && self.policy == DispatchPolicy::ShortestPredictedFirst {
                self.central_keys.pop_front();
            }
            self.engines[i].enqueue_back(w);
            pulled += 1;
        }
        pulled
    }

    // ---- open-loop arrivals ----

    /// Install an open-loop arrival stream (non-decreasing `t`).  The
    /// head arrival rides the event heap at pseudo-engine index
    /// `engines.len()`; delivery happens through `tick` in both cores.
    pub(crate) fn push_arrivals(&mut self, arrivals: Vec<PoolArrival>) {
        debug_assert!(
            arrivals.windows(2).all(|w| w[0].t <= w[1].t),
            "arrivals must be sorted by time"
        );
        self.arrival_mode = true;
        self.arrivals = arrivals.into();
        if self.core == SimCore::Event {
            self.reschedule_arrival();
        }
    }

    pub(crate) fn arrivals_pending(&self) -> usize {
        self.arrivals.len()
    }

    /// Refresh the arrival pseudo-engine's heap entry (head timestamp).
    fn reschedule_arrival(&mut self) {
        let n = self.engines.len();
        self.heap.invalidate(n);
        if let Some(a) = self.arrivals.front() {
            self.heap.push(n, a.t, 0);
        }
    }

    /// Dispatch one arrival per the pool policy.  RR stripes; LeastLoaded
    /// appends to the FIFO central queue; SJF binary-inserts by the
    /// precomputed priority key (after equal keys — earlier arrivals of
    /// the same predicted length keep FIFO order among themselves).
    fn deliver(&mut self, a: PoolArrival) {
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let i = self.rr % self.engines.len();
                self.rr += 1;
                self.engines[i].enqueue_back(a.work);
                self.sync(i);
                if self.core == SimCore::Event {
                    self.reschedule(i);
                }
            }
            DispatchPolicy::LeastLoaded => {
                let was_empty = self.central.is_empty();
                self.central.push_back(a.work);
                // only the empty→non-empty transition can flip a spare-
                // capacity engine's has_work/admission verdict (unlimited
                // gates never refuse; finite budgets see a new head only
                // if there was none)
                if self.core == SimCore::Event && was_empty {
                    self.reschedule_capacity();
                }
            }
            DispatchPolicy::ShortestPredictedFirst => {
                let was_empty = self.central.is_empty();
                let pos = self.central_keys.partition_point(|&k| k <= a.key);
                self.central_keys.insert(pos, a.key);
                self.central.insert(pos, a.work);
                // a new head changes what every spare-capacity engine
                // would pull next; deeper inserts change nothing gated on
                if self.core == SimCore::Event && (was_empty || pos == 0) {
                    self.reschedule_capacity();
                }
            }
        }
    }

    /// Jump every idle engine's clock forward to `t` (pool-wide idle gap:
    /// the next arrival lies beyond every stored clock).  Only legal when
    /// no engine has work.
    pub(crate) fn advance_idle_to(&mut self, t: f64) {
        debug_assert!(
            (0..self.engines.len()).all(|i| !self.has_work(i)),
            "advance_idle_to with work pending"
        );
        for e in self.engines.iter_mut() {
            if e.clock < t {
                e.clock = t;
            }
        }
        if self.core == SimCore::Event {
            self.reschedule_all();
        }
    }

    pub(crate) fn has_work(&self, i: usize) -> bool {
        let e = &self.engines[i];
        !e.running.is_empty()
            || e.queue_len() > 0
            || (self.policy != DispatchPolicy::RoundRobin && !self.central.is_empty())
    }

    pub(crate) fn total_running(&self) -> usize {
        debug_assert_eq!(
            self.running_total,
            self.engines.iter().map(|e| e.running.len()).sum::<usize>(),
            "running_total drift"
        );
        self.running_total
    }

    pub(crate) fn queued(&self) -> usize {
        debug_assert_eq!(
            self.queued_local,
            self.engines.iter().map(|e| e.queue_len()).sum::<usize>(),
            "queued_local drift"
        );
        self.central.len() + self.queued_local
    }

    /// Advance the pool by one decision: the earliest-clock engine with
    /// work runs one refill + admit + decode iteration (with any silent
    /// span folded first under the event core); returns its finishes, or
    /// None when the pool is drained.
    pub(crate) fn tick(&mut self) -> Option<Vec<SimRequest>> {
        match self.core {
            SimCore::Event => self.tick_event(),
            SimCore::Reference => self.tick_reference(),
        }
    }

    /// The original stepper, verbatim: linear min-clock scan, one decode
    /// iteration per call.  First minimal index wins — the order the
    /// event heap's `(key, engine)` tiebreak reproduces.
    fn tick_reference(&mut self) -> Option<Vec<SimRequest>> {
        // open-loop: the head arrival delivers iff it precedes every
        // pending decision point — STRICTLY before the min stored clock
        // over engines with work (ties go to engines, matching the event
        // heap's `(key, engine)` order where index n loses every tie)
        if let Some(t) = self.arrivals.front().map(|a| a.t) {
            let min_clock = (0..self.engines.len())
                .filter(|&i| self.has_work(i))
                .map(|i| self.engines[i].clock)
                .fold(f64::INFINITY, f64::min);
            if t < min_clock {
                let a = self.arrivals.pop_front().expect("front checked");
                self.deliver(a);
                return Some(Vec::new());
            }
        }
        let i = (0..self.engines.len())
            .filter(|&i| self.has_work(i))
            .min_by(|&a, &b| {
                self.engines[a]
                    .clock
                    .partial_cmp(&self.engines[b].clock)
                    .unwrap()
            })?;
        self.refill(i);
        self.sync(i);
        self.engines[i].admit();
        self.sync(i);
        let finished = self.engines[i].step();
        self.sync(i);
        Some(finished)
    }

    /// Event core: pop the earliest decision point, fold the engine's
    /// silent span, then run ONE reference micro-tick at the decision.
    fn tick_event(&mut self) -> Option<Vec<SimRequest>> {
        loop {
            let Some((key, i, fold)) = self.heap.pop() else {
                // defensive resync: external mutations are supposed to
                // keep every has_work engine scheduled; if any slipped,
                // one O(n) rescan restores the invariant
                if !self.reschedule_all() {
                    return None;
                }
                continue;
            };
            if i == self.engines.len() {
                // arrival pseudo-engine: every live engine entry keyed
                // <= this arrival's time has already popped (engines win
                // ties), so delivery happens exactly where the reference
                // core's strict `t < min clock` rule puts it
                let a = self
                    .arrivals
                    .pop_front()
                    .expect("valid arrival entry with empty arrival queue");
                debug_assert_eq!(a.t.to_bits(), key.to_bits(), "stale arrival key");
                debug_assert_eq!(fold, 0, "arrival entries never fold");
                // the mark floors every engine's next admission grid
                // point STRICTLY after t (index n loses all key ties)
                self.marks.push(self.seq, key, i);
                self.seq += 1;
                self.deliver(a);
                self.reschedule_arrival();
                return Some(Vec::new());
            }
            if !self.has_work(i) {
                continue;
            }
            debug_assert_eq!(
                self.next_event(i).map(|(k, f)| (k.to_bits(), f)),
                Some((key.to_bits(), fold)),
                "popped event diverges from a fresh recompute (engine {i})"
            );
            self.engines[i].fold_silent(fold);
            debug_assert_eq!(
                self.engines[i].clock.to_bits(),
                key.to_bits(),
                "fused clock must land exactly on the event key"
            );
            self.marks.push(self.seq, key, i);
            self.seq += 1;
            let pulled = self.refill(i);
            self.sync(i);
            self.engines[i].admit();
            self.sync(i);
            let finished = self.engines[i].step();
            self.sync(i);
            self.touched[i] = self.seq;
            self.reschedule(i);
            // a central pop changes the head other engines gate on: with
            // a finite budget any pop can flip a gate verdict; unlimited
            // gates never refuse, so only the drained-to-empty transition
            // (has_work flips) is observable
            if pulled > 0 && (!self.engines[i].kv.unlimited() || self.central.is_empty()) {
                self.reschedule_capacity();
            }
            return Some(finished);
        }
    }

    /// Would the reference core's next pick of `i` change state beyond a
    /// plain decode iteration?  True iff the local queue head or (non-RR)
    /// the central head passes the capacity + KV admission gates against
    /// the CURRENT stored state.  Both inputs are piecewise-constant over
    /// silent spans: kv_used only moves at page-crossing/finish events,
    /// and queue/central heads only change at events or external
    /// mutations (which reschedule).
    fn admission_ready(&self, i: usize) -> bool {
        let e = &self.engines[i];
        if e.running.len() < e.q {
            if let Some(front) = e.queue_front() {
                if !e.kv_gate_refuses(e.kv_used(), e.work_estimate(front)) {
                    return true;
                }
            }
        }
        if self.policy != DispatchPolicy::RoundRobin
            && e.running.len() + e.queue_len() < e.q
        {
            if let Some(front) = self.central.front() {
                if !e.kv_gate_refuses(e.kv_used() + e.queue_committed(),
                                      e.work_estimate(front))
                {
                    return true;
                }
            }
        }
        false
    }

    /// Silent iterations provably in the reference core's past: the first
    /// grid point `(clock + k*iter, j)` lexicographically after the
    /// maximum event key processed since `j` was last touched.  0 when
    /// idle, freshly touched, or the grid is degenerate.
    fn mat_fold(&self, j: usize) -> u64 {
        let e = &self.engines[j];
        if e.running.is_empty() {
            return 0;
        }
        let iter = e.iter_cost();
        if iter <= 0.0 {
            return 0;
        }
        let Some((mk, me)) = self.marks.suffix_max(self.touched[j]) else {
            return 0;
        };
        let c = e.clock;
        // float floor can land past the true first-after point; back off
        // two grid steps and walk forward to the exact lexicographic
        // successor
        let mut k: u64 = if mk > c {
            ((((mk - c) / iter).floor() as i64) - 2).max(0) as u64
        } else {
            0
        };
        while !key_after((c + k as f64 * iter, j), (mk, me)) {
            k += 1;
        }
        k
    }

    /// Engine `j`'s clock as the reference core would currently store it
    /// (stored clock plus virtually executed silent span) — pure, commits
    /// nothing.
    fn pending_clock(&self, j: usize) -> f64 {
        let e = &self.engines[j];
        if e.running.is_empty() {
            return e.clock;
        }
        e.clock + self.mat_fold(j) as f64 * e.iter_cost()
    }

    /// Pool clock as an outside observer (trainer, tracer, report) sees
    /// it.  Under the reference core this equals the stored max (no marks,
    /// every fold is 0); under the event core it includes virtual spans.
    pub(crate) fn observed_clock(&self) -> f64 {
        (0..self.engines.len())
            .map(|j| self.pending_clock(j))
            .fold(0.0, f64::max)
    }

    /// Commit engine `j`'s virtual silent span into stored state.  Every
    /// caller must reschedule `j` afterwards — the committed fold
    /// invalidates any live heap entry computed from the old clock.
    fn materialize(&mut self, j: usize) {
        if self.core != SimCore::Event {
            return;
        }
        let k = self.mat_fold(j);
        if k > 0 {
            self.engines[j].fold_silent(k);
        }
        self.touched[j] = self.seq;
    }

    fn materialize_all(&mut self) {
        for j in 0..self.engines.len() {
            self.materialize(j);
        }
    }

    /// Engine `j`'s next decision point from CURRENT stored state:
    /// `(absolute key, silent iterations to fold first)`.  None when it
    /// has no work.
    fn next_event(&self, i: usize) -> Option<(f64, u64)> {
        if !self.has_work(i) {
            return None;
        }
        let e = &self.engines[i];
        if e.running.is_empty() {
            // idle-with-work: the reference core picks it at its stored
            // clock, and refill/admit always progresses there (the
            // empty-engine gate escape), so the pick IS a decision point
            return Some((e.clock, 0));
        }
        let iter = e.iter_cost();
        let span_fold = e.silent_span() - 1;
        let fold = if self.admission_ready(i) {
            // the next unexecuted pick admits; it cannot lie past the
            // engine's own span event (that event would have popped
            // first — the heap-min invariant)
            let k = self.mat_fold(i);
            debug_assert!(k <= span_fold, "virtual progress crossed an event");
            k.min(span_fold)
        } else {
            span_fold
        };
        Some((e.clock + fold as f64 * iter, fold))
    }

    /// Recompute and replace engine `j`'s heap entry.
    fn reschedule(&mut self, j: usize) {
        if self.core != SimCore::Event {
            return;
        }
        self.heap.invalidate(j);
        if let Some((key, fold)) = self.next_event(j) {
            self.heap.push(j, key, fold);
        }
    }

    /// Reschedule every engine that could observe the central head: those
    /// with spare capacity (their admission/refill gates read it).
    fn reschedule_capacity(&mut self) {
        for j in 0..self.engines.len() {
            let e = &self.engines[j];
            if e.running.len() + e.queue_len() < e.q {
                self.reschedule(j);
            }
        }
    }

    /// Reschedule everything (arrival head included); returns whether any
    /// work remains — engine work or pending arrivals.
    fn reschedule_all(&mut self) -> bool {
        let mut any = false;
        for j in 0..self.engines.len() {
            self.reschedule(j);
            any |= self.has_work(j);
        }
        self.reschedule_arrival();
        any || !self.arrivals.is_empty()
    }

    /// Preempt one lane of one engine, progress kept; the partial re-enters
    /// the dispatch flow (central queue, or the same engine's local queue
    /// under static round-robin striping).
    pub(crate) fn preempt(&mut self, engine: usize, lane: usize) {
        if engine >= self.engines.len() {
            return;
        }
        self.materialize(engine);
        if let Some(w) = self.engines[engine].preempt_lane(lane) {
            if self.policy == DispatchPolicy::RoundRobin {
                self.engines[engine].enqueue_back(w);
            } else {
                // arrival-mode SJF mirror: requeued partials go to the
                // back, so their key must sort after every real priority
                if self.arrival_mode && self.policy == DispatchPolicy::ShortestPredictedFirst {
                    self.central_keys.push_back(f64::MAX);
                }
                self.central.push_back(w);
            }
        }
        self.sync(engine);
        if self.core == SimCore::Event {
            self.reschedule_all();
        }
    }

    /// Migrate work from engine `from` to engine `to`; returns the
    /// migrated progress tokens, or None when nothing moved (no such
    /// work, or the destination's KV budget refused it).
    pub(crate) fn steal(&mut self, from: usize, to: usize, lane: Option<usize>) -> Option<usize> {
        let n = self.engines.len();
        if from >= n || to >= n || from == to {
            return None;
        }
        // decision-time state must include virtual spans on both sides
        // (the thief's clock bump below reads them)
        self.materialize(from);
        self.materialize(to);
        let out = self.steal_inner(from, to, lane);
        self.sync(from);
        self.sync(to);
        if self.core == SimCore::Event {
            self.reschedule_all();
        }
        out
    }

    /// The migration itself.  Clock rule: a partial's tokens were produced
    /// under `from`'s clock, so the thief's clock is bumped to at least
    /// `from`'s before it may resume them — migration cannot replay work
    /// in the destination's past.  Fresh queued work (progress 0) carries
    /// no such constraint, exactly like a central-queue pull.
    fn steal_inner(&mut self, from: usize, to: usize, lane: Option<usize>) -> Option<usize> {
        let (work, progressed) = match lane {
            None => {
                let w = self.engines[from].dequeue_back()?;
                // refuse what the destination can never hold AND what its
                // current headroom cannot admit (see the harness twin)
                let dst = &self.engines[to];
                let est = dst.work_estimate(&w);
                if est > dst.kv.budget || dst.kv_gate_refuses(dst.kv_used(), est) {
                    self.engines[from].enqueue_back(w);
                    return None;
                }
                let progressed = w.progress > 0;
                (w, progressed)
            }
            Some(l) => {
                let reserve = {
                    let victim = self.engines[from].running.get(l)?;
                    self.engines[to].kv.admit_estimate(
                        victim.req.prompt_len,
                        victim.generated,
                        victim.req.output_len,
                        victim.predicted,
                    )
                };
                let dst = &self.engines[to];
                if reserve > dst.kv.headroom(dst.kv_used()) {
                    return None;
                }
                (self.engines[from].preempt_lane(l)?, true)
            }
        };
        if progressed && self.engines[to].clock < self.engines[from].clock {
            self.engines[to].clock = self.engines[from].clock;
        }
        let progress = work.progress;
        self.engines[to].enqueue_back(work);
        Some(progress)
    }

    /// Terminate everything pool-wide -> (request, progress, queued).
    pub(crate) fn terminate_all(&mut self) -> Vec<(SimRequest, usize, bool)> {
        self.materialize_all();
        let mut out = Vec::new();
        for i in 0..self.engines.len() {
            out.extend(self.engines[i].terminate_all());
            self.sync(i);
        }
        out.extend(self.central.drain(..).map(|w| (w.req, w.progress, true)));
        self.central_keys.clear();
        if self.core == SimCore::Event {
            // nothing has work; fresh entries arrive with the next stage —
            // but clear() invalidated the arrival slot too, so re-arm it
            self.heap.clear();
            self.reschedule_arrival();
        }
        out
    }

    /// Sync barrier: jump every engine clock to the pool max (harvest / wave
    /// end).  The gap between an engine's own finish time and the barrier is
    /// genuine rollout-phase idle; the timeline's trailing interval (last
    /// recorded running count, usually 0) accounts for it.
    pub(crate) fn align_clocks(&mut self) {
        self.materialize_all();
        let end = self.engines.iter().map(|e| e.clock).fold(0.0, f64::max);
        for e in self.engines.iter_mut() {
            e.clock = end;
        }
        if self.core == SimCore::Event {
            self.reschedule_all();
        }
    }

    pub(crate) fn tokens_out(&self) -> u64 {
        self.engines.iter().map(|e| e.tokens_out).sum()
    }
}
