//! Discrete-event rollout simulator.
//!
//! The paper's performance numbers (Fig. 1a/1b, Fig. 5) come from H100/MI300X
//! clusters serving 8B–32B models; this simulator reproduces their *shape*
//! with an explicit cost model of a bandwidth-bound serving engine:
//!
//!   iteration_time(r) = t_weights + r * t_token
//!
//! — every decode iteration streams the full weights once (the fixed cost
//! that makes low occupancy expensive, §2.2) plus per-request KV traffic.
//! Prefill is chunked and costs t_prefill_token per ingested token.  The
//! scheduling logic mirrors the real controller (oversubscription, early
//! termination at the batching threshold, on-policy restart vs partial
//! resume), so the same policies can be compared at paper scale (512
//! prompts, 8k-token caps) in milliseconds of host time.

use crate::coordinator::buffer::Mode;
use crate::metrics::{PredictorScore, Timeline};
use crate::rollout::kv::{KvConfig, KvMode};
use crate::sched::policy::{
    drive_traced, AsyncUpdatePolicy, BaselinePolicy, EngineLoad, GroupPolicy, HarvestAction,
    HarvestItem, KvGovernor, LaneView, PolicyParams, SchedView, ScheduleBackend,
    SchedulePolicy, StealConfig, WorkStealing, ASYNC_SYNC_EVERY,
};
use crate::sched::{make_predictor, sjf_priority, DispatchPolicy, LengthPredictor, PredictorKind};
use crate::trace::{series, SloSummary, Tracer};
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};

/// Serving-engine cost model (seconds).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Fixed per-iteration cost: weight streaming + kernel launch
    /// (the "captured graph" cost paid regardless of occupancy).
    pub t_weights: f64,
    /// Marginal per-running-request per-iteration cost (KV traffic).
    pub t_token: f64,
    /// Per-token prefill ingestion cost (chunked prefill).
    pub t_prefill_token: f64,
    /// Policy-update cost per trajectory token trained on (fwd+bwd).
    pub t_update_token: f64,
    /// Reward/reference inference cost per trajectory token.
    pub t_infer_token: f64,
}

impl Default for CostModel {
    /// Calibrated to Fig. 5's operating point (8B-class model, Q=128):
    /// full-batch decode = Q/(t_w + Q·t_t) ≈ 5.6k tok/s (the partial-mode
    /// ceiling) and ~26% mean occupancy yields ≈ 4.0k tok/s (the baseline),
    /// which solves to t_w ≈ 3.2 ms, t_t ≈ 0.155 ms.
    fn default() -> Self {
        CostModel {
            t_weights: 3.2e-3,
            t_token: 1.55e-4,
            t_prefill_token: 2e-6,
            t_update_token: 1.0e-4,
            t_infer_token: 2.5e-5,
        }
    }
}

/// One simulated request: predetermined prompt/output lengths (the paper's
/// Fig. 5 methodology — sampling parameters pinned so lengths match across
/// strategies).
#[derive(Debug, Clone, Copy)]
pub struct SimRequest {
    pub id: usize,
    pub prompt_len: usize,
    pub output_len: usize,
}

/// Long-tailed length workload matching Fig. 1c's shape: a lognormal body
/// (~80% of samples within 3/8 of the cap) plus ~6% of requests truncated
/// AT the generation cap — the paper observes "5% can extend up to the
/// token limit", and those cap-clipped requests are what the schedulers
/// fight over.
pub fn longtail_workload(n: usize, cap: usize, seed: u64) -> Vec<SimRequest> {
    let mut rng = Pcg64::with_stream(seed, 0x51);
    (0..n)
        .map(|id| {
            let len = if rng.bool_with(0.08) {
                cap // hit the generation limit
            } else {
                // body scaled to the cap: median ~0.11*cap (most responses
                // finish early — Fig. 1c's "80% within 3k of 16k"), with a
                // long right tail
                let body = rng.lognormal(0.0, 0.85) * 0.11 * cap as f64;
                (body as usize).clamp(16, cap)
            };
            SimRequest {
                id,
                prompt_len: 64 + rng.below(192) as usize,
                output_len: len,
            }
        })
        .collect()
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Run each rollout batch to completion (sync barrier).
    Baseline,
    /// SortedRL fully on-policy: early-terminate; interrupted requests
    /// restart from scratch (progress discarded).
    SortedOnPolicy,
    /// SortedRL partial: interrupted requests keep progress; resume costs
    /// a prefill over prompt + generated prefix.
    SortedPartial,
    /// Async updates: the trainer update overlaps continued decoding (no
    /// harvest barrier; partial-mode scavenge bounds staleness).  The
    /// modeled update cost hides under the engine clocks instead of
    /// serializing into `total_time`.
    Async,
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub mode: SimMode,
    pub timeline: Timeline,
    pub total_time: f64,
    pub rollout_time: f64,
    pub update_time: f64,
    pub infer_time: f64,
    /// Tokens belonging to harvested trajectories.
    pub useful_tokens: u64,
    /// Tokens generated then discarded by on-policy restarts.
    pub wasted_tokens: u64,
    pub bubble_ratio: f64,
    /// Useful output tokens / rollout wall time.
    pub throughput: f64,
    pub harvests: usize,
    /// Trajectories harvested clipped (incomplete) at group end.
    pub clipped: usize,
    /// Prompts dropped without training (never scheduled at group end).
    pub dropped: usize,
    /// Engines the run was sharded across (1 for [`simulate`]).
    pub engines: usize,
    /// Length-predictor mean absolute error (pool runs; 0 otherwise).
    pub predictor_mae: f64,
    /// Length-predictor Kendall tau (pool runs; 0 otherwise).
    pub predictor_tau: f64,
    /// Cross-engine migrations executed (work stealing; 0 when disabled).
    pub steals: u64,
    /// Partial-progress tokens carried across engines by steals.
    pub migrated_tokens: u64,
    /// Per-engine idle fraction over the rollout span — the load-imbalance
    /// breakdown stealing is meant to flatten (1.0 = engine never ran).
    pub engine_idle: Vec<f64>,
    /// Highest concurrent running-lane count across the pool — the
    /// admitted-lane headline paged KV accounting is meant to raise at a
    /// fixed budget.
    pub peak_lanes: usize,
    /// Lanes force-evicted by the paged in-step backpressure path.
    pub kv_sheds: u64,
    /// Lanes shed by executed `Decision::Throttle`s (the KvGovernor).
    pub throttles: u64,
    /// Pool-wide KV usage over time, (engine seconds, tokens charged),
    /// downsampled — the utilization curve `pool_kv.json` plots.  Empty
    /// when KV accounting is off.
    pub kv_trace: Vec<(f64, usize)>,
    /// Per-request latency roll-up (TTFT/TPOT/e2e quantiles, goodput).
    /// Default-empty unless the run carried a recording [`Tracer`]
    /// ([`simulate_pool_traced`], or `PoolSimOpts::slo`).
    pub slo: SloSummary,
}

struct Running {
    req: SimRequest,
    generated: usize,
    /// Predicted total length stamped at stage time (None = rank-only
    /// predictor) — what the paged admission estimate consumed, kept so
    /// an evicted lane re-admits under the same estimate.
    predicted: Option<usize>,
}

/// One unit of stageable work: a request plus preserved progress and the
/// stamped length prediction driving paged-KV admission estimates.
#[derive(Debug, Clone, Copy)]
struct SimWork {
    req: SimRequest,
    progress: usize,
    predicted: Option<usize>,
}

/// Stamp a raw prediction onto staged work via the shared
/// [`crate::rollout::kv::stamp_prediction`] rule (None for rank-only
/// predictors — bucket indices are not token counts and must not feed KV
/// estimates).
fn stamp_work(rank_only: bool, predicted: f64, req: SimRequest, progress: usize) -> SimWork {
    SimWork {
        req,
        progress,
        predicted: crate::rollout::kv::stamp_prediction(rank_only, predicted),
    }
}

/// Simulated engine with queue capacity `q`.
struct SimEngine {
    q: usize,
    cost: CostModel,
    /// KV memory model (mode + budget + page; `budget == usize::MAX` =
    /// accounting off).
    kv: KvConfig,
    clock: f64,
    running: Vec<Running>,
    queue: VecDeque<SimWork>,
    timeline: Timeline,
    tokens_out: u64,
    /// Forced paged evictions (actual usage outgrew the budget mid-step).
    sheds: u64,
    /// (clock, kv_used) samples — recorded only when accounting is on.
    kv_trace: Vec<(f64, usize)>,
}

impl SimEngine {
    fn new(q: usize, cost: CostModel, kv: KvConfig) -> Self {
        SimEngine {
            q,
            cost,
            kv,
            clock: 0.0,
            running: Vec::new(),
            queue: VecDeque::new(),
            timeline: Timeline::new(),
            tokens_out: 0,
            sheds: 0,
            kv_trace: Vec::new(),
        }
    }

    fn record(&mut self) {
        self.timeline.set_running(self.clock, self.running.len());
        if !self.kv.unlimited() {
            let used = self.kv_used();
            self.kv_trace.push((self.clock, used));
        }
    }

    /// What a running lane charges right now (worst case in reserve mode,
    /// the paged actual context otherwise).
    fn lane_charge(&self, r: &Running) -> usize {
        self.kv.lane_charge(r.req.prompt_len, r.generated, r.req.output_len)
    }

    /// What the admission gate charges a queued candidate.
    fn work_estimate(&self, w: &SimWork) -> usize {
        self.kv
            .admit_estimate(w.req.prompt_len, w.progress, w.req.output_len, w.predicted)
    }

    fn kv_used(&self) -> usize {
        self.running.iter().map(|r| self.lane_charge(r)).sum()
    }

    /// The KV admission gate shared by `admit`, `engine_loads`, and the
    /// pool's `steal`: admitting `estimate` on top of `used` is refused
    /// iff running lanes already hold KV and the sum overruns the budget
    /// (the empty-engine escape admits any head request alone).
    fn kv_gate_refuses(&self, used: usize, estimate: usize) -> bool {
        self.kv.gate_refuses(used, estimate)
    }

    fn admit(&mut self) {
        let mut used = self.kv_used();
        while self.running.len() < self.q {
            let Some(front) = self.queue.front() else { break };
            // KV admission gate: an otherwise-empty engine always admits
            // its head request (progress guarantee — a single oversized
            // context must not deadlock the queue).  The gate accumulates
            // admission ESTIMATES within the pass; paged lanes charge
            // their much smaller actual context once admitted.
            let est = self.work_estimate(front);
            if self.kv_gate_refuses(used, est) {
                break;
            }
            let w = self.queue.pop_front().unwrap();
            used += est;
            // prefill cost: prompt + any preserved progress
            self.clock += (w.req.prompt_len + w.progress) as f64 * self.cost.t_prefill_token;
            self.running
                .push(Running { req: w.req, generated: w.progress, predicted: w.predicted });
        }
        self.record();
    }

    /// One decode iteration; returns finished requests.
    fn step(&mut self) -> Vec<SimRequest> {
        let r = self.running.len();
        if r == 0 {
            return Vec::new();
        }
        self.clock += self.cost.t_weights + r as f64 * self.cost.t_token;
        self.tokens_out += r as u64;
        let mut finished = Vec::new();
        self.running.retain_mut(|run| {
            run.generated += 1;
            if run.generated >= run.req.output_len {
                finished.push(run.req);
                false
            } else {
                true
            }
        });
        if !finished.is_empty() {
            self.timeline.add_finished(finished.len() as u64);
        }
        self.shed_over_budget();
        self.record();
        finished
    }

    /// Forced paged backpressure: if actual usage outgrew the budget
    /// (admission estimates undershot), evict the smallest-context lane
    /// back to the local queue — progress kept, resume pays a re-prefill —
    /// until the budget holds or one lane remains (the running twin of the
    /// empty-engine admission escape).  The back of the queue makes the
    /// evicted partial the preferred steal victim for a KV-rich peer.
    fn shed_over_budget(&mut self) {
        if self.kv.mode != KvMode::Paged || self.kv.unlimited() {
            return;
        }
        while self.running.len() > 1 && self.kv_used() > self.kv.budget {
            let lane = self
                .running
                .iter()
                .enumerate()
                .min_by_key(|&(i, r)| (self.lane_charge(r), i))
                .map(|(i, _)| i)
                .expect("running checked non-empty");
            let r = self.running.remove(lane);
            self.queue.push_back(SimWork {
                req: r.req,
                progress: r.generated,
                predicted: r.predicted,
            });
            self.sheds += 1;
        }
    }

    /// Preempt ONE running lane back to the queue, KEEPING progress
    /// (resume costs only a re-prefill over prompt + prefix).
    fn preempt_lane(&mut self, lane: usize) -> Option<SimWork> {
        if lane >= self.running.len() {
            return None;
        }
        let r = self.running.remove(lane);
        self.record();
        Some(SimWork { req: r.req, progress: r.generated, predicted: r.predicted })
    }

    /// Terminate everything in flight; returns (request, progress, queued)
    /// triples — `queued` marks requests drained from the waiting queue
    /// rather than preempted out of a lane.
    fn terminate_all(&mut self) -> Vec<(SimRequest, usize, bool)> {
        let mut out: Vec<(SimRequest, usize, bool)> = self
            .running
            .drain(..)
            .map(|r| (r.req, r.generated, false))
            .collect();
        out.extend(self.queue.drain(..).map(|w| (w.req, w.progress, true)));
        self.record();
        out
    }
}

/// Simulate one full consumption of `workload` under `mode` on a single
/// engine with queue capacity `q`, `update_batch` trajectories per policy
/// update.  Thin wrapper over [`simulate_pool`] with one engine: since the
/// policy-API port, single-engine and pool runs execute the identical
/// decision sequence (and the same one the live controller executes).
pub fn simulate(mode: SimMode, workload: &[SimRequest], q: usize,
                update_batch: usize, cost: CostModel) -> SimReport {
    simulate_pool(mode, workload, 1, q, update_batch, cost,
                  DispatchPolicy::ShortestPredictedFirst, PredictorKind::History)
}

// ==========================================================================
// Multi-engine pool simulation (the `sched` layer's simulator mirror)
// ==========================================================================

/// Engine pool over [`SimEngine`]s: a central queue (or static stripes for
/// round-robin) plus event-driven stepping — always advance the
/// earliest-clock engine with work, so engine clocks stay within one
/// decode iteration of each other (parallel devices).
struct SimPool {
    engines: Vec<SimEngine>,
    central: VecDeque<SimWork>,
    policy: DispatchPolicy,
    rr: usize,
}

impl SimPool {
    fn new(n: usize, q_each: usize, cost: CostModel, policy: DispatchPolicy,
           kv: KvConfig) -> Self {
        SimPool {
            engines: (0..n).map(|_| SimEngine::new(q_each, cost, kv)).collect(),
            central: VecDeque::new(),
            policy,
            rr: 0,
        }
    }

    /// Targeted admission: push work straight onto engine `i`'s local
    /// queue, bypassing the dispatch policy (`Admit { engine: Some(i) }`).
    fn stage_to(&mut self, i: usize, work: Vec<SimWork>) {
        assert!(i < self.engines.len(), "stage_to engine out of range");
        self.engines[i].queue.extend(work);
    }

    /// Stage a wave of work per the dispatch policy.  Round-robin
    /// statically stripes (the FCFS baseline); least-loaded keeps a FIFO
    /// central queue that engines pull from as lanes free; SJF keeps the
    /// central queue sorted by predicted remaining length so each engine
    /// pulls a contiguous, similar-length run.
    fn stage(&mut self, work: Vec<SimWork>, pred: &dyn LengthPredictor) {
        match self.policy {
            DispatchPolicy::RoundRobin => {
                for w in work {
                    let i = self.rr % self.engines.len();
                    self.rr += 1;
                    self.engines[i].queue.push_back(w);
                }
            }
            DispatchPolicy::LeastLoaded => self.central.extend(work),
            DispatchPolicy::ShortestPredictedFirst => {
                // sjf_priority is THE policy shared with the real
                // EnginePool; keys computed once, not in the comparator
                let mut keyed: Vec<(f64, SimWork)> = work
                    .into_iter()
                    .map(|w| {
                        (sjf_priority(pred, w.req.id as u64, w.req.prompt_len, w.progress), w)
                    })
                    .collect();
                keyed.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0).unwrap().then(a.1.req.id.cmp(&b.1.req.id))
                });
                self.central.extend(keyed.into_iter().map(|(_, w)| w));
            }
        }
    }

    /// Pull central-queue work into engine `i`'s free lanes (late
    /// binding), KV-budget-aware: stop once the head's admission estimate
    /// no longer fits what the engine is already committed to (actual
    /// lane charges plus queued estimates) — route around KV-tight
    /// engines instead of queueing work behind a gate that will refuse
    /// it.  A fully empty engine always pulls (the dispatch twin of the
    /// empty-engine admission escape); unlimited budgets never refuse, so
    /// KV-oblivious runs pull exactly as before.
    fn refill(&mut self, i: usize) {
        if self.policy == DispatchPolicy::RoundRobin {
            return;
        }
        let kv = self.engines[i].kv;
        let mut committed = self.engines[i].kv_used()
            + self.engines[i]
                .queue
                .iter()
                .map(|w| self.engines[i].work_estimate(w))
                .sum::<usize>();
        loop {
            let e = &self.engines[i];
            if e.running.len() + e.queue.len() >= e.q {
                break;
            }
            let Some(front) = self.central.front() else { break };
            let est = e.work_estimate(front);
            if kv.gate_refuses(committed, est) {
                break;
            }
            committed = committed.saturating_add(est);
            let w = self.central.pop_front().unwrap();
            self.engines[i].queue.push_back(w);
        }
    }

    fn has_work(&self, i: usize) -> bool {
        let e = &self.engines[i];
        !e.running.is_empty()
            || !e.queue.is_empty()
            || (self.policy != DispatchPolicy::RoundRobin && !self.central.is_empty())
    }

    fn total_running(&self) -> usize {
        self.engines.iter().map(|e| e.running.len()).sum()
    }

    fn queued(&self) -> usize {
        self.central.len() + self.engines.iter().map(|e| e.queue.len()).sum::<usize>()
    }

    /// Advance the earliest-clock engine with work by one admit + decode
    /// iteration; returns its finishes, or None when the pool is drained.
    fn tick(&mut self) -> Option<Vec<SimRequest>> {
        let i = (0..self.engines.len())
            .filter(|&i| self.has_work(i))
            .min_by(|&a, &b| {
                self.engines[a]
                    .clock
                    .partial_cmp(&self.engines[b].clock)
                    .unwrap()
            })?;
        self.refill(i);
        self.engines[i].admit();
        Some(self.engines[i].step())
    }

    /// Preempt one lane of one engine, progress kept; the partial re-enters
    /// the dispatch flow (central queue, or the same engine's local queue
    /// under static round-robin striping).
    fn preempt(&mut self, engine: usize, lane: usize) {
        if engine >= self.engines.len() {
            return;
        }
        if let Some(w) = self.engines[engine].preempt_lane(lane) {
            if self.policy == DispatchPolicy::RoundRobin {
                self.engines[engine].queue.push_back(w);
            } else {
                self.central.push_back(w);
            }
        }
    }

    /// Migrate work from engine `from` to engine `to`; returns the
    /// migrated progress tokens, or None when nothing moved (no such
    /// work, or the destination's KV budget refused it).  Clock rule: a
    /// partial's tokens were produced under `from`'s clock, so the thief's
    /// clock is bumped to at least `from`'s before it may resume them —
    /// migration cannot replay work in the destination's past.  Fresh
    /// queued work (progress 0) carries no such constraint, exactly like
    /// a central-queue pull.
    fn steal(&mut self, from: usize, to: usize, lane: Option<usize>) -> Option<usize> {
        let n = self.engines.len();
        if from >= n || to >= n || from == to {
            return None;
        }
        let (work, progressed) = match lane {
            None => {
                let w = self.engines[from].queue.pop_back()?;
                // refuse what the destination can never hold AND what its
                // current headroom cannot admit (see the harness twin)
                let dst = &self.engines[to];
                let est = dst.work_estimate(&w);
                if est > dst.kv.budget || dst.kv_gate_refuses(dst.kv_used(), est) {
                    self.engines[from].queue.push_back(w);
                    return None;
                }
                let progressed = w.progress > 0;
                (w, progressed)
            }
            Some(l) => {
                let reserve = {
                    let victim = self.engines[from].running.get(l)?;
                    self.engines[to].kv.admit_estimate(
                        victim.req.prompt_len,
                        victim.generated,
                        victim.req.output_len,
                        victim.predicted,
                    )
                };
                let dst = &self.engines[to];
                if reserve > dst.kv.headroom(dst.kv_used()) {
                    return None;
                }
                (self.engines[from].preempt_lane(l)?, true)
            }
        };
        if progressed && self.engines[to].clock < self.engines[from].clock {
            self.engines[to].clock = self.engines[from].clock;
        }
        let progress = work.progress;
        self.engines[to].queue.push_back(work);
        Some(progress)
    }

    /// Terminate everything pool-wide -> (request, progress, queued).
    fn terminate_all(&mut self) -> Vec<(SimRequest, usize, bool)> {
        let mut out = Vec::new();
        for e in self.engines.iter_mut() {
            out.extend(e.terminate_all());
        }
        out.extend(self.central.drain(..).map(|(req, p)| (req, p, true)));
        out
    }

    /// Sync barrier: jump every engine clock to the pool max (harvest / wave
    /// end).  The gap between an engine's own finish time and the barrier is
    /// genuine rollout-phase idle; the timeline's trailing interval (last
    /// recorded running count, usually 0) accounts for it.
    fn align_clocks(&mut self) {
        let end = self.clock();
        for e in self.engines.iter_mut() {
            e.clock = end;
        }
    }

    fn clock(&self) -> f64 {
        self.engines.iter().map(|e| e.clock).fold(0.0, f64::max)
    }

    fn tokens_out(&self) -> u64 {
        self.engines.iter().map(|e| e.tokens_out).sum()
    }
}

/// Merge per-engine occupancy timelines into one pool timeline whose
/// running count is the sum across engines (tokens and finish counts sum
/// too), so [`Timeline::bubble_ratio`] with the pool's total capacity gives
/// the aggregate bubble.
fn merge_timelines(engines: &[SimEngine]) -> Timeline {
    let mut merged = Timeline::new();
    let sources: Vec<&[(f64, usize)]> =
        engines.iter().map(|e| e.timeline.events()).collect();
    for (t, total) in series::merge_running_totals(&sources) {
        merged.set_running(t, total);
    }
    let mut tokens = 0u64;
    let mut finished = 0u64;
    for e in engines {
        // SimEngine counts tokens in its own field — its timeline is
        // never fed add_tokens (unlike the real rollout::Engine)
        tokens += e.tokens_out;
        finished += e.timeline.finished();
    }
    merged.add_tokens(tokens);
    merged.add_finished(finished);
    merged
}

fn make_sim_predictor(kind: PredictorKind, workload: &[SimRequest]) -> Box<dyn LengthPredictor> {
    let mut pred = make_predictor(kind);
    if kind == PredictorKind::Oracle {
        // the oracle reads true cost: simulator ground truth
        for r in workload {
            pred.observe(r.id as u64, r.prompt_len, r.output_len);
        }
    }
    pred
}

/// Run `workload` to completion on an engine pool — one oversubscribed
/// wave, no harvests or updates — and return the makespan in seconds.
/// This is the dispatch-policy comparison number `sched_bench` prints.
///
/// Learning predictors (history/bucket) are warmed up on NOISY
/// observations of the workload first: the RL regime re-rolls the same
/// prompts every policy update, so by the time scheduling matters the
/// predictor has seen sibling samples / earlier epochs of each prompt —
/// which *estimate*, not reveal, this round's exact length.  (Cold
/// predictions are uncorrelated with true lengths, so a cold run would
/// measure only late-binding dispatch; an exact warmup would make history
/// indistinguishable from the oracle, since sim requests are keyed
/// individually.)  The ~±35% lognormal noise leaves rank quality high but
/// keeps the oracle a genuine ceiling.
pub fn pool_makespan(workload: &[SimRequest], engines: usize, q_total: usize,
                     cost: CostModel, dispatch: DispatchPolicy,
                     predictor: PredictorKind) -> f64 {
    assert!(engines >= 1 && q_total >= engines, "q_total must cover engines");
    let mut pred = make_sim_predictor(predictor, workload);
    if predictor != PredictorKind::Oracle {
        let mut rng = Pcg64::with_stream(0x5EED_17, 0x9E);
        for r in workload {
            let noisy = (r.output_len as f64 * rng.lognormal(0.0, 0.35))
                .clamp(1.0, 4.0 * r.output_len as f64);
            pred.observe(r.id as u64, r.prompt_len, noisy as usize);
        }
    }
    let mut pool = SimPool::new(engines, q_total / engines, cost, dispatch,
                                KvConfig::default());
    let work: Vec<SimWork> = workload
        .iter()
        .map(|r| {
            let p = pred.predict(r.id as u64, r.prompt_len);
            stamp_work(pred.is_rank_only(), p, *r, 0)
        })
        .collect();
    pool.stage(work, pred.as_ref());
    while pool.tick().is_some() {}
    pool.clock()
}

// ==========================================================================
// SimBackend — the simulator ScheduleBackend
// ==========================================================================

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SimLife {
    Fresh,
    InFlight,
    Ready,
    Consumed,
}

struct SimEntry {
    req: SimRequest,
    /// Preserved progress a resume re-prefills over.
    progress: usize,
    life: SimLife,
    /// Harvested response length (output_len, or clip progress).
    ready_len: usize,
    complete: bool,
    /// Completion-order stamp (what `ready_rids` sorts by).
    seq: u64,
}

/// The simulator `ScheduleBackend`: executes the SAME policy decision
/// sequence the live controller executes, against [`SimPool`]'s cost model.
/// The live mirror is `coordinator::controller`'s `LiveBackend`.
struct SimBackend {
    pool: SimPool,
    cost: CostModel,
    pred: Box<dyn LengthPredictor>,
    score: PredictorScore,
    /// Prediction captured at stage time — what actually drove dispatch —
    /// not recomputed after siblings finished.
    staged_pred: BTreeMap<usize, f64>,
    /// Workload not yet loaded (grouped loading pops from here).
    backlog: VecDeque<SimRequest>,
    entries: BTreeMap<u64, SimEntry>,
    q_cap: usize,
    total: usize,
    done: usize,
    // O(1) lifecycle counters (view() runs 2-3x per driver decision; a
    // BTreeMap scan there would dominate paper-scale sim host time)
    fresh_count: usize,
    ready_count: usize,
    unconsumed_count: usize,
    seq: u64,
    updates: usize,
    harvests: usize,
    clipped: usize,
    dropped: usize,
    wasted: u64,
    steals: u64,
    migrated_tokens: u64,
    infer_time: f64,
    update_time: f64,
    /// Lanes shed by executed `Decision::Throttle`s.
    throttles: u64,
    /// Async mode: updates overlap decoding instead of serializing.
    overlap_updates: bool,
    /// Engine-clock time at which the (async) trainer frees up.
    update_free_at: f64,
}

impl SimBackend {
    fn new(workload: &[SimRequest], engines: usize, q_each: usize, cost: CostModel,
           dispatch: DispatchPolicy, predictor: PredictorKind,
           overlap_updates: bool, kv: KvConfig) -> Self {
        SimBackend {
            pool: SimPool::new(engines, q_each, cost, dispatch, kv),
            cost,
            pred: make_sim_predictor(predictor, workload),
            score: PredictorScore::default(),
            staged_pred: BTreeMap::new(),
            backlog: workload.iter().copied().collect(),
            entries: BTreeMap::new(),
            q_cap: q_each * engines,
            total: workload.len(),
            done: 0,
            fresh_count: 0,
            ready_count: 0,
            unconsumed_count: 0,
            seq: 0,
            updates: 0,
            harvests: 0,
            clipped: 0,
            dropped: 0,
            wasted: 0,
            steals: 0,
            migrated_tokens: 0,
            infer_time: 0.0,
            update_time: 0.0,
            throttles: 0,
            overlap_updates,
            update_free_at: 0.0,
        }
    }

    fn into_report(self, mode: SimMode) -> SimReport {
        let rollout_time = self.pool.clock();
        let timeline = merge_timelines(&self.pool.engines);
        let bubble = timeline.bubble_ratio(self.q_cap, rollout_time);
        // the admitted-lane headline: max concurrent running lanes across
        // the pool over the whole run (from the merged occupancy events)
        let peak_lanes = timeline.events().iter().map(|&(_, r)| r).max().unwrap_or(0);
        let kv_trace = merge_kv_traces(&self.pool.engines);
        // per-engine idle fraction against the POOL end time: an engine
        // that never ran is 100% idle capacity, not a non-event
        let engine_idle: Vec<f64> = self
            .pool
            .engines
            .iter()
            .map(|e| {
                if e.timeline.events().is_empty() {
                    1.0
                } else {
                    e.timeline.bubble_ratio(e.q, rollout_time)
                }
            })
            .collect();
        // useful = tokens of trajectories actually harvested (clipping
        // shortens; restarts and drops waste)
        let useful = self.pool.tokens_out().saturating_sub(self.wasted);
        let total_time = if self.overlap_updates {
            // async: update cost hides under decoding; only the overhang
            // past the rollout end serializes
            rollout_time.max(self.update_free_at) + self.infer_time
        } else {
            rollout_time + self.infer_time + self.update_time
        };
        SimReport {
            mode,
            total_time,
            rollout_time,
            update_time: self.update_time,
            infer_time: self.infer_time,
            useful_tokens: useful,
            wasted_tokens: self.wasted,
            bubble_ratio: bubble,
            throughput: useful as f64 / rollout_time,
            timeline,
            harvests: self.harvests,
            clipped: self.clipped,
            dropped: self.dropped,
            engines: self.pool.engines.len(),
            predictor_mae: self.score.mae(),
            predictor_tau: self.score.kendall_tau(),
            steals: self.steals,
            migrated_tokens: self.migrated_tokens,
            engine_idle,
            peak_lanes,
            kv_sheds: self.pool.engines.iter().map(|e| e.sheds).sum(),
            throttles: self.throttles,
            kv_trace,
            slo: SloSummary::default(),
        }
    }
}

/// Merge per-engine (clock, kv_used) samples into one pool-wide usage
/// curve (running totals over merged event order), downsampled to at most
/// 256 points so `pool_kv.json` stays small at paper scale.
fn merge_kv_traces(engines: &[SimEngine]) -> Vec<(f64, usize)> {
    let sources: Vec<&[(f64, usize)]> =
        engines.iter().map(|e| e.kv_trace.as_slice()).collect();
    series::downsample(&series::merge_running_totals(&sources), 256)
}

impl ScheduleBackend for SimBackend {
    fn view(&self) -> SchedView {
        SchedView {
            running: self.pool.total_running(),
            queued: self.pool.queued(),
            ready: self.ready_count,
            fresh: self.fresh_count,
            unconsumed: self.unconsumed_count,
            lanes: self.q_cap,
            updates: self.updates,
        }
    }

    fn schedulable(&self) -> Vec<u64> {
        self.entries
            .values()
            .filter(|e| e.life == SimLife::Fresh)
            .map(|e| e.req.id as u64)
            .collect()
    }

    fn ready_rids(&self) -> Vec<u64> {
        let mut v: Vec<(u64, u64)> = self
            .entries
            .values()
            .filter(|e| e.life == SimLife::Ready)
            .map(|e| (e.seq, e.req.id as u64))
            .collect();
        v.sort_unstable();
        v.into_iter().map(|(_, rid)| rid).collect()
    }

    fn ready_len(&self, rid: u64) -> usize {
        self.entries.get(&rid).map(|e| e.ready_len).unwrap_or(0)
    }

    fn load_prompts(&mut self, prompts: usize) -> Result<usize> {
        let mut count = 0;
        for _ in 0..prompts {
            let Some(req) = self.backlog.pop_front() else { break };
            self.entries.insert(req.id as u64, SimEntry {
                req,
                progress: 0,
                life: SimLife::Fresh,
                ready_len: 0,
                complete: false,
                seq: 0,
            });
            self.fresh_count += 1;
            self.unconsumed_count += 1;
            count += 1;
        }
        Ok(count)
    }

    fn admit(&mut self, rids: &[u64], engine: Option<usize>) -> Result<()> {
        let mut work = Vec::with_capacity(rids.len());
        for rid in rids {
            let e = self.entries.get_mut(rid).expect("admit unknown sim rid");
            assert_eq!(e.life, SimLife::Fresh, "admit non-fresh sim rid {rid}");
            e.life = SimLife::InFlight;
            self.fresh_count -= 1;
            let predicted = self.pred.predict(e.req.id as u64, e.req.prompt_len);
            self.staged_pred.insert(e.req.id, predicted);
            work.push(stamp_work(self.pred.is_rank_only(), predicted, e.req, e.progress));
        }
        match engine {
            Some(i) => self.pool.stage_to(i, work),
            None => self.pool.stage(work, self.pred.as_ref()),
        }
        Ok(())
    }

    fn engine_loads(&self) -> Vec<EngineLoad> {
        self.pool
            .engines
            .iter()
            .map(|e| {
                let used = e.kv_used();
                let blocked = e
                    .queue
                    .front()
                    .is_some_and(|w| e.kv_gate_refuses(used, e.work_estimate(w)));
                EngineLoad {
                    queued: e.queue.len(),
                    active: e.running.len(),
                    lanes: e.q,
                    kv_used: used,
                    kv_budget: e.kv.budget,
                    kv_blocked: blocked,
                    kv_pressure: e.kv.pressure(used, e.running.len()),
                }
            })
            .collect()
    }

    fn engine_lanes(&self, engine: usize) -> Vec<LaneView> {
        self.pool
            .engines
            .get(engine)
            .map(|e| {
                e.running
                    .iter()
                    .enumerate()
                    .map(|(i, r)| LaneView {
                        lane: i,
                        progress: r.generated,
                        reserve: e.kv.admit_estimate(
                            r.req.prompt_len,
                            r.generated,
                            r.req.output_len,
                            r.predicted,
                        ),
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    fn trace_clock(&self) -> f64 {
        self.pool.clock()
    }

    fn lane_rids(&self, engine: usize) -> Vec<(usize, u64)> {
        self.pool
            .engines
            .get(engine)
            .map(|e| {
                e.running
                    .iter()
                    .enumerate()
                    .map(|(i, r)| (i, r.req.id as u64))
                    .collect()
            })
            .unwrap_or_default()
    }

    fn throttle(&mut self, engine: usize) -> Result<bool> {
        let Some(e) = self.pool.engines.get(engine) else { return Ok(false) };
        if e.running.len() < 2 {
            return Ok(false);
        }
        // shed the smallest-context lane, progress kept, routed like a
        // preemption so budget-aware dispatch can re-place it
        let lane = e
            .running
            .iter()
            .enumerate()
            .min_by_key(|&(i, r)| (e.lane_charge(r), i))
            .map(|(i, _)| i)
            .expect("running checked >= 2");
        self.pool.preempt(engine, lane);
        self.throttles += 1;
        Ok(true)
    }

    fn steal(&mut self, from: usize, to: usize, lane: Option<usize>) -> Result<bool> {
        match self.pool.steal(from, to, lane) {
            Some(progress) => {
                self.steals += 1;
                self.migrated_tokens += progress as u64;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    fn step(&mut self) -> Result<usize> {
        let Some(finished) = self.pool.tick() else { return Ok(0) };
        let n = finished.len();
        for r in &finished {
            let predicted = self
                .staged_pred
                .remove(&r.id)
                .unwrap_or_else(|| self.pred.predict(r.id as u64, r.prompt_len));
            self.score.push(predicted, r.output_len as f64);
            self.pred.observe(r.id as u64, r.prompt_len, r.output_len);
            let e = self
                .entries
                .get_mut(&(r.id as u64))
                .expect("finished unknown sim rid");
            debug_assert_eq!(e.life, SimLife::InFlight);
            e.life = SimLife::Ready;
            self.ready_count += 1;
            e.ready_len = r.output_len;
            e.complete = true;
            e.seq = self.seq;
            self.seq += 1;
        }
        Ok(n)
    }

    fn harvest_candidates(&mut self) -> Result<Vec<HarvestItem>> {
        let mut terminated = self.pool.terminate_all();
        // harvest is a sync point: engine clocks jump to the pool max
        self.pool.align_clocks();
        // highest progress first — clipping candidates
        terminated.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.id.cmp(&b.0.id)));
        let mut items = Vec::with_capacity(terminated.len());
        for (req, progress, was_queued) in terminated {
            // preemption progress is a length floor the predictor can use
            self.pred.observe_progress(req.id as u64, req.prompt_len, progress);
            self.staged_pred.remove(&req.id);
            // mirror the live backend's item contract: resumed requests
            // sitting in a queue still carry progress and count as partials
            items.push(HarvestItem {
                rid: req.id as u64,
                progress,
                queued: was_queued && progress == 0,
            });
        }
        Ok(items)
    }

    fn resolve(&mut self, item: &HarvestItem, action: HarvestAction) -> Result<()> {
        let e = self.entries.get_mut(&item.rid).expect("resolve unknown sim rid");
        debug_assert_eq!(e.life, SimLife::InFlight);
        match action {
            HarvestAction::Clip => {
                e.life = SimLife::Ready;
                self.ready_count += 1;
                e.ready_len = item.progress;
                e.complete = false;
                e.seq = self.seq;
                self.seq += 1;
                self.clipped += 1;
            }
            HarvestAction::Restart => {
                e.progress = 0;
                e.life = SimLife::Fresh;
                self.fresh_count += 1;
                self.wasted += item.progress as u64;
            }
            HarvestAction::Resume | HarvestAction::Requeue => {
                e.progress = item.progress;
                e.life = SimLife::Fresh;
                self.fresh_count += 1;
            }
            HarvestAction::Drop => {
                e.life = SimLife::Consumed;
                self.unconsumed_count -= 1;
                self.wasted += item.progress as u64;
                self.dropped += 1;
                self.done += 1;
            }
        }
        Ok(())
    }

    fn preempt(&mut self, engine: usize, lane: usize) -> Result<()> {
        self.pool.preempt(engine, lane);
        Ok(())
    }

    fn train(&mut self, rids: &[u64]) -> Result<()> {
        let mut toks = 0.0f64;
        for rid in rids {
            let e = self.entries.get_mut(rid).expect("train unknown sim rid");
            assert_eq!(e.life, SimLife::Ready, "train non-ready sim rid {rid}");
            // natural completions train at their true length; only clips
            // (complete == false) may be shorter
            debug_assert!(!e.complete || e.ready_len == e.req.output_len);
            e.life = SimLife::Consumed;
            self.ready_count -= 1;
            self.unconsumed_count -= 1;
            toks += (e.req.prompt_len + e.ready_len) as f64;
            self.done += 1;
        }
        self.infer_time += toks * self.cost.t_infer_token;
        let update_cost = toks * self.cost.t_update_token;
        self.update_time += update_cost;
        if self.overlap_updates {
            let start = self.update_free_at.max(self.pool.clock());
            self.update_free_at = start + update_cost;
        }
        self.harvests += 1;
        self.updates += 1;
        Ok(())
    }

    fn barrier(&mut self) -> Result<()> {
        // group-end sync barrier
        self.pool.align_clocks();
        self.entries.retain(|_, e| e.life != SimLife::Consumed);
        Ok(())
    }

    fn exhausted(&self) -> bool {
        self.done >= self.total
    }
}

/// Multi-engine pool simulation, policy-driven: the SAME `SchedulePolicy`
/// decision sequence the live controller executes, run against the cost
/// model.  Baseline loads sync-barrier waves of `q_total` requests; the
/// sorted/async modes treat the whole workload as one group pool
/// (oversubscription, early termination at the batching threshold, per-mode
/// clip/restart/resume at harvests).  `engines == 1` gives the
/// single-engine member of the same scheduler family, so 1-vs-N
/// comparisons isolate the sharding effect.
///
/// `q_total` is rounded down to a multiple of `engines`.
pub fn simulate_pool(mode: SimMode, workload: &[SimRequest], engines: usize,
                     q_total: usize, update_batch: usize, cost: CostModel,
                     dispatch: DispatchPolicy, predictor: PredictorKind) -> SimReport {
    simulate_pool_opts(mode, workload, PoolSimOpts {
        engines,
        q_total,
        update_batch,
        cost,
        dispatch,
        predictor,
        ..PoolSimOpts::default()
    })
}

/// Pool-simulation knobs beyond mode/workload.  The positional
/// [`simulate_pool`] covers the pre-stealing surface; construct this with
/// `..PoolSimOpts::default()` for the extended knobs.
#[derive(Debug, Clone, Copy)]
pub struct PoolSimOpts {
    pub engines: usize,
    /// Total lanes across engines (rounded down to a multiple of engines).
    pub q_total: usize,
    pub update_batch: usize,
    pub cost: CostModel,
    pub dispatch: DispatchPolicy,
    pub predictor: PredictorKind,
    /// Wrap the mode's policy in the [`WorkStealing`] composer.
    pub steal: bool,
    /// Per-engine KV budget in tokens; `usize::MAX` disables the model.
    pub kv_budget: usize,
    /// Reserve-the-cap (default) vs paged KV accounting.  Paged runs are
    /// additionally wrapped in the [`KvGovernor`] throttle composer.
    pub kv_mode: KvMode,
    /// Page granularity for paged accounting, in tokens.
    pub kv_page: usize,
    /// SLO deadline in simulated seconds.  `Some` turns on span recording
    /// (no Chrome trace) and fills `SimReport::slo` including goodput
    /// against this deadline; `None` (default) runs the zero-overhead
    /// disabled tracer.
    pub slo: Option<f64>,
}

impl Default for PoolSimOpts {
    fn default() -> Self {
        let kv = KvConfig::default();
        PoolSimOpts {
            engines: 1,
            q_total: 128,
            update_batch: 128,
            cost: CostModel::default(),
            dispatch: DispatchPolicy::ShortestPredictedFirst,
            predictor: PredictorKind::History,
            steal: false,
            kv_budget: kv.budget,
            kv_mode: kv.mode,
            kv_page: kv.page,
            slo: None,
        }
    }
}

/// [`simulate_pool`] with the full option set (work stealing, KV budget).
/// With `o.slo` set, the run carries a span-recording tracer and the
/// report's `slo` section is filled; otherwise the disabled no-op sink
/// rides along, so fuzz suites and decision goldens pay nothing.
pub fn simulate_pool_opts(mode: SimMode, workload: &[SimRequest],
                          o: PoolSimOpts) -> SimReport {
    let mut tracer =
        if o.slo.is_some() { Tracer::new(o.slo, false) } else { Tracer::disabled() };
    simulate_pool_traced(mode, workload, o, &mut tracer)
}

/// [`simulate_pool_opts`] with an explicit [`Tracer`] riding on the driver
/// — the entry point `sim --trace-out` uses to produce Perfetto traces and
/// full SLO telemetry from a simulated pool.
pub fn simulate_pool_traced(mode: SimMode, workload: &[SimRequest], o: PoolSimOpts,
                            tracer: &mut Tracer) -> SimReport {
    assert!(o.engines >= 1 && o.q_total >= o.engines, "q_total must cover engines");
    assert!(o.update_batch >= 1, "update_batch must be >= 1");
    let q_each = o.q_total / o.engines;
    let q_cap = q_each * o.engines;
    let params = PolicyParams {
        refill_prompts: match mode {
            SimMode::Baseline => q_cap,
            _ => workload.len().max(1),
        },
        entries_per_prompt: 1,
        update_batch: o.update_batch,
    };
    let mut policy: Box<dyn SchedulePolicy> = match mode {
        SimMode::Baseline => Box::new(BaselinePolicy::new(params, false)),
        SimMode::SortedOnPolicy => Box::new(GroupPolicy::new(params, Mode::OnPolicy)),
        SimMode::SortedPartial => Box::new(GroupPolicy::new(params, Mode::Partial)),
        SimMode::Async => Box::new(AsyncUpdatePolicy::new(params, ASYNC_SYNC_EVERY)),
    };
    // same composition order as make_policy_full: governor inside stealing
    if o.kv_mode == KvMode::Paged {
        policy = Box::new(KvGovernor::wrap(policy));
    }
    if o.steal {
        policy = Box::new(WorkStealing::wrap(policy, StealConfig::default()));
    }
    let kv = KvConfig { mode: o.kv_mode, budget: o.kv_budget, page: o.kv_page.max(1) };
    let mut backend =
        SimBackend::new(workload, o.engines, q_each, o.cost, o.dispatch, o.predictor,
                        mode == SimMode::Async, kv);
    drive_traced(policy.as_mut(), &mut backend, tracer)
        .expect("sim backend is infallible; a driver error means a policy livelock");
    let mut report = backend.into_report(mode);
    if tracer.enabled() {
        report.slo = tracer.slo_summary();
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_workload(n: usize, len: usize) -> Vec<SimRequest> {
        (0..n)
            .map(|id| SimRequest { id, prompt_len: 64, output_len: len })
            .collect()
    }

    #[test]
    fn equal_lengths_baseline_has_no_bubble() {
        let w = uniform_workload(128, 500);
        let r = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        assert!(r.bubble_ratio < 0.01, "{}", r.bubble_ratio);
        assert_eq!(r.useful_tokens, 128 * 500);
        assert_eq!(r.wasted_tokens, 0);
    }

    #[test]
    fn longtail_baseline_has_large_bubble() {
        let w = longtail_workload(512, 8192, 1);
        let r = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        assert!(r.bubble_ratio > 0.4, "expected drain bubbles, got {}", r.bubble_ratio);
    }

    #[test]
    fn sorted_modes_cut_bubble_by_more_than_half() {
        let w = longtail_workload(512, 8192, 1);
        let base = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let onp = simulate(SimMode::SortedOnPolicy, &w, 128, 128, CostModel::default());
        let part = simulate(SimMode::SortedPartial, &w, 128, 128, CostModel::default());
        assert!(onp.bubble_ratio < base.bubble_ratio / 2.0,
                "on-policy {} vs base {}", onp.bubble_ratio, base.bubble_ratio);
        assert!(part.bubble_ratio < base.bubble_ratio / 2.0,
                "partial {} vs base {}", part.bubble_ratio, base.bubble_ratio);
    }

    #[test]
    fn throughput_order_partial_ge_onpolicy_ge_baseline() {
        let w = longtail_workload(512, 8192, 2);
        let base = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let onp = simulate(SimMode::SortedOnPolicy, &w, 128, 128, CostModel::default());
        let part = simulate(SimMode::SortedPartial, &w, 128, 128, CostModel::default());
        assert!(part.throughput > onp.throughput,
                "partial {} <= on-policy {}", part.throughput, onp.throughput);
        assert!(onp.throughput > base.throughput,
                "on-policy {} <= baseline {}", onp.throughput, base.throughput);
    }

    #[test]
    fn on_policy_wastes_tokens_partial_does_not() {
        let w = longtail_workload(256, 4096, 3);
        let onp = simulate(SimMode::SortedOnPolicy, &w, 64, 64, CostModel::default());
        let part = simulate(SimMode::SortedPartial, &w, 64, 64, CostModel::default());
        assert!(onp.wasted_tokens > 0);
        assert_eq!(part.wasted_tokens, 0);
        // and on-policy clips more than partial (Fig. 2's gray bars)
        assert!(onp.clipped >= part.clipped);
    }

    #[test]
    fn all_requests_accounted_exactly_once() {
        for mode in [SimMode::Baseline, SimMode::SortedOnPolicy, SimMode::SortedPartial] {
            let w = longtail_workload(200, 2048, 4);
            let r = simulate(mode, &w, 64, 50, CostModel::default());
            // natural completions + clipped harvests + dropped == workload
            assert_eq!(r.timeline.finished() as usize + r.clipped + r.dropped,
                       200, "{mode:?}");
            // token conservation: everything generated is useful or wasted
            assert!(r.useful_tokens > 0);
            if mode == SimMode::Baseline {
                assert_eq!(r.useful_tokens,
                           w.iter().map(|x| x.output_len as u64).sum::<u64>());
                assert_eq!(r.clipped, 0);
            }
        }
    }

    #[test]
    fn async_mode_conserves_and_beats_baseline_bubble() {
        let w = longtail_workload(512, 8192, 1);
        let base = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let asy = simulate(SimMode::Async, &w, 128, 128, CostModel::default());
        assert_eq!(asy.timeline.finished() as usize + asy.clipped + asy.dropped, 512);
        assert_eq!(asy.wasted_tokens, 0, "async resumes partials, never discards");
        assert!(asy.bubble_ratio < base.bubble_ratio / 2.0,
                "async {} vs baseline {}", asy.bubble_ratio, base.bubble_ratio);
        // the async win: update cost hides under continued decoding instead
        // of serializing behind a harvest barrier
        let serialized = asy.rollout_time + asy.infer_time + asy.update_time;
        assert!(asy.total_time < serialized,
                "async total {} !< serialized {}", asy.total_time, serialized);
        assert!(asy.harvests >= 2, "expected multiple overlapped updates");
    }

    #[test]
    fn async_total_time_beats_sync_partial() {
        let w = longtail_workload(512, 8192, 2);
        let part = simulate(SimMode::SortedPartial, &w, 128, 128, CostModel::default());
        let asy = simulate(SimMode::Async, &w, 128, 128, CostModel::default());
        // same resume semantics, but updates overlap decoding
        assert!(asy.total_time < part.total_time,
                "async {} !< partial {}", asy.total_time, part.total_time);
    }

    #[test]
    fn longtail_workload_is_longtailed() {
        let w = longtail_workload(2000, 8192, 5);
        let mut lens: Vec<usize> = w.iter().map(|r| r.output_len).collect();
        lens.sort_unstable();
        let median = lens[lens.len() / 2];
        let p95 = lens[lens.len() * 95 / 100];
        assert!(p95 > 3 * median, "median {median} p95 {p95}");
    }

    #[test]
    fn update_time_scales_with_tokens() {
        let w = uniform_workload(64, 100);
        let r = simulate(SimMode::Baseline, &w, 64, 64, CostModel::default());
        let w2 = uniform_workload(64, 200);
        let r2 = simulate(SimMode::Baseline, &w2, 64, 64, CostModel::default());
        assert!(r2.update_time > r.update_time * 1.5);
    }

    // ------------------------------------------------------------------
    // multi-engine pool
    // ------------------------------------------------------------------

    use crate::sched::{DispatchPolicy, PredictorKind};

    #[test]
    fn pool_baseline_conserves_requests_and_tokens() {
        let w = longtail_workload(200, 2048, 7);
        for engines in [1usize, 2, 4] {
            for policy in DispatchPolicy::ALL {
                let r = simulate_pool(SimMode::Baseline, &w, engines, 64, 50,
                                      CostModel::default(), policy,
                                      PredictorKind::Oracle);
                assert_eq!(r.timeline.finished() as usize, 200,
                           "{engines} engines, {}", policy.name());
                assert_eq!(r.useful_tokens,
                           w.iter().map(|x| x.output_len as u64).sum::<u64>());
                assert_eq!(r.wasted_tokens, 0);
                assert_eq!(r.engines, engines);
            }
        }
    }

    #[test]
    fn pool_oracle_predictor_is_exact() {
        let w = longtail_workload(128, 1024, 8);
        let r = simulate_pool(SimMode::Baseline, &w, 2, 32, 32,
                              CostModel::default(),
                              DispatchPolicy::ShortestPredictedFirst,
                              PredictorKind::Oracle);
        assert!(r.predictor_mae < 1e-9, "oracle MAE {}", r.predictor_mae);
        // ties (cap-clipped lengths, duplicate body lengths) keep tau-a
        // slightly below 1 even for a perfect oracle
        assert!(r.predictor_tau > 0.9, "oracle tau {}", r.predictor_tau);
    }

    #[test]
    fn pool_sorted_modes_account_every_request() {
        let w = longtail_workload(160, 2048, 9);
        for mode in [SimMode::SortedOnPolicy, SimMode::SortedPartial] {
            for engines in [1usize, 4] {
                let r = simulate_pool(mode, &w, engines, 64, 40,
                                      CostModel::default(),
                                      DispatchPolicy::ShortestPredictedFirst,
                                      PredictorKind::History);
                assert_eq!(r.timeline.finished() as usize + r.clipped + r.dropped,
                           160, "{mode:?} x{engines}");
                assert!(r.useful_tokens > 0);
                assert!(r.bubble_ratio >= 0.0 && r.bubble_ratio <= 1.0);
                if mode == SimMode::SortedPartial {
                    assert_eq!(r.wasted_tokens, 0, "partial never discards");
                }
            }
        }
    }

    #[test]
    fn pool_single_engine_partial_beats_baseline_bubble() {
        let w = longtail_workload(512, 8192, 1);
        let base = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let part = simulate_pool(SimMode::SortedPartial, &w, 1, 128, 128,
                                 CostModel::default(),
                                 DispatchPolicy::ShortestPredictedFirst,
                                 PredictorKind::Oracle);
        assert!(part.bubble_ratio < base.bubble_ratio / 2.0,
                "pool partial {} vs baseline {}", part.bubble_ratio, base.bubble_ratio);
    }

    #[test]
    fn pool_multi_engine_throughput_scales() {
        let w = longtail_workload(256, 4096, 11);
        let one = simulate_pool(SimMode::SortedPartial, &w, 1, 128, 64,
                                CostModel::default(),
                                DispatchPolicy::ShortestPredictedFirst,
                                PredictorKind::Oracle);
        let four = simulate_pool(SimMode::SortedPartial, &w, 4, 128, 64,
                                 CostModel::default(),
                                 DispatchPolicy::ShortestPredictedFirst,
                                 PredictorKind::Oracle);
        // 4 engines of 32 lanes stream weights in parallel: wall time drops
        assert!(four.rollout_time < one.rollout_time,
                "4-engine {}s vs 1-engine {}s", four.rollout_time, one.rollout_time);
        assert!(four.throughput > one.throughput);
    }

    #[test]
    fn pool_makespan_runs_everything() {
        let w = longtail_workload(96, 1024, 13);
        for policy in DispatchPolicy::ALL {
            let m = pool_makespan(&w, 3, 24, CostModel::default(), policy,
                                  PredictorKind::History);
            assert!(m > 0.0 && m.is_finite(), "{}", policy.name());
        }
    }

    #[test]
    fn pool_sjf_beats_static_round_robin_makespan() {
        let w = longtail_workload(512, 8192, 1);
        let cost = CostModel::default();
        let rr = pool_makespan(&w, 4, 128, cost, DispatchPolicy::RoundRobin,
                               PredictorKind::History);
        let sjf = pool_makespan(&w, 4, 128, cost,
                                DispatchPolicy::ShortestPredictedFirst,
                                PredictorKind::Oracle);
        // late-binding + predicted ordering rebalances the long tail that
        // static striping strands on one engine
        assert!(sjf < rr, "sjf {sjf} !< round-robin {rr}");
    }

    /// 2 engines × 2 lanes, unit iteration cost (`t_weights` 1s, all other
    /// costs zero), lengths [3,5,3,5] round-robined: e0 runs rids {0,2}
    /// (lanes 0/1, finish t=3), e1 runs {1,3} (lanes 0/1, finish t=5).
    /// Every expected value below is hand-derived from the cost model:
    /// enqueue+dispatch at t=0, first token after each engine's first
    /// 1-second iteration (TTFT = 1), one token per second thereafter
    /// (TPOT = 1), e2e = [3,3,5,5] so the interpolated p50 is 4 and p99
    /// is 5, and with a 4-second SLO exactly the two short requests make
    /// the deadline (goodput 0.5).
    fn golden_workload_and_opts() -> (Vec<SimRequest>, PoolSimOpts) {
        let w = vec![
            SimRequest { id: 0, prompt_len: 8, output_len: 3 },
            SimRequest { id: 1, prompt_len: 8, output_len: 5 },
            SimRequest { id: 2, prompt_len: 8, output_len: 3 },
            SimRequest { id: 3, prompt_len: 8, output_len: 5 },
        ];
        let cost = CostModel {
            t_weights: 1.0,
            t_token: 0.0,
            t_prefill_token: 0.0,
            t_update_token: 0.0,
            t_infer_token: 0.0,
        };
        let opts = PoolSimOpts {
            engines: 2,
            q_total: 4,
            update_batch: 4,
            cost,
            dispatch: DispatchPolicy::RoundRobin,
            predictor: PredictorKind::Oracle,
            slo: Some(4.0),
            ..PoolSimOpts::default()
        };
        (w, opts)
    }

    #[test]
    fn slo_golden_two_engine_hand_derived() {
        let (w, opts) = golden_workload_and_opts();
        let mut tracer = Tracer::new(Some(4.0), false);
        let r = simulate_pool_traced(SimMode::Baseline, &w, opts, &mut tracer);
        let s = &r.slo;
        assert_eq!((s.enqueued, s.completed, s.clipped, s.dropped), (4, 4, 0, 0));
        assert!((s.ttft_p50 - 1.0).abs() < 1e-9, "ttft_p50 {}", s.ttft_p50);
        assert!((s.ttft_p99 - 1.0).abs() < 1e-9, "ttft_p99 {}", s.ttft_p99);
        assert!((s.tpot_p50 - 1.0).abs() < 1e-9, "tpot_p50 {}", s.tpot_p50);
        assert!((s.tpot_p99 - 1.0).abs() < 1e-9, "tpot_p99 {}", s.tpot_p99);
        assert!((s.e2e_p50 - 4.0).abs() < 1e-9, "e2e_p50 {}", s.e2e_p50);
        assert!((s.e2e_p99 - 5.0).abs() < 1e-9, "e2e_p99 {}", s.e2e_p99);
        assert!(s.queue_p99.abs() < 1e-9, "queue_p99 {}", s.queue_p99);
        assert!((s.goodput - 0.5).abs() < 1e-9, "goodput {}", s.goodput);
        // spans: complete, ordered, consumed by the one update, attributed
        // to the engine/lane the round-robin stripe put them on
        assert_eq!(tracer.spans().len(), 4);
        for (rid, sp) in tracer.spans() {
            assert!(sp.is_ordered(), "rid {rid} out of order: {sp:?}");
            assert!(sp.is_complete(), "rid {rid} incomplete: {sp:?}");
            assert!(sp.consumed.is_some(), "rid {rid} never consumed");
        }
        let at = |rid: u64| {
            let sp = &tracer.spans()[&rid];
            (sp.engine, sp.lane, sp.finished)
        };
        assert_eq!(at(0), (Some(0), Some(0), Some(3.0)));
        assert_eq!(at(2), (Some(0), Some(1), Some(3.0)));
        assert_eq!(at(1), (Some(1), Some(0), Some(5.0)));
        assert_eq!(at(3), (Some(1), Some(1), Some(5.0)));
        // the PoolSimOpts::slo path computes the identical summary
        let r2 = simulate_pool_opts(SimMode::Baseline, &w, opts);
        assert_eq!(r2.slo.completed, 4);
        assert!((r2.slo.goodput - 0.5).abs() < 1e-9);
        assert!((r2.slo.e2e_p99 - 5.0).abs() < 1e-9);
    }

    #[test]
    fn chrome_trace_schema_round_trip() {
        use crate::util::json::Json;
        let (w, opts) = golden_workload_and_opts();
        let mut tracer = Tracer::new(None, true);
        simulate_pool_traced(SimMode::Baseline, &w, opts, &mut tracer);
        let text = tracer.chrome_json().expect("chrome tracer").to_string_pretty();
        let back = Json::parse(&text).expect("trace must be valid JSON");
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!evs.is_empty());
        // every event carries the Chrome trace-event required fields, and
        // counter-track timestamps are monotone per (pid, name)
        let mut last_c: BTreeMap<(i64, String), f64> = BTreeMap::new();
        for e in evs {
            for k in ["pid", "tid", "ts", "ph"] {
                assert!(e.get(k).is_some(), "missing {k}: {e:?}");
            }
            if e.get("ph").unwrap().as_str() == Some("C") {
                let key = (
                    e.get("pid").unwrap().as_i64().unwrap(),
                    e.get("name").unwrap().as_str().unwrap().to_string(),
                );
                let ts = e.get("ts").unwrap().as_f64().unwrap();
                if let Some(prev) = last_c.insert(key.clone(), ts) {
                    assert!(prev <= ts, "counter {key:?} went backward");
                }
            }
        }
        // required track names: engine processes, occupancy counters, and
        // one slice per request
        for needle in ["\"process_name\"", "\"engine 0\"", "\"engine 1\"",
                       "\"running\"", "\"queued\"", "\"req 0\"", "\"req 3\""] {
            assert!(text.contains(needle), "trace missing {needle}");
        }
    }
}
