//! Discrete-event rollout simulator.
//!
//! The paper's performance numbers (Fig. 1a/1b, Fig. 5) come from H100/MI300X
//! clusters serving 8B–32B models; this simulator reproduces their *shape*
//! with an explicit cost model of a bandwidth-bound serving engine:
//!
//!   iteration_time(r) = t_weights + r * t_token
//!
//! — every decode iteration streams the full weights once (the fixed cost
//! that makes low occupancy expensive, §2.2) plus per-request KV traffic.
//! Prefill is chunked and costs t_prefill_token per ingested token.  The
//! scheduling logic mirrors the real controller (oversubscription, early
//! termination at the batching threshold, on-policy restart vs partial
//! resume), so the same policies can be compared at paper scale (512
//! prompts, 8k-token caps) in milliseconds of host time.

use crate::metrics::{PredictorScore, Timeline};
use crate::sched::{make_predictor, sjf_priority, DispatchPolicy, LengthPredictor, PredictorKind};
use crate::util::rng::Pcg64;
use std::collections::VecDeque;

/// Serving-engine cost model (seconds).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Fixed per-iteration cost: weight streaming + kernel launch
    /// (the "captured graph" cost paid regardless of occupancy).
    pub t_weights: f64,
    /// Marginal per-running-request per-iteration cost (KV traffic).
    pub t_token: f64,
    /// Per-token prefill ingestion cost (chunked prefill).
    pub t_prefill_token: f64,
    /// Policy-update cost per trajectory token trained on (fwd+bwd).
    pub t_update_token: f64,
    /// Reward/reference inference cost per trajectory token.
    pub t_infer_token: f64,
}

impl Default for CostModel {
    /// Calibrated to Fig. 5's operating point (8B-class model, Q=128):
    /// full-batch decode = Q/(t_w + Q·t_t) ≈ 5.6k tok/s (the partial-mode
    /// ceiling) and ~26% mean occupancy yields ≈ 4.0k tok/s (the baseline),
    /// which solves to t_w ≈ 3.2 ms, t_t ≈ 0.155 ms.
    fn default() -> Self {
        CostModel {
            t_weights: 3.2e-3,
            t_token: 1.55e-4,
            t_prefill_token: 2e-6,
            t_update_token: 1.0e-4,
            t_infer_token: 2.5e-5,
        }
    }
}

/// One simulated request: predetermined prompt/output lengths (the paper's
/// Fig. 5 methodology — sampling parameters pinned so lengths match across
/// strategies).
#[derive(Debug, Clone, Copy)]
pub struct SimRequest {
    pub id: usize,
    pub prompt_len: usize,
    pub output_len: usize,
}

/// Long-tailed length workload matching Fig. 1c's shape: a lognormal body
/// (~80% of samples within 3/8 of the cap) plus ~6% of requests truncated
/// AT the generation cap — the paper observes "5% can extend up to the
/// token limit", and those cap-clipped requests are what the schedulers
/// fight over.
pub fn longtail_workload(n: usize, cap: usize, seed: u64) -> Vec<SimRequest> {
    let mut rng = Pcg64::with_stream(seed, 0x51);
    (0..n)
        .map(|id| {
            let len = if rng.bool_with(0.08) {
                cap // hit the generation limit
            } else {
                // body scaled to the cap: median ~0.11*cap (most responses
                // finish early — Fig. 1c's "80% within 3k of 16k"), with a
                // long right tail
                let body = rng.lognormal(0.0, 0.85) * 0.11 * cap as f64;
                (body as usize).clamp(16, cap)
            };
            SimRequest {
                id,
                prompt_len: 64 + rng.below(192) as usize,
                output_len: len,
            }
        })
        .collect()
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Run each rollout batch to completion (sync barrier).
    Baseline,
    /// SortedRL fully on-policy: early-terminate; interrupted requests
    /// restart from scratch (progress discarded).
    SortedOnPolicy,
    /// SortedRL partial: interrupted requests keep progress; resume costs
    /// a prefill over prompt + generated prefix.
    SortedPartial,
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub mode: SimMode,
    pub timeline: Timeline,
    pub total_time: f64,
    pub rollout_time: f64,
    pub update_time: f64,
    pub infer_time: f64,
    /// Tokens belonging to harvested trajectories.
    pub useful_tokens: u64,
    /// Tokens generated then discarded by on-policy restarts.
    pub wasted_tokens: u64,
    pub bubble_ratio: f64,
    /// Useful output tokens / rollout wall time.
    pub throughput: f64,
    pub harvests: usize,
    /// Trajectories harvested clipped (incomplete) at group end.
    pub clipped: usize,
    /// Prompts dropped without training (never scheduled at group end).
    pub dropped: usize,
    /// Engines the run was sharded across (1 for [`simulate`]).
    pub engines: usize,
    /// Length-predictor mean absolute error (pool runs; 0 otherwise).
    pub predictor_mae: f64,
    /// Length-predictor Kendall tau (pool runs; 0 otherwise).
    pub predictor_tau: f64,
}

struct Running {
    req: SimRequest,
    generated: usize,
}

/// Simulated engine with queue capacity `q`.
struct SimEngine {
    q: usize,
    cost: CostModel,
    clock: f64,
    running: Vec<Running>,
    queue: VecDeque<(SimRequest, usize)>, // (request, progress)
    timeline: Timeline,
    tokens_out: u64,
}

impl SimEngine {
    fn new(q: usize, cost: CostModel) -> Self {
        SimEngine {
            q,
            cost,
            clock: 0.0,
            running: Vec::new(),
            queue: VecDeque::new(),
            timeline: Timeline::new(),
            tokens_out: 0,
        }
    }

    fn record(&mut self) {
        self.timeline.set_running(self.clock, self.running.len());
    }

    fn admit(&mut self) {
        while self.running.len() < self.q {
            let Some((req, progress)) = self.queue.pop_front() else { break };
            // prefill cost: prompt + any preserved progress
            self.clock += (req.prompt_len + progress) as f64 * self.cost.t_prefill_token;
            self.running.push(Running { req, generated: progress });
        }
        self.record();
    }

    /// One decode iteration; returns finished requests.
    fn step(&mut self) -> Vec<SimRequest> {
        let r = self.running.len();
        if r == 0 {
            return Vec::new();
        }
        self.clock += self.cost.t_weights + r as f64 * self.cost.t_token;
        self.tokens_out += r as u64;
        let mut finished = Vec::new();
        self.running.retain_mut(|run| {
            run.generated += 1;
            if run.generated >= run.req.output_len {
                finished.push(run.req);
                false
            } else {
                true
            }
        });
        if !finished.is_empty() {
            self.timeline.add_finished(finished.len() as u64);
        }
        self.record();
        finished
    }

    /// Preempt all running lanes back to the queue tail, KEEPING progress
    /// (partial-mode rotation: costs only re-prefill on re-admission).
    fn rotate(&mut self) {
        let preempted: Vec<(SimRequest, usize)> = self
            .running
            .drain(..)
            .map(|r| (r.req, r.generated))
            .collect();
        self.queue.extend(preempted);
        self.record();
    }

    /// Re-order the waiting queue longest-progress-first (commit phase:
    /// progress == sensed length in partial mode).
    fn prioritize_queue_by_progress(&mut self) {
        let mut v: Vec<(SimRequest, usize)> = self.queue.drain(..).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.id.cmp(&b.0.id)));
        self.queue.extend(v);
    }

    /// Terminate everything in flight; returns (request, progress) pairs.
    fn terminate_all(&mut self) -> Vec<(SimRequest, usize)> {
        let mut out: Vec<(SimRequest, usize)> = self
            .running
            .drain(..)
            .map(|r| (r.req, r.generated))
            .collect();
        out.extend(self.queue.drain(..).map(|(req, p)| (req, p)));
        self.record();
        out
    }
}

/// Simulate one full consumption of `workload` (n_batches × batch prompts)
/// under `mode`, with `update_batch` trajectories per policy update.
pub fn simulate(mode: SimMode, workload: &[SimRequest], q: usize,
                update_batch: usize, cost: CostModel) -> SimReport {
    match mode {
        SimMode::Baseline => simulate_baseline(workload, q, update_batch, cost),
        _ => simulate_sorted(mode, workload, q, update_batch, cost),
    }
}

fn post_phase_costs(finished: &[SimRequest], cost: &CostModel) -> (f64, f64) {
    let toks: f64 = finished
        .iter()
        .map(|r| (r.prompt_len + r.output_len) as f64)
        .sum();
    (toks * cost.t_infer_token, toks * cost.t_update_token)
}

/// Baseline: split the workload into batches of `q`, each run to completion
/// behind a sync barrier, then updates in chunks of `update_batch`.
fn simulate_baseline(workload: &[SimRequest], q: usize, update_batch: usize,
                     cost: CostModel) -> SimReport {
    let mut eng = SimEngine::new(q, cost);
    let mut infer_time = 0.0;
    let mut update_time = 0.0;
    let mut harvests = 0;
    for batch in workload.chunks(q) {
        eng.queue.extend(batch.iter().map(|r| (*r, 0usize)));
        let mut finished: Vec<SimRequest> = Vec::new();
        while !eng.queue.is_empty() || !eng.running.is_empty() {
            eng.admit();
            finished.extend(eng.step());
        }
        // sync barrier: inference + k sequential updates while engine idles
        let (ti, tu) = post_phase_costs(&finished, &cost);
        infer_time += ti;
        update_time += tu;
        harvests += finished.len().div_ceil(update_batch);
    }
    let rollout_time = eng.clock;
    let useful: u64 = workload.iter().map(|r| r.output_len as u64).sum();
    let bubble = eng.timeline.bubble_ratio(q, eng.clock);
    SimReport {
        mode: SimMode::Baseline,
        total_time: rollout_time + infer_time + update_time,
        rollout_time,
        update_time,
        infer_time,
        useful_tokens: useful,
        wasted_tokens: eng.tokens_out - useful,
        bubble_ratio: bubble,
        throughput: useful as f64 / rollout_time,
        timeline: eng.timeline,
        harvests,
        clipped: 0,
        dropped: 0,
        engines: 1,
        predictor_mae: 0.0,
        predictor_tau: 0.0,
    }
}

/// Park threshold for on-policy: requests sensed longer than ~P60 of the
/// sensed lengths are deferred (they would just feed the restart shredder).
fn sensed_park_threshold(pending: &[(SimRequest, usize, usize)]) -> usize {
    let mut sensed: Vec<usize> = pending.iter().map(|e| e.2).filter(|&x| x > 0).collect();
    if sensed.len() < 8 {
        return usize::MAX;
    }
    sensed.sort_unstable();
    sensed[sensed.len() * 3 / 5].max(1)
}

/// SortedRL modes: the whole workload is one group pool; oversubscribe,
/// early-terminate when `update_batch` trajectories complete, scavenge or
/// restart the rest, update, re-feed.
fn simulate_sorted(mode: SimMode, workload: &[SimRequest], q: usize,
                   update_batch: usize, cost: CostModel) -> SimReport {
    let mut eng = SimEngine::new(q, cost);
    // (request, preserved_progress, sensed_length) — `sensed` is the
    // controller's online length estimate (max tokens ever generated for
    // this request, §3.1 "sensing the fine-grained dynamics"); it survives
    // on-policy restarts even though the tokens themselves are discarded.
    let mut pending: Vec<(SimRequest, usize, usize)> =
        workload.iter().map(|r| (*r, 0usize, 0usize)).collect();
    let mut infer_time = 0.0;
    let mut update_time = 0.0;
    let mut wasted: u64 = 0;
    let mut done = 0usize;
    let mut harvests = 0usize;
    let mut clipped = 0usize;
    let mut dropped = 0usize;
    let total = workload.len();

    while done < total {
        // Length-aware priority (§3.1 "sensing the fine-grained dynamics").
        // The two modes want opposite orders:
        //  * partial: progress survives interruption, so LONG-sensed
        //    requests keep their lanes (LRF-style) and the group's final
        //    wave drains compactly; a quarter of the queue head is
        //    reserved for never-run prompts (discovery).
        //  * on-policy: interrupted progress is DISCARDED, so giving lanes
        //    to requests that cannot finish before the next harvest only
        //    manufactures waste — schedule shortest-sensed first to
        //    maximize completions per wave (long ones run last and mostly
        //    get clipped at group end, the paper's gray bars).
        let order: Vec<(SimRequest, usize, usize)> = match mode {
            SimMode::SortedPartial => {
                pending.sort_by(|a, b| b.2.cmp(&a.2).then(a.0.id.cmp(&b.0.id)));
                let (runners, fresh): (Vec<_>, Vec<_>) =
                    pending.drain(..).partition(|e| e.2 > 0);
                let keep = (q * 3 / 4).min(runners.len());
                let mut v = Vec::with_capacity(runners.len() + fresh.len());
                v.extend_from_slice(&runners[..keep]);
                v.extend(fresh);
                v.extend_from_slice(&runners[keep..]);
                v
            }
            _ => {
                // Hard-park sensed-long requests mid-group: admitting a
                // request that cannot finish before the next harvest only
                // generates tokens that the on-policy restart will discard.
                // Parked requests rejoin for the group's final wave (where
                // they run once and clip).
                pending.sort_by(|a, b| a.2.cmp(&b.2).then(a.0.id.cmp(&b.0.id)));
                let final_wave_next = total - done <= 2 * update_batch;
                if final_wave_next {
                    pending.drain(..).collect()
                } else {
                    // `<=` keeps the threshold value itself runnable; when
                    // every request has identical sensed progress the run
                    // set must not be empty (everything would park forever).
                    let park_at = sensed_park_threshold(&pending);
                    let (run, park): (Vec<_>, Vec<_>) =
                        pending.drain(..).partition(|e| e.2 <= park_at);
                    if run.is_empty() {
                        park
                    } else {
                        pending = park;
                        run
                    }
                }
            }
        };
        // oversubscribe: everything pending goes to the engine queue
        eng.queue.extend(order.into_iter().map(|(r, p, _)| (r, p)));
        let mut ready: Vec<SimRequest> = Vec::new();
        // Partial-mode discovery rotation: preemption preserves progress, so
        // the controller time-slices the whole pool early in the group to
        // sense every prompt's length, then commits lanes to the
        // longest-sensed requests (LRF-style) so the group's long poles run
        // without interruption.  On-policy mode cannot rotate (preemption
        // discards tokens), which is why its bubble stays above partial's —
        // matching the paper's 5.81% vs 3.37% ordering.
        let rotate_every = 160usize;
        let discovery_budget = if mode == SimMode::SortedPartial {
            (total / q).max(1) * rotate_every
        } else {
            0
        };
        let mut iters = 0usize;
        // Final sub-batch of the group: instead of riding the drain tail to
        // occupancy 1 (what kills the baseline, Fig. 1b), the controller
        // harvests "both completed and partially generated outputs" (§3.1):
        // once occupancy falls below the batching floor it clips whatever
        // is still running into the update batch (Fig. 9a's clipped long
        // answers) and drops never-scheduled prompts (Fig. 2's gray bars).
        let final_wave = total - done <= update_batch;
        let occ_floor = (q * 3 / 4).max(1);
        while !eng.queue.is_empty() || !eng.running.is_empty() {
            if discovery_budget > 0 {
                if iters < discovery_budget && iters % rotate_every == 0 && iters > 0 {
                    eng.rotate();
                } else if iters == discovery_budget {
                    eng.rotate();
                    eng.prioritize_queue_by_progress();
                }
            }
            eng.admit();
            ready.extend(eng.step());
            iters += 1;
            let remaining = total - done - ready.len();
            let quota = update_batch.min(total - done);
            // Early-termination threshold (§3.1 "batching-related
            // thresholds"): on-policy fires once most of the quota has
            // completed and fills the remainder by clipping the
            // top-progress runners — waiting for the last few completions
            // is where discarded-progress waste piles up.  Partial mode
            // waits for full completions (resume is free).
            let threshold = match mode {
                SimMode::SortedOnPolicy => quota * 3 / 4,
                _ => quota,
            };
            if ready.len() >= threshold && remaining > 0 {
                break; // early termination: harvest threshold reached
            }
            if final_wave && eng.queue.is_empty() && eng.running.len() < occ_floor {
                break; // batching floor: clip the stragglers
            }
            if remaining == 0 && eng.running.is_empty() && eng.queue.is_empty() {
                break;
            }
        }
        // Terminate in-flight; harvest/scavenge per mode.
        let mut terminated = eng.terminate_all();
        // highest progress first — clipping candidates
        terminated.sort_by(|a, b| b.1.cmp(&a.1));
        let quota = update_batch.min(total - done);
        for (req, progress) in terminated {
            let need_clip = ready.len() < quota;
            match mode {
                // On-policy harvests "both completed and partially generated
                // outputs" (§3.1): the highest-progress runners are CLIPPED
                // into the update batch (their tokens are from the latest
                // policy, so this stays on-policy — Fig. 9a's clipped long
                // answers); the rest lose their progress and the prompt
                // retries (Fig. 2's gray "partially discarded" bars).
                SimMode::SortedOnPolicy => {
                    if need_clip && progress > 0 {
                        let mut clipped_req = req;
                        clipped_req.output_len = progress;
                        ready.push(clipped_req);
                        clipped += 1;
                    } else if final_wave {
                        // group end: never-scheduled prompts are dropped
                        wasted += progress as u64;
                        dropped += 1;
                        done += 1;
                    } else {
                        wasted += progress as u64;
                        pending.push((req, 0, progress));
                    }
                }
                // Partial mode never discards: resume mid-group, clip only
                // at the group's final wave.
                SimMode::SortedPartial => {
                    if final_wave {
                        if progress > 0 {
                            let mut clipped_req = req;
                            clipped_req.output_len = progress;
                            ready.push(clipped_req);
                            clipped += 1;
                        } else {
                            dropped += 1;
                            done += 1;
                        }
                    } else {
                        pending.push((req, progress, progress));
                    }
                }
                SimMode::Baseline => unreachable!(),
            }
        }
        if ready.is_empty() {
            break;
        }
        done += ready.len();
        harvests += 1;
        let (ti, tu) = post_phase_costs(&ready, &cost);
        infer_time += ti;
        update_time += tu;
    }

    let rollout_time = eng.clock;
    // useful = tokens of trajectories actually harvested (clipping shortens)
    let useful: u64 = eng.tokens_out - wasted;
    let bubble = eng.timeline.bubble_ratio(q, eng.clock);
    SimReport {
        mode,
        total_time: rollout_time + infer_time + update_time,
        rollout_time,
        update_time,
        infer_time,
        useful_tokens: useful,
        wasted_tokens: wasted,
        bubble_ratio: bubble,
        throughput: useful as f64 / rollout_time,
        timeline: eng.timeline,
        harvests,
        clipped,
        dropped,
        engines: 1,
        predictor_mae: 0.0,
        predictor_tau: 0.0,
    }
}

// ==========================================================================
// Multi-engine pool simulation (the `sched` layer's simulator mirror)
// ==========================================================================

/// Engine pool over [`SimEngine`]s: a central queue (or static stripes for
/// round-robin) plus event-driven stepping — always advance the
/// earliest-clock engine with work, so engine clocks stay within one
/// decode iteration of each other (parallel devices).
struct SimPool {
    engines: Vec<SimEngine>,
    central: VecDeque<(SimRequest, usize)>,
    policy: DispatchPolicy,
    rr: usize,
}

impl SimPool {
    fn new(n: usize, q_each: usize, cost: CostModel, policy: DispatchPolicy) -> Self {
        SimPool {
            engines: (0..n).map(|_| SimEngine::new(q_each, cost)).collect(),
            central: VecDeque::new(),
            policy,
            rr: 0,
        }
    }

    /// Stage a wave of (request, progress) work per the dispatch policy.
    /// Round-robin statically stripes (the FCFS baseline); least-loaded
    /// keeps a FIFO central queue that engines pull from as lanes free;
    /// SJF keeps the central queue sorted by predicted remaining length so
    /// each engine pulls a contiguous, similar-length run.
    fn stage(&mut self, work: Vec<(SimRequest, usize)>, pred: &dyn LengthPredictor) {
        match self.policy {
            DispatchPolicy::RoundRobin => {
                for w in work {
                    let i = self.rr % self.engines.len();
                    self.rr += 1;
                    self.engines[i].queue.push_back(w);
                }
            }
            DispatchPolicy::LeastLoaded => self.central.extend(work),
            DispatchPolicy::ShortestPredictedFirst => {
                // sjf_priority is THE policy shared with the real
                // EnginePool; keys computed once, not in the comparator
                let mut keyed: Vec<(f64, (SimRequest, usize))> = work
                    .into_iter()
                    .map(|w| (sjf_priority(pred, w.0.id as u64, w.0.prompt_len, w.1), w))
                    .collect();
                keyed.sort_by(|a, b| {
                    a.0.partial_cmp(&b.0).unwrap().then((a.1).0.id.cmp(&(b.1).0.id))
                });
                self.central.extend(keyed.into_iter().map(|(_, w)| w));
            }
        }
    }

    /// Pull central-queue work into engine `i`'s free lanes (late binding).
    fn refill(&mut self, i: usize) {
        if self.policy == DispatchPolicy::RoundRobin {
            return;
        }
        loop {
            let e = &self.engines[i];
            if e.running.len() + e.queue.len() >= e.q {
                break;
            }
            let Some(w) = self.central.pop_front() else { break };
            self.engines[i].queue.push_back(w);
        }
    }

    fn has_work(&self, i: usize) -> bool {
        let e = &self.engines[i];
        !e.running.is_empty()
            || !e.queue.is_empty()
            || (self.policy != DispatchPolicy::RoundRobin && !self.central.is_empty())
    }

    fn total_running(&self) -> usize {
        self.engines.iter().map(|e| e.running.len()).sum()
    }

    fn queued(&self) -> usize {
        self.central.len() + self.engines.iter().map(|e| e.queue.len()).sum::<usize>()
    }

    /// Advance the earliest-clock engine with work by one admit + decode
    /// iteration; returns its finishes, or None when the pool is drained.
    fn tick(&mut self) -> Option<Vec<SimRequest>> {
        let i = (0..self.engines.len())
            .filter(|&i| self.has_work(i))
            .min_by(|&a, &b| {
                self.engines[a]
                    .clock
                    .partial_cmp(&self.engines[b].clock)
                    .unwrap()
            })?;
        self.refill(i);
        self.engines[i].admit();
        Some(self.engines[i].step())
    }

    /// Terminate everything pool-wide -> (request, progress) pairs.
    fn terminate_all(&mut self) -> Vec<(SimRequest, usize)> {
        let mut out = Vec::new();
        for e in self.engines.iter_mut() {
            out.extend(e.terminate_all());
        }
        out.extend(self.central.drain(..));
        out
    }

    /// Sync barrier: jump every engine clock to the pool max (harvest / wave
    /// end).  The gap between an engine's own finish time and the barrier is
    /// genuine rollout-phase idle; the timeline's trailing interval (last
    /// recorded running count, usually 0) accounts for it.
    fn align_clocks(&mut self) {
        let end = self.clock();
        for e in self.engines.iter_mut() {
            e.clock = end;
        }
    }

    fn clock(&self) -> f64 {
        self.engines.iter().map(|e| e.clock).fold(0.0, f64::max)
    }

    fn tokens_out(&self) -> u64 {
        self.engines.iter().map(|e| e.tokens_out).sum()
    }
}

/// Merge per-engine occupancy timelines into one pool timeline whose
/// running count is the sum across engines (tokens and finish counts sum
/// too), so [`Timeline::bubble_ratio`] with the pool's total capacity gives
/// the aggregate bubble.
fn merge_timelines(engines: &[SimEngine]) -> Timeline {
    let mut merged = Timeline::new();
    let mut events: Vec<(f64, usize, usize)> = Vec::new();
    for (idx, e) in engines.iter().enumerate() {
        for &(t, r) in e.timeline.events() {
            events.push((t, idx, r));
        }
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut cur = vec![0usize; engines.len()];
    let mut total = 0usize;
    for (t, idx, r) in events {
        total = total + r - cur[idx];
        cur[idx] = r;
        merged.set_running(t, total);
    }
    let mut tokens = 0u64;
    let mut finished = 0u64;
    for e in engines {
        // SimEngine counts tokens in its own field — its timeline is
        // never fed add_tokens (unlike the real rollout::Engine)
        tokens += e.tokens_out;
        finished += e.timeline.finished();
    }
    merged.add_tokens(tokens);
    merged.add_finished(finished);
    merged
}

fn make_sim_predictor(kind: PredictorKind, workload: &[SimRequest]) -> Box<dyn LengthPredictor> {
    let mut pred = make_predictor(kind);
    if kind == PredictorKind::Oracle {
        // the oracle reads true cost: simulator ground truth
        for r in workload {
            pred.observe(r.id as u64, r.prompt_len, r.output_len);
        }
    }
    pred
}

/// Run `workload` to completion on an engine pool — one oversubscribed
/// wave, no harvests or updates — and return the makespan in seconds.
/// This is the dispatch-policy comparison number `sched_bench` prints.
///
/// Learning predictors (history/bucket) are warmed up on NOISY
/// observations of the workload first: the RL regime re-rolls the same
/// prompts every policy update, so by the time scheduling matters the
/// predictor has seen sibling samples / earlier epochs of each prompt —
/// which *estimate*, not reveal, this round's exact length.  (Cold
/// predictions are uncorrelated with true lengths, so a cold run would
/// measure only late-binding dispatch; an exact warmup would make history
/// indistinguishable from the oracle, since sim requests are keyed
/// individually.)  The ~±35% lognormal noise leaves rank quality high but
/// keeps the oracle a genuine ceiling.
pub fn pool_makespan(workload: &[SimRequest], engines: usize, q_total: usize,
                     cost: CostModel, dispatch: DispatchPolicy,
                     predictor: PredictorKind) -> f64 {
    assert!(engines >= 1 && q_total >= engines, "q_total must cover engines");
    let mut pred = make_sim_predictor(predictor, workload);
    if predictor != PredictorKind::Oracle {
        let mut rng = Pcg64::with_stream(0x5EED_17, 0x9E);
        for r in workload {
            let noisy = (r.output_len as f64 * rng.lognormal(0.0, 0.35))
                .clamp(1.0, 4.0 * r.output_len as f64);
            pred.observe(r.id as u64, r.prompt_len, noisy as usize);
        }
    }
    let mut pool = SimPool::new(engines, q_total / engines, cost, dispatch);
    pool.stage(workload.iter().map(|r| (*r, 0usize)).collect(), pred.as_ref());
    while pool.tick().is_some() {}
    pool.clock()
}

/// Multi-engine pool simulation: the same group-pool semantics as
/// [`simulate`] (oversubscription, early termination at the batching
/// threshold, per-mode scavenge/restart), but sharded across `engines`
/// engines of `q_total/engines` lanes each, with admission ordered by a
/// [`LengthPredictor`] instead of the single-engine sense-by-generating
/// rotation.  `engines == 1` gives the single-engine member of the same
/// scheduler family, so 1-vs-N comparisons isolate the sharding effect.
///
/// `q_total` is rounded down to a multiple of `engines`.
pub fn simulate_pool(mode: SimMode, workload: &[SimRequest], engines: usize,
                     q_total: usize, update_batch: usize, cost: CostModel,
                     dispatch: DispatchPolicy, predictor: PredictorKind) -> SimReport {
    assert!(engines >= 1 && q_total >= engines, "q_total must cover engines");
    assert!(update_batch >= 1, "update_batch must be >= 1");
    let q_each = q_total / engines;
    let q_cap = q_each * engines;
    let mut pool = SimPool::new(engines, q_each, cost, dispatch);
    let mut pred = make_sim_predictor(predictor, workload);
    let mut score = PredictorScore::default();
    let mut infer_time = 0.0;
    let mut update_time = 0.0;
    let mut harvests = 0usize;

    // Predictions are scored as captured at STAGE time — what actually
    // drove the dispatch decision — not recomputed after siblings finished.
    let mut staged_pred: std::collections::BTreeMap<usize, f64> =
        std::collections::BTreeMap::new();

    if mode == SimMode::Baseline {
        // waves of q_cap behind a sync barrier, run to completion
        for batch in workload.chunks(q_cap) {
            for r in batch {
                staged_pred.insert(r.id, pred.predict(r.id as u64, r.prompt_len));
            }
            pool.stage(batch.iter().map(|r| (*r, 0usize)).collect(), pred.as_ref());
            let mut finished: Vec<SimRequest> = Vec::new();
            while let Some(f) = pool.tick() {
                for r in &f {
                    let p = staged_pred
                        .remove(&r.id)
                        .unwrap_or_else(|| pred.predict(r.id as u64, r.prompt_len));
                    score.push(p, r.output_len as f64);
                    pred.observe(r.id as u64, r.prompt_len, r.output_len);
                }
                finished.extend(f);
            }
            pool.align_clocks();
            let (ti, tu) = post_phase_costs(&finished, &cost);
            infer_time += ti;
            update_time += tu;
            harvests += finished.len().div_ceil(update_batch.max(1));
        }
        let rollout_time = pool.clock();
        let useful: u64 = workload.iter().map(|r| r.output_len as u64).sum();
        let timeline = merge_timelines(&pool.engines);
        let bubble = timeline.bubble_ratio(q_cap, rollout_time);
        return SimReport {
            mode,
            total_time: rollout_time + infer_time + update_time,
            rollout_time,
            update_time,
            infer_time,
            useful_tokens: useful,
            wasted_tokens: pool.tokens_out() - useful,
            bubble_ratio: bubble,
            throughput: useful as f64 / rollout_time,
            timeline,
            harvests,
            clipped: 0,
            dropped: 0,
            engines,
            predictor_mae: score.mae(),
            predictor_tau: score.kendall_tau(),
        };
    }

    // SortedRL modes: one group pool, early-terminate at the batching
    // threshold, clip/restart/resume per mode (mirrors simulate_sorted's
    // harvest accounting so reports are directly comparable).
    let total = workload.len();
    let mut pending: Vec<(SimRequest, usize)> =
        workload.iter().map(|r| (*r, 0usize)).collect();
    let mut done = 0usize;
    let mut wasted = 0u64;
    let mut clipped = 0usize;
    let mut dropped = 0usize;

    while done < total {
        let work = std::mem::take(&mut pending);
        for (req, _) in &work {
            staged_pred.insert(req.id, pred.predict(req.id as u64, req.prompt_len));
        }
        pool.stage(work, pred.as_ref());
        let quota = update_batch.min(total - done);
        let threshold = match mode {
            SimMode::SortedOnPolicy => (quota * 3 / 4).max(1),
            _ => quota,
        };
        let final_wave = total - done <= update_batch;
        let occ_floor = (q_cap * 3 / 4).max(1);
        let mut ready: Vec<SimRequest> = Vec::new();
        loop {
            let Some(f) = pool.tick() else { break };
            for r in &f {
                let p = staged_pred
                    .remove(&r.id)
                    .unwrap_or_else(|| pred.predict(r.id as u64, r.prompt_len));
                score.push(p, r.output_len as f64);
                pred.observe(r.id as u64, r.prompt_len, r.output_len);
            }
            ready.extend(f);
            let remaining = total - done - ready.len();
            if ready.len() >= threshold && remaining > 0 {
                break; // early termination: harvest threshold reached
            }
            if final_wave && pool.queued() == 0 && pool.total_running() < occ_floor {
                break; // batching floor: clip the stragglers
            }
        }
        let mut terminated = pool.terminate_all();
        pool.align_clocks();
        // highest progress first — clipping candidates
        terminated.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.id.cmp(&b.0.id)));
        for (req, progress) in terminated {
            // preemption progress is a length floor the predictor can use
            pred.observe_progress(req.id as u64, req.prompt_len, progress);
            let need_clip = ready.len() < quota;
            match mode {
                SimMode::SortedOnPolicy => {
                    if need_clip && progress > 0 {
                        let mut c = req;
                        c.output_len = progress;
                        ready.push(c);
                        clipped += 1;
                    } else if final_wave {
                        wasted += progress as u64;
                        dropped += 1;
                        done += 1;
                    } else {
                        wasted += progress as u64;
                        pending.push((req, 0));
                    }
                }
                SimMode::SortedPartial => {
                    if final_wave {
                        if progress > 0 {
                            let mut c = req;
                            c.output_len = progress;
                            ready.push(c);
                            clipped += 1;
                        } else {
                            dropped += 1;
                            done += 1;
                        }
                    } else {
                        pending.push((req, progress));
                    }
                }
                SimMode::Baseline => unreachable!(),
            }
        }
        if ready.is_empty() {
            break;
        }
        done += ready.len();
        harvests += 1;
        let (ti, tu) = post_phase_costs(&ready, &cost);
        infer_time += ti;
        update_time += tu;
    }

    let rollout_time = pool.clock();
    let useful = pool.tokens_out() - wasted;
    let timeline = merge_timelines(&pool.engines);
    let bubble = timeline.bubble_ratio(q_cap, rollout_time);
    SimReport {
        mode,
        total_time: rollout_time + infer_time + update_time,
        rollout_time,
        update_time,
        infer_time,
        useful_tokens: useful,
        wasted_tokens: wasted,
        bubble_ratio: bubble,
        throughput: useful as f64 / rollout_time,
        timeline,
        harvests,
        clipped,
        dropped,
        engines,
        predictor_mae: score.mae(),
        predictor_tau: score.kendall_tau(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_workload(n: usize, len: usize) -> Vec<SimRequest> {
        (0..n)
            .map(|id| SimRequest { id, prompt_len: 64, output_len: len })
            .collect()
    }

    #[test]
    fn equal_lengths_baseline_has_no_bubble() {
        let w = uniform_workload(128, 500);
        let r = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        assert!(r.bubble_ratio < 0.01, "{}", r.bubble_ratio);
        assert_eq!(r.useful_tokens, 128 * 500);
        assert_eq!(r.wasted_tokens, 0);
    }

    #[test]
    fn longtail_baseline_has_large_bubble() {
        let w = longtail_workload(512, 8192, 1);
        let r = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        assert!(r.bubble_ratio > 0.4, "expected drain bubbles, got {}", r.bubble_ratio);
    }

    #[test]
    fn sorted_modes_cut_bubble_by_more_than_half() {
        let w = longtail_workload(512, 8192, 1);
        let base = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let onp = simulate(SimMode::SortedOnPolicy, &w, 128, 128, CostModel::default());
        let part = simulate(SimMode::SortedPartial, &w, 128, 128, CostModel::default());
        assert!(onp.bubble_ratio < base.bubble_ratio / 2.0,
                "on-policy {} vs base {}", onp.bubble_ratio, base.bubble_ratio);
        assert!(part.bubble_ratio < base.bubble_ratio / 2.0,
                "partial {} vs base {}", part.bubble_ratio, base.bubble_ratio);
    }

    #[test]
    fn throughput_order_partial_ge_onpolicy_ge_baseline() {
        let w = longtail_workload(512, 8192, 2);
        let base = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let onp = simulate(SimMode::SortedOnPolicy, &w, 128, 128, CostModel::default());
        let part = simulate(SimMode::SortedPartial, &w, 128, 128, CostModel::default());
        assert!(part.throughput > onp.throughput,
                "partial {} <= on-policy {}", part.throughput, onp.throughput);
        assert!(onp.throughput > base.throughput,
                "on-policy {} <= baseline {}", onp.throughput, base.throughput);
    }

    #[test]
    fn on_policy_wastes_tokens_partial_does_not() {
        let w = longtail_workload(256, 4096, 3);
        let onp = simulate(SimMode::SortedOnPolicy, &w, 64, 64, CostModel::default());
        let part = simulate(SimMode::SortedPartial, &w, 64, 64, CostModel::default());
        assert!(onp.wasted_tokens > 0);
        assert_eq!(part.wasted_tokens, 0);
        // and on-policy clips more than partial (Fig. 2's gray bars)
        assert!(onp.clipped >= part.clipped);
    }

    #[test]
    fn all_requests_accounted_exactly_once() {
        for mode in [SimMode::Baseline, SimMode::SortedOnPolicy, SimMode::SortedPartial] {
            let w = longtail_workload(200, 2048, 4);
            let r = simulate(mode, &w, 64, 50, CostModel::default());
            // natural completions + clipped harvests + dropped == workload
            assert_eq!(r.timeline.finished() as usize + r.clipped + r.dropped,
                       200, "{mode:?}");
            // token conservation: everything generated is useful or wasted
            assert!(r.useful_tokens > 0);
            if mode == SimMode::Baseline {
                assert_eq!(r.useful_tokens,
                           w.iter().map(|x| x.output_len as u64).sum::<u64>());
                assert_eq!(r.clipped, 0);
            }
        }
    }

    #[test]
    fn longtail_workload_is_longtailed() {
        let w = longtail_workload(2000, 8192, 5);
        let mut lens: Vec<usize> = w.iter().map(|r| r.output_len).collect();
        lens.sort_unstable();
        let median = lens[lens.len() / 2];
        let p95 = lens[lens.len() * 95 / 100];
        assert!(p95 > 3 * median, "median {median} p95 {p95}");
    }

    #[test]
    fn update_time_scales_with_tokens() {
        let w = uniform_workload(64, 100);
        let r = simulate(SimMode::Baseline, &w, 64, 64, CostModel::default());
        let w2 = uniform_workload(64, 200);
        let r2 = simulate(SimMode::Baseline, &w2, 64, 64, CostModel::default());
        assert!(r2.update_time > r.update_time * 1.5);
    }

    // ------------------------------------------------------------------
    // multi-engine pool
    // ------------------------------------------------------------------

    use crate::sched::{DispatchPolicy, PredictorKind};

    #[test]
    fn pool_baseline_conserves_requests_and_tokens() {
        let w = longtail_workload(200, 2048, 7);
        for engines in [1usize, 2, 4] {
            for policy in DispatchPolicy::ALL {
                let r = simulate_pool(SimMode::Baseline, &w, engines, 64, 50,
                                      CostModel::default(), policy,
                                      PredictorKind::Oracle);
                assert_eq!(r.timeline.finished() as usize, 200,
                           "{engines} engines, {}", policy.name());
                assert_eq!(r.useful_tokens,
                           w.iter().map(|x| x.output_len as u64).sum::<u64>());
                assert_eq!(r.wasted_tokens, 0);
                assert_eq!(r.engines, engines);
            }
        }
    }

    #[test]
    fn pool_oracle_predictor_is_exact() {
        let w = longtail_workload(128, 1024, 8);
        let r = simulate_pool(SimMode::Baseline, &w, 2, 32, 32,
                              CostModel::default(),
                              DispatchPolicy::ShortestPredictedFirst,
                              PredictorKind::Oracle);
        assert!(r.predictor_mae < 1e-9, "oracle MAE {}", r.predictor_mae);
        // ties (cap-clipped lengths, duplicate body lengths) keep tau-a
        // slightly below 1 even for a perfect oracle
        assert!(r.predictor_tau > 0.9, "oracle tau {}", r.predictor_tau);
    }

    #[test]
    fn pool_sorted_modes_account_every_request() {
        let w = longtail_workload(160, 2048, 9);
        for mode in [SimMode::SortedOnPolicy, SimMode::SortedPartial] {
            for engines in [1usize, 4] {
                let r = simulate_pool(mode, &w, engines, 64, 40,
                                      CostModel::default(),
                                      DispatchPolicy::ShortestPredictedFirst,
                                      PredictorKind::History);
                assert_eq!(r.timeline.finished() as usize + r.clipped + r.dropped,
                           160, "{mode:?} x{engines}");
                assert!(r.useful_tokens > 0);
                assert!(r.bubble_ratio >= 0.0 && r.bubble_ratio <= 1.0);
                if mode == SimMode::SortedPartial {
                    assert_eq!(r.wasted_tokens, 0, "partial never discards");
                }
            }
        }
    }

    #[test]
    fn pool_single_engine_partial_beats_baseline_bubble() {
        let w = longtail_workload(512, 8192, 1);
        let base = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let part = simulate_pool(SimMode::SortedPartial, &w, 1, 128, 128,
                                 CostModel::default(),
                                 DispatchPolicy::ShortestPredictedFirst,
                                 PredictorKind::Oracle);
        assert!(part.bubble_ratio < base.bubble_ratio / 2.0,
                "pool partial {} vs baseline {}", part.bubble_ratio, base.bubble_ratio);
    }

    #[test]
    fn pool_multi_engine_throughput_scales() {
        let w = longtail_workload(256, 4096, 11);
        let one = simulate_pool(SimMode::SortedPartial, &w, 1, 128, 64,
                                CostModel::default(),
                                DispatchPolicy::ShortestPredictedFirst,
                                PredictorKind::Oracle);
        let four = simulate_pool(SimMode::SortedPartial, &w, 4, 128, 64,
                                 CostModel::default(),
                                 DispatchPolicy::ShortestPredictedFirst,
                                 PredictorKind::Oracle);
        // 4 engines of 32 lanes stream weights in parallel: wall time drops
        assert!(four.rollout_time < one.rollout_time,
                "4-engine {}s vs 1-engine {}s", four.rollout_time, one.rollout_time);
        assert!(four.throughput > one.throughput);
    }

    #[test]
    fn pool_makespan_runs_everything() {
        let w = longtail_workload(96, 1024, 13);
        for policy in DispatchPolicy::ALL {
            let m = pool_makespan(&w, 3, 24, CostModel::default(), policy,
                                  PredictorKind::History);
            assert!(m > 0.0 && m.is_finite(), "{}", policy.name());
        }
    }

    #[test]
    fn pool_sjf_beats_static_round_robin_makespan() {
        let w = longtail_workload(512, 8192, 1);
        let cost = CostModel::default();
        let rr = pool_makespan(&w, 4, 128, cost, DispatchPolicy::RoundRobin,
                               PredictorKind::History);
        let sjf = pool_makespan(&w, 4, 128, cost,
                                DispatchPolicy::ShortestPredictedFirst,
                                PredictorKind::Oracle);
        // late-binding + predicted ordering rebalances the long tail that
        // static striping strands on one engine
        assert!(sjf < rr, "sjf {sjf} !< round-robin {rr}");
    }
}
