//! Discrete-event rollout simulator.
//!
//! The paper's performance numbers (Fig. 1a/1b, Fig. 5) come from H100/MI300X
//! clusters serving 8B–32B models; this simulator reproduces their *shape*
//! with an explicit cost model of a bandwidth-bound serving engine:
//!
//!   iteration_time(r) = t_weights + r * t_token
//!
//! — every decode iteration streams the full weights once (the fixed cost
//! that makes low occupancy expensive, §2.2) plus per-request KV traffic.
//! Prefill is chunked and costs t_prefill_token per ingested token.  The
//! scheduling logic mirrors the real controller (oversubscription, early
//! termination at the batching threshold, on-policy restart vs partial
//! resume), so the same policies can be compared at paper scale (512
//! prompts, 8k-token caps) in milliseconds of host time.
//!
//! Module layout:
//! * [`engine`](self) (`engine.rs`) — one simulated engine: lanes, local
//!   queue, incremental KV accounting, fused silent-span arithmetic.
//! * `pool.rs` — the engine pool and the two stepping cores
//!   ([`SimCore::Event`] heap-ordered decisions vs [`SimCore::Reference`]
//!   linear min-scan).
//! * `heap.rs` — the lazy-deletion event heap and the suffix-max mark
//!   stack behind exact span materialization.
//! * `backend.rs` — the `ScheduleBackend` adapter driving policy
//!   decisions against the pool.

mod backend;
mod engine;
mod heap;
mod pool;

pub use pool::SimCore;

use crate::coordinator::controller::SchedulerKind;
use crate::metrics::Timeline;
use crate::rollout::kv::{KvConfig, KvMode};
use crate::sched::policy::{drive_traced, PolicyBuilder, PolicyParams};
use crate::sched::tail::TailConfig;
use crate::sched::{sjf_priority, DispatchPolicy, EngineSpec, LengthPredictor, PredictorKind};
use crate::trace::{SloSummary, Tracer};
use crate::util::rng::Pcg64;
use crate::workload::Arrival;
use backend::{make_sim_predictor, SimBackend};
use engine::{stamp_work, SimWork};
use pool::{PoolArrival, SimPool};
use std::collections::BTreeMap;

/// Serving-engine cost model (seconds).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Fixed per-iteration cost: weight streaming + kernel launch
    /// (the "captured graph" cost paid regardless of occupancy).
    pub t_weights: f64,
    /// Marginal per-running-request per-iteration cost (KV traffic).
    pub t_token: f64,
    /// Per-token prefill ingestion cost (chunked prefill).
    pub t_prefill_token: f64,
    /// Policy-update cost per trajectory token trained on (fwd+bwd).
    pub t_update_token: f64,
    /// Reward/reference inference cost per trajectory token.
    pub t_infer_token: f64,
}

impl Default for CostModel {
    /// Calibrated to Fig. 5's operating point (8B-class model, Q=128):
    /// full-batch decode = Q/(t_w + Q·t_t) ≈ 5.6k tok/s (the partial-mode
    /// ceiling) and ~26% mean occupancy yields ≈ 4.0k tok/s (the baseline),
    /// which solves to t_w ≈ 3.2 ms, t_t ≈ 0.155 ms.
    fn default() -> Self {
        CostModel {
            t_weights: 3.2e-3,
            t_token: 1.55e-4,
            t_prefill_token: 2e-6,
            t_update_token: 1.0e-4,
            t_infer_token: 2.5e-5,
        }
    }
}

/// One simulated request: predetermined prompt/output lengths (the paper's
/// Fig. 5 methodology — sampling parameters pinned so lengths match across
/// strategies).
#[derive(Debug, Clone, Copy)]
pub struct SimRequest {
    pub id: usize,
    pub prompt_len: usize,
    pub output_len: usize,
}

// The long-tail length sampler lives in `workload` now (one construction
// path shared with the arrival generators and trace replay); the historical
// `sim::longtail_workload` path keeps working via this re-export.
pub use crate::workload::longtail_workload;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Run each rollout batch to completion (sync barrier).
    Baseline,
    /// SortedRL fully on-policy: early-terminate; interrupted requests
    /// restart from scratch (progress discarded).
    SortedOnPolicy,
    /// SortedRL partial: interrupted requests keep progress; resume costs
    /// a prefill over prompt + generated prefix.
    SortedPartial,
    /// Async updates: the trainer update overlaps continued decoding (no
    /// harvest barrier; partial-mode scavenge bounds staleness).  The
    /// modeled update cost hides under the engine clocks instead of
    /// serializing into `total_time`.
    Async,
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub mode: SimMode,
    pub timeline: Timeline,
    pub total_time: f64,
    pub rollout_time: f64,
    pub update_time: f64,
    pub infer_time: f64,
    /// Tokens belonging to harvested trajectories.
    pub useful_tokens: u64,
    /// Tokens generated then discarded by on-policy restarts.
    pub wasted_tokens: u64,
    pub bubble_ratio: f64,
    /// Useful output tokens / rollout wall time.
    pub throughput: f64,
    pub harvests: usize,
    /// Trajectories harvested clipped (incomplete) at group end.
    pub clipped: usize,
    /// Prompts dropped without training (never scheduled at group end).
    pub dropped: usize,
    /// Engines the run was sharded across (1 for [`simulate`]).
    pub engines: usize,
    /// Length-predictor mean absolute error (pool runs; 0 otherwise).
    pub predictor_mae: f64,
    /// Length-predictor Kendall tau (pool runs; 0 otherwise).
    pub predictor_tau: f64,
    /// Cross-engine migrations executed (work stealing; 0 when disabled).
    pub steals: u64,
    /// Partial-progress tokens carried across engines by steals.
    pub migrated_tokens: u64,
    /// Per-engine idle fraction over the rollout span — the load-imbalance
    /// breakdown stealing is meant to flatten (1.0 = engine never ran).
    pub engine_idle: Vec<f64>,
    /// Highest concurrent running-lane count across the pool — the
    /// admitted-lane headline paged KV accounting is meant to raise at a
    /// fixed budget.
    pub peak_lanes: usize,
    /// Lanes force-evicted by the paged in-step backpressure path.
    pub kv_sheds: u64,
    /// Lanes shed by executed `Decision::Throttle`s (the KvGovernor).
    pub throttles: u64,
    /// Tail rounds opened by the `TailPacking` wrapper (0 when off).
    pub tail_rounds: u64,
    /// Requests admitted through tail rounds.
    pub tail_admitted: u64,
    /// Applied `Decision::Repartition`s (round-boundary donations plus
    /// their mirror restores).
    pub repartitions: u64,
    /// Head-group bubble over the rollout span.  Equals `bubble_ratio`
    /// when no tail group is configured; with one, tail packing should
    /// push this DOWN while `tail_bubble` absorbs the stragglers.
    pub head_bubble: f64,
    /// Tail-group bubble over the rollout span (0.0 with no tail group;
    /// 1.0 if the group was configured but never hosted a round).
    pub tail_bubble: f64,
    /// Pool-wide KV usage over time, (engine seconds, tokens charged),
    /// downsampled — the utilization curve `pool_kv.json` plots.  Empty
    /// when KV accounting is off.
    pub kv_trace: Vec<(f64, usize)>,
    /// Rids in training-consumption order — the full decision-equivalence
    /// fingerprint the event-vs-reference differential tests compare.
    pub consumed_rids: Vec<u64>,
    /// Per-sample version deltas of everything trained on: `hist[d]` =
    /// samples consumed exactly `d` updates after generation started.
    pub staleness_hist: BTreeMap<u64, u64>,
    /// Largest delta trained on; with `PoolSimOpts::staleness = Some(n)`
    /// this is provably `<= n`.
    pub max_staleness: u64,
    /// Samples bounced back to regeneration by the staleness cap.
    pub stale_resyncs: u64,
    /// Per-request latency roll-up (TTFT/TPOT/e2e quantiles, goodput).
    /// Default-empty unless the run carried a recording [`Tracer`]
    /// ([`SimRun::tracer`], or `PoolSimOpts::slo`).
    pub slo: SloSummary,
}

/// Simulate one full consumption of `workload` under `mode` on a single
/// engine with queue capacity `q`, `update_batch` trajectories per policy
/// update.  Thin wrapper over [`simulate_pool`] with one engine: since the
/// policy-API port, single-engine and pool runs execute the identical
/// decision sequence (and the same one the live controller executes).
pub fn simulate(mode: SimMode, workload: &[SimRequest], q: usize,
                update_batch: usize, cost: CostModel) -> SimReport {
    simulate_pool(mode, workload, 1, q, update_batch, cost,
                  DispatchPolicy::ShortestPredictedFirst, PredictorKind::History)
}

/// Run `workload` to completion on an engine pool — one oversubscribed
/// wave, no harvests or updates — and return the makespan in seconds.
/// This is the dispatch-policy comparison number `sched_bench` prints.
///
/// Learning predictors (history/bucket) are warmed up on NOISY
/// observations of the workload first: the RL regime re-rolls the same
/// prompts every policy update, so by the time scheduling matters the
/// predictor has seen sibling samples / earlier epochs of each prompt —
/// which *estimate*, not reveal, this round's exact length.  (Cold
/// predictions are uncorrelated with true lengths, so a cold run would
/// measure only late-binding dispatch; an exact warmup would make history
/// indistinguishable from the oracle, since sim requests are keyed
/// individually.)  The ~±35% lognormal noise leaves rank quality high but
/// keeps the oracle a genuine ceiling.
pub fn pool_makespan(workload: &[SimRequest], engines: usize, q_total: usize,
                     cost: CostModel, dispatch: DispatchPolicy,
                     predictor: PredictorKind) -> f64 {
    scale_probe(workload, engines, q_total, cost, dispatch, predictor,
                SimCore::Event, f64::INFINITY, 1)
        .makespan
}

/// What [`scale_probe`] measured: one oversubscribed dispatch wave run
/// (or cut off at the wall budget) on the chosen core.
#[derive(Debug, Clone, Copy)]
pub struct ScaleReport {
    pub requests: usize,
    pub engines: usize,
    /// Simulated seconds reached (the makespan when `finished_all`).
    pub makespan: f64,
    /// Host seconds the probe took.
    pub wall_secs: f64,
    /// Requests that completed within the wall budget.
    pub completed: usize,
    pub finished_all: bool,
}

/// [`pool_makespan`] with the scale knobs exposed: stepping core, host
/// wall-clock budget (checked every 4096 decisions; `f64::INFINITY` runs
/// to completion), and timeline stride (record every `stride`-th
/// occupancy change so million-request probes stay memory-bounded).
/// This is the engine under the `sched_bench --headline` run.
#[allow(clippy::too_many_arguments)]
pub fn scale_probe(workload: &[SimRequest], engines: usize, q_total: usize,
                   cost: CostModel, dispatch: DispatchPolicy,
                   predictor: PredictorKind, core: SimCore,
                   wall_budget_secs: f64, timeline_stride: usize) -> ScaleReport {
    assert!(engines >= 1 && q_total >= engines, "q_total must cover engines");
    let mut pred = make_sim_predictor(predictor, workload);
    if predictor != PredictorKind::Oracle {
        let mut rng = Pcg64::with_stream(0x5EED_17, 0x9E);
        for r in workload {
            let noisy = (r.output_len as f64 * rng.lognormal(0.0, 0.35))
                .clamp(1.0, 4.0 * r.output_len as f64);
            pred.observe(r.id as u64, r.prompt_len, noisy as usize);
        }
    }
    let mut pool = SimPool::new(engines, q_total / engines, cost, dispatch,
                                KvConfig::default(), core, timeline_stride.max(1));
    let work: Vec<SimWork> = workload
        .iter()
        .map(|r| {
            let p = pred.predict(r.id as u64, r.prompt_len);
            stamp_work(pred.is_rank_only(), p, *r, 0)
        })
        .collect();
    pool.stage(work, pred.as_ref());
    let start = std::time::Instant::now();
    let mut completed = 0usize;
    let mut finished_all = true;
    let mut decisions = 0u64;
    loop {
        match pool.tick() {
            Some(f) => completed += f.len(),
            None => break,
        }
        decisions += 1;
        if decisions % 4096 == 0 && start.elapsed().as_secs_f64() > wall_budget_secs {
            finished_all = false;
            break;
        }
    }
    ScaleReport {
        requests: workload.len(),
        engines,
        makespan: pool.observed_clock(),
        wall_secs: start.elapsed().as_secs_f64(),
        completed,
        finished_all,
    }
}

/// [`scale_probe`] over an open-loop arrival stream: the same
/// oversubscribed dispatch wave, but each request enters the pool only at
/// its arrival instant, delivered through the arrival key class on the
/// event heap (pseudo-engine `engines.len()`).  Host cost stays
/// O(decisions · log engines) — a 1M-Poisson-arrival probe is the
/// open-loop `sched_bench --headline` variant.  Predictor warmup matches
/// [`scale_probe`] exactly, so closed- and open-loop probes rank requests
/// identically; SJF priorities are precomputed at push time (the
/// predictor is frozen for the whole wave, so push-time and delivery-time
/// keys coincide).
#[allow(clippy::too_many_arguments)]
pub fn scale_probe_arrivals(arrivals: &[Arrival], engines: usize, q_total: usize,
                            cost: CostModel, dispatch: DispatchPolicy,
                            predictor: PredictorKind, core: SimCore,
                            wall_budget_secs: f64, timeline_stride: usize) -> ScaleReport {
    assert!(engines >= 1 && q_total >= engines, "q_total must cover engines");
    let workload: Vec<SimRequest> = arrivals.iter().map(|a| a.req).collect();
    let mut pred = make_sim_predictor(predictor, &workload);
    if predictor != PredictorKind::Oracle {
        let mut rng = Pcg64::with_stream(0x5EED_17, 0x9E);
        for r in &workload {
            let noisy = (r.output_len as f64 * rng.lognormal(0.0, 0.35))
                .clamp(1.0, 4.0 * r.output_len as f64);
            pred.observe(r.id as u64, r.prompt_len, noisy as usize);
        }
    }
    let mut pool = SimPool::new(engines, q_total / engines, cost, dispatch,
                                KvConfig::default(), core, timeline_stride.max(1));
    let stream: Vec<PoolArrival> = arrivals
        .iter()
        .map(|a| {
            let p = pred.predict(a.req.id as u64, a.req.prompt_len);
            let mut work = stamp_work(pred.is_rank_only(), p, a.req, 0);
            work.ready_at = a.t;
            let key = sjf_priority(pred.as_ref(), a.req.id as u64, a.req.prompt_len, 0);
            PoolArrival { t: a.t, key, work }
        })
        .collect();
    pool.push_arrivals(stream);
    let start = std::time::Instant::now();
    let mut completed = 0usize;
    let mut finished_all = true;
    let mut decisions = 0u64;
    loop {
        match pool.tick() {
            Some(f) => completed += f.len(),
            None => break,
        }
        decisions += 1;
        if decisions % 4096 == 0 && start.elapsed().as_secs_f64() > wall_budget_secs {
            finished_all = false;
            break;
        }
    }
    ScaleReport {
        requests: arrivals.len(),
        engines,
        makespan: pool.observed_clock(),
        wall_secs: start.elapsed().as_secs_f64(),
        completed,
        finished_all,
    }
}

/// Multi-engine pool simulation, policy-driven: the SAME `SchedulePolicy`
/// decision sequence the live controller executes, run against the cost
/// model.  Baseline loads sync-barrier waves of `q_total` requests; the
/// sorted/async modes treat the whole workload as one group pool
/// (oversubscription, early termination at the batching threshold, per-mode
/// clip/restart/resume at harvests).  `engines == 1` gives the
/// single-engine member of the same scheduler family, so 1-vs-N
/// comparisons isolate the sharding effect.
///
/// `q_total` is rounded down to a multiple of `engines`.
pub fn simulate_pool(mode: SimMode, workload: &[SimRequest], engines: usize,
                     q_total: usize, update_batch: usize, cost: CostModel,
                     dispatch: DispatchPolicy, predictor: PredictorKind) -> SimReport {
    SimRun::new(mode, PoolSimOpts {
        engines,
        q_total,
        update_batch,
        cost,
        dispatch,
        predictor,
        ..PoolSimOpts::default()
    })
    .workload(workload)
    .run()
}

/// Pool-simulation knobs beyond mode/workload.  The positional
/// [`simulate_pool`] covers the pre-stealing surface; construct this with
/// `..PoolSimOpts::default()` for the extended knobs.
#[derive(Debug, Clone, Copy)]
pub struct PoolSimOpts {
    pub engines: usize,
    /// Total lanes across engines (rounded down to a multiple of engines).
    pub q_total: usize,
    pub update_batch: usize,
    pub cost: CostModel,
    pub dispatch: DispatchPolicy,
    pub predictor: PredictorKind,
    /// Wrap the mode's policy in the [`WorkStealing`] composer.
    pub steal: bool,
    /// Per-engine KV budget in tokens; `usize::MAX` disables the model.
    pub kv_budget: usize,
    /// Reserve-the-cap (default) vs paged KV accounting.  Paged runs are
    /// additionally wrapped in the [`KvGovernor`] throttle composer.
    pub kv_mode: KvMode,
    /// Page granularity for paged accounting, in tokens.
    pub kv_page: usize,
    /// SLO deadline in simulated seconds.  `Some` turns on span recording
    /// (no Chrome trace) and fills `SimReport::slo` including goodput
    /// against this deadline; `None` (default) runs the zero-overhead
    /// disabled tracer.
    pub slo: Option<f64>,
    /// Stepping core.  [`SimCore::Event`] (default) fuses silent decode
    /// spans; [`SimCore::Reference`] replays the original per-iteration
    /// stepper.  An enabled tracer forces `Reference` — per-token TTFT /
    /// TPOT stamps need every iteration observed.
    pub core: SimCore,
    /// Record every `stride`-th occupancy change per engine timeline
    /// (and KV-trace sample).  1 (default) is lossless; bubble ratios
    /// stay exact at any stride via busy-area integration.
    pub timeline_stride: usize,
    /// `--staleness` off-policy-degree cap (async mode).  `Some(n)` sets
    /// the async policy's re-sync window to `n` AND enforces the hard cap
    /// at consume time (older samples re-sync once, drop on repeat) —
    /// the same semantics the live controller applies, so cross-backend
    /// goldens stay meaningful.  `None` (default) keeps the legacy
    /// `ASYNC_SYNC_EVERY` window with no consume-time cap.
    pub staleness: Option<usize>,
    /// `--tail-threshold`/`--tail-engines`: wrap the policy in the
    /// [`crate::sched::TailPacking`] composer (outermost), deferring
    /// predicted-long requests into batched tail rounds on the top
    /// `tail_engines` engines with elastic lane/KV repartitioning.
    /// `None` (default) keeps every pre-tail golden byte-identical.
    pub tail: Option<TailConfig>,
}

impl Default for PoolSimOpts {
    fn default() -> Self {
        let kv = KvConfig::default();
        PoolSimOpts {
            engines: 1,
            q_total: 128,
            update_batch: 128,
            cost: CostModel::default(),
            dispatch: DispatchPolicy::ShortestPredictedFirst,
            predictor: PredictorKind::History,
            steal: false,
            kv_budget: kv.budget,
            kv_mode: kv.mode,
            kv_page: kv.page,
            slo: None,
            core: SimCore::Event,
            timeline_stride: 1,
            staleness: None,
            tail: None,
        }
    }
}

/// Closed-loop (everything schedulable at t=0) vs open-loop (timestamped
/// arrivals) input to the one policy-driven pool runner.
enum PoolInput<'a> {
    Closed(&'a [SimRequest]),
    Open(&'a [Arrival]),
}

/// The one policy-driven pool runner, as a builder: every former
/// `simulate_pool_*` entry point is a chain over this.
///
/// ```ignore
/// let report = SimRun::new(mode, opts)
///     .workload(&w)            // or .arrivals(&stream) for open loop
///     .specs(&fleet)           // optional: heterogeneous --engine-spec
///     .tracer(&mut tracer)     // optional: Perfetto spans + SLO stamps
///     .run();
/// ```
///
/// With no explicit tracer, `opts.slo = Some(deadline)` runs a
/// span-recording tracer internally and fills `SimReport::slo`; otherwise
/// the disabled no-op sink rides along, so fuzz suites and decision
/// goldens pay nothing.  Open-loop arrivals must be sorted by time; when
/// the tracer records, each is registered with its tenant and arrival
/// instant, so SLO latencies come out arrival-relative (queueing delay
/// included) and the summary grows per-tenant rollups plus the Jain
/// fairness index.  An all-`t = 0` stream reproduces the corresponding
/// closed-loop run bit for bit (tested below), which is how
/// `--arrival batch` keeps every golden.
pub struct SimRun<'a> {
    mode: SimMode,
    opts: PoolSimOpts,
    input: PoolInput<'a>,
    specs: &'a [EngineSpec],
    tracer: Option<&'a mut Tracer>,
}

impl<'a> SimRun<'a> {
    /// A run of `mode` under `opts`, closed-loop over an empty workload
    /// until [`workload`](Self::workload) or
    /// [`arrivals`](Self::arrivals) supplies the input.
    pub fn new(mode: SimMode, opts: PoolSimOpts) -> Self {
        SimRun {
            mode,
            opts,
            input: PoolInput::Closed(&[]),
            specs: &[],
            tracer: None,
        }
    }

    /// Closed-loop input: the whole workload is schedulable at `t = 0`.
    pub fn workload(mut self, workload: &'a [SimRequest]) -> Self {
        self.input = PoolInput::Closed(workload);
        self
    }

    /// Open-loop input: requests become visible to the scheduler at their
    /// arrival instants (see `workload::ArrivalSpec`).  Replaces any
    /// previously set closed-loop workload.
    pub fn arrivals(mut self, arrivals: &'a [Arrival]) -> Self {
        self.input = PoolInput::Open(arrivals);
        self
    }

    /// Heterogeneous fleet shapes (`--engine-spec`): one spec per engine
    /// (validated in [`run`](Self::run)); lanes/KV/speed override the
    /// uniform `q_total / engines` split and the pool lane cap becomes
    /// the spec sum.
    pub fn specs(mut self, specs: &'a [EngineSpec]) -> Self {
        self.specs = specs;
        self
    }

    /// Ride an explicit [`Tracer`] on the driver — the path `sim
    /// --trace-out` uses to produce Perfetto traces and full SLO
    /// telemetry from a simulated pool.
    pub fn tracer(mut self, tracer: &'a mut Tracer) -> Self {
        self.tracer = Some(tracer);
        self
    }

    pub fn run(self) -> SimReport {
        let SimRun { mode, opts: o, input, specs, tracer } = self;
        let mut local =
            if o.slo.is_some() { Tracer::new(o.slo, false) } else { Tracer::disabled() };
        let tracer = tracer.unwrap_or(&mut local);
        assert!(o.engines >= 1 && o.q_total >= o.engines, "q_total must cover engines");
        assert!(o.update_batch >= 1, "update_batch must be >= 1");
        if !specs.is_empty() {
            assert_eq!(specs.len(), o.engines, "need one engine spec per engine");
            for s in specs {
                s.validate().expect("invalid engine spec");
            }
        }
        let q_each = o.q_total / o.engines;
        let q_cap = if specs.is_empty() {
            q_each * o.engines
        } else {
            specs.iter().map(|s| s.lanes).sum()
        };
        let total = match &input {
            PoolInput::Closed(w) => w.len(),
            PoolInput::Open(a) => a.len(),
        };
        let params = PolicyParams {
            refill_prompts: match mode {
                SimMode::Baseline => q_cap,
                _ => total.max(1),
            },
            entries_per_prompt: 1,
            update_batch: o.update_batch,
        };
        let kind = match mode {
            SimMode::Baseline => SchedulerKind::Baseline,
            SimMode::SortedOnPolicy => SchedulerKind::SortedOnPolicy,
            SimMode::SortedPartial => SchedulerKind::SortedPartial,
            SimMode::Async => SchedulerKind::AsyncUpdate,
        };
        let kv = KvConfig { mode: o.kv_mode, budget: o.kv_budget, page: o.kv_page.max(1) };
        // the composition order (governor inside stealing inside tail) and
        // the async re-sync window derivation live in PolicyBuilder — the
        // sim builds its policy exactly like the live controller does
        let mut policy = PolicyBuilder::new(kind, params)
            .kv(kv)
            .steal(o.steal)
            .staleness(o.staleness)
            .tail(o.tail)
            .build();
        // per-iteration latency stamps (TTFT/TPOT) need the per-iteration
        // stepper; fused spans would collapse them onto decision points
        let core = if tracer.enabled() { SimCore::Reference } else { o.core };
        let mut backend = match input {
            PoolInput::Closed(w) => {
                SimBackend::new(w, o.engines, q_each, o.cost, o.dispatch, o.predictor,
                                mode == SimMode::Async, kv, core, o.timeline_stride.max(1))
            }
            PoolInput::Open(a) => {
                if tracer.enabled() {
                    for x in a {
                        tracer.register_arrival(x.req.id as u64, x.t, x.tenant);
                    }
                }
                SimBackend::with_arrivals(a, o.engines, q_each, o.cost, o.dispatch,
                                          o.predictor, mode == SimMode::Async, kv, core,
                                          o.timeline_stride.max(1))
            }
        };
        if !specs.is_empty() {
            backend.apply_specs(specs);
        }
        if let Some(tc) = o.tail {
            backend.tail_engines = tc.tail_engines;
        }
        backend.staleness_cap = o.staleness.map(|n| n as u64);
        drive_traced(policy.as_mut(), &mut backend, tracer)
            .expect("sim backend is infallible; a driver error means a policy livelock");
        let mut report = backend.into_report(mode);
        if tracer.enabled() {
            report.slo = tracer.slo_summary();
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_workload(n: usize, len: usize) -> Vec<SimRequest> {
        (0..n)
            .map(|id| SimRequest { id, prompt_len: 64, output_len: len })
            .collect()
    }

    #[test]
    fn equal_lengths_baseline_has_no_bubble() {
        let w = uniform_workload(128, 500);
        let r = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        assert!(r.bubble_ratio < 0.01, "{}", r.bubble_ratio);
        assert_eq!(r.useful_tokens, 128 * 500);
        assert_eq!(r.wasted_tokens, 0);
    }

    #[test]
    fn longtail_baseline_has_large_bubble() {
        let w = longtail_workload(512, 8192, 1);
        let r = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        assert!(r.bubble_ratio > 0.4, "expected drain bubbles, got {}", r.bubble_ratio);
    }

    #[test]
    fn sorted_modes_cut_bubble_by_more_than_half() {
        let w = longtail_workload(512, 8192, 1);
        let base = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let onp = simulate(SimMode::SortedOnPolicy, &w, 128, 128, CostModel::default());
        let part = simulate(SimMode::SortedPartial, &w, 128, 128, CostModel::default());
        assert!(onp.bubble_ratio < base.bubble_ratio / 2.0,
                "on-policy {} vs base {}", onp.bubble_ratio, base.bubble_ratio);
        assert!(part.bubble_ratio < base.bubble_ratio / 2.0,
                "partial {} vs base {}", part.bubble_ratio, base.bubble_ratio);
    }

    #[test]
    fn throughput_order_partial_ge_onpolicy_ge_baseline() {
        let w = longtail_workload(512, 8192, 2);
        let base = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let onp = simulate(SimMode::SortedOnPolicy, &w, 128, 128, CostModel::default());
        let part = simulate(SimMode::SortedPartial, &w, 128, 128, CostModel::default());
        assert!(part.throughput > onp.throughput,
                "partial {} <= on-policy {}", part.throughput, onp.throughput);
        assert!(onp.throughput > base.throughput,
                "on-policy {} <= baseline {}", onp.throughput, base.throughput);
    }

    #[test]
    fn on_policy_wastes_tokens_partial_does_not() {
        let w = longtail_workload(256, 4096, 3);
        let onp = simulate(SimMode::SortedOnPolicy, &w, 64, 64, CostModel::default());
        let part = simulate(SimMode::SortedPartial, &w, 64, 64, CostModel::default());
        assert!(onp.wasted_tokens > 0);
        assert_eq!(part.wasted_tokens, 0);
        // and on-policy clips more than partial (Fig. 2's gray bars)
        assert!(onp.clipped >= part.clipped);
    }

    #[test]
    fn all_requests_accounted_exactly_once() {
        for mode in [SimMode::Baseline, SimMode::SortedOnPolicy, SimMode::SortedPartial] {
            let w = longtail_workload(200, 2048, 4);
            let r = simulate(mode, &w, 64, 50, CostModel::default());
            // natural completions + clipped harvests + dropped == workload
            assert_eq!(r.timeline.finished() as usize + r.clipped + r.dropped,
                       200, "{mode:?}");
            // token conservation: everything generated is useful or wasted
            assert!(r.useful_tokens > 0);
            if mode == SimMode::Baseline {
                assert_eq!(r.useful_tokens,
                           w.iter().map(|x| x.output_len as u64).sum::<u64>());
                assert_eq!(r.clipped, 0);
            }
        }
    }

    #[test]
    fn async_mode_conserves_and_beats_baseline_bubble() {
        let w = longtail_workload(512, 8192, 1);
        let base = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let asy = simulate(SimMode::Async, &w, 128, 128, CostModel::default());
        assert_eq!(asy.timeline.finished() as usize + asy.clipped + asy.dropped, 512);
        assert_eq!(asy.wasted_tokens, 0, "async resumes partials, never discards");
        assert!(asy.bubble_ratio < base.bubble_ratio / 2.0,
                "async {} vs baseline {}", asy.bubble_ratio, base.bubble_ratio);
        // the async win: update cost hides under continued decoding instead
        // of serializing behind a harvest barrier
        let serialized = asy.rollout_time + asy.infer_time + asy.update_time;
        assert!(asy.total_time < serialized,
                "async total {} !< serialized {}", asy.total_time, serialized);
        assert!(asy.harvests >= 2, "expected multiple overlapped updates");
    }

    #[test]
    fn async_total_time_beats_sync_partial() {
        let w = longtail_workload(512, 8192, 2);
        let part = simulate(SimMode::SortedPartial, &w, 128, 128, CostModel::default());
        let asy = simulate(SimMode::Async, &w, 128, 128, CostModel::default());
        // same resume semantics, but updates overlap decoding
        assert!(asy.total_time < part.total_time,
                "async {} !< partial {}", asy.total_time, part.total_time);
    }

    /// The `--staleness` cap, modeled at consume time exactly like the
    /// live buffer's `consume_bounded`: the capped run never trains on a
    /// sample older than the cap, while the uncapped run on the same
    /// workload provably goes further off-policy.  Conservation switches
    /// to trained-or-dropped accounting because re-synced samples
    /// legitimately regenerate (two engine completions, one trained
    /// sample).
    #[test]
    fn async_staleness_cap_bounds_offpolicy_degree() {
        let w = longtail_workload(512, 8192, 1);
        let run = |staleness| {
            SimRun::new(SimMode::Async, PoolSimOpts {
                engines: 1,
                q_total: 128,
                update_batch: 128,
                staleness,
                ..PoolSimOpts::default()
            })
            .workload(&w)
            .run()
        };
        let free = run(None);
        // all 512 samples are born at v0 and consumed at most 128 per
        // update, so at least 3 updates run and the uncapped tail trains
        // >= 2 versions behind
        assert!(free.max_staleness >= 2, "uncapped max {}", free.max_staleness);
        assert_eq!(free.stale_resyncs, 0, "no cap, nothing to bounce");

        let capped = run(Some(1));
        assert!(capped.max_staleness <= 1, "cap violated: {}", capped.max_staleness);
        // born-at-v0 samples consumed after the second update MUST have
        // bounced: only 256 can legally train at v_enter <= 1
        assert!(capped.stale_resyncs > 0, "cap never engaged");
        // every request still ends exactly once: trained or dropped
        assert_eq!(capped.consumed_rids.len() + capped.dropped, 512);
        assert_eq!(capped.staleness_hist.values().sum::<u64>() as usize,
                   capped.consumed_rids.len());
    }

    #[test]
    fn longtail_workload_is_longtailed() {
        let w = longtail_workload(2000, 8192, 5);
        let mut lens: Vec<usize> = w.iter().map(|r| r.output_len).collect();
        lens.sort_unstable();
        let median = lens[lens.len() / 2];
        let p95 = lens[lens.len() * 95 / 100];
        assert!(p95 > 3 * median, "median {median} p95 {p95}");
    }

    #[test]
    fn update_time_scales_with_tokens() {
        let w = uniform_workload(64, 100);
        let r = simulate(SimMode::Baseline, &w, 64, 64, CostModel::default());
        let w2 = uniform_workload(64, 200);
        let r2 = simulate(SimMode::Baseline, &w2, 64, 64, CostModel::default());
        assert!(r2.update_time > r.update_time * 1.5);
    }

    // ------------------------------------------------------------------
    // multi-engine pool
    // ------------------------------------------------------------------

    use crate::sched::{DispatchPolicy, PredictorKind};

    #[test]
    fn pool_baseline_conserves_requests_and_tokens() {
        let w = longtail_workload(200, 2048, 7);
        for engines in [1usize, 2, 4] {
            for policy in DispatchPolicy::ALL {
                let r = simulate_pool(SimMode::Baseline, &w, engines, 64, 50,
                                      CostModel::default(), policy,
                                      PredictorKind::Oracle);
                assert_eq!(r.timeline.finished() as usize, 200,
                           "{engines} engines, {}", policy.name());
                assert_eq!(r.useful_tokens,
                           w.iter().map(|x| x.output_len as u64).sum::<u64>());
                assert_eq!(r.wasted_tokens, 0);
                assert_eq!(r.engines, engines);
            }
        }
    }

    #[test]
    fn pool_oracle_predictor_is_exact() {
        let w = longtail_workload(128, 1024, 8);
        let r = simulate_pool(SimMode::Baseline, &w, 2, 32, 32,
                              CostModel::default(),
                              DispatchPolicy::ShortestPredictedFirst,
                              PredictorKind::Oracle);
        assert!(r.predictor_mae < 1e-9, "oracle MAE {}", r.predictor_mae);
        // ties (cap-clipped lengths, duplicate body lengths) keep tau-a
        // slightly below 1 even for a perfect oracle
        assert!(r.predictor_tau > 0.9, "oracle tau {}", r.predictor_tau);
    }

    #[test]
    fn pool_sorted_modes_account_every_request() {
        let w = longtail_workload(160, 2048, 9);
        for mode in [SimMode::SortedOnPolicy, SimMode::SortedPartial] {
            for engines in [1usize, 4] {
                let r = simulate_pool(mode, &w, engines, 64, 40,
                                      CostModel::default(),
                                      DispatchPolicy::ShortestPredictedFirst,
                                      PredictorKind::History);
                assert_eq!(r.timeline.finished() as usize + r.clipped + r.dropped,
                           160, "{mode:?} x{engines}");
                assert!(r.useful_tokens > 0);
                assert!(r.bubble_ratio >= 0.0 && r.bubble_ratio <= 1.0);
                if mode == SimMode::SortedPartial {
                    assert_eq!(r.wasted_tokens, 0, "partial never discards");
                }
            }
        }
    }

    #[test]
    fn pool_single_engine_partial_beats_baseline_bubble() {
        let w = longtail_workload(512, 8192, 1);
        let base = simulate(SimMode::Baseline, &w, 128, 128, CostModel::default());
        let part = simulate_pool(SimMode::SortedPartial, &w, 1, 128, 128,
                                 CostModel::default(),
                                 DispatchPolicy::ShortestPredictedFirst,
                                 PredictorKind::Oracle);
        assert!(part.bubble_ratio < base.bubble_ratio / 2.0,
                "pool partial {} vs baseline {}", part.bubble_ratio, base.bubble_ratio);
    }

    #[test]
    fn pool_multi_engine_throughput_scales() {
        let w = longtail_workload(256, 4096, 11);
        let one = simulate_pool(SimMode::SortedPartial, &w, 1, 128, 64,
                                CostModel::default(),
                                DispatchPolicy::ShortestPredictedFirst,
                                PredictorKind::Oracle);
        let four = simulate_pool(SimMode::SortedPartial, &w, 4, 128, 64,
                                 CostModel::default(),
                                 DispatchPolicy::ShortestPredictedFirst,
                                 PredictorKind::Oracle);
        // 4 engines of 32 lanes stream weights in parallel: wall time drops
        assert!(four.rollout_time < one.rollout_time,
                "4-engine {}s vs 1-engine {}s", four.rollout_time, one.rollout_time);
        assert!(four.throughput > one.throughput);
    }

    #[test]
    fn pool_makespan_runs_everything() {
        let w = longtail_workload(96, 1024, 13);
        for policy in DispatchPolicy::ALL {
            let m = pool_makespan(&w, 3, 24, CostModel::default(), policy,
                                  PredictorKind::History);
            assert!(m > 0.0 && m.is_finite(), "{}", policy.name());
        }
    }

    #[test]
    fn pool_sjf_beats_static_round_robin_makespan() {
        let w = longtail_workload(512, 8192, 1);
        let cost = CostModel::default();
        let rr = pool_makespan(&w, 4, 128, cost, DispatchPolicy::RoundRobin,
                               PredictorKind::History);
        let sjf = pool_makespan(&w, 4, 128, cost,
                                DispatchPolicy::ShortestPredictedFirst,
                                PredictorKind::Oracle);
        // late-binding + predicted ordering rebalances the long tail that
        // static striping strands on one engine
        assert!(sjf < rr, "sjf {sjf} !< round-robin {rr}");
    }

    /// 2 engines × 2 lanes, unit iteration cost (`t_weights` 1s, all other
    /// costs zero), lengths [3,5,3,5] round-robined: e0 runs rids {0,2}
    /// (lanes 0/1, finish t=3), e1 runs {1,3} (lanes 0/1, finish t=5).
    /// Every expected value below is hand-derived from the cost model:
    /// enqueue+dispatch at t=0, first token after each engine's first
    /// 1-second iteration (TTFT = 1), one token per second thereafter
    /// (TPOT = 1), e2e = [3,3,5,5] so the interpolated p50 is 4 and p99
    /// is 5, and with a 4-second SLO exactly the two short requests make
    /// the deadline (goodput 0.5).
    fn golden_workload_and_opts() -> (Vec<SimRequest>, PoolSimOpts) {
        let w = vec![
            SimRequest { id: 0, prompt_len: 8, output_len: 3 },
            SimRequest { id: 1, prompt_len: 8, output_len: 5 },
            SimRequest { id: 2, prompt_len: 8, output_len: 3 },
            SimRequest { id: 3, prompt_len: 8, output_len: 5 },
        ];
        let cost = CostModel {
            t_weights: 1.0,
            t_token: 0.0,
            t_prefill_token: 0.0,
            t_update_token: 0.0,
            t_infer_token: 0.0,
        };
        let opts = PoolSimOpts {
            engines: 2,
            q_total: 4,
            update_batch: 4,
            cost,
            dispatch: DispatchPolicy::RoundRobin,
            predictor: PredictorKind::Oracle,
            slo: Some(4.0),
            ..PoolSimOpts::default()
        };
        (w, opts)
    }

    #[test]
    fn slo_golden_two_engine_hand_derived() {
        let (w, opts) = golden_workload_and_opts();
        let mut tracer = Tracer::new(Some(4.0), false);
        let r = SimRun::new(SimMode::Baseline, opts)
            .workload(&w)
            .tracer(&mut tracer)
            .run();
        let s = &r.slo;
        assert_eq!((s.enqueued, s.completed, s.clipped, s.dropped), (4, 4, 0, 0));
        assert!((s.ttft_p50 - 1.0).abs() < 1e-9, "ttft_p50 {}", s.ttft_p50);
        assert!((s.ttft_p99 - 1.0).abs() < 1e-9, "ttft_p99 {}", s.ttft_p99);
        assert!((s.tpot_p50 - 1.0).abs() < 1e-9, "tpot_p50 {}", s.tpot_p50);
        assert!((s.tpot_p99 - 1.0).abs() < 1e-9, "tpot_p99 {}", s.tpot_p99);
        assert!((s.e2e_p50 - 4.0).abs() < 1e-9, "e2e_p50 {}", s.e2e_p50);
        assert!((s.e2e_p99 - 5.0).abs() < 1e-9, "e2e_p99 {}", s.e2e_p99);
        assert!(s.queue_p99.abs() < 1e-9, "queue_p99 {}", s.queue_p99);
        assert!((s.goodput - 0.5).abs() < 1e-9, "goodput {}", s.goodput);
        // spans: complete, ordered, consumed by the one update, attributed
        // to the engine/lane the round-robin stripe put them on
        assert_eq!(tracer.spans().len(), 4);
        for (rid, sp) in tracer.spans() {
            assert!(sp.is_ordered(), "rid {rid} out of order: {sp:?}");
            assert!(sp.is_complete(), "rid {rid} incomplete: {sp:?}");
            assert!(sp.consumed.is_some(), "rid {rid} never consumed");
        }
        let at = |rid: u64| {
            let sp = &tracer.spans()[&rid];
            (sp.engine, sp.lane, sp.finished)
        };
        assert_eq!(at(0), (Some(0), Some(0), Some(3.0)));
        assert_eq!(at(2), (Some(0), Some(1), Some(3.0)));
        assert_eq!(at(1), (Some(1), Some(0), Some(5.0)));
        assert_eq!(at(3), (Some(1), Some(1), Some(5.0)));
        // the PoolSimOpts::slo path computes the identical summary
        let r2 = SimRun::new(SimMode::Baseline, opts).workload(&w).run();
        assert_eq!(r2.slo.completed, 4);
        assert!((r2.slo.goodput - 0.5).abs() < 1e-9);
        assert!((r2.slo.e2e_p99 - 5.0).abs() < 1e-9);
    }

    #[test]
    fn chrome_trace_schema_round_trip() {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let (w, opts) = golden_workload_and_opts();
        let mut tracer = Tracer::new(None, true);
        SimRun::new(SimMode::Baseline, opts)
            .workload(&w)
            .tracer(&mut tracer)
            .run();
        let text = tracer.chrome_json().expect("chrome tracer").to_string_pretty();
        let back = Json::parse(&text).expect("trace must be valid JSON");
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!evs.is_empty());
        // every event carries the Chrome trace-event required fields, and
        // counter-track timestamps are monotone per (pid, name)
        let mut last_c: BTreeMap<(i64, String), f64> = BTreeMap::new();
        for e in evs {
            for k in ["pid", "tid", "ts", "ph"] {
                assert!(e.get(k).is_some(), "missing {k}: {e:?}");
            }
            if e.get("ph").unwrap().as_str() == Some("C") {
                let key = (
                    e.get("pid").unwrap().as_i64().unwrap(),
                    e.get("name").unwrap().as_str().unwrap().to_string(),
                );
                let ts = e.get("ts").unwrap().as_f64().unwrap();
                if let Some(prev) = last_c.insert(key.clone(), ts) {
                    assert!(prev <= ts, "counter {key:?} went backward");
                }
            }
        }
        // required track names: engine processes, occupancy counters, and
        // one slice per request
        for needle in ["\"process_name\"", "\"engine 0\"", "\"engine 1\"",
                       "\"running\"", "\"queued\"", "\"req 0\"", "\"req 3\""] {
            assert!(text.contains(needle), "trace missing {needle}");
        }
    }

    // ------------------------------------------------------------------
    // event core vs reference core differentials
    // ------------------------------------------------------------------

    /// All five cost knobs exactly representable in binary (multiples of
    /// 2^-5): repeated adds in the reference stepper and the event core's
    /// fused `k * iter` multiply are then both EXACT, so engine clocks —
    /// and everything derived from them — must agree bit for bit.
    fn dyadic_cost() -> CostModel {
        CostModel {
            t_weights: 0.5,
            t_token: 0.25,
            t_prefill_token: 0.125,
            t_update_token: 0.0625,
            t_infer_token: 0.03125,
        }
    }

    fn assert_reports_identical(a: &SimReport, b: &SimReport, ctx: &str) {
        assert_eq!(a.timeline.finished(), b.timeline.finished(), "{ctx}: finished");
        assert_eq!(a.timeline.tokens_out(), b.timeline.tokens_out(), "{ctx}: tokens");
        assert_eq!(a.useful_tokens, b.useful_tokens, "{ctx}: useful");
        assert_eq!(a.wasted_tokens, b.wasted_tokens, "{ctx}: wasted");
        assert_eq!(a.clipped, b.clipped, "{ctx}: clipped");
        assert_eq!(a.dropped, b.dropped, "{ctx}: dropped");
        assert_eq!(a.harvests, b.harvests, "{ctx}: harvests");
        assert_eq!(a.steals, b.steals, "{ctx}: steals");
        assert_eq!(a.migrated_tokens, b.migrated_tokens, "{ctx}: migrated");
        assert_eq!(a.kv_sheds, b.kv_sheds, "{ctx}: kv_sheds");
        assert_eq!(a.throttles, b.throttles, "{ctx}: throttles");
        assert_eq!(a.tail_rounds, b.tail_rounds, "{ctx}: tail_rounds");
        assert_eq!(a.tail_admitted, b.tail_admitted, "{ctx}: tail_admitted");
        assert_eq!(a.repartitions, b.repartitions, "{ctx}: repartitions");
        assert_eq!(a.head_bubble.to_bits(), b.head_bubble.to_bits(),
                   "{ctx}: head_bubble {} vs {}", a.head_bubble, b.head_bubble);
        assert_eq!(a.tail_bubble.to_bits(), b.tail_bubble.to_bits(),
                   "{ctx}: tail_bubble {} vs {}", a.tail_bubble, b.tail_bubble);
        assert_eq!(a.peak_lanes, b.peak_lanes, "{ctx}: peak_lanes");
        assert_eq!(a.consumed_rids, b.consumed_rids, "{ctx}: consumed order");
        assert_eq!(a.staleness_hist, b.staleness_hist, "{ctx}: staleness hist");
        assert_eq!(a.max_staleness, b.max_staleness, "{ctx}: max staleness");
        assert_eq!(a.stale_resyncs, b.stale_resyncs, "{ctx}: stale resyncs");
        assert_eq!(a.rollout_time.to_bits(), b.rollout_time.to_bits(),
                   "{ctx}: rollout_time {} vs {}", a.rollout_time, b.rollout_time);
        assert_eq!(a.total_time.to_bits(), b.total_time.to_bits(), "{ctx}: total_time");
        assert_eq!(a.predictor_mae.to_bits(), b.predictor_mae.to_bits(), "{ctx}: mae");
        assert_eq!(a.predictor_tau.to_bits(), b.predictor_tau.to_bits(), "{ctx}: tau");
        assert_eq!(a.timeline.events().len(), b.timeline.events().len(),
                   "{ctx}: timeline length");
        for (i, (x, y)) in a.timeline.events().iter().zip(b.timeline.events()).enumerate() {
            assert_eq!((x.0.to_bits(), x.1), (y.0.to_bits(), y.1),
                       "{ctx}: timeline[{i}] {x:?} vs {y:?}");
        }
        assert_eq!(a.kv_trace.len(), b.kv_trace.len(), "{ctx}: kv_trace length");
        for (i, (x, y)) in a.kv_trace.iter().zip(&b.kv_trace).enumerate() {
            assert_eq!((x.0.to_bits(), x.1), (y.0.to_bits(), y.1),
                       "{ctx}: kv_trace[{i}] {x:?} vs {y:?}");
        }
        assert_eq!(a.engine_idle.len(), b.engine_idle.len(), "{ctx}: idle length");
        for (i, (x, y)) in a.engine_idle.iter().zip(&b.engine_idle).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: engine_idle[{i}] {x} vs {y}");
        }
    }

    #[test]
    fn event_core_matches_reference_core_exactly() {
        let w = longtail_workload(90, 384, 42);
        for mode in [SimMode::Baseline, SimMode::SortedOnPolicy,
                     SimMode::SortedPartial, SimMode::Async] {
            for dispatch in DispatchPolicy::ALL {
                for steal in [false, true] {
                    let run = |core| {
                        SimRun::new(mode, PoolSimOpts {
                            engines: 3,
                            q_total: 24,
                            update_batch: 16,
                            cost: dyadic_cost(),
                            dispatch,
                            predictor: PredictorKind::Oracle,
                            steal,
                            core,
                            ..PoolSimOpts::default()
                        })
                        .workload(&w)
                        .run()
                    };
                    assert_reports_identical(
                        &run(SimCore::Event),
                        &run(SimCore::Reference),
                        &format!("{mode:?}/{}/steal={steal}", dispatch.name()),
                    );
                }
            }
        }
    }

    #[test]
    fn event_core_matches_reference_under_kv_pressure() {
        let w = longtail_workload(70, 256, 9);
        for kv_mode in [KvMode::Reserve, KvMode::Paged] {
            for (budget, page) in [(2048usize, 1usize), (1536, 64), (1100, 7)] {
                for dispatch in DispatchPolicy::ALL {
                    let run = |core| {
                        SimRun::new(SimMode::SortedPartial, PoolSimOpts {
                            engines: 2,
                            q_total: 16,
                            update_batch: 12,
                            cost: dyadic_cost(),
                            dispatch,
                            predictor: PredictorKind::History,
                            steal: true,
                            kv_budget: budget,
                            kv_mode,
                            kv_page: page,
                            core,
                            ..PoolSimOpts::default()
                        })
                        .workload(&w)
                        .run()
                    };
                    assert_reports_identical(
                        &run(SimCore::Event),
                        &run(SimCore::Reference),
                        &format!("{kv_mode:?}/b{budget}/p{page}/{}", dispatch.name()),
                    );
                }
            }
        }
    }

    #[test]
    fn makespan_identical_across_cores_with_dyadic_costs() {
        let w = longtail_workload(200, 512, 21);
        for dispatch in DispatchPolicy::ALL {
            let probe = |core| {
                scale_probe(&w, 4, 32, dyadic_cost(), dispatch,
                            PredictorKind::History, core, f64::INFINITY, 1)
            };
            let e = probe(SimCore::Event);
            let r = probe(SimCore::Reference);
            assert_eq!(e.makespan.to_bits(), r.makespan.to_bits(),
                       "{}: {} vs {}", dispatch.name(), e.makespan, r.makespan);
            assert_eq!(e.completed, r.completed, "{}", dispatch.name());
            assert!(e.finished_all && r.finished_all);
        }
    }

    /// Non-dyadic (default) costs: ULP-level clock divergence may reorder
    /// exact ties, so cores are checked for conservation independently
    /// rather than against each other.
    #[test]
    fn both_cores_conserve_with_default_costs() {
        let w = longtail_workload(120, 2048, 17);
        for core in [SimCore::Event, SimCore::Reference] {
            for mode in [SimMode::Baseline, SimMode::SortedPartial, SimMode::Async] {
                let r = SimRun::new(mode, PoolSimOpts {
                    engines: 4,
                    q_total: 64,
                    update_batch: 32,
                    core,
                    ..PoolSimOpts::default()
                })
                .workload(&w)
                .run();
                assert_eq!(r.timeline.finished() as usize + r.clipped + r.dropped,
                           120, "{core:?} {mode:?}");
                assert_eq!(r.consumed_rids.len(), 120 - r.dropped, "{core:?} {mode:?}");
                if mode != SimMode::SortedOnPolicy {
                    assert_eq!(r.wasted_tokens, 0, "{core:?} {mode:?}");
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // tail rounds + elastic repartition
    // ------------------------------------------------------------------

    /// The pinned tail-packing regression: a hand-built skew where two
    /// 50-token stragglers (rids 3 and 6) land on the same engine under
    /// round-robin striping, and the paged KV budget (page 1, budget 64)
    /// cannot host both estimates at once (2 x (8 + 50) = 116 > 64) — so
    /// without tail rounds they serialize on one lane while the rest of
    /// the fleet drains 2-token shorts and goes idle.  With tail packing
    /// the stragglers defer, the starved pool opens a tail round, the two
    /// head engines each donate a lane and half their budget
    /// (64 + 2 x 32 = 128 >= 116), and both stragglers decode
    /// concurrently — the bubble must come down STRICTLY.
    #[test]
    fn tail_packing_strictly_cuts_longtail_bubble() {
        let w: Vec<SimRequest> = (0..9)
            .map(|id| SimRequest {
                id,
                prompt_len: 8,
                output_len: if id % 3 == 0 && id > 0 { 50 } else { 2 },
            })
            .collect();
        let opts = PoolSimOpts {
            engines: 3,
            q_total: 9,
            update_batch: 9,
            cost: dyadic_cost(),
            dispatch: DispatchPolicy::RoundRobin,
            predictor: PredictorKind::Oracle,
            kv_budget: 64,
            kv_mode: KvMode::Paged,
            kv_page: 1,
            ..PoolSimOpts::default()
        };
        let base = SimRun::new(SimMode::Baseline, opts).workload(&w).run();
        let tail = SimRun::new(SimMode::Baseline, PoolSimOpts {
            tail: Some(TailConfig { threshold: 25, tail_engines: 1 }),
            ..opts
        })
        .workload(&w)
        .run();
        assert_eq!(base.tail_rounds, 0);
        assert_eq!(base.repartitions, 0);
        assert!(tail.tail_rounds >= 1, "no tail round opened");
        assert_eq!(tail.tail_admitted, 2, "both stragglers must pack");
        assert!(tail.repartitions >= 2, "donation + restore expected, got {}",
                tail.repartitions);
        assert!(tail.bubble_ratio < base.bubble_ratio,
                "tail packing must strictly cut the bubble: {} !< {}",
                tail.bubble_ratio, base.bubble_ratio);
        // split telemetry fills and stays sane; the tail group hosted work
        assert!(tail.tail_bubble < 1.0, "tail group never ran");
        assert!((0.0..=1.0).contains(&tail.head_bubble), "{}", tail.head_bubble);
        // both runs still consume every request exactly once
        assert_eq!(base.consumed_rids.len(), 9);
        assert_eq!(tail.consumed_rids.len(), 9);
    }

    /// Event core == reference core, bitwise, with the full new surface
    /// on: tail rounds (elastic repartitions included) over a
    /// heterogeneous fleet (per-engine lanes / KV budgets / dyadic
    /// speeds), with and without stealing.
    #[test]
    fn tail_and_hetero_specs_match_across_cores() {
        let w = longtail_workload(90, 384, 42);
        let specs = [
            EngineSpec { lanes: 12, kv_budget: 4096, speed: 2.0 },
            EngineSpec { lanes: 8, kv_budget: 4096, speed: 1.0 },
            EngineSpec { lanes: 4, kv_budget: 8192, speed: 0.5 },
        ];
        for mode in [SimMode::Baseline, SimMode::SortedPartial, SimMode::Async] {
            for steal in [false, true] {
                let run = |core| {
                    SimRun::new(mode, PoolSimOpts {
                        engines: 3,
                        q_total: 24,
                        update_batch: 16,
                        cost: dyadic_cost(),
                        dispatch: DispatchPolicy::ShortestPredictedFirst,
                        predictor: PredictorKind::Oracle,
                        steal,
                        kv_mode: KvMode::Paged,
                        kv_budget: 4096,
                        kv_page: 16,
                        tail: Some(TailConfig { threshold: 96, tail_engines: 1 }),
                        core,
                        ..PoolSimOpts::default()
                    })
                    .workload(&w)
                    .specs(&specs)
                    .run()
                };
                assert_reports_identical(
                    &run(SimCore::Event),
                    &run(SimCore::Reference),
                    &format!("tail+specs {mode:?}/steal={steal}"),
                );
            }
        }
    }

    /// Tail packing composed over a rank-only predictor is inert by
    /// construction: nothing stamps a prediction, so nothing defers and
    /// the decision sequence stays byte-identical to the untailed run —
    /// the `PolicyBuilder` misuse case degrades to a no-op, not a hang.
    #[test]
    fn tail_is_inert_with_rank_only_predictor() {
        let w = longtail_workload(60, 256, 3);
        let run = |tail| {
            SimRun::new(SimMode::SortedPartial, PoolSimOpts {
                engines: 3,
                q_total: 12,
                update_batch: 12,
                cost: dyadic_cost(),
                predictor: PredictorKind::Bucket,
                tail,
                ..PoolSimOpts::default()
            })
            .workload(&w)
            .run()
        };
        let off = run(None);
        let on = run(Some(TailConfig { threshold: 8, tail_engines: 2 }));
        assert_eq!(on.tail_rounds, 0, "rank-only predictions must not defer");
        assert_eq!(on.tail_admitted, 0);
        assert_eq!(on.repartitions, 0);
        assert_eq!(on.consumed_rids, off.consumed_rids, "decision sequence changed");
        assert_eq!(on.rollout_time.to_bits(), off.rollout_time.to_bits());
        assert_eq!(on.total_time.to_bits(), off.total_time.to_bits());
        assert_eq!(on.steals, off.steals);
        assert_eq!(on.kv_sheds, off.kv_sheds);
    }

    #[test]
    fn event_core_scales_without_per_token_stepping() {
        // 64 engines, 4k requests: completes in well under the wall budget
        // because host work scales with decisions, not tokens
        let w = longtail_workload(4000, 512, 3);
        let rep = scale_probe(&w, 64, 1024, CostModel::default(),
                              DispatchPolicy::ShortestPredictedFirst,
                              PredictorKind::History, SimCore::Event, 60.0, 32);
        assert!(rep.finished_all, "probe hit the wall budget");
        assert_eq!(rep.completed, 4000);
        assert!(rep.makespan > 0.0 && rep.makespan.is_finite());
        assert_eq!(rep.engines, 64);
    }

    // ------------------------------------------------------------------
    // open-loop arrivals
    // ------------------------------------------------------------------

    /// `--arrival batch` is the closed loop: an all-`t = 0` stream (the
    /// `ArrivalSpec::Batch` output) must reproduce the closed-loop `SimRun`
    /// bit for bit, on both cores, for every mode and dispatch policy —
    /// the guarantee that keeps every pre-open-loop golden byte-identical.
    #[test]
    fn batch_arrival_stream_reproduces_closed_loop_exactly() {
        let w = longtail_workload(90, 384, 42);
        let arrivals = crate::workload::ArrivalSpec::Batch
            .build(90, 384, 42)
            .expect("batch stream");
        for mode in [SimMode::Baseline, SimMode::SortedPartial, SimMode::Async] {
            for dispatch in DispatchPolicy::ALL {
                for core in [SimCore::Event, SimCore::Reference] {
                    let o = PoolSimOpts {
                        engines: 3,
                        q_total: 24,
                        update_batch: 16,
                        cost: dyadic_cost(),
                        dispatch,
                        predictor: PredictorKind::Oracle,
                        core,
                        ..PoolSimOpts::default()
                    };
                    let closed = SimRun::new(mode, o).workload(&w).run();
                    let open = SimRun::new(mode, o).arrivals(&arrivals).run();
                    assert_reports_identical(
                        &closed,
                        &open,
                        &format!("batch {mode:?}/{}/{core:?}", dispatch.name()),
                    );
                }
            }
        }
    }

    /// Dyadic arrival times (multiples of 1/4 s) keep open-loop clock
    /// arithmetic exact in both cores, so the event-vs-reference
    /// differential contract extends to timestamped arrivals.
    #[test]
    fn open_loop_event_core_matches_reference_core() {
        let w = longtail_workload(80, 256, 5);
        let mut rng = Pcg64::with_stream(99, 0x77);
        let mut t = 0.0f64;
        let arrivals: Vec<Arrival> = w
            .iter()
            .map(|&req| {
                t += (rng.below(8) + 1) as f64 * 0.25;
                Arrival { t, tenant: req.id % 3, req }
            })
            .collect();
        for mode in [SimMode::Baseline, SimMode::SortedOnPolicy,
                     SimMode::SortedPartial, SimMode::Async] {
            for dispatch in DispatchPolicy::ALL {
                let run = |core| {
                    SimRun::new(mode, PoolSimOpts {
                        engines: 3,
                        q_total: 24,
                        update_batch: 16,
                        cost: dyadic_cost(),
                        dispatch,
                        predictor: PredictorKind::Oracle,
                        core,
                        ..PoolSimOpts::default()
                    })
                    .arrivals(&arrivals)
                    .run()
                };
                assert_reports_identical(
                    &run(SimCore::Event),
                    &run(SimCore::Reference),
                    &format!("open-loop {mode:?}/{}", dispatch.name()),
                );
            }
        }
    }

    /// Pool-level open-loop probe (the `sched_bench` path): zero gaps are
    /// allowed — simultaneous arrivals exercise the tie rule (engines win
    /// ties against the arrival pseudo-index, matching the reference
    /// core's strict `t < min clock` delivery gate).
    #[test]
    fn open_loop_probe_matches_across_cores() {
        let w = longtail_workload(150, 384, 31);
        let mut rng = Pcg64::with_stream(7, 0x78);
        let mut t = 0.0f64;
        let arrivals: Vec<Arrival> = w
            .iter()
            .map(|&req| {
                t += rng.below(4) as f64 * 0.25;
                Arrival { t, tenant: 0, req }
            })
            .collect();
        for dispatch in DispatchPolicy::ALL {
            let probe = |core| {
                scale_probe_arrivals(&arrivals, 4, 32, dyadic_cost(), dispatch,
                                     PredictorKind::History, core, f64::INFINITY, 1)
            };
            let e = probe(SimCore::Event);
            let r = probe(SimCore::Reference);
            assert_eq!(e.makespan.to_bits(), r.makespan.to_bits(),
                       "{}: {} vs {}", dispatch.name(), e.makespan, r.makespan);
            assert_eq!(e.completed, r.completed, "{}", dispatch.name());
            assert_eq!(e.completed, 150, "{}", dispatch.name());
            assert!(e.finished_all && r.finished_all);
        }
    }

    /// Traced open-loop runs fill the per-tenant SLO section: counts
    /// partition the stream, latencies are arrival-relative, and two
    /// identical halves of the same longtail mix score near-perfect Jain
    /// fairness.
    #[test]
    fn open_loop_tenant_metrics_and_fairness_fill() {
        let w = longtail_workload(60, 256, 8);
        let arrivals: Vec<Arrival> = w
            .iter()
            .enumerate()
            .map(|(i, &req)| Arrival { t: 0.25 * i as f64, tenant: i % 2, req })
            .collect();
        let r = SimRun::new(SimMode::Baseline, PoolSimOpts {
            engines: 2,
            q_total: 16,
            update_batch: 16,
            slo: Some(60.0),
            ..PoolSimOpts::default()
        })
        .arrivals(&arrivals)
        .run();
        let s = &r.slo;
        assert_eq!(s.enqueued, 60);
        assert_eq!(s.tenants.len(), 2);
        assert_eq!(s.tenants.iter().map(|t| t.enqueued).sum::<usize>(), 60);
        for ten in &s.tenants {
            assert_eq!(ten.enqueued, 30);
            assert!(ten.completed > 0, "tenant {} completed nothing", ten.tenant);
            assert!(ten.e2e_p50 > 0.0);
            assert!(ten.e2e_p99 >= ten.e2e_p50);
        }
        assert!(!s.queue_depth.is_empty(), "queue-depth series missing");
        assert!(s.fairness_jain > 0.9 && s.fairness_jain <= 1.0,
                "jain {}", s.fairness_jain);
    }
}
