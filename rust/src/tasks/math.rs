//! Arithmetic-chain task: the DAPO-Math-17k stand-in (§4.1).
//!
//! A problem is a depth-`d` left-nested integer expression; the answer is
//! always an integer (the paper's dataset is transformed the same way "for
//! easy and precise verification").  Difficulty = depth, which linearly
//! controls the natural chain-of-thought length (one `step` line per op).

use super::{parse_format, AnswerKey, Problem, Reward, Task};
use crate::tokenizer::{
    Tokenizer, ANS_CLOSE, ANS_OPEN, BOS, EOS, EQUALS, LPAREN, MATH, MINUS, PLUS,
    QMARK, RPAREN, SEP, SLASH, STAR, STEP, THINK_CLOSE, THINK_OPEN,
};
use crate::util::rng::Pcg64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Add,
    Sub,
    Mul,
    Div,
}

impl Op {
    pub fn token(self) -> i32 {
        match self {
            Op::Add => PLUS,
            Op::Sub => MINUS,
            Op::Mul => STAR,
            Op::Div => SLASH,
        }
    }

    pub fn apply(self, a: i64, b: i64) -> i64 {
        match self {
            Op::Add => a + b,
            Op::Sub => a - b,
            Op::Mul => a * b,
            Op::Div => a / b,
        }
    }
}

/// Left-nested chain: (((v0 op1 c1) op2 c2) ... op_d c_d).
#[derive(Debug, Clone)]
pub struct Chain {
    pub start: i64,
    pub steps: Vec<(Op, i64)>,
}

impl Chain {
    pub fn value(&self) -> i64 {
        self.steps.iter().fold(self.start, |acc, (op, c)| op.apply(acc, *c))
    }

    /// Intermediate values after each step.
    pub fn intermediates(&self) -> Vec<i64> {
        let mut acc = self.start;
        self.steps
            .iter()
            .map(|(op, c)| {
                acc = op.apply(acc, *c);
                acc
            })
            .collect()
    }
}

/// Generate a chain whose intermediates stay in [-999, 999].
pub fn generate_chain(rng: &mut Pcg64, depth: usize) -> Chain {
    loop {
        let start = rng.range_i64(-9, 10);
        let mut acc = start;
        let mut steps = Vec::with_capacity(depth);
        for _ in 0..depth {
            // pick an op that keeps the value bounded (and division exact)
            for _attempt in 0..20 {
                let op = match rng.below(8) {
                    0 | 1 | 2 => Op::Add,
                    3 | 4 | 5 => Op::Sub,
                    6 => Op::Mul,
                    _ => Op::Div,
                };
                let c = rng.range_i64(1, 10);
                if op == Op::Div && acc % c != 0 {
                    continue;
                }
                let next = op.apply(acc, c);
                if next.abs() <= 999 {
                    acc = next;
                    steps.push((op, c));
                    break;
                }
            }
        }
        // a failed step leaves the chain short — retry the whole chain
        if steps.len() == depth {
            return Chain { start, steps };
        }
    }
}

/// `<bos> MATH ( ( v0 op c1 ) op c2 ) ... = ?`
pub fn prompt_tokens(chain: &Chain, tok: &Tokenizer) -> Vec<i32> {
    let d = chain.steps.len();
    let mut t = vec![BOS, MATH];
    for _ in 0..d {
        t.push(LPAREN);
    }
    t.extend(tok.encode_int(chain.start));
    for (op, c) in &chain.steps {
        t.push(op.token());
        t.extend(tok.encode_int(*c));
        t.push(RPAREN);
    }
    t.extend([EQUALS, QMARK]);
    t
}

/// CoT: `step a op c = r ;` per step.
pub fn cot_tokens(chain: &Chain, tok: &Tokenizer) -> Vec<i32> {
    let mut t = Vec::new();
    let mut acc = chain.start;
    for (op, c) in &chain.steps {
        let r = op.apply(acc, *c);
        t.push(STEP);
        t.extend(tok.encode_int(acc));
        t.push(op.token());
        t.extend(tok.encode_int(*c));
        t.push(EQUALS);
        t.extend(tok.encode_int(r));
        t.push(SEP);
        acc = r;
    }
    t
}

pub struct MathTask;

impl Task for MathTask {
    fn name(&self) -> &'static str {
        "math"
    }

    fn difficulty_range(&self) -> (u32, u32) {
        (2, 8)
    }

    fn generate(&self, rng: &mut Pcg64, difficulty: u32, id: u64) -> Problem {
        let tok = Tokenizer::new();
        let chain = generate_chain(rng, difficulty as usize);
        let prompt = prompt_tokens(&chain, &tok);
        let mut sft = vec![THINK_OPEN];
        sft.extend(cot_tokens(&chain, &tok));
        sft.push(THINK_CLOSE);
        sft.push(ANS_OPEN);
        sft.extend(tok.encode_int(chain.value()));
        sft.push(ANS_CLOSE);
        sft.push(EOS);
        Problem {
            id,
            difficulty,
            prompt,
            sft_target: sft,
            answer: AnswerKey::Math(chain.value()),
        }
    }

    fn verify(&self, problem: &Problem, response: &[i32]) -> Reward {
        let Some(body) = parse_format(response) else {
            return Reward::bad_format();
        };
        let AnswerKey::Math(want) = problem.answer else {
            return Reward::bad_format();
        };
        let tok = Tokenizer::new();
        match tok.decode_int(body) {
            Some(got) => Reward::graded(got == want),
            None => Reward::bad_format(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_value_matches_intermediates() {
        let c = Chain { start: 3, steps: vec![(Op::Add, 5), (Op::Mul, 2), (Op::Sub, 4)] };
        assert_eq!(c.intermediates(), vec![8, 16, 12]);
        assert_eq!(c.value(), 12);
    }

    #[test]
    fn generated_chains_bounded_and_exact_division() {
        let mut r = Pcg64::new(7);
        for d in 2..=8 {
            let c = generate_chain(&mut r, d);
            assert_eq!(c.steps.len(), d);
            let mut acc = c.start;
            for &(op, k) in &c.steps {
                if op == Op::Div {
                    assert_eq!(acc % k, 0, "non-exact division generated");
                }
                acc = op.apply(acc, k);
                assert!(acc.abs() <= 999);
            }
        }
    }

    #[test]
    fn sft_target_passes_own_verifier() {
        let task = MathTask;
        let mut r = Pcg64::new(11);
        for d in 2..=8 {
            let prob = task.generate(&mut r, d, 0);
            let reward = task.verify(&prob, &prob.sft_target);
            assert!(reward.correct, "d={d}");
        }
    }

    #[test]
    fn wrong_integer_graded_incorrect() {
        let task = MathTask;
        let mut r = Pcg64::new(13);
        let prob = task.generate(&mut r, 3, 0);
        let tok = Tokenizer::new();
        let AnswerKey::Math(v) = prob.answer else { unreachable!() };
        let mut resp = vec![THINK_OPEN, THINK_CLOSE, ANS_OPEN];
        resp.extend(tok.encode_int(v + 1));
        resp.extend([ANS_CLOSE, EOS]);
        let reward = task.verify(&prob, &resp);
        assert!(reward.format_ok && !reward.correct);
    }

    #[test]
    fn cot_length_linear_in_depth() {
        let task = MathTask;
        let mut r = Pcg64::new(17);
        let len = |d: u32, r: &mut Pcg64| -> f64 {
            (0..40)
                .map(|i| task.generate(r, d, i).sft_target.len())
                .sum::<usize>() as f64
                / 40.0
        };
        let l2 = len(2, &mut r);
        let l8 = len(8, &mut r);
        assert!(l8 > l2 * 2.0, "{l2} vs {l8}");
    }

    #[test]
    fn prompt_decodes_to_valid_expression() {
        let tok = Tokenizer::new();
        let mut r = Pcg64::new(19);
        let c = generate_chain(&mut r, 4);
        let text = tok.decode(&prompt_tokens(&c, &tok));
        assert!(text.starts_with("<bos> MATH ( ( ( ("));
        assert!(text.ends_with("= ?"));
    }
}
