//! Knights & Knaves puzzle generator + truth-table solver + verifier.
//!
//! Mirrors the LogicRL dataset (Xie et al. 2025): n in 3..=7 characters,
//! each makes exactly one statement; knights always tell the truth, knaves
//! always lie; exactly one consistent assignment exists.
//!
//! The synthetic chain-of-thought enumerates candidate assignments in a
//! problem-seeded order until the solution is found — so harder puzzles
//! (and unlucky enumeration orders) produce longer targets, reproducing the
//! length-difficulty correlation the paper's scheduler exploits.

use super::{parse_format, AnswerKey, Problem, Reward, Task};
use crate::tokenizer::{
    Tokenizer, AND, ARROW, BOS, CHECK, COLON, EOS, FALSE_WORD, IFF, KNAVE, KNIGHT,
    LOGIC, LPAREN, OR, PERSON0, QMARK, RPAREN, SAYS, SEP, SO, THINK_CLOSE,
    THINK_OPEN, TRUE_WORD, ANS_CLOSE, ANS_OPEN, DIGIT0,
};
use crate::util::rng::Pcg64;

/// One statement made by a speaker about other islanders.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Statement {
    /// "Pj is a knight/knave"
    Claim { about: usize, knight: bool },
    /// "Pj and Pk are the same kind"
    Iff { a: usize, b: usize },
    /// "Pj is X AND Pk is Y"
    Both { a: usize, a_knight: bool, b: usize, b_knight: bool },
    /// "Pj is X OR Pk is Y"
    Either { a: usize, a_knight: bool, b: usize, b_knight: bool },
}

impl Statement {
    /// Truth value of the statement under an assignment (bit i = Pi knight).
    pub fn eval(&self, assign: u32) -> bool {
        let knight = |i: usize| assign & (1 << i) != 0;
        match *self {
            Statement::Claim { about, knight: k } => knight(about) == k,
            Statement::Iff { a, b } => knight(a) == knight(b),
            Statement::Both { a, a_knight, b, b_knight } => {
                knight(a) == a_knight && knight(b) == b_knight
            }
            Statement::Either { a, a_knight, b, b_knight } => {
                knight(a) == a_knight || knight(b) == b_knight
            }
        }
    }
}

/// A complete puzzle: person i utters `statements[i]`.
#[derive(Debug, Clone)]
pub struct Puzzle {
    pub n: usize,
    pub statements: Vec<Statement>,
}

impl Puzzle {
    /// An assignment is a model iff every statement's truth value equals its
    /// speaker's knight-ness.
    pub fn is_model(&self, assign: u32) -> bool {
        self.statements.iter().enumerate().all(|(i, s)| {
            let speaker_knight = assign & (1 << i) != 0;
            s.eval(assign) == speaker_knight
        })
    }

    /// All satisfying assignments (brute force over 2^n).
    pub fn models(&self) -> Vec<u32> {
        (0..1u32 << self.n).filter(|&a| self.is_model(a)).collect()
    }
}

/// Anyone but the speaker (self-reference makes degenerate puzzles).
fn other(rng: &mut Pcg64, n: usize, speaker: usize) -> usize {
    loop {
        let j = rng.range_usize(0, n);
        if j != speaker {
            return j;
        }
    }
}

fn random_statement(rng: &mut Pcg64, n: usize, speaker: usize) -> Statement {
    match rng.below(4) {
        0 => Statement::Claim { about: other(rng, n, speaker), knight: rng.bool_with(0.5) },
        1 => {
            let a = other(rng, n, speaker);
            loop {
                let b = rng.range_usize(0, n);
                if b != a && b != speaker {
                    return Statement::Iff { a, b };
                }
            }
        }
        2 => Statement::Both {
            a: other(rng, n, speaker),
            a_knight: rng.bool_with(0.5),
            b: other(rng, n, speaker),
            b_knight: rng.bool_with(0.5),
        },
        _ => Statement::Either {
            a: other(rng, n, speaker),
            a_knight: rng.bool_with(0.5),
            b: other(rng, n, speaker),
            b_knight: rng.bool_with(0.5),
        },
    }
}

/// Generate a puzzle with exactly one model.
pub fn generate_puzzle(rng: &mut Pcg64, n: usize) -> (Puzzle, u32) {
    loop {
        let statements = (0..n).map(|i| random_statement(rng, n, i)).collect();
        let p = Puzzle { n, statements };
        let models = p.models();
        if models.len() == 1 {
            return (p, models[0]);
        }
    }
}

fn statement_tokens(speaker: usize, s: &Statement) -> Vec<i32> {
    let person = |i: usize| PERSON0 + i as i32;
    let role = |k: bool| if k { KNIGHT } else { KNAVE };
    let mut t = vec![person(speaker), SAYS];
    match *s {
        Statement::Claim { about, knight } => t.extend([person(about), role(knight)]),
        Statement::Iff { a, b } => {
            t.extend([LPAREN, person(a), IFF, person(b), RPAREN])
        }
        Statement::Both { a, a_knight, b, b_knight } => t.extend([
            person(a), role(a_knight), AND, person(b), role(b_knight),
        ]),
        Statement::Either { a, a_knight, b, b_knight } => t.extend([
            person(a), role(a_knight), OR, person(b), role(b_knight),
        ]),
    }
    t
}

/// `<bos> LOGIC <n> ; stmt ; stmt ; ... ?`
pub fn prompt_tokens(p: &Puzzle) -> Vec<i32> {
    let mut t = vec![BOS, LOGIC, DIGIT0 + p.n as i32, SEP];
    for (i, s) in p.statements.iter().enumerate() {
        t.extend(statement_tokens(i, s));
        t.push(SEP);
    }
    t.push(QMARK);
    t
}

/// `<answer>` body: `P0 : K ; P1 : N ; ...` (no trailing SEP).
pub fn answer_tokens(n: usize, solution: u32) -> Vec<i32> {
    let mut t = Vec::new();
    for i in 0..n {
        if i > 0 {
            t.push(SEP);
        }
        let role = if solution & (1 << i) != 0 { KNIGHT } else { KNAVE };
        t.extend([PERSON0 + i as i32, COLON, role]);
    }
    t
}

/// Synthetic CoT: `check r0 r1 .. -> false ;` per tried assignment, ending
/// with the solution (`-> true`), then `so`.  `max_checks` caps length.
pub fn cot_tokens(p: &Puzzle, solution: u32, rng: &mut Pcg64, max_checks: usize) -> Vec<i32> {
    let n = p.n;
    let mut order: Vec<u32> = (0..1u32 << n).collect();
    rng.shuffle(&mut order);
    let sol_idx = order.iter().position(|&a| a == solution).unwrap();
    let mut tried: Vec<u32> = if sol_idx + 1 <= max_checks {
        order[..=sol_idx].to_vec()
    } else {
        // keep the tail so the trace still ends at the solution
        let mut v = order[sol_idx + 1 - max_checks..=sol_idx].to_vec();
        v.dedup();
        v
    };
    // the solution is always the last check
    debug_assert_eq!(tried.pop(), Some(solution));
    let mut t = Vec::new();
    for a in tried {
        t.push(CHECK);
        for i in 0..n {
            t.push(if a & (1 << i) != 0 { KNIGHT } else { KNAVE });
        }
        t.extend([ARROW, FALSE_WORD, SEP]);
    }
    t.push(CHECK);
    for i in 0..n {
        t.push(if solution & (1 << i) != 0 { KNIGHT } else { KNAVE });
    }
    t.extend([ARROW, TRUE_WORD, SEP, SO]);
    t
}

pub struct LogicTask {
    /// Cap on enumeration lines in the synthetic CoT (token budget control).
    pub max_checks: usize,
}

impl Default for LogicTask {
    fn default() -> Self {
        Self { max_checks: 12 }
    }
}

impl Task for LogicTask {
    fn name(&self) -> &'static str {
        "logic"
    }

    fn difficulty_range(&self) -> (u32, u32) {
        (3, 7)
    }

    fn generate(&self, rng: &mut Pcg64, difficulty: u32, id: u64) -> Problem {
        let n = difficulty as usize;
        assert!((3..=7).contains(&n), "difficulty = #characters in 3..=7");
        let (puzzle, solution) = generate_puzzle(rng, n);
        let prompt = prompt_tokens(&puzzle);
        let mut sft = vec![THINK_OPEN];
        sft.extend(cot_tokens(&puzzle, solution, rng, self.max_checks));
        sft.push(THINK_CLOSE);
        sft.push(ANS_OPEN);
        sft.extend(answer_tokens(n, solution));
        sft.push(ANS_CLOSE);
        sft.push(EOS);
        let answer = (0..n).map(|i| solution & (1 << i) != 0).collect();
        Problem {
            id,
            difficulty,
            prompt,
            sft_target: sft,
            answer: AnswerKey::Logic(answer),
        }
    }

    fn verify(&self, problem: &Problem, response: &[i32]) -> Reward {
        let Some(body) = parse_format(response) else {
            return Reward::bad_format();
        };
        let AnswerKey::Logic(ref want) = problem.answer else {
            return Reward::bad_format();
        };
        match parse_logic_answer(body, want.len()) {
            Some(got) => Reward::graded(&got == want),
            None => Reward::bad_format(),
        }
    }
}

/// Parse `P0 : K ; P1 : N ; ...` strictly (persons in order, one role each).
pub fn parse_logic_answer(body: &[i32], n: usize) -> Option<Vec<bool>> {
    let mut out = Vec::with_capacity(n);
    let mut it = body.iter().copied().peekable();
    for i in 0..n {
        if i > 0 && it.next()? != SEP {
            return None;
        }
        if it.next()? != PERSON0 + i as i32 {
            return None;
        }
        if it.next()? != COLON {
            return None;
        }
        match it.next()? {
            t if t == KNIGHT => out.push(true),
            t if t == KNAVE => out.push(false),
            _ => return None,
        }
    }
    if it.next().is_some() {
        return None;
    }
    Some(out)
}

/// Pretty-print a puzzle for docs / debugging.
pub fn render(p: &Puzzle, tok: &Tokenizer) -> String {
    tok.decode(&prompt_tokens(p))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg64 {
        Pcg64::new(123)
    }

    #[test]
    fn generated_puzzles_have_unique_solution() {
        let mut r = rng();
        for n in 3..=7 {
            let (p, sol) = generate_puzzle(&mut r, n);
            let models = p.models();
            assert_eq!(models, vec![sol], "n={n}");
        }
    }

    #[test]
    fn statement_eval_matches_semantics() {
        // P0 says "P1 is a knight" — true iff bit1 set.
        let s = Statement::Claim { about: 1, knight: true };
        assert!(s.eval(0b10));
        assert!(!s.eval(0b00));
        let iff = Statement::Iff { a: 0, b: 1 };
        assert!(iff.eval(0b11) && iff.eval(0b00));
        assert!(!iff.eval(0b01));
        let both = Statement::Both { a: 0, a_knight: true, b: 1, b_knight: false };
        assert!(both.eval(0b01));
        assert!(!both.eval(0b11));
        let either = Statement::Either { a: 0, a_knight: false, b: 1, b_knight: true };
        assert!(either.eval(0b10) && either.eval(0b00));
        assert!(!either.eval(0b01));
    }

    #[test]
    fn sft_target_passes_own_verifier() {
        let task = LogicTask::default();
        let mut r = rng();
        for d in 3..=7 {
            let prob = task.generate(&mut r, d, 0);
            let reward = task.verify(&prob, &prob.sft_target);
            assert!(reward.correct && reward.format_ok, "d={d}");
            assert_eq!(reward.total(), Reward::MAX);
        }
    }

    #[test]
    fn wrong_answer_graded_incorrect() {
        let task = LogicTask::default();
        let mut r = rng();
        let prob = task.generate(&mut r, 3, 0);
        let AnswerKey::Logic(want) = &prob.answer else { unreachable!() };
        // flip one role in the answer block
        let mut resp = prob.sft_target.clone();
        let pos = resp.iter().rposition(|&t| t == KNIGHT || t == KNAVE).unwrap();
        resp[pos] = if resp[pos] == KNIGHT { KNAVE } else { KNIGHT };
        let reward = task.verify(&prob, &resp);
        assert!(reward.format_ok && !reward.correct);
        assert!(want.len() == 3);
    }

    #[test]
    fn truncated_response_is_bad_format() {
        let task = LogicTask::default();
        let mut r = rng();
        let prob = task.generate(&mut r, 4, 0);
        let cut = prob.sft_target.len() / 2;
        let reward = task.verify(&prob, &prob.sft_target[..cut]);
        assert!(!reward.format_ok);
        assert_eq!(reward.total(), -1.0);
    }

    #[test]
    fn cot_length_grows_with_difficulty() {
        let task = LogicTask { max_checks: 64 };
        let mut r = rng();
        let avg_len = |d: u32, r: &mut Pcg64| -> f64 {
            (0..30)
                .map(|i| task.generate(r, d, i).sft_target.len())
                .sum::<usize>() as f64
                / 30.0
        };
        let l3 = avg_len(3, &mut r);
        let l7 = avg_len(7, &mut r);
        assert!(l7 > l3 * 1.5, "expected length growth: {l3} vs {l7}");
    }

    #[test]
    fn parse_logic_answer_strictness() {
        let good = answer_tokens(3, 0b101);
        assert_eq!(parse_logic_answer(&good, 3), Some(vec![true, false, true]));
        // wrong person order
        let mut bad = good.clone();
        bad.swap(0, 4);
        assert_eq!(parse_logic_answer(&bad, 3), None);
        // trailing garbage
        let mut trail = good.clone();
        trail.push(SEP);
        assert_eq!(parse_logic_answer(&trail, 3), None);
        // too few
        assert_eq!(parse_logic_answer(&good, 4), None);
    }

    #[test]
    fn prompt_round_trips_through_tokenizer() {
        let tok = Tokenizer::new();
        let mut r = rng();
        let (p, _) = generate_puzzle(&mut r, 5);
        let text = render(&p, &tok);
        assert_eq!(tok.encode(&text).unwrap(), prompt_tokens(&p));
    }
}
