//! Rule-verifiable task substrates.
//!
//! Two task families mirror the paper's two datasets (§4.1):
//!   * [`logic`] — Knights & Knaves puzzles (LogicRL stand-in), difficulty
//!     3..=7 characters, generated with a truth-table solver so every
//!     puzzle has a unique solution.
//!   * [`math`]  — integer arithmetic chains (DAPO-Math stand-in),
//!     difficulty = expression depth, integer answers.
//!
//! Both emit prompts in the shared symbolic vocabulary and verify responses
//! with rule-based rewards (format + correctness), the same outcome-reward
//! setup the paper trains with.

pub mod logic;
pub mod math;

use crate::util::rng::Pcg64;

/// A generated problem instance.
#[derive(Debug, Clone)]
pub struct Problem {
    pub id: u64,
    pub difficulty: u32,
    /// `<bos> ... ?` — what the rollout engine is fed.
    pub prompt: Vec<i32>,
    /// `<think> ... </think> <answer> ... </answer> <eos>` — supervised
    /// warm-start target (stands in for starting from an instruct model).
    pub sft_target: Vec<i32>,
    pub answer: AnswerKey,
}

#[derive(Debug, Clone, PartialEq)]
pub enum AnswerKey {
    /// Role of each person (true = knight).
    Logic(Vec<bool>),
    Math(i64),
}

/// Reward decomposition (Logic-RL-style shaping).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Reward {
    pub format: f64,
    pub answer: f64,
    pub format_ok: bool,
    pub correct: bool,
}

impl Reward {
    pub fn total(&self) -> f64 {
        self.format + self.answer
    }

    pub fn bad_format() -> Self {
        Reward { format: -1.0, answer: 0.0, format_ok: false, correct: false }
    }

    pub fn graded(correct: bool) -> Self {
        Reward {
            format: 1.0,
            answer: if correct { 2.0 } else { -1.5 },
            format_ok: true,
            correct,
        }
    }

    /// Maximum achievable total (for normalizing validation scores).
    pub const MAX: f64 = 3.0;
}

pub trait Task: Send + Sync {
    fn name(&self) -> &'static str;

    /// Inclusive difficulty range this task generates.
    fn difficulty_range(&self) -> (u32, u32);

    /// Generate one problem at the given difficulty.
    fn generate(&self, rng: &mut Pcg64, difficulty: u32, id: u64) -> Problem;

    /// Grade a generated response (response tokens only, prompt excluded).
    fn verify(&self, problem: &Problem, response: &[i32]) -> Reward;

    /// Generate at a difficulty sampled uniformly from the task's range.
    fn generate_any(&self, rng: &mut Pcg64, id: u64) -> Problem {
        let (lo, hi) = self.difficulty_range();
        let d = rng.range_i64(lo as i64, hi as i64 + 1) as u32;
        self.generate(rng, d, id)
    }
}

/// Shared format check: `<think> ... </think> <answer> BODY </answer> <eos>?`
/// Returns the answer body on success.  The trailing EOS is optional because
/// harvest-at-cap can clip it — correctness should not depend on the clip.
pub fn parse_format(response: &[i32]) -> Option<&[i32]> {
    use crate::tokenizer::{ANS_CLOSE, ANS_OPEN, EOS, PAD, THINK_CLOSE, THINK_OPEN};
    // strip trailing PAD / EOS
    let mut end = response.len();
    while end > 0 && (response[end - 1] == PAD || response[end - 1] == EOS) {
        end -= 1;
    }
    let r = &response[..end];
    if r.first() != Some(&THINK_OPEN) {
        return None;
    }
    let tc = r.iter().position(|&t| t == THINK_CLOSE)?;
    let ao = tc + r[tc..].iter().position(|&t| t == ANS_OPEN)?;
    let ac = ao + r[ao..].iter().position(|&t| t == ANS_CLOSE)?;
    // nothing after </answer>
    if ac + 1 != r.len() {
        return None;
    }
    // no stray structural tokens inside the answer body
    let body = &r[ao + 1..ac];
    if body.iter().any(|&t| {
        t == THINK_OPEN || t == THINK_CLOSE || t == ANS_OPEN || t == ANS_CLOSE
    }) {
        return None;
    }
    Some(body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::*;

    #[test]
    fn parse_format_happy_path() {
        let r = [THINK_OPEN, CHECK, THINK_CLOSE, ANS_OPEN, DIGIT0 + 4, ANS_CLOSE, EOS];
        assert_eq!(parse_format(&r), Some(&r[4..5]));
    }

    #[test]
    fn parse_format_allows_missing_eos() {
        let r = [THINK_OPEN, THINK_CLOSE, ANS_OPEN, DIGIT0, ANS_CLOSE];
        assert!(parse_format(&r).is_some());
    }

    #[test]
    fn parse_format_rejects_missing_think() {
        let r = [ANS_OPEN, DIGIT0, ANS_CLOSE, EOS];
        assert!(parse_format(&r).is_none());
    }

    #[test]
    fn parse_format_rejects_trailing_tokens() {
        let r = [THINK_OPEN, THINK_CLOSE, ANS_OPEN, DIGIT0, ANS_CLOSE, CHECK, EOS];
        assert!(parse_format(&r).is_none());
    }

    #[test]
    fn parse_format_rejects_nested_markers() {
        let r = [THINK_OPEN, THINK_CLOSE, ANS_OPEN, ANS_OPEN, ANS_CLOSE, EOS];
        assert!(parse_format(&r).is_none());
    }

    #[test]
    fn reward_totals() {
        assert_eq!(Reward::bad_format().total(), -1.0);
        assert_eq!(Reward::graded(true).total(), 3.0);
        assert_eq!(Reward::graded(false).total(), -0.5);
        assert_eq!(Reward::graded(true).total(), Reward::MAX);
    }
}
