//! Bounded-channel trainer hand-off: the live path's second thread.
//!
//! [`Pipeline`] owns the pair of rendezvous channels connecting the
//! controller's engine-stepping loop to a trainer worker running on its
//! own (scoped) thread.  The controller issues an update batch and keeps
//! stepping `EnginePool` while the worker grinds through train_step; the
//! result (the post-update weights + log row) is harvested at the NEXT
//! issue point, so at most one update is in flight and the serving policy
//! lags the trainer by at most one logical update — the paper's one-step
//! off-policy pipeline, with the `--staleness` cap enforced upstream by
//! [`crate::coordinator::buffer::RolloutBuffer::consume_bounded`].
//!
//! The channels are `sync_channel(1)`: `issue` on a full pipe and `wait`
//! on an empty one both block, so backpressure is structural — the
//! controller can never run ahead of the trainer by more than the one
//! in-flight batch, and the worker never buffers results the controller
//! has not consumed.
//!
//! Generic over job/result types so the deterministic tests below can
//! drive it with an injected-latency stub instead of a real `Trainer`
//! (constructing a `Runtime` needs compiled HLO artifacts).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::thread::{Scope, ScopedJoinHandle};

/// One in-flight trainer hand-off (see module docs).  Lives inside a
/// [`std::thread::scope`] so the worker may borrow non-`'static` state
/// (the trainer borrows `Runtime`).
pub struct Pipeline<'scope, J: Send, R: Send> {
    job_tx: SyncSender<J>,
    res_rx: Receiver<R>,
    handle: ScopedJoinHandle<'scope, ()>,
    in_flight: usize,
    issued: usize,
}

impl<'scope, J: Send + 'scope, R: Send + 'scope> Pipeline<'scope, J, R> {
    /// Spawn the worker inside `scope`.  `work` runs once per issued job,
    /// in issue order, on the worker thread.
    pub fn spawn<'env, F>(scope: &'scope Scope<'scope, 'env>, mut work: F) -> Self
    where
        F: FnMut(J) -> R + Send + 'scope,
    {
        let (job_tx, job_rx) = sync_channel::<J>(1);
        let (res_tx, res_rx) = sync_channel::<R>(1);
        let handle = scope.spawn(move || {
            // exits when the controller drops its job sender (shutdown) or
            // stops harvesting results (abandoned pipeline)
            while let Ok(job) = job_rx.recv() {
                if res_tx.send(work(job)).is_err() {
                    break;
                }
            }
        });
        Self { job_tx, res_rx, handle, in_flight: 0, issued: 0 }
    }

    /// Hand a job to the worker.  Blocks only if the rendezvous slot is
    /// full — callers keep `in_flight() <= 1` by `wait`ing first, so in
    /// practice this returns immediately.
    pub fn issue(&mut self, job: J) {
        self.job_tx.send(job).expect("trainer worker died");
        self.in_flight += 1;
        self.issued += 1;
    }

    /// Jobs issued but not yet harvested (0 or 1 under the controller's
    /// discipline).
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Total jobs ever issued — the controller's `exhausted()` budget
    /// counts updates ISSUED, not installed, so the final in-flight
    /// update is not double-scheduled during drain.
    pub fn issued(&self) -> usize {
        self.issued
    }

    /// Block until the oldest in-flight job completes.
    pub fn wait(&mut self) -> R {
        assert!(self.in_flight > 0, "wait with nothing in flight");
        let r = self.res_rx.recv().expect("trainer worker died");
        self.in_flight -= 1;
        r
    }

    /// Non-blocking harvest: the completed result if the worker has
    /// finished, `None` if it is still running (or nothing is in flight).
    pub fn try_harvest(&mut self) -> Option<R> {
        if self.in_flight == 0 {
            return None;
        }
        match self.res_rx.try_recv() {
            Ok(r) => {
                self.in_flight -= 1;
                Some(r)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => panic!("trainer worker died"),
        }
    }

    /// Drain every in-flight result, stop the worker, and join it.
    /// Propagates a worker panic so a crashed trainer fails the run
    /// instead of silently truncating it.
    pub fn shutdown(mut self) -> Vec<R> {
        let mut rest = Vec::new();
        while self.in_flight > 0 {
            rest.push(self.wait());
        }
        drop(self.job_tx); // worker's recv() errors -> loop exits
        self.handle.join().expect("trainer worker panicked");
        rest
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::buffer::{Lifecycle, RolloutBuffer};
    use crate::coordinator::trainer::entry_staleness;
    use crate::rollout::{Request, Rollout};
    use std::thread;
    use std::time::{Duration, Instant};

    #[test]
    fn results_arrive_in_issue_order() {
        thread::scope(|s| {
            let mut p = Pipeline::spawn(s, |j: u32| j * 10);
            p.issue(1);
            assert_eq!(p.wait(), 10);
            p.issue(2);
            p.issue(3); // fills the rendezvous slot behind the in-flight job
            assert_eq!(p.wait(), 20);
            assert_eq!(p.wait(), 30);
            assert_eq!(p.issued(), 3);
            assert!(p.shutdown().is_empty());
        });
    }

    #[test]
    fn try_harvest_is_nonblocking() {
        thread::scope(|s| {
            let mut p = Pipeline::spawn(s, |j: u32| {
                thread::sleep(Duration::from_millis(50));
                j
            });
            assert_eq!(p.try_harvest(), None, "nothing in flight");
            p.issue(7);
            // the worker is still sleeping; harvest must not block
            let first = p.try_harvest();
            assert!(first.is_none() || first == Some(7));
            assert_eq!(p.shutdown(), if first.is_some() { vec![] } else { vec![7] });
        });
    }

    /// The tentpole's acceptance assertion: with an injected trainer
    /// latency, the threaded hand-off finishes in strictly less
    /// wall-clock than the measured serial (generate-then-train) loop.
    /// Margins are generous — per-iteration overlap saves a full
    /// `TRAIN` sleep, so the ideal gap is `TRAIN * (JOBS - 1)` and we
    /// only require beating serial at all.
    #[test]
    fn overlapped_pipeline_beats_serial_wall_clock() {
        const GEN: Duration = Duration::from_millis(25);
        const TRAIN: Duration = Duration::from_millis(25);
        const JOBS: usize = 4;

        // serial reference: every update blocks generation
        let t0 = Instant::now();
        for _ in 0..JOBS {
            thread::sleep(GEN);
            thread::sleep(TRAIN);
        }
        let serial = t0.elapsed();

        // threaded: train job j while generating batch j+1
        let t0 = Instant::now();
        thread::scope(|s| {
            let mut p = Pipeline::spawn(s, |j: usize| {
                thread::sleep(TRAIN);
                j
            });
            for j in 0..JOBS {
                thread::sleep(GEN); // "EnginePool stepping"
                if p.in_flight() > 0 {
                    p.wait(); // harvest the previous update first
                }
                p.issue(j);
            }
            assert_eq!(p.shutdown().len(), 1);
        });
        let threaded = t0.elapsed();

        assert!(
            threaded < serial,
            "pipelined {threaded:?} did not beat serial {serial:?}"
        );
    }

    fn finished(rid: u64, born: u64) -> Rollout {
        Rollout {
            request: Request {
                rid,
                problem_idx: 0,
                prompt_id: rid,
                prompt: vec![1, 2],
                resumed: vec![],
                resumed_logp: vec![],
                born_version: Some(born),
                resumes: 0,
                max_new: 64,
                predicted_len: None,
            },
            response: vec![5, 6],
            logp: vec![-0.5, -0.5],
            finish_version: born,
            complete: true,
            finished_at: 1.0,
        }
    }

    /// Satellite-5 end-to-end: cache + channel together.  Samples flow
    /// from a staleness-aware `RolloutBuffer` through the pipeline to an
    /// injected-latency trainer stub; the consume-time cap must guarantee
    /// no batch the worker ever sees contains a sample older than
    /// `--staleness`, with over-stale work re-synced (not silently
    /// trained) along the way.
    #[test]
    fn no_lane_trains_beyond_staleness_cap() {
        const CAP: u64 = 1;
        thread::scope(|s| {
            // the worker reports the max staleness it actually trained on
            let mut p = Pipeline::spawn(s, |(batch, v_enter): (Vec<_>, u64)| {
                thread::sleep(Duration::from_millis(2)); // injected latency
                batch
                    .iter()
                    .map(|e| entry_staleness(e, v_enter))
                    .max()
                    .unwrap_or(0)
            });

            let mut buf = RolloutBuffer::new();
            let a = buf.load_prompt(0, 0, vec![1, 2], 64);
            let b = buf.load_prompt(1, 1, vec![1, 2], 64);
            let mut version = 0u64;
            let mut observed = Vec::new();

            // a finishes on-policy and trains immediately
            buf.dispatch_stamped(&[a, b], version);
            buf.record_finished(&finished(a, 0));
            let out = buf.consume_bounded(&[a], version, Some(CAP));
            p.issue((out.entries, version));

            // b straggles: by the time it is harvested the trainer has
            // finished a's update plus two more elsewhere, and b (born at
            // 0) is 3 versions stale — the cap must bounce it back to
            // schedulable instead of letting the trainer see it
            observed.push(p.wait()); // a's update installs
            version += 1;
            version += 2; // two further updates land elsewhere
            buf.record_finished(&finished(b, 0));
            let out = buf.consume_bounded(&[b], version, Some(CAP));
            assert!(out.entries.is_empty(), "stale sample reached the trainer");
            assert_eq!(out.resynced, vec![b], "first violation re-syncs");
            assert_eq!(buf.get(b).unwrap().lifecycle, Lifecycle::Scavenged);

            // b regenerates under the current weights and now passes
            buf.dispatch_stamped(&[b], version);
            buf.record_finished(&finished(b, version));
            let out = buf.consume_bounded(&[b], version, Some(CAP));
            assert_eq!(out.entries.len(), 1);
            p.issue((out.entries, version));

            observed.extend(p.shutdown());
            assert!(!observed.is_empty());
            assert!(
                observed.iter().all(|&st| st <= CAP),
                "trained on staleness {observed:?} > cap {CAP}"
            );
        });
    }
}
